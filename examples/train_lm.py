"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps with
the full production stack — Mirage numerics, microbatched gradient
accumulation, BFP gradient compression, fault-tolerant checkpointing, and
deterministic resumable data.

  PYTHONPATH=src python examples/train_lm.py --steps 200          # full run
  PYTHONPATH=src python examples/train_lm.py --steps 20 --small   # quick look

Kill it mid-run (Ctrl-C) and re-run with --resume: it checkpoints on
preemption and continues from the exact batch it would have seen.
"""

import argparse
import dataclasses

import jax

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import ModelConfig, TrainConfig
from repro.core.precision import get_policy
from repro.data.pipeline import SyntheticLM, SyntheticLMConfig
from repro.models import build_model
from repro.models.lm import LMCallOptions
from repro.runtime.elastic import (PreemptionGuard, StragglerMitigator,
                                   fault_tolerant_train_loop)
from repro.runtime.trainer import init_train_state


def lm_100m() -> ModelConfig:
    """~100M dense LM (qwen2-style GQA family)."""
    return ModelConfig(
        arch_id="lm-100m", family="dense", n_layers=10, d_model=640,
        n_heads=10, n_kv_heads=2, d_ff=2560, vocab_size=16000, head_dim=64,
        qkv_bias=True, tie_embeddings=True)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--policy", default="mirage")
    ap.add_argument("--small", action="store_true")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/mirage_train_lm")
    args = ap.parse_args()

    cfg = lm_100m()
    if args.small:
        cfg = dataclasses.replace(cfg, n_layers=4, d_model=256, d_ff=1024,
                                  vocab_size=4000, n_heads=4, n_kv_heads=2)
    n_params_est = (cfg.vocab_size * cfg.d_model
                    + cfg.n_layers * (3 * cfg.d_model * cfg.d_ff
                                      + 2 * cfg.d_model * cfg.d_model
                                      + 2 * cfg.d_model * cfg.n_kv_heads
                                      * cfg.resolved_head_dim))
    print(f"model ~{n_params_est/1e6:.0f}M params, policy={args.policy}")

    policy = get_policy(args.policy)
    tc = TrainConfig(policy=policy, optimizer="adamw", lr=3e-4,
                     microbatches=args.microbatches,
                     grad_compression="bfp")   # error-feedback BFP all-reduce
    model = build_model(cfg, policy, LMCallOptions(q_chunk=64, kv_chunk=64))
    state = init_train_state(model, tc, jax.random.PRNGKey(0))

    data = SyntheticLM(SyntheticLMConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, batch_size=args.batch))
    ckpt = Checkpointer(args.ckpt_dir, keep_last=2)
    if args.resume and ckpt.latest_step() is not None:
        state, meta = ckpt.restore(state)
        if meta and "data" in (meta or {}):
            data.restore(meta["data"])
        print(f"resumed at step {int(state['step'])}")

    state, metrics = fault_tolerant_train_loop(
        model, tc, state, iter(data), args.steps, ckpt, ckpt_every=25,
        guard=PreemptionGuard(), straggler=StragglerMitigator())
    print(f"done at step {int(state['step'])}: "
          f"loss={float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
