"""Batched serving demo: the continuous-batching engine (one jitted decode
over a stacked slot cache) with streaming token callbacks. Works for every
architecture family in the zoo — try --arch mamba2-2.7b (SSM state cache)
or --arch mixtral-8x7b (MoE + SWA ring cache).

  PYTHONPATH=src python examples/serve_lm.py --arch qwen2-0.5b
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.precision import get_policy
from repro.models import build_model
from repro.models.lm import LMCallOptions
from repro.runtime.server import LMServer, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-tokens", type=int, default=10)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--policy", default="mirage")
    ap.add_argument("--cache-layout", choices=("dense", "paged"),
                    default="dense",
                    help="paged = block-table KV pool for long-context memory")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="piggybacked prefill chunk size (paged only)")
    ap.add_argument("--block-size", type=int, default=4,
                    help="positions per KV block (paged only; small enough "
                         "that the demo's 8-token shared prefix spans "
                         "full blocks)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share matched prompt-prefix blocks copy-on-write "
                         "(paged only; the demo prompts share 8 tokens)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="self-draft + verify this many tokens per tick "
                         "(paged only, token-identical to greedy)")
    ap.add_argument("--stream", action="store_true",
                    help="print tokens as they are emitted")
    args = ap.parse_args()
    if (args.prefix_cache or args.spec_k) and args.cache_layout != "paged":
        ap.error("--prefix-cache / --spec-k require --cache-layout paged")

    cfg = get_config(args.arch).reduced()
    policy = get_policy(args.policy)
    model = build_model(cfg, policy, LMCallOptions(q_chunk=32, kv_chunk=32))
    params = model.init(jax.random.PRNGKey(0))
    on_token = (lambda req, tok: print(f"  [req {req.rid}] -> {tok}")) \
        if args.stream else None
    server = LMServer(model, params,
                      cap=args.prompt_len + args.max_tokens + 4,
                      batch_slots=args.slots, on_token=on_token,
                      cache_layout=args.cache_layout,
                      block_size=args.block_size,
                      prefill_chunk=args.prefill_chunk,
                      prefix_cache=args.prefix_cache,
                      spec_k=args.spec_k)

    rng = np.random.default_rng(7)
    shared = rng.integers(0, cfg.vocab_size,
                          min(8, args.prompt_len)).astype(np.int32)
    t0 = time.perf_counter()
    for rid in range(args.requests):
        tail = rng.integers(0, cfg.vocab_size,
                            args.prompt_len - len(shared)).astype(np.int32)
        server.submit(Request(
            rid=rid,
            prompt=np.concatenate([shared, tail]),
            max_tokens=args.max_tokens))
    finished = server.run_until_drained()
    dt = time.perf_counter() - t0
    toks = sum(len(r.tokens_out) for r in finished)
    lat = server.scheduler.latency_summary()
    print(f"{args.arch}: {len(finished)} requests, {toks} tokens, "
          f"{toks/dt:.1f} tok/s, {server.metrics['ticks']} decode ticks, "
          f"TTFT {lat['ttft_mean_s']*1e3:.1f}ms, "
          f"TPOT {lat['tpot_mean_s']*1e3:.1f}ms")
    if args.prefix_cache:
        print(f"  prefix hits: {server.metrics['prefix_hits']} "
              f"({server.metrics['prefix_shared_blocks']} blocks shared)")
    if args.spec_k:
        m = server.metrics
        print(f"  spec accepted/tick: "
              f"{m['spec_accepted'] / max(m['spec_slot_ticks'], 1):.2f}")


if __name__ == "__main__":
    main()
