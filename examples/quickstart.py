"""Quickstart: train a small LM with Mirage (BFP+RNS) numerics in ~2 minutes.

  PYTHONPATH=src python examples/quickstart.py

What this shows:
  1. every GEMM (forward AND backward) runs the paper's BFP(b_m=4, g=16)
     quantization via `mirage_matmul`'s custom_vjp;
  2. FP32 master weights are updated by a plain FP32 optimizer (paper Eq. 4);
  3. the loss goes down just like FP32 training (paper Table I's claim,
     at demo scale).
"""

import jax

from repro.configs import get_config
from repro.configs.base import TrainConfig
from repro.core.precision import get_policy
from repro.data.pipeline import SyntheticLM, SyntheticLMConfig
from repro.models import build_model
from repro.models.lm import LMCallOptions
from repro.runtime.trainer import init_train_state, train_loop


def main():
    cfg = get_config("qwen2-0.5b").reduced()   # tiny same-family config
    policy = get_policy("mirage")              # the paper's operating point
    print(f"policy: {policy.mode} b_m={policy.b_m} g={policy.g} "
          f"moduli={policy.moduli} (M={policy.rns_M})")

    model = build_model(cfg, policy, LMCallOptions(q_chunk=32, kv_chunk=32))
    tc = TrainConfig(policy=policy, optimizer="adamw", lr=1e-3)
    state = init_train_state(model, tc, jax.random.PRNGKey(0))

    data = SyntheticLM(SyntheticLMConfig(
        vocab_size=cfg.vocab_size, seq_len=48, batch_size=4))
    state, metrics = train_loop(model, tc, state, iter(data), n_steps=40,
                                log_every=5)
    print(f"final loss {float(metrics['loss']):.4f} — "
          f"Mirage numerics train like FP32.")


if __name__ == "__main__":
    main()
