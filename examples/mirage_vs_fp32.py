"""Numerics showcase: the paper's central claims, observable in minutes.

  PYTHONPATH=src python examples/mirage_vs_fp32.py
  PYTHONPATH=src python examples/mirage_vs_fp32.py --snr-db 45 --rrns

1. RNS EXACTNESS (Section II-D): a BFP-mantissa GEMM computed through
   {31,32,33} residues + CRT equals the direct integer GEMM bit-for-bit.
2. GEMM ERROR (Section V-A sensitivity): BFP(b_m, g) quantization error vs
   FP32 for b_m in {3,4,5}, reproducing the shape of Fig. 5a's trade-off.
3. TRAINING PARITY (Table I): the same small LM trained under FP32 / bf16 /
   Mirage / INT8 — Mirage tracks FP32, INT8 lags.
4. NOISE + RRNS (Section VII, with --snr-db/--rrns): the analog channel at
   a finite detector SNR corrupts the uncorrected RNS GEMM; redundant-RNS
   majority decoding (``mirage_rrns``) recovers the accuracy.
"""

import argparse

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.base import TrainConfig
from repro.core import gemm, rns
from repro.core.precision import MiragePolicy, get_policy
from repro.data.pipeline import SyntheticLM, SyntheticLMConfig
from repro.models import build_model
from repro.models.lm import LMCallOptions
from repro.runtime.trainer import init_train_state, train_loop


def rns_exactness():
    print("=== 1. RNS exactness (residue GEMM + CRT == integer GEMM) ===")
    rng = np.random.default_rng(0)
    x = rng.integers(-15, 16, size=(8, 16)).astype(np.float32)
    w = rng.integers(-15, 16, size=(16, 8)).astype(np.float32)
    direct = x @ w
    via_rns = np.asarray(rns.rns_dot_reconstruct(jnp.asarray(x), jnp.asarray(w), k=5))
    print(f"  max |direct - rns| = {np.abs(direct - via_rns).max():.1f} "
          f"(exact: {np.array_equal(direct, via_rns)})")


def gemm_error():
    print("=== 2. BFP GEMM error vs b_m (cf. Fig 5a trade-off) ===")
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(32, 256)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(256, 32)).astype(np.float32))
    ref = np.asarray(gemm.mirage_matmul_nograd(x, w, get_policy("fp32")))
    for b_m in (3, 4, 5, 6):
        p = MiragePolicy(mode="mirage_fast", b_m=b_m, g=16, k=max(5, b_m + 2))
        out = np.asarray(gemm.mirage_matmul_nograd(x, w, p))
        rel = np.abs(out - ref).max() / np.abs(ref).max()
        print(f"  b_m={b_m}: max rel err {rel:.4f}")


def training_parity(steps=30):
    print("=== 3. Training parity (cf. Table I) ===")
    cfg = get_config("qwen2-0.5b").reduced()
    data_cfg = SyntheticLMConfig(vocab_size=cfg.vocab_size, seq_len=48,
                                 batch_size=4)
    results = {}
    for name in ("fp32", "bf16", "mirage", "int8"):
        policy = get_policy(name)
        model = build_model(cfg, policy, LMCallOptions(q_chunk=32, kv_chunk=32))
        tc = TrainConfig(policy=policy, optimizer="adamw", lr=1e-3)
        state = init_train_state(model, tc, jax.random.PRNGKey(0))
        state, metrics = train_loop(model, tc, state,
                                    iter(SyntheticLM(data_cfg)), steps,
                                    log_every=0)
        results[name] = float(metrics["loss"])
        print(f"  {name:8s}: final loss {results[name]:.4f}")
    gap_mirage = results["mirage"] - results["fp32"]
    gap_int8 = results["int8"] - results["fp32"]
    print(f"  -> Mirage-FP32 gap {gap_mirage:+.4f}; INT8-FP32 gap {gap_int8:+.4f}")


def noise_recovery(snr_db: float, with_rrns: bool):
    from repro.analog import sweep
    print(f"=== 4. Analog channel @ {snr_db:g} dB SNR"
          + (" + RRNS correction" if with_rrns else "") + " ===")
    modes = ["mirage_rns_noisy"] + (["mirage_rrns"] if with_rrns else [])
    rows = sweep.gemm_error_sweep(snr_dbs=(snr_db,), modes=modes,
                                  shape=(16, 128, 16), seed=4)
    for r in rows:
        print(f"  {r['mode']:18s}: rel err {r['rel_fro_err']:.4f}, "
              f"corrupted outputs {r['corrupt_frac']*100:.1f}%")
    if with_rrns:
        print("  -> majority decoding over the redundant moduli repairs the"
              " single-residue errors the bare channel lets through")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--snr-db", type=float, default=None,
                    help="detector SNR for the analog-channel demo (e.g. 45)")
    ap.add_argument("--rrns", action="store_true",
                    help="also run the RRNS-corrected backend in the demo")
    ap.add_argument("--skip-training", action="store_true",
                    help="skip the (slow) training-parity section")
    args = ap.parse_args()
    rns_exactness()
    gemm_error()
    if not args.skip_training:
        training_parity()
    if args.snr_db is not None or args.rrns:
        noise_recovery(args.snr_db if args.snr_db is not None else 45.0,
                       args.rrns)
