"""Structured benchmark output: tee CSV lines to stdout AND collect JSON.

Every bench section emits ``section,name,value[,extra]`` CSV lines through
a ``print_fn`` (or plain ``print``). :class:`BenchWriter` is a drop-in
``print_fn`` that forwards each line to a real stream and, in parallel,
parses it into a structured row — so ``--json PATH`` works for every
section without touching the sections themselves. It can also swallow raw
row dicts (``add_rows``) from sections that are natively structured
(``bench_noise``).

JSON schema:
    {"meta": {"argv": [...], "elapsed_s": ..., ...},
     "rows": [{"section": ..., "name": ..., "value": ..., "extra": ...} |
              <native row dict>, ...]}
"""

from __future__ import annotations

import contextlib
import io
import json
import os
import sys
from typing import Dict, Iterable, List, Optional


def _maybe_float(s: str):
    try:
        return float(s)
    except ValueError:
        return s


class BenchWriter:
    """print_fn-compatible collector of benchmark rows."""

    def __init__(self, stream=None):
        self.stream = stream if stream is not None else sys.stdout
        self.rows: List[Dict] = []
        self.meta: Dict = {}

    def __call__(self, *args) -> None:
        line = " ".join(str(a) for a in args)
        print(line, file=self.stream)
        self.record_line(line)

    def record_line(self, line: str) -> None:
        line = line.strip()
        if not line or line.startswith("#"):
            return
        parts = line.split(",")
        if len(parts) < 3:
            return
        self.rows.append({
            "section": parts[0],
            "name": parts[1],
            "value": _maybe_float(parts[2]),
            "extra": ",".join(parts[3:]),
        })

    def add_rows(self, rows: Iterable[Dict]) -> None:
        self.rows.extend(rows)

    @contextlib.contextmanager
    def capture_stdout(self):
        """Capture sections that print directly to stdout: everything still
        reaches the terminal, CSV-shaped lines are also recorded."""
        writer = self

        class _Tee(io.TextIOBase):
            def __init__(self):
                self._buf = ""

            def write(self, s):
                writer.stream.write(s)
                self._buf += s
                while "\n" in self._buf:
                    line, self._buf = self._buf.split("\n", 1)
                    writer.record_line(line)
                return len(s)

            def flush(self):
                writer.stream.flush()

        with contextlib.redirect_stdout(_Tee()):
            yield self

    def write_json(self, path: str, **meta) -> None:
        payload = {"meta": {**self.meta, **meta}, "rows": self.rows}
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(payload, f, indent=2)
        print(f"# wrote {len(self.rows)} rows to {path}", file=self.stream)
