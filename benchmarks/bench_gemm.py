"""Table II + Fig. 5b analog: MAC-level energy/area/frequency for Mirage vs
systolic-array formats, and the pJ/MAC sensitivity sweep over (b_m, g)."""

from __future__ import annotations

from benchmarks import hw_model as hm


def table_ii(print_fn=print):
    hw = hm.MirageHW()
    p_rx = hm.calibrate_p_rx(hw)
    e = hw.energy_per_mac_pj(p_rx)
    area = hw.area_mm2()
    mac_area = area["total_3d"] / (hw.n_units * hw.rows * hw.g)
    print_fn("# Table II analog: pJ/MAC, mm2/MAC, freq")
    print_fn(f"table2,mirage_pj_mac,{e['total']:.3f},paper=0.21(calibrated)")
    print_fn(f"table2,mirage_mm2_mac,{mac_area:.4f},paper=0.12")
    print_fn(f"table2,mirage_freq_hz,{hm.PHOTONIC_CLOCK_HZ:.0f},paper=10GHz")
    print_fn(f"table2,calibrated_p_rx_w,{p_rx:.3e},shot-noise-floor-fit")
    for fmt, (pj, mm2, f) in hm.SYSTOLIC_FORMATS.items():
        print_fn(f"table2,{fmt}_pj_mac,{pj},published")
    ratios = {f: hm.SYSTOLIC_FORMATS[f][0] / e["total"]
              for f in hm.SYSTOLIC_FORMATS}
    print_fn(f"table2,energy_ratio_vs_fp32,{ratios['FP32']:.1f},paper=59.1x")
    print_fn(f"table2,energy_ratio_vs_int8,{ratios['INT8']:.1f},paper=2x")
    return e


def fig_5b(print_fn=print):
    print_fn("# Fig 5b analog: pJ/MAC vs (b_m, g)")
    p_rx = hm.calibrate_p_rx(hm.MirageHW())
    k_for_bm = {3: 4, 4: 5, 5: 6}
    for b_m, k in k_for_bm.items():
        for g in (4, 8, 16, 32, 64):
            # Eq. 10: need log2 M >= 2(b_m+1)+log2 g-1
            import math
            M = (2**k - 1) * 2**k * (2**k + 1)
            need = 2 * (b_m + 1) + math.log2(g) - 1
            kk = k
            while math.log2(M) < need:
                kk += 1
                M = (2**kk - 1) * 2**kk * (2**kk + 1)
            hw = hm.MirageHW(g=g, b_m=b_m, k=kk)
            e = hw.energy_per_mac_pj(p_rx)["total"]
            print_fn(f"fig5b,bm{b_m}_g{g}_k{kk},{e:.4f},pJ/MAC")


def fig_9(print_fn=print):
    hw = hm.MirageHW()
    p_rx = hm.calibrate_p_rx(hw)
    pw = hw.peak_power_w(p_rx)
    ar = hw.area_mm2()
    print_fn("# Fig 9 analog: peak power + area breakdown")
    for k, v in pw.items():
        print_fn(f"fig9,power_{k}_w,{v:.3f},paper_total=19.95W")
    for k, v in ar.items():
        print_fn(f"fig9,area_{k}_mm2,{v:.1f},paper_total3d=234mm2")
    frac_sram = pw["sram"] / pw["total"]
    print_fn(f"fig9,sram_power_fraction,{frac_sram:.2f},paper=0.612")


def main(print_fn=print):
    table_ii(print_fn)
    fig_5b(print_fn)
    fig_9(print_fn)


if __name__ == "__main__":
    main()
