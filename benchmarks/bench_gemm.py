"""Table II + Fig. 5b analog: MAC-level energy/area/frequency for Mirage vs
systolic-array formats, the pJ/MAC sensitivity sweep over (b_m, g), and the
wall-clock before/after comparison of the group-batched GEMM backends
against the seed fori_loop implementations (paper point b_m=4, g=16, k=5)."""

from __future__ import annotations

import time

from benchmarks import hw_model as hm


def table_ii(print_fn=print):
    hw = hm.MirageHW()
    p_rx = hm.calibrate_p_rx(hw)
    e = hw.energy_per_mac_pj(p_rx)
    area = hw.area_mm2()
    mac_area = area["total_3d"] / (hw.n_units * hw.rows * hw.g)
    print_fn("# Table II analog: pJ/MAC, mm2/MAC, freq")
    print_fn(f"table2,mirage_pj_mac,{e['total']:.3f},paper=0.21(calibrated)")
    print_fn(f"table2,mirage_mm2_mac,{mac_area:.4f},paper=0.12")
    print_fn(f"table2,mirage_freq_hz,{hm.PHOTONIC_CLOCK_HZ:.0f},paper=10GHz")
    print_fn(f"table2,calibrated_p_rx_w,{p_rx:.3e},shot-noise-floor-fit")
    for fmt, (pj, mm2, f) in hm.SYSTOLIC_FORMATS.items():
        print_fn(f"table2,{fmt}_pj_mac,{pj},published")
    ratios = {f: hm.SYSTOLIC_FORMATS[f][0] / e["total"]
              for f in hm.SYSTOLIC_FORMATS}
    print_fn(f"table2,energy_ratio_vs_fp32,{ratios['FP32']:.1f},paper=59.1x")
    print_fn(f"table2,energy_ratio_vs_int8,{ratios['INT8']:.1f},paper=2x")
    return e


def fig_5b(print_fn=print):
    print_fn("# Fig 5b analog: pJ/MAC vs (b_m, g)")
    p_rx = hm.calibrate_p_rx(hm.MirageHW())
    k_for_bm = {3: 4, 4: 5, 5: 6}
    for b_m, k in k_for_bm.items():
        for g in (4, 8, 16, 32, 64):
            # Eq. 10: need log2 M >= 2(b_m+1)+log2 g-1
            import math
            M = (2**k - 1) * 2**k * (2**k + 1)
            need = 2 * (b_m + 1) + math.log2(g) - 1
            kk = k
            while math.log2(M) < need:
                kk += 1
                M = (2**kk - 1) * 2**kk * (2**kk + 1)
            hw = hm.MirageHW(g=g, b_m=b_m, k=kk)
            e = hw.energy_per_mac_pj(p_rx)["total"]
            print_fn(f"fig5b,bm{b_m}_g{g}_k{kk},{e:.4f},pJ/MAC")


def fig_9(print_fn=print):
    hw = hm.MirageHW()
    p_rx = hm.calibrate_p_rx(hw)
    pw = hw.peak_power_w(p_rx)
    ar = hw.area_mm2()
    print_fn("# Fig 9 analog: peak power + area breakdown")
    for k, v in pw.items():
        print_fn(f"fig9,power_{k}_w,{v:.3f},paper_total=19.95W")
    for k, v in ar.items():
        print_fn(f"fig9,area_{k}_mm2,{v:.1f},paper_total3d=234mm2")
    frac_sram = pw["sram"] / pw["total"]
    print_fn(f"fig9,sram_power_fraction,{frac_sram:.2f},paper=0.612")


def _bench_pair(f_ref, f_new, x, w, iters=9):
    """Median ms/call for both callables, samples interleaved (the shared
    container's CPU clock is noisy — interleaving keeps the comparison fair)."""
    import jax
    import numpy as np
    jax.block_until_ready((f_ref(x, w), f_new(x, w)))  # compile + warm
    t_ref, t_new = [], []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(f_ref(x, w))
        t_ref.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(f_new(x, w))
        t_new.append(time.perf_counter() - t0)
    return np.median(t_ref) * 1e3, np.median(t_new) * 1e3


def _assert_decode_matches_oracle():
    """Bit-parity of the fused RRNS decode vs the frozen numpy oracle on a
    randomized corruption sample — gate before any rrns timing is reported."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.analog import rrns
    from repro.core import noise
    from repro.core.precision import special_moduli

    base = list(special_moduli(5))
    allm = base + list(rrns.default_redundant_moduli(5))
    psi = (int(np.prod(base)) - 1) // 2
    tables = rrns.build_tables(allm, 3, psi)
    rng = np.random.default_rng(7)
    xs = rng.integers(-psi, psi + 1, size=2048)
    res = np.stack([np.mod(xs, m) for m in allm]).astype(np.int32)
    for j in range(res.shape[1]):
        if j % 3 == 0:
            continue
        p = rng.integers(0, len(allm))
        res[p, j] = rng.integers(0, allm[p])
        if j % 5 == 0:
            q = (p + 1) % len(allm)
            res[q, j] = rng.integers(0, allm[q])
    dec, cor = jax.jit(lambda r: rrns.rrns_decode(r, tables))(jnp.asarray(res))
    dec_np, cor_np = noise.rrns_decode_np(res.astype(np.int64), allm, 3, psi)
    if not (np.array_equal(np.asarray(dec), dec_np)
            and np.array_equal(np.asarray(cor), cor_np)):
        raise AssertionError(
            "fused rrns_decode is not bit-identical to the rrns_decode_np "
            "oracle — refusing to benchmark a decode that computes "
            "different answers")


def gemm_walltime(print_fn=print, iters=9):
    """Vectorized group-batched backends vs the seed fori_loop references.

    Paper operating point (b_m=4, g=16, k=5). Shapes cover the serving
    decode regime (M=1, where the seed's G sequential dispatches dominate),
    a wide-MLP prefill slice, and a square training GEMM. Outputs are
    asserted bit-identical before timing.

    The ``rrns`` rows compare the error-corrected path before/after this
    PR's fast-path work: ``mirage_rrns_ref`` (per-call weight encode +
    subset-loop decode, frozen) vs ``mirage_rrns`` executing against
    admission-time stationary residues with the fused single-pass decode.
    The fused decode is bit-checked against the frozen ``rrns_decode_np``
    oracle and both backends' outputs asserted identical before timing.
    """
    import jax
    import numpy as np
    import jax.numpy as jnp
    from repro.core import gemm as gemm_mod, stationary
    from repro.core.precision import get_policy

    print_fn("# gemm wall-clock: group-batched backends vs seed fori_loop")
    shapes = {
        "decode_1x2048x2048": (1, 2048, 2048),
        "wide_8x1024x4096": (8, 1024, 4096),
        "prefill_16x2048x2048": (16, 2048, 2048),
        "train_256x1024x256": (256, 1024, 256),
    }
    pairs = {"faithful": ("mirage_faithful_ref", "mirage_faithful"),
             "rns": ("mirage_rns_ref", "mirage_rns")}
    rng = np.random.default_rng(0)
    results = {}
    for sname, (M, K, N) in shapes.items():
        x = jnp.asarray(rng.normal(size=(M, K)).astype(np.float32))
        w = jnp.asarray(rng.normal(size=(K, N)).astype(np.float32))
        for pname, (ref_mode, new_mode) in pairs.items():
            if pname == "rns" and M * K * N > 1 << 25:
                continue  # seed RNS at large shapes takes minutes; skip
            p_ref, p_new = get_policy(ref_mode), get_policy(new_mode)
            f_ref = jax.jit(lambda a, b, pp=p_ref: gemm_mod.mirage_matmul_nograd(a, b, pp))
            f_new = jax.jit(lambda a, b, pp=p_new: gemm_mod.mirage_matmul_nograd(a, b, pp))
            same = np.array_equal(np.asarray(f_ref(x, w)), np.asarray(f_new(x, w)))
            if not same:
                raise AssertionError(
                    f"{new_mode} is not bit-identical to {ref_mode} at "
                    f"{sname} — refusing to report a speedup for a backend "
                    f"that computes different answers")
            ms_ref, ms_new = _bench_pair(f_ref, f_new, x, w, iters=iters)
            speedup = ms_ref / ms_new
            results[(sname, pname)] = speedup
            print_fn(f"gemm,{pname}_{sname},{ms_ref:.2f}->{ms_new:.2f}ms,"
                     f"{speedup:.1f}x,bitexact={same}")

    # error-corrected path, large-N serving-decode regime (this is where
    # the pre-PR per-call weight encode + O(S^2) vote dominated walltime)
    _assert_decode_matches_oracle()
    sname, (M, K, N) = "rrns_decode_8x2048x2048", (8, 2048, 2048)
    x = jnp.asarray(rng.normal(size=(M, K)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(K, N)).astype(np.float32))
    p_ref, p_new = get_policy("mirage_rrns_ref"), get_policy("mirage_rrns")
    sw = stationary.encode_stationary(w, p_new)        # once per admission
    f_ref = jax.jit(lambda a, b, pp=p_ref: gemm_mod.mirage_matmul_nograd(a, b, pp))
    f_new = jax.jit(lambda a, b, pp=p_new: gemm_mod.mirage_matmul_nograd(a, b, pp))
    same = np.array_equal(np.asarray(f_ref(x, w)), np.asarray(f_new(x, sw)))
    if not same:
        raise AssertionError(
            "mirage_rrns (fused decode + stationary residues) is not "
            "bit-identical to mirage_rrns_ref — refusing to report a "
            "speedup for a backend that computes different answers")
    ms_ref, ms_new = _bench_pair(f_ref, lambda a, b: f_new(a, sw), x, w,
                                 iters=max(3, iters // 2))
    speedup = ms_ref / ms_new
    results[(sname, "rrns")] = speedup
    print_fn(f"gemm,rrns_{sname},{ms_ref:.2f}->{ms_new:.2f}ms,"
             f"{speedup:.1f}x,bitexact={same}")
    return results


def main(print_fn=print):
    table_ii(print_fn)
    fig_5b(print_fn)
    fig_9(print_fn)
    gemm_walltime(print_fn)


if __name__ == "__main__":
    main()
