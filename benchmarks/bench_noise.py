"""Accuracy-vs-SNR campaign: analog channel + RRNS correction (§VII).

  PYTHONPATH=src python -m benchmarks.bench_noise                 # full sweep
  PYTHONPATH=src python -m benchmarks.bench_noise --quick         # CI smoke
  PYTHONPATH=src python -m benchmarks.bench_noise --json out.json

Sections (Fig. 10-style, cf. arXiv:2309.10759):
  noise_gemm   relative GEMM error + corrupted-output fraction vs detector
               SNR for mirage_rns_noisy (uncorrected) and mirage_rrns
               (majority-decoded), referenced to noiseless mirage_rns
  noise_train  small-LM final train loss vs SNR for the same two modes,
               anchored by noiseless mirage_rns and fp32 runs
"""

from __future__ import annotations

import argparse
import sys
import time

from benchmarks.emit import BenchWriter
from repro.analog import sweep


def noise_gemm(print_fn=print, snr_dbs=sweep.DEFAULT_SNR_DBS,
               shape=(32, 256, 32)):
    print_fn("# Fig 10 analog: GEMM error vs detector SNR, +-RRNS correction")
    rows = sweep.gemm_error_sweep(snr_dbs=snr_dbs, shape=shape)
    for r in rows:
        print_fn(f"noise_gemm,{r['mode']}_snr{r['snr_db']:g},"
                 f"{r['rel_fro_err']:.5f},"
                 f"corrupt_frac={r['corrupt_frac']:.5f}")
    return rows


def noise_train(print_fn=print, snr_dbs=(40.0, 50.0), steps=12):
    print_fn("# train-loss vs SNR: RRNS recovers what the noisy path loses")
    rows = sweep.train_loss_sweep(snr_dbs=snr_dbs, steps=steps)
    for r in rows:
        tag = (f"{r['mode']}_snr{r['snr_db']:g}" if r["snr_db"] is not None
               else r["mode"])
        print_fn(f"noise_train,{tag},{r['loss']:.4f},steps={steps}")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: noise_gemm,noise_train")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write rows as structured JSON")
    ap.add_argument("--quick", action="store_true",
                    help="tiny sweep (CI smoke): 3 SNR points, 4 train steps")
    ap.add_argument("--steps", type=int, default=12,
                    help="training steps per noise_train point")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None

    def want(name):
        return only is None or name in only

    writer = BenchWriter()
    t0 = time.time()
    if args.quick:
        gemm_snrs, train_snrs, steps = (40.0, 44.0, 48.0), (45.0,), 4
        shape = (16, 128, 16)
    else:
        gemm_snrs, train_snrs, steps = (sweep.DEFAULT_SNR_DBS, (40.0, 50.0),
                                        args.steps)
        shape = (32, 256, 32)
    # sections print CSV to stdout; the JSON gets the richer native rows
    if want("noise_gemm"):
        writer.add_rows(noise_gemm(print, snr_dbs=gemm_snrs, shape=shape))
    if want("noise_train"):
        writer.add_rows(noise_train(print, snr_dbs=train_snrs, steps=steps))
    elapsed = time.time() - t0
    print(f"# bench_noise done in {elapsed:.1f}s")
    if args.json:
        writer.write_json(args.json, argv=list(argv or sys.argv[1:]),
                          elapsed_s=round(elapsed, 2))
    return 0


if __name__ == "__main__":
    sys.exit(main())
