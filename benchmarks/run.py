"""Benchmark harness: one section per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --only table1,fig8
  PYTHONPATH=src python -m benchmarks.run --json results/bench.json

Output: ``name,value,derived`` CSV lines per section, plus a Roofline dump
if results/dryrun_baseline.json exists (produced by repro.launch.dryrun).
``--json PATH`` additionally writes every CSV row as structured JSON
(``benchmarks.emit.BenchWriter``) so trajectories are machine-readable.
"""

from __future__ import annotations

import argparse
import contextlib
import json
import os
import sys
import time


def roofline_section(print_fn=print):
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "results", "dryrun_baseline.json")
    if not os.path.exists(path):
        print_fn("roofline,skipped,0,run repro.launch.dryrun first")
        return
    rows = json.load(open(path))
    print_fn("# Roofline terms from the compiled dry-run (seconds/step)")
    for r in rows:
        if r.get("status") != "ok":
            continue
        tag = f"{r['arch']}|{r['shape']}|{r['mesh']}"
        print_fn(f"roofline,{tag},{r['dominant']},"
                 f"compute={r['compute_s']:.4f};memory={r['memory_s']:.4f};"
                 f"collective={r['collective_s']:.4f};"
                 f"frac={r['roofline_fraction']:.4f}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: table1,table2,fig5a,fig5b,fig6,fig7,"
                         "fig8,fig9,table3,gemm,ops,noise,serving,roofline")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write every row as structured JSON")
    ap.add_argument("--steps", type=int, default=None,
                    help="training steps for table1/fig5a (CI smoke uses a "
                         "small value; default: each section's own)")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None

    def want(*names):
        return only is None or bool(only.intersection(names))

    from benchmarks.emit import BenchWriter
    writer = BenchWriter()
    t0 = time.time()
    from benchmarks import (bench_accuracy, bench_dataflow, bench_gemm,
                            bench_noise, bench_ops)

    # capture stdout too: sections that ignore print_fn still land in JSON
    with writer.capture_stdout() if args.json else contextlib.nullcontext():
        if want("table2"):
            bench_gemm.table_ii()
        if want("fig5b"):
            bench_gemm.fig_5b()
        if want("fig9"):
            bench_gemm.fig_9()
        if want("gemm"):
            bench_gemm.gemm_walltime()
        if want("fig6"):
            bench_dataflow.fig_6()
        if want("fig7"):
            bench_dataflow.fig_7()
        if want("fig8"):
            bench_dataflow.fig_8()
        if want("table3"):
            bench_dataflow.table_iii()
        if want("ops"):
            bench_ops.main()
        if want("table1"):
            if args.steps:
                bench_accuracy.table_i(steps=args.steps)
            else:
                bench_accuracy.table_i()
        if want("fig5a"):
            if args.steps:
                bench_accuracy.fig_5a(steps=args.steps)
            else:
                bench_accuracy.fig_5a()
        if want("noise"):
            bench_noise.noise_gemm()
        if want("serving"):
            from benchmarks import bench_serving
            bench_serving.slots_sweep(slot_counts=(1, 4),
                                      requests_per_slot=2, max_tokens=8)
            # paged-vs-dense cache bytes + chunked-prefill spike (the CI
            # artifact the paged-KV acceptance gate reads)
            bench_serving.paged_sweep(slots=4, long_len=96, max_tokens=8,
                                      chunk=8)
            # prefix caching + speculative decoding (CI artifact gates:
            # >= 2x prefill walltime at 90% overlap; accepted/tick > 1
            # at k=4). The prompt must be long enough that prefill
            # compute dominates dispatch — see prefix_sweep's docstring.
            bench_serving.prefix_sweep(slots=8, prompt_len=512,
                                       overlaps=(0.0, 0.9))
            bench_serving.spec_sweep(slots=4, ks=(0, 2, 4))
            # observability: paired off/on overhead rows (production-path
            # <2% gate, RRNS fault-counter <15% bound) plus the health
            # correctness checks (nonzero corrected at low SNR, zero
            # clean, token parity) — the health asserts always fire; the
            # wall-clock gates stay informational here (the dedicated
            # bench_serving run enforces them)
            bench_serving.obs_sweep(slots=2, n_requests=4, max_tokens=6,
                                    repeats=2, enforce=False)
            # meshed serving: TP token parity + the locality-vs-round-robin
            # placement gate (spilled allocs, peak remote fraction). The
            # children run in subprocesses with forced host devices, so
            # this process keeps its single-device view. All gates are
            # deterministic (token equality / allocation counts), so they
            # stay enforced even at CI scale.
            bench_serving.mesh_sweep(slots=4, tp_list=(1, 2), max_tokens=8,
                                     n_requests=6, enforce=True)
            # SNR-adaptive degradation: guardian-on must stream exact
            # fp32 under a full collapse while guardian-off diverges —
            # deterministic, so the gate stays enforced at CI scale
            bench_serving.degraded_sweep(slots=2, n_requests=4,
                                         max_tokens=6, scales=(1e6,),
                                         enforce=True)
        if want("roofline"):
            roofline_section()
    elapsed = time.time() - t0
    print(f"# benchmarks done in {elapsed:.1f}s")
    if args.json:
        writer.write_json(args.json, argv=list(argv or sys.argv[1:]),
                          elapsed_s=round(elapsed, 2))
    return 0


if __name__ == "__main__":
    sys.exit(main())
