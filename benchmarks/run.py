"""Benchmark harness: one section per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run --only table1,fig8

Output: ``name,value,derived`` CSV lines per section, plus a Roofline dump
if results/dryrun_baseline.json exists (produced by repro.launch.dryrun).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time


def roofline_section(print_fn=print):
    path = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "results", "dryrun_baseline.json")
    if not os.path.exists(path):
        print_fn("roofline,skipped,0,run repro.launch.dryrun first")
        return
    rows = json.load(open(path))
    print_fn("# Roofline terms from the compiled dry-run (seconds/step)")
    for r in rows:
        if r.get("status") != "ok":
            continue
        tag = f"{r['arch']}|{r['shape']}|{r['mesh']}"
        print_fn(f"roofline,{tag},{r['dominant']},"
                 f"compute={r['compute_s']:.4f};memory={r['memory_s']:.4f};"
                 f"collective={r['collective_s']:.4f};"
                 f"frac={r['roofline_fraction']:.4f}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: table1,table2,fig5a,fig5b,fig6,fig7,"
                         "fig8,fig9,table3,ops,roofline")
    args = ap.parse_args(argv)
    only = set(args.only.split(",")) if args.only else None

    def want(*names):
        return only is None or bool(only.intersection(names))

    t0 = time.time()
    from benchmarks import bench_accuracy, bench_dataflow, bench_gemm, bench_ops

    if want("table2"):
        bench_gemm.table_ii()
    if want("fig5b"):
        bench_gemm.fig_5b()
    if want("fig9"):
        bench_gemm.fig_9()
    if want("fig6"):
        bench_dataflow.fig_6()
    if want("fig7"):
        bench_dataflow.fig_7()
    if want("fig8"):
        bench_dataflow.fig_8()
    if want("table3"):
        bench_dataflow.table_iii()
    if want("ops"):
        bench_ops.main()
    if want("table1"):
        bench_accuracy.table_i()
    if want("fig5a"):
        bench_accuracy.fig_5a()
    if want("roofline"):
        roofline_section()
    print(f"# benchmarks done in {time.time()-t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
