"""Fig. 6 + Fig. 7 analogs: spatial utilization vs array sizing, and
per-layer/per-model dataflow latency (DF1/DF2/OPT2) for Mirage vs systolic."""

from __future__ import annotations

from benchmarks import hw_model as hm
from repro.configs import ARCHS


def fig_6(print_fn=print):
    print_fn("# Fig 6 analog: spatial utilization vs rows / n_units (g=16)")
    work = hm.alexnet_gemms() + hm.transformer_gemms()
    for rows in (8, 16, 32, 64, 128):
        u = hm.spatial_utilization(work, rows=rows, g=16, n_units=8)
        print_fn(f"fig6,rows_{rows},{u:.3f},utilization")
    for n_units in (2, 4, 8, 16, 32):
        u = hm.spatial_utilization(work, rows=32, g=16, n_units=n_units)
        print_fn(f"fig6,units_{n_units},{u:.3f},utilization")
    # assigned archs at the chosen 32x16x8 point
    for arch_id in sorted(ARCHS):
        gemms = hm.config_gemms(ARCHS[arch_id], batch=8, seq=512)
        u = hm.spatial_utilization(gemms, rows=32, g=16, n_units=8)
        print_fn(f"fig6,{arch_id},{u:.3f},utilization@32x16x8")


def fig_7(print_fn=print):
    print_fn("# Fig 7 analog: per-step latency by dataflow (batch 256)")
    hw = hm.MirageHW()
    workloads = {
        "alexnet": hm.alexnet_gemms(256),
        "transformer": hm.transformer_gemms(256),
    }
    for name, gemms in workloads.items():
        for df in ("DF1", "DF2", "OPT2"):
            t = hm.training_step_latency_s(gemms, "mirage", hw, dataflow=df)
            print_fn(f"fig7,{name}_mirage_{df},{t*1e3:.3f},ms/step")
        for df in ("DF1", "DF3", "OPT2"):
            t = hm.training_step_latency_s(gemms, "systolic", hw, fmt="INT12",
                                           n_arrays=1, dataflow=df)
            print_fn(f"fig7,{name}_systolic_{df},{t*1e3:.3f},ms/step")
    # paper finding: flexible dataflow (OPT2) helps systolic ~12%, mirage ~0%
    g = workloads["transformer"]
    m_best = min(hm.training_step_latency_s(g, "mirage", hw, dataflow=d)
                 for d in ("DF1", "DF2"))
    m_opt = hm.training_step_latency_s(g, "mirage", hw, dataflow="OPT2")
    s_best = min(hm.training_step_latency_s(g, "systolic", hw, fmt="INT12",
                                            dataflow=d) for d in ("DF1", "DF3"))
    s_opt = hm.training_step_latency_s(g, "systolic", hw, fmt="INT12",
                                       dataflow="OPT2")
    print_fn(f"fig7,mirage_opt2_gain,{(m_best/m_opt-1)*100:.1f},pct(paper~0)")
    print_fn(f"fig7,systolic_opt2_gain,{(s_best/s_opt-1)*100:.1f},pct(paper~12.5)")


def fig_8(print_fn=print):
    print_fn("# Fig 8 analog: iso-energy / iso-area runtime+EDP+power")
    hw = hm.MirageHW()
    p_rx = hm.calibrate_p_rx(hw)
    mirage_pj = hw.energy_per_mac_pj(p_rx)["total"]
    gemms = hm.transformer_gemms(256)
    t_mirage = hm.training_step_latency_s(gemms, "mirage", hw, dataflow="OPT2")
    p_mirage = hw.peak_power_w(p_rx)["total"]
    print_fn(f"fig8,mirage_step_s,{t_mirage:.4f},s/step")
    print_fn(f"fig8,mirage_power_w,{p_mirage:.2f},W")
    for fmt in ("FP32", "INT12", "INT8", "FMAC"):
        for mode in ("iso_energy", "iso_area"):
            if mode == "iso_energy":
                n = hm.iso_energy_arrays(fmt, hw, p_rx)
            else:
                n = hm.iso_area_arrays(fmt, hw)
                if n == 0:
                    continue
            t = hm.training_step_latency_s(gemms, "systolic", hw, fmt=fmt,
                                           n_arrays=n, dataflow="OPT2")
            pj = hm.SYSTOLIC_FORMATS[fmt][0]
            power = (n * hw.rows * hw.g * hm.SYSTOLIC_FORMATS[fmt][2]
                     * pj * 1e-12)
            edp_ratio = (t * t * power) / (t_mirage * t_mirage * p_mirage)
            print_fn(f"fig8,{fmt}_{mode}_arrays,{n},count")
            print_fn(f"fig8,{fmt}_{mode}_step_s,{t:.4f},speedup_vs_mirage="
                     f"{t_mirage/t:.2f}x")
            print_fn(f"fig8,{fmt}_{mode}_power_w,{power:.2f},"
                     f"mirage/systolic={p_mirage/power:.2f}")
            print_fn(f"fig8,{fmt}_{mode}_edp_vs_mirage,{edp_ratio:.2f},"
                     f">1 means mirage better")


def table_iii(print_fn=print):
    print_fn("# Table III analog: inference IPS / IPS-per-W")
    hw = hm.MirageHW()
    p_rx = hm.calibrate_p_rx(hw)
    p = hw.peak_power_w(p_rx)["total"]
    # ResNet50 fwd ~ 4.1 GFLOP -> 2.05 GMAC; AlexNet ~ 0.72 GFLOP
    resnet50 = [(49 * 49, 576, 64)] + [(14 * 14 * 4, 1152, 128)] * 16
    alexnet = hm.alexnet_gemms(1)
    for name, gemms in (("resnet50", resnet50), ("alexnet", alexnet)):
        t = sum(hm.mirage_gemm_latency_opt_s(m, k, n, hw)[0]
                for m, k, n in gemms)
        ips = 1.0 / t
        print_fn(f"table3,{name}_ips,{ips:.0f},paper={10474 if name=='resnet50' else 64963}")
        print_fn(f"table3,{name}_ips_per_w,{ips/p:.1f},paper="
                 f"{1540.6 if name=='resnet50' else 1904.5}")


def main(print_fn=print):
    fig_6(print_fn)
    fig_7(print_fn)
    fig_8(print_fn)
    table_iii(print_fn)


if __name__ == "__main__":
    main()
