"""Serving benchmark: throughput / TTFT / TPOT vs offered load and slots.

Sections (all CSV rows through ``benchmarks.emit``-compatible print_fn,
so ``--json`` makes them machine-readable):

  * ``serving_slots``  — decode throughput of the batched continuous-
    batching engine as slot count grows, against the retained per-slot
    oracle loop at the same occupancy. The ``speedup_slots{n}`` rows are
    the measured batched/oracle ratio (the acceptance gate requires > 1 at
    slots >= 4).
  * ``serving_load``   — open-loop offered load sweep: requests arrive at
    a fixed rate; rows report achieved tok/s, mean TTFT, mean TPOT and
    queue time per offered rate.
  * ``serving_paged``  — cache MEMORY for a mixed-length long-context
    workload: bytes the dense layout allocates (slots x cap rings) vs the
    paged block pool's measured peak (live tokens rounded to blocks). The
    acceptance gate requires >= 2x saving; a second run on a pool sized to
    that peak proves the tight pool actually serves the workload.
  * ``serving_chunked`` — the admission latency spike: per-tick wall times
    while a long prompt arrives into active short-decode streams, with
    monolithic prefill (dense) vs chunked/piggybacked prefill. Rows report
    the max tick (the stall), the steady-state median tick, and the long
    request's TTFT for both engines.
  * ``serving_degraded`` — graceful degradation under an SNR ramp:
    throughput + exact-match-vs-clean-fp32 fraction for the raw
    mirage_rrns engine vs the SNR guardian's verify-before-commit drain,
    per collapse scale. The gate requires guardian-on to be EXACTLY fp32
    at the severest collapse while guardian-off diverges.

  PYTHONPATH=src python -m benchmarks.bench_serving --json out.json
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Optional

import numpy as np


def _build(arch: str, policy_name: str, prompt_len: int, max_tokens: int):
    import jax

    from repro.configs import get_config
    from repro.core.precision import get_policy
    from repro.models import build_model
    from repro.models.lm import LMCallOptions

    cfg = get_config(arch).reduced()
    model = build_model(cfg, get_policy(policy_name),
                        LMCallOptions(q_chunk=32, kv_chunk=32))
    params = model.init(jax.random.PRNGKey(0))
    cap = prompt_len + max_tokens + 4
    return cfg, model, params, cap


def _requests(cfg, n: int, prompt_len: int, max_tokens: int):
    from repro.runtime.server import Request

    rng = np.random.default_rng(0)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        prompt_len).astype(np.int32),
                    max_tokens=max_tokens)
            for i in range(n)]


def _drain(server, reqs):
    """Serve ``reqs`` to completion; returns (tokens, seconds, finished)."""
    for r in reqs:
        server.submit(r)
    t0 = time.perf_counter()
    finished = server.run_until_drained()
    dt = time.perf_counter() - t0
    return sum(len(r.tokens_out) for r in finished), dt, finished


def slots_sweep(print_fn=print, arch: str = "qwen2-0.5b",
                policy: str = "mirage", slot_counts=(1, 2, 4),
                requests_per_slot: int = 3, prompt_len: int = 12,
                max_tokens: int = 16):
    """Batched engine vs per-slot oracle at growing occupancy."""
    from repro.runtime.server import LMServer, PerSlotLMServer

    cfg, model, params, cap = _build(arch, policy, prompt_len, max_tokens)
    print_fn(f"# serving: {arch} policy={policy} prompt={prompt_len} "
             f"max_tokens={max_tokens}")
    speedups = {}
    for slots in slot_counts:
        n_req = slots * requests_per_slot
        results = {}
        for name, cls in (("batched", LMServer), ("oracle", PerSlotLMServer)):
            server = cls(model, params, cap=cap, batch_slots=slots)
            # warm THIS instance's jit caches (each server owns its jitted
            # step functions), then time a steady-state drain
            _drain(server, _requests(cfg, slots, prompt_len, max_tokens))
            toks, dt, _ = _drain(server,
                                 _requests(cfg, n_req, prompt_len, max_tokens))
            results[name] = toks / dt
            print_fn(f"serving_slots,{name}_slots{slots},{toks / dt:.2f},"
                     f"tok_per_s;requests={n_req}")
        speedups[slots] = results["batched"] / results["oracle"]
        print_fn(f"serving_slots,speedup_slots{slots},"
                 f"{speedups[slots]:.3f},batched_over_oracle")
    return speedups


def load_sweep(print_fn=print, arch: str = "qwen2-0.5b",
               policy: str = "mirage", slots: int = 4,
               rates=(4.0, 16.0, 64.0), n_requests: int = 12,
               prompt_len: int = 12, max_tokens: int = 16):
    """Open-loop arrival sweep: submit at a fixed offered rate (req/s) and
    measure achieved throughput and latency percentiles."""
    from repro.runtime.server import LMServer

    from repro.runtime.server import Scheduler

    cfg, model, params, cap = _build(arch, policy, prompt_len, max_tokens)
    # one engine across rates; warm every pow2 admission-batch size so the
    # measured TTFT is serving latency, not prefill compiles
    server = LMServer(model, params, cap=cap, batch_slots=slots)
    bp = 1
    while bp <= slots:
        _drain(server, _requests(cfg, bp, prompt_len, max_tokens))
        bp *= 2

    for rate in rates:
        server.scheduler = Scheduler()      # fresh per-rate metrics
        reqs = _requests(cfg, n_requests, prompt_len, max_tokens)
        t0 = time.perf_counter()
        pending = list(reqs)
        finished = []
        tick_guard = 0
        while (pending or server.scheduler.waiting or
               any(r is not None for r in server.slot_req)):
            now = time.perf_counter() - t0
            while pending and len(reqs) - len(pending) < now * rate:
                server.submit(pending.pop(0))
            if server.scheduler.waiting or \
                    any(r is not None for r in server.slot_req):
                finished.extend(server.tick())
            elif pending:
                time.sleep(0.001)           # idle: next arrival not due yet
            tick_guard += 1
            if tick_guard > 100_000:
                break
        dt = time.perf_counter() - t0
        toks = sum(len(r.tokens_out) for r in finished)
        lat = server.scheduler.latency_summary()
        print_fn(f"serving_load,rate{rate:g}_tok_s,{toks / dt:.2f},"
                 f"slots={slots}")
        print_fn(f"serving_load,rate{rate:g}_ttft_ms,"
                 f"{lat['ttft_mean_s'] * 1e3:.2f},mean")
        print_fn(f"serving_load,rate{rate:g}_tpot_ms,"
                 f"{lat['tpot_mean_s'] * 1e3:.2f},mean")
        for m in ("ttft", "tpot"):
            for q in ("p50", "p95", "p99"):
                print_fn(f"serving_load,rate{rate:g}_{m}_{q}_ms,"
                         f"{lat[f'{m}_{q}_s'] * 1e3:.2f},{q}")
        print_fn(f"serving_load,rate{rate:g}_queue_ms,"
                 f"{lat['queue_mean_s'] * 1e3:.2f},mean")


def _kv_leaf_bytes(spec, keys):
    return sum(int(np.prod(s)) * np.dtype(d).itemsize
               for k, (s, d) in spec.items() if k in keys)


def paged_sweep(print_fn=print, arch: str = "qwen2-0.5b",
                policy: str = "mirage", slots: int = 4,
                block_size: int = 16, short_len: int = 8,
                long_len: int = 192, max_tokens: int = 8,
                chunk: int = 8, enforce: bool = True):
    """Paged-vs-dense cache bytes on a mixed-length workload, then the
    chunked-prefill admission-spike comparison. Returns a dict of headline
    numbers (also printed as CSV rows).

    With ``enforce=True`` (the CI default) the DETERMINISTIC acceptance
    gates raise on regression: cache saving must stay >= 2x and the
    tight-pool rerun must serve the whole workload. The spike ratio is
    wall-clock (noisy on a shared box) and stays informational. Pass
    ``enforce=False`` for exploratory configs where < 2x is expected."""
    import jax

    from repro.models import lm as lm_helpers
    from repro.runtime.server import LMServer, Request

    cap = long_len + max_tokens + block_size
    cfg, model, params, _ = _build(arch, policy, long_len, max_tokens)

    def mixed_requests(rid0=0):
        rng = np.random.default_rng(rid0)
        reqs = [Request(rid=rid0 + i,
                        prompt=rng.integers(0, cfg.vocab_size,
                                            short_len).astype(np.int32),
                        max_tokens=max_tokens)
                for i in range(slots - 1)]
        reqs.append(Request(rid=rid0 + slots - 1,
                            prompt=rng.integers(0, cfg.vocab_size,
                                                long_len).astype(np.int32),
                            max_tokens=max_tokens))
        return reqs

    # ---- cache bytes: dense allocation vs paged peak ----
    dense_spec = model.cache_spec(slots, cap, per_slot_idx=True)
    dense_bytes = _kv_leaf_bytes(
        dense_spec, ("k", "v", "shared_k", "shared_v"))
    server = LMServer(model, params, cap=cap, batch_slots=slots,
                      cache_layout="paged", block_size=block_size)
    if server.alloc is None:
        # pure-SSM archs have no KV to page (O(1) recurrent state per slot)
        print_fn(f"serving_paged,skipped,0,{arch} has no paged KV "
                 f"(pure-SSM recurrent state)")
        return {"cache_saving_ratio": float("nan"),
                "spike_flatten_ratio": float("nan")}
    _drain(server, mixed_requests())
    peak = server.alloc.peak_in_use
    pool_spec = model.cache_spec(slots, cap, per_slot_idx=True,
                                 layout="paged", block_size=block_size,
                                 n_blocks=server.alloc.n_blocks)
    per_block = _kv_leaf_bytes(pool_spec, lm_helpers.PAGE_POOL_LEAVES) \
        // server.alloc.n_blocks
    table_bytes = _kv_leaf_bytes(pool_spec, ("bt",))
    paged_bytes = peak * per_block + table_bytes
    ratio = dense_bytes / max(paged_bytes, 1)
    print_fn(f"# paged KV: {arch} slots={slots} cap={cap} "
             f"lens={slots - 1}x{short_len}+1x{long_len} block={block_size}")
    print_fn(f"serving_paged,cache_bytes_dense,{dense_bytes},"
             f"slots={slots};cap={cap}")
    print_fn(f"serving_paged,cache_bytes_paged,{paged_bytes},"
             f"peak_blocks={peak};block={block_size}")
    print_fn(f"serving_paged,cache_saving_ratio,{ratio:.2f},dense_over_paged")
    if enforce and ratio < 2.0:
        raise RuntimeError(
            f"paged cache saving regressed below the 2x acceptance gate: "
            f"{ratio:.2f}x (dense {dense_bytes} vs paged {paged_bytes})")
    # prove a pool sized to the measured peak serves the same workload
    tight = LMServer(model, params, cap=cap, batch_slots=slots,
                     cache_layout="paged", block_size=block_size,
                     n_blocks=peak)
    _, _, fin = _drain(tight, mixed_requests(rid0=100))
    print_fn(f"serving_paged,tight_pool_completed,{len(fin)},"
             f"n_blocks={peak}")
    if enforce and len(fin) != slots:
        raise RuntimeError(
            f"tight pool ({peak} blocks) failed to serve the workload: "
            f"{len(fin)}/{slots} requests completed")

    # ---- admission spike: monolithic vs chunked prefill ----
    def spike_run(**kw):
        srv = LMServer(model, params, cap=cap, batch_slots=slots, **kw)
        # warm every path this run will hit (incl. the long prefill /
        # every chunk shape) so measured ticks are compute, not compiles
        _drain(srv, mixed_requests(rid0=200))
        reqs = mixed_requests(rid0=300)
        shorts, long_req = reqs[:-1], reqs[-1]
        for r in shorts:
            srv.submit(r)
        srv.tick()                      # admit + first decode, steady state
        ticks = []
        srv.submit(long_req)
        guard = 0
        while (srv.scheduler.waiting or srv.prefilling or
               any(r is not None for r in srv.slot_req)):
            t0 = time.perf_counter()
            srv.tick()
            ticks.append(time.perf_counter() - t0)
            guard += 1
            if guard > 10_000:
                break
        return (max(ticks) * 1e3, float(np.median(ticks)) * 1e3,
                long_req.ttft * 1e3)

    results = {"cache_bytes_dense": dense_bytes,
               "cache_bytes_paged": paged_bytes,
               "cache_saving_ratio": ratio}
    for label, kw in (
            ("dense", {}),
            ("chunked", {"cache_layout": "paged", "block_size": block_size,
                         "prefill_chunk": chunk})):
        spike_ms, median_ms, ttft_ms = spike_run(**kw)
        results[f"{label}_tick_max_ms"] = spike_ms
        print_fn(f"serving_chunked,{label}_tick_max_ms,{spike_ms:.2f},"
                 f"long={long_len};chunk="
                 f"{chunk if label == 'chunked' else 'off'}")
        print_fn(f"serving_chunked,{label}_tick_median_ms,{median_ms:.2f},"
                 f"steady_state")
        print_fn(f"serving_chunked,{label}_long_ttft_ms,{ttft_ms:.2f},mean")
    flatten = results["dense_tick_max_ms"] / \
        max(results["chunked_tick_max_ms"], 1e-9)
    results["spike_flatten_ratio"] = flatten
    print_fn(f"serving_chunked,spike_flatten_ratio,{flatten:.2f},"
             f"dense_over_chunked_max_tick")
    return results


def prefix_sweep(print_fn=print, arch: str = "qwen2-0.5b",
                 policy: str = "mirage", slots: int = 8,
                 block_size: int = 16, prompt_len: int = 512,
                 overlaps=(0.0, 0.5, 0.9), max_tokens: int = 2,
                 enforce: bool = True):
    """Prefix caching: prefill walltime + peak cache blocks vs the fraction
    of the prompt shared across requests (a common-system-prompt workload).
    ``max_tokens`` stays tiny so the drain walltime IS prefill walltime.
    The acceptance gate requires >= 2x walltime reduction at 90% overlap
    (matched full blocks skip prefill entirely; only suffixes run).

    ``prompt_len`` must be large enough that prefill compute dominates
    dispatch: cache-off admits the whole wave as ONE batched prefill while
    prefix admission runs one suffix chunk per matched request, so at
    short prompts the per-call overhead of the serial path swamps the
    FLOP savings and the measured speedup collapses below 1."""
    from repro.runtime.server import LMServer, Request

    cfg, model, params, cap = _build(arch, policy, prompt_len, max_tokens)
    probe = LMServer(model, params, cap=cap, batch_slots=slots,
                     cache_layout="paged", block_size=block_size,
                     prefix_cache=True)
    if not probe.prefix_cache:
        # SSM/hybrid: recurrent state at the match point cannot be skipped
        print_fn(f"serving_prefix,skipped,0,{arch} cannot share prefixes "
                 f"(recurrent state at the match point)")
        return {"prefill_speedup_at_0.9": float("nan")}

    def shared_requests(overlap, rid0=0):
        rng = np.random.default_rng(rid0 + 1)
        shared = rng.integers(0, cfg.vocab_size,
                              int(prompt_len * overlap)).astype(np.int32)
        out = []
        for i in range(slots):
            tail = rng.integers(0, cfg.vocab_size,
                                prompt_len - len(shared)).astype(np.int32)
            out.append(Request(rid=rid0 + i,
                               prompt=np.concatenate([shared, tail]),
                               max_tokens=max_tokens))
        return out

    print_fn(f"# prefix caching: {arch} slots={slots} prompt={prompt_len} "
             f"block={block_size}")
    results = {}
    for overlap in overlaps:
        row = {}
        for label, kw in (("off", {}), ("on", {"prefix_cache": True})):
            server = LMServer(model, params, cap=cap, batch_slots=slots,
                              cache_layout="paged", block_size=block_size,
                              **kw)
            _drain(server, shared_requests(overlap, rid0=1000))  # warm jits
            _, dt, fin = _drain(server, shared_requests(overlap))
            assert len(fin) == slots
            row[label] = dt
            row[f"peak_{label}"] = server.alloc.peak_in_use
            print_fn(f"serving_prefix,overlap{overlap:g}_{label}_wall_ms,"
                     f"{dt * 1e3:.2f},prefill_dominated")
        print_fn(f"serving_prefix,overlap{overlap:g}_peak_blocks,"
                 f"{row['peak_on']},vs_{row['peak_off']}_unshared")
        speedup = row["off"] / max(row["on"], 1e-9)
        results[f"prefill_speedup_at_{overlap:g}"] = speedup
        results[f"peak_blocks_at_{overlap:g}"] = row["peak_on"]
        results[f"peak_blocks_unshared_at_{overlap:g}"] = row["peak_off"]
        print_fn(f"serving_prefix,overlap{overlap:g}_prefill_speedup,"
                 f"{speedup:.2f},off_over_on")
    gate = results.get("prefill_speedup_at_0.9")
    if enforce and gate is not None and gate < 2.0:
        raise RuntimeError(
            f"prefix caching prefill reduction regressed below the 2x "
            f"acceptance gate at 90% overlap: {gate:.2f}x")
    return results


def spec_sweep(print_fn=print, arch: str = "qwen2-0.5b",
               policy: str = "mirage", slots: int = 4,
               block_size: int = 16, prompt_len: int = 12,
               max_tokens: int = 16, ks=(0, 2, 4),
               n_requests: int = 8, enforce: bool = True):
    """Speculative decoding: accepted-tokens/tick and walltime vs draft
    length ``k`` (k=0 is the plain decode baseline). The acceptance gate
    requires mean accepted-tokens per slot-tick > 1 at k=4 — the verify
    step must amortize its per-tick cost over more than one token."""
    from repro.runtime.server import LMServer, Request

    cfg, model, params, cap = _build(arch, policy, prompt_len, max_tokens)

    def reqs(rid0=0):
        rng = np.random.default_rng(7)
        return [Request(rid=rid0 + i,
                        prompt=rng.integers(0, cfg.vocab_size,
                                            prompt_len).astype(np.int32),
                        max_tokens=max_tokens)
                for i in range(n_requests)]

    print_fn(f"# speculative decoding: {arch} slots={slots} "
             f"max_tokens={max_tokens}")
    results = {}
    baseline_toks = None
    for k in ks:
        server = LMServer(model, params, cap=cap, batch_slots=slots,
                          cache_layout="paged", block_size=block_size,
                          spec_k=k)
        _drain(server, reqs(rid0=1000))                       # warm jits
        toks, dt, fin = _drain(server, reqs())
        out = {r.rid: r.tokens_out for r in fin}
        if baseline_toks is None:
            baseline_toks = out
        # exactness is part of the measurement: same tokens at every k
        assert out == baseline_toks, f"spec k={k} diverged from greedy"
        m = server.metrics
        acc = m["spec_accepted"] / max(m["spec_slot_ticks"], 1) \
            if k else 1.0
        results[f"accepted_per_tick_k{k}"] = acc
        results[f"tok_per_s_k{k}"] = toks / dt
        print_fn(f"serving_spec,k{k}_accepted_per_tick,{acc:.3f},"
                 f"ticks={m['ticks']}")
        print_fn(f"serving_spec,k{k}_tok_per_s,{toks / dt:.2f},"
                 f"token_identical_to_greedy")
    gate = results.get("accepted_per_tick_k4")
    if enforce and gate is not None and gate <= 1.0:
        raise RuntimeError(
            f"speculative decoding accepted-tokens/tick regressed to the "
            f"k=4 acceptance gate: {gate:.3f} (must be > 1)")
    return results


def obs_sweep(print_fn=print, arch: str = "qwen2-0.5b", slots: int = 4,
              prompt_len: int = 12, max_tokens: int = 12,
              n_requests: int = 8, repeats: int = 3,
              snr_db: float = 12.0, noise_seed: int = 7,
              enforce: bool = True):
    """Observability overhead + analog-health correctness.

    Overhead is measured PAIRED: the uninstrumented and fully instrumented
    engines (tracer, registry snapshot per drain, health accumulators) are
    built up front, then drained in adjacent off/on pairs and the overhead
    is the median of the PAIRWISE deltas. The box's run-to-run drift is
    several percent — larger than the gate — so only adjacent-pair
    comparisons are meaningful.

    Two policies, two bounds:

      * ``mirage`` (the default production serving path): instrumentation
        is the span tracer + metrics registry + health plumbing (a
        deterministic backend has no record sites). Gate: < 2% overhead.
      * ``mirage_rrns`` at low SNR (the worst case): instrumentation
        additionally keeps exact fault-count reductions live next to every
        GEMM's RRNS decode (~hundreds per tick). On the interpret-mode CPU
        box each live reduction is ~µs of dispatch against a ~0.3 ms
        GEMM+decode, so exact counting costs ~5-10% HERE; on real hardware
        the same per-GEMM scalar sums are noise against the GEMM
        arithmetic. Bound: < 15%, a regression tripwire (e.g. recording an
        unreduced tensor), not a production gate.

    Correctness (always asserted — these are deterministic):

      * low-SNR run reports NONZERO corrected residue faults;
      * the clean run (``snr_db=None``) reports exactly zero for both
        corrected and uncorrected;
      * both instrumented engines emit token-identical output to their
        uninstrumented twins (same noise streams — the counters observe
        the channel, they never perturb it).
    """
    import jax

    from repro.configs import get_config
    from repro.core.precision import get_policy
    from repro.models import build_model
    from repro.models.lm import LMCallOptions
    from repro.obs import trace as obs_trace
    from repro.runtime.server import LMServer

    cfg = get_config(arch).reduced()
    opts = LMCallOptions(q_chunk=32, kv_chunk=32)
    cap = prompt_len + max_tokens + 4

    def paired(policy, n_req):
        """Adjacent off/on drain pairs; returns medians + pairwise
        overhead + last-drain tokens + the instrumented server.

        ``n_req`` sizes the drain per policy: the deterministic engine is
        several times faster than the RRNS one, and fixed per-drain costs
        (the registry snapshot ≈ one Prometheus scrape, which production
        scrapes at O(10 s) cadence, not per 0.1 s) must amortize over
        comparable wall time to weigh them honestly."""
        model = build_model(cfg, policy, opts)
        params = model.init(jax.random.PRNGKey(0))
        servers, rates, tokens = {}, {"off": [], "on": []}, {}
        overheads = []
        try:
            for label, inst in (("off", False), ("on", True)):
                obs_trace.configure(enabled=inst)
                servers[label] = LMServer(model, params, cap=cap,
                                          batch_slots=slots, instrument=inst)
                _drain(servers[label], _requests(cfg, slots, prompt_len,
                                                 max_tokens))      # warm jits
            for _ in range(repeats):
                for label, inst in (("off", False), ("on", True)):
                    obs_trace.configure(enabled=inst)
                    toks, dt, fin = _drain(
                        servers[label],
                        _requests(cfg, n_req, prompt_len, max_tokens))
                    rates[label].append(toks / dt)
                    tokens[label] = {r.rid: list(r.tokens_out) for r in fin}
                    if inst:
                        # the ONE host transfer per snapshot is part of the
                        # instrumented cost — charge it inside the pair
                        servers[label].scheduler.registry.snapshot()
                overheads.append((rates["off"][-1] - rates["on"][-1])
                                 / max(rates["off"][-1], 1e-9) * 100.0)
        finally:
            obs_trace.configure(enabled=False)
        if tokens["on"] != tokens["off"]:
            raise RuntimeError(
                f"instrumentation changed the served tokens under "
                f"{policy.mode} — counters and spans must observe the "
                f"engine, never perturb it")
        return (float(np.median(rates["off"])),
                float(np.median(rates["on"])),
                float(np.median(overheads)), servers["on"])

    print_fn(f"# observability: {arch} slots={slots} requests={n_requests} "
             f"pairs={repeats} (paired off/on drains)")
    results = {}

    # production path: deterministic backend, tracer + metrics only (4x
    # the requests — see paired() on equalizing drain wall time)
    off, on, overhead, _ = paired(get_policy("mirage"), n_requests * 4)
    results["obs_off_tok_s"] = off
    results["obs_on_tok_s"] = on
    results["obs_overhead_pct"] = overhead
    print_fn(f"serving_obs,decode_tok_s_obs_off,{off:.2f},policy=mirage")
    print_fn(f"serving_obs,decode_tok_s_obs_on,{on:.2f},policy=mirage")
    print_fn(f"serving_obs,overhead_pct,{overhead:.2f},gate_lt_2pct")

    # worst case: RRNS fault counters live in every decode
    noisy = get_policy("mirage_rrns", snr_db=snr_db, noise_seed=noise_seed)
    off_r, on_r, overhead_r, server_on = paired(noisy, n_requests)
    health_on = server_on.health_snapshot()
    results["rrns_obs_off_tok_s"] = off_r
    results["rrns_obs_on_tok_s"] = on_r
    results["rrns_health_overhead_pct"] = overhead_r
    results["token_parity"] = True          # paired() raised otherwise
    print_fn(f"serving_obs,rrns_decode_tok_s_obs_off,{off_r:.2f},"
             f"snr_db={snr_db:g}")
    print_fn(f"serving_obs,rrns_decode_tok_s_obs_on,{on_r:.2f},"
             f"snr_db={snr_db:g}")
    print_fn(f"serving_obs,rrns_health_overhead_pct,{overhead_r:.2f},"
             f"bound_lt_15pct")
    print_fn(f"serving_obs,token_parity,1,instrumented_vs_uninstrumented")

    results["rrns_corrected_low_snr"] = health_on.get("rrns_corrected", 0)
    results["rrns_uncorrected_low_snr"] = health_on.get("rrns_uncorrected", 0)
    print_fn(f"serving_obs,rrns_corrected_low_snr,"
             f"{results['rrns_corrected_low_snr']},snr_db={snr_db:g}")
    print_fn(f"serving_obs,rrns_uncorrected_low_snr,"
             f"{results['rrns_uncorrected_low_snr']},snr_db={snr_db:g}")
    if results["rrns_corrected_low_snr"] <= 0:
        raise RuntimeError(
            f"RRNS serving at snr_db={snr_db:g} reported zero corrected "
            f"residue faults — the health counters are not wired through "
            f"the decode step")

    # clean channel: decode still votes, counters must stay exactly zero
    clean = get_policy("mirage_rrns")
    model_c = build_model(cfg, clean, opts)
    server = LMServer(model_c, model_c.init(jax.random.PRNGKey(0)),
                      cap=cap, batch_slots=slots)
    _drain(server, _requests(cfg, slots, prompt_len, min(max_tokens, 4)))
    health_c = server.health_snapshot()
    results["rrns_corrected_clean"] = health_c.get("rrns_corrected", 0)
    results["rrns_uncorrected_clean"] = health_c.get("rrns_uncorrected", 0)
    print_fn(f"serving_obs,rrns_corrected_clean,"
             f"{results['rrns_corrected_clean']},snr_db=None")
    if any(v != 0 for v in health_c.values()):
        raise RuntimeError(
            f"clean-channel RRNS serving reported nonzero analog-health "
            f"counters: {health_c}")

    if enforce and overhead >= 2.0:
        raise RuntimeError(
            f"observability overhead regressed past the 2% acceptance gate "
            f"on the production serving path: {overhead:.2f}% (instrumented "
            f"{on:.2f} tok/s vs uninstrumented {off:.2f} tok/s)")
    if enforce and overhead_r >= 15.0:
        raise RuntimeError(
            f"RRNS analog-health counter overhead regressed past the 15% "
            f"bound: {overhead_r:.2f}% (instrumented {on_r:.2f} tok/s vs "
            f"uninstrumented {off_r:.2f} tok/s) — is something recording "
            f"an unreduced tensor per GEMM?")
    return results


# ---------------------------------------------------------------------------
# meshed serving: TP decode scaling + block-locality gate (subprocess)
# ---------------------------------------------------------------------------

def _mesh_child(cfg_json: str) -> int:
    """Child-process body for ``mesh_sweep``: serve one deterministic
    request stream on the requested mesh and print a single
    ``MESH_CHILD_RESULT {json}`` line. Runs in its own process because the
    forced-host-platform device count must be set before jax initializes."""
    import jax

    from repro.launch.mesh import make_debug_mesh
    from repro.runtime.server import LMServer

    c = json.loads(cfg_json)
    cfg, model, params, cap = _build(c["arch"], c["policy"],
                                     c["prompt_len"], c["max_tokens"])
    mesh = (make_debug_mesh(c["mesh_data"], c["mesh_model"])
            if c["mesh_data"] * c["mesh_model"] > 1 else None)
    server = LMServer(model, params, cap=cap, batch_slots=c["slots"],
                      buckets=(16,), cache_layout="paged",
                      block_size=c["block_size"], n_blocks=c["n_blocks"],
                      mesh=mesh, block_placement=c["placement"])
    if c.get("warmup", True):
        server.warmup()               # measure decode, not compile
    reqs = _requests(cfg, c["n_requests"], c["prompt_len"], c["max_tokens"])
    for r in reqs:
        server.submit(r)
    a = server.alloc
    finished, remote_peak = [], 0.0
    t0 = time.perf_counter()
    # tick manually so remote_fraction is sampled while refs are LIVE
    # (after the drain every slot has released and the fraction reads 0)
    for _ in range(10_000):
        if not server.scheduler.waiting and \
                all(r is None for r in server.slot_req):
            break
        finished.extend(server.tick())
        remote_peak = max(remote_peak, a.remote_fraction())
    dt = time.perf_counter() - t0
    toks = sum(len(r.tokens_out) for r in finished)
    out = {
        "tok_s": toks / max(dt, 1e-9),
        "tokens": sorted((r.rid, list(map(int, r.tokens_out)))
                         for r in finished),
        "n_shards": a.n_shards,
        "local": a.local_allocs,
        "spilled": a.spilled_allocs,
        "remote_fraction": remote_peak,
    }
    print("MESH_CHILD_RESULT " + json.dumps(out))
    return 0


def _spawn_mesh_child(child_cfg: dict, timeout: int = 1200) -> dict:
    import os
    import subprocess

    env = dict(os.environ)
    n_dev = max(child_cfg["mesh_data"] * child_cfg["mesh_model"], 1)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_serving",
         "--mesh-child", json.dumps(child_cfg)],
        env=env, capture_output=True, text=True, timeout=timeout)
    if proc.returncode != 0:
        raise RuntimeError(
            f"mesh child {child_cfg} failed:\n{proc.stdout}\n{proc.stderr}")
    for line in proc.stdout.splitlines():
        if line.startswith("MESH_CHILD_RESULT "):
            return json.loads(line[len("MESH_CHILD_RESULT "):])
    raise RuntimeError(f"mesh child emitted no result line:\n{proc.stdout}")


def mesh_sweep(print_fn=print, arch: str = "qwen2-0.5b",
               policy: str = "mirage", slots: int = 4,
               block_size: int = 16, n_blocks: int = 32,
               tp_list=(1, 2, 4), prompt_len: int = 12,
               max_tokens: int = 16, n_requests: int = 6,
               enforce: bool = True):
    """Meshed-serving rows (each point is a fresh subprocess with its own
    forced device count):

      * ``tp{t}_tok_s`` — decode throughput of the paged engine at
        model-parallel degree t (t=1 is the single-device baseline). On the
        forced HOST platform the shards share physical cores, so wall-clock
        SCALING is informational — the row exists so a real multi-chip run
        of the same artifact shows the curve.
      * locality gate (deterministic, enforced): on a data=2 mesh the
        locality placement must strictly reduce spilled allocations AND
        remote-gather fraction vs round_robin, at identical emitted tokens
        — placement is bookkeeping, never semantics.
    """
    base = dict(arch=arch, policy=policy, slots=slots,
                block_size=block_size, n_blocks=n_blocks,
                prompt_len=prompt_len, max_tokens=max_tokens,
                n_requests=n_requests, placement="locality")
    print_fn(f"# meshed serving: {arch} policy={policy} slots={slots} "
             f"blocks={n_blocks} requests={n_requests}")
    results = {}
    tok0 = None
    for t in tp_list:
        r = _spawn_mesh_child(dict(base, mesh_data=1, mesh_model=t))
        results[f"tp{t}_tok_s"] = r["tok_s"]
        print_fn(f"serving_mesh,tp{t}_tok_s,{r['tok_s']:.2f},"
                 f"decode+prefill tok/s at model={t} (host-platform "
                 f"scaling informational)")
        if tok0 is None:
            tok0 = r["tokens"]
        elif enforce and r["tokens"] != tok0:
            raise RuntimeError(
                f"meshed engine at tp={t} diverged from the tp=1 greedy "
                f"token stream")

    loc = _spawn_mesh_child(dict(base, mesh_data=2, mesh_model=1))
    rr = _spawn_mesh_child(dict(base, mesh_data=2, mesh_model=1,
                                placement="round_robin"))
    results.update(locality_spilled=loc["spilled"], rr_spilled=rr["spilled"],
                   locality_remote=loc["remote_fraction"],
                   rr_remote=rr["remote_fraction"])
    print_fn(f"serving_mesh,locality_spilled_allocs,{loc['spilled']},"
             f"data=2 mesh, locality placement ({loc['local']} local)")
    print_fn(f"serving_mesh,round_robin_spilled_allocs,{rr['spilled']},"
             f"data=2 mesh, round_robin placement ({rr['local']} local)")
    print_fn(f"serving_mesh,locality_remote_fraction,"
             f"{loc['remote_fraction']:.3f},peak live refs homed off-shard")
    print_fn(f"serving_mesh,round_robin_remote_fraction,"
             f"{rr['remote_fraction']:.3f},peak live refs homed off-shard")
    print_fn(f"serving_mesh,locality_tok_s,{loc['tok_s']:.2f},"
             f"throughput with locality placement")
    print_fn(f"serving_mesh,round_robin_tok_s,{rr['tok_s']:.2f},"
             f"throughput with round_robin placement")
    if enforce:
        if loc["tokens"] != rr["tokens"]:
            raise RuntimeError(
                "block placement changed the emitted token stream — "
                "placement must be pure bookkeeping")
        if loc["n_shards"] > 1 and not (
                loc["spilled"] < rr["spilled"]
                and loc["remote_fraction"] <= rr["remote_fraction"]):
            raise RuntimeError(
                f"locality placement did not beat round_robin: spilled "
                f"{loc['spilled']} vs {rr['spilled']}, remote fraction "
                f"{loc['remote_fraction']:.3f} vs "
                f"{rr['remote_fraction']:.3f}")
    return results


def degraded_sweep(print_fn=print, arch: str = "qwen2-0.5b",
                   slots: int = 2, prompt_len: int = 12,
                   max_tokens: int = 8, n_requests: int = 4,
                   snr_db: float = 60.0, noise_seed: int = 7,
                   scales=(1e2, 1e6), window: int = 2,
                   enforce: bool = True):
    """Graceful degradation under an SNR ramp: throughput + exactness vs
    the clean fp32 engine, guardian off vs on.

    For each collapse scale the whole drain runs with the detector sigma
    multiplied by ``scale`` (an SNR drop of ``20*log10(scale)`` dB).
    Rows per scale:

      * ``off_*``  — the raw mirage_rrns engine under the collapse:
        achieved tok/s and the fraction of requests whose greedy stream
        exactly matches clean fp32 (the corruption the guardian prevents);
      * ``on_*``   — the same collapse drained through the SNR guardian's
        verify-before-commit windows: tok/s (the price: rollbacks +
        re-prefills + backend switches), exact-match fraction, the final
        ladder level and the number of guardian transitions.

    Exactness gate (``enforce``): at the severest scale the guardian-on
    drain must be EXACTLY fp32 (every committed window ran on the fp32
    rung — ``rrns_uncorrected == 0`` is the per-window certificate) while
    guardian-off must have diverged; anything else means the guardian
    stopped guarding. Mild-scale rows are informational: windows that
    verify clean at low redundancy legitimately commit quantized-RRNS
    streams, which differ from fp32 without being faulty.
    """
    import jax

    from repro.configs import get_config
    from repro.core.precision import get_policy
    from repro.models import build_model
    from repro.models.lm import LMCallOptions
    from repro.runtime.faults import FaultInjector, FaultSchedule
    from repro.runtime.resilience import SNRGuardian
    from repro.runtime.server import LMServer

    cfg = get_config(arch).reduced()
    opts = LMCallOptions(q_chunk=32, kv_chunk=32)
    cap = prompt_len + max_tokens + 4
    fp32 = build_model(cfg, get_policy("fp32"), opts)
    params = fp32.init(jax.random.PRNGKey(0))
    rrns = build_model(cfg, get_policy("mirage_rrns", snr_db=snr_db,
                                       noise_seed=noise_seed), opts)
    print_fn(f"# serving_degraded: {arch} rrns@{snr_db:.0f}dB slots={slots} "
             f"requests={n_requests} window={window}")

    ref = LMServer(fp32, params, cap=cap, batch_slots=slots)
    toks, dt, _ = _drain(ref, _requests(cfg, n_requests, prompt_len,
                                        max_tokens))
    want = {r.rid: list(map(int, r.tokens_out))
            for r in ref.scheduler.finished}
    print_fn(f"serving_degraded,fp32_clean,{toks / dt:.2f},tok_per_s")

    def exact_frac(server):
        got = {r.rid: list(map(int, r.tokens_out))
               for r in server.scheduler.finished}
        return sum(got.get(rid) == toks_ for rid, toks_ in want.items()) \
            / len(want)

    results = {}
    for scale in scales:
        spec = f"snr_drop@0:1000000:scale={scale}"
        tag = f"snr-{20 * np.log10(scale):.0f}db"

        inj = FaultInjector(FaultSchedule.parse(spec), seed=0)
        off = LMServer(rrns, params, cap=cap, batch_slots=slots,
                       instrument=True, fault_injector=inj)
        toks, dt, _ = _drain(off, _requests(cfg, n_requests, prompt_len,
                                            max_tokens))
        off_exact = exact_frac(off)
        print_fn(f"serving_degraded,off_{tag},{toks / dt:.2f},"
                 f"tok_per_s;exact={off_exact:.2f}")

        inj = FaultInjector(FaultSchedule.parse(spec), seed=0)
        on = LMServer(rrns, params, cap=cap, batch_slots=slots,
                      instrument=True, fault_injector=inj)
        guardian = SNRGuardian(on, window=window, cooldown=10 ** 6)
        for r in _requests(cfg, n_requests, prompt_len, max_tokens):
            on.submit(r)
        t0 = time.perf_counter()
        guardian.run_until_drained()
        dt = time.perf_counter() - t0
        toks = sum(len(r.tokens_out) for r in on.scheduler.finished)
        on_exact = exact_frac(on)
        print_fn(f"serving_degraded,on_{tag},{toks / dt:.2f},"
                 f"tok_per_s;exact={on_exact:.2f};level={guardian.level};"
                 f"transitions={len(guardian.transitions)}")
        results[scale] = {"off_exact": off_exact, "on_exact": on_exact,
                          "level": guardian.level,
                          "transitions": len(guardian.transitions)}

    worst = results[max(scales)]
    print_fn(f"serving_degraded,exactness_gate,"
             f"{float(worst['on_exact'] == 1.0 and worst['off_exact'] < 1.0)},"
             f"guardian_on_exact_and_off_diverged")
    if enforce and not (worst["on_exact"] == 1.0
                        and worst["off_exact"] < 1.0):
        raise RuntimeError(
            f"degradation gate failed at scale={max(scales):g}: guardian-on "
            f"exact fraction {worst['on_exact']:.2f} (must be 1.0), "
            f"guardian-off {worst['off_exact']:.2f} (must be < 1.0)")
    return results


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--policy", default="mirage")
    ap.add_argument("--slots", type=int, nargs="+", default=[1, 2, 4])
    ap.add_argument("--rates", type=float, nargs="+", default=[4.0, 64.0])
    ap.add_argument("--requests-per-slot", type=int, default=3)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-tokens", type=int, default=16)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: tiny sweep")
    ap.add_argument("--skip-paged", action="store_true",
                    help="skip the paged-memory / chunked-prefill section")
    ap.add_argument("--skip-prefix", action="store_true",
                    help="skip the prefix-caching sweep")
    ap.add_argument("--skip-spec", action="store_true",
                    help="skip the speculative-decoding sweep")
    ap.add_argument("--skip-obs", action="store_true",
                    help="skip the observability overhead/health sweep")
    ap.add_argument("--skip-mesh", action="store_true",
                    help="skip the meshed-serving sweep")
    ap.add_argument("--skip-degraded", action="store_true",
                    help="skip the SNR-adaptive degradation sweep")
    ap.add_argument("--mesh-tp", type=int, nargs="+", default=[1, 2, 4],
                    help="model-parallel degrees for the mesh sweep")
    ap.add_argument("--mesh-child", default=None, metavar="JSON",
                    help="internal: run one meshed serving measurement "
                         "in-process and print its result line")
    ap.add_argument("--obs-snr-db", type=float, default=12.0,
                    help="detector SNR for the observability health check")
    ap.add_argument("--spec-ks", type=int, nargs="+", default=[0, 2, 4])
    ap.add_argument("--overlaps", type=float, nargs="+",
                    default=[0.0, 0.5, 0.9])
    ap.add_argument("--block-size", type=int, default=16)
    ap.add_argument("--long-len", type=int, default=192,
                    help="long-context prompt for the paged/chunked section")
    ap.add_argument("--prefix-len", type=int, default=512,
                    help="prompt length for the prefix-caching section "
                         "(long enough that prefill compute dominates "
                         "dispatch; see prefix_sweep)")
    ap.add_argument("--prefill-chunk", type=int, default=8)
    ap.add_argument("--json", default=None, metavar="PATH")
    args = ap.parse_args(argv)
    if args.mesh_child is not None:
        return _mesh_child(args.mesh_child)
    if args.quick:
        args.slots = [1, 4]
        args.rates = [64.0]
        args.requests_per_slot = 2
        args.max_tokens = 8
        args.long_len = 96
        args.prefix_len = 192
        args.mesh_tp = [1, 2]

    from benchmarks.emit import BenchWriter

    writer = BenchWriter()
    t0 = time.time()
    speedups = slots_sweep(
        writer, arch=args.arch, policy=args.policy,
        slot_counts=tuple(args.slots),
        requests_per_slot=args.requests_per_slot,
        prompt_len=args.prompt_len, max_tokens=args.max_tokens)
    load_sweep(writer, arch=args.arch, policy=args.policy,
               slots=max(args.slots), rates=tuple(args.rates),
               n_requests=max(args.slots) * args.requests_per_slot,
               prompt_len=args.prompt_len, max_tokens=args.max_tokens)
    if not args.skip_paged:
        paged = paged_sweep(writer, arch=args.arch, policy=args.policy,
                            slots=max(args.slots),
                            block_size=args.block_size,
                            long_len=args.long_len,
                            max_tokens=args.max_tokens,
                            chunk=args.prefill_chunk)
        print(f"# paged KV saves {paged['cache_saving_ratio']:.1f}x cache "
              f"bytes; chunked prefill flattens the admission spike "
              f"{paged['spike_flatten_ratio']:.1f}x")
    if not args.skip_prefix:
        # --quick runs are informational (too small for the walltime gate
        # to be meaningful); the full run enforces both acceptance gates
        pref = prefix_sweep(writer, arch=args.arch, policy=args.policy,
                            slots=max(args.slots),
                            block_size=args.block_size,
                            prompt_len=args.prefix_len,
                            overlaps=tuple(args.overlaps),
                            enforce=not args.quick)
        sp = pref.get("prefill_speedup_at_0.9")
        if sp == sp:                               # not NaN
            print(f"# prefix caching cuts prefill walltime "
                  f"{sp:.1f}x at 90% overlap")
    if not args.skip_spec:
        spec = spec_sweep(writer, arch=args.arch, policy=args.policy,
                          slots=max(args.slots),
                          block_size=args.block_size,
                          prompt_len=args.prompt_len,
                          max_tokens=args.max_tokens,
                          ks=tuple(args.spec_ks),
                          enforce=not args.quick)
        k_top = max(k for k in args.spec_ks)
        acc = spec.get(f"accepted_per_tick_k{k_top}")
        if acc:
            print(f"# speculative decoding accepts {acc:.2f} tokens/tick "
                  f"at k={k_top} (token-identical to greedy)")
    if not args.skip_obs:
        # --quick keeps the (wall-clock-noisy) overhead gates
        # informational; the full run enforces them
        obs = obs_sweep(writer, arch=args.arch,
                        slots=max(args.slots),
                        prompt_len=args.prompt_len,
                        max_tokens=(6 if args.quick else args.max_tokens),
                        n_requests=(4 if args.quick else
                                    max(args.slots) * args.requests_per_slot),
                        repeats=3, snr_db=args.obs_snr_db,
                        enforce=not args.quick)
        print(f"# observability overhead {obs['obs_overhead_pct']:+.2f}% "
              f"on the production path (gate < 2%), "
              f"{obs['rrns_health_overhead_pct']:+.2f}% with RRNS fault "
              f"counters (bound < 15%); {obs['rrns_corrected_low_snr']} "
              f"corrected residue faults at {args.obs_snr_db:g} dB, 0 on "
              f"the clean channel, tokens identical to the uninstrumented "
              f"engine")
    if not args.skip_degraded:
        deg = degraded_sweep(writer, arch=args.arch,
                             slots=min(2, max(args.slots)),
                             prompt_len=args.prompt_len,
                             max_tokens=(6 if args.quick else 8),
                             n_requests=4,
                             scales=((1e6,) if args.quick else (1e2, 1e6)),
                             enforce=True)  # exactness gate is deterministic
        w = deg[max(deg)]
        print(f"# SNR-adaptive degradation: guardian-on exact fraction "
              f"{w['on_exact']:.2f} at the severest collapse "
              f"(guardian-off {w['off_exact']:.2f}), "
              f"{w['transitions']} ladder transitions")
    if not args.skip_mesh:
        mesh = mesh_sweep(writer, arch=args.arch, policy=args.policy,
                          slots=max(args.slots),
                          block_size=args.block_size,
                          tp_list=tuple(args.mesh_tp),
                          prompt_len=args.prompt_len,
                          max_tokens=args.max_tokens,
                          n_requests=max(args.slots) *
                          args.requests_per_slot,
                          enforce=True)  # all mesh gates are deterministic
        print(f"# meshed serving: locality spills "
              f"{mesh['locality_spilled']} vs round_robin "
              f"{mesh['rr_spilled']} on a data=2 mesh "
              f"(tokens identical across placements and TP degrees)")
    if args.json:
        writer.write_json(args.json, argv=list(argv or sys.argv[1:]),
                          elapsed_s=round(time.time() - t0, 2))
    big = [s for s in speedups if s >= 4]
    if big:
        print(f"# decode speedup at slots={big[0]}: "
              f"{speedups[big[0]]:.2f}x over per-slot oracle")
    return 0


if __name__ == "__main__":
    sys.exit(main())
