"""Serving benchmark: throughput / TTFT / TPOT vs offered load and slots.

Sections (all CSV rows through ``benchmarks.emit``-compatible print_fn,
so ``--json`` makes them machine-readable):

  * ``serving_slots``  — decode throughput of the batched continuous-
    batching engine as slot count grows, against the retained per-slot
    oracle loop at the same occupancy. The ``speedup_slots{n}`` rows are
    the measured batched/oracle ratio (the acceptance gate requires > 1 at
    slots >= 4).
  * ``serving_load``   — open-loop offered load sweep: requests arrive at
    a fixed rate; rows report achieved tok/s, mean TTFT, mean TPOT and
    queue time per offered rate.

  PYTHONPATH=src python -m benchmarks.bench_serving --json out.json
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Optional

import numpy as np


def _build(arch: str, policy_name: str, prompt_len: int, max_tokens: int):
    import jax

    from repro.configs import get_config
    from repro.core.precision import get_policy
    from repro.models import build_model
    from repro.models.lm import LMCallOptions

    cfg = get_config(arch).reduced()
    model = build_model(cfg, get_policy(policy_name),
                        LMCallOptions(q_chunk=32, kv_chunk=32))
    params = model.init(jax.random.PRNGKey(0))
    cap = prompt_len + max_tokens + 4
    return cfg, model, params, cap


def _requests(cfg, n: int, prompt_len: int, max_tokens: int):
    from repro.runtime.server import Request

    rng = np.random.default_rng(0)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        prompt_len).astype(np.int32),
                    max_tokens=max_tokens)
            for i in range(n)]


def _drain(server, reqs):
    """Serve ``reqs`` to completion; returns (tokens, seconds, finished)."""
    for r in reqs:
        server.submit(r)
    t0 = time.perf_counter()
    finished = server.run_until_drained()
    dt = time.perf_counter() - t0
    return sum(len(r.tokens_out) for r in finished), dt, finished


def slots_sweep(print_fn=print, arch: str = "qwen2-0.5b",
                policy: str = "mirage", slot_counts=(1, 2, 4),
                requests_per_slot: int = 3, prompt_len: int = 12,
                max_tokens: int = 16):
    """Batched engine vs per-slot oracle at growing occupancy."""
    from repro.runtime.server import LMServer, PerSlotLMServer

    cfg, model, params, cap = _build(arch, policy, prompt_len, max_tokens)
    print_fn(f"# serving: {arch} policy={policy} prompt={prompt_len} "
             f"max_tokens={max_tokens}")
    speedups = {}
    for slots in slot_counts:
        n_req = slots * requests_per_slot
        results = {}
        for name, cls in (("batched", LMServer), ("oracle", PerSlotLMServer)):
            server = cls(model, params, cap=cap, batch_slots=slots)
            # warm THIS instance's jit caches (each server owns its jitted
            # step functions), then time a steady-state drain
            _drain(server, _requests(cfg, slots, prompt_len, max_tokens))
            toks, dt, _ = _drain(server,
                                 _requests(cfg, n_req, prompt_len, max_tokens))
            results[name] = toks / dt
            print_fn(f"serving_slots,{name}_slots{slots},{toks / dt:.2f},"
                     f"tok_per_s;requests={n_req}")
        speedups[slots] = results["batched"] / results["oracle"]
        print_fn(f"serving_slots,speedup_slots{slots},"
                 f"{speedups[slots]:.3f},batched_over_oracle")
    return speedups


def load_sweep(print_fn=print, arch: str = "qwen2-0.5b",
               policy: str = "mirage", slots: int = 4,
               rates=(4.0, 16.0, 64.0), n_requests: int = 12,
               prompt_len: int = 12, max_tokens: int = 16):
    """Open-loop arrival sweep: submit at a fixed offered rate (req/s) and
    measure achieved throughput and latency percentiles."""
    from repro.runtime.server import LMServer

    from repro.runtime.server import Scheduler

    cfg, model, params, cap = _build(arch, policy, prompt_len, max_tokens)
    # one engine across rates; warm every pow2 admission-batch size so the
    # measured TTFT is serving latency, not prefill compiles
    server = LMServer(model, params, cap=cap, batch_slots=slots)
    bp = 1
    while bp <= slots:
        _drain(server, _requests(cfg, bp, prompt_len, max_tokens))
        bp *= 2

    for rate in rates:
        server.scheduler = Scheduler()      # fresh per-rate metrics
        reqs = _requests(cfg, n_requests, prompt_len, max_tokens)
        t0 = time.perf_counter()
        pending = list(reqs)
        finished = []
        tick_guard = 0
        while (pending or server.scheduler.waiting or
               any(r is not None for r in server.slot_req)):
            now = time.perf_counter() - t0
            while pending and len(reqs) - len(pending) < now * rate:
                server.submit(pending.pop(0))
            if server.scheduler.waiting or \
                    any(r is not None for r in server.slot_req):
                finished.extend(server.tick())
            elif pending:
                time.sleep(0.001)           # idle: next arrival not due yet
            tick_guard += 1
            if tick_guard > 100_000:
                break
        dt = time.perf_counter() - t0
        toks = sum(len(r.tokens_out) for r in finished)
        lat = server.scheduler.latency_summary()
        print_fn(f"serving_load,rate{rate:g}_tok_s,{toks / dt:.2f},"
                 f"slots={slots}")
        print_fn(f"serving_load,rate{rate:g}_ttft_ms,"
                 f"{lat['ttft_mean_s'] * 1e3:.2f},mean")
        print_fn(f"serving_load,rate{rate:g}_tpot_ms,"
                 f"{lat['tpot_mean_s'] * 1e3:.2f},mean")
        print_fn(f"serving_load,rate{rate:g}_queue_ms,"
                 f"{lat['queue_mean_s'] * 1e3:.2f},mean")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--policy", default="mirage")
    ap.add_argument("--slots", type=int, nargs="+", default=[1, 2, 4])
    ap.add_argument("--rates", type=float, nargs="+", default=[4.0, 64.0])
    ap.add_argument("--requests-per-slot", type=int, default=3)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-tokens", type=int, default=16)
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: tiny sweep")
    ap.add_argument("--json", default=None, metavar="PATH")
    args = ap.parse_args(argv)
    if args.quick:
        args.slots = [1, 4]
        args.rates = [64.0]
        args.requests_per_slot = 2
        args.max_tokens = 8

    from benchmarks.emit import BenchWriter

    writer = BenchWriter()
    t0 = time.time()
    speedups = slots_sweep(
        writer, arch=args.arch, policy=args.policy,
        slot_counts=tuple(args.slots),
        requests_per_slot=args.requests_per_slot,
        prompt_len=args.prompt_len, max_tokens=args.max_tokens)
    load_sweep(writer, arch=args.arch, policy=args.policy,
               slots=max(args.slots), rates=tuple(args.rates),
               n_requests=max(args.slots) * args.requests_per_slot,
               prompt_len=args.prompt_len, max_tokens=args.max_tokens)
    if args.json:
        writer.write_json(args.json, argv=list(argv or sys.argv[1:]),
                          elapsed_s=round(time.time() - t0, 2))
    big = [s for s in speedups if s >= 4]
    if big:
        print(f"# decode speedup at slots={big[0]}: "
              f"{speedups[big[0]]:.2f}x over per-slot oracle")
    return 0


if __name__ == "__main__":
    sys.exit(main())
