"""Analytical model of the Mirage photonic accelerator (paper Section IV-B).

Reproduces the paper's in-house simulator: device-level energy/area/latency
for the RNS-MMVMU datapath, the tiling latency model behind Fig. 7/8, the
pJ/MAC sensitivity of Fig. 5b, utilization of Fig. 6, and the power/area
breakdown of Fig. 9.

Device constants are taken verbatim from Section IV-B. One quantity the paper
does not fully specify is the shot-noise-limited receiver power for
"SNR > m"; we model laser power as
    P_laser = P_rx_min * m^2 * 10^(loss_dB/10) / (coupler_eff * laser_eff)
with P_rx_min calibrated once so the flagship configuration (b_m=4, g=16,
k=5) lands on the paper's published 0.21 pJ/MAC — all RELATIVE behaviour
(vs g, b_m, moduli, and vs the systolic baselines) then follows from first
principles. The calibration constant is printed for transparency.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Sequence, Tuple

# ---------------------------------------------------------------------------
# Device constants (Section IV-B) — canonical copies live in
# repro.analog.device so the analog channel model (noise injection) and this
# energy/area model can never drift apart; re-exported here for the bench
# scripts that address them as hw_model attributes.
# ---------------------------------------------------------------------------

from repro.analog.device import (  # noqa: E402,F401
    PHOTONIC_CLOCK_HZ,
    DIGITAL_CLOCK_HZ,
    PS_PROGRAM_NS,
    MVM_NS,
    PS_LOSS_DB,
    MRR_LOSS_DB,
    BEND_LOSS_DB,
    COUPLER_LOSS_DB,
    LASER_EFF,
    DETECTOR_A_PER_W,
    TIA_J_PER_BIT,
    MRR_TUNE_W,
    DAC6_W, DAC6_GSPS, DAC6_MM2,
    ADC6_W, ADC6_GSPS, ADC6_MM2,
    RNS_CONV_J,
    RNS_CONV_MM2,
    SRAM_BYTES,
    SRAM_PJ_PER_BYTE,
    SRAM_MM2_PER_MB,
    PS_LEN_UM,
    MRR_RADIUS_UM,
    WG_PITCH_UM,
    P_RX_FLOOR_W,
)

# Published Table II constants (the paper's own synthesis results)
SYSTOLIC_FORMATS = {
    # name: (pJ/MAC, mm^2/MAC, freq_Hz)
    "FP32": (12.42, 9.6e-3, 500e6),
    "bfloat16": (3.20, 3.5e-3, 500e6),
    "HFP8": (1.47, 1.4e-3, 500e6),
    "INT12": (0.71, 7.7e-4, 1e9),
    "INT8": (0.42, 4.1e-4, 1e9),
    "FMAC": (0.11, None, 500e6),
}

MIRAGE_TABLE_II_PJ_MAC = 0.21     # calibration target


@dataclasses.dataclass(frozen=True)
class MirageHW:
    """One Mirage accelerator instance."""
    g: int = 16                  # MMUs per MDPU (contraction width)
    rows: int = 32               # MDPUs per MMVMU
    n_units: int = 8             # RNS-MMVMUs
    k: int = 5                   # moduli {2^k-1, 2^k, 2^k+1}
    b_m: int = 4

    @property
    def moduli(self) -> Tuple[int, int, int]:
        return (2**self.k - 1, 2**self.k, 2**self.k + 1)

    @property
    def converter_bits(self) -> Tuple[int, ...]:
        return tuple(int(math.ceil(math.log2(m))) for m in self.moduli)

    # ------------------------------------------------------------------
    # optics: loss + laser power
    # ------------------------------------------------------------------

    def path_loss_db(self) -> float:
        """Optical loss along one MDPU row: g MMUs, each with ceil(log2 m)
        digit stages (2 MRR switches + shifter-or-bypass + bends)."""
        digits = max(self.converter_bits)
        per_digit = 2 * MRR_LOSS_DB / 2 + PS_LOSS_DB + 2 * BEND_LOSS_DB
        # (on average one of the two MRR couplings is on the taken route)
        return COUPLER_LOSS_DB + self.g * digits * per_digit

    def laser_power_w(self, p_rx_min_w: float) -> float:
        """Per-MDPU-row laser power to keep SNR > m at the detector, doubled
        for the two-quadrature phase detection (Section III-B3)."""
        m = max(self.moduli)
        p_rx = p_rx_min_w * m**2
        return 2 * p_rx * 10 ** (self.path_loss_db() / 10) / LASER_EFF

    # ------------------------------------------------------------------
    # energy
    # ------------------------------------------------------------------

    def energy_per_mac_pj(self, p_rx_min_w: float,
                          include_sram: bool = False) -> Dict[str, float]:
        """pJ per MAC, broken down by component. One RNS output consumes the
        work of all 3 modular MMVMUs and amortizes over g MACs."""
        macs_per_output = self.g
        out_rate = PHOTONIC_CLOCK_HZ
        comp = {}
        # lasers: n_moduli rows' worth of optical power per output stream
        laser_w = sum(self.laser_power_w(p_rx_min_w) for _ in self.moduli)
        comp["laser"] = laser_w / out_rate * 1e12
        # MRR switching: g MMUs x digits x n_moduli
        n_mrr = self.g * sum(self.converter_bits)
        comp["mrr"] = n_mrr * MRR_TUNE_W / out_rate * 1e12
        # ADCs: 2 per detection (I/Q) per modulus; 6b scaled 4x per bit
        adc = 0.0
        for bits in self.converter_bits:
            e6 = ADC6_W / ADC6_GSPS
            adc += 2 * e6 * 4.0 ** (bits - 6)
        comp["adc"] = adc * 1e12
        # DACs: programmed once per tile, amortized over reuse (weight
        # stationary, Section IV-B2) — negligible steady-state; charge the
        # program burst over a nominal 512-MVM tile lifetime
        dac = 0.0
        for bits in self.converter_bits:
            e6 = DAC6_W / DAC6_GSPS
            dac += self.g * e6 * 4.0 ** (bits - 6) / 512.0
        comp["dac"] = dac * 1e12
        # TIAs: bits per output per modulus, two quadratures
        comp["tia"] = sum(2 * b * TIA_J_PER_BIT for b in self.converter_bits) * 1e12
        # RNS<->BNS conversions: one forward (input) + one reverse per output
        comp["rns_conv"] = 2 * RNS_CONV_J * 1e12
        # FP32 accumulate (digital, per output)
        comp["accum"] = 0.9  # pJ, 32b add + SRAM-local reg traffic at 40nm
        if include_sram:
            comp["sram"] = 2 * 4 * SRAM_PJ_PER_BYTE  # rd+wr one FP32 word
        total = sum(comp.values())
        return {**{k: v / macs_per_output for k, v in comp.items()},
                "total": total / macs_per_output}

    # ------------------------------------------------------------------
    # area
    # ------------------------------------------------------------------

    def area_mm2(self) -> Dict[str, float]:
        digits = max(self.converter_bits)
        # one MMU: digit shifters with lengths L..2^(b-1)L + 2 MRRs per digit
        ps_len_um = PS_LEN_UM * (2**digits - 1)
        mmu_um2 = ps_len_um * WG_PITCH_UM + digits * (
            (2 * MRR_RADIUS_UM) ** 2 * 2)
        mdpu_um2 = self.g * mmu_um2
        photonic_um2 = (len(self.moduli) * self.n_units * self.rows
                        * mdpu_um2) * 1.5   # routing/pitch overhead
        photonic = photonic_um2 * 1e-6
        n_adc = len(self.moduli) * self.n_units * self.rows * 2
        n_dac = len(self.moduli) * self.n_units * self.g
        adc = n_adc * ADC6_MM2
        dac = n_dac * DAC6_MM2
        conv = len(self.moduli) * self.n_units * 10 * RNS_CONV_MM2
        sram = SRAM_BYTES / 2**20 * SRAM_MM2_PER_MB
        digital_logic = 8.0
        return {"photonic": photonic, "adc": adc, "dac": dac,
                "rns_conv": conv, "sram": sram, "digital": digital_logic,
                "electronic_total": adc + dac + conv + sram + digital_logic,
                "total_3d": max(photonic, adc + dac + conv + sram + digital_logic)}

    def peak_power_w(self, p_rx_min_w: float) -> Dict[str, float]:
        """Peak power at full utilization (Fig. 9 analog)."""
        rate = PHOTONIC_CLOCK_HZ * self.n_units * self.rows  # outputs/s
        e = self.energy_per_mac_pj(p_rx_min_w)
        out = {}
        for kcomp in ("laser", "mrr", "adc", "dac", "tia", "rns_conv", "accum"):
            out[kcomp] = e[kcomp] * self.g * 1e-12 * rate
        # SRAM: FP32 read+write per output (paper: dominant)
        out["sram"] = rate * 2 * 4 * SRAM_PJ_PER_BYTE * 1e-12
        out["total"] = sum(out.values())
        return out

    def peak_macs_per_s(self) -> float:
        return PHOTONIC_CLOCK_HZ * self.n_units * self.rows * self.g


def calibrate_p_rx(hw: MirageHW = MirageHW()) -> float:
    """Solve P_rx_min so the flagship config hits the paper's 0.21 pJ/MAC.

    With our component accounting the converter/TIA/conversion energies alone
    (~0.4 pJ/MAC) already exceed the paper's published total, so the fit
    saturates at the physical receiver floor (1 nW) — we report our
    first-principles number next to the paper's and keep the 1 nW floor;
    all RELATIVE comparisons (vs g, b_m, and the systolic formats) are
    preserved. See EXPERIMENTS.md for the discrepancy discussion."""
    lo, hi = P_RX_FLOOR_W, 1e-3
    for _ in range(80):
        mid = math.sqrt(lo * hi)
        t = hw.energy_per_mac_pj(mid)["total"]
        if t > MIRAGE_TABLE_II_PJ_MAC:
            hi = mid
        else:
            lo = mid
    return max(math.sqrt(lo * hi), P_RX_FLOOR_W)


# ---------------------------------------------------------------------------
# Latency model (Fig. 7): tiled GEMM schedules DF1/DF2 (+ systolic DF3)
# ---------------------------------------------------------------------------

def mirage_gemm_latency_s(M: int, K: int, N: int, hw: MirageHW,
                          dataflow: str = "DF1") -> float:
    """O(M x K -> N): stationary operand programmed per tile (5 ns), then one
    MVM per 0.1 ns streams the moving operand. Tiles run across n_units.

    DF1 (weight stationary): tiles = ceil(N/rows)*ceil(K/g), stream M.
    DF2 (input stationary):  tiles = ceil(M/rows)*ceil(K/g), stream N.
    """
    if dataflow == "DF1":
        tiles = math.ceil(N / hw.rows) * math.ceil(K / hw.g)
        stream = M
    elif dataflow == "DF2":
        tiles = math.ceil(M / hw.rows) * math.ceil(K / hw.g)
        stream = N
    else:
        raise ValueError("Mirage supports DF1/DF2 only (Section V-A3)")
    t_tile = PS_PROGRAM_NS * 1e-9 + stream * MVM_NS * 1e-9
    return math.ceil(tiles / hw.n_units) * t_tile


def mirage_gemm_latency_opt_s(M, K, N, hw: MirageHW) -> Tuple[float, str]:
    """OPT2: best dataflow per GEMM (Section V-A3)."""
    best = min((mirage_gemm_latency_s(M, K, N, hw, df), df)
               for df in ("DF1", "DF2"))
    return best


def systolic_gemm_latency_s(M: int, K: int, N: int, rows: int = 32,
                            cols: int = 16, n_arrays: int = 1,
                            freq_hz: float = 1e9,
                            dataflow: str = "DF1") -> float:
    """Classic systolic estimate: per (rows x cols) tile, fill + stream."""
    if dataflow in ("DF1", "DF2"):
        tiles = math.ceil(N / rows) * math.ceil(K / cols)
        stream = M
        fill = rows + cols
    else:  # DF3 output stationary: K streams through
        tiles = math.ceil(M / rows) * math.ceil(N / cols)
        stream = K
        fill = rows + cols
    cycles = math.ceil(tiles / n_arrays) * (stream + fill)
    return cycles / freq_hz


# ---------------------------------------------------------------------------
# Workloads: training step = 3 GEMMs per layer (Eqs. 1-3)
# ---------------------------------------------------------------------------

def alexnet_gemms(batch: int = 256) -> List[Tuple[int, int, int]]:
    """(M, K, N) im2col GEMMs for AlexNet's 5 convs + 3 FCs."""
    convs = [  # (out_hw, k*k*cin, cout)
        (55 * 55, 11 * 11 * 3, 64),
        (27 * 27, 5 * 5 * 64, 192),
        (13 * 13, 3 * 3 * 192, 384),
        (13 * 13, 3 * 3 * 384, 256),
        (13 * 13, 3 * 3 * 256, 256),
    ]
    fcs = [(1, 9216, 4096), (1, 4096, 4096), (1, 4096, 1000)]
    return ([(batch * hw_, k, n) for hw_, k, n in convs]
            + [(batch, k, n) for _, k, n in fcs])


def transformer_gemms(batch: int = 256, seq: int = 128, d: int = 768,
                      ffn: int = 3072, layers: int = 12) -> List[Tuple[int, int, int]]:
    per_layer = [
        (batch * seq, d, 3 * d),    # qkv
        (batch * seq, d, d),        # out proj
        (batch * seq, d, ffn),      # ffn up
        (batch * seq, ffn, d),      # ffn down
    ]
    return per_layer * layers


def config_gemms(cfg, batch: int, seq: int) -> List[Tuple[int, int, int]]:
    """Per-training-step GEMMs of one of our assigned ModelConfigs."""
    T = batch * seq
    hd = cfg.resolved_head_dim
    out: List[Tuple[int, int, int]] = []
    for _ in range(cfg.n_layers):
        if cfg.family in ("ssm", "hybrid"):
            d_in = 2 * cfg.d_inner + 2 * cfg.ssm_state + cfg.ssm_heads
            out += [(T, cfg.d_model, d_in), (T, cfg.d_inner, cfg.d_model)]
            continue
        out += [(T, cfg.d_model, cfg.n_heads * hd),
                (T, cfg.d_model, 2 * cfg.n_kv_heads * hd),
                (T, cfg.n_heads * hd, cfg.d_model)]
        if cfg.n_experts:
            ff = cfg.moe_d_ff
            act = cfg.experts_per_token
            out += [(T * act, cfg.d_model, 2 * ff), (T * act, ff, cfg.d_model)]
        elif cfg.d_ff:
            out += [(T, cfg.d_model, 2 * cfg.d_ff), (T, cfg.d_ff, cfg.d_model)]
    out.append((T, cfg.d_model, cfg.vocab_size))
    return out


def training_step_latency_s(gemms: Sequence[Tuple[int, int, int]],
                            engine: str = "mirage",
                            hw: MirageHW = MirageHW(),
                            fmt: str = "FP32", n_arrays: int = 1,
                            dataflow: str = "OPT2") -> float:
    """Training = fwd (MxKxN) + dX (MxNxK) + dW (KxMxN) per GEMM."""
    total = 0.0
    for (M, K, N) in gemms:
        tri = [(M, K, N), (M, N, K), (K, M, N)]
        for (m, k, n) in tri:
            if engine == "mirage":
                if dataflow == "OPT2":
                    t, _ = mirage_gemm_latency_opt_s(m, k, n, hw)
                else:
                    t = mirage_gemm_latency_s(m, k, n, hw, dataflow)
            else:
                freq = SYSTOLIC_FORMATS[fmt][2]
                if dataflow == "OPT2":
                    t = min(systolic_gemm_latency_s(m, k, n, hw.rows, hw.g,
                                                    n_arrays, freq, df)
                            for df in ("DF1", "DF3"))
                else:
                    t = systolic_gemm_latency_s(m, k, n, hw.rows, hw.g,
                                                n_arrays, freq, dataflow)
            total += t
    return total


def spatial_utilization(gemms, rows: int, g: int, n_units: int) -> float:
    """Fig. 6: mean per-layer fraction of MAC slots doing useful work (tile
    rounding on N/K plus idle units on the last tile round)."""
    utils = []
    for (M, K, N) in gemms:
        tiles = math.ceil(N / rows) * math.ceil(K / g)
        rounds = math.ceil(tiles / n_units)
        useful = M * K * N
        allocated = rounds * n_units * rows * g * M
        utils.append(useful / max(allocated, 1.0))
    return sum(utils) / max(len(utils), 1)


def iso_energy_arrays(fmt: str, hw: MirageHW = MirageHW(),
                      p_rx: float = None) -> int:
    """Systolic array count whose pJ/MAC budget matches Mirage (Fig. 8 left):
    arrays sized so energy/MAC is equal => count scales with the
    energy-per-MAC ratio at iso MAC-throughput demand."""
    p_rx = p_rx if p_rx is not None else calibrate_p_rx(hw)
    mirage_pj = hw.energy_per_mac_pj(p_rx)["total"]
    fmt_pj = SYSTOLIC_FORMATS[fmt][0]
    # same total energy rate: n_arrays * (rows*g) * f * pj == mirage rate * pj_m
    mirage_rate = hw.peak_macs_per_s()
    fmt_rate = hw.rows * hw.g * SYSTOLIC_FORMATS[fmt][2]
    n = (mirage_rate * mirage_pj) / (fmt_rate * fmt_pj)
    return max(1, int(round(n)))


def iso_area_arrays(fmt: str, hw: MirageHW = MirageHW()) -> int:
    area = hw.area_mm2()["total_3d"]
    mm2 = SYSTOLIC_FORMATS[fmt][1]
    if mm2 is None:
        return 0
    per_array = hw.rows * hw.g * mm2
    return max(1, int(area / per_array))
