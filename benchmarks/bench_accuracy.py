"""Table I + Fig. 5a analogs at CPU scale: training parity across numerics
formats, and the (b_m, g) sensitivity sweep — same methodology as the paper
(swap every GEMM for the quantized version, FP32 master weights), on a small
LM + synthetic bigram data instead of ImageNet."""

from __future__ import annotations

import time

import jax

from repro.configs import get_config
from repro.configs.base import TrainConfig
from repro.core.precision import MiragePolicy, get_policy
from repro.data.pipeline import SyntheticLM, SyntheticLMConfig
from repro.models import build_model
from repro.models.lm import LMCallOptions
from repro.runtime.trainer import init_train_state, make_train_step


def _train(policy, steps=15, seed=0):
    cfg = get_config("qwen2-0.5b").reduced()
    model = build_model(cfg, policy, LMCallOptions(q_chunk=16, kv_chunk=16))
    tc = TrainConfig(policy=policy, optimizer="adamw", lr=1e-3)
    state = init_train_state(model, tc, jax.random.PRNGKey(seed))
    step = jax.jit(make_train_step(model, tc))
    data = SyntheticLM(SyntheticLMConfig(
        vocab_size=cfg.vocab_size, seq_len=32, batch_size=4, seed=seed))
    t0 = time.perf_counter()
    metrics = {}
    for _ in range(steps):
        state, metrics = step(state, next(data))
    jax.block_until_ready(metrics["loss"])
    dt = (time.perf_counter() - t0) / steps
    return float(metrics["loss"]), dt


def table_i(print_fn=print, steps=60):
    print_fn("# Table I analog: training parity across formats (small LM)")
    losses = {}
    for name in ("fp32", "bf16", "mirage", "mirage_faithful", "int8"):
        loss, dt = _train(get_policy(name), steps)
        losses[name] = loss
        print_fn(f"table1,{name}_loss,{loss:.4f},us_per_step={dt*1e6:.0f}")
    print_fn(f"table1,mirage_minus_fp32,{losses['mirage']-losses['fp32']:+.4f},"
             f"paper_gap<=0.1pt")
    print_fn(f"table1,int8_minus_fp32,{losses['int8']-losses['fp32']:+.4f},"
             f"paper_gap=2-5pt")
    return losses


def fig_5a(print_fn=print, steps=12):
    print_fn("# Fig 5a analog: loss after fixed steps vs (b_m, g)")
    for b_m in (2, 3, 4, 5):
        for g in (8, 16, 32):
            k = 4 if b_m <= 3 else (5 if b_m == 4 else 6)
            import math
            while math.log2((2**k - 1) * 2**k * (2**k + 1)) < \
                    2 * (b_m + 1) + math.log2(g) - 1:
                k += 1
            policy = MiragePolicy(mode="mirage_fast", b_m=b_m, g=g, k=k)
            loss, _ = _train(policy, steps)
            print_fn(f"fig5a,bm{b_m}_g{g},{loss:.4f},loss@{steps}steps")


def main(print_fn=print):
    table_i(print_fn)
    fig_5a(print_fn)


if __name__ == "__main__":
    main()
