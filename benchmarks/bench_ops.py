"""Op-level microbenchmarks: us/call for every GEMM mode and Pallas kernel
(CPU jit walltime — relative costs of the numerics paths, not TPU numbers)."""

from __future__ import annotations

import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import backends, gemm
from repro.core.precision import get_policy
from repro.kernels.bfp_quantize import bfp_fake_quant_pallas
from repro.kernels.mirage_gemm import mirage_gemm_pallas


def _time(fn, *args, iters=20):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def main(print_fn=print):
    print_fn("# op microbenchmarks (CPU jit; relative numerics-path costs)")
    rng = np.random.default_rng(0)
    M, K, N = 256, 1024, 256
    x = jnp.asarray(rng.normal(size=(M, K)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(K, N)).astype(np.float32))

    # every registered backend, discovered from the registry (kernel-routed
    # variants are exercised separately below / in bench_gemm.py)
    for mode in backends.available_backends():
        if mode == "mirage_rns_pallas":
            continue  # interpret-mode Pallas: covered by the kernel rows
        p = get_policy(mode)
        f = jax.jit(lambda a, b, pp=p: gemm.mirage_matmul_nograd(a, b, pp))
        us = _time(f, x, w)
        print_fn(f"ops,matmul_{mode}_{M}x{K}x{N},{us:.1f},us_per_call")

    us = _time(lambda a: bfp_fake_quant_pallas(a, interpret=True), x, iters=3)
    print_fn(f"ops,pallas_bfp_quant_interp,{us:.1f},us_per_call")
    us = _time(lambda a, b: mirage_gemm_pallas(a, b, interpret=True), x, w,
               iters=2)
    print_fn(f"ops,pallas_mirage_gemm_interp,{us:.1f},us_per_call")

    # grad path
    p = get_policy("mirage")
    gfn = jax.jit(jax.grad(lambda a, b: jnp.sum(
        gemm.mirage_matmul(a, b, p) ** 2), argnums=(0, 1)))
    us = _time(lambda a, b: gfn(a, b)[0], x, w)
    print_fn(f"ops,matmul_mirage_fwd_bwd,{us:.1f},us_per_call")


if __name__ == "__main__":
    main()
