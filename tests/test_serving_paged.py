"""Paged KV serving + chunked prefill: token-exact parity of the paged /
paged+chunked engines vs the dense engine and the per-slot oracle (all 4
model families, clean and error-corrected RRNS modes), block lifecycle
through the engine, chunked TTFT/queue accounting, OOB drop-sentinel
behavior of the stacked-cache helpers under both layouts, elastic slot and
block-pool resizes, and paged-state shardings."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core.precision import get_policy
from repro.models import build_model
from repro.models import lm as lm_helpers
from repro.models.lm import LMCallOptions
from repro.runtime.paging import BlockAllocator
from repro.runtime.server import LMServer, PerSlotLMServer, Request


@pytest.fixture(scope="module")
def served():
    cfg = get_config("qwen2-0.5b").reduced()
    model = build_model(cfg, get_policy("mirage"),
                        LMCallOptions(q_chunk=16, kv_chunk=16))
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _mk_requests(cfg, n, lens, max_tokens=4, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        lens[i % len(lens)]).astype(np.int32),
                    max_tokens=max_tokens)
            for i in range(n)]


def _serve(model, cfg, reqs_kw, cap=24, slots=3, **server_kw):
    server = LMServer(model, reqs_kw.pop("params"), cap=cap,
                      batch_slots=slots, **server_kw)
    for r in _mk_requests(cfg, **reqs_kw):
        server.submit(r)
    return server, {r.rid: r.tokens_out for r in server.run_until_drained()}


# --------------------------------------------------------------------------
# parity: paged / paged+chunked vs dense vs oracle
# --------------------------------------------------------------------------

def test_paged_engine_token_exact_vs_dense_and_oracle(served):
    """The acceptance gate: greedy decode through the paged block-table
    cache — with and without chunked prefill — emits exactly the dense
    engine's (and the oracle's) tokens, across mixed lengths, slot reuse
    and block reuse."""
    cfg, model, params = served
    kw = dict(params=params, n=7, lens=[8, 11, 6], max_tokens=5)
    _, dense = _serve(model, cfg, dict(kw))
    sp, paged = _serve(model, cfg, dict(kw), cache_layout="paged",
                       block_size=8)
    sc, chunk = _serve(model, cfg, dict(kw), cache_layout="paged",
                       block_size=8, prefill_chunk=4)
    oracle = PerSlotLMServer(model, params, cap=24, batch_slots=3)
    for r in _mk_requests(cfg, 7, lens=[8, 11, 6], max_tokens=5):
        oracle.submit(r)
    orc = {r.rid: r.tokens_out for r in oracle.run_until_drained()}
    assert set(dense) == set(range(7))
    assert paged == dense == orc
    assert chunk == dense
    # block lifecycle: everything returned to the pool, invariants hold
    for s in (sp, sc):
        s.alloc.check_invariants()
        assert s.alloc.used_count == 0
        assert s.alloc.peak_in_use > 0


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "mamba2-2.7b",
                                  "zamba2-2.7b"])
def test_paged_chunked_parity_across_families(arch):
    """SWA window masks over linear (non-ring) page addressing (mixtral),
    dense recurrent state + chunk-carried SSM recurrences (mamba2), and the
    hybrid's paged shared-attention pages (zamba2) all stay token-identical
    to the dense engine."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg, get_policy("mirage"),
                        LMCallOptions(q_chunk=16, kv_chunk=16))
    params = model.init(jax.random.PRNGKey(0))
    kw = dict(params=params, n=3, lens=[6, 9], max_tokens=3, seed=2)
    _, dense = _serve(model, cfg, dict(kw), cap=20, slots=2)
    _, paged = _serve(model, cfg, dict(kw), cap=20, slots=2,
                      cache_layout="paged", block_size=4)
    _, chunk = _serve(model, cfg, dict(kw), cap=20, slots=2,
                      cache_layout="paged", block_size=4, prefill_chunk=4)
    assert paged == dense and chunk == dense and len(dense) == 3


def test_rrns_serving_paged_parity_and_chunked_determinism():
    """Error-corrected serving over the paged cache: the unchunked paged
    engine draws the SAME per-tick noise keys as the dense engine (identical
    prefill/decode streams) so it stays token-identical even under the
    analog channel; the chunked engine draws from its own chunk stream, so
    the guarantee there is per-seed determinism."""
    cfg = get_config("qwen2-0.5b").reduced()
    policy = get_policy("mirage_rrns", snr_db=28.0, noise_seed=7)
    model = build_model(cfg, policy, LMCallOptions(q_chunk=16, kv_chunk=16))
    params = model.init(jax.random.PRNGKey(0))
    kw = dict(params=params, n=2, lens=[6], max_tokens=3, seed=5)
    _, dense = _serve(model, cfg, dict(kw), cap=20, slots=2)
    _, paged = _serve(model, cfg, dict(kw), cap=20, slots=2,
                      cache_layout="paged", block_size=4)
    assert paged == dense
    _, c1 = _serve(model, cfg, dict(kw), cap=20, slots=2,
                   cache_layout="paged", block_size=4, prefill_chunk=4)
    _, c2 = _serve(model, cfg, dict(kw), cap=20, slots=2,
                   cache_layout="paged", block_size=4, prefill_chunk=4)
    assert c1 == c2


# --------------------------------------------------------------------------
# chunked prefill: scheduler accounting + long-prompt streaming
# --------------------------------------------------------------------------

def test_chunked_ttft_stamped_after_final_chunk(served):
    """TTFT stamps on the token emitted by the FINAL chunk (host
    materialization), not at admission or at intermediate chunks; the
    prefilling gauge counts chunk-pending requests and drains to zero."""
    cfg, model, params = served
    server = LMServer(model, params, cap=24, batch_slots=2,
                      cache_layout="paged", block_size=8, prefill_chunk=4)
    [req] = _mk_requests(cfg, 1, lens=[10], max_tokens=3)
    server.submit(req)
    server.tick()                       # admit + chunk 1 of [4, 4, 2]
    assert server.metrics["prefilling"] == 1
    assert req.tokens_out == [] and req.t_first_token == 0.0
    assert req.t_admit > 0
    server.tick()                       # chunk 2 — still no token
    assert req.tokens_out == [] and req.t_first_token == 0.0
    # final chunk -> first token; the same tick then piggybacks a decode
    # (exactly like the dense engine's admit-then-decode tick), so the
    # request may gain a second token here — TTFT belongs to the first
    server.tick()
    assert len(req.tokens_out) in (1, 2)
    t_first = req.t_first_token
    assert t_first >= req.t_admit >= req.t_enqueue
    assert server.metrics["prefilling"] == 0
    assert server.metrics["prefill_chunks"] == 3
    server.run_until_drained()
    assert req.t_first_token == t_first          # stamped exactly once
    assert req.t_done >= t_first
    assert len(req.tokens_out) == 3


def test_chunked_long_prompt_streams_past_bucket_limit(served):
    """Chunked prefill admits prompts up to the paged cache's LINEAR
    capacity (cap), beyond the dense engine's largest bucket, interleaving
    chunks with live decode ticks."""
    cfg, model, params = served
    server = LMServer(model, params, cap=40, batch_slots=2,
                      cache_layout="paged", block_size=8, prefill_chunk=8)
    short = _mk_requests(cfg, 1, lens=[6], max_tokens=12, seed=1)[0]
    long_req = _mk_requests(cfg, 1, lens=[33], max_tokens=3, seed=2)[0]
    long_req.rid = 1
    server.submit(short)
    server.tick()                       # short is decoding
    server.submit(long_req)
    finished = {r.rid: r for r in server.run_until_drained()}
    assert len(finished) == 2
    assert len(finished[0].tokens_out) == 12
    assert len(finished[1].tokens_out) == 3
    # the short stream kept emitting while the long prompt chunked in
    assert server.metrics["prefill_chunks"] >= 5   # ceil(33/8) chunks


def test_small_pool_queues_admissions_head_of_line(served):
    """A pool too small for two concurrent prompts serves them one after
    the other (FCFS head-of-line wait for freed blocks) instead of
    exhausting mid-decode."""
    cfg, model, params = served
    for chunk in (None, 4):
        server = LMServer(model, params, cap=24, batch_slots=2,
                          cache_layout="paged", block_size=8, n_blocks=2,
                          prefill_chunk=chunk)
        for r in _mk_requests(cfg, 2, lens=[10], max_tokens=4, seed=3):
            server.submit(r)
        done = {r.rid: r for r in server.run_until_drained()}
        assert len(done) == 2
        assert all(len(r.tokens_out) == 4 for r in done.values())
        server.alloc.check_invariants()
        assert server.alloc.used_count == 0
        assert server.alloc.peak_in_use <= 2


def test_admission_reserves_decode_growth_blocks(served):
    """Admission budgets the request's FULL lifetime (prompt + max_tokens),
    not just the prompt — a tight pool serializes admissions instead of
    exhausting when decode crosses a block boundary mid-flight."""
    cfg, model, params = served
    for chunk in (None, 4):
        # prompt 6 = 1 block of 8, but 6 + 12 tokens = 18 positions = 3
        # blocks; a pool of 3 must serve the two requests one at a time
        server = LMServer(model, params, cap=24, batch_slots=2,
                          cache_layout="paged", block_size=8, n_blocks=3,
                          prefill_chunk=chunk)
        for r in _mk_requests(cfg, 2, lens=[6], max_tokens=12, seed=4):
            server.submit(r)
        done = {r.rid: r for r in server.run_until_drained()}
        assert len(done) == 2
        assert all(len(r.tokens_out) == 12 for r in done.values())
        server.alloc.check_invariants()
        assert server.alloc.used_count == 0
        assert server.alloc.peak_in_use <= 3


def test_pool_oversized_request_rejected_not_livelocked(served):
    """A request whose lifetime block budget exceeds the whole pool can
    never be admitted — submit() rejects it loudly instead of wedging the
    FCFS queue behind an unsatisfiable head-of-line wait."""
    cfg, model, params = served
    server = LMServer(model, params, cap=24, batch_slots=2,
                      cache_layout="paged", block_size=8, n_blocks=2)
    with pytest.raises(ValueError, match="blocks"):
        server.submit(Request(rid=0, prompt=np.zeros(20, np.int32),
                              max_tokens=4))
    # prompt + max_tokens beyond the LINEAR capacity is rejected too: paged
    # addressing cannot ring-wrap like the dense layout, so those decode
    # writes would silently drop the request's own recent context
    ok = LMServer(model, params, cap=24, batch_slots=2,
                  cache_layout="paged", block_size=8)
    with pytest.raises(ValueError, match="linear capacity"):
        ok.submit(Request(rid=1, prompt=np.zeros(8, np.int32),
                          max_tokens=500))
    ok.submit(Request(rid=2, prompt=np.zeros(8, np.int32), max_tokens=16))


# --------------------------------------------------------------------------
# stacked-cache helpers: OOB drop-sentinel coverage under both layouts
# --------------------------------------------------------------------------

def test_cache_insert_oob_sentinel_drops_dense(served):
    """Direct coverage of the ``mode="drop"`` contract: admission rows
    addressed at the ``>= n_slots`` sentinel vanish instead of wrapping."""
    cfg, model, params = served
    live = model.init_cache(3, 24, per_slot_idx=True)
    rng = np.random.default_rng(0)
    new = {k: jnp.asarray(rng.normal(size=v.shape).astype(np.float32))
           if k != "idx" else jnp.asarray([5, 7], jnp.int32)
           for k, v in model.init_cache(2, 24, per_slot_idx=True).items()}
    out = lm_helpers.cache_insert(live, new, jnp.asarray([3, 1]))
    # row 0 targeted the sentinel slot 3: dropped everywhere
    assert float(jnp.abs(out["k"][:, [0, 2]]).sum()) == 0.0
    assert int(out["idx"][0]) == 0 and int(out["idx"][2]) == 0
    np.testing.assert_array_equal(np.asarray(out["k"][:, 1]),
                                  np.asarray(new["k"][:, 1]))
    assert int(out["idx"][1]) == 7
    # extract is the inverse on in-bounds slots
    back = lm_helpers.cache_extract(out, [1])
    np.testing.assert_array_equal(np.asarray(back["k"][:, 0]),
                                  np.asarray(new["k"][:, 1]))


def test_cache_insert_oob_sentinel_drops_paged(served):
    """Paged layout: dense prefill rows scatter through the live block
    tables; a sentinel slot gets an all-sentinel table (drops), unmapped
    table entries drop, and mapped positions land in their exact blocks."""
    cfg, model, params = served
    bs, cap = 8, 24
    live = model.init_cache(3, cap, per_slot_idx=True, layout="paged",
                            block_size=bs, n_blocks=4)
    alloc = BlockAllocator(4, bs, 3, max_blocks_per_slot=3)
    alloc.ensure(1, 16)                 # slot 1 -> blocks for pos 0..15 only
    live["bt"] = jnp.asarray(alloc.tables)
    rng = np.random.default_rng(1)
    new = {k: jnp.asarray(rng.normal(size=v.shape).astype(np.float32))
           if k != "idx" else jnp.asarray([20, 20], jnp.int32)
           for k, v in model.init_cache(2, cap, per_slot_idx=True).items()}
    out = lm_helpers.cache_insert(live, new, jnp.asarray([3, 1]))
    b0, b1 = alloc.slot_blocks(1)
    # row 1 (slot 1): positions 0..15 land in its two blocks ...
    np.testing.assert_array_equal(np.asarray(out["kp"][:, b0]),
                                  np.asarray(new["k"][:, 1, 0:bs]))
    np.testing.assert_array_equal(np.asarray(out["kp"][:, b1]),
                                  np.asarray(new["k"][:, 1, bs:2 * bs]))
    # ... positions 16..23 hit the unmapped sentinel entry: dropped
    unused = [b for b in range(4) if b not in (b0, b1)]
    assert float(jnp.abs(out["kp"][:, unused]).sum()) == 0.0
    # row 0 (sentinel slot 3) was dropped entirely, incl. its idx
    assert int(out["idx"][0]) == 0
    assert int(out["idx"][1]) == 20
    # extract: per-slot leaves gathered, pools pass through globally
    back = lm_helpers.cache_extract(out, [1])
    assert back["kp"].shape == out["kp"].shape
    np.testing.assert_array_equal(np.asarray(back["bt"][0]),
                                  alloc.tables[1])
    assert int(back["idx"][0]) == 20


# --------------------------------------------------------------------------
# elastic: slot resize + block-pool resize on the live paged engine
# --------------------------------------------------------------------------

def test_paged_resize_slots_and_pool_preserve_tokens(served):
    """Mid-flight slot grow + pool shrink/grow keep every in-flight stream
    emitting exactly its original greedy continuation (block ids move, the
    tables are rewritten, the tokens must not notice)."""
    cfg, model, params = served
    reqs = lambda: _mk_requests(cfg, 5, lens=[8], max_tokens=5, seed=9)
    grown = LMServer(model, params, cap=24, batch_slots=2,
                     cache_layout="paged", block_size=8)
    for r in reqs():
        grown.submit(r)
    grown.tick()
    grown.tick()
    grown.resize_slots(3)
    used = grown.alloc.used_count
    grown.resize_block_pool(used + 2)   # shrink to just above live blocks
    grown.resize_block_pool(9)          # grow back
    grown.alloc.check_invariants()
    fa = {r.rid: r.tokens_out for r in grown.run_until_drained()}
    fixed = LMServer(model, params, cap=24, batch_slots=3,
                     cache_layout="paged", block_size=8)
    for r in reqs():
        fixed.submit(r)
    fb = {r.rid: r.tokens_out for r in fixed.run_until_drained()}
    assert len(fa) == 5 and fa == fb


def test_pool_shrink_below_live_blocks_raises(served):
    cfg, model, params = served
    server = LMServer(model, params, cap=24, batch_slots=2,
                      cache_layout="paged", block_size=8)
    for r in _mk_requests(cfg, 2, lens=[10], max_tokens=6):
        server.submit(r)
    server.tick()
    with pytest.raises(ValueError, match="do not fit"):
        server.resize_block_pool(1)
    server.run_until_drained()


# --------------------------------------------------------------------------
# shardings cover the paged state
# --------------------------------------------------------------------------

def test_serve_state_shardings_cover_paged_state(served):
    from jax.sharding import Mesh, NamedSharding

    from repro.parallel.sharding import serve_state_shardings

    cfg, model, params = served
    server = LMServer(model, params, cap=24, batch_slots=2,
                      cache_layout="paged", block_size=8)
    assert {"kp", "vp", "bt"} <= set(server.state["cache"])
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    shardings = serve_state_shardings(mesh, cfg, server.state)
    flat, _ = jax.tree_util.tree_flatten(shardings)
    assert flat and all(isinstance(s, NamedSharding) for s in flat)
    jax.device_put(server.state, shardings)
