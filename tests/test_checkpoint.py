"""Checkpointing: atomicity, resume, async, GC, elastic metadata."""

import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint.checkpointer import Checkpointer


def _state(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "params": {"w": jnp.asarray(rng.normal(size=(4, 8)).astype(np.float32)),
                   "b": jnp.asarray(rng.normal(size=(8,)).astype(np.float32))},
        "opt": {"m": {"w": jnp.zeros((4, 8)), "b": jnp.zeros((8,))},
                "count": jnp.asarray(3, jnp.int32)},
        "step": jnp.asarray(7, jnp.int32),
    }


def test_save_restore_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    s = _state()
    ck.save(s, step=7, metadata={"data": {"step": 7, "seed": 0}})
    restored, meta = ck.restore(s)
    assert meta["data"]["step"] == 7
    for a, b in zip(jax.tree_util.tree_leaves(s),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep_last=2)
    s = _state()
    for step in (1, 2, 3, 4):
        ck.save(s, step=step)
    assert ck.latest_step() == 4
    assert ck.available_steps() == [3, 4]  # GC kept last 2


def test_async_save(tmp_path):
    ck = Checkpointer(str(tmp_path))
    s = _state()
    ck.save_async(s, step=1)
    ck.wait()
    assert ck.latest_step() == 1
    restored, _ = ck.restore(s)
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(s["params"]["w"]))


def test_tmp_dir_never_visible(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(_state(), step=5)
    names = os.listdir(tmp_path)
    assert all(not n.endswith(".tmp") for n in names)


def test_restore_specific_step(tmp_path):
    ck = Checkpointer(str(tmp_path), keep_last=5)
    s1, s2 = _state(1), _state(2)
    ck.save(s1, step=1)
    ck.save(s2, step=2)
    r1, _ = ck.restore(s1, step=1)
    np.testing.assert_array_equal(np.asarray(r1["params"]["w"]),
                                  np.asarray(s1["params"]["w"]))


def test_corrupt_tmp_is_ignored(tmp_path):
    """A crashed (uncommitted) write must not break restore."""
    ck = Checkpointer(str(tmp_path))
    ck.save(_state(), step=1)
    os.makedirs(tmp_path / "step_0000000002.tmp")  # simulated crash
    assert ck.latest_step() == 1
    restored, _ = ck.restore(_state())
    assert int(restored["step"]) == 7


def test_train_resume_equivalence(tmp_path):
    """Train 6 steps straight == train 3, checkpoint, restore, train 3."""
    from repro.configs import get_config
    from repro.configs.base import TrainConfig
    from repro.core.precision import get_policy
    from repro.data.pipeline import SyntheticLM, SyntheticLMConfig
    from repro.models import build_model
    from repro.models.lm import LMCallOptions
    from repro.runtime.trainer import init_train_state, make_train_step

    cfg = get_config("qwen2-0.5b").reduced()
    tc = TrainConfig(policy=get_policy("mirage"), lr=1e-3)
    model = build_model(cfg, get_policy("mirage"),
                        LMCallOptions(q_chunk=16, kv_chunk=16))
    step_fn = jax.jit(make_train_step(model, tc))
    dcfg = SyntheticLMConfig(vocab_size=cfg.vocab_size, seq_len=32,
                             batch_size=2)

    # run A: straight through
    state = init_train_state(model, tc, jax.random.PRNGKey(0))
    data = SyntheticLM(dcfg)
    for _ in range(6):
        state, _ = step_fn(state, next(data))
    loss_a = None
    state_a = state

    # run B: 3 steps, checkpoint (incl. data state), restore, 3 more
    state = init_train_state(model, tc, jax.random.PRNGKey(0))
    data = SyntheticLM(dcfg)
    for _ in range(3):
        state, _ = step_fn(state, next(data))
    ck = Checkpointer(str(tmp_path))
    ck.save(state, step=3, metadata={"data": data.state()})

    state_b, meta = ck.restore(state)
    data_b = SyntheticLM(dcfg)
    data_b.restore(meta["data"])
    for _ in range(3):
        state_b, _ = step_fn(state_b, next(data_b))

    for a, b in zip(jax.tree_util.tree_leaves(state_a["params"]),
                    jax.tree_util.tree_leaves(state_b["params"])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-6, atol=1e-7)
