"""Degrade hypothesis to per-test skips when it is not installed.

Test modules import ``given``/``settings``/``st`` from here instead of from
``hypothesis`` directly. With hypothesis present these are the real
objects; without it, ``@given(...)`` wraps the test in a
``pytest.importorskip("hypothesis")`` call so only the property tests skip
(with a clear reason) while the rest of the suite collects and runs.
"""

from __future__ import annotations

import pytest

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAS_HYPOTHESIS = True
except ImportError:
    HAS_HYPOTHESIS = False

    def given(*_args, **_kwargs):  # noqa: D401
        def deco(fn):
            def skipper(*a, **k):
                pytest.importorskip(
                    "hypothesis",
                    reason="property test requires hypothesis")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _StrategyStub:
        """st.integers(...), st.sampled_from(...), ... at decoration time."""

        def __getattr__(self, name):
            def strategy(*_a, **_k):
                return None
            return strategy

    st = _StrategyStub()
