"""Mirage GEMM path equivalences and gradient behaviour."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
# degrades to per-test skips when hypothesis is missing (see module)
from _hypothesis_compat import given, settings, st

from repro.core import gemm
from repro.core.precision import MiragePolicy, get_policy


def _rand(shape, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype(np.float32) * scale)


@pytest.mark.parametrize("shape", [(5, 37, 9), (2, 16, 4), (7, 64, 13), (1, 1, 1)])
def test_faithful_equals_rns(shape):
    """The RNS hardware path reconstructs the integer group dots EXACTLY."""
    m, k, n = shape
    x, w = _rand((m, k), 1), _rand((k, n), 2)
    pf = get_policy("mirage_faithful")
    pr = get_policy("mirage_rns")
    of = gemm.mirage_matmul_nograd(x, w, pf)
    orn = gemm.mirage_matmul_nograd(x, w, pr)
    np.testing.assert_array_equal(np.asarray(of), np.asarray(orn))


@pytest.mark.parametrize("shape", [(5, 37, 9), (3, 128, 17), (2, 16, 4)])
def test_fast_close_to_faithful(shape):
    """Folding scales into mantissas == per-group accumulation, up to f32
    accumulation order (exact when partials are exactly representable)."""
    m, k, n = shape
    x, w = _rand((m, k), 3), _rand((k, n), 4)
    pf = get_policy("mirage_faithful")
    pq = get_policy("mirage")
    of = np.asarray(gemm.mirage_matmul_nograd(x, w, pf))
    oq = np.asarray(gemm.mirage_matmul_nograd(x, w, pq))
    np.testing.assert_allclose(oq, of, rtol=1e-6, atol=1e-6 * np.abs(of).max())


def test_fast_exactly_equals_faithful_small_k():
    """With one group the accumulation orders coincide -> bitwise equal."""
    x, w = _rand((4, 16, ), 5), _rand((16, 8), 6)
    of = np.asarray(gemm.mirage_matmul_nograd(x, w, get_policy("mirage_faithful")))
    oq = np.asarray(gemm.mirage_matmul_nograd(x, w, get_policy("mirage")))
    np.testing.assert_array_equal(oq, of)


def test_bf16_compute_dtype_value_identical():
    """BFP(b_m=4) values are exact in bfloat16 -> same products on the MXU."""
    x, w = _rand((8, 64), 7), _rand((64, 8), 8)
    p32 = get_policy("mirage")
    p16 = get_policy("mirage", compute_dtype="bfloat16")
    o32 = np.asarray(gemm.mirage_matmul_nograd(x, w, p32))
    o16 = np.asarray(gemm.mirage_matmul_nograd(x, w, p16))
    # products are exact in bf16; accumulation is f32 in both paths
    np.testing.assert_allclose(o16, o32, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("mode", ["fp32", "bf16", "int8", "mirage_fast"])
def test_modes_approximate_fp32(mode):
    x, w = _rand((6, 96), 9, 0.5), _rand((96, 10), 10, 0.5)
    ref = np.asarray(gemm.mirage_matmul_nograd(x, w, get_policy("fp32")))
    out = np.asarray(gemm.mirage_matmul_nograd(x, w, get_policy(mode if mode != "mirage_fast" else "mirage")))
    rel = np.abs(out - ref).max() / (np.abs(ref).max() + 1e-9)
    tol = {"fp32": 1e-7, "bf16": 2e-2, "int8": 4e-2, "mirage_fast": 0.12}[mode]
    assert rel < tol, f"{mode}: rel err {rel}"


def test_batched_leading_dims():
    x = _rand((2, 3, 5, 32), 11)
    w = _rand((32, 7), 12)
    p = get_policy("mirage")
    out = gemm.mirage_matmul_nograd(x, w, p)
    assert out.shape == (2, 3, 5, 7)
    ref = gemm.mirage_matmul_nograd(x.reshape(-1, 32), w, p).reshape(2, 3, 5, 7)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-6)


def test_custom_vjp_grads_close_to_fp32():
    x, w = _rand((4, 48), 13, 0.3), _rand((48, 6), 14, 0.3)

    def loss(xx, ww, policy):
        return jnp.sum(gemm.mirage_matmul(xx, ww, policy) ** 2)

    gx_ref, gw_ref = jax.grad(loss, argnums=(0, 1))(x, w, get_policy("fp32"))
    gx, gw = jax.grad(loss, argnums=(0, 1))(x, w, get_policy("mirage"))
    for got, ref in ((gx, gx_ref), (gw, gw_ref)):
        rel = np.abs(np.asarray(got) - np.asarray(ref)).max() / (np.abs(np.asarray(ref)).max() + 1e-9)
        assert rel < 0.15, rel


def test_custom_vjp_backward_is_quantized():
    """The backward GEMMs must themselves be BFP-quantized (not FP32)."""
    x, w = _rand((4, 48), 15), _rand((48, 6), 16)

    def loss(xx, ww, policy):
        return jnp.sum(gemm.mirage_matmul(xx, ww, policy))

    # cotangent of ones: dX = 1 @ W^T quantized along N. With N=6 < g=16 the
    # quantization of the all-ones cotangent is exact, but W columns get BFP'd:
    gx_m = np.asarray(jax.grad(loss)(x, w, get_policy("mirage")))
    gx_f = np.asarray(jax.grad(loss)(x, w, get_policy("fp32")))
    assert not np.array_equal(gx_m, gx_f)  # quantization visibly applied
    rel = np.abs(gx_m - gx_f).max() / np.abs(gx_f).max()
    assert rel < 0.1


@settings(deadline=None, max_examples=50)
@given(
    m=st.integers(1, 8), k=st.integers(1, 96), n=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_rns_equals_faithful(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    of = np.asarray(gemm.mirage_matmul_nograd(x, w, get_policy("mirage_faithful")))
    orn = np.asarray(gemm.mirage_matmul_nograd(x, w, get_policy("mirage_rns")))
    np.testing.assert_array_equal(of, orn)


@settings(deadline=None, max_examples=30)
@given(
    b_m=st.sampled_from([3, 4, 5]),
    g=st.sampled_from([8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_property_quantization_error_shrinks_with_bm(b_m, g, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(4, 64)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(64, 4)).astype(np.float32))
    ref = np.asarray(gemm.mirage_matmul_nograd(x, w, get_policy("fp32")))
    p = MiragePolicy(mode="mirage_fast", b_m=b_m, g=g, k=max(5, b_m + 2))
    out = np.asarray(gemm.mirage_matmul_nograd(x, w, p))
    rel = np.abs(out - ref).max() / (np.abs(ref).max() + 1e-9)
    assert rel < 0.75 * 2.0 ** (-b_m) * np.sqrt(64) * 4  # loose analytic bound


def test_jit_and_grad_compile():
    x, w = _rand((4, 32), 17), _rand((32, 8), 18)
    p = get_policy("mirage")
    f = jax.jit(lambda a, b: gemm.mirage_matmul(a, b, p))
    out = f(x, w)
    assert out.shape == (4, 8)
    g = jax.jit(jax.grad(lambda a, b: jnp.sum(gemm.mirage_matmul(a, b, p) ** 2)))
    assert g(x, w).shape == x.shape
