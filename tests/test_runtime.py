"""Runtime layers: server continuous batching, schedules, straggler monitor,
preemption-safe loop."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core.precision import get_policy
from repro.models import build_model
from repro.models.lm import LMCallOptions
from repro.optim import schedules
from repro.runtime.elastic import StragglerMitigator
from repro.runtime.server import LMServer, PerSlotLMServer, Request


@pytest.fixture(scope="module")
def served():
    cfg = get_config("qwen2-0.5b").reduced()
    model = build_model(cfg, get_policy("mirage"),
                        LMCallOptions(q_chunk=16, kv_chunk=16))
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.mark.parametrize("engine", [LMServer, PerSlotLMServer])
def test_server_completes_all_requests(served, engine):
    cfg, model, params = served
    server = engine(model, params, cap=24, batch_slots=2)
    rng = np.random.default_rng(0)
    for rid in range(5):
        server.submit(Request(rid=rid,
                              prompt=rng.integers(0, cfg.vocab_size, 8
                                                  ).astype(np.int32),
                              max_tokens=4))
    finished = server.run_until_drained()
    assert len(finished) == 5
    assert all(len(r.tokens_out) == 4 for r in finished)
    assert server.metrics["completed"] == 5


@pytest.mark.parametrize("engine", [LMServer, PerSlotLMServer])
def test_server_greedy_matches_manual_decode(served, engine):
    cfg, model, params = served
    rng = np.random.default_rng(1)
    prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)

    server = engine(model, params, cap=24, batch_slots=1)
    server.submit(Request(rid=0, prompt=prompt, max_tokens=3))
    [req] = server.run_until_drained()

    logits, cache = model.prefill(params, jnp.asarray(prompt)[None, :], 24)
    toks = [int(jnp.argmax(logits[0, -1]))]
    for _ in range(2):
        logits, cache = model.decode_step(
            params, cache, jnp.asarray([[toks[-1]]], jnp.int32))
        toks.append(int(jnp.argmax(logits[0, -1])))
    assert req.tokens_out == toks


def test_eos_stops_generation(served):
    cfg, model, params = served
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    # discover the first emitted token, then use it as EOS
    s1 = LMServer(model, params, cap=24, batch_slots=1)
    s1.submit(Request(rid=0, prompt=prompt, max_tokens=4))
    [r1] = s1.run_until_drained()
    eos = r1.tokens_out[1] if len(r1.tokens_out) > 1 else r1.tokens_out[0]
    s2 = LMServer(model, params, cap=24, batch_slots=1)
    s2.submit(Request(rid=0, prompt=prompt, max_tokens=10, eos_id=eos))
    [r2] = s2.run_until_drained()
    assert len(r2.tokens_out) <= 10
    assert eos in r2.tokens_out


def test_schedules():
    step = schedules.step_decay(0.01, decay_every=20)
    assert float(step(jnp.asarray(0))) == pytest.approx(0.01)
    assert float(step(jnp.asarray(20))) == pytest.approx(0.001)
    assert float(step(jnp.asarray(40))) == pytest.approx(0.0001)
    wc = schedules.warmup_cosine(1.0, warmup=10, total=110)
    assert float(wc(jnp.asarray(0))) == 0.0
    assert float(wc(jnp.asarray(10))) == pytest.approx(1.0)
    assert float(wc(jnp.asarray(110))) == pytest.approx(0.1, rel=1e-3)


def test_straggler_monitor():
    events = []
    sm = StragglerMitigator(factor=2.0, patience=2,
                            on_straggle=lambda s, dt: events.append((s, dt)))
    for i in range(10):
        sm.record(i, 1.0)
    assert sm.events == 0
    sm.record(10, 5.0)
    sm.record(11, 5.0)   # second consecutive slow step -> event
    assert sm.events == 1 and len(events) == 1
