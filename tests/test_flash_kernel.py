"""Flash-attention Pallas kernel vs the chunked-JAX reference (interpret)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.models.attention import chunked_attention


def _rand(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype(np.float32) * 0.5)


def _ref(q, k, v, causal=True, window=None):
    Lq, Sk = q.shape[1], k.shape[1]
    return chunked_attention(q, k, v, jnp.arange(Lq), jnp.arange(Sk),
                             causal=causal, window=window,
                             q_chunk=16, kv_chunk=16)


@pytest.mark.parametrize("shape", [
    (1, 32, 4, 16, 32, 4),    # MHA
    (2, 24, 8, 16, 24, 2),    # GQA 4:1
    (1, 17, 6, 8, 33, 3),     # ragged lengths, GQA 2:1
])
def test_flash_matches_ref(shape):
    B, Lq, H, D, Sk, Kv = shape
    q = _rand((B, Lq, H, D), 1)
    k = _rand((B, Sk, Kv, D), 2)
    v = _rand((B, Sk, Kv, D), 3)
    got = flash_attention(q, k, v, causal=True, block_q=8, block_k=8,
                          interpret=True)
    want = _ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("window", [None, 8])
def test_flash_masks(causal, window):
    B, L, H, D, Kv = 1, 20, 4, 8, 4
    q = _rand((B, L, H, D), 4)
    k = _rand((B, L, Kv, D), 5)
    v = _rand((B, L, Kv, D), 6)
    got = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=8, block_k=8, interpret=True)
    want = _ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_block_shape_invariance():
    B, L, H, D, Kv = 1, 40, 4, 16, 2
    q, k, v = _rand((B, L, H, D), 7), _rand((B, L, Kv, D), 8), _rand((B, L, Kv, D), 9)
    a = flash_attention(q, k, v, block_q=8, block_k=16, interpret=True)
    b = flash_attention(q, k, v, block_q=16, block_k=8, interpret=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_dtypes(dtype):
    B, L, H, D, Kv = 1, 16, 2, 8, 2
    q = _rand((B, L, H, D), 10).astype(dtype)
    k = _rand((B, L, Kv, D), 11).astype(dtype)
    v = _rand((B, L, Kv, D), 12).astype(dtype)
    got = flash_attention(q, k, v, block_q=8, block_k=8, interpret=True)
    want = _ref(q.astype(jnp.float32), k.astype(jnp.float32),
                v.astype(jnp.float32))
    tol = 2e-5 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got).astype(np.float32),
                               np.asarray(want), rtol=tol, atol=tol)


def test_flash_option_in_model_matches_reference():
    """LM forward with use_flash_kernel == reference attention path."""
    from repro.configs import ARCHS
    from repro.core.precision import get_policy
    from repro.models import build_model
    from repro.models.lm import LMCallOptions

    cfg = ARCHS["qwen3-14b"].reduced()
    policy = get_policy("mirage")
    m0 = build_model(cfg, policy, LMCallOptions(q_chunk=16, kv_chunk=16))
    m1 = build_model(cfg, policy, LMCallOptions(q_chunk=16, kv_chunk=16,
                                                use_flash_kernel=True))
    params = m0.init(jax.random.PRNGKey(0))
    tokens = jnp.asarray(np.arange(2 * 16).reshape(2, 16) % cfg.vocab_size,
                         jnp.int32)
    l0, _, _ = m0.forward(params, tokens)
    l1, _, _ = m1.forward(params, tokens)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l0),
                               rtol=2e-4, atol=2e-4)
