"""Prefix-sharing KV cache + speculative decoding over the paged engine:
token-exact parity vs the dense engine and the per-slot oracle (all 4
model families), full-prefix-hit admission (no prefill, TTFT stamped at
first-token host materialization), copy-on-write isolation between
sharers, sliding-window block trims mid-flight, and error-corrected RRNS
serving (exact parity at high SNR across differing noise streams,
per-seed determinism at low SNR)."""

import numpy as np
import jax
import pytest

from repro.configs import get_config
from repro.core.precision import get_policy
from repro.models import build_model
from repro.models.lm import LMCallOptions
from repro.runtime.server import LMServer, PerSlotLMServer, Request

FAMILIES = ["qwen2-0.5b", "mixtral-8x7b", "mamba2-2.7b", "zamba2-2.7b"]


@pytest.fixture(scope="module")
def served():
    cfg = get_config("qwen2-0.5b").reduced()
    model = build_model(cfg, get_policy("mirage"),
                        LMCallOptions(q_chunk=16, kv_chunk=16))
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _shared_requests(cfg, n, prefix_len, total_len, max_tokens=4, seed=3):
    """n prompts sharing their first ``prefix_len`` tokens."""
    rng = np.random.default_rng(seed)
    prefix = rng.integers(0, cfg.vocab_size, prefix_len).astype(np.int32)
    out = []
    for i in range(n):
        tail = rng.integers(0, cfg.vocab_size,
                            total_len - prefix_len).astype(np.int32)
        out.append(Request(rid=i, prompt=np.concatenate([prefix, tail]),
                           max_tokens=max_tokens))
    return out


def _drain(server, reqs):
    for r in reqs:
        server.submit(r)
    out = {r.rid: r.tokens_out for r in server.run_until_drained()}
    if server.alloc is not None:
        server.alloc.check_invariants()
        assert server.alloc.used_count == 0
    return out


# --------------------------------------------------------------------------
# parity: prefix-shared / speculative / both vs dense vs oracle
# --------------------------------------------------------------------------

@pytest.mark.parametrize("arch", FAMILIES)
def test_prefix_and_spec_token_exact_across_families(arch):
    """The acceptance gate: greedy decode with prefix sharing, with
    speculative decoding, and with both at once emits exactly the dense
    engine's (and the per-slot oracle's) tokens for every family —
    attention, MoE+SWA, pure SSM (prefix inert, spec via the scanned
    recurrence) and the hybrid."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg, get_policy("mirage"),
                        LMCallOptions(q_chunk=16, kv_chunk=16))
    params = model.init(jax.random.PRNGKey(0))
    mk = lambda: _shared_requests(cfg, 4, 8, 12, max_tokens=4)
    run = lambda **kw: LMServer(model, params, cap=24, batch_slots=2, **kw)

    dense = _drain(run(), mk())
    oracle = PerSlotLMServer(model, params, cap=24, batch_slots=2)
    for r in mk():
        oracle.submit(r)
    orc = {r.rid: r.tokens_out for r in oracle.run_until_drained()}

    sp = run(cache_layout="paged", block_size=4, prefix_cache=True)
    pref = _drain(sp, mk())
    sv = run(cache_layout="paged", block_size=4, spec_k=3)
    spec = _drain(sv, mk())
    both = _drain(run(cache_layout="paged", block_size=4, prefix_cache=True,
                      spec_k=3), mk())

    assert dense == orc and len(dense) == 4
    assert pref == dense and spec == dense and both == dense
    if model.kind != "mamba":
        # the 8-token shared prefix = 2 full blocks actually got shared
        assert sp.metrics["prefix_hits"] >= 1
        assert sp.metrics["prefix_shared_blocks"] >= 2
    assert sv.metrics["spec_ticks"] >= 1
    assert sv.metrics["spec_accepted"] >= sv.metrics["spec_slot_ticks"]


def test_prefix_spec_compose_with_chunked_prefill(served):
    """All three serving features at once: chunked prefill resumes AFTER
    the shared prefix, full hits skip the chunk queue, and verify ticks
    leave mid-prefill slots frozen — still token-identical to dense."""
    cfg, model, params = served
    mk = lambda: _shared_requests(cfg, 5, 8, 13, max_tokens=5, seed=6)
    dense = _drain(LMServer(model, params, cap=32, batch_slots=2), mk())
    s = LMServer(model, params, cap=32, batch_slots=2, cache_layout="paged",
                 block_size=4, prefill_chunk=4, prefix_cache=True, spec_k=3)
    assert _drain(s, mk()) == dense
    assert s.metrics["prefix_hits"] >= 1


# --------------------------------------------------------------------------
# full-prefix hit: no prefill, TTFT stamped at first-token materialization
# --------------------------------------------------------------------------

def test_full_prefix_hit_skips_prefill_and_stamps_ttft(served):
    cfg, model, params = served
    server = LMServer(model, params, cap=24, batch_slots=2,
                      cache_layout="paged", block_size=4, prefix_cache=True)
    prompt = (np.arange(12) % cfg.vocab_size).astype(np.int32)
    r0 = Request(rid=0, prompt=prompt.copy(), max_tokens=6)
    r1 = Request(rid=1, prompt=prompt.copy(), max_tokens=6)
    server.submit(r0)
    server.tick()
    prefills_before = server.metrics["prefill_batches"]
    server.submit(r1)
    server.tick()
    # r1's whole prompt minus its last token was in shared blocks: admitted
    # with NO prefill, its first token comes from the decode tick, and TTFT
    # is stamped at that token's host materialization — not at admission
    assert server.metrics["prefix_full_hits"] == 1
    assert len(r1.tokens_out) == 1
    assert r1.t_first_token >= r1.t_admit > 0
    done = {r.rid: r for r in server.run_until_drained()}
    assert len(done) == 2
    # identical prompts under greedy -> identical continuations
    assert done[0].tokens_out == done[1].tokens_out
    server.alloc.check_invariants()
    assert server.alloc.used_count == 0


def test_cow_fork_isolates_sharers(served):
    """Two requests sharing a prefix diverge after it; each must emit the
    same tokens as when served alone (a sharer's decode writes must never
    leak into the other's blocks)."""
    cfg, model, params = served
    mk = lambda: _shared_requests(cfg, 2, 8, 12, max_tokens=6, seed=11)
    solo = {}
    for r in mk():
        solo.update(_drain(LMServer(model, params, cap=24, batch_slots=1),
                           [r]))
    shared = _drain(LMServer(model, params, cap=24, batch_slots=2,
                             cache_layout="paged", block_size=4,
                             prefix_cache=True), mk())
    assert shared == solo


# --------------------------------------------------------------------------
# sliding-window trims
# --------------------------------------------------------------------------

def test_swa_trim_frees_behind_window_blocks():
    """Mid-flight, an SWA slot's blocks wholly behind the attention window
    are returned to the pool (the validity mask already hides them) — and
    the stream still emits exactly the dense engine's tokens."""
    cfg = get_config("mixtral-8x7b").reduced()   # sliding_window = 32
    model = build_model(cfg, get_policy("mirage"),
                        LMCallOptions(q_chunk=16, kv_chunk=16))
    params = model.init(jax.random.PRNGKey(0))
    mk = lambda: _shared_requests(cfg, 1, 4, 8, max_tokens=34, seed=7)

    def run(**kw):
        server = LMServer(model, params, cap=48, batch_slots=1, **kw)
        [r] = mk()
        server.submit(r)
        trimmed = 0
        for _ in range(200):
            if not server.scheduler.waiting and server.slot_req[0] is None:
                break
            server.tick()
            if server.alloc is not None:
                trimmed = max(trimmed, int(server.alloc.lo[0]))
        return server, r.tokens_out, trimmed

    _, dense, _ = run()
    s, paged, trimmed = run(cache_layout="paged", block_size=4)
    assert paged == dense and len(dense) == 34
    assert trimmed >= 2                 # blocks actually freed mid-flight
    s.alloc.check_invariants()
    assert s.alloc.used_count == 0


# --------------------------------------------------------------------------
# error-corrected RRNS serving
# --------------------------------------------------------------------------

def test_rrns_high_snr_exact_parity_across_noise_streams():
    """At high SNR the RRNS correction is exact, so engines drawing from
    DIFFERENT noise-key streams (prefix admission uses the chunk stream;
    spec verify advances the tick stream once per k+1 tokens) still emit
    bit-identical greedy tokens."""
    cfg = get_config("qwen2-0.5b").reduced()
    policy = get_policy("mirage_rrns", snr_db=60.0, noise_seed=7)
    model = build_model(cfg, policy, LMCallOptions(q_chunk=16, kv_chunk=16))
    params = model.init(jax.random.PRNGKey(0))
    mk = lambda: _shared_requests(cfg, 3, 8, 12, max_tokens=4, seed=5)
    run = lambda **kw: _drain(
        LMServer(model, params, cap=24, batch_slots=2, **kw), mk())
    dense = run()
    assert run(cache_layout="paged", block_size=4,
               prefix_cache=True) == dense
    assert run(cache_layout="paged", block_size=4, spec_k=3) == dense


def test_rrns_low_snr_per_seed_determinism():
    """At serving SNR the guarantee is per-seed determinism: the same
    noise_seed replays the identical token stream, prefix-shared and
    speculative alike."""
    cfg = get_config("qwen2-0.5b").reduced()
    policy = get_policy("mirage_rrns", snr_db=28.0, noise_seed=9)
    model = build_model(cfg, policy, LMCallOptions(q_chunk=16, kv_chunk=16))
    params = model.init(jax.random.PRNGKey(0))
    mk = lambda: _shared_requests(cfg, 3, 8, 12, max_tokens=4, seed=8)
    run = lambda **kw: _drain(
        LMServer(model, params, cap=24, batch_slots=2, cache_layout="paged",
                 block_size=4, **kw), mk())
    assert run(prefix_cache=True) == run(prefix_cache=True)
    assert run(spec_k=3) == run(spec_k=3)


# --------------------------------------------------------------------------
# knob validation
# --------------------------------------------------------------------------

def test_flag_validation(served):
    cfg, model, params = served
    with pytest.raises(ValueError, match="prefix_cache"):
        LMServer(model, params, cap=24, batch_slots=2, prefix_cache=True)
    with pytest.raises(ValueError, match="spec_k"):
        LMServer(model, params, cap=24, batch_slots=2, spec_k=3)
    with pytest.raises(ValueError, match="greedy"):
        LMServer(model, params, cap=24, batch_slots=2, cache_layout="paged",
                 spec_k=3, greedy=False)
    with pytest.raises(ValueError, match="spec_k"):
        LMServer(model, params, cap=24, batch_slots=2, cache_layout="paged",
                 spec_k=-1)
