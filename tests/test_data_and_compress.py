"""Data pipeline determinism + BFP gradient compression properties."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
# degrades to per-test skips when hypothesis is missing (see module)
from _hypothesis_compat import given, settings, st

from repro.data.pipeline import SyntheticLM, SyntheticLMConfig
from repro.optim import grad_compress as gc


def test_pipeline_deterministic_across_instances():
    cfg = SyntheticLMConfig(vocab_size=128, seq_len=16, batch_size=2, seed=3)
    a, b = SyntheticLM(cfg), SyntheticLM(cfg)
    for _ in range(3):
        ba, bb = next(a), next(b)
        np.testing.assert_array_equal(ba["tokens"], bb["tokens"])


def test_pipeline_restart_resumes_same_batches():
    cfg = SyntheticLMConfig(vocab_size=128, seq_len=16, batch_size=2, seed=5)
    a = SyntheticLM(cfg)
    seen = [next(a) for _ in range(5)]
    st_ = a.state()
    b = SyntheticLM(cfg)
    b.restore(st_)
    nxt = next(b)
    expect = a.batch_at(5)
    np.testing.assert_array_equal(nxt["tokens"], expect["tokens"])
    assert not np.array_equal(seen[4]["tokens"], nxt["tokens"])


def test_pipeline_shards_differ():
    c0 = SyntheticLMConfig(vocab_size=128, seq_len=16, batch_size=2, seed=1,
                           shard_id=0, num_shards=2)
    c1 = SyntheticLMConfig(vocab_size=128, seq_len=16, batch_size=2, seed=1,
                           shard_id=1, num_shards=2)
    b0, b1 = next(SyntheticLM(c0)), next(SyntheticLM(c1))
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_labels_shifted():
    cfg = SyntheticLMConfig(vocab_size=128, seq_len=16, batch_size=2)
    b = next(SyntheticLM(cfg))
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_bigram_structure_learnable():
    """Bigram stream entropy is far below uniform — the training signal."""
    cfg = SyntheticLMConfig(vocab_size=64, seq_len=512, batch_size=4)
    b = next(SyntheticLM(cfg))
    src = SyntheticLM(cfg)
    # every (prev -> next) pair must be one of the 8 allowed successors
    toks, labels = b["tokens"], b["labels"]
    ok = 0
    for prev, nxt in zip(toks.flatten(), labels.flatten()):
        ok += nxt in src.succ[prev]
    assert ok / toks.size > 0.99


# --------------------------------------------------------------------------
# gradient compression
# --------------------------------------------------------------------------

def test_compression_ratio():
    assert gc.compression_ratio(4, 16) == pytest.approx(4 / ((5 + 0.5) / 8))


def test_error_feedback_reduces_bias():
    """With error feedback the MEAN compressed gradient converges to the true
    gradient (compression bias vanishes); without it the bias persists."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=(8, 64)).astype(np.float32))
    err = jnp.zeros_like(g_true)
    acc_ef = np.zeros_like(np.asarray(g_true))
    acc_plain = np.zeros_like(np.asarray(g_true))
    n = 50
    for _ in range(n):
        q_ef, err = gc.compress_with_error_feedback(g_true, err, b_m=2, g=8)
        acc_ef += np.asarray(q_ef)
        acc_plain += np.asarray(gc.compress_tree(g_true, b_m=2, g=8))
    bias_ef = np.abs(acc_ef / n - np.asarray(g_true)).max()
    bias_plain = np.abs(acc_plain / n - np.asarray(g_true)).max()
    assert bias_ef < 0.15 * bias_plain + 1e-5, (bias_ef, bias_plain)


def test_error_feedback_on_quadratic_converges():
    """SGD on a quadratic with aggressively compressed grads still converges
    when error feedback is on."""
    rng = np.random.default_rng(1)
    target = jnp.asarray(rng.normal(size=(16,)).astype(np.float32))
    x = jnp.zeros((16,))
    err = {"x": jnp.zeros((16,))}
    for _ in range(300):
        g = {"x": x - target}
        q, err = gc.compress_with_error_feedback(g, err, b_m=2, g=8)
        x = x - 0.3 * q["x"]
    assert float(jnp.abs(x - target).max()) < 0.05


@settings(deadline=None, max_examples=30)
@given(b_m=st.sampled_from([2, 3, 4]), seed=st.integers(0, 2**31 - 1))
def test_compress_idempotent_on_grid(b_m, seed):
    """Compressing an already-compressed tensor is exact (grid fixpoint)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(4, 32)).astype(np.float32))
    once = gc.compress_tree(x, b_m=b_m, g=16)
    twice = gc.compress_tree(once, b_m=b_m, g=16)
    np.testing.assert_array_equal(np.asarray(once), np.asarray(twice))
