"""Chaos-injection harness + request-level robustness: schedule parsing and
control composition, deterministic host corruption, engine survival of every
host-side fault site (corrupted transfers, pool squeezes, prefill worker
crashes), deadline expiry in queue and mid-decode, admission caps, graceful
drain/shutdown, and the empty-drain latency guards."""

import numpy as np
import jax
import pytest

from repro.configs import get_config
from repro.core.precision import get_policy
from repro.models import build_model
from repro.models.lm import LMCallOptions
from repro.runtime.faults import (SITES, FaultEvent, FaultInjector,
                                  FaultSchedule)
from repro.runtime.server import (TERMINAL_STATUSES, AdmissionRejected,
                                  LMServer, Request, Scheduler)


@pytest.fixture(scope="module")
def served():
    cfg = get_config("qwen2-0.5b").reduced()
    model = build_model(cfg, get_policy("mirage"),
                        LMCallOptions(q_chunk=16, kv_chunk=16))
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _mk_requests(cfg, n, lens, max_tokens=4, seed=0, **req_kw):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        lens[i % len(lens)]).astype(np.int32),
                    max_tokens=max_tokens, **req_kw)
            for i in range(n)]


# --------------------------------------------------------------------------
# schedule / injector unit semantics
# --------------------------------------------------------------------------

def test_schedule_parse_compact_form():
    s = FaultSchedule.parse(
        "snr_drop@4:12:scale=30;worker_crash@2;"
        "pool_exhaustion@3:9:blocks=16")
    assert len(s) == 3
    snr, crash, pool = s.events
    assert (snr.site, snr.start, snr.stop) == ("snr_drop", 4, 12)
    assert snr.get("scale") == 30.0
    assert (crash.start, crash.stop) == (2, 3)  # stop defaults start+1
    assert pool.get("blocks") == 16
    assert s.horizon == 12
    assert s.sites() == {"snr_drop", "worker_crash", "pool_exhaustion"}
    assert FaultSchedule.parse("").describe() == "(empty)"


def test_schedule_validation():
    with pytest.raises(ValueError, match="unknown fault site"):
        FaultEvent(site="meteor_strike", start=0, stop=1)
    with pytest.raises(ValueError, match="bad window"):
        FaultEvent(site="snr_drop", start=5, stop=5)
    with pytest.raises(ValueError, match="unknown params"):
        FaultEvent(site="snr_drop", start=0, stop=1, params={"rate": 2})
    with pytest.raises(ValueError, match="expected site@"):
        FaultSchedule.parse("snr_drop")


def test_controls_compose_and_identity():
    inj = FaultInjector(FaultSchedule.parse(
        "snr_drop@0:4:scale=10;snr_drop@2:4:scale=3;"
        "burst_storm@2:3:rate=0.1,width=2;burst_storm@2:3:rate=0.2,width=4;"
        "stuck_channel@2:3:channel=1,level=7"), seed=0)
    c = inj.controls(2, n_moduli=3)
    assert c["sigma_scale"] == np.float32(30.0)      # overlaps multiply
    assert np.isclose(c["burst_rate"], 0.3)          # rates add
    assert c["burst_width"] == 4                     # width takes max
    assert list(c["stuck_mask"]) == [False, True, False]
    assert c["stuck_level"][1] == 7
    ident = inj.controls(100, n_moduli=3)            # outside every window
    assert ident["sigma_scale"] == 1.0 and ident["burst_rate"] == 0.0
    assert not ident["stuck_mask"].any()
    assert any("enters window" in l for l in inj.log)
    assert any("leaves window" in l for l in inj.log)


def test_corrupt_tokens_deterministic_and_out_of_vocab():
    toks = np.arange(64, dtype=np.int64)
    mk = lambda seed: FaultInjector(
        FaultSchedule.parse("host_corruption@3:5:rate=0.5"), seed=seed)
    a = mk(1).corrupt_tokens(3, toks, vocab_size=100)
    b = mk(1).corrupt_tokens(3, toks, vocab_size=100)
    np.testing.assert_array_equal(a, b)              # seeded replay
    hit = a != toks
    assert hit.any() and (a[hit] >= 100).all()       # always out-of-vocab
    assert (mk(2).corrupt_tokens(3, toks, 100) != a).any()
    np.testing.assert_array_equal(                   # inactive tick: no-op
        mk(1).corrupt_tokens(7, toks, 100), toks)


def test_worker_crash_fires_once_per_event():
    inj = FaultInjector(FaultSchedule.parse("worker_crash@2;worker_crash@5"))
    fired = [t for t in range(8) if inj.worker_crash(t)]
    assert fired == [2, 5]
    assert not inj.worker_crash(2)                   # consumed


# --------------------------------------------------------------------------
# engine under host-side fault sites
# --------------------------------------------------------------------------

def test_host_corruption_detected_retried_and_token_exact(served):
    """A corrupted device->host transfer is caught by vocab-range
    validation, the slot aborted and the request retried from scratch —
    the committed streams never contain a corrupt token and, once the
    window passes, match the clean engine exactly."""
    cfg, model, params = served
    kw = dict(n=4, lens=[6, 9], max_tokens=4, seed=3)
    clean = LMServer(model, params, cap=24, batch_slots=2)
    for r in _mk_requests(cfg, **kw):
        clean.submit(r)
    want = {r.rid: r.tokens_out for r in clean.run_until_drained()}

    inj = FaultInjector(
        FaultSchedule.parse("host_corruption@1:3:rate=1.0"), seed=1)
    chaos = LMServer(model, params, cap=24, batch_slots=2,
                     fault_injector=inj, max_retries=8)
    reqs = _mk_requests(cfg, **kw)
    for r in reqs:
        chaos.submit(r)
    finished = chaos.run_until_drained()
    assert all(r.status in TERMINAL_STATUSES for r in reqs)
    assert chaos.metrics["retried"] >= 1
    assert any("host_corruption flipped" in l for l in inj.log)
    got = {r.rid: r.tokens_out for r in finished if r.status == "completed"}
    assert got == {rid: want[rid] for rid in got} and got
    assert all(s is None for s in chaos.slot_req)    # no stranded slots


def test_pool_exhaustion_squeeze_delays_but_preserves_streams(served):
    """A quarantine squeeze on the paged block pool forces admissions
    through the real exhaustion paths; the drain still completes with the
    clean engine's exact streams, the quarantine is returned when the
    window closes, and the allocator invariants hold throughout."""
    cfg, model, params = served
    kw = dict(n=5, lens=[8, 11], max_tokens=4, seed=2)
    pkw = dict(cache_layout="paged", block_size=4, n_blocks=48)
    clean = LMServer(model, params, cap=24, batch_slots=2, **pkw)
    for r in _mk_requests(cfg, **kw):
        clean.submit(r)
    want = {r.rid: r.tokens_out for r in clean.run_until_drained()}

    inj = FaultInjector(
        FaultSchedule.parse("pool_exhaustion@1:5:blocks=40"), seed=0)
    chaos = LMServer(model, params, cap=24, batch_slots=2,
                     fault_injector=inj, **pkw)
    squeezed = []
    reqs = _mk_requests(cfg, **kw)
    for r in reqs:
        chaos.submit(r)
    while (chaos.scheduler.waiting
           or any(s is not None for s in chaos.slot_req)):
        chaos.tick()
        chaos.alloc.check_invariants()
        squeezed.append(len(chaos.alloc.quarantined))
    got = {r.rid: r.tokens_out for r in chaos.scheduler.finished}
    assert got == want
    assert max(squeezed) > 0                         # the squeeze happened
    assert not chaos.alloc.quarantined               # and was returned
    assert all(r.status == "completed" for r in reqs)


# --------------------------------------------------------------------------
# deadlines, retries, admission control, drain
# --------------------------------------------------------------------------

def test_queue_deadline_expires_waiting_requests(served):
    """Queue-TTL expiry runs at tick start, BEFORE admission: with a zero
    TTL every request retires as timed_out without ever reaching a slot —
    and the latency summary stays all-zero-guarded for phases nothing
    reached. A generous TTL admits and completes everything."""
    cfg, model, params = served
    server = LMServer(model, params, cap=24, batch_slots=1,
                      default_queue_ttl_s=0.0)
    reqs = _mk_requests(cfg, n=4, lens=[6], max_tokens=3)
    for r in reqs:
        server.submit(r)
    finished = server.run_until_drained()
    assert len(finished) == 4
    assert all(r.status == "timed_out" for r in reqs)
    assert all(r.t_admit == 0.0 for r in reqs)       # never admitted
    assert server.metrics["timed_out"] == 4
    s = server.scheduler.latency_summary()
    assert all(v == 0.0 for v in s.values())         # guarded, not NaN

    roomy = LMServer(model, params, cap=24, batch_slots=1,
                     default_queue_ttl_s=600.0)
    reqs2 = _mk_requests(cfg, n=3, lens=[6], max_tokens=3)
    for r in reqs2:
        roomy.submit(r)
    roomy.run_until_drained()
    assert all(r.status == "completed" for r in reqs2)


def test_decode_deadline_aborts_mid_flight_and_frees_blocks(served):
    """A TTL that expires mid-decode retires the request as timed_out,
    clears its slot and returns its KV blocks (shared-prefix refcounts
    included) — the paged pool ends the drain fully free."""
    cfg, model, params = served
    server = LMServer(model, params, cap=24, batch_slots=2,
                      cache_layout="paged", block_size=4, n_blocks=32,
                      prefix_cache=True)
    rng = np.random.default_rng(0)
    shared = rng.integers(0, cfg.vocab_size, 8).astype(np.int32)
    reqs = [Request(rid=i, prompt=shared.copy(), max_tokens=12)
            for i in range(2)]
    for r in reqs:
        server.submit(r)
    server.tick()
    for r in reqs:
        r.ttl_s = 1e-9                               # expire both mid-flight
    finished = server.run_until_drained()
    assert {r.rid for r in finished} == {0, 1}
    assert all(r.status == "timed_out" for r in reqs)
    assert all(r.error for r in reqs)
    assert all(s is None for s in server.slot_req)
    server.alloc.check_invariants()
    assert server.alloc.used_count == 0              # no leaked blocks


def test_admission_cap_rejects_with_retry_after(served):
    cfg, model, params = served
    server = LMServer(model, params, cap=24, batch_slots=1,
                      max_queue_depth=2)
    reqs = _mk_requests(cfg, n=5, lens=[6], max_tokens=3)
    rejected = []
    for r in reqs:
        try:
            server.submit(r)
        except AdmissionRejected as e:
            rejected.append((r, e))
    assert len(rejected) == 3                # 2 queued, the rest bounced
    assert all(r.status == "rejected" for r, _ in rejected)
    assert all(e.retry_after_s > 0 for _, e in rejected)
    assert server.metrics["rejected"] == 3
    server.run_until_drained()
    assert all(r.status == "completed"
               for r in reqs if r not in [x for x, _ in rejected])


def test_drain_refuses_new_work_and_shutdown_flushes(served):
    cfg, model, params = served
    server = LMServer(model, params, cap=24, batch_slots=2)
    reqs = _mk_requests(cfg, n=2, lens=[6], max_tokens=3)
    for r in reqs:
        server.submit(r)
    server.drain()
    assert all(r.status == "completed" for r in reqs)
    # drain() is a flush, not a teardown: admission reopens afterwards.
    late = _mk_requests(cfg, n=1, lens=[6], max_tokens=3, seed=9)[0]
    late.rid += 100
    server.submit(late)
    server.drain()
    assert late.status == "completed"

    server2 = LMServer(model, params, cap=24, batch_slots=1)
    active = _mk_requests(cfg, n=1, lens=[6], max_tokens=3)[0]
    queued = _mk_requests(cfg, n=2, lens=[6], max_tokens=3, seed=1)
    server2.submit(active)
    for q in queued:
        q.rid += 10
        server2.submit(q)
    server2.tick()                                   # admit the first
    server2.shutdown()
    assert active.status == "completed"
    assert all(q.status == "rejected" for q in queued)
    assert all(q.error == "server shutting down" for q in queued)
    # shutdown leaves the engine closed: no admission afterwards.
    with pytest.raises(AdmissionRejected, match="draining"):
        server2.submit(_mk_requests(cfg, n=1, lens=[6], seed=9)[0])


def test_latency_summary_empty_and_phase_guards():
    """satellite: a drain that retired nothing (or only never-streamed
    requests) must yield all-zero latency rows, not NaN."""
    sched = Scheduler()
    s = sched.latency_summary()
    assert set(s) == {"ttft_mean_s", "ttft_p50_s", "ttft_p95_s", "ttft_p99_s",
                      "tpot_mean_s", "tpot_p50_s", "tpot_p95_s", "tpot_p99_s",
                      "queue_mean_s"}
    assert all(v == 0.0 for v in s.values())
    r = Request(rid=0, prompt=np.zeros(4, np.int32), max_tokens=4)
    r.t_enqueue = 1.0                                # queued, never admitted
    sched.retire(r, status="timed_out")
    s = sched.latency_summary()
    assert all(v == 0.0 for v in s.values())
    assert sched.metrics["timed_out"] == 1
