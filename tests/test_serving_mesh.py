"""Meshed serving engine: dp x tp token parity, pipelined prefill parity,
and AOT warmup guarantees.

Token-parity runs go through subprocesses with forced host devices (the
same pattern as test_multidevice.py) so the main pytest process keeps its
single-device view. Each subprocess serves the SAME request stream on a
single device and on a data=2 x model=4 mesh and asserts the greedy token
streams match exactly — including the mirage_rrns stochastic backend,
whose noise keys derive from engine counters and therefore line up
tick-for-tick across placements.
"""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(py_src: str, n_dev: int = 8, timeout: int = 1200) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", py_src], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


_PARITY_SRC = """
    import jax, numpy as np
    from repro.configs import get_config
    from repro.core.precision import get_policy
    from repro.models import build_model
    from repro.models.lm import LMCallOptions
    from repro.runtime.server import LMServer, Request
    from repro.launch.mesh import make_debug_mesh

    arch, pol, layout = {arch!r}, {pol!r}, {layout!r}
    cfg = get_config(arch).reduced()
    model = build_model(cfg, get_policy(pol),
                        LMCallOptions(q_chunk=16, kv_chunk=16))
    params = model.init(jax.random.PRNGKey(0))

    def run(mesh):
        kw = dict(cache_layout=layout)
        if layout == "paged":
            kw.update(block_size=16, n_blocks=32)
        s = LMServer(model, params, cap=64, batch_slots=4, buckets=(16,),
                     mesh=mesh, **kw)
        rng = np.random.default_rng(0)
        for i in range(6):
            s.submit(Request(
                rid=i,
                prompt=rng.integers(0, cfg.vocab_size, 12).astype(np.int32),
                max_tokens=8))
        s.run_until_drained()
        toks = {{r.rid: list(map(int, r.tokens_out))
                 for r in s.scheduler.finished}}
        assert len(toks) == 6
        return toks

    single = run(None)
    mesh = make_debug_mesh(2, 4)
    meshed = run(mesh)
    assert single == meshed, (single, meshed)
    print("MESH_PARITY_OK")
"""


@pytest.mark.parametrize("arch,pol,layout", [
    ("qwen2-0.5b", "mirage", "paged"),
    ("qwen2-0.5b", "mirage_rrns", "paged"),
    ("mixtral-8x7b", "mirage", "paged"),
    ("mamba2-2.7b", "mirage", "dense"),
    ("zamba2-2.7b", "mirage", "dense"),
])
def test_meshed_engine_token_parity(arch, pol, layout):
    """dp=2 x tp=4 meshed engine emits the exact single-device stream."""
    src = textwrap.dedent(_PARITY_SRC.format(arch=arch, pol=pol,
                                             layout=layout))
    out = _run(src)
    assert "MESH_PARITY_OK" in out


def test_meshed_paged_allocator_is_sharded():
    """Under a dp=2 mesh the allocator grows per-shard free lists and the
    locality policy keeps allocations on the slot's home shard."""
    src = textwrap.dedent("""
        import jax, numpy as np
        from repro.configs import get_config
        from repro.core.precision import get_policy
        from repro.models import build_model
        from repro.models.lm import LMCallOptions
        from repro.runtime.server import LMServer, Request
        from repro.launch.mesh import make_debug_mesh

        cfg = get_config("qwen2-0.5b").reduced()
        model = build_model(cfg, get_policy("mirage"),
                            LMCallOptions(q_chunk=16, kv_chunk=16))
        params = model.init(jax.random.PRNGKey(0))
        s = LMServer(model, params, cap=64, batch_slots=4, buckets=(16,),
                     cache_layout="paged", block_size=16, n_blocks=32,
                     mesh=make_debug_mesh(2, 4))
        assert s.alloc.n_shards == 2, s.alloc.n_shards
        rng = np.random.default_rng(0)
        for i in range(6):
            s.submit(Request(
                rid=i,
                prompt=rng.integers(0, cfg.vocab_size, 12).astype(np.int32),
                max_tokens=8))
        s.run_until_drained()
        assert s.alloc.local_allocs > 0
        assert s.alloc.spilled_allocs == 0, s.alloc.spilled_allocs
        assert s.alloc.remote_fraction() == 0.0
        s.alloc.check_invariants()
        print("ALLOC_SHARDED_OK")
    """)
    assert "ALLOC_SHARDED_OK" in _run(src)


# ---------------------------------------------------------------------------
# in-process (single device): pipelining and warmup
# ---------------------------------------------------------------------------

def _build(arch="qwen2-0.5b", pol="mirage", **kw):
    import jax
    from repro.configs import get_config
    from repro.core.precision import get_policy
    from repro.models import build_model
    from repro.models.lm import LMCallOptions
    from repro.runtime.server import LMServer

    cfg = get_config(arch).reduced()
    model = build_model(cfg, get_policy(pol),
                        LMCallOptions(q_chunk=16, kv_chunk=16))
    params = model.init(jax.random.PRNGKey(0))
    return cfg, LMServer(model, params, cap=32, batch_slots=4,
                         buckets=(16,), **kw)


def _requests(cfg, n=6, max_tokens=8):
    import numpy as np
    from repro.runtime.server import Request

    rng = np.random.default_rng(0)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 12).astype(np.int32),
                    max_tokens=max_tokens)
            for i in range(n)]


def _drain(server, reqs):
    for r in reqs:
        server.submit(r)
    server.run_until_drained()
    return {r.rid: list(map(int, r.tokens_out))
            for r in server.scheduler.finished}


def test_pipelined_prefill_token_parity():
    """pipeline_depth>0 overlaps prefill compute with decode ticks on a
    worker thread; with a deterministic backend the emitted streams are
    identical to the synchronous engine."""
    cfg, sync = _build()
    want = _drain(sync, _requests(cfg))
    _, piped = _build(pipeline_depth=2)
    try:
        got = _drain(piped, _requests(cfg))
    finally:
        piped.close()
    assert want == got


def test_pipelined_prefill_crash_retries_then_fails():
    """A worker-thread failure no longer hangs (or poisons) the drain
    loop: the crashed job's requests release their slots, re-queue for a
    bounded retry and — when the step stays broken — retire terminally as
    status='failed' with the error recorded."""
    cfg, piped = _build(pipeline_depth=1)
    try:
        piped._prefill_compute = None  # simulates a permanently dead step
        reqs = _requests(cfg, n=2)
        for r in reqs:
            piped.submit(r)
        finished = piped.run_until_drained()
        assert {r.rid for r in finished} == {0, 1}
        assert all(r.status == "failed" for r in reqs)
        assert all(r.retries >= 1 for r in reqs)
        assert all("prefill worker crash" in r.error for r in reqs)
        assert piped.last_prefill_error is not None
        assert all(s is None for s in piped.slot_req)
        assert not piped.prefilling
        assert piped.scheduler.metrics["retried"] >= 2
    finally:
        piped.close()


def test_pipelined_injected_crash_recovers_with_retry():
    """A TRANSIENT worker crash (the chaos harness's worker_crash site)
    costs one retry and nothing else: the retried prefill reproduces the
    exact streams of an unfaulted pipelined engine."""
    from repro.runtime.faults import FaultInjector, FaultSchedule

    cfg, clean = _build(pipeline_depth=2)
    try:
        want = _drain(clean, _requests(cfg))
    finally:
        clean.close()
    inj = FaultInjector(FaultSchedule.parse("worker_crash@0"), seed=0)
    _, chaos = _build(pipeline_depth=2, fault_injector=inj, max_retries=3)
    try:
        got = _drain(chaos, _requests(cfg))
    finally:
        chaos.close()
    assert got == want
    assert any("worker_crash fired" in l for l in inj.log)
    assert chaos.scheduler.metrics["retried"] >= 1
    assert all(r.status == "completed" for r in chaos.scheduler.finished)


def test_warmup_compiles_all_shapes_and_prevents_recompiles():
    """warmup() pre-compiles every (bucket, batch) prefill shape plus the
    tick; a warmed drain triggers zero new compilations and emits the same
    tokens as a cold engine."""
    cfg, cold = _build()
    want = _drain(cold, _requests(cfg))

    _, warm = _build()
    stats = warm.warmup()
    assert stats["compiled"] >= 2  # at least one prefill shape + the tick
    assert stats["seconds"] > 0
    counts = warm.compile_counts()
    got = _drain(warm, _requests(cfg))
    assert want == got, "warmup changed the emitted stream"
    assert warm.compile_counts() == counts, (
        "recompilation during a warmed drain", counts, warm.compile_counts())


def test_warmup_requires_idle_engine():
    cfg, srv = _build()
    srv.submit(_requests(cfg, n=1)[0])
    with pytest.raises(RuntimeError):
        srv.warmup()


def test_warmup_spec_decode_and_paged():
    """Warmup covers the verify step (spec_k) and the paged layout."""
    cfg, srv = _build(cache_layout="paged", block_size=8, n_blocks=32,
                      spec_k=2)
    counts0 = srv.warmup()
    assert counts0["compiled"] >= 3  # prefill + tick + verify
    counts = srv.compile_counts()
    assert counts["verify_tick"] >= 1
    _drain(srv, _requests(cfg))
    assert srv.compile_counts() == counts
