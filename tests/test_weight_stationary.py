"""Weight-stationary quantization (§Perf iteration 1) numerics guarantees."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import bfp, gemm
from repro.core.precision import get_policy


def test_prequantized_weight_gemm_matches_baseline_forward():
    """quantize(W) once + skip == quantize inside the GEMM (same fwd values)."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(8, 64)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(64, 16)).astype(np.float32))
    base = get_policy("mirage")
    out_base = gemm.mirage_matmul_nograd(x, w, base)

    wq = jnp.moveaxis(bfp.bfp_fake_quant(jnp.moveaxis(w, -2, -1), 4, 16),
                      -1, -2)
    pre = base.replace(assume_quantized_weights=True)
    out_pre = gemm.mirage_matmul_nograd(x, wq, pre)
    np.testing.assert_allclose(np.asarray(out_pre), np.asarray(out_base),
                               rtol=1e-6, atol=1e-6)


def test_prequantized_bf16_storage_is_lossless():
    """BFP(b_m=4) grid values are exactly representable in bfloat16."""
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(size=(64, 16)).astype(np.float32))
    wq = bfp.bfp_fake_quant(w.T, 4, 16).T
    np.testing.assert_array_equal(
        np.asarray(wq), np.asarray(wq.astype(jnp.bfloat16).astype(jnp.float32)))


def test_train_step_wsq_close_to_baseline():
    """One wsq train step tracks the per-GEMM-quantization step closely
    (difference bounded by the single- vs double-quantization delta in dX)."""
    from repro.configs import get_config
    from repro.configs.base import TrainConfig
    from repro.data.pipeline import SyntheticLM, SyntheticLMConfig
    from repro.models import build_model
    from repro.models.lm import LMCallOptions
    from repro.runtime.trainer import init_train_state, make_train_step

    cfg = get_config("qwen2-0.5b").reduced()
    data = SyntheticLM(SyntheticLMConfig(vocab_size=cfg.vocab_size,
                                         seq_len=32, batch_size=2))
    batch = next(data)

    base_p = get_policy("mirage")
    m0 = build_model(cfg, base_p, LMCallOptions(q_chunk=16, kv_chunk=16))
    tc0 = TrainConfig(policy=base_p, lr=1e-3)
    s0 = init_train_state(m0, tc0, jax.random.PRNGKey(0))
    s0, met0 = jax.jit(make_train_step(m0, tc0))(s0, batch)

    wsq_p = base_p.replace(assume_quantized_weights=True)
    m1 = build_model(cfg, wsq_p, LMCallOptions(q_chunk=16, kv_chunk=16))
    tc1 = TrainConfig(policy=wsq_p, lr=1e-3, weight_stationary_quant=True,
                      quant_param_dtype="bfloat16")
    s1 = init_train_state(m1, tc1, jax.random.PRNGKey(0))
    s1, met1 = jax.jit(make_train_step(m1, tc1))(s1, batch)

    # identical loss (same forward numerics)
    assert abs(float(met0["loss"]) - float(met1["loss"])) < 1e-5
    # parameter updates stay close (dX path differs by one quantization)
    diffs = [float(jnp.abs(a - b).max()) for a, b in zip(
        jax.tree_util.tree_leaves(s0["params"]),
        jax.tree_util.tree_leaves(s1["params"]))]
    assert max(diffs) < 5e-3, max(diffs)


def test_wsq_training_converges():
    """Loss decreases under weight-stationary quantization."""
    from repro.configs import get_config
    from repro.configs.base import TrainConfig
    from repro.data.pipeline import SyntheticLM, SyntheticLMConfig
    from repro.models import build_model
    from repro.models.lm import LMCallOptions
    from repro.runtime.trainer import init_train_state, make_train_step

    cfg = get_config("qwen2-0.5b").reduced()
    p = get_policy("mirage").replace(assume_quantized_weights=True,
                                     compute_dtype="bfloat16")
    model = build_model(cfg, p, LMCallOptions(q_chunk=16, kv_chunk=16))
    tc = TrainConfig(policy=p, lr=1e-3, weight_stationary_quant=True,
                     quant_param_dtype="bfloat16")
    state = init_train_state(model, tc, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, tc))
    data = SyntheticLM(SyntheticLMConfig(vocab_size=cfg.vocab_size,
                                         seq_len=32, batch_size=4))
    losses = []
    for _ in range(12):
        state, met = step(state, next(data))
        losses.append(float(met["loss"]))
    assert losses[-1] < losses[0] - 0.01, losses
