"""BFP quantization invariants."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
# degrades to per-test skips when hypothesis is missing (see module)
from _hypothesis_compat import given, settings, st

from repro.core import bfp


def test_quantize_shapes_and_padding():
    x = jnp.arange(2 * 3 * 37, dtype=jnp.float32).reshape(2, 3, 37)
    t = bfp.bfp_quantize(x, b_m=4, g=16)
    assert t.mantissa.shape == (2, 3, 3, 16)  # 37 -> padded to 48 -> G=3
    assert t.scale.shape == (2, 3, 3, 1)
    back = bfp.bfp_dequantize(t)
    assert back.shape == x.shape


def test_mantissa_range():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(8, 64)).astype(np.float32) * 100)
    for b_m in (3, 4, 5, 6):
        t = bfp.bfp_quantize(x, b_m=b_m, g=16)
        q = np.asarray(t.mantissa)
        assert np.all(np.abs(q) <= 2**b_m - 1)
        assert np.all(q == np.round(q))  # integer-valued


def test_scale_is_power_of_two():
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(4, 32)).astype(np.float32))
    t = bfp.bfp_quantize(x, b_m=4, g=16)
    s = np.asarray(t.scale)
    e = np.log2(s)
    np.testing.assert_allclose(e, np.round(e), atol=0)


@pytest.mark.parametrize("rounding", ["nearest", "truncate"])
def test_error_bound(rounding):
    """|x - dq(q(x))| <= scale (truncate) or scale/2 (nearest), per element."""
    rng = np.random.default_rng(2)
    x = jnp.asarray((rng.normal(size=(16, 64)) * 10**rng.uniform(-3, 3, (16, 1))
                     ).astype(np.float32))
    t = bfp.bfp_quantize(x, b_m=4, g=16, rounding=rounding)
    back = bfp.bfp_dequantize(t)
    err = np.abs(np.asarray(back) - np.asarray(x))
    bound = np.asarray(t.scale)
    bound = np.repeat(bound, 16, axis=-1).reshape(16, 64)
    limit = bound * (0.5 if rounding == "nearest" else 1.0)
    # clipping of the rounded-up max element can add at most one extra level
    assert np.all(err <= limit + bound * (np.abs(np.asarray(t.mantissa)).reshape(16, 64) >= 15))


def test_zero_group_is_exact():
    x = jnp.zeros((2, 32), jnp.float32)
    t = bfp.bfp_quantize(x, b_m=4, g=16)
    np.testing.assert_array_equal(np.asarray(bfp.bfp_dequantize(t)), 0.0)


def test_power_of_two_values_exact():
    """Values on the quantization grid survive exactly. Group max 1.0 with
    b_m=4 gives E=0, scale=2^-3: multiples of 0.125 up to 15/8 are exact."""
    x = jnp.asarray([[1.0, 0.5, 0.25, 0.125, 1.875, -1.0, -0.5, 0.75] * 2],
                    jnp.float32)
    t = bfp.bfp_quantize(x, b_m=4, g=16)
    np.testing.assert_allclose(np.asarray(bfp.bfp_dequantize(t)), np.asarray(x))


def test_stochastic_rounding_unbiased():
    key = jax.random.PRNGKey(0)
    # with b_m=4 and group max 1+2^-6, scale=2^-3: value sits between levels
    x = jnp.full((4096, 16), 1.0 + 2**-6, jnp.float32)
    t = bfp.bfp_quantize(x, b_m=4, g=16, rounding="stochastic", key=key)
    mean = float(np.asarray(bfp.bfp_dequantize(t)).mean())
    assert abs(mean - (1.0 + 2**-6)) < 2e-3


@settings(deadline=None, max_examples=100)
@given(
    b_m=st.sampled_from([3, 4, 5, 6]),
    g=st.sampled_from([4, 8, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_relative_error_property(b_m, g, seed):
    """Per-element error <= 2^-b_m * group_max for round-to-nearest."""
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(3, g * 2)).astype(np.float32)
    t = bfp.bfp_quantize(jnp.asarray(x), b_m=b_m, g=g)
    back = np.asarray(bfp.bfp_dequantize(t))
    gmax = np.abs(x.reshape(3, 2, g)).max(-1, keepdims=True)
    err = np.abs(back - x).reshape(3, 2, g)
    assert np.all(err <= bfp.bfp_error_bound(b_m) * np.maximum(gmax, 1e-30) * (1 + 1e-6))
