"""Analog noise + redundant-RNS error correction (paper §VII, beyond-paper)."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import noise, rns
from repro.core.precision import special_moduli


def test_no_noise_is_identity():
    moduli = special_moduli(5)
    r = jnp.asarray(np.random.default_rng(0).integers(0, 31, (3, 8)), jnp.int32)
    out = noise.inject_phase_noise(r, moduli, sigma=0.0,
                                   key=jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(r))


def test_noise_stays_in_range():
    moduli = special_moduli(5)
    rng = np.random.default_rng(1)
    r = jnp.asarray(np.stack([rng.integers(0, m, 64) for m in moduli]),
                    jnp.int32)
    out = np.asarray(noise.inject_phase_noise(r, moduli, sigma=2.0,
                                              key=jax.random.PRNGKey(1)))
    for i, m in enumerate(moduli):
        assert out[i].min() >= 0 and out[i].max() < m


def test_small_noise_scales_up_through_crt():
    """Paper §VII: one residue error becomes a LARGE integer error after
    reconstruction — the motivation for RRNS."""
    k = 5
    x = 1234
    r = np.array([[x % 31], [x % 32], [x % 33]], np.int32)
    r_bad = r.copy()
    r_bad[0, 0] = (r_bad[0, 0] + 1) % 31   # single phase-level error
    good = int(np.asarray(rns.from_rns_special(jnp.asarray(r), k))[0])
    bad = int(np.asarray(rns.from_rns_special(jnp.asarray(r_bad), k))[0])
    assert good == x
    assert abs(bad - x) > 100   # error amplified far beyond one level


def test_rrns_corrects_single_residue_error():
    """With 2 redundant moduli, majority decoding recovers the true value."""
    base = list(special_moduli(5))          # 31, 32, 33
    redundant = [29, 37]                    # co-prime extras
    all_moduli = base + redundant
    M = np.prod(base)
    psi = (M - 1) // 2
    rng = np.random.default_rng(2)
    xs = rng.integers(-1000, 1000, size=6)
    residues = np.stack([np.mod(xs, m) for m in all_moduli]).astype(np.int64)
    # corrupt ONE residue of the first three values
    residues[1, 0] = (residues[1, 0] + 3) % all_moduli[1]
    residues[4, 1] = (residues[4, 1] + 1) % all_moduli[4]
    residues[0, 2] = (residues[0, 2] + 7) % all_moduli[0]
    decoded, corrected = noise.rrns_decode_np(residues, all_moduli,
                                              n_required=3, psi=psi)
    np.testing.assert_array_equal(decoded, xs)
    assert corrected[0] and corrected[1] and corrected[2]
    assert not corrected[3] and not corrected[5]


def test_snr_requirement_monotonic():
    assert noise.snr_requirement_db(33) > noise.snr_requirement_db(31)
