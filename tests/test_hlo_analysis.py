"""Loop-aware HLO analyzer: validated against hand-computable programs."""

import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _compile_and_analyze(py_src: str, n_dev: int = 4) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", py_src], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr
    return out.stdout


def test_scan_flops_counted_with_trip_count():
    src = textwrap.dedent("""
        import jax, jax.numpy as jnp
        from repro.launch.hlo_analysis import analyze_hlo
        def f(x, w):
            def body(h, _):
                return jnp.tanh(h @ w), None
            h, _ = jax.lax.scan(body, x, None, length=11)
            return h
        c = jax.jit(f).lower(
            jax.ShapeDtypeStruct((32, 64), jnp.float32),
            jax.ShapeDtypeStruct((64, 64), jnp.float32)).compile()
        cost = analyze_hlo(c.as_text(), default_group=1)
        expect = 2 * 32 * 64 * 64 * 11
        print("FLOPS", cost.flops, expect)
        assert cost.flops == expect, (cost.flops, expect)
        assert cost.max_trip == 11
    """)
    out = _compile_and_analyze(src, n_dev=1)
    assert "FLOPS" in out


def test_collectives_counted_per_iteration():
    src = textwrap.dedent("""
        import jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.launch.hlo_analysis import analyze_hlo
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        def f(x, w):
            def body(h, _):
                return jnp.tanh(h @ w), None
            h, _ = jax.lax.scan(body, x, None, length=5)
            return h
        c = jax.jit(f, in_shardings=(NamedSharding(mesh, P("data", None)),
                                     NamedSharding(mesh, P("data", "model")))
            ).lower(jax.ShapeDtypeStruct((64, 128), jnp.float32),
                    jax.ShapeDtypeStruct((128, 128), jnp.float32)).compile()
        cost = analyze_hlo(c.as_text(), default_group=4)
        total = sum(cost.coll_counts.values())
        print("COLLS", cost.coll_counts)
        assert total >= 5, cost.coll_counts   # per-iteration gather x trips
    """)
    out = _compile_and_analyze(src, n_dev=4)
    assert "COLLS" in out


def test_dus_charged_as_slice_not_buffer():
    src = textwrap.dedent("""
        import jax, jax.numpy as jnp
        from repro.launch.hlo_analysis import analyze_hlo
        def f(x):
            def body(buf, i):
                return jax.lax.dynamic_update_index_in_dim(
                    buf, jnp.ones((128,)) * i, i, 0), None
            buf, _ = jax.lax.scan(body, x, jnp.arange(64))
            return buf
        c = jax.jit(f).lower(
            jax.ShapeDtypeStruct((64, 128), jnp.float32)).compile()
        cost = analyze_hlo(c.as_text(), default_group=1)
        # 64 iterations x 2 * 512B slice  ~= 64KB, NOT 64 x 32KB = 2MB
        print("BYTES", cost.hbm_bytes)
        assert cost.hbm_bytes < 1e6, cost.hbm_bytes
    """)
    out = _compile_and_analyze(src, n_dev=1)
    assert "BYTES" in out


def test_parse_shapes_and_groups():
    from repro.launch.hlo_analysis import _shape_bytes, _group_size
    assert _shape_bytes("f32[128,256]{1,0}") == 128 * 256 * 4
    assert _shape_bytes("(f32[8]{0}, bf16[4,4]{1,0})") == 32 + 32
    assert _group_size("replica_groups=[2,128]<=[256]", 1) == 128
    assert _group_size("replica_groups={{0,1,2,3}}", 1) == 4
    assert _group_size("no groups here", 7) == 7
