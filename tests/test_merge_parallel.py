"""Merged parallel-block projection (§Perf iteration 3) is value-identical
up to f32 accumulation order."""

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.precision import get_policy
from repro.models import build_model
from repro.models.lm import LMCallOptions


def test_merged_projection_matches_separate():
    cfg = get_config("command-r-plus-104b").reduced()
    policy = get_policy("mirage")
    tokens = jnp.asarray(np.arange(2 * 16).reshape(2, 16) % cfg.vocab_size,
                         jnp.int32)
    m0 = build_model(cfg, policy, LMCallOptions(q_chunk=16, kv_chunk=16,
                                                merge_parallel_proj=False))
    m1 = build_model(cfg, policy, LMCallOptions(q_chunk=16, kv_chunk=16,
                                                merge_parallel_proj=True))
    params = m0.init(jax.random.PRNGKey(0))
    l0, _, _ = m0.forward(params, tokens)
    l1, _, _ = m1.forward(params, tokens)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1),
                               rtol=2e-4, atol=2e-4)


def test_merged_projection_grads_match():
    cfg = get_config("command-r-plus-104b").reduced()
    policy = get_policy("mirage")
    tokens = jnp.asarray(np.arange(2 * 16).reshape(2, 16) % cfg.vocab_size,
                         jnp.int32)
    batch = {"tokens": tokens, "labels": tokens}
    m0 = build_model(cfg, policy, LMCallOptions(q_chunk=16, kv_chunk=16))
    m1 = build_model(cfg, policy, LMCallOptions(q_chunk=16, kv_chunk=16,
                                                merge_parallel_proj=True))
    params = m0.init(jax.random.PRNGKey(1))
    g0 = jax.grad(lambda p: m0.loss(p, batch)[0])(params)
    g1 = jax.grad(lambda p: m1.loss(p, batch)[0])(params)
    for a, b in zip(jax.tree_util.tree_leaves(g0),
                    jax.tree_util.tree_leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=5e-3, atol=5e-4)
