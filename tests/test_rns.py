"""RNS arithmetic invariants (unit + hypothesis property tests)."""

import numpy as np
import jax.numpy as jnp
import pytest
# degrades to per-test skips when hypothesis is missing (see module)
from _hypothesis_compat import given, settings, st

from repro.core import rns
from repro.core.precision import special_moduli


KS = [3, 4, 5, 6, 8, 10]


@pytest.mark.parametrize("k", KS)
def test_special_moduli_coprime(k):
    import math
    m = special_moduli(k)
    assert math.gcd(m[0], m[1]) == 1
    assert math.gcd(m[1], m[2]) == 1
    assert math.gcd(m[0], m[2]) == 1


@pytest.mark.parametrize("k", KS)
def test_roundtrip_exhaustive_small(k):
    """from_rns(to_rns(X)) == X over a dense sweep of the signed range."""
    M = np.prod(special_moduli(k))
    psi = (M - 1) // 2
    xs = np.linspace(-psi, psi, 2048).astype(np.int64)
    xs = np.unique(np.concatenate([xs, [-psi, -1, 0, 1, psi]]))
    res = rns.to_rns_special(jnp.asarray(xs, jnp.int32), k)
    back = rns.from_rns_special(res, k, signed=True)
    np.testing.assert_array_equal(np.asarray(back), xs)


@pytest.mark.parametrize("k", [4, 5])
def test_special_matches_generic(k):
    moduli = special_moduli(k)
    M = int(np.prod(moduli))
    psi = (M - 1) // 2
    rng = np.random.default_rng(0)
    xs = rng.integers(-psi, psi + 1, size=512)
    fast = np.asarray(rns.to_rns_special(jnp.asarray(xs, jnp.int32), k))
    generic = np.stack([np.mod(xs, m) for m in moduli]).astype(np.int64)
    np.testing.assert_array_equal(fast, generic)
    back = rns.from_rns_generic_np(generic, moduli, signed=True)
    np.testing.assert_array_equal(back, xs)


@settings(deadline=None, max_examples=200)
@given(
    k=st.sampled_from([4, 5, 6, 8]),
    x=st.integers(min_value=-(10**6), max_value=10**6),
)
def test_roundtrip_property(k, x):
    M = int(np.prod(special_moduli(k)))
    psi = (M - 1) // 2
    x = x % (2 * psi + 1) - psi  # fold into the representable range
    res = rns.to_rns_special(jnp.asarray([x], jnp.int32), k)
    back = int(np.asarray(rns.from_rns_special(res, k))[0])
    assert back == x


@settings(deadline=None, max_examples=100)
@given(
    k=st.sampled_from([5, 6]),
    a=st.integers(min_value=-100, max_value=100),
    b=st.integers(min_value=-100, max_value=100),
    c=st.integers(min_value=-500, max_value=500),
)
def test_closure_mac(k, a, b, c):
    """to_rns(a*b + c) == mod-MAC on residues, within range."""
    moduli = special_moduli(k)
    M = int(np.prod(moduli))
    psi = (M - 1) // 2
    if abs(a * b + c) > psi:
        return
    ra = rns.to_rns_special(jnp.asarray([a], jnp.int32), k)
    rb = rns.to_rns_special(jnp.asarray([b], jnp.int32), k)
    rc = rns.to_rns_special(jnp.asarray([c], jnp.int32), k)
    mac = jnp.stack(
        [rns.mod_mac(ra[i], rb[i], rc[i], m) for i, m in enumerate(moduli)]
    ).astype(jnp.int32)
    got = int(np.asarray(rns.from_rns_special(mac, k))[0])
    assert got == a * b + c


@pytest.mark.parametrize("k", [4, 5, 6])
@pytest.mark.parametrize("shape", [(3, 7, 5), (1, 16, 8), (4, 4, 4)])
def test_rns_matmul_exact(k, shape):
    """Residue GEMM + CRT == direct integer GEMM (the paper's core claim)."""
    m, kk, n = shape
    qmax = 15  # b_m = 4 mantissas
    rng = np.random.default_rng(k * 100 + m)
    x = rng.integers(-qmax, qmax + 1, size=(m, kk)).astype(np.float32)
    w = rng.integers(-qmax, qmax + 1, size=(kk, n)).astype(np.float32)
    expect = x @ w
    psi = (int(np.prod(special_moduli(k))) - 1) // 2
    if np.abs(expect).max() > psi:
        pytest.skip("dot exceeds RNS range for this k")
    got = np.asarray(rns.rns_dot_reconstruct(jnp.asarray(x), jnp.asarray(w), k))
    np.testing.assert_array_equal(got, expect.astype(np.int64))


def test_overflow_bound_adversarial():
    """Eq. 10: the worst-case +/-qmax group dot stays inside [-psi, psi]."""
    from repro.core.precision import MiragePolicy
    p = MiragePolicy()  # b_m=4, g=16, k=5
    qmax = p.mantissa_max
    x = np.full((1, p.g), qmax, np.float32)
    w = np.full((p.g, 1), qmax, np.float32)
    dot = float((x @ w)[0, 0])
    assert dot <= p.psi
    got = np.asarray(rns.rns_dot_reconstruct(jnp.asarray(x), jnp.asarray(w), p.k))
    assert got[0, 0] == dot
    # and the negative extreme
    got2 = np.asarray(rns.rns_dot_reconstruct(jnp.asarray(-x), jnp.asarray(w), p.k))
    assert got2[0, 0] == -dot


def test_mod_matmul_matches_numpy():
    rng = np.random.default_rng(7)
    for m in (31, 32, 33):
        xr = rng.integers(0, m, size=(9, 33)).astype(np.int32)
        wr = rng.integers(0, m, size=(33, 5)).astype(np.int32)
        got = np.asarray(rns.mod_matmul(jnp.asarray(xr), jnp.asarray(wr), m))
        expect = (xr.astype(np.int64) @ wr.astype(np.int64)) % m
        np.testing.assert_array_equal(got.astype(np.int64), expect)
