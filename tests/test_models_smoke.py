"""Per-architecture smoke tests: REDUCED config of the same family, one
forward + one train-gradient step + one prefill/decode step on CPU,
asserting output shapes and no NaNs. (Full configs are exercised only via
the dry-run.)"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS
from repro.core.precision import get_policy
from repro.models import build_model
from repro.models.lm import LMCallOptions

POLICY = get_policy("mirage")
ARCH_IDS = sorted(ARCHS)


def _batch_for(cfg, B=2, L=32):
    rng = np.random.default_rng(0)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, L)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, L)), jnp.int32),
    }
    if cfg.frontend == "vit_stub":
        batch["patches"] = jnp.asarray(
            rng.normal(size=(B, cfg.frontend_len, cfg.frontend_dim)), jnp.float32)
    if cfg.is_encdec:
        batch = {
            "frames": jnp.asarray(rng.normal(size=(B, L, cfg.frontend_dim)),
                                  jnp.float32),
            "tokens": batch["tokens"],
            "labels": batch["labels"],
        }
    return batch


@pytest.fixture(scope="module")
def built():
    """Init each reduced model once per module."""
    cache = {}

    def get(arch_id):
        if arch_id not in cache:
            cfg = ARCHS[arch_id].reduced()
            model = build_model(cfg, POLICY, LMCallOptions(q_chunk=16, kv_chunk=16))
            params = model.init(jax.random.PRNGKey(0))
            cache[arch_id] = (cfg, model, params)
        return cache[arch_id]

    return get


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_loss_and_grad_step(arch_id, built):
    cfg, model, params = built(arch_id)
    batch = _batch_for(cfg)
    loss, metrics = model.loss(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch_id}: loss={loss}"

    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    leaves = jax.tree_util.tree_leaves(grads)
    assert all(np.all(np.isfinite(np.asarray(l))) for l in leaves), arch_id
    # at least the embedding gradient must be nonzero
    gnorm = sum(float(jnp.sum(l * l)) for l in leaves)
    assert gnorm > 0, arch_id


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_prefill_then_decode(arch_id, built):
    cfg, model, params = built(arch_id)
    B, L, cap = 2, 16, 24
    rng = np.random.default_rng(1)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, L)), jnp.int32)

    if cfg.is_encdec:
        frames = jnp.asarray(rng.normal(size=(B, L, cfg.frontend_dim)), jnp.float32)
        logits, cache = model.prefill(params, frames, tokens, cap)
    elif cfg.frontend == "vit_stub":
        patches = jnp.asarray(
            rng.normal(size=(B, cfg.frontend_len, cfg.frontend_dim)), jnp.float32)
        logits, cache = model.prefill(params, tokens, cap, extra_embeds=patches)
    else:
        logits, cache = model.prefill(params, tokens, cap)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits))), arch_id

    nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
    logits2, cache = model.decode_step(params, cache, nxt)
    assert logits2.shape == (B, 1, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits2))), arch_id
    assert int(cache["idx"]) == (L if cfg.is_encdec else
                                 L + (cfg.frontend_len if cfg.frontend == "vit_stub" else 0)) + 1


@pytest.mark.parametrize("arch_id", ["qwen2-0.5b", "mamba2-2.7b", "mixtral-8x7b"])
def test_decode_matches_forward(arch_id, built):
    """Teacher-forced decode must agree with the full forward pass."""
    cfg, model, params = built(arch_id)
    B, L = 1, 12
    rng = np.random.default_rng(2)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, L)), jnp.int32)
    full_logits, _, _ = model.forward(params, tokens)

    prefix = 6
    logits, cache = model.prefill(params, tokens[:, :prefix], cap=L + 2)
    np.testing.assert_allclose(
        np.asarray(logits[:, -1]), np.asarray(full_logits[:, prefix - 1]),
        rtol=2e-3, atol=2e-3)
    for t in range(prefix, L):
        logits, cache = model.decode_step(params, cache, tokens[:, t:t + 1])
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(full_logits[:, t]),
            rtol=2e-3, atol=2e-3, err_msg=f"{arch_id} step {t}")


def test_kv_repeat_is_value_identical():
    """Repeating KV heads (for TP divisibility) must not change outputs."""
    cfg = ARCHS["qwen3-14b"].reduced()
    m1 = build_model(cfg, POLICY, LMCallOptions(kv_repeat=1, q_chunk=16, kv_chunk=16))
    m2 = build_model(cfg, POLICY, LMCallOptions(kv_repeat=2, q_chunk=16, kv_chunk=16))
    params = m1.init(jax.random.PRNGKey(3))
    tokens = jnp.asarray(np.arange(2 * 16).reshape(2, 16) % cfg.vocab_size,
                         jnp.int32)
    l1, _, _ = m1.forward(params, tokens)
    l2, _, _ = m2.forward(params, tokens)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-5,
                               atol=1e-5)


def test_swa_matches_full_attention_for_short_seq():
    """With seq < window, SWA must equal full attention (mixtral check)."""
    import dataclasses
    cfg = ARCHS["mixtral-8x7b"].reduced()
    cfg_full = dataclasses.replace(cfg, sliding_window=None)
    m_swa = build_model(cfg, POLICY, LMCallOptions(q_chunk=16, kv_chunk=16))
    m_full = build_model(cfg_full, POLICY, LMCallOptions(q_chunk=16, kv_chunk=16))
    params = m_swa.init(jax.random.PRNGKey(4))
    tokens = jnp.asarray(np.arange(2 * 16).reshape(2, 16) % cfg.vocab_size,
                         jnp.int32)
    l1, _, _ = m_swa.forward(params, tokens)   # window=32 > L=16
    l2, _, _ = m_full.forward(params, tokens)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), rtol=1e-6)
