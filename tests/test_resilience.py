"""SNR-adaptive degradation + crash-consistent snapshots.

The acceptance gate lives here: under a mid-run SNR collapse the guardian's
verify-before-commit windows roll back every window whose
``rrns_uncorrected`` delta is nonzero, walk the degradation ladder
(r=2 -> r=4 -> fp32) and end up streaming EXACTLY the clean fp32 engine's
greedy tokens — while the same collapse without the guardian diverges.
Snapshot/restore is exercised both through the guardian's rollbacks and as
a standalone fresh-engine resume (dense and paged+prefix-shared).

The single-family (qwen2) chaos gate runs in tier-1; the full four-family
sweep is CI's chaos-smoke job (RUN_CHAOS_FAMILIES=1).
"""

import functools
import os

import numpy as np
import jax
import pytest

from repro.configs import get_config
from repro.core.precision import get_policy
from repro.models import build_model
from repro.models.lm import LMCallOptions
from repro.runtime.faults import FaultInjector, FaultSchedule
from repro.runtime.resilience import SNRGuardian, degradation_ladder
from repro.runtime.server import LMServer, Request

COLLAPSE = "snr_drop@0:100000:scale=1e6"   # -120 dB: nothing survives


def _mk_requests(cfg, n=4, lens=(6, 9), max_tokens=4, seed=3):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        lens[i % len(lens)]).astype(np.int32),
                    max_tokens=max_tokens)
            for i in range(n)]


def _streams(server, reqs, runner=None):
    for r in reqs:
        server.submit(r)
    (runner or server.run_until_drained)()
    return {r.rid: list(map(int, r.tokens_out))
            for r in server.scheduler.finished}


@functools.lru_cache(maxsize=None)
def _family(arch):
    cfg = get_config(arch).reduced()
    opts = LMCallOptions(q_chunk=16, kv_chunk=16)
    fp32 = build_model(cfg, get_policy("fp32"), opts)
    params = fp32.init(jax.random.PRNGKey(0))
    rrns = build_model(cfg, get_policy("mirage_rrns", snr_db=60.0,
                                       noise_seed=7), opts)
    return cfg, fp32, rrns, params


# --------------------------------------------------------------------------
# ladder + guardian preconditions
# --------------------------------------------------------------------------

def test_degradation_ladder_shape():
    pol = get_policy("mirage_rrns", snr_db=60.0, noise_seed=7)
    ladder = degradation_ladder(pol, max_r=4)
    assert [p.mode for p in ladder] == ["mirage_rrns", "mirage_rrns", "fp32"]
    assert ladder[0] is pol
    assert len(ladder[1].redundant_moduli) == 4
    assert ladder[1].k == pol.k and ladder[1].moduli == pol.moduli
    with pytest.raises(ValueError, match="mirage_rrns"):
        degradation_ladder(get_policy("fp32"))


def test_guardian_preconditions():
    cfg, _, rrns, params = _family("qwen2-0.5b")
    plain = LMServer(rrns, params, cap=24, batch_slots=2, instrument=False)
    with pytest.raises(ValueError, match="instrument"):
        SNRGuardian(plain)
    piped = LMServer(rrns, params, cap=24, batch_slots=2,
                     instrument=True, pipeline_depth=1)
    try:
        with pytest.raises(ValueError, match="pipeline"):
            SNRGuardian(piped)
    finally:
        piped.close()


# --------------------------------------------------------------------------
# THE chaos parity gate
# --------------------------------------------------------------------------

def _chaos_parity(arch):
    cfg, fp32, rrns, params = _family(arch)
    want = _streams(LMServer(fp32, params, cap=24, batch_slots=2),
                    _mk_requests(cfg))

    # guardian ON: every committed window certifies rrns_uncorrected == 0,
    # and under a from-tick-0 collapse that means every committed window
    # ran on the fp32 rung -> streams are exactly the fp32 engine's
    inj = FaultInjector(FaultSchedule.parse(COLLAPSE), seed=0)
    guarded = LMServer(rrns, params, cap=24, batch_slots=2,
                       instrument=True, fault_injector=inj)
    guardian = SNRGuardian(guarded, window=2, cooldown=10_000)
    got = _streams(guarded, _mk_requests(cfg),
                   runner=guardian.run_until_drained)
    assert got == want, f"{arch}: guardian-on streams differ from clean fp32"
    assert guardian.level == len(guardian.ladder) - 1   # walked to fp32
    assert len(guardian.transitions) >= 2               # r=4 then fp32
    assert all(r.status == "completed"
               for r in guarded.scheduler.finished)

    # guardian OFF: the same collapse streams uncorrectable garbage
    inj2 = FaultInjector(FaultSchedule.parse(COLLAPSE), seed=0)
    naked = LMServer(rrns, params, cap=24, batch_slots=2,
                     instrument=True, fault_injector=inj2)
    diverged = _streams(naked, _mk_requests(cfg))
    assert diverged != want, f"{arch}: collapse had no effect?"
    unc = naked.health_snapshot().get("rrns_uncorrected", 0)
    assert (sum(unc) if isinstance(unc, list) else unc) > 0


def test_chaos_parity_guardian_vs_fp32_qwen2():
    _chaos_parity("qwen2-0.5b")


@pytest.mark.skipif(not os.environ.get("RUN_CHAOS_FAMILIES"),
                    reason="full four-family chaos sweep runs in CI's "
                           "chaos-smoke job (set RUN_CHAOS_FAMILIES=1)")
@pytest.mark.parametrize("arch", ["mixtral-8x7b", "mamba2-2.7b",
                                  "zamba2-2.7b"])
def test_chaos_parity_guardian_all_families(arch):
    _chaos_parity(arch)


def test_guardian_recovers_after_transient_collapse():
    """A bounded SNR hole: the guardian escalates through it, then the
    cooldown probe steps back down once windows verify clean again. (No
    fp32 parity claim here — after recovery the engine legitimately runs
    the quantized rrns rung; exactness-vs-fp32 is certified only while
    every committed window ran on the fp32 rung, i.e. the test above.)"""
    cfg, _, rrns, params = _family("qwen2-0.5b")
    inj = FaultInjector(
        FaultSchedule.parse("snr_drop@0:4:scale=1e6"), seed=0)
    srv = LMServer(rrns, params, cap=24, batch_slots=2,
                   instrument=True, fault_injector=inj)
    guardian = SNRGuardian(srv, window=2, cooldown=1)
    reqs = _mk_requests(cfg, n=3, max_tokens=6)
    _streams(srv, reqs, runner=guardian.run_until_drained)
    assert all(r.status == "completed" for r in reqs)
    assert any("escalate" in t for t in guardian.transitions)
    assert any("probe down" in t for t in guardian.transitions)
    assert guardian.level < len(guardian.ladder) - 1  # stepped back down


# --------------------------------------------------------------------------
# crash-consistent snapshots: fresh-engine resume
# --------------------------------------------------------------------------

def _snapshot_resume(server_kw, arch="qwen2-0.5b"):
    cfg, fp32, _, params = _family(arch)
    mk = lambda: LMServer(fp32, params, cap=24, batch_slots=2, **server_kw)
    want = _streams(mk(), _mk_requests(cfg, n=4, max_tokens=6))

    half = mk()
    reqs = _mk_requests(cfg, n=4, max_tokens=6)
    for r in reqs:
        half.submit(r)
    for _ in range(3):
        half.tick()
    snap = half.snapshot()

    fresh = mk()                                      # a new "process"
    fresh.restore(snap)
    fresh.run_until_drained()
    got = {r.rid: list(map(int, r.tokens_out))
           for r in fresh.scheduler.finished}
    assert got == want
    if fresh.alloc is not None:
        fresh.alloc.check_invariants()
        assert fresh.alloc.used_count == 0


def test_snapshot_restore_fresh_engine_dense():
    _snapshot_resume({})


def test_snapshot_restore_fresh_engine_paged_prefix():
    _snapshot_resume({"cache_layout": "paged", "block_size": 4,
                      "n_blocks": 48, "prefix_cache": True})
