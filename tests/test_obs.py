"""Unified telemetry (repro.obs): metrics registry, span tracer, health.

Covers the observability PR's acceptance surface: label-cardinality guard,
histogram bucket-edge semantics, ring-buffer wraparound, snapshot-while-
writing thread safety, and RRNS fault-counter parity against the frozen
``rrns_decode_np`` host oracle on injected single/double residue errors.
"""

import json
import re
import threading

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.analog import rrns
from repro.core import noise
from repro.core.precision import get_policy, special_moduli
from repro.obs import health
from repro.obs.metrics import (DEFAULT_BUCKETS, MAX_LABEL_SETS,
                               MetricsRegistry)
from repro.obs.trace import SpanTracer


# --------------------------------------------------------------------------
# metrics registry
# --------------------------------------------------------------------------

def test_counter_gauge_histogram_roundtrip():
    reg = MetricsRegistry()
    c = reg.counter("req_total", "requests")
    c.inc()
    c.inc(2)
    g = reg.gauge("depth", "queue depth")
    g.set(5)
    g.dec(2)
    h = reg.histogram("lat_s", buckets=(0.1, 1.0))
    for v in (0.05, 0.5, 5.0):
        h.observe(v)
    snap = reg.snapshot()
    assert snap["req_total"]["series"]["_"] == 3
    assert snap["depth"]["series"]["_"] == 3
    hs = snap["lat_s"]["series"]["_"]
    assert hs["count"] == 3 and hs["counts"] == [1, 1, 1]
    assert abs(hs["sum"] - 5.55) < 1e-9
    # get-or-create is idempotent; kind mismatch is always a bug
    assert reg.counter("req_total") is c
    with pytest.raises(ValueError, match="already registered"):
        reg.gauge("req_total")


def test_labels_resolve_and_cardinality_guard_trips():
    reg = MetricsRegistry()
    c = reg.counter("flips", label_names=("channel",))
    c.labels("31").inc(4)
    c.labels(31).inc(1)          # values stringify: same child
    assert c.labels("31").value == 5
    with pytest.raises(ValueError, match="expected 1 label"):
        c.labels("31", "32")
    for i in range(MAX_LABEL_SETS - 1):
        c.labels(f"m{i}").inc()
    with pytest.raises(ValueError, match="cardinality"):
        c.labels("one-too-many")


def test_histogram_bucket_edges_are_le_upper_bounds():
    """A value exactly ON an edge lands in that edge's bucket (Prometheus
    cumulative ``le`` semantics); past the last edge goes to +Inf."""
    reg = MetricsRegistry()
    h = reg.histogram("edges", buckets=(1.0, 2.0, 4.0))
    for v in (0.5, 1.0, 2.0, 2.0001, 4.0, 99.0):
        h.observe(v)
    snap = reg.snapshot()["edges"]["series"]["_"]
    assert snap["counts"] == [2, 1, 2, 1]      # le=1, le=2, le=4, +Inf
    text = reg.prometheus_text()
    assert 'edges_bucket{le="1"} 2' in text
    assert 'edges_bucket{le="2"} 3' in text    # cumulative
    assert 'edges_bucket{le="4"} 5' in text
    assert 'edges_bucket{le="+Inf"} 6' in text
    assert "edges_count 6" in text
    with pytest.raises(ValueError, match="strictly increasing"):
        reg.histogram("bad", buckets=(1.0, 1.0, 2.0))
    with pytest.raises(ValueError, match="strictly increasing"):
        reg.histogram("bad2", buckets=(2.0, 1.0))


def test_histogram_percentile_interpolates_within_bucket():
    reg = MetricsRegistry()
    h = reg.histogram("p", buckets=(10.0, 20.0))
    for _ in range(100):
        h.observe(15.0)          # all in (10, 20]
    assert 10.0 <= h.percentile(0.5) <= 20.0
    assert h.percentile(0.0) == 0.0 or h.percentile(0.0) <= 20.0
    empty = reg.histogram("p0", buckets=(1.0,))
    assert empty.percentile(0.99) == 0.0


def test_prometheus_text_parses():
    """Every exposition line matches the text-format grammar a scraper
    (and the CI smoke) expects."""
    reg = MetricsRegistry()
    reg.counter("a_total", "things").inc()
    reg.gauge("b", label_names=("ch",)).labels("31").set(2)
    reg.histogram("c_s", "lat", buckets=DEFAULT_BUCKETS[:3]).observe(0.002)
    line_re = re.compile(
        r'^(# (HELP|TYPE) \S.*'
        r'|[a-zA-Z_:][a-zA-Z0-9_:]*(\{[a-zA-Z_]+="[^"]*"'
        r'(,[a-zA-Z_]+="[^"]*")*\})? (\+Inf|-?[0-9.e+-]+))$')
    text = reg.prometheus_text()
    for ln in text.splitlines():
        assert line_re.match(ln), f"malformed exposition line: {ln!r}"
    assert text.endswith("\n")


def test_gauge_fn_and_collectors_run_at_scrape_time():
    reg = MetricsRegistry()
    box = {"v": 1.0}
    reg.gauge_fn("lazy", lambda: box["v"])
    calls = []
    reg.add_collector(lambda r: calls.append(1) or
                      r.gauge("collected").set(7))
    box["v"] = 42.0
    snap = reg.snapshot()
    assert snap["lazy"]["series"]["_"] == 42.0
    assert snap["collected"]["series"]["_"] == 7
    assert len(calls) == 1                      # once per scrape

    def broken(r):
        raise RuntimeError("boom")
    reg.add_collector(broken)
    reg.snapshot()                              # never kills a scrape


def test_snapshot_while_writing_is_consistent():
    """Scrapes racing writer threads must never see a torn histogram
    (sum(counts) != count) and final totals must be exact."""
    reg = MetricsRegistry()
    c = reg.counter("n_total")
    h = reg.histogram("lat", buckets=(0.5, 1.0, 2.0))
    n_threads, n_each = 4, 2000
    start = threading.Barrier(n_threads + 1)

    def writer(seed):
        rng = np.random.default_rng(seed)
        vals = rng.uniform(0, 3, n_each)
        start.wait()
        for v in vals:
            c.inc()
            h.observe(float(v))

    threads = [threading.Thread(target=writer, args=(i,))
               for i in range(n_threads)]
    for t in threads:
        t.start()
    start.wait()
    torn = 0
    for _ in range(200):
        hs = reg.snapshot()["lat"]["series"]["_"]
        if sum(hs["counts"]) != hs["count"]:
            torn += 1
        reg.prometheus_text()
    for t in threads:
        t.join()
    assert torn == 0
    assert c.value == n_threads * n_each
    assert h.count == n_threads * n_each


# --------------------------------------------------------------------------
# span tracer
# --------------------------------------------------------------------------

def test_disabled_tracer_records_nothing_and_reuses_null_cm():
    tr = SpanTracer(capacity=4, enabled=False)
    cm1 = tr.span("a")
    cm2 = tr.span("b")
    assert cm1 is cm2            # the shared no-op context manager
    with cm1:
        pass
    tr.instant("mark")
    assert tr.n_recorded == 0 and tr.spans() == []


def test_ring_wraparound_keeps_most_recent_spans():
    tr = SpanTracer(capacity=8, enabled=True)
    for i in range(20):
        with tr.span(f"s{i}", {"i": i}):
            pass
    assert tr.n_recorded == 20
    assert tr.n_dropped == 12
    got = [s["name"] for s in tr.spans()]
    assert got == [f"s{i}" for i in range(12, 20)]   # oldest first
    # chrome trace is valid JSON with one event per surviving span
    doc = json.loads(json.dumps(tr.chrome_trace()))
    assert len(doc["traceEvents"]) == 8
    assert doc["otherData"]["dropped_spans"] == 12
    for ev in doc["traceEvents"]:
        assert ev["ph"] == "X" and ev["dur"] >= 0
    tr.clear()
    assert tr.n_recorded == 0 and tr.spans() == []
    with pytest.raises(ValueError):
        SpanTracer(capacity=0)


def test_tracer_export_and_thread_safety(tmp_path):
    tr = SpanTracer(capacity=64, enabled=True)
    done = threading.Barrier(5)

    def worker():
        done.wait()
        for _ in range(100):
            with tr.span("w"):
                pass

    threads = [threading.Thread(target=worker) for _ in range(4)]
    for t in threads:
        t.start()
    done.wait()
    for _ in range(50):
        tr.spans()               # concurrent reads during writes
    for t in threads:
        t.join()
    assert tr.n_recorded == 400
    path = tr.export(str(tmp_path / "trace.json"))
    with open(path) as f:
        doc = json.load(f)
    assert len(doc["traceEvents"]) == 64        # capacity-bounded


# --------------------------------------------------------------------------
# analog-health counters vs the frozen RRNS oracle
# --------------------------------------------------------------------------

BASE = list(special_moduli(5))
ALL = BASE + list(rrns.default_redundant_moduli(5))
PSI = (int(np.prod(BASE)) - 1) // 2


def _residues(xs):
    return np.stack([np.mod(xs, m) for m in ALL]).astype(np.int32)


def _decode_with_health(res, tables):
    # eager (not jitted): the recorded values must be CONCRETE so the test
    # can read them off the collector directly; the serving engine's jitted
    # steps instead fold them into device accumulators (see obs/health.py)
    with health.collect() as hc:
        dec, cor = rrns.rrns_decode(jnp.asarray(res), tables)
    return (np.asarray(dec), np.asarray(cor),
            {k: np.asarray(v) for k, v in hc.values.items()})


def test_health_counters_match_oracle_on_single_residue_errors():
    """Every single-residue error is repairable with r=2: the counters
    must report exactly the oracle's corrected-flag count, zero
    uncorrected, and the decode itself must bit-match the oracle."""
    rng = np.random.default_rng(3)
    xs = rng.integers(1, PSI + 1, size=128)     # nonzero ground truth
    res = _residues(xs)
    hit = rng.random(128) < 0.5
    pos = rng.integers(0, len(ALL), size=128)
    for j in np.flatnonzero(hit):
        m = ALL[pos[j]]
        res[pos[j], j] = (res[pos[j], j] + rng.integers(1, m)) % m
    tables = rrns.build_tables(ALL, 3, PSI)
    dec, cor, h = _decode_with_health(res, tables)
    dec_np, cor_np = noise.rrns_decode_np(res.astype(np.int64), ALL, 3, PSI)
    np.testing.assert_array_equal(dec, dec_np)
    np.testing.assert_array_equal(cor, cor_np)
    np.testing.assert_array_equal(dec, xs)      # all repaired to truth
    assert int(h["rrns_corrected"]) == int(cor_np.sum()) > 0
    assert int(h["rrns_uncorrected"]) == 0


def test_health_counters_match_oracle_on_double_residue_errors():
    """Two simultaneous residue errors exceed the r=2 correction radius:
    the corrected/uncorrected split must still partition the oracle's
    flagged positions exactly (flagged = repaired ∪ unrepairable), and
    unrepairable positions are exactly the oracle's clamped-to-0 ones."""
    rng = np.random.default_rng(4)
    xs = rng.integers(1, PSI + 1, size=96)      # nonzero ground truth
    res = _residues(xs)
    for j in range(0, 96, 2):                   # half get double errors
        p = int(rng.integers(0, len(ALL)))
        q = (p + 1 + int(rng.integers(0, len(ALL) - 1))) % len(ALL)
        for k in (p, q):
            m = ALL[k]
            res[k, j] = (res[k, j] + rng.integers(1, m)) % m
    tables = rrns.build_tables(ALL, 3, PSI)
    dec, cor, h = _decode_with_health(res, tables)
    dec_np, cor_np = noise.rrns_decode_np(res.astype(np.int64), ALL, 3, PSI)
    np.testing.assert_array_equal(dec, dec_np)
    np.testing.assert_array_equal(cor, cor_np)
    n_corr, n_unc = int(h["rrns_corrected"]), int(h["rrns_uncorrected"])
    assert n_corr + n_unc == int(cor_np.sum()) > 0
    # the split follows the correction-radius certificate: a trustworthy
    # winner agrees with >= n_total - floor(r/2) = 4 moduli (single-error
    # radius for r=2). Double-error elements fall below it even when some
    # legal — wrong — value wins the vote (legality alone certifies
    # nothing: the all-base subset is legal for every residue tuple)
    cons = np.stack([dec_np % m == res[i] % m
                     for i, m in enumerate(ALL)]).sum(axis=0)
    assert n_unc == int((cons < len(ALL) - 1).sum()) > 0


def test_health_counters_zero_on_clean_residues():
    xs = np.arange(1, 65)
    tables = rrns.build_tables(ALL, 3, PSI)
    dec, cor, h = _decode_with_health(_residues(xs), tables)
    np.testing.assert_array_equal(dec, xs)
    assert int(h["rrns_corrected"]) == 0
    assert int(h["rrns_uncorrected"]) == 0


def test_record_is_noop_without_scope_and_under_suppression():
    health.record("rrns_corrected", jnp.ones(()))   # no scope: no-op
    with health.collect() as hc:
        with health.suppressed():
            assert not health.active()
            health.record("rrns_corrected", jnp.ones(()))
        assert health.active()
        health.record("rrns_corrected", jnp.asarray(2, jnp.int32))
    assert int(hc.values["rrns_corrected"]) == 2


def test_lifted_scan_reraises_inner_records_one_level_up():
    """Records inside a scan body cross to the enclosing scope via the
    lift (stacked outputs summed over the scan axis) — composing through
    a nested scan."""
    def inner_body(c, x):
        health.record("hits", jnp.asarray(1, jnp.int32))
        return c + x, x

    def outer_body(c, x):
        s, _ = health.lifting_scan(health.lifted(inner_body),
                                   jnp.zeros(()), jnp.ones((3,)) * x)
        return c + s, s

    with health.collect() as hc:
        total, _ = health.lifting_scan(health.lifted(outer_body),
                                       jnp.zeros(()), jnp.ones((4,)))
    assert float(total) == 12.0
    assert int(hc.values["hits"]) == 12


def test_spec_and_fold_contract():
    assert health.spec(get_policy("mirage")) == {}
    s = health.spec(get_policy("mirage_rrns"))
    assert set(s) == {"rrns_corrected", "rrns_uncorrected"}
    sn = health.spec(get_policy("mirage_rrns", snr_db=12.0, noise_seed=0))
    assert "detector_flips" in sn and sn["detector_flips"][0] == len(ALL)
    acc = health.init(s)
    acc2 = health.fold(acc, {"rrns_corrected": jnp.asarray(3, jnp.int32),
                             "not_in_spec": jnp.asarray(9, jnp.int32)})
    assert int(acc2["rrns_corrected"]) == 3
    assert int(acc2["rrns_uncorrected"]) == 0
    assert "not_in_spec" not in acc2            # spec is the contract
