"""Fast error-corrected execution: fused single-pass RRNS decode (jnp +
Pallas subset-major kernel), residue-level channel composition under
``use_pallas``, stationary-residue weight caching, correlated burst
errors, and the weight-stationary contract extended to the RNS backends.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from tests._hypothesis_compat import given, settings, st

from repro.analog import channel, rrns
from repro.core import gemm, noise, stationary
from repro.core.precision import MiragePolicy, get_policy, special_moduli
from repro.kernels.rrns_decode import rrns_decode_pallas


def _rand(shape, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


def _setup(k):
    base = list(special_moduli(k))
    extra = list(rrns.default_redundant_moduli(k))
    allm = base + extra
    psi = (int(np.prod(base)) - 1) // 2
    return allm, psi, rrns.build_tables(allm, len(base), psi)


def _corrupt(allm, psi, seed, size=96, err_rate=0.6):
    """Residues of a value mix hitting the psi boundaries, with 0..n_total
    random residue errors per element."""
    rng = np.random.default_rng(seed)
    xs = rng.integers(-psi, psi + 1, size=size)
    xs[: min(6, size)] = [psi, -psi, 0, psi - 1, 1 - psi, 1][: min(6, size)]
    res = np.stack([np.mod(xs, m) for m in allm]).astype(np.int32)
    for j in range(size):
        if rng.random() > err_rate:
            continue
        nerr = rng.integers(1, len(allm) + 1)
        for p in rng.choice(len(allm), size=nerr, replace=False):
            res[p, j] = (res[p, j] + rng.integers(1, allm[p])) % allm[p]
    return res


# --------------------------------------------------------------------------
# Fused decode ≡ frozen oracle (randomized + hypothesis property)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("k", [3, 4, 5, 6])
def test_fused_decode_matches_oracle_all_paths(k):
    """Bit-parity of fused jnp decode, reference decode and (when the
    moduli fit the f32 window) the Pallas kernel, against the numpy oracle,
    across psi-boundary values and 0..n_total residue errors. k=6 exceeds
    the f32 window and exercises the int32 fallback."""
    allm, psi, tables = _setup(k)
    res = _corrupt(allm, psi, seed=k)
    dec_np, cor_np = noise.rrns_decode_np(res.astype(np.int64), allm,
                                          tables.n_required, psi)
    dec, cor = jax.jit(lambda r: rrns.rrns_decode(r, tables))(jnp.asarray(res))
    np.testing.assert_array_equal(np.asarray(dec), dec_np)
    np.testing.assert_array_equal(np.asarray(cor), cor_np)
    dr, cr = rrns.rrns_decode_reference(jnp.asarray(res), tables)
    np.testing.assert_array_equal(np.asarray(dr), dec_np)
    np.testing.assert_array_equal(np.asarray(cr), cor_np)
    if tables.f32_exact:
        dp, cp = rrns_decode_pallas(jnp.asarray(res), tables, block_e=32)
        np.testing.assert_array_equal(np.asarray(dp), dec_np)
        np.testing.assert_array_equal(np.asarray(cp), cor_np)
    else:
        with pytest.raises(ValueError, match="f32"):
            rrns_decode_pallas(jnp.asarray(res), tables)


@given(st.integers(min_value=3, max_value=6),
       st.integers(min_value=0, max_value=2**31 - 1),
       st.floats(min_value=0.0, max_value=1.0))
@settings(max_examples=25, deadline=None)
def test_fused_decode_oracle_property(k, seed, err_rate):
    """Property form of the parity test: any moduli set (both f32 and int32
    decode regimes), any corruption pattern, psi boundaries included."""
    allm, psi, tables = _setup(k)
    res = _corrupt(allm, psi, seed=seed, size=48, err_rate=err_rate)
    dec_np, cor_np = noise.rrns_decode_np(res.astype(np.int64), allm,
                                          tables.n_required, psi)
    dec, cor = rrns.rrns_decode(jnp.asarray(res), tables)
    np.testing.assert_array_equal(np.asarray(dec), dec_np)
    np.testing.assert_array_equal(np.asarray(cor), cor_np)
    if tables.f32_exact:
        dp, cp = rrns_decode_pallas(jnp.asarray(res), tables, block_e=64)
        np.testing.assert_array_equal(np.asarray(dp), dec_np)
        np.testing.assert_array_equal(np.asarray(cp), cor_np)


def test_fused_decode_is_vmap_safe_and_jittable():
    allm, psi, tables = _setup(5)
    xs = np.arange(-8, 8).reshape(4, 4)
    res = np.stack([np.mod(xs, m) for m in allm]).astype(np.int32)
    out = jax.vmap(lambda r: rrns.rrns_decode(r, tables)[0], in_axes=1,
                   out_axes=0)(jnp.asarray(res))
    np.testing.assert_array_equal(np.asarray(out), xs)
    lowered = jax.jit(
        lambda r: rrns.rrns_decode(r, tables)).lower(jnp.asarray(res))
    assert "callback" not in lowered.as_text().lower()


# --------------------------------------------------------------------------
# use_pallas composes with the analog channel
# --------------------------------------------------------------------------

def test_pallas_rrns_runs_the_channel():
    """The acceptance bar: use_pallas + mirage_rrns executes the channel —
    noisy outputs differ from the clean kernel path and are deterministic
    per noise_seed."""
    x, w = _rand((4, 64), 1), _rand((64, 8), 2)
    clean = np.asarray(gemm.mirage_matmul_nograd(
        x, w, get_policy("mirage_rns_pallas")))
    p = get_policy("mirage_rrns", use_pallas=True, snr_db=30.0, noise_seed=5)
    a = np.asarray(jax.jit(
        lambda x, w: gemm.mirage_matmul_nograd(x, w, p))(x, w))
    b = np.asarray(jax.jit(
        lambda x, w: gemm.mirage_matmul_nograd(x, w, p))(x, w))
    np.testing.assert_array_equal(a, b)            # deterministic per seed
    assert not np.array_equal(a, clean)            # the channel really ran
    c = np.asarray(gemm.mirage_matmul_nograd(
        x, w, p.replace(noise_seed=6)))
    assert not np.array_equal(a, c)                # seed actually keys it


@pytest.mark.parametrize("mode", ["mirage_rns_noisy", "mirage_rrns"])
def test_pallas_channel_bit_matches_jnp_channel(mode):
    """With crosstalk=0 the fused in-kernel readout (noise + ADC epilogue)
    draws the SAME noise from the SAME key as the jnp channel stages —
    bit-identical outputs, not just statistically similar."""
    x, w = _rand((3, 64), 3), _rand((64, 6), 4)
    base = get_policy(mode, snr_db=32.0, noise_seed=9, adc_bits=5)
    jnp_out = np.asarray(gemm.mirage_matmul_nograd(x, w, base))
    pal_out = np.asarray(gemm.mirage_matmul_nograd(
        x, w, base.replace(use_pallas=True)))
    np.testing.assert_array_equal(jnp_out, pal_out)


def test_pallas_crosstalk_config_still_composes():
    """Nonzero crosstalk cannot fuse into one kernel block (neighbor-group
    mixing); the kernel runs clean and the jnp readout chain applies — the
    config executes rather than raising, and matches the pure-jnp path."""
    x, w = _rand((3, 64), 5), _rand((64, 6), 6)
    p = get_policy("mirage_rrns", snr_db=32.0, noise_seed=1, crosstalk=0.02)
    a = np.asarray(gemm.mirage_matmul_nograd(x, w, p))
    b = np.asarray(gemm.mirage_matmul_nograd(x, w, p.replace(use_pallas=True)))
    np.testing.assert_array_equal(a, b)


def test_noiseless_pallas_rrns_matches_clean_rns():
    x, w = _rand((4, 64), 7), _rand((64, 6), 8)
    ref = np.asarray(gemm.mirage_matmul_nograd(x, w, get_policy("mirage_rns")))
    out = np.asarray(gemm.mirage_matmul_nograd(
        x, w, get_policy("mirage_rrns", use_pallas=True)))
    np.testing.assert_array_equal(out, ref)


# --------------------------------------------------------------------------
# Stationary residues (program-once weight admission)
# --------------------------------------------------------------------------

def test_stationary_residues_bit_match_per_call_path():
    x, w = _rand((4, 64), 9), _rand((64, 8), 10)
    for mode in ("mirage_rns", "mirage_rrns", "mirage_rns_noisy"):
        p = get_policy(mode) if mode == "mirage_rns" else \
            get_policy(mode, snr_db=40.0, noise_seed=2)
        sw = stationary.encode_stationary(w, p)
        a = np.asarray(gemm.mirage_matmul_nograd(x, w, p))
        b = np.asarray(gemm.mirage_matmul_nograd(x, sw, p))
        np.testing.assert_array_equal(a, b)


def test_stationary_residues_mismatch_raises():
    x, w = _rand((4, 64), 11), _rand((64, 8), 12)
    p = get_policy("mirage_rrns")
    sw = stationary.encode_stationary(w, p)
    with pytest.raises(ValueError, match="moduli"):
        gemm.mirage_matmul_nograd(x, sw, get_policy("mirage_rns"))
    with pytest.raises(ValueError, match="BFP"):
        gemm.mirage_matmul_nograd(x, sw, p.replace(g=8))
    with pytest.raises(TypeError, match="supports_stationary_residues"):
        gemm.mirage_matmul_nograd(x, sw, get_policy("mirage_fast"))


def test_encode_stationary_params_selects_gemm_leaves():
    params = {
        "layers": {"mlp": {"down": _rand((3, 32, 8), 13)},
                   "attn": {"q": {"w": _rand((32, 16), 14),
                                  "b": jnp.zeros((16,))}}},
        "router": {"w": _rand((32, 4), 15)},
        "embed": {"emb": _rand((64, 32), 16)},
        "final_norm": {"scale": jnp.ones((32,))},
    }
    enc = stationary.encode_stationary_params(params, get_policy("mirage_rrns"))
    assert isinstance(enc["layers"]["mlp"]["down"],
                      stationary.StationaryResidues)
    assert enc["layers"]["mlp"]["down"].residues.shape[0] == 3  # stack dim
    assert isinstance(enc["layers"]["attn"]["q"]["w"],
                      stationary.StationaryResidues)
    # router / embeddings / norms / biases stay raw arrays
    assert isinstance(enc["router"]["w"], jax.Array)
    assert isinstance(enc["embed"]["emb"], jax.Array)
    assert isinstance(enc["final_norm"]["scale"], jax.Array)
    assert isinstance(enc["layers"]["attn"]["q"]["b"], jax.Array)


def test_stationary_serving_token_parity_and_determinism():
    """LMServer auto-programs stationary residues for RNS-family policies;
    clean-channel served tokens are identical to the per-call path, and
    noisy stationary serving stays deterministic per seed."""
    from repro.configs import get_config
    from repro.models import build_model
    from repro.models.lm import LMCallOptions
    from repro.runtime.server import LMServer, Request

    cfg = get_config("qwen2-0.5b").reduced()

    def serve(policy, stationary_flag):
        model = build_model(cfg, policy, LMCallOptions(q_chunk=16,
                                                       kv_chunk=16))
        params = model.init(jax.random.PRNGKey(0))
        srv = LMServer(model, params, cap=16, batch_slots=2,
                       stationary_weights=stationary_flag)
        rng = np.random.default_rng(3)
        for i in range(3):
            srv.submit(Request(
                rid=i, prompt=rng.integers(1, cfg.vocab_size,
                                           size=5).astype(np.int32),
                max_tokens=3))
        done = srv.run_until_drained()
        return srv.stationary_weights, {r.rid: tuple(r.tokens_out)
                                        for r in done}

    p = get_policy("mirage_rrns")
    auto_on, toks_on = serve(p, None)
    off, toks_off = serve(p, False)
    assert auto_on and not off
    assert toks_on == toks_off

    pn = get_policy("mirage_rrns", snr_db=35.0, noise_seed=11)
    on1, t1 = serve(pn, None)
    on2, t2 = serve(pn, None)
    assert on1 and on2 and t1 == t2


# --------------------------------------------------------------------------
# Correlated burst errors
# --------------------------------------------------------------------------

def test_burst_width1_fully_corrected_width2_degrades():
    """Single-residue bursts stay inside the 2-redundant-moduli correction
    radius: the corrected output is BIT-IDENTICAL to the clean path while
    the uncorrected backend visibly corrupts. Double-residue bursts exceed
    the radius and degrade the corrected path detectably."""
    x, w = _rand((8, 128), 17), _rand((128, 8), 18)
    clean = np.asarray(gemm.mirage_matmul_nograd(x, w, get_policy("mirage_rns")))
    p1 = get_policy("mirage_rrns", burst_rate=0.2, burst_width=1,
                    noise_seed=0)
    out1 = np.asarray(gemm.mirage_matmul_nograd(x, w, p1))
    np.testing.assert_array_equal(out1, clean)
    u1 = np.asarray(gemm.mirage_matmul_nograd(
        x, w, get_policy("mirage_rns_noisy", burst_rate=0.2, burst_width=1,
                         noise_seed=0)))
    assert not np.array_equal(u1, clean)
    p2 = p1.replace(burst_width=2)
    out2 = np.asarray(gemm.mirage_matmul_nograd(x, w, p2))
    assert not np.array_equal(out2, clean)


def test_burst_stage_deterministic_and_residue_valued():
    allm = [31, 32, 33, 37, 41]
    r = jnp.asarray(np.stack(
        [np.random.default_rng(i).integers(0, m, size=(4, 16))
         for i, m in enumerate(allm)]), jnp.int32)
    key = jax.random.PRNGKey(0)
    a = channel.burst_errors(r, allm, 0.5, 2, key)
    b = channel.burst_errors(r, allm, 0.5, 2, key)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    out = np.asarray(a)
    for i, m in enumerate(allm):
        assert out[i].min() >= 0 and out[i].max() < m
    assert not np.array_equal(out, np.asarray(r))
    # exactly `width` adjacent channels change on each hit element
    changed = (out != np.asarray(r)).sum(axis=0)
    assert set(np.unique(changed)) <= {0, 1, 2}   # errs can alias to 0 shift


# --------------------------------------------------------------------------
# Weight-stationary contract on the RNS/faithful backends
# --------------------------------------------------------------------------

def test_prequantized_weight_rns_gemm_bit_matches():
    """assume_quantized_weights on the group-dot backends: the round/clip-
    free decomposition of an on-grid weight is bit-identical to a full
    re-quantization."""
    from repro.core import bfp
    rng = np.random.default_rng(19)
    x = jnp.asarray(rng.normal(size=(6, 64)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(64, 8)).astype(np.float32))
    wq = jnp.moveaxis(bfp.bfp_fake_quant(jnp.moveaxis(w, -2, -1), 4, 16),
                      -1, -2)
    for mode in ("mirage_faithful", "mirage_rns", "mirage_rrns"):
        p = get_policy(mode) if mode != "mirage_rrns" else \
            get_policy(mode, snr_db=45.0, noise_seed=4)
        base = np.asarray(gemm.mirage_matmul_nograd(x, wq, p))
        pre = np.asarray(gemm.mirage_matmul_nograd(
            x, wq, p.replace(assume_quantized_weights=True)))
        np.testing.assert_array_equal(base, pre)


def test_wsq_training_composes_with_rrns():
    """The trainer's weight-stationary flag now reaches the RNS-family
    backends (capability flag): gradients flow and the dX GEMM re-quantizes
    the transposed read (aligned-only contract) instead of mis-decomposing."""
    x, w = _rand((4, 32), 20), _rand((32, 4), 21)
    p = get_policy("mirage_rrns", snr_db=50.0, noise_seed=0,
                   assume_quantized_weights=True)

    def loss(xx, ww):
        return jnp.sum(gemm.mirage_matmul(xx, ww, p) ** 2)

    gx, gw = jax.jit(jax.grad(loss, argnums=(0, 1)))(x, w)
    assert np.isfinite(np.asarray(gx)).all()
    assert np.isfinite(np.asarray(gw)).all()


def test_channel_key_tag_is_deterministic():
    """The per-GEMM-site noise tag folds operand dims with an explicit
    mixer — no CPython hash(), so error patterns reproduce everywhere."""
    from repro.core.backends.mirage_rrns import _dims_tag
    assert _dims_tag(((4, 64), (64, 8))) == _dims_tag(((4, 64), (64, 8)))
    assert _dims_tag(((4, 64), (64, 8))) != _dims_tag(((64, 4), (64, 8)))
    # pinned value: changing the fold silently would change every seeded
    # error pattern in checked-in baselines
    assert _dims_tag(((2, 3),)) == (
        ((0 * 1000003 + 2 + 0x9E3779B1) % 0x7FFFFFFF) * 1000003
        + 3 + 0x9E3779B1) % 0x7FFFFFFF


def test_backend_capability_flags():
    from repro.core import backends
    for mode in ("mirage_rns", "mirage_rns_pallas", "mirage_rns_noisy",
                 "mirage_rrns"):
        b = backends.get_backend(mode)
        assert b.supports_stationary_residues
        assert b.supports_weight_stationary
        assert b.weight_stationary_aligned_only
    assert backends.get_backend("mirage_rrns_ref").reference
    assert not backends.get_backend("mirage_fast").weight_stationary_aligned_only
    assert MiragePolicy(mode="mirage_rrns_ref").mode == "mirage_rrns_ref"
