"""Block allocator behind the paged KV cache: allocation/free/table
invariants (unit + hypothesis property tests over random admit/retire
sequences), refcounted sharing / copy-on-write forks / window trims, slot
remapping, elastic pool resize, and the hash-chain prefix index."""

import numpy as np
import pytest

from repro.runtime.paging import BlockAllocator, PrefixIndex, blocks_for
from tests._hypothesis_compat import given, settings, st


def test_blocks_for():
    assert blocks_for(0, 16) == 0
    assert blocks_for(1, 16) == 1
    assert blocks_for(16, 16) == 1
    assert blocks_for(17, 16) == 2


def test_alloc_assigns_distinct_blocks():
    a = BlockAllocator(n_blocks=8, block_size=4, n_slots=3)
    a.ensure(0, 9)          # 3 blocks
    a.ensure(1, 5)          # 2 blocks
    a.check_invariants()
    assert a.n_owned[0] == 3 and a.n_owned[1] == 2
    assert not set(a.slot_blocks(0)) & set(a.slot_blocks(1))
    assert a.free_count == 3
    # growing to an already-covered position is a no-op
    a.ensure(0, 12)
    assert a.n_owned[0] == 3


def test_unmapped_entries_hold_sentinel():
    a = BlockAllocator(n_blocks=6, block_size=4, n_slots=2,
                       max_blocks_per_slot=4)
    a.ensure(0, 6)
    assert list(a.tables[0, 2:]) == [a.sentinel] * 2
    assert list(a.tables[1]) == [a.sentinel] * 4


def test_release_returns_blocks_and_reuse_prefers_low_ids():
    a = BlockAllocator(n_blocks=4, block_size=4, n_slots=2)
    a.ensure(0, 8)
    first = a.slot_blocks(0)
    a.release(0)
    a.check_invariants()
    assert a.free_count == 4 and a.used_count == 0
    # defrag-on-retirement: the freed (low) ids come back first
    a.ensure(1, 8)
    assert a.slot_blocks(1) == sorted(first)


def test_pool_exhaustion_and_table_overflow_raise():
    a = BlockAllocator(n_blocks=2, block_size=4, n_slots=2,
                       max_blocks_per_slot=2)
    a.ensure(0, 8)
    with pytest.raises(RuntimeError, match="exhausted"):
        a.ensure(1, 4)
    with pytest.raises(ValueError, match="tables hold"):
        a.ensure(0, 12)
    assert not a.can_fit(1)
    a.release(0)
    assert a.can_fit(8)


def test_peak_tracks_high_watermark():
    a = BlockAllocator(n_blocks=8, block_size=4, n_slots=2)
    a.ensure(0, 16)
    a.ensure(1, 8)
    a.release(0)
    assert a.used_count == 2 and a.peak_in_use == 6


def test_remap_slots_compacts_kept_rows():
    a = BlockAllocator(n_blocks=8, block_size=4, n_slots=3)
    a.ensure(0, 4)
    a.ensure(1, 8)
    a.ensure(2, 4)
    keep_blocks = a.slot_blocks(2)
    a.remap_slots([2], 2)
    a.check_invariants()
    assert a.n_slots == 2
    assert a.slot_blocks(0) == keep_blocks      # old slot 2 -> row 0
    assert a.n_owned[1] == 0
    assert a.free_count == 7


def test_resize_pool_compacts_and_remaps_tables():
    a = BlockAllocator(n_blocks=8, block_size=4, n_slots=2)
    a.ensure(0, 8)
    a.ensure(1, 8)
    a.release(0)                                # leaves holes
    held = {int(b) for b in a.slot_blocks(1)}
    old_ids, new_ids = a.resize_pool(3)
    a.check_invariants()
    assert a.n_blocks == 3 and a.sentinel == 3
    assert set(old_ids) == held
    assert list(new_ids) == list(range(len(held)))
    # the slot's data moved with the renumbering
    assert sorted(a.slot_blocks(1)) == list(new_ids)
    with pytest.raises(ValueError):
        a.resize_pool(1)
    # growing back works too
    a.resize_pool(10)
    a.check_invariants()
    assert a.free_count == 10 - a.used_count


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 32)),
                min_size=1, max_size=60))
def test_random_admit_retire_preserves_invariants(ops):
    """Property: over any admit/grow/retire sequence, no block is ever
    double-owned, frees return to the pool, and tables stay consistent."""
    a = BlockAllocator(n_blocks=12, block_size=4, n_slots=4,
                       max_blocks_per_slot=8)
    lens = [0] * 4
    for slot, n in ops:
        if n == 0:
            freed = a.release(slot)
            assert len(freed) == blocks_for(lens[slot], 4)
            lens[slot] = 0
        else:
            n = max(lens[slot], n)      # ensure() only grows
            need = blocks_for(n, 4) - blocks_for(lens[slot], 4)
            if need > a.free_count:
                with pytest.raises(RuntimeError):
                    a.ensure(slot, n)
            else:
                a.ensure(slot, n)
                lens[slot] = n
        a.check_invariants()
        assert a.used_count == sum(blocks_for(length, 4) for length in lens)
    for s in range(4):
        a.release(s)
    a.check_invariants()
    assert a.free_count == 12


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 15), min_size=1, max_size=12),
       st.integers(0, 3))
def test_alloc_after_retire_reuses_blocks(lengths, retire_every):
    """Property: serving a stream of admissions through ONE slot never
    grows the footprint past that slot's own block need — retired blocks
    are reused, not leaked."""
    a = BlockAllocator(n_blocks=6, block_size=4, n_slots=1,
                       max_blocks_per_slot=6)
    for i, n in enumerate(lengths):
        a.ensure(0, n)
        a.check_invariants()
        assert a.used_count <= blocks_for(max(lengths), 4)
        if retire_every and i % (retire_every + 1) == retire_every:
            a.release(0)
    a.release(0)
    assert a.free_count == 6


# -- refcounted sharing / copy-on-write / trims -------------------------


def test_share_bumps_refcounts_and_survives_donor_release():
    a = BlockAllocator(n_blocks=8, block_size=4, n_slots=2)
    a.ensure(0, 8)
    ids = a.slot_blocks(0)
    a.share(1, ids)
    a.check_invariants()
    assert all(a.is_shared(b) for b in ids)
    freed = a.release(0)
    assert freed == []                       # the sharer keeps them alive
    a.check_invariants()
    assert a.slot_blocks(1) == ids
    assert sorted(a.release(1)) == sorted(ids)
    assert a.free_count == 8


def test_share_rejects_dead_blocks_and_full_tables():
    a = BlockAllocator(n_blocks=4, block_size=4, n_slots=2,
                       max_blocks_per_slot=2)
    a.ensure(0, 8)
    with pytest.raises(ValueError, match="dead block"):
        a.share(1, [3])
    a.share(1, a.slot_blocks(0))
    with pytest.raises(ValueError, match="past"):
        a.share(1, a.slot_blocks(0)[:1])


def test_fork_cow_copies_exactly_one_block():
    a = BlockAllocator(n_blocks=8, block_size=4, n_slots=2)
    a.ensure(0, 8)
    ids = a.slot_blocks(0)
    a.share(1, ids)
    used0 = a.used_count
    src, dst = a.fork_cow(1, 0)
    a.check_invariants()
    assert a.used_count == used0 + 1         # exactly one new block
    assert src == ids[0] and dst not in ids
    assert a.refcount[src] == 1 and a.refcount[dst] == 1
    assert a.slot_blocks(1) == [dst, ids[1]]
    assert a.slot_blocks(0) == ids           # the other holder is untouched
    # private / unmapped blocks need no fork
    assert a.fork_cow(1, 0) is None
    assert a.fork_cow(1, 5) is None


def test_trim_below_is_refcount_aware():
    a = BlockAllocator(n_blocks=8, block_size=4, n_slots=2)
    a.ensure(0, 16)
    ids = a.slot_blocks(0)
    a.share(1, ids[:2])
    # positions < 9 -> logical blocks 0,1 are wholly behind the window;
    # both are shared, so the trim frees NOTHING
    freed = a.trim_below(0, 9)
    a.check_invariants()
    assert freed == []
    assert a.slot_blocks(0) == ids[2:]
    # the second holder's trim drops the last references
    freed = a.trim_below(1, 9)
    assert sorted(freed) == sorted(ids[:2])
    a.check_invariants()
    # a trimmed slot keeps growing at the tail
    a.ensure(0, 20)
    a.check_invariants()
    assert int(a.lo[0]) == 2 and int(a.n_owned[0]) == 5


def test_resize_pool_preserves_shared_refcounts():
    a = BlockAllocator(n_blocks=8, block_size=4, n_slots=2)
    a.ensure(0, 8)
    ids = a.slot_blocks(0)
    a.share(1, ids)
    old_ids, new_ids = a.resize_pool(4)
    a.check_invariants()
    renum = dict(zip([int(b) for b in old_ids], [int(b) for b in new_ids]))
    for b in ids:
        assert int(a.refcount[renum[b]]) == 2
    assert a.slot_blocks(0) == a.slot_blocks(1)


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 5), st.integers(0, 3),
                          st.integers(0, 31)),
                min_size=1, max_size=80))
def test_share_fork_trim_interleavings_preserve_invariants(ops):
    """Property: any interleaving of grow/share/fork/trim/release/remap
    keeps refcounts equal to live table references, never double-frees,
    and every copy-on-write fork allocates exactly one block."""
    a = BlockAllocator(n_blocks=24, block_size=4, n_slots=4,
                       max_blocks_per_slot=8)
    for op, slot, arg in ops:
        if op == 0:      # grow
            need = blocks_for(arg, 4)
            if need <= 8 and need - int(a.n_owned[slot]) <= a.free_count:
                a.ensure(slot, arg)
        elif op == 1:    # share a donor's blocks into an empty slot
            donor = arg % 4
            blocks = a.slot_blocks(donor)
            if donor != slot and int(a.n_owned[slot]) == 0 and blocks:
                a.share(slot, blocks[: arg % len(blocks) + 1])
        elif op == 2:    # copy-on-write fork of one mapped logical block
            lo, hi = int(a.lo[slot]), int(a.n_owned[slot])
            if hi > lo and a.free_count > 0:
                used0 = a.used_count
                r = a.fork_cow(slot, lo + arg % (hi - lo))
                if r is not None:
                    assert a.used_count == used0 + 1
                    assert a.refcount[r[1]] == 1
        elif op == 3:    # trim behind a sliding window
            a.trim_below(slot, arg)
        elif op == 4:    # release
            a.release(slot)
        else:            # identity remap still rewrites every row
            assert a.remap_slots(list(range(4)), 4) == []
        a.check_invariants()
    for s in range(4):
        a.release(s)
    a.check_invariants()
    assert a.free_count == 24


# -- hash-chain prefix index --------------------------------------------


def test_prefix_index_chain_match_and_divergence():
    ix = PrefixIndex(4)
    p = np.arange(12, dtype=np.int32)
    ix.insert_chain(p, [5, 6, 7])
    assert ix.match(p) == [5, 6, 7]
    assert ix.match(p[:11]) == [5, 6]        # partial final block ignored
    q = p.copy()
    q[5] = 99                                # diverges inside block 1
    assert ix.match(q) == [5]
    assert ix.match(np.arange(100, 104, dtype=np.int32)) == []


def test_prefix_index_keys_are_chained_not_per_block():
    ix = PrefixIndex(4)
    a_ = np.array([1, 2, 3, 4, 9, 9, 9, 9], np.int32)
    b_ = np.array([5, 6, 7, 8, 9, 9, 9, 9], np.int32)
    ix.insert_chain(a_, [0, 1])
    # identical second-block TOKENS after a different first block: the
    # chained key differs, so nothing matches
    assert ix.match(b_) == []


def test_prefix_index_first_insert_wins_and_eviction():
    ix = PrefixIndex(4)
    p = np.arange(8, dtype=np.int32)
    ix.insert_chain(p, [0, 1])
    ix.insert_chain(p, [2, 3])               # duplicate content: keep 0,1
    assert ix.match(p) == [0, 1]
    assert len(ix) == 2
    ix.evict_blocks([0])
    assert ix.match(p) == []                 # chain broken at block 0
    assert not ix.contains_block(0) and ix.contains_block(1)


def test_prefix_index_remap_follows_pool_resize():
    ix = PrefixIndex(4)
    p = np.arange(8, dtype=np.int32)
    ix.insert_chain(p, [4, 6])
    ix.remap({4: 0, 6: 1})
    assert ix.match(p) == [0, 1]
    ix.remap({0: 0})                         # block 1 freed by the resize
    assert ix.match(p) == [0] and len(ix) == 1


# ---------------------------------------------------------------------------
# sharded allocator (meshed serving: per-shard free lists + locality)
# ---------------------------------------------------------------------------

def test_shard_of_block_matches_xla_contiguous_chunks():
    a = BlockAllocator(n_blocks=8, block_size=4, n_slots=4, n_shards=2)
    assert [a.shard_of_block(b) for b in range(8)] == [0] * 4 + [1] * 4
    assert [a.shard_of_slot(s) for s in range(4)] == [0, 0, 1, 1]
    assert a.free_by_shard() == [4, 4]


def test_single_shard_degrades_to_flat_allocator():
    """n_shards=1 must behave bit-for-bit like the pre-sharding allocator:
    lowest free id first, no spills ever counted."""
    a = BlockAllocator(n_blocks=6, block_size=4, n_slots=2)
    a.ensure(0, 8)
    a.ensure(1, 8)
    assert sorted(a.slot_blocks(0)) == [0, 1]
    assert sorted(a.slot_blocks(1)) == [2, 3]
    a.release(0)
    a.ensure(1, 16)                          # reuses the freed low ids
    assert sorted(a.slot_blocks(1)) == [0, 1, 2, 3]
    assert a.spilled_allocs == 0
    assert a.remote_fraction() == 0.0


def test_locality_prefers_home_shard():
    a = BlockAllocator(n_blocks=8, block_size=4, n_slots=4, n_shards=2)
    a.ensure(0, 8)          # slot 0 home shard 0
    a.ensure(2, 8)          # slot 2 home shard 1
    assert {a.shard_of_block(b) for b in a.slot_blocks(0)} == {0}
    assert {a.shard_of_block(b) for b in a.slot_blocks(2)} == {1}
    assert a.local_allocs == 4 and a.spilled_allocs == 0
    assert a.remote_fraction() == 0.0


def test_locality_spills_when_home_shard_dry():
    a = BlockAllocator(n_blocks=8, block_size=4, n_slots=4, n_shards=2)
    a.ensure(0, 16)         # all 4 shard-0 blocks
    assert a.free_by_shard() == [0, 4]
    a.ensure(1, 8)          # home shard 0 is dry -> spill to shard 1
    assert {a.shard_of_block(b) for b in a.slot_blocks(1)} == {1}
    assert a.spilled_allocs == 2
    assert a.remote_fraction() == pytest.approx(2 / 6)
    a.check_invariants()
    # full exhaustion still raises
    a.ensure(2, 8)
    with pytest.raises(RuntimeError):
        a.ensure(3, 4)


def test_round_robin_ignores_home_shard():
    a = BlockAllocator(n_blocks=8, block_size=4, n_slots=4, n_shards=2,
                       placement="round_robin")
    a.ensure(0, 16)         # 4 blocks for a shard-0 slot
    shards = [a.shard_of_block(b) for b in a.slot_blocks(0)]
    assert shards.count(0) == 2 and shards.count(1) == 2
    assert a.spilled_allocs == 2  # half landed off-home
    with pytest.raises(ValueError):
        BlockAllocator(4, 4, 1, n_shards=2, placement="nope")


def test_n_shards_must_divide_pool():
    with pytest.raises(ValueError):
        BlockAllocator(n_blocks=7, block_size=4, n_slots=2, n_shards=2)


def test_freed_blocks_return_to_their_own_shard():
    a = BlockAllocator(n_blocks=8, block_size=4, n_slots=4, n_shards=2)
    a.ensure(0, 16)
    a.ensure(1, 8)          # spilled to shard 1
    a.release(1)
    assert a.free_by_shard() == [0, 4]       # spilled blocks went home to 1
    a.release(0)
    assert a.free_by_shard() == [4, 4]
    a.check_invariants()


def test_fork_cow_prefers_home_shard():
    a = BlockAllocator(n_blocks=8, block_size=4, n_slots=4, n_shards=2)
    a.ensure(0, 4)                            # block on shard 0
    donor = a.slot_blocks(0)
    a.share(2, donor)                         # slot 2 (home shard 1) shares
    src, dst = a.fork_cow(2, 0)
    assert a.shard_of_block(dst) == 1         # fork brought the copy local
    a.check_invariants()


def test_resize_pool_preserves_shard_residency():
    a = BlockAllocator(n_blocks=16, block_size=4, n_slots=4, n_shards=2)
    a.ensure(0, 8)
    a.ensure(2, 8)
    homes = {b: a.shard_of_block(b) for s in (0, 2) for b in a.slot_blocks(s)}
    old_ids, new_ids = a.resize_pool(8)
    a.check_invariants()
    moved = dict(zip(map(int, old_ids), map(int, new_ids)))
    for old, shard in homes.items():
        assert a.shard_of_block(moved[old]) == shard
    with pytest.raises(ValueError):
        a.resize_pool(2)                      # 4 live blocks don't fit
    with pytest.raises(ValueError):
        a.resize_pool(7)                      # not a multiple of n_shards


@settings(max_examples=30, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 24)),
                min_size=1, max_size=40),
       st.sampled_from(["locality", "round_robin"]))
def test_sharded_admit_retire_preserves_invariants(ops, placement):
    """Property: per-shard free lists stay disjoint and complete under any
    admit/grow/retire interleaving, for both placement policies, and
    locality never spills while the home shard has free blocks."""
    a = BlockAllocator(n_blocks=16, block_size=4, n_slots=4, n_shards=2,
                       placement=placement)
    lens = [0] * 4
    for slot, n in ops:
        if n == 0:
            a.release(slot)
            lens[slot] = 0
        else:
            n = max(lens[slot], n)
            need = blocks_for(n, 4) - blocks_for(lens[slot], 4)
            if need > a.free_count:
                with pytest.raises(RuntimeError):
                    a.ensure(slot, n)
            else:
                home_free = a.free_by_shard()[a.shard_of_slot(slot)]
                spills0 = a.spilled_allocs
                a.ensure(slot, n)
                lens[slot] = n
                if placement == "locality" and need <= home_free:
                    assert a.spilled_allocs == spills0
        a.check_invariants()
    assert a.local_allocs + a.spilled_allocs >= a.used_count


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 6), st.integers(0, 1))
def test_sharded_resize_and_prefix_remap_stay_consistent(n_live, grow):
    """Property: after shared-prefix COW traffic and a pool resize, the
    prefix index follows the explicit (old, new) map and every remapped
    block keeps its shard."""
    a = BlockAllocator(n_blocks=16, block_size=4, n_slots=4, n_shards=2)
    ix = PrefixIndex(4)
    prompt = np.arange(4 * n_live, dtype=np.int32)
    a.ensure(0, 4 * n_live)
    chain = a.slot_blocks(0)
    ix.insert_chain(prompt, chain)
    a.share(2, ix.match(prompt))              # cross-shard sharing
    a.fork_cow(2, 0)
    homes = {int(b): a.shard_of_block(int(b)) for b in chain}
    old_ids, new_ids = a.resize_pool(24 if grow else 16)
    a.check_invariants()
    moved = dict(zip(map(int, old_ids), map(int, new_ids)))
    ix.remap(moved)
    assert ix.match(prompt) == [moved[int(b)] for b in chain]
    for old, shard in homes.items():
        assert a.shard_of_block(moved[old]) == shard


# ---------------------------------------------------------------------------
# quarantine (the pool_exhaustion chaos fault site)
# ---------------------------------------------------------------------------

def test_quarantine_squeezes_free_pool_high_ids_first():
    a = BlockAllocator(n_blocks=8, block_size=4, n_slots=2)
    a.ensure(0, 9)                            # 3 used, 5 free
    taken = a.quarantine(3)
    assert len(taken) == 3 and a.free_count == 2
    assert min(taken) > max(b for b in range(8)
                            if b not in taken and a.refcount[b] == 0)
    a.check_invariants()
    # squeezing a dry pool caps at what is actually free
    more = a.quarantine(10)
    assert len(more) == 2 and a.free_count == 0
    with pytest.raises(RuntimeError, match="exhausted"):
        a.ensure(1, 4)                        # zero free budget: no admission


def test_unquarantine_restores_admission_and_guards_resize():
    a = BlockAllocator(n_blocks=6, block_size=4, n_slots=2)
    a.ensure(0, 8)                            # 2 used, 4 free
    taken = a.quarantine(4)
    with pytest.raises(RuntimeError, match="quarantined"):
        a.resize_pool(12)                     # no elastic resize mid-squeeze
    a.unquarantine(taken[:2])
    a.ensure(1, 8)                            # admission possible again
    a.check_invariants()
    a.unquarantine()                          # default: return everything
    assert not a.quarantined and a.free_count == 2
    a.check_invariants()
    with pytest.raises(ValueError, match="not quarantined"):
        a.unquarantine([taken[0]])            # double return


def test_quarantined_blocks_survive_release_and_share_traffic():
    """Release/share/fork churn around an active squeeze never touches the
    quarantined ids, and they come back clean."""
    a = BlockAllocator(n_blocks=10, block_size=4, n_slots=3)
    ix = PrefixIndex(4)
    prompt = np.arange(8, dtype=np.int32)
    a.ensure(0, 8)
    ix.insert_chain(prompt, a.slot_blocks(0))
    taken = set(a.quarantine(4))
    a.share(1, ix.match(prompt))              # shared-prefix admission
    a.fork_cow(1, 0)                          # COW write on the shared block
    a.release(0)                              # timed-out sharer retires
    a.check_invariants()
    assert all(a.refcount[b] == 0 for b in taken)
    assert not taken & set(a.slot_blocks(1))
    a.release(1)
    a.unquarantine()
    a.check_invariants()
    assert a.free_count == 10
