"""Block allocator behind the paged KV cache: allocation/free/table
invariants (unit + hypothesis property tests over random admit/retire
sequences), slot remapping and elastic pool resize."""

import numpy as np
import pytest

from repro.runtime.paging import BlockAllocator, blocks_for
from tests._hypothesis_compat import given, settings, st


def test_blocks_for():
    assert blocks_for(0, 16) == 0
    assert blocks_for(1, 16) == 1
    assert blocks_for(16, 16) == 1
    assert blocks_for(17, 16) == 2


def test_alloc_assigns_distinct_blocks():
    a = BlockAllocator(n_blocks=8, block_size=4, n_slots=3)
    a.ensure(0, 9)          # 3 blocks
    a.ensure(1, 5)          # 2 blocks
    a.check_invariants()
    assert a.n_owned[0] == 3 and a.n_owned[1] == 2
    assert not set(a.slot_blocks(0)) & set(a.slot_blocks(1))
    assert a.free_count == 3
    # growing to an already-covered position is a no-op
    a.ensure(0, 12)
    assert a.n_owned[0] == 3


def test_unmapped_entries_hold_sentinel():
    a = BlockAllocator(n_blocks=6, block_size=4, n_slots=2,
                       max_blocks_per_slot=4)
    a.ensure(0, 6)
    assert list(a.tables[0, 2:]) == [a.sentinel] * 2
    assert list(a.tables[1]) == [a.sentinel] * 4


def test_release_returns_blocks_and_reuse_prefers_low_ids():
    a = BlockAllocator(n_blocks=4, block_size=4, n_slots=2)
    a.ensure(0, 8)
    first = a.slot_blocks(0)
    a.release(0)
    a.check_invariants()
    assert a.free_count == 4 and a.used_count == 0
    # defrag-on-retirement: the freed (low) ids come back first
    a.ensure(1, 8)
    assert a.slot_blocks(1) == sorted(first)


def test_pool_exhaustion_and_table_overflow_raise():
    a = BlockAllocator(n_blocks=2, block_size=4, n_slots=2,
                       max_blocks_per_slot=2)
    a.ensure(0, 8)
    with pytest.raises(RuntimeError, match="exhausted"):
        a.ensure(1, 4)
    with pytest.raises(ValueError, match="tables hold"):
        a.ensure(0, 12)
    assert not a.can_fit(1)
    a.release(0)
    assert a.can_fit(8)


def test_peak_tracks_high_watermark():
    a = BlockAllocator(n_blocks=8, block_size=4, n_slots=2)
    a.ensure(0, 16)
    a.ensure(1, 8)
    a.release(0)
    assert a.used_count == 2 and a.peak_in_use == 6


def test_remap_slots_compacts_kept_rows():
    a = BlockAllocator(n_blocks=8, block_size=4, n_slots=3)
    a.ensure(0, 4)
    a.ensure(1, 8)
    a.ensure(2, 4)
    keep_blocks = a.slot_blocks(2)
    a.remap_slots([2], 2)
    a.check_invariants()
    assert a.n_slots == 2
    assert a.slot_blocks(0) == keep_blocks      # old slot 2 -> row 0
    assert a.n_owned[1] == 0
    assert a.free_count == 7


def test_resize_pool_compacts_and_remaps_tables():
    a = BlockAllocator(n_blocks=8, block_size=4, n_slots=2)
    a.ensure(0, 8)
    a.ensure(1, 8)
    a.release(0)                                # leaves holes
    held = {int(b) for b in a.slot_blocks(1)}
    old_ids, new_ids = a.resize_pool(3)
    a.check_invariants()
    assert a.n_blocks == 3 and a.sentinel == 3
    assert set(old_ids) == held
    assert list(new_ids) == list(range(len(held)))
    # the slot's data moved with the renumbering
    assert sorted(a.slot_blocks(1)) == list(new_ids)
    with pytest.raises(ValueError):
        a.resize_pool(1)
    # growing back works too
    a.resize_pool(10)
    a.check_invariants()
    assert a.free_count == 10 - a.used_count


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 3), st.integers(0, 32)),
                min_size=1, max_size=60))
def test_random_admit_retire_preserves_invariants(ops):
    """Property: over any admit/grow/retire sequence, no block is ever
    double-owned, frees return to the pool, and tables stay consistent."""
    a = BlockAllocator(n_blocks=12, block_size=4, n_slots=4,
                       max_blocks_per_slot=8)
    lens = [0] * 4
    for slot, n in ops:
        if n == 0:
            freed = a.release(slot)
            assert freed == blocks_for(lens[slot], 4)
            lens[slot] = 0
        else:
            n = max(lens[slot], n)      # ensure() only grows
            need = blocks_for(n, 4) - blocks_for(lens[slot], 4)
            if need > a.free_count:
                with pytest.raises(RuntimeError):
                    a.ensure(slot, n)
            else:
                a.ensure(slot, n)
                lens[slot] = n
        a.check_invariants()
        assert a.used_count == sum(blocks_for(length, 4) for length in lens)
    for s in range(4):
        a.release(s)
    a.check_invariants()
    assert a.free_count == 12


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(0, 15), min_size=1, max_size=12),
       st.integers(0, 3))
def test_alloc_after_retire_reuses_blocks(lengths, retire_every):
    """Property: serving a stream of admissions through ONE slot never
    grows the footprint past that slot's own block need — retired blocks
    are reused, not leaked."""
    a = BlockAllocator(n_blocks=6, block_size=4, n_slots=1,
                       max_blocks_per_slot=6)
    for i, n in enumerate(lengths):
        a.ensure(0, n)
        a.check_invariants()
        assert a.used_count <= blocks_for(max(lengths), 4)
        if retire_every and i % (retire_every + 1) == retire_every:
            a.release(0)
    a.release(0)
    assert a.free_count == 6
