"""Analog channel + jittable RRNS subsystem (repro.analog, §IV-B/§VII).

Covers the acceptance criteria of the subsystem PR: the jittable RRNS
decode bit-matches the frozen ``rrns_decode_np`` oracle on randomized
inputs, corrects 100% of injected single-residue errors with two redundant
moduli under ``jax.jit``, channel stages are deterministic under a fixed
PRNG key, and the ``mirage_rns_noisy`` / ``mirage_rrns`` backends are
reachable (and jittable, host-callback-free) through ``policy.mode`` alone.
"""

import importlib
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.analog import channel, device, rrns
from repro.core import gemm, noise, rns
from repro.core.precision import MiragePolicy, get_policy, special_moduli


def _rand(shape, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


BASE = list(special_moduli(5))              # 31, 32, 33
EXTRA = list(rrns.default_redundant_moduli(5))   # 37, 41
ALL = BASE + EXTRA
PSI = (int(np.prod(BASE)) - 1) // 2


def _residues(xs):
    return np.stack([np.mod(xs, m) for m in ALL]).astype(np.int32)


# --------------------------------------------------------------------------
# RRNS decode vs the frozen numpy oracle
# --------------------------------------------------------------------------

def test_default_redundant_moduli_are_coprime_primes():
    assert EXTRA == [37, 41]
    for e in EXTRA:
        for b in BASE + [x for x in EXTRA if x != e]:
            assert np.gcd(e, b) == 1


def test_rrns_decode_matches_oracle_randomized():
    """Bit-match (decoded AND corrected-mask) on a randomized mix of clean
    values, single-residue errors, and multi-residue errors."""
    rng = np.random.default_rng(0)
    xs = rng.integers(-PSI, PSI + 1, size=300)
    res = _residues(xs)
    pos = rng.integers(0, len(ALL), size=300)
    for j in range(300):
        if j % 3 == 0:
            continue                         # leave a third clean
        m = ALL[pos[j]]
        res[pos[j], j] = (res[pos[j], j] + rng.integers(1, m)) % m
        if j % 7 == 0:                       # some double errors too
            q = (pos[j] + 1) % len(ALL)
            res[q, j] = (res[q, j] + rng.integers(1, ALL[q])) % ALL[q]
    tables = rrns.build_tables(ALL, 3, PSI)
    dec, cor = jax.jit(lambda r: rrns.rrns_decode(r, tables))(jnp.asarray(res))
    dec_np, cor_np = noise.rrns_decode_np(res.astype(np.int64), ALL, 3, PSI)
    np.testing.assert_array_equal(np.asarray(dec), dec_np)
    np.testing.assert_array_equal(np.asarray(cor), cor_np)


def test_rrns_corrects_every_single_residue_error_under_jit():
    """2 redundant moduli -> 100% of single-residue errors corrected, for
    every error position and a sweep of error magnitudes, inside jit."""
    rng = np.random.default_rng(1)
    xs = rng.integers(-PSI, PSI + 1, size=64)
    tables = rrns.build_tables(ALL, 3, PSI)
    decode = jax.jit(lambda r: rrns.rrns_decode(r, tables))
    for pos in range(len(ALL)):
        res = _residues(xs)
        m = ALL[pos]
        res[pos] = (res[pos] + rng.integers(1, m, size=64)) % m
        dec, cor = decode(jnp.asarray(res))
        np.testing.assert_array_equal(np.asarray(dec), xs)
        assert bool(np.all(np.asarray(cor)))


def test_rrns_clean_residues_decode_unflagged():
    xs = np.arange(-32, 32)
    tables = rrns.build_tables(ALL, 3, PSI)
    dec, cor = rrns.rrns_decode(jnp.asarray(_residues(xs)), tables)
    np.testing.assert_array_equal(np.asarray(dec), xs)
    assert not np.any(np.asarray(cor))


def test_rrns_decode_is_vmap_safe():
    xs = np.arange(-6, 6).reshape(3, 4)
    tables = rrns.build_tables(ALL, 3, PSI)
    batched = jax.vmap(lambda r: rrns.rrns_decode(r, tables)[0], in_axes=1,
                       out_axes=0)(jnp.asarray(_residues(xs)))
    np.testing.assert_array_equal(np.asarray(batched), xs)


def test_rrns_encode_roundtrip():
    xs = jnp.asarray(np.arange(-50, 50), jnp.int32)
    res = rrns.rrns_encode(xs, ALL)
    assert res.shape == (len(ALL), 100)
    tables = rrns.build_tables(ALL, 3, PSI)
    dec, _ = rrns.rrns_decode(res, tables)
    np.testing.assert_array_equal(np.asarray(dec), np.arange(-50, 50))


def test_build_tables_rejects_non_coprime_and_overflow():
    with pytest.raises(ValueError, match="co-prime"):
        rrns.build_tables([31, 32, 33, 33 * 2], 3, PSI)
    big = special_moduli(10)                 # (2^10+1)^3 products leave int32
    with pytest.raises(ValueError, match="int32"):
        rrns.build_tables(list(big) + [1021, 1031], 3,
                          (int(np.prod(big)) - 1) // 2)


# --------------------------------------------------------------------------
# Channel stages
# --------------------------------------------------------------------------

def test_channel_default_config_is_identity():
    cfg = channel.AnalogChannelConfig()
    assert cfg.identity and not cfg.stochastic
    r = jnp.asarray(_residues(np.arange(16)))
    out = channel.apply_readout_channel(r, ALL, cfg, None)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(r))
    out = channel.apply_program_channel(r, ALL, cfg, None)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(r))


def test_channel_stages_deterministic_under_fixed_key():
    cfg = channel.AnalogChannelConfig(snr_db=38.0, phase_drift_sigma=0.4,
                                      crosstalk=0.02, adc_bits=5)
    r = jnp.asarray(np.stack(
        [np.random.default_rng(i).integers(0, m, size=(4, 8))
         for i, m in enumerate(ALL)]), jnp.int32)
    key = jax.random.PRNGKey(42)
    a = channel.apply_readout_channel(r, ALL, cfg, key)
    b = channel.apply_readout_channel(r, ALL, cfg, key)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    c = channel.apply_readout_channel(r, ALL, cfg, jax.random.PRNGKey(43))
    assert not np.array_equal(np.asarray(a), np.asarray(c))
    a = channel.apply_program_channel(r, ALL, cfg, key)
    b = channel.apply_program_channel(r, ALL, cfg, key)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_channel_outputs_stay_residues():
    cfg = channel.AnalogChannelConfig(snr_db=30.0, crosstalk=0.05,
                                      dac_bits=4, adc_bits=4)
    r = jnp.asarray(np.stack(
        [np.random.default_rng(i).integers(0, m, size=(6, 16))
         for i, m in enumerate(ALL)]), jnp.int32)
    out = np.asarray(channel.apply_readout_channel(
        r, ALL, cfg, jax.random.PRNGKey(0)))
    for i, m in enumerate(ALL):
        assert out[i].min() >= 0 and out[i].max() < m


def test_converter_quantize_exact_at_design_point():
    """ceil(log2 m) bits resolve every level -> identity (paper point)."""
    r = jnp.asarray(_residues(np.arange(64)))
    out = channel.converter_quantize(r, ALL, 6)     # 2^6 = 64 >= 41
    np.testing.assert_array_equal(np.asarray(out), np.asarray(r))
    coarse = np.asarray(channel.converter_quantize(r, ALL, 3))
    assert not np.array_equal(coarse, np.asarray(r))
    for i, m in enumerate(ALL):
        assert len(np.unique(coarse[i])) <= 8


def test_detector_sigma_matches_snr_requirement():
    """At the §IV-B1 requirement SNR (20 log10 m) the sigma is one level."""
    for m in ALL:
        s = channel.detector_sigma_levels(m, device.snr_requirement_db(m))
        assert abs(s - 1.0) < 1e-9


def test_crosstalk_single_group_is_identity():
    r = jnp.asarray(_residues(np.arange(8))).reshape(len(ALL), 1, 8)
    out = channel.crosstalk_mix(r, ALL, 0.1, group_axis=1)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(r))


def test_receiver_snr_model_monotone_and_invertible():
    p1 = device.receiver_power_for_snr_w(30.0)
    p2 = device.receiver_power_for_snr_w(40.0)
    assert p2 > p1 > 0
    assert abs(device.receiver_snr_db(p2) - 40.0) < 0.1


def test_legacy_noise_sigma_maps_to_flat_channel():
    p = get_policy("mirage_rns_noisy", noise_sigma=1.5)
    cfg = channel.AnalogChannelConfig.from_policy(p)
    assert cfg.stochastic and cfg.snr_db is None
    assert cfg.detector_sigmas(BASE) == (1.5, 1.5, 1.5)


# --------------------------------------------------------------------------
# Backends: reachable, jittable, corrected
# --------------------------------------------------------------------------

def test_noiseless_rrns_backend_bit_matches_mirage_rns():
    x, w = _rand((4, 64), 1), _rand((64, 6), 2)
    a = gemm.mirage_matmul_nograd(x, w, get_policy("mirage_rns"))
    b = gemm.mirage_matmul_nograd(x, w, get_policy("mirage_rrns"))
    c = gemm.mirage_matmul_nograd(x, w, get_policy("mirage_rns_noisy"))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(c))


def test_rrns_backend_runs_fully_jitted_and_recovers_accuracy():
    """The acceptance bar: at an SNR where the uncorrected path corrupts a
    sizable fraction of outputs, the jitted RRNS backend drives corruption
    and error down by a large factor — with no host callbacks."""
    x, w = _rand((8, 128), 3), _rand((128, 8), 4)
    ref = np.asarray(gemm.mirage_matmul_nograd(x, w, get_policy("mirage_rns")))
    key = jax.random.PRNGKey(0)
    outs = {}
    for mode in ("mirage_rns_noisy", "mirage_rrns"):
        p = get_policy(mode, snr_db=45.0)
        f = jax.jit(lambda x, w, p=p: gemm.mirage_matmul_nograd(
            x, w, p, key=key))
        lowered = f.lower(x, w).as_text()
        assert "callback" not in lowered.lower()   # no host round-trips
        outs[mode] = np.asarray(f(x, w))
    tol = 1e-6 * np.abs(ref).max()
    frac_noisy = np.mean(np.abs(outs["mirage_rns_noisy"] - ref) > tol)
    frac_rrns = np.mean(np.abs(outs["mirage_rrns"] - ref) > tol)
    assert frac_noisy > 0.05                 # channel visibly corrupts
    assert frac_rrns < frac_noisy / 2        # correction removes most of it


def test_noisy_backend_requires_key_or_seed():
    x, w = _rand((4, 64), 5), _rand((64, 4), 6)
    with pytest.raises(ValueError, match="noise_seed"):
        gemm.mirage_matmul_nograd(x, w, get_policy("mirage_rrns", snr_db=40.0))


def test_noise_seed_gives_keyless_deterministic_noise():
    """policy.noise_seed makes the stochastic channel reachable from keyless
    call sites (jitted trainer/serving) with a static error pattern."""
    x, w = _rand((4, 64), 7), _rand((64, 4), 8)
    p = get_policy("mirage_rns_noisy", snr_db=40.0, noise_seed=11)
    a = np.asarray(gemm.mirage_matmul_nograd(x, w, p))
    b = np.asarray(gemm.mirage_matmul_nograd(x, w, p))
    np.testing.assert_array_equal(a, b)
    clean = np.asarray(gemm.mirage_matmul_nograd(x, w, get_policy("mirage_rns")))
    assert not np.array_equal(a, clean)
    p2 = p.replace(noise_seed=12)
    c = np.asarray(gemm.mirage_matmul_nograd(x, w, p2))
    assert not np.array_equal(a, c)


def test_noisy_backend_trains_through_custom_vjp():
    """Gradients flow through the analog backends via noise_seed (the
    trainer path: mirage_matmul takes no key)."""
    x, w = _rand((4, 32), 9), _rand((32, 4), 10)
    p = get_policy("mirage_rrns", snr_db=50.0, noise_seed=0)

    def loss(xx, ww):
        return jnp.sum(gemm.mirage_matmul(xx, ww, p) ** 2)

    gx, gw = jax.jit(jax.grad(loss, argnums=(0, 1)))(x, w)
    assert gx.shape == x.shape and gw.shape == w.shape
    assert np.isfinite(np.asarray(gx)).all()
    assert np.isfinite(np.asarray(gw)).all()


def test_explicit_redundant_moduli_respected():
    x, w = _rand((4, 64), 13), _rand((64, 4), 14)
    p = get_policy("mirage_rrns", redundant_moduli=(43, 47))
    out = gemm.mirage_matmul_nograd(x, w, p)
    ref = gemm.mirage_matmul_nograd(x, w, get_policy("mirage_rns"))
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_undersized_redundant_moduli_rejected():
    """Redundant moduli below the base set shrink some subset ranges past
    the legal interval: clean values would alias to wrong legal decodes, so
    build_tables refuses (classic RRNS m_redundant >= m_base requirement)."""
    with pytest.raises(ValueError, match="redundant moduli"):
        rrns.build_tables(BASE + [29, 37], 3, PSI)
    x, w = _rand((4, 64), 15), _rand((64, 4), 16)
    with pytest.raises(ValueError, match="redundant moduli"):
        gemm.mirage_matmul_nograd(
            x, w, get_policy("mirage_rrns", redundant_moduli=(29, 37)))


# --------------------------------------------------------------------------
# grouped.py env overrides (satellite)
# --------------------------------------------------------------------------

def test_grouped_env_overrides():
    from repro.core.backends import grouped
    default_budget = grouped.VECTORIZE_BUDGET_BYTES
    default_block = grouped.DEFAULT_GROUP_BLOCK
    os.environ["MIRAGE_VECTORIZE_BUDGET_BYTES"] = "1234"
    os.environ["MIRAGE_SCAN_BLOCK"] = "3"
    try:
        importlib.reload(grouped)
        assert grouped.VECTORIZE_BUDGET_BYTES == 1234
        assert grouped.DEFAULT_GROUP_BLOCK == 3
        os.environ["MIRAGE_SCAN_BLOCK"] = "not_an_int"
        importlib.reload(grouped)
        assert grouped.DEFAULT_GROUP_BLOCK == default_block  # malformed -> default
    finally:
        os.environ.pop("MIRAGE_VECTORIZE_BUDGET_BYTES", None)
        os.environ.pop("MIRAGE_SCAN_BLOCK", None)
        importlib.reload(grouped)
        assert grouped.VECTORIZE_BUDGET_BYTES == default_budget
        assert grouped.DEFAULT_GROUP_BLOCK == default_block


# --------------------------------------------------------------------------
# Policy surface
# --------------------------------------------------------------------------

def test_new_modes_resolve_via_registry():
    from repro.core import backends
    for mode in ("mirage_rns_noisy", "mirage_rrns"):
        b = backends.get_backend(mode)
        assert b.supports_noise
        assert MiragePolicy(mode=mode).mode == mode


def test_sweep_rows_are_machine_readable():
    from repro.analog import sweep
    rows = sweep.gemm_error_sweep(snr_dbs=(50.0,), shape=(8, 64, 8))
    assert {r["mode"] for r in rows} == set(sweep.NOISY_MODES)
    for r in rows:
        assert set(r) >= {"section", "mode", "snr_db", "rel_fro_err",
                          "corrupt_frac"}
        assert np.isfinite(r["rel_fro_err"])
