"""Continuous-batching serving engine: scheduler semantics (FCFS admission,
EOS vs max-token retirement, slot reuse), token-exact parity of the batched
engine vs the retained per-slot oracle, stacked-cache helpers, per-tick
noise-key plumbing, elastic slot resize, and stacked-layout shardings."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core import gemm
from repro.core.precision import get_policy
from repro.models import build_model
from repro.models import lm as lm_helpers
from repro.models.lm import LMCallOptions
from repro.runtime.server import (LMServer, PerSlotLMServer, Request,
                                  Scheduler, default_buckets, pick_bucket)


@pytest.fixture(scope="module")
def served():
    cfg = get_config("qwen2-0.5b").reduced()
    model = build_model(cfg, get_policy("mirage"),
                        LMCallOptions(q_chunk=16, kv_chunk=16))
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _mk_requests(cfg, n, lens, max_tokens=4, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size,
                                        lens[i % len(lens)]).astype(np.int32),
                    max_tokens=max_tokens)
            for i in range(n)]


# --------------------------------------------------------------------------
# parity: batched engine vs per-slot oracle
# --------------------------------------------------------------------------

def test_batched_engine_token_exact_vs_oracle(served):
    """The acceptance gate: greedy decode through the stacked-cache engine
    (mixed prompt lengths -> mixed buckets, slot reuse) must emit exactly
    the oracle's tokens for every request."""
    cfg, model, params = served
    batched = LMServer(model, params, cap=24, batch_slots=3)
    oracle = PerSlotLMServer(model, params, cap=24, batch_slots=3)
    for server, seed in ((batched, 0), (oracle, 0)):
        for r in _mk_requests(cfg, 7, lens=[8, 11, 6], max_tokens=5,
                              seed=seed):
            server.submit(r)
    fa = {r.rid: r.tokens_out for r in batched.run_until_drained()}
    fb = {r.rid: r.tokens_out for r in oracle.run_until_drained()}
    assert set(fa) == set(fb) == set(range(7))
    assert fa == fb


@pytest.mark.parametrize("arch", ["mixtral-8x7b", "mamba2-2.7b",
                                  "zamba2-2.7b"])
def test_parity_across_families(arch):
    """SWA ring masks (mixtral), exact-length SSM bucketing (mamba2) and
    the vector-idx hybrid shared-attention decode (zamba2) all stay
    token-identical to the oracle."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg, get_policy("mirage"),
                        LMCallOptions(q_chunk=16, kv_chunk=16))
    params = model.init(jax.random.PRNGKey(0))
    batched = LMServer(model, params, cap=20, batch_slots=2)
    oracle = PerSlotLMServer(model, params, cap=20, batch_slots=2)
    for server in (batched, oracle):
        for r in _mk_requests(cfg, 3, lens=[6, 9], max_tokens=3, seed=2):
            server.submit(r)
    fa = {r.rid: r.tokens_out for r in batched.run_until_drained()}
    fb = {r.rid: r.tokens_out for r in oracle.run_until_drained()}
    assert fa == fb and len(fa) == 3


def test_batched_engine_fewer_ticks_than_oracle(served):
    """Occupancy batches into ONE decode per tick: serving n requests on n
    slots takes ~max_tokens ticks, not n * max_tokens."""
    cfg, model, params = served
    server = LMServer(model, params, cap=24, batch_slots=3)
    for r in _mk_requests(cfg, 3, lens=[8], max_tokens=6):
        server.submit(r)
    server.run_until_drained()
    assert server.metrics["completed"] == 3
    # 3 requests x 6 tokens = 18 tokens; 1 prefill + 5 decode ticks
    assert server.metrics["ticks"] <= 7


# --------------------------------------------------------------------------
# scheduler semantics
# --------------------------------------------------------------------------

def test_admission_order_is_fcfs(served):
    cfg, model, params = served
    server = LMServer(model, params, cap=24, batch_slots=1)
    reqs = _mk_requests(cfg, 4, lens=[8], max_tokens=3)
    for r in reqs:
        server.submit(r)
    finished = server.run_until_drained()
    # single slot: strict FCFS completion order, monotone admission stamps
    assert [r.rid for r in finished] == [0, 1, 2, 3]
    admits = [r.t_admit for r in finished]
    assert admits == sorted(admits)
    assert all(r.t_admit >= r.t_enqueue for r in finished)


def test_eos_vs_max_token_retirement(served):
    cfg, model, params = served
    [probe] = _mk_requests(cfg, 1, lens=[8], max_tokens=6, seed=3)
    s0 = LMServer(model, params, cap=24, batch_slots=1)
    s0.submit(probe)
    [r0] = s0.run_until_drained()
    eos = r0.tokens_out[2]          # a token the model WILL emit at step 2

    s1 = LMServer(model, params, cap=24, batch_slots=2)
    [req_eos] = _mk_requests(cfg, 1, lens=[8], max_tokens=20, seed=3)
    req_eos.eos_id = eos
    [req_max] = _mk_requests(cfg, 1, lens=[8], max_tokens=4, seed=4)
    req_max.rid = 1
    s1.submit(req_eos)
    s1.submit(req_max)
    done = {r.rid: r for r in s1.run_until_drained()}
    # EOS retirement: stops at the eos token, well before max_tokens
    assert done[0].tokens_out[-1] == eos
    assert len(done[0].tokens_out) < 20
    # max-token retirement: exactly the budget
    assert len(done[1].tokens_out) == 4


@pytest.mark.parametrize("engine", [LMServer, PerSlotLMServer])
def test_retire_at_admission(served, engine):
    """A request whose prefill token is already EOS, or whose budget is one
    token, retires at admission with exactly one emitted token — it never
    occupies a decode slot."""
    cfg, model, params = served
    [probe] = _mk_requests(cfg, 1, lens=[8], max_tokens=2, seed=11)
    s0 = LMServer(model, params, cap=24, batch_slots=1)
    s0.submit(probe)
    [r0] = s0.run_until_drained()
    first = r0.tokens_out[0]

    server = engine(model, params, cap=24, batch_slots=1)
    [req_eos] = _mk_requests(cfg, 1, lens=[8], max_tokens=20, seed=11)
    req_eos.eos_id = first
    [req_one] = _mk_requests(cfg, 1, lens=[8], max_tokens=1, seed=12)
    req_one.rid = 1
    server.submit(req_eos)
    server.submit(req_one)
    done = {r.rid: r for r in server.run_until_drained()}
    assert done[0].tokens_out == [first]
    assert len(done[1].tokens_out) == 1
    assert server.metrics["completed"] == 2


def test_slot_reuse_after_retire(served):
    cfg, model, params = served
    server = LMServer(model, params, cap=24, batch_slots=2)
    admitted_slots = []
    orig_admit = server._admit

    def spy_admit():
        before = list(server.slot_req)
        retired = orig_admit()
        for i, (a, b) in enumerate(zip(before, server.slot_req)):
            if a is None and b is not None:
                admitted_slots.append((b.rid, i))
        return retired
    server._admit = spy_admit
    for r in _mk_requests(cfg, 5, lens=[8], max_tokens=3):
        server.submit(r)
    finished = server.run_until_drained()
    assert len(finished) == 5
    assert server.metrics["completed"] == 5
    assert all(r is None for r in server.slot_req)
    # with 5 requests over 2 slots, some slot served >= 2 requests
    slots_used = [s for _, s in admitted_slots]
    assert max(np.bincount(slots_used)) >= 2


def test_streaming_callback_and_latency_metrics(served):
    cfg, model, params = served
    streamed = []
    server = LMServer(model, params, cap=24, batch_slots=2,
                      on_token=lambda req, tok: streamed.append((req.rid, tok)))
    for r in _mk_requests(cfg, 3, lens=[8], max_tokens=4):
        server.submit(r)
    finished = server.run_until_drained()
    per_rid = {r.rid: [t for rid, t in streamed if rid == r.rid]
               for r in finished}
    for r in finished:
        assert per_rid[r.rid] == r.tokens_out
        assert r.t_enqueue <= r.t_admit <= r.t_first_token <= r.t_done
        assert r.ttft >= 0 and r.tpot >= 0 and r.queue_time >= 0
    lat = server.scheduler.latency_summary()
    assert lat["ttft_mean_s"] > 0


def test_scheduler_component_is_deque_fcfs():
    sched = Scheduler()
    import collections
    assert isinstance(sched.waiting, collections.deque)
    for i in range(5):
        sched.submit(Request(rid=i, prompt=np.zeros(4, np.int32)))
    taken = sched.take(3)
    assert [r.rid for r in taken] == [0, 1, 2]
    assert [r.rid for r in sched.waiting] == [3, 4]


def test_bucketing():
    assert default_buckets(64, min_bucket=8) == (8, 16, 32, 64)
    assert pick_bucket(5, (8, 16)) == 8
    assert pick_bucket(9, (8, 16)) == 16
    with pytest.raises(ValueError):
        pick_bucket(17, (8, 16))


def test_overlong_prompt_rejected(served):
    cfg, model, params = served
    server = LMServer(model, params, cap=24, batch_slots=1)
    with pytest.raises(ValueError):
        server.submit(Request(rid=0, prompt=np.zeros(100, np.int32)))


# --------------------------------------------------------------------------
# stacked-cache helpers + elastic resize
# --------------------------------------------------------------------------

def test_cache_insert_extract_roundtrip(served):
    cfg, model, params = served
    rng = np.random.default_rng(0)
    live = model.init_cache(4, 24, per_slot_idx=True)
    new = {k: jnp.asarray(rng.normal(size=v.shape).astype(np.float32))
           if k != "idx" else jnp.asarray([3, 7], jnp.int32)
           for k, v in model.init_cache(2, 24, per_slot_idx=True).items()}
    inserted = lm_helpers.cache_insert(live, new, jnp.asarray([2, 0]))
    back = lm_helpers.cache_extract(inserted, [2, 0])
    for k in new:
        np.testing.assert_array_equal(np.asarray(back[k]),
                                      np.asarray(new[k]), err_msg=k)
    # untouched slots stay zero
    assert float(jnp.abs(inserted["k"][:, 1]).sum()) == 0.0
    # out-of-bounds sentinel rows are dropped, not wrapped
    dropped = lm_helpers.cache_insert(live, new, jnp.asarray([4, 1]))
    np.testing.assert_array_equal(np.asarray(dropped["k"][:, 1]),
                                  np.asarray(new["k"][:, 1]))
    assert float(jnp.abs(dropped["k"][:, [0, 2, 3]]).sum()) == 0.0


def test_resize_slots_preserves_tokens(served):
    cfg, model, params = served
    reqs = lambda: _mk_requests(cfg, 5, lens=[8], max_tokens=5, seed=9)
    grown = LMServer(model, params, cap=24, batch_slots=2)
    for r in reqs():
        grown.submit(r)
    grown.tick()
    grown.tick()
    grown.resize_slots(3)
    fa = {r.rid: r.tokens_out for r in grown.run_until_drained()}
    fixed = LMServer(model, params, cap=24, batch_slots=3)
    for r in reqs():
        fixed.submit(r)
    fb = {r.rid: r.tokens_out for r in fixed.run_until_drained()}
    assert len(fa) == 5
    # greedy decode is deterministic: the in-flight slots carried across the
    # resize must keep emitting exactly their original continuations
    assert fa == fb


# --------------------------------------------------------------------------
# per-tick noise keys (noisy / RRNS serving)
# --------------------------------------------------------------------------

def test_noise_key_scope_feeds_stochastic_backends():
    from repro.core.gemm import mirage_matmul_nograd

    policy = get_policy("mirage_rns_noisy", snr_db=20.0)
    x = np.asarray(np.random.default_rng(0).normal(size=(4, 32)), np.float32)
    w = np.asarray(np.random.default_rng(1).normal(size=(32, 8)), np.float32)
    key = jax.random.PRNGKey(0)
    with gemm.noise_key_scope(key):
        a1 = mirage_matmul_nograd(x, w, policy)
        a2 = mirage_matmul_nograd(x, w, policy)
    # consecutive calls under one scope draw DIFFERENT subkeys
    assert not np.allclose(np.asarray(a1), np.asarray(a2))
    # reopening the same scope replays the same subkey sequence
    with gemm.noise_key_scope(key):
        b1 = mirage_matmul_nograd(x, w, policy)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(b1))
    # no scope + no seed -> the existing loud error
    with pytest.raises(ValueError, match="randomness"):
        mirage_matmul_nograd(x, w, policy)


def test_layer_noise_independent_inside_scan():
    """The per-call-site counter is a trace-time constant: without
    fold_noise_scope every iteration of a layer scan would reuse one noise
    draw per GEMM site. The model's layer scans fold the traced index."""
    from repro.core.gemm import mirage_matmul_nograd

    policy = get_policy("mirage_rns_noisy", snr_db=20.0)
    x = jnp.asarray(np.random.default_rng(0).normal(size=(4, 32)),
                    jnp.float32)
    w = jnp.asarray(np.random.default_rng(1).normal(size=(32, 8)),
                    jnp.float32)

    @jax.jit
    def scanned(key):
        with gemm.noise_key_scope(key):
            def body(c, i):
                with gemm.fold_noise_scope(i):
                    return c, mirage_matmul_nograd(x, w, policy)
            _, ys = jax.lax.scan(body, 0, jnp.arange(3))
        return ys

    ys = np.asarray(scanned(jax.random.PRNGKey(0)))
    assert not np.allclose(ys[0], ys[1])
    assert not np.allclose(ys[1], ys[2])


def test_tick_keys_are_fresh_per_tick(served):
    cfg, model, params = served
    server = LMServer(model, params, cap=24, batch_slots=2)
    k0, s0 = server._next_keys(0, 0)
    k1, s1 = server._next_keys(0, 1)
    kp, sp = server._next_keys(1, 0)   # prefill stream is distinct
    assert not np.array_equal(np.asarray(k0), np.asarray(k1))
    assert not np.array_equal(np.asarray(k0), np.asarray(kp))
    assert not np.array_equal(np.asarray(s0), np.asarray(s1))


def test_noisy_serving_deterministic_per_seed():
    """Same policy.noise_seed => identical served tokens (fresh noise per
    tick is folded from the seed + tick counter, not wall-clock state)."""
    cfg = get_config("qwen2-0.5b").reduced()
    policy = get_policy("mirage_rns_noisy", snr_db=28.0, noise_seed=7)
    model = build_model(cfg, policy, LMCallOptions(q_chunk=16, kv_chunk=16))
    params = model.init(jax.random.PRNGKey(0))

    def serve_once():
        server = LMServer(model, params, cap=20, batch_slots=2)
        for r in _mk_requests(cfg, 2, lens=[6], max_tokens=3, seed=5):
            server.submit(r)
        return {r.rid: tuple(r.tokens_out)
                for r in server.run_until_drained()}

    assert serve_once() == serve_once()


# --------------------------------------------------------------------------
# stacked-layout shardings
# --------------------------------------------------------------------------

def test_serve_state_shardings_cover_engine_state(served):
    from jax.sharding import Mesh, NamedSharding

    from repro.parallel.sharding import serve_state_shardings

    cfg, model, params = served
    server = LMServer(model, params, cap=24, batch_slots=2)
    mesh = Mesh(np.array(jax.devices()[:1]).reshape(1, 1), ("data", "model"))
    shardings = serve_state_shardings(mesh, cfg, server.state)
    flat, _ = jax.tree_util.tree_flatten(shardings)
    assert flat and all(isinstance(s, NamedSharding) for s in flat)
    # per-slot idx vector gets a (replicated-or-dp) rank-1-compatible spec
    jax.device_put(server.state, shardings)
