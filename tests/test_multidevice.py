"""Multi-device semantics, via subprocesses with forced host devices (so the
main pytest process keeps its single-device view).

Covers: sharded-vs-single-device training equivalence, sharding-rule
divisibility fallbacks, elastic checkpoint restore across meshes, and the
mesh factory itself.
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(py_src: str, n_dev: int = 4, timeout: int = 600) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", py_src], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_sharded_training_matches_single_device():
    """One train step on a 2x2 mesh == the same step on 1 device."""
    src = textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
        from repro.configs import get_config
        from repro.configs.base import TrainConfig
        from repro.core.precision import get_policy
        from repro.models import build_model
        from repro.models.lm import LMCallOptions
        from repro.parallel import sharding as sh
        from repro.runtime.trainer import init_train_state, make_train_step
        from repro.launch.mesh import make_debug_mesh

        cfg = get_config("qwen2-0.5b").reduced()
        tc = TrainConfig(policy=get_policy("mirage"), lr=1e-3)
        model = build_model(cfg, get_policy("mirage"),
                            LMCallOptions(q_chunk=16, kv_chunk=16))
        state = init_train_state(model, tc, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)),
                                       jnp.int32),
                 "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (4, 32)),
                                       jnp.int32)}
        step = make_train_step(model, tc)

        # single device
        s1, m1 = jax.jit(step)(state, batch)

        # 2x2 mesh with the production sharding rules
        mesh = make_debug_mesh(2, 2)
        state_sh = sh.train_state_shardings(mesh, cfg, state)
        batch_sh = sh.batch_shardings(mesh, cfg, batch)
        with mesh:
            s2, m2 = jax.jit(step, in_shardings=(state_sh, batch_sh),
                             out_shardings=(state_sh, None))(state, batch)
        d = max(float(jnp.abs(a - b).max()) for a, b in zip(
            jax.tree_util.tree_leaves(s1["params"]),
            jax.tree_util.tree_leaves(s2["params"])))
        loss_diff = abs(float(m1["loss"]) - float(m2["loss"]))
        print("PARAM_DIFF", d, "LOSS_DIFF", loss_diff)
        assert d < 5e-5, d
        assert loss_diff < 1e-5, loss_diff
    """)
    out = _run(src, n_dev=4)
    assert "PARAM_DIFF" in out


def test_decode_step_sharded_matches_single():
    src = textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.core.precision import get_policy
        from repro.models import build_model
        from repro.models.lm import LMCallOptions
        from repro.parallel import sharding as sh
        from repro.launch.mesh import make_debug_mesh

        cfg = get_config("mixtral-8x7b").reduced()
        model = build_model(cfg, get_policy("mirage"),
                            LMCallOptions(q_chunk=16, kv_chunk=16))
        params = model.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(1)
        toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 12)), jnp.int32)
        logits, cache = jax.jit(lambda p, t: model.prefill(p, t, 16))(params, toks)
        nxt = jnp.argmax(logits[:, -1:, :], axis=-1).astype(jnp.int32)
        l1, _ = jax.jit(model.decode_step)(params, cache, nxt)

        mesh = make_debug_mesh(2, 2)
        p_sh = sh.param_shardings(mesh, cfg, params)
        c_sh = sh.batch_shardings(mesh, cfg, cache)
        with mesh:
            l2, _ = jax.jit(model.decode_step,
                            in_shardings=(p_sh, c_sh, None))(params, cache, nxt)
        diff = float(jnp.abs(l1 - l2).max())
        print("LOGIT_DIFF", diff)
        assert diff < 5e-4, diff
    """)
    out = _run(src, n_dev=4)
    assert "LOGIT_DIFF" in out


def test_elastic_restore_across_meshes(tmp_path):
    """Checkpoint written under a 2x2 mesh restores onto 1x4 and 1x1."""
    src = textwrap.dedent(f"""
        import numpy as np, jax, jax.numpy as jnp
        from repro.checkpoint.checkpointer import Checkpointer
        from repro.configs import get_config
        from repro.configs.base import TrainConfig
        from repro.core.precision import get_policy
        from repro.models import build_model
        from repro.models.lm import LMCallOptions
        from repro.parallel import sharding as sh
        from repro.runtime.trainer import init_train_state
        from repro.launch.mesh import make_debug_mesh

        cfg = get_config("qwen2-0.5b").reduced()
        tc = TrainConfig(policy=get_policy("mirage"))
        model = build_model(cfg, get_policy("mirage"),
                            LMCallOptions(q_chunk=16, kv_chunk=16))
        state = init_train_state(model, tc, jax.random.PRNGKey(0))

        mesh_a = make_debug_mesh(2, 2)
        sh_a = sh.train_state_shardings(mesh_a, cfg, state)
        state_a = jax.tree_util.tree_map(jax.device_put, state, sh_a)
        ck = Checkpointer({str(tmp_path)!r})
        ck.save(state_a, step=1)

        mesh_b = make_debug_mesh(1, 4)   # "elastic" new topology
        sh_b = sh.train_state_shardings(mesh_b, cfg, state)
        restored, _ = ck.restore(state, shardings=sh_b)
        d = max(float(jnp.abs(a - b).max()) for a, b in zip(
            jax.tree_util.tree_leaves(state["params"]),
            jax.tree_util.tree_leaves(restored["params"])))
        print("ELASTIC_DIFF", d)
        assert d == 0.0
    """)
    out = _run(src, n_dev=4)
    assert "ELASTIC_DIFF 0.0" in out


def test_production_mesh_shapes():
    src = textwrap.dedent("""
        import jax
        from repro.launch.mesh import make_production_mesh
        m1 = make_production_mesh()
        m2 = make_production_mesh(multi_pod=True)
        print("SINGLE", m1.shape, "MULTI", m2.shape)
        assert dict(m1.shape) == {"data": 16, "model": 16}
        assert dict(m2.shape) == {"pod": 2, "data": 16, "model": 16}
        assert m1.size == 256 and m2.size == 512
    """)
    out = _run(src, n_dev=512, timeout=300)
    assert "SINGLE" in out


def test_param_spec_divisibility_fallback():
    """Sharding rules must degrade to replication on non-divisible dims."""
    src = textwrap.dedent("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.configs import get_config
        from repro.parallel import sharding as sh
        from repro.launch.mesh import make_debug_mesh

        mesh = make_debug_mesh(2, 2)
        cfg = get_config("qwen2-0.5b")
        # 14 heads * 64 = 896 divisible by 2 -> tp applies on flat dim
        spec = sh.param_spec(mesh, cfg, "layers/attn/q/w", (24, 896, 896))
        assert spec == P(None, "data", "model"), spec
        # odd vocab (92553) must fall back to None on that dim
        cfg2 = get_config("internvl2-2b")
        spec2 = sh.param_spec(mesh, cfg2, "lm_head/w", (2048, 92553))
        assert spec2 == P("data", None), spec2
        # moduli-style tiny leaves replicate
        spec3 = sh.param_spec(mesh, cfg, "layers/mamba/A_log", (24, 80))
        assert spec3 == P(None, None), spec3
        print("SPECS_OK")
    """)
    out = _run(src, n_dev=4)
    assert "SPECS_OK" in out
