"""Per-kernel allclose tests: Pallas (interpret=True) vs pure-jnp oracles,
swept over shapes, dtypes, and block configurations."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.precision import special_moduli
from repro.kernels import ref
from repro.kernels.bfp_quantize import bfp_fake_quant_pallas
from repro.kernels.mirage_gemm import mirage_gemm_pallas
from repro.kernels.rns_matmul import rns_matmul_pallas


def _rand(shape, seed=0, dtype=np.float32, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray((rng.normal(size=shape) * scale).astype(dtype))


# --------------------------------------------------------------------------
# bfp_quantize
# --------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(4, 16), (3, 37), (2, 5, 64), (1, 1), (7, 200)])
@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_bfp_quant_kernel_matches_ref(shape, dtype):
    x = _rand(shape, seed=hash(shape) % 2**31, dtype=dtype)
    got = bfp_fake_quant_pallas(x, b_m=4, g=16, interpret=True)
    want = ref.bfp_fake_quant_ref(x, b_m=4, g=16)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("b_m,g", [(3, 8), (4, 16), (5, 32), (6, 16)])
def test_bfp_quant_kernel_bm_g_sweep(b_m, g):
    x = _rand((9, 3 * g + 5), seed=b_m * 10 + g)
    got = bfp_fake_quant_pallas(x, b_m=b_m, g=g, interpret=True)
    want = ref.bfp_fake_quant_ref(x, b_m=b_m, g=g)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("rounding", ["nearest", "truncate"])
def test_bfp_quant_kernel_rounding(rounding):
    x = _rand((8, 64), seed=3)
    got = bfp_fake_quant_pallas(x, rounding=rounding, interpret=True)
    want = ref.bfp_fake_quant_ref(x, rounding=rounding)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_bfp_quant_kernel_blocking_invariance():
    """Different block shapes must not change results (groups are intact)."""
    x = _rand((70, 300), seed=4)
    a = bfp_fake_quant_pallas(x, block_rows=16, block_cols=64, interpret=True)
    b = bfp_fake_quant_pallas(x, block_rows=256, block_cols=512, interpret=True)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_bfp_quant_kernel_extreme_values():
    x = jnp.asarray([[1e30, 1e-30, 0.0, -1e30] * 4, [65504.0, -2.0, 3e-8, 1.0] * 4],
                    jnp.float32)
    got = bfp_fake_quant_pallas(x, interpret=True)
    want = ref.bfp_fake_quant_ref(x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-7)


# --------------------------------------------------------------------------
# mirage_gemm (fused)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("mkn", [(4, 16, 4), (7, 37, 9), (32, 128, 16),
                                 (1, 1, 1), (130, 257, 66)])
def test_mirage_gemm_kernel_matches_ref(mkn):
    m, k, n = mkn
    x = _rand((m, k), seed=m * 100 + k)
    w = _rand((k, n), seed=n * 100 + k)
    got = mirage_gemm_pallas(x, w, interpret=True)
    want = ref.mirage_gemm_ref(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-5)


@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_mirage_gemm_kernel_dtypes(dtype):
    x = _rand((8, 64), seed=11, dtype=dtype)
    w = _rand((64, 8), seed=12, dtype=dtype)
    got = mirage_gemm_pallas(x, w, interpret=True)
    want = ref.mirage_gemm_ref(x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-5)


def test_mirage_gemm_kernel_batched_input():
    x = _rand((2, 3, 48), seed=13)
    w = _rand((48, 5), seed=14)
    got = mirage_gemm_pallas(x, w, interpret=True)
    assert got.shape == (2, 3, 5)
    want = ref.mirage_gemm_ref(x.reshape(-1, 48), w).reshape(2, 3, 5)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


def test_mirage_gemm_kernel_block_sweep():
    x = _rand((50, 200), seed=15)
    w = _rand((200, 30), seed=16)
    outs = []
    for bm_, bn, bk in [(16, 16, 32), (128, 128, 512), (32, 8, 16), (64, 32, 64)]:
        outs.append(np.asarray(mirage_gemm_pallas(
            x, w, block_m=bm_, block_n=bn, block_k=bk, interpret=True)))
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-5, atol=1e-5)


def test_mirage_gemm_kernel_matches_core_fast_path():
    """The fused kernel and core.gemm mirage_fast agree (same numerics)."""
    from repro.core import gemm
    from repro.core.precision import get_policy
    x = _rand((12, 96), seed=17)
    w = _rand((96, 12), seed=18)
    got = np.asarray(mirage_gemm_pallas(x, w, interpret=True))
    want = np.asarray(gemm.mirage_matmul_nograd(x, w, get_policy("mirage")))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


# --------------------------------------------------------------------------
# rns_matmul
# --------------------------------------------------------------------------

@pytest.mark.parametrize("k", [4, 5, 6])
@pytest.mark.parametrize("mkn", [(4, 16, 4), (9, 33, 7), (32, 64, 16)])
def test_rns_matmul_kernel_matches_ref(k, mkn):
    m, kk, n = mkn
    moduli = special_moduli(k)
    rng = np.random.default_rng(k * 1000 + m)
    xr = jnp.asarray(np.stack([rng.integers(0, mm, size=(m, kk)) for mm in moduli]),
                     jnp.int32)
    wr = jnp.asarray(np.stack([rng.integers(0, mm, size=(kk, n)) for mm in moduli]),
                     jnp.int32)
    got = rns_matmul_pallas(xr, wr, moduli, interpret=True)
    want = ref.rns_matmul_ref(xr, wr, moduli)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_rns_matmul_kernel_block_accumulation():
    """K larger than block_k exercises the modular block accumulation."""
    k = 5
    moduli = special_moduli(k)
    rng = np.random.default_rng(77)
    xr = jnp.asarray(np.stack([rng.integers(0, mm, size=(8, 1024)) for mm in moduli]),
                     jnp.int32)
    wr = jnp.asarray(np.stack([rng.integers(0, mm, size=(1024, 8)) for mm in moduli]),
                     jnp.int32)
    got = rns_matmul_pallas(xr, wr, moduli, block_k=64, interpret=True)
    want = ref.rns_matmul_ref(xr, wr, moduli)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_rns_matmul_kernel_end_to_end_crt():
    """Kernel residue GEMM + CRT == exact integer GEMM (hardware claim)."""
    from repro.core import rns as rns_mod
    k = 5
    qmax = 15
    rng = np.random.default_rng(5)
    x = rng.integers(-qmax, qmax + 1, size=(6, 16)).astype(np.float32)
    w = rng.integers(-qmax, qmax + 1, size=(16, 6)).astype(np.float32)
    xr = rns_mod.to_rns_special(jnp.asarray(x), k)
    wr = rns_mod.to_rns_special(jnp.asarray(w), k)
    res = rns_matmul_pallas(xr, wr, special_moduli(k), interpret=True)
    got = np.asarray(rns_mod.from_rns_special(res, k, signed=True))
    np.testing.assert_array_equal(got, (x @ w).astype(np.int64))
