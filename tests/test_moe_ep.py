"""shard_map expert-parallel MoE == GSPMD dense-dispatch MoE (dropless)."""

import os
import subprocess
import sys
import textwrap

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(py_src: str, n_dev: int = 4, timeout: int = 900) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_dev}"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", py_src], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr}"
    return out.stdout


def test_ep_shard_map_matches_gspmd():
    src = textwrap.dedent("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core.precision import get_policy
        from repro.models import moe
        from repro.models.lm import LMCallOptions
        from repro.launch.mesh import make_debug_mesh

        mesh = make_debug_mesh(2, 2)
        E, K, d, f = 8, 2, 32, 16
        rng = np.random.default_rng(0)
        p = {"router": {"w": jnp.asarray(rng.normal(size=(d, E)), jnp.float32)},
             "gate": jnp.asarray(rng.normal(size=(E, d, f)) * 0.2, jnp.float32),
             "up": jnp.asarray(rng.normal(size=(E, d, f)) * 0.2, jnp.float32),
             "down": jnp.asarray(rng.normal(size=(E, f, d)) * 0.2, jnp.float32)}
        x = jnp.asarray(rng.normal(size=(4, 8, d)), jnp.float32)
        policy = get_policy("mirage")
        opt = LMCallOptions(act_dp=("data",), act_tp="model",
                            mesh_sizes=(("data", 2), ("model", 2)))

        def dense_fn(p, x):
            return moe.moe_apply(p, x, policy, n_experts=E,
                                 experts_per_token=K, capacity_factor=8.0,
                                 opt=opt)

        def ep_fn(p, x):
            return moe.moe_apply_ep(p, x, policy, n_experts=E,
                                    experts_per_token=K, capacity_factor=8.0,
                                    opt=opt)

        with mesh:
            x_sh = NamedSharding(mesh, P("data", None, None))
            o1, a1 = jax.jit(dense_fn, in_shardings=(None, x_sh))(p, x)
            o2, a2 = jax.jit(ep_fn, in_shardings=(None, x_sh))(p, x)
        diff = float(jnp.abs(o1 - o2).max())
        adiff = abs(float(a1) - float(a2))
        print("OUT_DIFF", diff, "AUX_DIFF", adiff)
        assert diff < 1e-5, diff
        assert adiff < 1e-5, adiff

        # gradients flow through the shard_map path
        g = jax.jit(jax.grad(lambda pp, xx: jnp.sum(ep_fn(pp, xx)[0]) ,
                             argnums=0), in_shardings=(None, x_sh))
        with mesh:
            grads = g(p, x)
        gn = sum(float(jnp.sum(l**2)) for l in jax.tree_util.tree_leaves(grads))
        print("GRAD_NORM", gn)
        assert gn > 0
    """)
    out = _run(src, n_dev=4)
    assert "OUT_DIFF" in out
