"""Backend registry + group-batched execution parity tests.

The vectorized faithful/RNS backends must be bit-identical to the frozen
seed fori_loop implementations (``*_ref`` backends), the Pallas-routed RNS
backend must match the pure-jnp one exactly, and every mode string in
``GEMM_MODES`` must resolve through the registry.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import backends, gemm, rns
from repro.core.backends import grouped
from repro.core.precision import GEMM_MODES, MiragePolicy, get_policy, special_moduli
from repro.kernels.rns_matmul import rns_matmul_pallas


def _rand(shape, seed=0, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype(np.float32) * scale)


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

def test_every_gemm_mode_resolves_to_a_backend():
    for mode in GEMM_MODES:
        b = backends.get_backend(mode)
        assert b.name == mode
        assert callable(b.fn)


def test_unknown_mode_raises_with_listing():
    with pytest.raises(KeyError, match="available"):
        backends.get_backend("definitely_not_a_backend")


def test_policy_rejects_unregistered_mode():
    with pytest.raises(ValueError, match="not a registered backend"):
        MiragePolicy(mode="definitely_not_a_backend")


def test_custom_backend_registration_end_to_end():
    name = "test_only_double_fp32"

    @backends.register_fn(name, description="2 * (x @ w)", quantized=False)
    def _double(x, w, policy, *, key=None):
        return 2.0 * jnp.matmul(x, w, preferred_element_type=jnp.float32)

    try:
        p = MiragePolicy(mode=name)  # policy accepts registered custom modes
        x, w = _rand((3, 8), 1), _rand((8, 4), 2)
        out = gemm.mirage_matmul_nograd(x, w, p)
        np.testing.assert_allclose(np.asarray(out),
                                   2.0 * np.asarray(x) @ np.asarray(w),
                                   rtol=1e-6)
    finally:
        from repro.core.backends import base
        base._REGISTRY.pop(name, None)


def test_capability_flags():
    assert backends.get_backend("mirage_fast").supports_weight_stationary
    assert backends.get_backend("mirage_rns").supports_noise
    assert backends.get_backend("mirage_faithful_ref").reference
    assert not backends.get_backend("fp32").quantized


# --------------------------------------------------------------------------
# Vectorized vs seed fori_loop: bit-identical
# --------------------------------------------------------------------------

PARITY_SHAPES = [(5, 37, 9), (2, 16, 4), (7, 64, 13), (1, 1, 1), (3, 300, 17),
                 (1, 256, 64), (16, 129, 8)]


@pytest.mark.parametrize("shape", PARITY_SHAPES)
def test_faithful_vectorized_bit_identical_to_seed(shape):
    m, k, n = shape
    x, w = _rand((m, k), m * 10 + k), _rand((k, n), n * 10 + k)
    ref = gemm.mirage_matmul_nograd(x, w, get_policy("mirage_faithful_ref"))
    new = gemm.mirage_matmul_nograd(x, w, get_policy("mirage_faithful"))
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(new))


@pytest.mark.parametrize("shape", [(5, 37, 9), (2, 16, 4), (3, 300, 17)])
def test_rns_vectorized_bit_identical_to_seed(shape):
    m, k, n = shape
    x, w = _rand((m, k), m + k), _rand((k, n), n + k)
    ref = gemm.mirage_matmul_nograd(x, w, get_policy("mirage_rns_ref"))
    new = gemm.mirage_matmul_nograd(x, w, get_policy("mirage_rns"))
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(new))


def test_faithful_parity_with_batch_dims():
    x = _rand((2, 3, 5, 32), 11)
    w = _rand((32, 7), 12)
    ref = gemm.mirage_matmul_nograd(x, w, get_policy("mirage_faithful_ref"))
    new = gemm.mirage_matmul_nograd(x, w, get_policy("mirage_faithful"))
    assert new.shape == (2, 3, 5, 7)
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(new))


@pytest.mark.parametrize("group_block", [-1, 1, 3, 8])
def test_faithful_group_block_invariance(group_block):
    """Forced single-dot / scan-blocked execution agree with the default."""
    x, w = _rand((6, 160), 21), _rand((160, 12), 22)
    base = gemm.mirage_matmul_nograd(x, w, get_policy("mirage_faithful"))
    blk = gemm.mirage_matmul_nograd(
        x, w, get_policy("mirage_faithful", group_block=group_block))
    np.testing.assert_array_equal(np.asarray(base), np.asarray(blk))


@pytest.mark.parametrize("group_block", [-1, 2, 5])
def test_rns_group_block_invariance(group_block):
    """The RNS scan-over-blocks regime (memory-bounded per-block pipeline)
    agrees with the default vectorized execution."""
    x, w = _rand((4, 160), 23), _rand((160, 6), 24)
    base = gemm.mirage_matmul_nograd(x, w, get_policy("mirage_rns"))
    blk = gemm.mirage_matmul_nograd(
        x, w, get_policy("mirage_rns", group_block=group_block))
    np.testing.assert_array_equal(np.asarray(base), np.asarray(blk))


def test_faithful_scan_regime_matches_seed():
    """Shapes past the vectorize budget take the scan-over-blocks path."""
    x, w = _rand((4, 640), 31), _rand((640, 8), 32)
    ref = gemm.mirage_matmul_nograd(x, w, get_policy("mirage_faithful_ref"))
    blk = gemm.mirage_matmul_nograd(
        x, w, get_policy("mirage_faithful", group_block=4))
    np.testing.assert_array_equal(np.asarray(ref), np.asarray(blk))


def test_faithful_adversarial_dynamic_range_allclose():
    """With per-group gains spanning 2^+-20 the cross-group f32 accumulation
    association can differ from the seed's left-to-right fold (partial sums
    leave the exact window). Values must still agree to f32 roundoff."""
    rng = np.random.default_rng(7)
    m, k, n = 8, 256, 8
    gains = 2.0 ** rng.integers(-20, 20, size=(1, k // 16)).repeat(16, axis=1)
    x = jnp.asarray((rng.normal(size=(m, k)) * gains).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    ref = np.asarray(gemm.mirage_matmul_nograd(x, w, get_policy("mirage_faithful_ref")))
    new = np.asarray(gemm.mirage_matmul_nograd(x, w, get_policy("mirage_faithful")))
    np.testing.assert_allclose(new, ref, rtol=1e-6,
                               atol=1e-6 * np.abs(ref).max())


def test_faithful_grad_compiles_and_matches_ref():
    x, w = _rand((4, 48), 41, 0.3), _rand((48, 6), 42, 0.3)

    def loss(xx, ww, policy):
        return jnp.sum(gemm.mirage_matmul(xx, ww, policy) ** 2)

    gx_ref, gw_ref = jax.grad(loss, argnums=(0, 1))(
        x, w, get_policy("mirage_faithful_ref"))
    gx, gw = jax.jit(jax.grad(loss, argnums=(0, 1)), static_argnums=2)(
        x, w, get_policy("mirage_faithful"))
    np.testing.assert_array_equal(np.asarray(gx_ref), np.asarray(gx))
    np.testing.assert_array_equal(np.asarray(gw_ref), np.asarray(gw))


# --------------------------------------------------------------------------
# Pallas-routed RNS backend
# --------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(5, 37, 9), (2, 160, 12)])
def test_rns_pallas_routing_matches_jnp_exactly(shape):
    m, k, n = shape
    x, w = _rand((m, k), m + 2 * k), _rand((k, n), n + 2 * k)
    jnp_out = gemm.mirage_matmul_nograd(x, w, get_policy("mirage_rns"))
    pal_out = gemm.mirage_matmul_nograd(
        x, w, get_policy("mirage_rns", use_pallas=True))
    mode_out = gemm.mirage_matmul_nograd(x, w, get_policy("mirage_rns_pallas"))
    np.testing.assert_array_equal(np.asarray(jnp_out), np.asarray(pal_out))
    np.testing.assert_array_equal(np.asarray(jnp_out), np.asarray(mode_out))


def test_rns_pallas_with_batch_dims():
    x = _rand((2, 3, 64), 51)
    w = _rand((64, 5), 52)
    a = gemm.mirage_matmul_nograd(x, w, get_policy("mirage_rns"))
    b = gemm.mirage_matmul_nograd(x, w, get_policy("mirage_rns_pallas"))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.parametrize("k", [4, 5, 6, 8])
@pytest.mark.parametrize("mkn", [(4, 16, 4), (9, 33, 7), (5, 70, 3)])
def test_rns_matmul_pallas_vs_rns_matmul(k, mkn):
    """Kernel parity against core rns.rns_matmul across moduli sets and
    non-aligned shapes (satellite requirement)."""
    m, kk, n = mkn
    moduli = special_moduli(k)
    rng = np.random.default_rng(k * 1000 + m + kk)
    xr = jnp.asarray(np.stack([rng.integers(0, mm, size=(m, kk)) for mm in moduli]),
                     jnp.int32)
    wr = jnp.asarray(np.stack([rng.integers(0, mm, size=(kk, n)) for mm in moduli]),
                     jnp.int32)
    got = rns_matmul_pallas(xr, wr, moduli, interpret=True)
    want = rns.rns_matmul(xr, wr, moduli).astype(jnp.int32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --------------------------------------------------------------------------
# Analog noise wiring (policy.noise_sigma)
# --------------------------------------------------------------------------

def test_noise_zero_sigma_is_exact_fast_path():
    x, w = _rand((4, 64), 61), _rand((64, 6), 62)
    clean = gemm.mirage_matmul_nograd(x, w, get_policy("mirage_rns"))
    keyed = gemm.mirage_matmul_nograd(
        x, w, get_policy("mirage_rns", noise_sigma=0.0),
        key=jax.random.PRNGKey(0))
    np.testing.assert_array_equal(np.asarray(clean), np.asarray(keyed))


def test_noise_requires_explicit_key():
    x, w = _rand((4, 64), 63), _rand((64, 6), 64)
    with pytest.raises(ValueError, match="PRNG key"):
        gemm.mirage_matmul_nograd(
            x, w, get_policy("mirage_rns", noise_sigma=0.5))


def test_noise_is_keyed_and_perturbs_outputs():
    x, w = _rand((4, 64), 65), _rand((64, 6), 66)
    p = get_policy("mirage_rns", noise_sigma=1.0)
    a1 = np.asarray(gemm.mirage_matmul_nograd(x, w, p, key=jax.random.PRNGKey(0)))
    a2 = np.asarray(gemm.mirage_matmul_nograd(x, w, p, key=jax.random.PRNGKey(0)))
    b = np.asarray(gemm.mirage_matmul_nograd(x, w, p, key=jax.random.PRNGKey(1)))
    clean = np.asarray(gemm.mirage_matmul_nograd(x, w, get_policy("mirage_rns")))
    np.testing.assert_array_equal(a1, a2)       # same key -> same draw
    assert not np.array_equal(a1, b)            # different key -> different
    assert not np.array_equal(a1, clean)        # sigma=1 visibly perturbs


# --------------------------------------------------------------------------
# Modular arithmetic: exact_mod + mod_matmul K-chunking
# --------------------------------------------------------------------------

@pytest.mark.parametrize("k", [5, 8, 10])
def test_exact_mod_matches_jnp_mod(k):
    for m in special_moduli(k):
        rng = np.random.default_rng(m)
        hi = (1 << 24) - 1
        a = np.concatenate([
            np.arange(0, 4 * m + 2),                       # small values
            rng.integers(0, hi, size=20000),               # bulk
            np.arange(hi - 4 * m, hi + 1),                 # window boundary
        ]).astype(np.float32)
        got = np.asarray(grouped.exact_mod(jnp.asarray(a), m))
        want = np.asarray(jnp.mod(jnp.asarray(a), float(m)))
        np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("k", [5, 10])
def test_mod_matmul_large_k_stays_exact(k):
    """K * (m-1)^2 >= 2^24 used to overflow the f32 exact-integer window;
    the chunked accumulation must match a python-int oracle."""
    moduli = special_moduli(k)
    K = 40000 if k == 5 else 64
    rng = np.random.default_rng(k)
    for m in moduli:
        assert K * (m - 1) ** 2 >= 1 << 24  # the regime the seed got wrong
        xr = rng.integers(0, m, size=(3, K))
        wr = rng.integers(0, m, size=(K, 4))
        got = np.asarray(rns.mod_matmul(jnp.asarray(xr, jnp.int32),
                                        jnp.asarray(wr, jnp.int32), m))
        want = (xr.astype(np.int64) @ wr.astype(np.int64)) % m  # < 2^63: exact
        np.testing.assert_array_equal(got.astype(np.int64), want)


def test_mod_matmul_small_k_unchanged():
    """Below the window the original single-matmul path is taken."""
    m = 33
    rng = np.random.default_rng(0)
    xr = rng.integers(0, m, size=(5, 64))
    wr = rng.integers(0, m, size=(64, 5))
    got = np.asarray(rns.mod_matmul(jnp.asarray(xr, jnp.int32),
                                    jnp.asarray(wr, jnp.int32), m))
    want = (xr @ wr) % m
    np.testing.assert_array_equal(got.astype(np.int64), want)


# --------------------------------------------------------------------------
# Transpose-free weight quantization
# --------------------------------------------------------------------------

def test_exponent_bits_matches_frexp_oracle():
    """_exponent_bits replaced the frexp-based _exponent in the hot quantize
    path; their bit-identity is load-bearing for the *_ref parity oracles,
    so keep the frexp version alive as the oracle here."""
    from repro.core import bfp
    rng = np.random.default_rng(0)
    vals = np.concatenate([
        np.abs(rng.normal(size=4096)).astype(np.float32),
        2.0 ** rng.integers(-126, 128, size=1024).astype(np.float32),
        np.float32([0.0, 1e-38, 1e-45, 2.0**-126, 2.0**-149, 65504.0,
                    1e30, 3.4e38, 1.0, 0.5, 2.0]),
    ])
    a = np.asarray(bfp._exponent(jnp.asarray(vals)))
    b = np.asarray(bfp._exponent_bits(jnp.asarray(vals)))
    np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("kn", [(64, 8), (37, 5), (200, 16)])
def test_bfp_quantize_contract_matches_transposed_path(kn):
    from repro.core import bfp
    K, N = kn
    w = _rand((K, N), K + N)
    qw, sw = bfp.bfp_quantize_contract(w, 4, 16)
    t = bfp.bfp_quantize(w.T, 4, 16)
    np.testing.assert_array_equal(np.asarray(qw),
                                  np.asarray(t.mantissa.transpose(1, 2, 0)))
    np.testing.assert_array_equal(np.asarray(sw),
                                  np.asarray(t.scale.transpose(1, 2, 0)))
