"""Structural checks for every (arch x shape) cell's abstract inputs —
cheap (no compilation): shapes well-formed, caches consistent with model
cache_spec, batch divisibility assumptions hold."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ALL_SHAPES, ARCHS, cell_is_skipped
from repro.models import build_model, input_specs
from repro.models.lm import LMCallOptions

CELLS = [(a, s) for a in sorted(ARCHS) for s in ALL_SHAPES]


@pytest.mark.parametrize("arch_id,shape", CELLS,
                         ids=[f"{a}-{s.name}" for a, s in CELLS])
def test_input_specs_well_formed(arch_id, shape):
    if cell_is_skipped(arch_id, shape.name):
        pytest.skip(cell_is_skipped(arch_id, shape.name))
    cfg = ARCHS[arch_id]
    specs = input_specs(cfg, shape)
    leaves = jax.tree_util.tree_leaves(specs)
    assert leaves, (arch_id, shape.name)
    for l in leaves:
        assert isinstance(l, jax.ShapeDtypeStruct)
        assert all(d >= 0 for d in l.shape)

    if shape.kind == "train":
        assert specs["tokens"].shape == specs["labels"].shape
        if not cfg.is_encdec:
            assert specs["tokens"].shape == (shape.global_batch, shape.seq_len)
    if shape.kind == "decode":
        assert "cache" in specs
        assert specs["tokens"].shape == (shape.global_batch, 1)
        # cache leaves must match the model's own cache_spec
        model = build_model(cfg)
        if cfg.is_encdec:
            ms = model.cache_spec(shape.global_batch,
                                  max(shape.seq_len // 8, 16), shape.seq_len)
        else:
            ms = model.cache_spec(shape.global_batch, shape.seq_len)
        for k, (s, d) in ms.items():
            assert specs["cache"][k].shape == tuple(s), (k, arch_id)


@pytest.mark.parametrize("arch_id", sorted(ARCHS))
def test_swa_caps_cache_capacity(arch_id):
    """SWA archs must cap decode caches at the window (mixtral 500k decode
    holds a 4096-slot ring, not a 524288 buffer)."""
    cfg = ARCHS[arch_id]
    if cfg.is_encdec:
        pytest.skip("enc-dec")
    model = build_model(cfg)
    spec = model.cache_spec(1, 524_288)
    if cfg.sliding_window:
        assert spec["k"][0][2] == cfg.sliding_window
    elif cfg.family in ("ssm", "hybrid"):
        assert "ssm" in spec
    else:
        assert spec["k"][0][2] == 524_288


def test_param_counts_match_published_scale():
    """Total parameter counts are in the advertised ballpark."""
    import math
    expected = {
        "command-r-plus-104b": (100e9, 112e9),
        "qwen3-14b": (13e9, 16e9),
        "mixtral-8x7b": (44e9, 48e9),
        "qwen3-moe-30b-a3b": (28e9, 32e9),
        "mamba2-2.7b": (2.4e9, 3.0e9),
        "zamba2-2.7b": (2.3e9, 3.1e9),
        "qwen2-1.5b": (1.2e9, 1.9e9),
        "qwen2-0.5b": (0.4e9, 0.7e9),
        "internvl2-2b": (1.6e9, 2.4e9),
        "seamless-m4t-large-v2": (1.4e9, 2.6e9),
    }
    for arch_id, (lo, hi) in expected.items():
        cfg = ARCHS[arch_id]
        model = build_model(cfg)
        params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
        n = sum(math.prod(l.shape) for l in jax.tree_util.tree_leaves(params))
        assert lo <= n <= hi, f"{arch_id}: {n/1e9:.2f}B not in [{lo/1e9},{hi/1e9}]"
