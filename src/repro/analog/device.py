"""Mirage device constants (paper Section IV-B) + receiver noise physics.

Single source of truth for the §IV-B device-level constants: the analytical
hardware model (``benchmarks/hw_model.py``) imports them from here, and the
analog channel model (``repro.analog.channel``) derives detector noise
sigmas from the same numbers, so energy accounting and noise injection can
never drift apart.

The receiver model turns an optical power at the detector into an SNR:
photocurrent ``I = R * P`` (responsivity R), shot-noise variance
``2 q I B`` and thermal (Johnson) variance ``4 k T B / R_load`` over the
detection bandwidth B. The paper's requirement "SNR > m" (§IV-B1) is an
*amplitude* SNR: the full-scale signal spans m phase levels, so a detector
at exactly the required SNR resolves one level.
"""

from __future__ import annotations

import math

# ---------------------------------------------------------------------------
# Device constants (Section IV-B) — imported by benchmarks/hw_model.py
# ---------------------------------------------------------------------------

PHOTONIC_CLOCK_HZ = 10e9          # 10 GHz MVM rate
DIGITAL_CLOCK_HZ = 1e9            # 1 GHz digital, x10 interleaved
PS_PROGRAM_NS = 5.0               # phase-shifter settle per tile [3]
MVM_NS = 0.1                      # one MVM per 0.1 ns

PS_LOSS_DB = 0.04                 # 25um phase shifter loss
MRR_LOSS_DB = 0.2                 # MRR insertion+propagation when coupled
BEND_LOSS_DB = 0.01               # 180-degree bend
COUPLER_LOSS_DB = 0.2             # laser-to-chip coupler
LASER_EFF = 0.20                  # wall-plug efficiency
DETECTOR_A_PER_W = 1.1            # photodetector responsivity
TIA_J_PER_BIT = 57e-15
MRR_TUNE_W = 0.3e-12              # electro-optic MRR switching power

DAC6_W, DAC6_GSPS, DAC6_MM2 = 136e-3, 20e9, 0.072   # [27]
ADC6_W, ADC6_GSPS, ADC6_MM2 = 23e-3, 24e9, 0.03     # [56]
RNS_CONV_J = 0.48e-12             # per RNS-BNS conversion [21]
RNS_CONV_MM2 = 1545.8e-6          # mm^2
SRAM_BYTES = 3 * 8 * 2**20        # three 8MB arrays
SRAM_PJ_PER_BYTE = 0.6            # 40nm 32kB-bank read energy estimate
SRAM_MM2_PER_MB = 0.45            # 40nm SRAM compiler estimate

# device geometry for area
PS_LEN_UM = 25.0
MRR_RADIUS_UM = 10.0
WG_PITCH_UM = 5.0

P_RX_FLOOR_W = 1e-9   # ~1 nW: shot-noise-limited receiver floor at 10 GHz

# receiver front-end (shot/thermal noise model)
ELECTRON_CHARGE_C = 1.602176634e-19
BOLTZMANN_J_PER_K = 1.380649e-23
RECEIVER_TEMP_K = 300.0
TIA_LOAD_OHM = 50.0


def receiver_snr_db(p_rx_w: float,
                    bandwidth_hz: float = PHOTONIC_CLOCK_HZ,
                    responsivity: float = DETECTOR_A_PER_W) -> float:
    """Amplitude SNR (dB) of the shot/thermal-limited receiver at power P.

    SNR_amp = I / sqrt(2 q I B + 4 k T B / R_load); returned as 20*log10.
    """
    if p_rx_w <= 0:
        return -math.inf
    i_ph = responsivity * p_rx_w
    shot = 2.0 * ELECTRON_CHARGE_C * i_ph * bandwidth_hz
    thermal = (4.0 * BOLTZMANN_J_PER_K * RECEIVER_TEMP_K * bandwidth_hz
               / TIA_LOAD_OHM)
    return 20.0 * math.log10(i_ph / math.sqrt(shot + thermal))


def snr_requirement_db(m: int) -> float:
    """Paper §IV-B1: to distinguish m phase levels the core needs SNR > m."""
    return 20.0 * math.log10(m)


def receiver_power_for_snr_w(snr_db: float,
                             bandwidth_hz: float = PHOTONIC_CLOCK_HZ,
                             responsivity: float = DETECTOR_A_PER_W) -> float:
    """Inverse of :func:`receiver_snr_db` (bisection on the monotone model)."""
    lo, hi = 1e-15, 1e6
    for _ in range(260):
        mid = math.sqrt(lo * hi)
        if receiver_snr_db(mid, bandwidth_hz, responsivity) < snr_db:
            lo = mid
        else:
            hi = mid
    p = math.sqrt(lo * hi)
    achieved = receiver_snr_db(p, bandwidth_hz, responsivity)
    if achieved < snr_db - 0.5:
        raise ValueError(
            f"requested SNR {snr_db:.1f} dB unreachable within the "
            f"bisection bracket (achieved {achieved:.1f} dB at {p:.3g} W)")
    return p
