"""Vectorized, jit/vmap-safe redundant-RNS (RRNS) encode + majority decode.

Paper §VII: one residue phase error explodes through CRT reconstruction, so
``r`` redundant moduli are added and the value is reconstructed from every
size-``n`` subset of the ``n + r`` moduli; the value most subsets agree on
(and that lies inside the legal dynamic range ``|X| <= psi``) wins. With
``r = 2`` redundant moduli any single residue error is corrected (classic
RRNS result; Demirkiran et al., arXiv:2309.10759).

``repro.core.noise.rrns_decode_np`` is the frozen host-side parity oracle
(python-int CRT, dict voting). This module is the deployable counterpart:
all ``C(n+r, n)`` CRT reconstructions are precomputed as static weight
tables (:func:`build_tables`), so a decode is one batched modular
contraction plus vectorized vote counting — no host callbacks, safe under
``jax.jit`` / ``jax.vmap``, and bit-matching the oracle (vote counts and
first-max tie-breaking included).

int32 safety: every per-term product ``res_i * c_i`` is bounded by
``(m_max - 1) * (M_subset - 1)`` and every vote sum by the subset count;
:func:`build_tables` rejects moduli sets where any bound leaves int32 (the
paper point k=5 with two redundant moduli is ~2^21, far inside).
"""

from __future__ import annotations

import dataclasses
import functools
import itertools
import math
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rns


def default_redundant_moduli(k: int, r: int = 2) -> Tuple[int, ...]:
    """First ``r`` primes above ``2^k + 1``: co-prime to the special set
    {2^k-1, 2^k, 2^k+1} and to each other, and >= every base modulus (the
    standard RRNS requirement for full single-error coverage)."""
    out = []
    cand = 2 ** k + 2
    while len(out) < r:
        if all(cand % p for p in range(2, int(math.isqrt(cand)) + 1)):
            out.append(cand)
        cand += 1
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class RRNSTables:
    """Static CRT subset tables for one (moduli, n_required, psi) decode.

    weights[s, i] is the CRT reconstruction weight ``(M_i * T_i) mod M_s``
    of modulus i in subset s (0 when i is not in s), so the subset-s value
    is ``(sum_i res_i * weights[s, i]) mod subset_M[s]``, sign-folded at
    ``subset_psi[s]``. A legal decode satisfies ``|X| <= psi``.
    """

    moduli: Tuple[int, ...]
    n_required: int
    psi: int
    subsets: Tuple[Tuple[int, ...], ...]
    weights: np.ndarray       # (S, n_total) int32
    subset_M: np.ndarray      # (S,) int32
    subset_psi: np.ndarray    # (S,) int32

    @property
    def n_subsets(self) -> int:
        return len(self.subsets)


def build_tables(moduli: Sequence[int], n_required: int,
                 psi: int) -> RRNSTables:
    """Precompute CRT weights for all C(n_total, n_required) subsets."""
    moduli = tuple(int(m) for m in moduli)
    n_total = len(moduli)
    if not 0 < n_required <= n_total:
        raise ValueError(f"n_required={n_required} out of range for "
                         f"{n_total} moduli")
    for a, b in itertools.combinations(moduli, 2):
        if math.gcd(a, b) != 1:
            raise ValueError(f"moduli must be pairwise co-prime; "
                             f"gcd({a}, {b}) != 1")
    subsets = tuple(itertools.combinations(range(n_total), n_required))
    m_max = max(moduli)
    weights = np.zeros((len(subsets), n_total), np.int64)
    subset_M = np.zeros(len(subsets), np.int64)
    for s, sub in enumerate(subsets):
        sub_moduli = [moduli[i] for i in sub]
        M_s, consts = rns.crt_constants(sub_moduli)
        subset_M[s] = M_s
        for i, c in zip(sub, consts):
            weights[s, i] = c
        # accumulator peak: (M_s - 1) carried + (m_max - 1)(M_s - 1) per term
        if m_max * (M_s - 1) >= 2 ** 31:
            raise ValueError(
                f"subset {sub_moduli}: modular-accumulation bound "
                f"{m_max * (M_s - 1)} leaves int32; decode would be "
                f"inexact under jit (use smaller k or fewer moduli)")
        if M_s < 2 * psi + 1:
            raise ValueError(
                f"subset {sub_moduli}: range M={M_s} cannot represent the "
                f"legal interval [-{psi}, {psi}] — redundant moduli must be "
                f">= every base modulus (classic RRNS requirement), else "
                f"clean values alias to wrong legal decodes")
    return RRNSTables(
        moduli=moduli, n_required=n_required, psi=int(psi),
        subsets=subsets,
        weights=weights.astype(np.int32),
        subset_M=subset_M.astype(np.int32),
        subset_psi=((subset_M - 1) // 2).astype(np.int32),
    )


@functools.lru_cache(maxsize=64)
def get_tables(moduli: Tuple[int, ...], n_required: int,
               psi: int) -> RRNSTables:
    """Cached :func:`build_tables` (backends rebuild per GEMM call)."""
    return build_tables(moduli, n_required, psi)


def rrns_encode(x: jax.Array, moduli: Sequence[int]) -> jax.Array:
    """Residues of x over the full (base + redundant) moduli set, stacked on
    a new leading axis — plain forward conversion, redundancy is free."""
    return rns.to_rns(x, moduli)


def rrns_decode(residues: jax.Array,
                tables: RRNSTables) -> Tuple[jax.Array, jax.Array]:
    """Majority-vote RRNS decode, fully vectorized (jit/vmap-safe).

    residues: (n_total, ...) int32 over ``tables.moduli``.
    Returns ``(decoded, corrected)``: int32 values (0 where no subset lands
    in the legal range) and a bool mask marking positions where at least one
    subset disagreed (i.e. an error was detected/corrected) — identical
    semantics to the :func:`repro.core.noise.rrns_decode_np` oracle.
    """
    S = tables.n_subsets
    res = residues.astype(jnp.int32)
    # reconstruct each subset with a static accumulation over its n_required
    # members, reducing mod M_s per term so everything stays int32; the
    # subset/member loops are python (static, small) so peak memory is
    # O(output) rather than the O(S * n_total * output) of a fully batched
    # contraction — decisive for GEMM-sized residue tensors
    Xs = []
    for s, sub in enumerate(tables.subsets):
        M_s = int(tables.subset_M[s])
        psi_s = int(tables.subset_psi[s])
        acc = jnp.zeros(res.shape[1:], jnp.int32)
        for i in sub:
            c = int(tables.weights[s, i])
            acc = jnp.mod(acc + res[i] * c, M_s)
        Xs.append(jnp.where(acc > psi_s, acc - M_s, acc))    # sign fold
    X = jnp.stack(Xs, axis=0)                                # (S, ...)
    legal = jnp.abs(X) <= tables.psi
    # votes[s] = #subsets t with a LEGAL value equal to X[s]; a python loop
    # over the (static, small) subset axis keeps memory at O(S * out) rather
    # than the O(S^2 * out) of a fully materialized equality cube
    votes = jnp.stack(
        [jnp.sum((X == X[s][None]) & legal, axis=0) for s in range(S)], axis=0)
    votes = jnp.where(legal, votes, -1)
    # argmax ties resolve to the lowest subset index == the first-inserted
    # value of the oracle's dict iteration (insertion follows subset order)
    best = jnp.argmax(votes, axis=0)
    decoded = jnp.take_along_axis(X, best[None], axis=0)[0]
    max_votes = jnp.take_along_axis(votes, best[None], axis=0)[0]
    any_legal = jnp.any(legal, axis=0)
    decoded = jnp.where(any_legal, decoded, 0)
    corrected = jnp.where(any_legal, max_votes < S, True)
    return decoded, corrected
