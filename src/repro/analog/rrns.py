"""Vectorized, jit/vmap-safe redundant-RNS (RRNS) encode + majority decode.

Paper §VII: one residue phase error explodes through CRT reconstruction, so
``r`` redundant moduli are added and the value is reconstructed from every
size-``n`` subset of the ``n + r`` moduli; the value most subsets agree on
(and that lies inside the legal dynamic range ``|X| <= psi``) wins. With
``r = 2`` redundant moduli any single residue error is corrected (classic
RRNS result; Demirkiran et al., arXiv:2309.10759).

``repro.core.noise.rrns_decode_np`` is the frozen host-side parity oracle
(python-int CRT, dict voting). This module is the deployable counterpart:
all ``C(n+r, n)`` CRT reconstructions are precomputed as static weight
tables (:func:`build_tables`) and :func:`rrns_decode` is a **single-pass
fused decode**: one reconstruction and one vote count per subset, no
pairwise value-comparison tensor at all. The quadratic compare is avoided
with a counting identity: a subset ``t`` reconstructs the same value as
``s`` iff every modulus of ``t`` is consistent with ``X_s`` (uniqueness of
CRT inside the subset range, which :func:`build_tables` guarantees covers
the legal interval), so the oracle's vote count for ``X_s`` is exactly

    votes[s] = C(n_required + extra_s, n_required)

where ``extra_s`` counts the *complement* moduli consistent with ``X_s``
(the ``n_required`` members of ``s`` are consistent by construction). That
replaces the ``O(S^2)`` equality cube of the seed decode with ``O(S *
(n_total - n_required))`` cheap congruence checks, and the winner is
tracked with a running first-max select instead of an argmax + gather pass.

At the paper operating point every quantity fits the f32 exact-integer
window, so the whole decode runs as fused f32 FMA/select chains (one
round-based modular fold per subset, one 4-op divisibility test per
consistency check) — no integer division anywhere. Moduli sets too large
for the f32 window fall back to an int32 per-term modular accumulation
(same voting identity, still single-pass). ``rrns_decode_reference`` keeps
the pre-fusion subset-loop decode, frozen as a parity oracle and benchmark
baseline; ``repro.kernels.rrns_decode`` is the Pallas kernel counterpart
(subset-major grid) reachable through ``policy.use_pallas``.

int32 safety: every per-term product ``res_i * c_i`` is bounded by
``(m_max - 1) * (M_subset - 1)`` and every vote sum by the subset count;
:func:`build_tables` rejects moduli sets where any bound leaves int32 (the
paper point k=5 with two redundant moduli is ~2^21, far inside).
"""

from __future__ import annotations

import dataclasses
import functools
import itertools
import math
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rns
from repro.obs import health as obs_health


def default_redundant_moduli(k: int, r: int = 2) -> Tuple[int, ...]:
    """First ``r`` primes above ``2^k + 1``: co-prime to the special set
    {2^k-1, 2^k, 2^k+1} and to each other, and >= every base modulus (the
    standard RRNS requirement for full single-error coverage)."""
    out = []
    cand = 2 ** k + 2
    while len(out) < r:
        if all(cand % p for p in range(2, int(math.isqrt(cand)) + 1)):
            out.append(cand)
        cand += 1
    return tuple(out)


@dataclasses.dataclass(frozen=True)
class RRNSTables:
    """Static CRT subset tables for one (moduli, n_required, psi) decode.

    weights[s, i] is the CRT reconstruction weight ``(M_i * T_i) mod M_s``
    of modulus i in subset s (0 when i is not in s), so the subset-s value
    is ``(sum_i res_i * weights[s, i]) mod subset_M[s]``, sign-folded at
    ``subset_psi[s]``. A legal decode satisfies ``|X| <= psi``.

    The fused decode additionally uses the member/complement index tables
    (``members``/``comp``), the vote lookup ``binom[e] = C(n_required + e,
    n_required)`` and, when ``f32_exact`` (every accumulation bound inside
    the f32 exact-integer window 2^24), runs entirely in f32.
    """

    moduli: Tuple[int, ...]
    n_required: int
    psi: int
    subsets: Tuple[Tuple[int, ...], ...]
    weights: np.ndarray       # (S, n_total) int32
    subset_M: np.ndarray      # (S,) int32
    subset_psi: np.ndarray    # (S,) int32
    members: np.ndarray       # (S, n_required) int32 member positions
    comp: np.ndarray          # (S, n_total - n_required) int32 complement
    binom: Tuple[int, ...]    # vote count per consistent-complement count
    f32_exact: bool           # every decode bound fits the f32 window
    vote_threshold: int       # min winner votes inside the correction radius

    @property
    def n_subsets(self) -> int:
        return len(self.subsets)


# f32 holds integers exactly up to 2^24; every fused-decode accumulation
# (subset reconstruction sum, quotient * modulus product) must stay inside.
_F32_WINDOW = 1 << 24


def build_tables(moduli: Sequence[int], n_required: int,
                 psi: int) -> RRNSTables:
    """Precompute CRT weights for all C(n_total, n_required) subsets."""
    moduli = tuple(int(m) for m in moduli)
    n_total = len(moduli)
    if not 0 < n_required <= n_total:
        raise ValueError(f"n_required={n_required} out of range for "
                         f"{n_total} moduli")
    for a, b in itertools.combinations(moduli, 2):
        if math.gcd(a, b) != 1:
            raise ValueError(f"moduli must be pairwise co-prime; "
                             f"gcd({a}, {b}) != 1")
    subsets = tuple(itertools.combinations(range(n_total), n_required))
    m_max = max(moduli)
    weights = np.zeros((len(subsets), n_total), np.int64)
    subset_M = np.zeros(len(subsets), np.int64)
    f32_exact = True
    for s, sub in enumerate(subsets):
        sub_moduli = [moduli[i] for i in sub]
        M_s, consts = rns.crt_constants(sub_moduli)
        subset_M[s] = M_s
        for i, c in zip(sub, consts):
            weights[s, i] = c
        # accumulator peak: (M_s - 1) carried + (m_max - 1)(M_s - 1) per term
        if m_max * (M_s - 1) >= 2 ** 31:
            raise ValueError(
                f"subset {sub_moduli}: modular-accumulation bound "
                f"{m_max * (M_s - 1)} leaves int32; decode would be "
                f"inexact under jit (use smaller k or fewer moduli)")
        if M_s < 2 * psi + 1:
            raise ValueError(
                f"subset {sub_moduli}: range M={M_s} cannot represent the "
                f"legal interval [-{psi}, {psi}] — redundant moduli must be "
                f">= every base modulus (classic RRNS requirement), else "
                f"clean values alias to wrong legal decodes")
        # fused f32 path: the whole reconstruction sum (n_required terms,
        # no intermediate reduction) plus one fold step must stay exact
        if n_required * (m_max - 1) * (M_s - 1) + M_s >= _F32_WINDOW:
            f32_exact = False
    members = np.asarray(subsets, np.int64).reshape(len(subsets), n_required)
    comp = np.asarray(
        [[i for i in range(n_total) if i not in sub] for sub in subsets],
        np.int64).reshape(len(subsets), n_total - n_required)
    binom = tuple(math.comb(n_required + e, n_required)
                  for e in range(n_total - n_required + 1))
    # Trust certificate (classic RRNS): with r redundant moduli the decode
    # corrects t = floor(r/2) residue errors, and a winner is inside that
    # radius iff it is consistent with >= n_total - t moduli, i.e. its
    # vote count reaches C(n_required + r - t, n_required). Winners below
    # this (however "legal" their value) are beyond the correction radius
    # and untrustworthy — note psi = (M_base - 1) // 2 makes the all-base
    # subset legal for EVERY residue tuple, so mere legality certifies
    # nothing.
    r = n_total - n_required
    vote_threshold = math.comb(n_required + r - r // 2, n_required)
    return RRNSTables(
        moduli=moduli, n_required=n_required, psi=int(psi),
        subsets=subsets,
        weights=weights.astype(np.int32),
        subset_M=subset_M.astype(np.int32),
        subset_psi=((subset_M - 1) // 2).astype(np.int32),
        members=members.astype(np.int32),
        comp=comp.astype(np.int32),
        binom=binom,
        f32_exact=bool(f32_exact),
        vote_threshold=int(vote_threshold),
    )


@functools.lru_cache(maxsize=64)
def get_tables(moduli: Tuple[int, ...], n_required: int,
               psi: int) -> RRNSTables:
    """Cached :func:`build_tables` (backends rebuild per GEMM call)."""
    return build_tables(moduli, n_required, psi)


def rrns_moduli(policy) -> Tuple[int, ...]:
    """Base + redundant moduli a policy's error-corrected mode executes
    over (explicit ``policy.redundant_moduli``, else the default primes).
    Single source of truth shared by the ``mirage_rrns`` backend and the
    stationary-weight encoder — a weight programmed over any other set
    fails the backend's static check."""
    extra = tuple(policy.redundant_moduli)
    if not extra:
        extra = default_redundant_moduli(policy.k)
    return tuple(policy.moduli) + extra


def rrns_encode(x: jax.Array, moduli: Sequence[int]) -> jax.Array:
    """Residues of x over the full (base + redundant) moduli set, stacked on
    a new leading axis — plain forward conversion, redundancy is free."""
    return rns.to_rns(x, moduli)


# --------------------------------------------------------------------------
# Fused single-pass decode
# --------------------------------------------------------------------------

def _fold_signed_f32(acc: jax.Array, M_s: int, psi_s: int) -> jax.Array:
    """Signed representative of ``acc mod M_s`` in the subset window
    ``[psi_s + 1 - M_s, psi_s]`` via one round-based fold.

    ``floor(acc/M + 1/2)`` lands the remainder (an exact f32 integer) in
    roughly the right window already; two selects absorb both the half-up
    boundary and the reciprocal's possible off-by-one — bit-identical to
    the reference's "reduce to [0, M) then sign-fold" for every integer
    ``acc`` inside the f32 exact window (property-tested vs the oracle).
    """
    Mf, lo = float(M_s), float(psi_s + 1 - M_s)
    q = jnp.floor(acc * (1.0 / Mf) + 0.5)
    X = acc - q * Mf
    X = jnp.where(X > float(psi_s), X - Mf, X)
    return jnp.where(X < lo, X + Mf, X)


def _is_multiple_f32(d: jax.Array, m: int) -> jax.Array:
    """Exact ``d ≡ 0 (mod m)`` for integer-valued f32 ``d`` (|d| < 2^24).

    ``d - round(d/m) * m`` is exactly zero iff m divides d: when it does,
    the rounded quotient is exact (|d/m| ≪ 2^23 keeps the reciprocal error
    below 1/2); when it does not, no integer quotient can cancel d.
    """
    k = jnp.round(d * (1.0 / float(m)))
    return d - k * float(m) == 0.0


def rrns_decode(residues: jax.Array,
                tables: RRNSTables) -> Tuple[jax.Array, jax.Array]:
    """Fused majority-vote RRNS decode (jit/vmap-safe, single pass).

    residues: (n_total, ...) int32 over ``tables.moduli``.
    Returns ``(decoded, corrected)``: int32 values (0 where no subset lands
    in the legal range) and a bool mask marking positions where at least one
    subset disagreed (i.e. an error was detected/corrected) — identical
    semantics (bit-identical outputs) to the
    :func:`repro.core.noise.rrns_decode_np` oracle.

    One pass over the subsets: each subset contributes one reconstruction,
    ``n_total - n_required`` congruence checks (its vote count is the
    binomial of its consistency count — see the module docstring), and one
    first-max running select. No ``(S, ...)`` stack, no pairwise compares,
    no argmax/gather epilogue.
    """
    S = tables.n_subsets
    n_comp = tables.comp.shape[1]
    moduli = tables.moduli
    fast = tables.f32_exact
    # f32 fast path: every value is an exact f32 integer (|X| <= psi_s <
    # 2^24, checked at build time). General fallback keeps X/best in int32
    # (subset ranges can exceed the f32 window there).
    res = residues.astype(jnp.float32 if fast else jnp.int32)
    best_votes = jnp.full(res.shape[1:], -2.0, jnp.float32)
    best_val = jnp.zeros(res.shape[1:], jnp.float32 if fast else jnp.int32)
    for s in range(S):
        M_s = int(tables.subset_M[s])
        psi_s = int(tables.subset_psi[s])
        if fast:
            # whole reconstruction sum is exact in f32 (checked at build)
            acc = None
            for j in tables.members[s]:
                term = res[int(j)] * float(int(tables.weights[s, int(j)]))
                acc = term if acc is None else acc + term
            X = _fold_signed_f32(acc, M_s, psi_s)
        else:
            # general moduli: int32 per-term modular accumulation
            acc = jnp.zeros(res.shape[1:], jnp.int32)
            for j in tables.members[s]:
                c = int(tables.weights[s, int(j)])
                acc = jnp.mod(acc + res[int(j)] * c, M_s)
            X = jnp.where(acc > psi_s, acc - M_s, acc)
        # consistency count over the complement moduli; the n_required
        # members are congruent by construction (exact CRT), so the vote
        # count is binom[extra] = C(n_required + extra, n_required)
        extra = None
        for i in tables.comp[s]:
            m_i = moduli[int(i)]
            if fast:
                ok = _is_multiple_f32(X - res[int(i)], m_i)
            else:
                ok = jnp.mod(X - res[int(i)], m_i) == 0
            ok = ok.astype(jnp.float32)
            extra = ok if extra is None else extra + ok
        votes = jnp.full(res.shape[1:], float(tables.binom[0]))
        if extra is not None:                  # n_required < n_total
            for e in range(1, n_comp + 1):
                votes = jnp.where(extra == float(e), float(tables.binom[e]),
                                  votes)
        legal = (jnp.abs(X) <= float(tables.psi) if fast
                 else jnp.abs(X) <= tables.psi)
        votes = jnp.where(legal, votes, -1.0)
        # strict > keeps the FIRST max: subset order == the oracle's dict
        # insertion order, so ties resolve to the first-inserted value
        better = votes > best_votes
        best_votes = jnp.where(better, votes, best_votes)
        best_val = jnp.where(better, X, best_val)
    any_legal = best_votes >= 0.0
    zero = jnp.zeros((), best_val.dtype)
    decoded = jnp.where(any_legal, best_val, zero).astype(jnp.int32)
    corrected = jnp.where(any_legal, best_votes < float(S), True)
    if obs_health.active():
        # split the conflated flag for telemetry by the correction-radius
        # certificate: corrected = winner inside the radius (votes >=
        # tables.vote_threshold) but with dissent — exactly repaired;
        # uncorrected = winner beyond the radius or no legal value at all
        # — untrustworthy output. Legality alone certifies nothing (the
        # all-base subset is legal for every residue tuple), so the old
        # no-legal-value split could never fire. Guarded: without an open
        # collection scope these reductions are never traced. One fused
        # reduction (cheaper than two chains in the op-dispatch-bound
        # decode step): vot >= S implies trusted, so trusted -
        # full_agreement = repaired and size - trusted = untrustworthy.
        T = float(tables.vote_threshold)
        n = jnp.sum(jnp.stack([best_votes >= T, best_votes >= float(S)])
                    .astype(jnp.int32),
                    axis=tuple(range(1, best_votes.ndim + 1)))
        obs_health.record("rrns_corrected", n[0] - n[1])
        obs_health.record("rrns_uncorrected",
                          jnp.int32(best_votes.size) - n[0])
    return decoded, corrected


# --------------------------------------------------------------------------
# Pre-fusion reference decode (frozen: parity oracle + benchmark baseline)
# --------------------------------------------------------------------------

def rrns_decode_reference(residues: jax.Array,
                          tables: RRNSTables) -> Tuple[jax.Array, jax.Array]:
    """The pre-fusion decode: python loop over subsets + ``O(S^2)`` vote
    materialization. Kept verbatim as the walltime baseline of
    ``benchmarks/bench_gemm.py`` and as a second parity oracle for the
    fused decode — do not optimize."""
    S = tables.n_subsets
    res = residues.astype(jnp.int32)
    # reconstruct each subset with a static accumulation over its n_required
    # members, reducing mod M_s per term so everything stays int32
    Xs = []
    for s, sub in enumerate(tables.subsets):
        M_s = int(tables.subset_M[s])
        psi_s = int(tables.subset_psi[s])
        acc = jnp.zeros(res.shape[1:], jnp.int32)
        for i in sub:
            c = int(tables.weights[s, i])
            acc = jnp.mod(acc + res[i] * c, M_s)
        Xs.append(jnp.where(acc > psi_s, acc - M_s, acc))    # sign fold
    X = jnp.stack(Xs, axis=0)                                # (S, ...)
    legal = jnp.abs(X) <= tables.psi
    # votes[s] = #subsets t with a LEGAL value equal to X[s]
    votes = jnp.stack(
        [jnp.sum((X == X[s][None]) & legal, axis=0) for s in range(S)], axis=0)
    votes = jnp.where(legal, votes, -1)
    # argmax ties resolve to the lowest subset index == the first-inserted
    # value of the oracle's dict iteration (insertion follows subset order)
    best = jnp.argmax(votes, axis=0)
    decoded = jnp.take_along_axis(X, best[None], axis=0)[0]
    max_votes = jnp.take_along_axis(votes, best[None], axis=0)[0]
    any_legal = jnp.any(legal, axis=0)
    decoded = jnp.where(any_legal, decoded, 0)
    corrected = jnp.where(any_legal, max_votes < S, True)
    if obs_health.active():
        # same correction-radius split as the fused decode
        trusted = max_votes >= tables.vote_threshold
        obs_health.record("rrns_corrected", jnp.sum(
            (trusted & (max_votes < S)).astype(jnp.int32)))
        obs_health.record("rrns_uncorrected",
                          jnp.sum((~trusted).astype(jnp.int32)))
    return decoded, corrected
