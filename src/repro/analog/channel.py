"""Composable analog channel stages for the photonic signal chain (§IV-B).

Every stage maps a residue tensor ``(n_moduli, ...)`` int32 to a residue
tensor of the same shape, is pure/jittable, and is driven by one
:class:`AnalogChannelConfig`. The chain mirrors the physical datapath:

  program side (stationary operand, once per tile)
    DAC quantization  ->  phase-shifter programming drift
  readout side (per MVM output)
    inter-MMU crosstalk  ->  shot/thermal detector noise  ->  ADC

Detector noise is parameterized by an amplitude SNR in dB using the same
§IV-B device constants as ``benchmarks/hw_model.py`` (``repro.analog.device``):
a full-scale signal spans the ``m`` phase levels of modulus ``m``, so noise
with amplitude SNR ``s`` has sigma ``m / 10^(s/20)`` in phase-level units —
at the paper's requirement ``SNR > m`` (§IV-B1) the sigma is below one level.

The legacy ``MiragePolicy.noise_sigma`` knob is subsumed as the derived
special case: an otherwise-identity config whose detector stage adds a flat
per-level sigma on every modulus (see :meth:`AnalogChannelConfig.from_policy`).
"""

from __future__ import annotations

import contextlib
import dataclasses
import math
import threading
from typing import Dict, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.analog import device
from repro.obs import health as obs_health


# -- runtime fault controls (chaos injection) ----------------------------
#
# The serving engine compiles its step functions ONCE; mid-run channel
# faults (SNR collapse, burst storms, stuck detector channels) must
# therefore enter the traced computation as *operands*, not as config
# constants. ``fault_scope`` is a trace-time thread-local — the same
# ambient-scope pattern as ``gemm.noise_key_scope`` — carrying a small
# pytree of traced control arrays that the channel stages consume when a
# scope is active. With no scope (the default) every stage traces exactly
# as before: zero overhead, bit-identical programs. With a scope whose
# controls are the identity (``identity_fault_controls``) the extra traced
# ops are arithmetic no-ops (noise * sigma * 1.0, where(False, ...)), so
# outputs stay bit-identical to the unscoped engine under the same keys.

_FAULT = threading.local()


def identity_fault_controls(n_moduli: int) -> Dict[str, jnp.ndarray]:
    """The do-nothing control pytree for an ``n_moduli``-channel readout:
    detector sigma unscaled, no extra bursts, no stuck channels."""
    return {
        "sigma_scale": jnp.float32(1.0),
        "burst_rate": jnp.float32(0.0),
        "burst_width": jnp.int32(1),
        "stuck_mask": jnp.zeros((n_moduli,), jnp.bool_),
        "stuck_level": jnp.zeros((n_moduli,), jnp.int32),
    }


@contextlib.contextmanager
def fault_scope(controls: Optional[Dict[str, jnp.ndarray]]):
    """Make ``controls`` ambient for channel stages traced inside the
    scope. ``None`` is allowed and pushes an inert scope (stages trace
    the unfaulted path), so call sites can pass through unconditionally."""
    stack = getattr(_FAULT, "stack", None)
    if stack is None:
        stack = _FAULT.stack = []
    stack.append(controls)
    try:
        yield
    finally:
        stack.pop()


def fault_controls() -> Optional[Dict[str, jnp.ndarray]]:
    """The innermost active fault-control pytree, or ``None``."""
    stack = getattr(_FAULT, "stack", None)
    return stack[-1] if stack else None


def detector_sigma_levels(m: int, snr_db: float) -> float:
    """Detector noise sigma in phase-level units for modulus m at SNR (dB)."""
    return m / (10.0 ** (snr_db / 20.0))


@dataclasses.dataclass(frozen=True)
class AnalogChannelConfig:
    """Full analog channel description, one field per physical impairment.

    Attributes:
      dac_bits: DAC precision programming/streaming residues. ``None`` means
        exact (a ``ceil(log2 m)``-bit converter per modulus, the paper's
        design point); fewer bits re-grid residues onto ``2^dac_bits`` levels.
      adc_bits: ADC precision on readout, same convention as ``dac_bits``.
      snr_db: amplitude SNR at the detector; per-modulus Gaussian noise with
        sigma ``m / 10^(snr_db/20)`` phase levels. ``None`` disables.
      noise_sigma: flat extra sigma in phase-level units on every modulus
        (the legacy ``MiragePolicy.noise_sigma`` knob), added in quadrature
        with the SNR-derived sigma.
      phase_drift_sigma: Gaussian programming drift on the *stationary*
        operand's phase shifters, in phase-level units (applied once per
        tile, i.e. once per GEMM here).
      crosstalk: inter-MMU leakage coefficient: each group output channel
        leaks ``crosstalk`` of each neighboring group's signal into itself
        (deterministic mixing along the group axis).
      burst_rate: probability per readout element of a correlated burst
        event (transient detector saturation / link glitch) that slams
        ``burst_width`` adjacent residue channels with uniform errors —
        the non-i.i.d. error model the i.i.d. detector stage cannot
        express. ``burst_width=1`` is the single-residue-error regime RRNS
        corrects exactly; ``burst_width>=2`` exceeds the 2-redundant-moduli
        correction radius and degrades detectably.
      burst_width: number of adjacent residue channels one burst corrupts.
    """

    dac_bits: Optional[int] = None
    adc_bits: Optional[int] = None
    snr_db: Optional[float] = None
    noise_sigma: float = 0.0
    phase_drift_sigma: float = 0.0
    crosstalk: float = 0.0
    burst_rate: float = 0.0
    burst_width: int = 1

    @classmethod
    def from_policy(cls, policy) -> "AnalogChannelConfig":
        """Channel described by a MiragePolicy's analog fields.

        A policy carrying only the legacy ``noise_sigma`` knob yields the
        flat-sigma special case; the richer fields map one-to-one."""
        return cls(
            dac_bits=policy.dac_bits,
            adc_bits=policy.adc_bits,
            snr_db=policy.snr_db,
            noise_sigma=policy.noise_sigma,
            phase_drift_sigma=policy.phase_drift_sigma,
            crosstalk=policy.crosstalk,
            burst_rate=getattr(policy, "burst_rate", 0.0),
            burst_width=getattr(policy, "burst_width", 1),
        )

    @property
    def stochastic(self) -> bool:
        """True when any stage draws random numbers (needs a PRNG key)."""
        return (self.snr_db is not None
                or self.noise_sigma > 0
                or self.phase_drift_sigma > 0
                or self.burst_rate > 0)

    @property
    def identity(self) -> bool:
        """True when every stage is a no-op for any moduli set."""
        return (not self.stochastic and self.crosstalk == 0.0
                and self.dac_bits is None and self.adc_bits is None)

    def detector_sigmas(self, moduli: Sequence[int]) -> tuple:
        """Per-modulus readout sigma: SNR-derived ⊕ flat, in level units."""
        out = []
        for m in moduli:
            s2 = self.noise_sigma ** 2
            if self.snr_db is not None:
                s2 += detector_sigma_levels(m, self.snr_db) ** 2
            out.append(math.sqrt(s2))
        return tuple(out)

    def required_receiver_power_w(self, moduli: Sequence[int]) -> float:
        """Optical power at the detector for this SNR (§IV-B receiver model);
        the hw-model hook that prices a sweep point in laser watts."""
        snr = self.snr_db
        if snr is None:
            snr = device.snr_requirement_db(max(moduli))
        return device.receiver_power_for_snr_w(snr)


def _mods_col(moduli: Sequence[int], ndim: int) -> jnp.ndarray:
    return jnp.asarray(moduli, jnp.float32).reshape((-1,) + (1,) * (ndim - 1))


def converter_quantize(residues: jax.Array, moduli: Sequence[int],
                       bits: Optional[int]) -> jax.Array:
    """Re-grid residues onto the 2^bits uniform levels of a DAC/ADC.

    Identity whenever ``2^bits >= m`` (the converter resolves every phase
    level, the paper's ``ceil(log2 m)``-bit design point) or ``bits is
    None``; otherwise each residue snaps to the nearest representable level
    of a uniform grid over [0, m-1].
    """
    if bits is None:
        return residues
    outs = []
    for i, m in enumerate(moduli):
        levels = 2 ** bits
        if levels >= m:
            outs.append(residues[i])
            continue
        step = (m - 1) / (levels - 1)
        q = jnp.round(jnp.round(residues[i].astype(jnp.float32) / step) * step)
        outs.append(jnp.clip(q, 0, m - 1).astype(jnp.int32))
    return jnp.stack(outs, axis=0)


def phase_noise(residues: jax.Array, moduli: Sequence[int],
                sigmas, key: jax.Array) -> jax.Array:
    """Per-modulus additive Gaussian phase noise, re-quantized to the nearest
    level and wrapped mod m (the detector reads phases on a ring).

    ``sigmas`` is a per-modulus sequence of floats (static: an all-zero
    chain short-circuits to the identity) or a traced f32 array of the
    same length (runtime fault controls: always traced, zero sigma is an
    arithmetic no-op)."""
    if not isinstance(sigmas, jax.Array) and all(s <= 0 for s in sigmas):
        return residues
    sig = jnp.asarray(sigmas, jnp.float32).reshape(
        (-1,) + (1,) * (residues.ndim - 1))
    noise = jax.random.normal(key, residues.shape) * sig
    noisy = jnp.round(residues.astype(jnp.float32) + noise)
    return jnp.mod(noisy, _mods_col(moduli, residues.ndim)).astype(jnp.int32)


def crosstalk_mix(residues: jax.Array, moduli: Sequence[int],
                  eps: float, group_axis: int = 1) -> jax.Array:
    """Inter-MMU crosstalk: each group channel leaks ``eps`` of each
    neighboring group into itself (deterministic, wraps around the array
    edge like the physical waveguide bus). Re-quantized and wrapped mod m.

    With one group (no neighbors) the mix is exactly the identity."""
    if eps == 0.0 or residues.shape[group_axis] == 1:
        return residues
    r = residues.astype(jnp.float32)
    if residues.shape[group_axis] == 2:
        # two channels have ONE neighbor each (roll +1 == roll -1)
        mixed = (1.0 - eps) * r + eps * jnp.roll(r, 1, axis=group_axis)
    else:
        mixed = ((1.0 - 2.0 * eps) * r
                 + eps * jnp.roll(r, 1, axis=group_axis)
                 + eps * jnp.roll(r, -1, axis=group_axis))
    return jnp.mod(jnp.round(mixed),
                   _mods_col(moduli, residues.ndim)).astype(jnp.int32)


def burst_errors(residues: jax.Array, moduli: Sequence[int], rate,
                 width, key: jax.Array) -> jax.Array:
    """Correlated burst corruption: with probability ``rate`` per output
    element, ``width`` ADJACENT residue channels (wrapping at the array
    edge, like the physical detector bank) take uniform errors in
    ``[1, m-1]`` simultaneously.

    This is the correlation the i.i.d. channel stages cannot express: one
    transient event (detector saturation, readout-link glitch) hits a
    contiguous span of residue channels at once. At ``width=1`` every hit
    is a single-residue error — exactly the regime two redundant moduli
    correct 100% of; at ``width>=2`` the burst exceeds the correction
    radius and the decode degrades detectably (tested both ways).

    ``rate``/``width`` may be traced scalars (runtime fault controls);
    the zero-rate short-circuit only applies to the static case.
    """
    if not isinstance(rate, jax.Array) and rate <= 0:
        return residues
    n = len(moduli)
    k_hit, k_pos, k_err = jax.random.split(key, 3)
    hit = jax.random.uniform(k_hit, residues.shape[1:]) < rate
    if obs_health.active():
        obs_health.record("burst_hits", jnp.sum(hit.astype(jnp.int32)))
    start = jax.random.randint(k_pos, residues.shape[1:], 0, n)
    outs = []
    for i, m in enumerate(moduli):
        in_burst = jnp.mod(i - start, n) < width
        err = jax.random.randint(jax.random.fold_in(k_err, i),
                                 residues.shape[1:], 1, m)
        outs.append(jnp.where(hit & in_burst,
                              jnp.mod(residues[i] + err, m), residues[i]))
    return jnp.stack(outs, axis=0)


def apply_program_channel(residues: jax.Array, moduli: Sequence[int],
                          cfg: AnalogChannelConfig,
                          key: Optional[jax.Array]) -> jax.Array:
    """Program-side chain on the stationary operand: DAC -> shifter drift."""
    out = converter_quantize(residues, moduli, cfg.dac_bits)
    if cfg.phase_drift_sigma > 0:
        drifted = phase_noise(out, moduli,
                              (cfg.phase_drift_sigma,) * len(moduli), key)
        if obs_health.active():
            # per-channel count of residues the drift moved >= 1 level
            # (zero under stationary weights: programming happens once at
            # admission, outside any collection scope)
            obs_health.record("drift_flips", jnp.sum(
                (drifted != out).astype(jnp.int32),
                axis=tuple(range(1, out.ndim))))
        out = drifted
    return out


def apply_readout_channel(residues: jax.Array, moduli: Sequence[int],
                          cfg: AnalogChannelConfig,
                          key: Optional[jax.Array],
                          group_axis: int = 1) -> jax.Array:
    """Readout-side chain: crosstalk -> detector noise -> ADC re-quantize.

    Under an active :func:`fault_scope` the detector sigma is scaled by the
    traced ``sigma_scale`` control (SNR-collapse injection: scaling the
    same normal draw preserves bit-identity at scale 1.0) and ``stuck``
    channels are clamped to their stuck level after the noise stage."""
    ctl = fault_controls()
    out = crosstalk_mix(residues, moduli, cfg.crosstalk, group_axis)
    sigmas = cfg.detector_sigmas(moduli)
    if ctl is not None:
        sigmas = jnp.asarray(sigmas, jnp.float32) * ctl["sigma_scale"]
    if isinstance(sigmas, jax.Array) or any(s > 0 for s in sigmas):
        noisy = phase_noise(out, moduli, sigmas, key)
        if obs_health.active():
            # per-channel count of residues the detector noise moved >= 1
            # phase level this step (what the RRNS decode then has to
            # correct — the two counters together give correction margin)
            obs_health.record("detector_flips", jnp.sum(
                (noisy != out).astype(jnp.int32),
                axis=tuple(range(1, out.ndim))))
        out = noisy
    if ctl is not None:
        shape = (-1,) + (1,) * (out.ndim - 1)
        mask = ctl["stuck_mask"].reshape(shape)
        level = jnp.mod(
            ctl["stuck_level"].astype(jnp.float32).reshape(shape),
            _mods_col(moduli, out.ndim)).astype(jnp.int32)
        stuck = jnp.where(mask, level, out)
        if obs_health.active():
            obs_health.record("detector_flips", jnp.sum(
                (stuck != out).astype(jnp.int32),
                axis=tuple(range(1, out.ndim))))
        out = stuck
    return converter_quantize(out, moduli, cfg.adc_bits)
