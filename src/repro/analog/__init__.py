"""Analog signal-chain + RRNS fault-tolerance subsystem (paper §IV-B, §VII).

Models the full photonic signal chain as composable, jittable channel
stages (``repro.analog.channel``) and makes redundant-RNS error correction
a first-class execution mode (``repro.analog.rrns`` + the ``mirage_rrns``
GEMM backend in ``repro.core.backends``).

  device.py   §IV-B device constants (shared with benchmarks/hw_model.py)
              and shot/thermal-noise SNR models
  channel.py  AnalogChannelConfig + DAC / drift / detector / ADC / crosstalk
              stages applied to residue tensors
  rrns.py     vectorized, jit/vmap-safe RRNS encode + majority decode with
              precomputed CRT subset tables
  sweep.py    accuracy-vs-SNR campaign helpers (benchmarks/bench_noise.py)
"""

from repro.analog.channel import (
    AnalogChannelConfig,
    apply_program_channel,
    apply_readout_channel,
    detector_sigma_levels,
)
from repro.analog.rrns import (
    RRNSTables,
    build_tables,
    default_redundant_moduli,
    get_tables,
    rrns_decode,
    rrns_encode,
)

__all__ = [
    "AnalogChannelConfig",
    "apply_program_channel",
    "apply_readout_channel",
    "detector_sigma_levels",
    "RRNSTables",
    "build_tables",
    "default_redundant_moduli",
    "get_tables",
    "rrns_decode",
    "rrns_encode",
]
