"""Accuracy-vs-SNR sweep campaign (Fig. 10-style, §VII + arXiv:2309.10759).

Library half of ``benchmarks/bench_noise.py``: given a list of detector SNR
points, measure (a) GEMM relative error and (b) small-LM training loss for
the uncorrected analog path (``mirage_rns_noisy``) and the RRNS-corrected
path (``mirage_rrns``), against the noiseless ``mirage_rns`` / FP32
references. Every function returns machine-readable row dicts; the bench
harness turns them into CSV lines + JSON.

Interpretation guide: with amplitude SNR ``s`` the per-modulus noise sigma
is ``m / 10^(s/20)`` phase levels, so residue flips become likely below
~45 dB for the paper's k=5 moduli; RRNS with two redundant moduli repairs
every single-residue flip, pushing the usable SNR floor down by several dB
(exactly the paper's §VII argument and the Blueprint paper's Fig. 10).
"""

from __future__ import annotations

from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core.precision import get_policy

# the residue-flip transition for the k=5 moduli lives between ~38 and
# ~50 dB (sigma of 1 level sits at the §IV-B1 requirement, ~30 dB; flips
# become rare once sigma < ~0.15 level); sample that shoulder densely
DEFAULT_SNR_DBS = (38.0, 40.0, 42.0, 44.0, 46.0, 48.0, 50.0, 55.0)
NOISY_MODES = ("mirage_rns_noisy", "mirage_rrns")


def gemm_error_sweep(snr_dbs: Sequence[float] = DEFAULT_SNR_DBS,
                     modes: Sequence[str] = NOISY_MODES,
                     shape=(32, 256, 32), seed: int = 0,
                     policy_overrides: Optional[Dict] = None,
                     ) -> List[Dict]:
    """Relative GEMM error vs SNR for each analog mode.

    The reference is the NOISELESS ``mirage_rns`` output, so the metric
    isolates channel corruption from BFP quantization error. Error is the
    relative Frobenius norm (mean-field, Fig. 10-style) plus the fraction
    of corrupted output elements — the latter shows the correction effect
    even when a rare multi-residue error dominates the norm.
    """
    import jax
    import jax.numpy as jnp
    from repro.core import gemm

    m, k, n = shape
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(k, n)).astype(np.float32))
    overrides = dict(policy_overrides or {})
    ref = np.asarray(gemm.mirage_matmul_nograd(
        x, w, get_policy("mirage_rns", **overrides)))
    ref_norm = float(np.linalg.norm(ref)) or 1.0
    tol = 1e-6 * float(np.abs(ref).max() or 1.0)
    rows: List[Dict] = []
    for snr in snr_dbs:
        for mode in modes:
            policy = get_policy(mode, snr_db=float(snr), **overrides)
            out = np.asarray(gemm.mirage_matmul_nograd(
                x, w, policy, key=jax.random.PRNGKey(seed)))
            err = out - ref
            rows.append({
                "section": "noise_gemm",
                "mode": mode,
                "snr_db": float(snr),
                "rel_fro_err": float(np.linalg.norm(err) / ref_norm),
                "corrupt_frac": float(np.mean(np.abs(err) > tol)),
                "shape": list(shape),
            })
    return rows


def train_loss_sweep(snr_dbs: Sequence[float] = (40.0, 50.0),
                     modes: Sequence[str] = NOISY_MODES,
                     steps: int = 12, seed: int = 0) -> List[Dict]:
    """Final small-LM train loss vs SNR, with the noiseless ``mirage_rns``
    and ``fp32`` runs as anchors. Channel noise reaches the jitted train
    step through ``policy.noise_seed`` (static per-GEMM error patterns)."""
    rows: List[Dict] = []
    anchors = {"fp32": get_policy("fp32"),
               "mirage_rns": get_policy("mirage_rns")}
    for name, policy in anchors.items():
        rows.append({"section": "noise_train", "mode": name,
                     "snr_db": None, "loss": _train_small_lm(policy, steps, seed)})
    for snr in snr_dbs:
        for mode in modes:
            policy = get_policy(mode, snr_db=float(snr), noise_seed=seed)
            rows.append({"section": "noise_train", "mode": mode,
                         "snr_db": float(snr),
                         "loss": _train_small_lm(policy, steps, seed)})
    return rows


def _train_small_lm(policy, steps: int, seed: int) -> float:
    """Same recipe as benchmarks/bench_accuracy: reduced LM, synthetic
    bigram data, adamw — the loss after ``steps`` steps."""
    import jax
    from repro.configs import get_config
    from repro.configs.base import TrainConfig
    from repro.data.pipeline import SyntheticLM, SyntheticLMConfig
    from repro.models import build_model
    from repro.models.lm import LMCallOptions
    from repro.runtime.trainer import init_train_state, make_train_step

    cfg = get_config("qwen2-0.5b").reduced()
    model = build_model(cfg, policy, LMCallOptions(q_chunk=16, kv_chunk=16))
    tc = TrainConfig(policy=policy, optimizer="adamw", lr=1e-3)
    state = init_train_state(model, tc, jax.random.PRNGKey(seed))
    step = jax.jit(make_train_step(model, tc))
    data = SyntheticLM(SyntheticLMConfig(
        vocab_size=cfg.vocab_size, seq_len=32, batch_size=4, seed=seed))
    metrics = {}
    for _ in range(steps):
        state, metrics = step(state, next(data))
    jax.block_until_ready(metrics["loss"])
    return float(metrics["loss"])
