"""Serving launcher: the continuous-batching engine on a reduced config.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --requests 8

Knobs: ``--engine batched`` (one jitted decode over the stacked slot cache;
default) vs ``--engine oracle`` (the retained per-slot parity loop);
``--policy mirage_rns_noisy --snr-db 30 --noise-seed 7`` serves under the
analog channel with fresh noise per tick; ``--sample`` switches greedy
argmax to device-side categorical sampling.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.precision import get_policy
from repro.models import build_model
from repro.models.lm import LMCallOptions
from repro.runtime.server import LMServer, PerSlotLMServer, Request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-tokens", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--policy", default="mirage")
    ap.add_argument("--engine", choices=("batched", "oracle"),
                    default="batched")
    ap.add_argument("--snr-db", type=float, default=None,
                    help="serve through the analog channel at this SNR "
                         "(use with --policy mirage_rns_noisy/mirage_rrns)")
    ap.add_argument("--noise-seed", type=int, default=0,
                    help="base seed for per-tick analog noise")
    ap.add_argument("--sample", action="store_true",
                    help="categorical sampling instead of greedy argmax")
    args = ap.parse_args(argv)
    if args.engine == "oracle" and args.sample:
        ap.error("--sample needs the batched engine (the per-slot oracle "
                 "is greedy-only)")

    cfg = get_config(args.arch).reduced()
    overrides = {}
    if args.snr_db is not None:
        overrides.update(snr_db=args.snr_db, noise_seed=args.noise_seed)
    policy = get_policy(args.policy, **overrides)
    model = build_model(cfg, policy, LMCallOptions(q_chunk=32, kv_chunk=32))
    params = model.init(jax.random.PRNGKey(0))

    cap = args.prompt_len + args.max_tokens + 4
    if args.engine == "batched":
        server = LMServer(model, params, cap=cap, batch_slots=args.slots,
                          greedy=not args.sample)
    else:
        server = PerSlotLMServer(model, params, cap=cap,
                                 batch_slots=args.slots)
    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for rid in range(args.requests):
        server.submit(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab_size,
                                args.prompt_len).astype(np.int32),
            max_tokens=args.max_tokens))
    finished = server.run_until_drained()
    dt = time.perf_counter() - t0
    tot_toks = sum(len(r.tokens_out) for r in finished)
    ttfts = [r.t_first_token - r.t_enqueue for r in finished]
    print(f"[{args.engine}] served {len(finished)} requests, {tot_toks} "
          f"tokens in {dt:.2f}s ({tot_toks / dt:.1f} tok/s); "
          f"mean TTFT {np.mean(ttfts)*1e3:.1f}ms; "
          f"{server.metrics['ticks']} decode ticks")
    for r in finished[:3]:
        print(f"  req {r.rid}: {r.tokens_out[:8]}...")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
