"""Serving launcher: the continuous-batching engine on a reduced config.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --requests 8

Knobs: ``--engine batched`` (one jitted decode over the stacked slot cache;
default) vs ``--engine oracle`` (the retained per-slot parity loop);
``--cache-layout paged`` switches the per-slot KV rings to the block-table
page pool (``--block-size``/``--n-blocks`` size it — memory scales with
live tokens instead of slots x cap); ``--prefill-chunk N`` streams prompts
through the decode loop N tokens per tick (piggybacked prefill, paged
only) so long arrivals don't stall active streams;
``--policy mirage_rns_noisy --snr-db 30 --noise-seed 7`` serves under the
analog channel with fresh noise per tick; ``--sample`` switches greedy
argmax to device-side categorical sampling; ``--prefix-cache`` shares
matched whole-prompt-prefix blocks copy-on-write across slots (paged
only; ``--shared-prefix N`` makes the synthetic prompts actually share
their first N tokens so hits occur); ``--spec-k K`` self-drafts K tokens
per tick and verifies them in one jitted step (paged + greedy only,
token-identical to plain greedy decode).

Robustness: ``--chaos SPEC`` injects the seeded fault schedule (SNR
collapses, burst storms, stuck detector channels, block-pool squeezes,
prefill-worker crashes, host-transfer corruption — see
``repro.runtime.faults``); ``--guardian`` drains through the SNR guardian's
verify-before-commit windows (``repro.runtime.resilience``), escalating
RRNS redundancy and hard-falling-back to fp32 when the analog-health
counters report uncorrectable faults; ``--ttl-s`` / ``--queue-ttl-s`` give
requests decode/admission deadlines (terminal status ``timed_out``);
``--max-queue-depth`` caps admission (rejected with a retry-after hint);
``--max-retries`` bounds retries of fault-aborted requests.

Observability: ``--metrics-port P`` serves the engine's metrics registry
over HTTP (``/metrics`` Prometheus text, ``/metrics.json`` snapshot,
``/trace`` Chrome trace; port 0 picks a free one); ``--trace-export F``
enables the span tracer and writes a Chrome-trace JSON (load in
chrome://tracing or Perfetto) at exit; ``--profile-window DIR`` wraps the
run in a ``jax.profiler`` capture with GEMM-dispatch annotations;
``--metrics-dump F`` writes the final registry snapshot as JSON (the CI
artifact).
"""

from __future__ import annotations

import argparse
import contextlib
import json
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.precision import get_policy
from repro.models import build_model
from repro.models.lm import LMCallOptions
from repro.obs import trace as obs_trace
from repro.obs.http import MetricsServer
from repro.runtime.server import LMServer, PerSlotLMServer, Request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-tokens", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--policy", default="mirage")
    ap.add_argument("--engine", choices=("batched", "oracle"),
                    default="batched")
    ap.add_argument("--cache-layout", choices=("dense", "paged"),
                    default="dense",
                    help="paged = block-table KV pool (memory scales with "
                         "live tokens, not slots x cap)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="positions per KV block (paged layout)")
    ap.add_argument("--n-blocks", type=int, default=None,
                    help="block-pool size (default: slots * ceil(cap/block) "
                         "= no saving but never exhausts)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="stream prompts through decode ticks in chunks of "
                         "this many tokens (requires --cache-layout paged)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share matched prompt-prefix blocks copy-on-write "
                         "across slots (requires --cache-layout paged)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="first N prompt tokens identical across the "
                         "synthetic requests (makes --prefix-cache hit)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding: self-draft this many tokens "
                         "per tick, verify in one step (paged + greedy)")
    ap.add_argument("--mesh-data", type=int, default=1,
                    help="data-parallel mesh axis: shard slots (and paged "
                         "block pools) over this many devices")
    ap.add_argument("--mesh-model", type=int, default=1,
                    help="tensor-parallel mesh axis: Megatron-shard the "
                         "GEMMs over this many devices")
    ap.add_argument("--block-placement", choices=("locality", "round_robin"),
                    default="locality",
                    help="paged-pool block placement under a data-sharded "
                         "mesh: prefer same-shard blocks per slot "
                         "(locality) or rotate blindly (round_robin, the "
                         "baseline the benchmark gates against)")
    ap.add_argument("--warmup", action="store_true",
                    help="AOT-compile every (bucket, batch) prefill shape "
                         "plus tick/verify before traffic")
    ap.add_argument("--pipeline-depth", type=int, default=0,
                    help="overlap up to this many bucketed prefills with "
                         "decode on a worker thread (0 = synchronous)")
    ap.add_argument("--snr-db", type=float, default=None,
                    help="serve through the analog channel at this SNR "
                         "(use with --policy mirage_rns_noisy/mirage_rrns)")
    ap.add_argument("--noise-seed", type=int, default=0,
                    help="base seed for per-tick analog noise")
    ap.add_argument("--sample", action="store_true",
                    help="categorical sampling instead of greedy argmax")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve /metrics (Prometheus), /metrics.json and "
                         "/trace over HTTP on this port (0 = pick free)")
    ap.add_argument("--trace-export", default=None, metavar="FILE",
                    help="enable the span tracer and write Chrome-trace "
                         "JSON here at exit")
    ap.add_argument("--profile-window", default=None, metavar="LOGDIR",
                    help="capture a jax.profiler trace of the whole run "
                         "into this directory")
    ap.add_argument("--metrics-dump", default=None, metavar="FILE",
                    help="write the final metrics snapshot as JSON")
    ap.add_argument("--chaos", default=None, metavar="SPEC",
                    help="fault-injection schedule, e.g. "
                         "'snr_drop@4:12:scale=30;worker_crash@2;"
                         "pool_exhaustion@3:9:blocks=16' (see "
                         "repro.runtime.faults)")
    ap.add_argument("--chaos-seed", type=int, default=0,
                    help="seed for the host-side fault sites (replays "
                         "bit-identically)")
    ap.add_argument("--guardian", action="store_true",
                    help="drain through the SNR guardian: verify-before-"
                         "commit windows over the analog-health counters, "
                         "escalating RRNS redundancy and falling back to "
                         "fp32 (requires --policy mirage_rrns + --snr-db)")
    ap.add_argument("--guardian-window", type=int, default=4,
                    help="decode ticks per guarded verify window")
    ap.add_argument("--ttl-s", type=float, default=None,
                    help="per-request end-to-end deadline: requests still "
                         "decoding past it retire as timed_out")
    ap.add_argument("--queue-ttl-s", type=float, default=None,
                    help="admission deadline: requests still queued past "
                         "it retire as timed_out")
    ap.add_argument("--max-queue-depth", type=int, default=None,
                    help="admission cap: reject submissions (with a "
                         "retry-after hint) past this queue depth")
    ap.add_argument("--max-retries", type=int, default=1,
                    help="retry budget for fault-aborted requests")
    args = ap.parse_args(argv)
    if args.engine == "oracle" and args.sample:
        ap.error("--sample needs the batched engine (the per-slot oracle "
                 "is greedy-only)")
    if args.engine == "oracle" and (args.cache_layout != "dense" or
                                    args.prefill_chunk or args.prefix_cache
                                    or args.spec_k):
        ap.error("--cache-layout paged / --prefill-chunk / --prefix-cache / "
                 "--spec-k need the batched engine")
    if (args.prefix_cache or args.spec_k) and args.cache_layout != "paged":
        ap.error("--prefix-cache / --spec-k require --cache-layout paged")
    if args.spec_k and args.sample:
        ap.error("--spec-k verifies against greedy argmax; drop --sample")
    if args.engine == "oracle" and (args.mesh_data > 1 or args.mesh_model > 1
                                    or args.warmup or args.pipeline_depth):
        ap.error("--mesh-data/--mesh-model/--warmup/--pipeline-depth need "
                 "the batched engine")
    if args.engine == "oracle" and (args.chaos or args.guardian
                                    or args.max_queue_depth
                                    or args.ttl_s or args.queue_ttl_s):
        ap.error("--chaos/--guardian/--max-queue-depth/--ttl-s/--queue-ttl-s "
                 "need the batched engine")
    if args.guardian and args.policy != "mirage_rrns":
        ap.error("--guardian escalates RRNS redundancy; it needs "
                 "--policy mirage_rrns (plus --snr-db for a stochastic "
                 "channel worth guarding)")
    if args.guardian and args.pipeline_depth:
        ap.error("--guardian snapshots at window boundaries; drop "
                 "--pipeline-depth")

    mesh = None
    if args.mesh_data > 1 or args.mesh_model > 1:
        need = args.mesh_data * args.mesh_model
        if len(jax.devices()) < need:
            ap.error(
                f"mesh {args.mesh_data}x{args.mesh_model} needs {need} "
                f"devices but only {len(jax.devices())} are visible; on a "
                f"CPU box set "
                f"XLA_FLAGS=--xla_force_host_platform_device_count={need}")
        from repro.launch.mesh import make_debug_mesh
        mesh = make_debug_mesh(n_data=args.mesh_data,
                               n_model=args.mesh_model)

    cfg = get_config(args.arch).reduced()
    overrides = {}
    if args.snr_db is not None:
        overrides.update(snr_db=args.snr_db, noise_seed=args.noise_seed)
    policy = get_policy(args.policy, **overrides)
    model = build_model(cfg, policy, LMCallOptions(q_chunk=32, kv_chunk=32))
    params = model.init(jax.random.PRNGKey(0))

    injector = None
    if args.chaos:
        from repro.runtime.faults import FaultInjector, FaultSchedule
        schedule = FaultSchedule.parse(args.chaos)
        injector = FaultInjector(schedule, seed=args.chaos_seed)
        print(f"chaos: {schedule.describe()} (seed {args.chaos_seed})")

    cap = args.prompt_len + args.max_tokens + 4
    if args.engine == "batched":
        server = LMServer(model, params, cap=cap, batch_slots=args.slots,
                          greedy=not args.sample,
                          cache_layout=args.cache_layout,
                          block_size=args.block_size,
                          n_blocks=args.n_blocks,
                          prefill_chunk=args.prefill_chunk,
                          prefix_cache=args.prefix_cache,
                          spec_k=args.spec_k,
                          mesh=mesh,
                          pipeline_depth=args.pipeline_depth,
                          block_placement=args.block_placement,
                          fault_injector=injector,
                          max_queue_depth=args.max_queue_depth,
                          default_ttl_s=args.ttl_s,
                          default_queue_ttl_s=args.queue_ttl_s,
                          max_retries=args.max_retries)
        if mesh is not None:
            print(f"mesh: data={args.mesh_data} x model={args.mesh_model} "
                  f"({len(mesh.devices.flat)} devices); allocator shards="
                  f"{server.alloc.n_shards if server.alloc else 1} "
                  f"placement={args.block_placement}")
        if args.warmup:
            w = server.warmup()
            print(f"warmup: {w['compiled']:.0f} shapes compiled in "
                  f"{w['seconds']:.1f}s")
    else:
        server = PerSlotLMServer(model, params, cap=cap,
                                 batch_slots=args.slots)
    if args.trace_export:
        obs_trace.configure(enabled=True)
    tracer = obs_trace.get_tracer()
    http_srv = None
    if args.metrics_port is not None:
        registry = getattr(server, "scheduler", None)
        registry = registry.registry if registry is not None else None
        http_srv = MetricsServer(port=args.metrics_port, registry=registry,
                                 tracer=tracer)
        http_srv.start()
        print(f"metrics at {http_srv.url}/metrics (json: /metrics.json, "
              f"trace: /trace)")

    rng = np.random.default_rng(0)
    shared = rng.integers(0, cfg.vocab_size,
                          min(args.shared_prefix,
                              args.prompt_len)).astype(np.int32)
    t0 = time.perf_counter()
    for rid in range(args.requests):
        tail = rng.integers(0, cfg.vocab_size,
                            args.prompt_len - len(shared)).astype(np.int32)
        server.submit(Request(
            rid=rid,
            prompt=np.concatenate([shared, tail]),
            max_tokens=args.max_tokens))
    guardian = None
    if args.guardian:
        from repro.runtime.resilience import SNRGuardian
        guardian = SNRGuardian(server, window=args.guardian_window)
        drain = guardian.run_until_drained
    else:
        drain = server.run_until_drained
    profile_cm = (obs_trace.profile_window(args.profile_window, tracer)
                  if args.profile_window else contextlib.nullcontext())
    with profile_cm:
        finished = drain()
        finished = (server.scheduler.finished
                    if getattr(server, "scheduler", None) is not None
                    else finished)
    dt = time.perf_counter() - t0
    tot_toks = sum(len(r.tokens_out) for r in finished)
    # only requests that actually streamed have a TTFT (a chaos run can
    # time out / reject everything — the summary must not NaN)
    ttfts = [r.t_first_token - r.t_enqueue for r in finished
             if r.t_first_token > 0]
    mean_ttft_ms = float(np.mean(ttfts)) * 1e3 if ttfts else 0.0
    print(f"[{args.engine}] served {len(finished)} requests, {tot_toks} "
          f"tokens in {dt:.2f}s ({tot_toks / dt:.1f} tok/s); "
          f"mean TTFT {mean_ttft_ms:.1f}ms; "
          f"{server.metrics['ticks']} decode ticks")
    if getattr(server, "scheduler", None) is not None:
        by_status = {}
        for r in finished:
            by_status[r.status] = by_status.get(r.status, 0) + 1
        print(f"  terminal statuses: {by_status}")
    if guardian is not None:
        print(f"  guardian: level {guardian.level} "
              f"({len(guardian.transitions)} transitions)")
        for t in guardian.transitions:
            print(f"    {t}")
    if injector is not None and injector.log:
        print(f"  chaos log ({len(injector.log)} events):")
        for line in injector.log[:20]:
            print(f"    {line}")
    if getattr(server, "alloc", None) is not None:
        a = server.alloc
        print(f"  paged KV: block_size={a.block_size}, pool={a.n_blocks} "
              f"blocks, peak in use {a.peak_in_use} "
              f"({a.peak_in_use / a.n_blocks:.0%})")
        if a.n_shards > 1:
            print(f"  block locality ({a.placement}): "
                  f"{a.local_allocs} local / {a.spilled_allocs} spilled "
                  f"allocs; remote-gather fraction "
                  f"{a.remote_fraction():.2f}; free by shard "
                  f"{a.free_by_shard()}")
    m = server.metrics
    if args.prefix_cache:
        print(f"  prefix cache: {m['prefix_hits']} hits "
              f"({m['prefix_full_hits']} full), "
              f"{m['prefix_shared_blocks']} blocks shared")
    if args.spec_k:
        per = m["spec_accepted"] / max(m["spec_slot_ticks"], 1)
        print(f"  speculative k={args.spec_k}: {m['spec_accepted']} tokens "
              f"accepted over {m['spec_slot_ticks']} slot-ticks "
              f"({per:.2f}/tick)")
    if getattr(server, "scheduler", None) is not None:
        lat = server.scheduler.latency_summary()
        print(f"  TTFT p50/p95/p99: {lat['ttft_p50_s']*1e3:.1f}/"
              f"{lat['ttft_p95_s']*1e3:.1f}/{lat['ttft_p99_s']*1e3:.1f}ms; "
              f"TPOT p50/p95/p99: {lat['tpot_p50_s']*1e3:.1f}/"
              f"{lat['tpot_p95_s']*1e3:.1f}/{lat['tpot_p99_s']*1e3:.1f}ms")
        health = server.health_snapshot()
        if health:
            print(f"  analog health: {health}")
    for r in finished[:3]:
        print(f"  req {r.rid}: {r.tokens_out[:8]}...")

    if http_srv is not None:
        # self-scrape: prove the exposition endpoint round-trips before exit
        import urllib.request
        with urllib.request.urlopen(f"{http_srv.url}/metrics",
                                    timeout=5) as resp:
            n_series = sum(1 for ln in resp.read().decode().splitlines()
                           if ln and not ln.startswith("#"))
        print(f"  scraped {n_series} series from {http_srv.url}/metrics")
    if args.metrics_dump and getattr(server, "scheduler", None) is not None:
        with open(args.metrics_dump, "w") as f:
            json.dump(server.scheduler.registry.snapshot(), f, indent=2)
        print(f"  metrics snapshot -> {args.metrics_dump}")
    if args.trace_export:
        tracer.export(args.trace_export)
        print(f"  chrome trace ({tracer.n_recorded} spans) -> "
              f"{args.trace_export}")
    if http_srv is not None:
        http_srv.stop()
    if hasattr(server, "close"):
        server.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
