"""Serving launcher: the continuous-batching engine on a reduced config.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --requests 8

Knobs: ``--engine batched`` (one jitted decode over the stacked slot cache;
default) vs ``--engine oracle`` (the retained per-slot parity loop);
``--cache-layout paged`` switches the per-slot KV rings to the block-table
page pool (``--block-size``/``--n-blocks`` size it — memory scales with
live tokens instead of slots x cap); ``--prefill-chunk N`` streams prompts
through the decode loop N tokens per tick (piggybacked prefill, paged
only) so long arrivals don't stall active streams;
``--policy mirage_rns_noisy --snr-db 30 --noise-seed 7`` serves under the
analog channel with fresh noise per tick; ``--sample`` switches greedy
argmax to device-side categorical sampling; ``--prefix-cache`` shares
matched whole-prompt-prefix blocks copy-on-write across slots (paged
only; ``--shared-prefix N`` makes the synthetic prompts actually share
their first N tokens so hits occur); ``--spec-k K`` self-drafts K tokens
per tick and verifies them in one jitted step (paged + greedy only,
token-identical to plain greedy decode).
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core.precision import get_policy
from repro.models import build_model
from repro.models.lm import LMCallOptions
from repro.runtime.server import LMServer, PerSlotLMServer, Request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-tokens", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--policy", default="mirage")
    ap.add_argument("--engine", choices=("batched", "oracle"),
                    default="batched")
    ap.add_argument("--cache-layout", choices=("dense", "paged"),
                    default="dense",
                    help="paged = block-table KV pool (memory scales with "
                         "live tokens, not slots x cap)")
    ap.add_argument("--block-size", type=int, default=16,
                    help="positions per KV block (paged layout)")
    ap.add_argument("--n-blocks", type=int, default=None,
                    help="block-pool size (default: slots * ceil(cap/block) "
                         "= no saving but never exhausts)")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="stream prompts through decode ticks in chunks of "
                         "this many tokens (requires --cache-layout paged)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="share matched prompt-prefix blocks copy-on-write "
                         "across slots (requires --cache-layout paged)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="first N prompt tokens identical across the "
                         "synthetic requests (makes --prefix-cache hit)")
    ap.add_argument("--spec-k", type=int, default=0,
                    help="speculative decoding: self-draft this many tokens "
                         "per tick, verify in one step (paged + greedy)")
    ap.add_argument("--snr-db", type=float, default=None,
                    help="serve through the analog channel at this SNR "
                         "(use with --policy mirage_rns_noisy/mirage_rrns)")
    ap.add_argument("--noise-seed", type=int, default=0,
                    help="base seed for per-tick analog noise")
    ap.add_argument("--sample", action="store_true",
                    help="categorical sampling instead of greedy argmax")
    args = ap.parse_args(argv)
    if args.engine == "oracle" and args.sample:
        ap.error("--sample needs the batched engine (the per-slot oracle "
                 "is greedy-only)")
    if args.engine == "oracle" and (args.cache_layout != "dense" or
                                    args.prefill_chunk or args.prefix_cache
                                    or args.spec_k):
        ap.error("--cache-layout paged / --prefill-chunk / --prefix-cache / "
                 "--spec-k need the batched engine")
    if (args.prefix_cache or args.spec_k) and args.cache_layout != "paged":
        ap.error("--prefix-cache / --spec-k require --cache-layout paged")
    if args.spec_k and args.sample:
        ap.error("--spec-k verifies against greedy argmax; drop --sample")

    cfg = get_config(args.arch).reduced()
    overrides = {}
    if args.snr_db is not None:
        overrides.update(snr_db=args.snr_db, noise_seed=args.noise_seed)
    policy = get_policy(args.policy, **overrides)
    model = build_model(cfg, policy, LMCallOptions(q_chunk=32, kv_chunk=32))
    params = model.init(jax.random.PRNGKey(0))

    cap = args.prompt_len + args.max_tokens + 4
    if args.engine == "batched":
        server = LMServer(model, params, cap=cap, batch_slots=args.slots,
                          greedy=not args.sample,
                          cache_layout=args.cache_layout,
                          block_size=args.block_size,
                          n_blocks=args.n_blocks,
                          prefill_chunk=args.prefill_chunk,
                          prefix_cache=args.prefix_cache,
                          spec_k=args.spec_k)
    else:
        server = PerSlotLMServer(model, params, cap=cap,
                                 batch_slots=args.slots)
    rng = np.random.default_rng(0)
    shared = rng.integers(0, cfg.vocab_size,
                          min(args.shared_prefix,
                              args.prompt_len)).astype(np.int32)
    t0 = time.perf_counter()
    for rid in range(args.requests):
        tail = rng.integers(0, cfg.vocab_size,
                            args.prompt_len - len(shared)).astype(np.int32)
        server.submit(Request(
            rid=rid,
            prompt=np.concatenate([shared, tail]),
            max_tokens=args.max_tokens))
    finished = server.run_until_drained()
    dt = time.perf_counter() - t0
    tot_toks = sum(len(r.tokens_out) for r in finished)
    ttfts = [r.t_first_token - r.t_enqueue for r in finished]
    print(f"[{args.engine}] served {len(finished)} requests, {tot_toks} "
          f"tokens in {dt:.2f}s ({tot_toks / dt:.1f} tok/s); "
          f"mean TTFT {np.mean(ttfts)*1e3:.1f}ms; "
          f"{server.metrics['ticks']} decode ticks")
    if getattr(server, "alloc", None) is not None:
        a = server.alloc
        print(f"  paged KV: block_size={a.block_size}, pool={a.n_blocks} "
              f"blocks, peak in use {a.peak_in_use} "
              f"({a.peak_in_use / a.n_blocks:.0%})")
    m = server.metrics
    if args.prefix_cache:
        print(f"  prefix cache: {m['prefix_hits']} hits "
              f"({m['prefix_full_hits']} full), "
              f"{m['prefix_shared_blocks']} blocks shared")
    if args.spec_k:
        per = m["spec_accepted"] / max(m["spec_slot_ticks"], 1)
        print(f"  speculative k={args.spec_k}: {m['spec_accepted']} tokens "
              f"accepted over {m['spec_slot_ticks']} slot-ticks "
              f"({per:.2f}/tick)")
    for r in finished[:3]:
        print(f"  req {r.rid}: {r.tokens_out[:8]}...")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
