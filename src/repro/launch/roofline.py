"""Roofline accounting from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), in seconds (TPU v5e-class constants):

  compute    = HLO_FLOPs / (chips * 197 TFLOP/s bf16)
  memory     = HLO_bytes / (chips * 819 GB/s HBM)
  collective = wire_bytes / (chips * 50 GB/s ICI per link)

``compiled.cost_analysis()`` reports the per-device partitioned module, so
per-device flops/bytes divide by the single-chip peak directly (equivalently,
HLO_FLOPs = per_device * chips). Collective bytes are NOT in cost_analysis:
we parse the optimized HLO and charge each collective its ring wire cost:

  all-reduce      2 * (G-1)/G * bytes
  all-gather          (G-1)/G * bytes(output)
  reduce-scatter      (G-1)/G * bytes(input)  ~= (G-1) * bytes(output)
  all-to-all          (G-1)/G * bytes
  collective-permute  bytes

where G is the replica-group size parsed from the instruction.
"""

from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

PEAK_FLOPS = 197e12          # bf16 per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|s32|s16|s8|"
                       r"u64|u32|u16|u8|pred|c64|c128)\[([0-9,]*)\]")
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_LIST_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _LIST_GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return default


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    raw_bytes: Dict[str, int]     # per-device payload bytes (output side)
    wire_bytes: Dict[str, float]  # ring-cost wire bytes

    @property
    def total_wire(self) -> float:
        return sum(self.wire_bytes.values())

    @property
    def total_raw(self) -> int:
        return sum(self.raw_bytes.values())


def parse_collectives(hlo_text: str, default_group: int = 1) -> CollectiveStats:
    counts: Dict[str, int] = {}
    raw: Dict[str, int] = {}
    wire: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        lhs, _, rhs = s.partition("=")
        rhs = rhs.strip()
        op = None
        for c in _COLLECTIVES:
            # match "<shape> <op>(" — avoids -start/-done fragments double count
            if re.search(rf"\s{c}(\.\d+)?\(", " " + rhs) or rhs.startswith(c + "("):
                op = c
                break
        if op is None:
            continue
        if f" {op}-done" in rhs or rhs.startswith(f"{op}-done"):
            continue
        shape_part = rhs.split(op)[0]
        nbytes = _shape_bytes(shape_part)
        if nbytes == 0:
            continue
        G = _group_size(s, default_group)
        if op == "all-reduce":
            w = 2.0 * (G - 1) / G * nbytes
        elif op == "all-gather":
            w = (G - 1) / G * nbytes
        elif op == "reduce-scatter":
            w = (G - 1) * nbytes          # input = G * output
        elif op == "all-to-all":
            w = (G - 1) / G * nbytes
        else:                              # collective-permute
            w = float(nbytes)
        counts[op] = counts.get(op, 0) + 1
        raw[op] = raw.get(op, 0) + nbytes
        wire[op] = wire.get(op, 0.0) + w
    return CollectiveStats(counts, raw, wire)


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    per_device_flops: float
    per_device_bytes: float
    collectives: CollectiveStats
    model_flops: float = 0.0           # 6ND / 2ND analytic (global)
    peak_memory_bytes: float = 0.0     # per device (memory_analysis)

    @property
    def compute_s(self) -> float:
        return self.per_device_flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.per_device_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.collectives.total_wire / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def hlo_flops_global(self) -> float:
        return self.per_device_flops * self.chips

    @property
    def useful_flop_ratio(self) -> float:
        if self.hlo_flops_global == 0:
            return 0.0
        return self.model_flops / self.hlo_flops_global

    @property
    def bound_time_s(self) -> float:
        """Roofline-ideal step time = max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """Fraction of ideal: useful compute time / achievable step time.

        useful time = MODEL_FLOPS / (chips * peak). Equals MFU when
        compute-dominated and everything overlaps perfectly."""
        if self.bound_time_s == 0:
            return 0.0
        useful = self.model_flops / (self.chips * PEAK_FLOPS)
        return useful / self.bound_time_s

    def to_dict(self) -> Dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "per_device_flops": self.per_device_flops,
            "per_device_bytes": self.per_device_bytes,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "collective_wire_bytes": self.collectives.total_wire,
            "collective_counts": self.collectives.counts,
            "collective_raw_bytes": self.collectives.raw_bytes,
            "dominant": self.dominant,
            "model_flops": self.model_flops,
            "hlo_flops_global": self.hlo_flops_global,
            "useful_flop_ratio": self.useful_flop_ratio,
            "roofline_fraction": self.roofline_fraction,
            "peak_memory_bytes": self.peak_memory_bytes,
        }


def count_params(abstract_params) -> int:
    import jax
    return sum(int(math.prod(l.shape))
               for l in jax.tree_util.tree_leaves(abstract_params))


def model_flops_estimate(cfg, shape, n_params: int) -> float:
    """6*N*D (train) / 2*N*D (prefill) / 2*N*B (decode), with N = active
    params for MoE (experts scaled by k/E)."""
    n_active = n_params
    if cfg.n_experts > 0:
        expert_params = (cfg.n_layers * cfg.n_experts * 3
                         * cfg.d_model * cfg.moe_d_ff)
        n_active = (n_params - expert_params
                    + expert_params * cfg.experts_per_token / cfg.n_experts)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        if cfg.is_encdec:
            tokens = shape.global_batch * (shape.seq_len + shape.seq_len // 8)
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch
