"""Render dry-run JSON results into the EXPERIMENTS.md roofline tables."""

from __future__ import annotations

import json
import sys


def fmt_bytes(b):
    for unit in ("B", "KB", "MB", "GB", "TB", "PB"):
        if abs(b) < 1024:
            return f"{b:.1f}{unit}"
        b /= 1024
    return f"{b:.1f}EB"


def render(path: str, mesh_filter: str = None) -> str:
    rows = json.load(open(path))
    out = []
    out.append("| arch | shape | mesh | chips | compute s | memory s | "
               "collective s | dominant | MODEL_FLOPS | useful/HLO | "
               "roofline frac |")
    out.append("|---|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r.get("status") == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — | "
                       f"SKIP: {r['reason']} | — | — | — |")
            continue
        if r.get("status") != "ok":
            out.append(f"| {r['arch']} | {r['shape']} | {r.get('mesh')} | — "
                       f"| ERROR | | | | | | |")
            continue
        if mesh_filter and mesh_filter not in r["mesh"]:
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | "
            f"{'multi' if 'multi' in r['mesh'] else 'single'} | {r['chips']} "
            f"| {r['compute_s']:.4f} | {r['memory_s']:.4f} "
            f"| {r['collective_s']:.4f} | **{r['dominant']}** "
            f"| {r['model_flops']:.2e} | {r['useful_flop_ratio']:.3f} "
            f"| {r['roofline_fraction']:.4f} |")
    return "\n".join(out)


def render_collectives(path: str) -> str:
    rows = json.load(open(path))
    out = ["| arch | shape | mesh | collective counts | wire bytes/device |",
           "|---|---|---|---|---|"]
    for r in rows:
        if r.get("status") != "ok":
            continue
        cc = ", ".join(f"{k}:{v}" for k, v in
                       sorted(r.get("collective_counts", {}).items()))
        out.append(f"| {r['arch']} | {r['shape']} | "
                   f"{'multi' if 'multi' in r['mesh'] else 'single'} "
                   f"| {cc} | {fmt_bytes(r['collective_wire_bytes'])} |")
    return "\n".join(out)


if __name__ == "__main__":
    print(render(sys.argv[1] if len(sys.argv) > 1
                 else "results/dryrun_baseline.json"))
