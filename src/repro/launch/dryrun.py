import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x mesh)
cell on 512 placeholder host devices and extract the roofline terms.

  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both --out dryrun.json

Nothing is allocated: inputs and train state are ShapeDtypeStructs and the
cell is judged by ``.lower().compile()`` succeeding, plus memory_analysis()
(fits per-chip HBM) and cost_analysis() (FLOPs/bytes for the roofline).
"""

import argparse      # noqa: E402
import json          # noqa: E402
import sys           # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import (ARCHS, SHAPES, cell_is_skipped, get_config,  # noqa: E402
                           get_shape)
from repro.configs.base import TrainConfig  # noqa: E402
from repro.core.precision import get_policy  # noqa: E402
from repro.launch import hlo_analysis as ha  # noqa: E402
from repro.launch import roofline as rl  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.models import build_model, input_specs  # noqa: E402
from repro.models.lm import LMCallOptions  # noqa: E402
from repro.parallel import sharding as sh  # noqa: E402
from repro.runtime.trainer import abstract_train_state, make_train_step  # noqa: E402


def options_for(cfg, shape, mesh, *, perf_level: int = 0,
                moe_impl: str = None) -> LMCallOptions:
    """Per-cell call options. perf_level selects hillclimb variants (see
    EXPERIMENTS.md Section Perf); 0 = baseline."""
    mesh_sizes = tuple((name, int(mesh.shape[name])) for name in mesh.axis_names)
    return LMCallOptions(
        kv_repeat=sh.kv_repeat_for(cfg, mesh),
        q_chunk=2048 if shape.seq_len >= 32768 else 1024,
        kv_chunk=2048 if shape.seq_len >= 32768 else 1024,
        remat=(shape.kind == "train"),
        carry_dtype="bfloat16" if shape.kind == "train" else "float32",
        ce_chunk=4096 if shape.kind == "train" else 0,
        # attn bf16 scores: REFUTED (convert boundaries added traffic;
        # see EXPERIMENTS.md §Perf iteration 2b) — kept off.
        merge_parallel_proj=perf_level >= 3,
        moe_impl=(moe_impl if moe_impl is not None else
                  ("ep_shard_map" if perf_level >= 5 else "gspmd")),
        act_dp=sh.dp_axes(mesh),
        act_tp="model",
        mesh_sizes=mesh_sizes,
    )


def train_cfg_for(cfg, shape, mesh, policy, perf_level: int = 0) -> TrainConfig:
    dp_total = 1
    for ax in sh.dp_axes(mesh):
        dp_total *= mesh.shape[ax]
    per_dev_batch = max(shape.global_batch // dp_total, 1)
    # microbatch so one microbatch holds ~1 sequence per device for big models
    nmb = per_dev_batch if cfg.d_model >= 8192 else (
        max(per_dev_batch // 4, 1) if cfg.d_model >= 2048 else 1)
    if perf_level >= 3 and cfg.d_model >= 8192:
        nmb = max(per_dev_batch // 2, 1)   # iteration 3: fewer weight passes
    # microbatch count must divide the global batch
    while shape.global_batch % nmb:
        nmb -= 1
    return TrainConfig(
        policy=policy, optimizer="adamw", microbatches=nmb,
        weight_stationary_quant=perf_level >= 1,
        quant_param_dtype="bfloat16" if perf_level >= 2 else "float32")


def policy_for(policy_name: str, shape, perf_level: int):
    """Perf-level ladder (EXPERIMENTS.md §Perf):
      0: paper-faithful baseline — per-GEMM BFP quantization, f32 folded ops
      1: weight-stationary quantization (quantize W once/step; grid reused
         across microbatches, remat, and the transposed dX read)
      2: + bf16 storage/compute for the folded operands (value-identical:
         BFP(b_m<=6) grid values are exact in bfloat16)
      3: + schedule/structural tuning (microbatches; MoE capacity 1.0;
         SSD chunk 128; merged parallel-block projection)
      4: + mesh aspect (data=32, model=8) for single-pod cells
      5: + shard_map expert-parallel MoE dispatch; SSD chunk 64
      6: SSD chunk 32
    """
    from repro.core import backends

    policy = get_policy(policy_name)
    ws_capable = backends.resolve(policy).supports_weight_stationary
    if perf_level >= 1 and ws_capable:
        policy = policy.replace(assume_quantized_weights=(shape.kind == "train"))
    if perf_level >= 2 and ws_capable:
        policy = policy.replace(compute_dtype="bfloat16")
    return policy


def lower_cell(arch_id: str, shape_name: str, multi_pod: bool,
               policy_name: str = "mirage", perf_level: int = 0,
               moe_impl: str = None, mesh_override: str = None):
    import dataclasses as _dc
    cfg = get_config(arch_id)
    if perf_level >= 3:
        # per-family structural moves (EXPERIMENTS.md Perf iteration 3):
        #   moe: capacity 1.25 -> 1.0 (dispatch buffers + combine wire -20%)
        #   ssm: SSD chunk 256 -> 128 (L-matrix traffic ~ B*H*L*Q halves)
        if cfg.n_experts:
            cfg = _dc.replace(cfg, capacity_factor=1.0)
        if cfg.ssm_state:
            cfg = _dc.replace(cfg, ssm_chunk={5: 64, 6: 32}.get(perf_level, 128) if perf_level >= 5 else 128)
    shape = get_shape(shape_name)
    if mesh_override == "16x16":
        mesh = make_production_mesh(multi_pod=False)
    elif (perf_level >= 4 and not multi_pod) or mesh_override == "32x8":
        # iteration 4: mesh aspect ratio. Same 256 chips as (data=16,model=16)
        # but (data=32, model=8): FSDP all-gather wire per device is
        # (G-1)/G * N/tp and N/tp doubles DOWN as tp halves -> weight-gather
        # volume ~halves; TP all-reduce payload changes only by (7/8)/(15/16).
        import jax as _jax
        mesh = _jax.make_mesh((32, 8), ("data", "model"))
    else:
        mesh = make_production_mesh(multi_pod=multi_pod)
    policy = policy_for(policy_name, shape, perf_level)
    opts = options_for(cfg, shape, mesh, perf_level=perf_level,
                       moe_impl=moe_impl)
    model = build_model(cfg, policy, opts)
    specs = input_specs(cfg, shape, opts)

    with mesh:
        if shape.kind == "train":
            tc = train_cfg_for(cfg, shape, mesh, policy, perf_level)
            state = abstract_train_state(model, tc)
            state_sh = sh.train_state_shardings(mesh, cfg, state)
            batch_sh = sh.batch_shardings(mesh, cfg, specs)
            step = make_train_step(model, tc)
            jitted = jax.jit(
                step,
                in_shardings=(state_sh, batch_sh),
                out_shardings=(state_sh, None),
                donate_argnums=(0,))
            lowered = jitted.lower(state, specs)
        elif shape.kind == "prefill":
            params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
            params_sh = sh.param_shardings(mesh, cfg, params)
            batch_sh = sh.batch_shardings(mesh, cfg, specs)
            cap = shape.seq_len + 64 if not cfg.is_encdec else \
                max(shape.seq_len // 8, 16) + 64

            if cfg.is_encdec:
                def prefill_step(p, batch):
                    return model.prefill(p, batch["frames"], batch["tokens"],
                                         cap)
            elif cfg.frontend == "vit_stub":
                def prefill_step(p, batch):
                    return model.prefill(p, batch["tokens"], cap,
                                         extra_embeds=batch["patches"])
            else:
                def prefill_step(p, batch):
                    return model.prefill(p, batch["tokens"], cap)

            jitted = jax.jit(prefill_step,
                             in_shardings=(params_sh, batch_sh))
            lowered = jitted.lower(params, specs)
        else:  # decode
            params = jax.eval_shape(model.init, jax.random.PRNGKey(0))
            params_sh = sh.param_shardings(mesh, cfg, params)
            cache_sh = sh.batch_shardings(mesh, cfg, specs["cache"])
            tok_sh = sh.batch_shardings(
                mesh, cfg, {"tokens": specs["tokens"]})["tokens"]

            def serve_step(p, cache, tokens):
                return model.decode_step(p, cache, tokens)

            jitted = jax.jit(
                serve_step,
                in_shardings=(params_sh, cache_sh, tok_sh),
                out_shardings=(None, cache_sh),
                donate_argnums=(1,))
            lowered = jitted.lower(params, specs["cache"], specs["tokens"])

        compiled = lowered.compile()
    return cfg, shape, mesh, model, lowered, compiled


def analyze_cell(arch_id: str, shape_name: str, multi_pod: bool,
                 policy_name: str = "mirage", perf_level: int = 0,
                 keep_hlo: bool = False):
    t0 = time.time()
    cfg, shape, mesh, model, lowered, compiled = lower_cell(
        arch_id, shape_name, multi_pod, policy_name, perf_level)
    chips = mesh.size

    # cost_analysis counts while bodies ONCE (verified; see EXPERIMENTS.md) —
    # kept as auxiliary evidence. Primary numbers come from the loop-aware
    # HLO analyzer (launch/hlo_analysis.py) over the compiled text.
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # older jax: list of per-program dicts
        cost = cost[0] if cost else {}
    ca_flops = float(cost.get("flops", 0.0))
    ca_bytes = float(cost.get("bytes accessed", 0.0))

    try:
        mem = compiled.memory_analysis()
        peak = float(getattr(mem, "temp_size_in_bytes", 0) +
                     getattr(mem, "argument_size_in_bytes", 0) +
                     getattr(mem, "output_size_in_bytes", 0) -
                     getattr(mem, "alias_size_in_bytes", 0))
        mem_str = str(mem)
    except Exception as e:  # CPU backend may not implement it
        peak, mem_str = 0.0, f"unavailable: {e}"

    hlo = compiled.as_text()
    hc = ha.analyze_hlo(hlo, default_group=chips)
    stats = rl.CollectiveStats(
        counts={k: int(v) for k, v in hc.coll_counts.items()},
        raw_bytes={k: int(v) for k, v in hc.coll_raw_bytes.items()},
        wire_bytes=dict(hc.coll_wire_bytes))

    params_abs = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    n_params = rl.count_params(params_abs)
    mflops = rl.model_flops_estimate(cfg, shape, n_params)

    roof = rl.Roofline(
        arch=arch_id, shape=shape_name,
        mesh="multi_pod_2x16x16" if multi_pod else "single_pod_16x16",
        chips=chips, per_device_flops=hc.flops, per_device_bytes=hc.hbm_bytes,
        collectives=stats, model_flops=mflops, peak_memory_bytes=peak)
    out = roof.to_dict()
    out.update(n_params=n_params, policy=policy_name,
               compile_seconds=round(time.time() - t0, 1),
               cost_analysis_flops=ca_flops, cost_analysis_bytes=ca_bytes,
               n_while=hc.n_while, max_trip=hc.max_trip,
               memory_analysis=mem_str[:2000],
               hlo_bytes=len(hlo))
    if keep_hlo:
        out["hlo_text"] = hlo
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--policy", default="mirage")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--perf-level", type=int, default=0)
    args = ap.parse_args(argv)

    archs = sorted(ARCHS) if (args.all or args.arch is None) else [args.arch]
    shapes = sorted(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    results = []
    for arch in archs:
        for shp in shapes:
            skip = cell_is_skipped(arch, shp)
            if skip:
                results.append({"arch": arch, "shape": shp, "status": "skipped",
                                "reason": skip})
                print(f"[skip] {arch} x {shp}: {skip}", flush=True)
                continue
            for mp in meshes:
                tag = f"{arch} x {shp} x {'multi' if mp else 'single'}"
                try:
                    r = analyze_cell(arch, shp, mp, args.policy,
                                     args.perf_level)
                    r["status"] = "ok"
                    results.append(r)
                    print(f"[ok]   {tag}: compute={r['compute_s']:.4f}s "
                          f"memory={r['memory_s']:.4f}s "
                          f"collective={r['collective_s']:.4f}s "
                          f"dominant={r['dominant']} "
                          f"(compile {r['compile_seconds']}s)", flush=True)
                except Exception as e:
                    results.append({"arch": arch, "shape": shp,
                                    "mesh": "multi" if mp else "single",
                                    "status": "error", "error": str(e)[:2000]})
                    print(f"[FAIL] {tag}: {e}", flush=True)
                    traceback.print_exc()
                if args.out:
                    with open(args.out, "w") as f:
                        json.dump(results, f, indent=1, default=str)
    n_err = sum(1 for r in results if r.get("status") == "error")
    print(f"done: {len(results)} cells, {n_err} errors", flush=True)
    return 1 if n_err else 0


if __name__ == "__main__":
    sys.exit(main())
