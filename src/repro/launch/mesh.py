"""Production mesh construction.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import to materialize the placeholder devices.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips.
    Multi-pod: (pod=2, data=16, model=16) = 512 chips; the pod axis is the
    outer data-parallel (DCN) axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(n_data: int = 2, n_model: int = 2, pods: int = 0):
    """Small mesh for CI-scale multi-device tests (subprocess with a handful
    of forced host devices)."""
    if pods:
        return jax.make_mesh((pods, n_data, n_model), ("pod", "data", "model"))
    return jax.make_mesh((n_data, n_model), ("data", "model"))
