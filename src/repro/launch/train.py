"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --steps 100 \
      --reduced --policy mirage --ckpt-dir /tmp/ckpt

On a real cluster this process runs per host under
``jax.distributed.initialize()`` (flag --distributed); in this container it
drives the same code on one CPU device with reduced configs.

Recommended XLA flags for real TPU runs (latency-hiding overlap of the FSDP
all-gathers and gradient reduce-scatters with compute):
  --xla_tpu_enable_latency_hiding_scheduler=true
  --xla_tpu_overlap_compute_collective_tc=true
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config
from repro.configs.base import TrainConfig
from repro.core.precision import get_policy
from repro.data.pipeline import SyntheticLM, SyntheticLMConfig, with_extras
from repro.models import build_model
from repro.models.lm import LMCallOptions
from repro.runtime.elastic import (PreemptionGuard, StragglerMitigator,
                                   fault_tolerant_train_loop)
from repro.runtime.trainer import init_train_state


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--policy", default="mirage",
                    help="fp32|bf16|int8|mirage|mirage_faithful|mirage_rns|"
                         "mirage_rns_noisy|mirage_rrns")
    ap.add_argument("--snr-db", type=float, default=None,
                    help="detector SNR for the analog-channel policies")
    ap.add_argument("--noise-seed", type=int, default=None,
                    help="static per-GEMM-site error pattern seed for "
                         "keyless noisy training")
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-scale)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--grad-compression", default="none", choices=["none", "bfp"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--distributed", action="store_true")
    ap.add_argument("--trace-export", default=None, metavar="FILE",
                    help="enable the span tracer (train.step / "
                         "train.data_next / train.host_sync) and write a "
                         "Chrome-trace JSON here at exit")
    args = ap.parse_args(argv)

    if args.distributed:
        jax.distributed.initialize()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    overrides = {}
    if args.snr_db is not None:
        overrides["snr_db"] = args.snr_db
    if args.noise_seed is not None:
        overrides["noise_seed"] = args.noise_seed
    policy = get_policy(args.policy, **overrides)
    tc = TrainConfig(policy=policy, optimizer=args.optimizer, lr=args.lr,
                     microbatches=args.microbatches,
                     grad_compression=args.grad_compression, seed=args.seed)
    model = build_model(cfg, policy, LMCallOptions(q_chunk=64, kv_chunk=64))

    data = with_extras(
        SyntheticLM(SyntheticLMConfig(
            vocab_size=cfg.vocab_size, seq_len=args.seq,
            batch_size=args.batch, seed=args.seed,
            shard_id=jax.process_index(), num_shards=jax.process_count())),
        cfg)

    state = init_train_state(model, tc, jax.random.PRNGKey(args.seed))
    ckpt = Checkpointer(args.ckpt_dir) if args.ckpt_dir else None
    if ckpt and args.resume and ckpt.latest_step() is not None:
        state, meta = ckpt.restore(state)
        print(f"resumed from step {int(state['step'])}")

    if args.trace_export:
        from repro.obs import trace as obs_trace
        obs_trace.configure(enabled=True)

    t0 = time.time()
    if ckpt:
        state, metrics = fault_tolerant_train_loop(
            model, tc, state, iter(data), args.steps, ckpt,
            ckpt_every=args.ckpt_every, guard=PreemptionGuard(),
            straggler=StragglerMitigator())
    else:
        from repro.runtime.trainer import train_loop
        state, metrics = train_loop(model, tc, state, iter(data), args.steps)
    dt = time.time() - t0
    print(f"trained {args.steps} steps in {dt:.1f}s "
          f"({args.steps / dt:.2f} steps/s); final loss "
          f"{float(metrics['loss']):.4f}")
    if args.trace_export:
        from repro.obs import trace as obs_trace
        tracer = obs_trace.get_tracer()
        tracer.export(args.trace_export)
        print(f"chrome trace ({tracer.n_recorded} spans) -> "
              f"{args.trace_export}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
