"""Loop-aware analysis of optimized HLO text.

``compiled.cost_analysis()`` counts while-loop bodies ONCE — for scan-over-
layers programs that under-reports FLOPs/bytes/collectives by a factor of
n_layers (discovered on the first dry-run cell; see EXPERIMENTS.md). This
module re-derives the roofline inputs from ``compiled.as_text()`` with loop
multiplicities:

  * computations are parsed into instruction lists;
  * every ``while`` op's trip count is recovered from the loop-condition
    computation (jax scans lower to ``lt(i, constant(N))``);
  * execution multiplicity propagates entry -> while body/cond (x trip),
    conditional branches (x1), and fusions inherit their caller;
  * FLOPs: 2*M*N*K per ``dot`` (batch dims included), x multiplicity;
  * HBM bytes: for every top-level instruction in an executed computation,
    output + operand bytes (fusion internals excluded == perfect-fusion
    HBM traffic model), x multiplicity;
  * collective wire bytes: ring cost per op (see roofline.py), x multiplicity.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_SHAPE_TOKEN = re.compile(
    r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|s32|s16|s8|u64|u32|u16|u8|pred|"
    r"c64|c128)\[([0-9,]*)\]")

_INSTR = re.compile(r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_CALLED_COMP = re.compile(r"(?:to_apply|body|condition|branch_computations|"
                          r"called_computations)=\{?%?([\w.\-, %]+)\}?")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_TOKEN.findall(text):
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _dims_of_first_shape(text: str) -> Optional[List[int]]:
    m = _SHAPE_TOKEN.search(text)
    if not m:
        return None
    dims = m.group(2)
    return [int(d) for d in dims.split(",")] if dims else []


@dataclasses.dataclass
class Instr:
    name: str
    op: str
    out_bytes: int
    rhs: str
    operands: List[str]
    is_root: bool = False


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]


_OP_NAME = re.compile(
    r"\b([a-z][a-z0-9\-]*)\(")


def parse_computations(hlo: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in hlo.splitlines():
        if cur is None:
            mh = _COMP_HEADER.match(line.strip())
            if mh:
                cur = Computation(mh.group(1), [])
            continue
        s = line.strip()
        if s == "}" or s.startswith("} "):
            comps[cur.name] = cur
            cur = None
            continue
        mi = _INSTR.match(s)
        if not mi:
            continue
        is_root = bool(mi.group(1))
        name, rhs = mi.group(2), mi.group(3)
        # shape portion precedes the op name
        mop = None
        for m in _OP_NAME.finditer(rhs):
            op_candidate = m.group(1)
            if op_candidate in _DTYPE_BYTES:
                continue
            mop = m
            break
        op = mop.group(1) if mop else "unknown"
        shape_part = rhs[:mop.start()] if mop else rhs
        out_bytes = _shape_bytes(shape_part)
        # Operand names within the op's (...) group. Depending on jaxlib the
        # printer emits bare "%name" or "f32[32,64]{1,0} %name" (and tuple
        # shapes nest parens), so take the balanced paren group and pull the
        # %-prefixed references — operand names are always %-prefixed.
        operands: List[str] = []
        if mop:
            after = rhs[mop.end() - 1:]
            depth = 0
            end = len(after)
            for i, ch in enumerate(after):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i + 1
                        break
            operands = re.findall(r"%([\w.\-]+)", after[:end])
        cur.instrs.append(Instr(name, op, out_bytes, rhs, operands, is_root))
    return comps


def _trip_count(cond: Computation) -> int:
    """jax scans compare the counter against a constant upper bound."""
    best = 1
    for ins in cond.instrs:
        if ins.op == "constant":
            m = re.search(r"constant\((\d+)\)", ins.rhs)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _dot_flops(ins: Instr, name_bytes: Dict[str, Tuple[int, List[int]]]) -> float:
    """2 * prod(output dims) * K. K from lhs shape + contracting dims."""
    out_dims = _dims_of_first_shape(ins.rhs) or []
    out_elems = 1
    for d in out_dims:
        out_elems *= d
    mcd = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.rhs)
    k = 1
    if mcd and ins.operands:
        lhs = name_bytes.get(ins.operands[0])
        if lhs is not None:
            lhs_dims = lhs[1]
            for idx in mcd.group(1).split(","):
                if idx and int(idx) < len(lhs_dims):
                    k *= lhs_dims[int(idx)]
    return 2.0 * out_elems * k


_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "unknown", "while", "call", "conditional",
                   "after-all", "iota", "copy-start", "copy-done"}

_COLL_OPS = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
             "collective-permute", "all-reduce-start", "all-gather-start",
             "collective-permute-start"}


@dataclasses.dataclass
class HloCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    coll_counts: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_raw_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_wire_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    n_while: int = 0
    max_trip: int = 1

    @property
    def total_wire(self) -> float:
        return sum(self.coll_wire_bytes.values())


def _group_size(rhs: str, default: int) -> int:
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]", rhs)
    if m:
        return int(m.group(2))
    m = re.search(r"replica_groups=\{\{([0-9, ]+)\}", rhs)
    if m:
        return len(m.group(1).split(","))
    return default


def analyze_hlo(hlo: str, default_group: int = 1,
                entry: Optional[str] = None) -> HloCost:
    comps = parse_computations(hlo)
    if not comps:
        return HloCost()
    # entry = computation referenced by "ENTRY" (parse again quickly)
    m = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo)
    entry_name = entry or (m.group(1) if m else next(iter(comps)))

    # name -> (bytes, dims) per computation for dot K lookup
    cost = HloCost()
    visited_mult: Dict[str, float] = {}

    def visit(comp_name: str, mult: float):
        comp = comps.get(comp_name)
        if comp is None:
            return
        # avoid double counting a computation reached twice at same level —
        # but fusions/bodies are unique per callsite in XLA, so accumulate.
        name_info: Dict[str, Tuple[int, List[int]]] = {}
        for ins in comp.instrs:
            name_info[ins.name] = (ins.out_bytes,
                                   _dims_of_first_shape(ins.rhs) or [])
        for ins in comp.instrs:
            if ins.op == "while":
                cond_m = re.search(r"condition=%?([\w.\-]+)", ins.rhs)
                body_m = re.search(r"body=%?([\w.\-]+)", ins.rhs)
                trip = 1
                if cond_m and cond_m.group(1) in comps:
                    trip = _trip_count(comps[cond_m.group(1)])
                cost.n_while += 1
                cost.max_trip = max(cost.max_trip, trip)
                if body_m:
                    visit(body_m.group(1), mult * trip)
                continue
            if ins.op in ("call", "fusion"):
                mcc = re.search(r"calls=%?([\w.\-]+)", ins.rhs)
                sub_comp = comps.get(mcc.group(1)) if mcc else None
                root = None
                param_name_by_idx = {}
                uses_by_name: Dict[str, List[Instr]] = {}
                if sub_comp is not None:
                    sub_info = {
                        i.name: (i.out_bytes, _dims_of_first_shape(i.rhs) or [])
                        for i in sub_comp.instrs}
                    for sub in sub_comp.instrs:
                        # fusion internals: count dots (flops); bytes only at
                        # the fusion boundary.
                        if sub.op == "dot":
                            cost.flops += mult * _dot_flops(sub, sub_info)
                        if sub.is_root:
                            root = sub
                        if sub.op == "parameter":
                            mp = re.search(r"parameter\((\d+)\)", sub.rhs)
                            if mp:
                                param_name_by_idx[int(mp.group(1))] = sub.name
                        for o in sub.operands:
                            uses_by_name.setdefault(o, []).append(sub)
                if root is not None and root.op == "dynamic-update-slice":
                    # in-place slice write on an aliased loop buffer: traffic
                    # = read update + write slice, not the full buffer
                    upd = (sub_info.get(root.operands[1], (0, []))[0]
                           if len(root.operands) > 1 else 0)
                    cost.hbm_bytes += mult * 2.0 * upd
                    continue
                if root is not None and root.op == "dynamic-slice":
                    # slice read: traffic = read + write of the slice only
                    cost.hbm_bytes += mult * 2.0 * ins.out_bytes
                    continue
                # operand accounting: a fused operand consumed ONLY by
                # dynamic-slice reads just the slice, not the whole buffer
                # (e.g. indexing one layer out of a stacked residual array).
                op_bytes = 0.0
                for oi, oname in enumerate(ins.operands):
                    full = name_info.get(oname, (0, []))[0]
                    pname = param_name_by_idx.get(oi)
                    uses = uses_by_name.get(pname, []) if pname else []
                    if uses and all(u.op == "dynamic-slice" for u in uses):
                        op_bytes += sum(sub_info.get(u.name, (0, []))[0]
                                        for u in uses)
                    else:
                        op_bytes += full
                cost.hbm_bytes += mult * (ins.out_bytes + op_bytes)
                continue
            if ins.op == "conditional":
                mbc = re.search(r"branch_computations=\{([^}]*)\}", ins.rhs)
                branches = []
                if mbc:
                    branches = [b.strip().lstrip("%")
                                for b in mbc.group(1).split(",")]
                else:
                    mtb = re.search(r"true_computation=%?([\w.\-]+)", ins.rhs)
                    mfb = re.search(r"false_computation=%?([\w.\-]+)", ins.rhs)
                    branches = [m.group(1) for m in (mtb, mfb) if m]
                for br in branches:
                    if br in comps:
                        visit(br, mult)
                continue
            if ins.op == "dot":
                cost.flops += mult * _dot_flops(ins, name_info)
            base = ins.op.replace("-start", "")
            if base in ("all-reduce", "all-gather", "reduce-scatter",
                        "all-to-all", "collective-permute"):
                nbytes = ins.out_bytes
                if nbytes == 0:
                    continue
                G = _group_size(ins.rhs, default_group)
                if base == "all-reduce":
                    w = 2.0 * (G - 1) / G * nbytes
                elif base == "all-gather":
                    w = (G - 1) / G * nbytes
                elif base == "reduce-scatter":
                    w = (G - 1.0) * nbytes
                elif base == "all-to-all":
                    w = (G - 1) / G * nbytes
                else:
                    w = float(nbytes)
                cost.coll_counts[base] = cost.coll_counts.get(base, 0) + mult
                cost.coll_raw_bytes[base] = (cost.coll_raw_bytes.get(base, 0)
                                             + mult * nbytes)
                cost.coll_wire_bytes[base] = (cost.coll_wire_bytes.get(base, 0)
                                              + mult * w)
            if ins.op in _SKIP_BYTES_OPS or ins.op.endswith("-done"):
                continue
            if ins.op == "dynamic-update-slice":
                # in-place slice write: traffic = read update + write slice,
                # NOT the full destination buffer (its declared output shape)
                upd = (name_info.get(ins.operands[1], (0, []))[0]
                       if len(ins.operands) > 1 else 0)
                cost.hbm_bytes += mult * 2.0 * upd
                continue
            if ins.op == "dynamic-slice":
                cost.hbm_bytes += mult * 2.0 * ins.out_bytes
                continue
            cost.hbm_bytes += mult * (
                ins.out_bytes + sum(name_info.get(o, (0, []))[0]
                                    for o in ins.operands))

    visit(entry_name, 1.0)
    return cost
