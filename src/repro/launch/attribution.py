import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=512 "
                           + os.environ.get("XLA_FLAGS", ""))

"""Per-op / per-source attribution of the roofline terms for one cell.

  PYTHONPATH=src python -m repro.launch.attribution --arch X --shape Y

Prints the top HBM-byte and collective-byte contributors with their
multiplicities and jax op_name provenance — the profile that drives the
hypothesis->change->measure loop in EXPERIMENTS.md Section Perf.
"""

import argparse      # noqa: E402
import collections   # noqa: E402
import json          # noqa: E402
import re            # noqa: E402

from repro.launch import hlo_analysis as ha  # noqa: E402
from repro.launch.dryrun import lower_cell   # noqa: E402


def attribute(hlo: str, default_group: int):
    comps = ha.parse_computations(hlo)
    entry = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo).group(1)
    rows = []
    coll_rows = []

    def visit(cn, mult):
        comp = comps.get(cn)
        if comp is None:
            return
        info = {i.name: i.out_bytes for i in comp.instrs}
        for ins in comp.instrs:
            if ins.op == "while":
                c = re.search(r"condition=%?([\w.\-]+)", ins.rhs)
                b = re.search(r"body=%?([\w.\-]+)", ins.rhs)
                trip = ha._trip_count(comps[c.group(1)]) if c and c.group(1) in comps else 1
                if b:
                    visit(b.group(1), mult * trip)
                continue
            if ins.op == "conditional":
                mbc = re.search(r"branch_computations=\{([^}]*)\}", ins.rhs)
                if mbc:
                    for br in [x.strip().lstrip("%") for x in mbc.group(1).split(",")]:
                        visit(br, mult)
                continue
            md = re.search(r'op_name="([^"]*)"', ins.rhs)
            src = md.group(1) if md else ""
            base = ins.op.replace("-start", "")
            if base in ("all-reduce", "all-gather", "reduce-scatter",
                        "all-to-all", "collective-permute") and ins.out_bytes:
                coll_rows.append((mult * ins.out_bytes, base, mult, src))
            if ins.op in ha._SKIP_BYTES_OPS or ins.op.endswith("-done"):
                continue
            if ins.op == "fusion":
                mcc = re.search(r"calls=%?([\w.\-]+)", ins.rhs)
                sub = comps.get(mcc.group(1)) if mcc else None
                root = next((i for i in sub.instrs if i.is_root), None) if sub else None
                sub_info = ({i.name: i.out_bytes for i in sub.instrs}
                            if sub else {})
                if root is not None and root.op == "dynamic-update-slice":
                    upd = sub_info.get(root.operands[1], 0) if len(root.operands) > 1 else 0
                    rows.append((mult * 2 * upd, "fusion:dus", mult, src))
                    continue
                if root is not None and root.op == "dynamic-slice":
                    rows.append((mult * 2 * ins.out_bytes, "fusion:ds", mult, src))
                    continue
                # slice-aware operand accounting (matches hlo_analysis)
                pbyidx = {}
                uses = {}
                if sub is not None:
                    for si in sub.instrs:
                        if si.op == "parameter":
                            mp = re.search(r"parameter\((\d+)\)", si.rhs)
                            if mp:
                                pbyidx[int(mp.group(1))] = si.name
                        for o in si.operands:
                            uses.setdefault(o, []).append(si)
                op_bytes = 0
                for oi, oname in enumerate(ins.operands):
                    full = info.get(oname, 0)
                    pn = pbyidx.get(oi)
                    us = uses.get(pn, []) if pn else []
                    if us and all(u.op == "dynamic-slice" for u in us):
                        op_bytes += sum(sub_info.get(u.name, 0) for u in us)
                    else:
                        op_bytes += full
                rows.append((mult * (ins.out_bytes + op_bytes), "fusion",
                             mult, src))
                continue
            if ins.op == "dynamic-update-slice":
                upd = info.get(ins.operands[1], 0) if len(ins.operands) > 1 else 0
                rows.append((mult * 2 * upd, ins.op, mult, src))
                continue
            if ins.op == "dynamic-slice":
                rows.append((mult * 2 * ins.out_bytes, ins.op, mult, src))
                continue
            b = ins.out_bytes + sum(info.get(o, 0) for o in ins.operands)
            rows.append((mult * b, ins.op, mult, src))

    visit(entry, 1.0)
    return rows, coll_rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--policy", default="mirage")
    ap.add_argument("--perf-level", type=int, default=0)
    ap.add_argument("--top", type=int, default=20)
    ap.add_argument("--save-hlo", default=None)
    args = ap.parse_args(argv)

    cfg, shape, mesh, model, lowered, compiled = lower_cell(
        args.arch, args.shape, args.multi_pod, args.policy, args.perf_level)
    hlo = compiled.as_text()
    if args.save_hlo:
        open(args.save_hlo, "w").write(hlo)
    rows, coll = attribute(hlo, mesh.size)
    total = sum(r[0] for r in rows)
    print(f"== HBM bytes/device: {total:.3e} "
          f"({total/819e9:.2f}s at 819GB/s) ==")
    agg = collections.Counter()
    for b, op, mult, src in rows:
        key = (op, src.split("/")[-1][:60] if src else "?",
               "/".join(p for p in src.split("/") if "while" not in p
                        and "body" not in p)[:80])
        agg[key] += b
    for (op, leaf, src), b in agg.most_common(args.top):
        print(f"  {b:.2e} ({100*b/total:5.1f}%) {op:22s} {leaf:40s} {src}")
    ctotal = sum(r[0] for r in coll)
    print(f"== collective payload bytes/device: {ctotal:.3e} ==")
    cagg = collections.Counter()
    for b, op, mult, src in coll:
        cagg[(op, src.split("/")[-1][:70])] += b
    for (op, src), b in cagg.most_common(args.top):
        print(f"  {b:.2e} ({100*b/max(ctotal,1):5.1f}%) {op:20s} {src}")


if __name__ == "__main__":
    main()
