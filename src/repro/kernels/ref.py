"""Pure-jnp oracles for every Pallas kernel (bitwise/allclose targets)."""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import bfp, rns
from repro.core.precision import MiragePolicy


def bfp_fake_quant_ref(x: jax.Array, b_m: int = 4, g: int = 16,
                       rounding: str = "nearest") -> jax.Array:
    """Oracle for kernels.bfp_quantize.bfp_fake_quant_pallas."""
    return bfp.bfp_fake_quant(x.astype(jnp.float32), b_m, g, rounding)


def mirage_gemm_ref(x: jax.Array, w: jax.Array, b_m: int = 4, g: int = 16,
                    rounding: str = "nearest",
                    compute_dtype: str = "float32") -> jax.Array:
    """Oracle for kernels.mirage_gemm.mirage_gemm_pallas: quantize both
    operands along K, fold scales, single f32-accumulated matmul."""
    xq = bfp.bfp_fake_quant(x.astype(jnp.float32), b_m, g, rounding)
    wq = bfp.bfp_fake_quant(w.astype(jnp.float32).T, b_m, g, rounding).T
    dt = jnp.bfloat16 if compute_dtype == "bfloat16" else jnp.float32
    return jnp.matmul(xq.astype(dt), wq.astype(dt),
                      preferred_element_type=jnp.float32)


def rns_matmul_ref(x_res: jax.Array, w_res: jax.Array,
                   moduli: Tuple[int, ...]) -> jax.Array:
    """Oracle for kernels.rns_matmul.rns_matmul_pallas."""
    return rns.rns_matmul(x_res, w_res, moduli).astype(jnp.int32)
