"""Jit'd public wrappers around the Pallas kernels.

On the CPU container all kernels execute with ``interpret=True`` (the policy
default); on real TPU hardware set ``MiragePolicy(use_pallas=True,
interpret=False)``. Each wrapper handles padding/reshaping so callers can pass
arbitrary ranks; the kernels see MXU-aligned 2-D blocks.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.precision import MiragePolicy
from repro.kernels.bfp_quantize import bfp_fake_quant_pallas
from repro.kernels.mirage_gemm import mirage_gemm_pallas
from repro.kernels.rns_matmul import (rns_matmul_pallas,
                                      rns_matmul_pallas_channel)


def bfp_fake_quant(x: jax.Array, policy: MiragePolicy) -> jax.Array:
    return bfp_fake_quant_pallas(
        x, b_m=policy.b_m, g=policy.g, rounding=policy.rounding,
        interpret=policy.interpret)


def mirage_matmul_fused(x: jax.Array, w: jax.Array,
                        policy: MiragePolicy) -> jax.Array:
    """Fused BFP-quantize + GEMM (paper dataflow steps 2-9 in one kernel)."""
    return mirage_gemm_pallas(
        x, w, b_m=policy.b_m, g=policy.g, rounding=policy.rounding,
        compute_dtype=policy.compute_dtype, interpret=policy.interpret)


def rns_residue_matmul(x_res: jax.Array, w_res: jax.Array,
                       moduli: Tuple[int, ...],
                       interpret: bool = True) -> jax.Array:
    return rns_matmul_pallas(x_res, w_res, tuple(moduli), interpret=interpret)


def rns_group_matmul(x_res: jax.Array, w_res: jax.Array,
                     moduli: Tuple[int, ...],
                     interpret: bool = True) -> jax.Array:
    """Group-batched residue GEMM through the Pallas kernel.

    x_res: (n_mod, G, M, g), w_res: (n_mod, G, g, N) -> (n_mod, G, M, N).

    The kernel's grid is modulus-major with the modulus value streamed in as
    a (1,)-blocked operand, so one compiled kernel serves any number of
    "moduli" — flattening the (modulus, group) axes into n_mod * G slots
    with each modulus repeated G times executes ALL per-group modular GEMMs
    in a single pallas_call.
    """
    nm, G, M, g = x_res.shape
    N = w_res.shape[-1]
    xf = x_res.reshape(nm * G, M, g)
    wf = w_res.reshape(nm * G, g, N)
    flat_moduli = tuple(m for m in moduli for _ in range(G))
    res = rns_matmul_pallas(xf, wf, flat_moduli, interpret=interpret)
    return res.reshape(nm, G, M, N)


def rns_group_matmul_channel(x_res: jax.Array, w_res: jax.Array,
                             moduli: Tuple[int, ...],
                             noise: jax.Array,
                             adc_bits=None,
                             interpret: bool = True) -> jax.Array:
    """Group-batched residue GEMM with the readout channel fused in-kernel.

    Same (modulus, group)-flattened grid as :func:`rns_group_matmul`, but
    each accumulated residue block gets detector noise + ADC re-gridding
    applied in the kernel epilogue (``rns_matmul_pallas_channel``). ``noise``
    is (n_mod, G, M, N) f32, pre-scaled to the per-modulus detector sigmas
    (zeros = noiseless readout).
    """
    nm, G, M, g = x_res.shape
    N = w_res.shape[-1]
    xf = x_res.reshape(nm * G, M, g)
    wf = w_res.reshape(nm * G, g, N)
    nzf = noise.reshape(nm * G, M, N)
    flat_moduli = tuple(m for m in moduli for _ in range(G))
    res = rns_matmul_pallas_channel(xf, wf, flat_moduli, nzf,
                                    adc_bits=adc_bits, interpret=interpret)
    return res.reshape(nm, G, M, N)
