"""Jit'd public wrappers around the Pallas kernels.

On the CPU container all kernels execute with ``interpret=True`` (the policy
default); on real TPU hardware set ``MiragePolicy(use_pallas=True,
interpret=False)``. Each wrapper handles padding/reshaping so callers can pass
arbitrary ranks; the kernels see MXU-aligned 2-D blocks.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.precision import MiragePolicy
from repro.kernels.bfp_quantize import bfp_fake_quant_pallas
from repro.kernels.mirage_gemm import mirage_gemm_pallas
from repro.kernels.rns_matmul import rns_matmul_pallas


def bfp_fake_quant(x: jax.Array, policy: MiragePolicy) -> jax.Array:
    return bfp_fake_quant_pallas(
        x, b_m=policy.b_m, g=policy.g, rounding=policy.rounding,
        interpret=policy.interpret)


def mirage_matmul_fused(x: jax.Array, w: jax.Array,
                        policy: MiragePolicy) -> jax.Array:
    """Fused BFP-quantize + GEMM (paper dataflow steps 2-9 in one kernel)."""
    return mirage_gemm_pallas(
        x, w, b_m=policy.b_m, g=policy.g, rounding=policy.rounding,
        compute_dtype=policy.compute_dtype, interpret=policy.interpret)


def rns_residue_matmul(x_res: jax.Array, w_res: jax.Array,
                       moduli: Tuple[int, ...],
                       interpret: bool = True) -> jax.Array:
    return rns_matmul_pallas(x_res, w_res, tuple(moduli), interpret=interpret)
