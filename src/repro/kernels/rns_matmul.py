"""Pallas TPU kernel: per-modulus residue GEMM (modular matmul).

The photonic MMVMU accumulates phase mod 2*pi/m per MAC; digitally the modulo
is a ring homomorphism, so the kernel accumulates exact integer partial dots
per K-block (kept below the f32 exact-integer window 2^24) and reduces
``mod m`` once per block, keeping the running accumulator in [0, m). This
preserves the paper's invariant that no stored value ever exceeds
ceil(log2 m) bits of information outside the accumulator.

Grid: (modulus, M blocks, N blocks, K blocks). The modulus value is streamed
in as a (1,)-blocked operand indexed by the first grid axis, so one compiled
kernel serves the whole moduli set.

``rns_matmul_pallas_channel`` is the analog-channel variant: the readout
side of the channel (SNR-parameterized detector noise + ADC re-gridding,
``repro.analog.channel``) is applied at **residue granularity inside the
kernel epilogue** — on the last K step the accumulated residue block gets
the pre-sampled, pre-scaled Gaussian phase noise added, is re-quantized to
the nearest phase level, wrapped mod m, and re-gridded onto the ADC levels,
all while the block is still VMEM-resident. The noise tensor is sampled
*outside* with the caller's PRNG key (``gemm.noise_key_scope`` plumbing),
so the kernel stays deterministic per key and bit-identical to the jnp
channel path (``channel.phase_noise`` + ``channel.converter_quantize``).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(mod_ref, x_ref, w_ref, o_ref):
    m = mod_ref[0]

    @pl.when(pl.program_id(3) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # exact integer partial dot in f32 (block_k * (m-1)^2 < 2^24 enforced below)
    part = jnp.dot(x_ref[0], w_ref[0], preferred_element_type=jnp.float32)
    o_ref[0] = jnp.mod(o_ref[0] + jnp.mod(part, m), m)


@functools.partial(
    jax.jit,
    static_argnames=("moduli", "block_m", "block_n", "block_k", "interpret"),
)
def rns_matmul_pallas(
    x_res: jax.Array,
    w_res: jax.Array,
    moduli: Tuple[int, ...],
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """(n_mod, M, K) x (n_mod, K, N) -> (n_mod, M, N) residue matmul.

    x_res/w_res: non-negative residues (int32 or exact f32).
    moduli: static tuple of modulus values.
    """
    nm, M, K = x_res.shape
    N = w_res.shape[2]
    assert len(moduli) == nm, (moduli, x_res.shape)
    xf = x_res.astype(jnp.float32)
    wf = w_res.astype(jnp.float32)
    mf = jnp.asarray(moduli, jnp.float32)

    # keep block-partial dots exactly representable in f32
    max_m = max(moduli)
    exact_cap = (2**24) // max(1, (max_m - 1) ** 2)
    bk = max(1, min(block_k, K, exact_cap))
    bm_ = min(block_m, M)
    bn = min(block_n, N)
    pm, pn, pk = (-M) % bm_, (-N) % bn, (-K) % bk
    if pm or pk:
        xf = jnp.pad(xf, ((0, 0), (0, pm), (0, pk)))
    if pk or pn:
        wf = jnp.pad(wf, ((0, 0), (0, pk), (0, pn)))

    grid = (nm, xf.shape[1] // bm_, wf.shape[2] // bn, xf.shape[2] // bk)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda mi, i, j, k: (mi,)),
            pl.BlockSpec((1, bm_, bk), lambda mi, i, j, k: (mi, i, k)),
            pl.BlockSpec((1, bk, bn), lambda mi, i, j, k: (mi, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bm_, bn), lambda mi, i, j, k: (mi, i, j)),
        out_shape=jax.ShapeDtypeStruct((nm, xf.shape[1], wf.shape[2]),
                                       jnp.float32),
        interpret=interpret,
    )(mf, xf, wf)
    return out[:, :M, :N].astype(jnp.int32)


def _kernel_channel(mod_ref, step_ref, x_ref, w_ref, nz_ref, o_ref, *,
                    nk: int):
    m = mod_ref[0]

    @pl.when(pl.program_id(3) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    part = jnp.dot(x_ref[0], w_ref[0], preferred_element_type=jnp.float32)
    o_ref[0] = jnp.mod(o_ref[0] + jnp.mod(part, m), m)

    @pl.when(pl.program_id(3) == nk - 1)
    def _readout():
        # residue-level readout channel, fused on the VMEM-resident block:
        # detector phase noise (pre-scaled N(0, sigma_m) levels), nearest-
        # level re-quantize, ring wrap, then ADC re-grid. Bit-identical to
        # channel.phase_noise + channel.converter_quantize on the same draws.
        o = jnp.mod(jnp.round(o_ref[0] + nz_ref[0]), m)
        step = step_ref[0]
        safe = jnp.where(step > 0, step, 1.0)
        oq = jnp.clip(jnp.round(jnp.round(o / safe) * safe), 0, m - 1)
        o_ref[0] = jnp.where(step > 0, oq, o)


@functools.partial(
    jax.jit,
    static_argnames=("moduli", "adc_bits", "block_m", "block_n", "block_k",
                     "interpret"),
)
def rns_matmul_pallas_channel(
    x_res: jax.Array,
    w_res: jax.Array,
    moduli: Tuple[int, ...],
    noise: jax.Array,
    adc_bits: Optional[int] = None,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """Residue matmul with the readout channel fused into the epilogue.

    x_res/w_res: (n_mod, M, K) x (n_mod, K, N) non-negative residues.
    noise: (n_mod, M, N) f32 — detector noise PRE-SCALED to per-modulus
      phase-level sigmas (zeros for noiseless channels); sampled by the
      caller so determinism/keying stays outside the kernel.
    adc_bits: ADC precision; identity whenever ``2^bits >= m`` (per slot).
    """
    nm, M, K = x_res.shape
    N = w_res.shape[2]
    assert len(moduli) == nm, (moduli, x_res.shape)
    assert noise.shape == (nm, M, N), (noise.shape, (nm, M, N))
    xf = x_res.astype(jnp.float32)
    wf = w_res.astype(jnp.float32)
    nz = noise.astype(jnp.float32)
    mf = jnp.asarray(moduli, jnp.float32)
    # per-slot ADC grid step; 0 flags the identity converter (2^bits >= m)
    steps = []
    for m in moduli:
        if adc_bits is None or 2 ** adc_bits >= m:
            steps.append(0.0)
        else:
            steps.append((m - 1) / (2 ** adc_bits - 1))
    sf = jnp.asarray(steps, jnp.float32)

    max_m = max(moduli)
    exact_cap = (2**24) // max(1, (max_m - 1) ** 2)
    bk = max(1, min(block_k, K, exact_cap))
    bm_ = min(block_m, M)
    bn = min(block_n, N)
    pm, pn, pk = (-M) % bm_, (-N) % bn, (-K) % bk
    if pm or pk:
        xf = jnp.pad(xf, ((0, 0), (0, pm), (0, pk)))
    if pk or pn:
        wf = jnp.pad(wf, ((0, 0), (0, pk), (0, pn)))
    if pm or pn:
        nz = jnp.pad(nz, ((0, 0), (0, pm), (0, pn)))

    nk = xf.shape[2] // bk
    grid = (nm, xf.shape[1] // bm_, wf.shape[2] // bn, nk)
    out = pl.pallas_call(
        functools.partial(_kernel_channel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda mi, i, j, k: (mi,)),
            pl.BlockSpec((1,), lambda mi, i, j, k: (mi,)),
            pl.BlockSpec((1, bm_, bk), lambda mi, i, j, k: (mi, i, k)),
            pl.BlockSpec((1, bk, bn), lambda mi, i, j, k: (mi, k, j)),
            pl.BlockSpec((1, bm_, bn), lambda mi, i, j, k: (mi, i, j)),
        ],
        out_specs=pl.BlockSpec((1, bm_, bn), lambda mi, i, j, k: (mi, i, j)),
        out_shape=jax.ShapeDtypeStruct((nm, xf.shape[1], wf.shape[2]),
                                       jnp.float32),
        interpret=interpret,
    )(mf, sf, xf, wf, nz)
    return out[:, :M, :N].astype(jnp.int32)
