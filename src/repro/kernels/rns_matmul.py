"""Pallas TPU kernel: per-modulus residue GEMM (modular matmul).

The photonic MMVMU accumulates phase mod 2*pi/m per MAC; digitally the modulo
is a ring homomorphism, so the kernel accumulates exact integer partial dots
per K-block (kept below the f32 exact-integer window 2^24) and reduces
``mod m`` once per block, keeping the running accumulator in [0, m). This
preserves the paper's invariant that no stored value ever exceeds
ceil(log2 m) bits of information outside the accumulator.

Grid: (modulus, M blocks, N blocks, K blocks). The modulus value is streamed
in as a (1,)-blocked operand indexed by the first grid axis, so one compiled
kernel serves the whole moduli set.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(mod_ref, x_ref, w_ref, o_ref):
    m = mod_ref[0]

    @pl.when(pl.program_id(3) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # exact integer partial dot in f32 (block_k * (m-1)^2 < 2^24 enforced below)
    part = jnp.dot(x_ref[0], w_ref[0], preferred_element_type=jnp.float32)
    o_ref[0] = jnp.mod(o_ref[0] + jnp.mod(part, m), m)


@functools.partial(
    jax.jit,
    static_argnames=("moduli", "block_m", "block_n", "block_k", "interpret"),
)
def rns_matmul_pallas(
    x_res: jax.Array,
    w_res: jax.Array,
    moduli: Tuple[int, ...],
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """(n_mod, M, K) x (n_mod, K, N) -> (n_mod, M, N) residue matmul.

    x_res/w_res: non-negative residues (int32 or exact f32).
    moduli: static tuple of modulus values.
    """
    nm, M, K = x_res.shape
    N = w_res.shape[2]
    assert len(moduli) == nm, (moduli, x_res.shape)
    xf = x_res.astype(jnp.float32)
    wf = w_res.astype(jnp.float32)
    mf = jnp.asarray(moduli, jnp.float32)

    # keep block-partial dots exactly representable in f32
    max_m = max(moduli)
    exact_cap = (2**24) // max(1, (max_m - 1) ** 2)
    bk = max(1, min(block_k, K, exact_cap))
    bm_ = min(block_m, M)
    bn = min(block_n, N)
    pm, pn, pk = (-M) % bm_, (-N) % bn, (-K) % bk
    if pm or pk:
        xf = jnp.pad(xf, ((0, 0), (0, pm), (0, pk)))
    if pk or pn:
        wf = jnp.pad(wf, ((0, 0), (0, pk), (0, pn)))

    grid = (nm, xf.shape[1] // bm_, wf.shape[2] // bn, xf.shape[2] // bk)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda mi, i, j, k: (mi,)),
            pl.BlockSpec((1, bm_, bk), lambda mi, i, j, k: (mi, i, k)),
            pl.BlockSpec((1, bk, bn), lambda mi, i, j, k: (mi, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bm_, bn), lambda mi, i, j, k: (mi, i, j)),
        out_shape=jax.ShapeDtypeStruct((nm, xf.shape[1], wf.shape[2]),
                                       jnp.float32),
        interpret=interpret,
    )(mf, xf, wf)
    return out[:, :M, :N].astype(jnp.int32)
