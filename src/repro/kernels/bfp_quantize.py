"""Pallas TPU kernel: BFP fake-quantization (shared-exponent groups).

Implements paper Section III-A step 2 as a tiled VMEM kernel: for each group
of ``g`` consecutive elements along the last axis, find the max exponent,
round mantissas to ``b_m`` bits, and write back the dequantized values.

The group exponent is extracted from the f32 bit pattern (exact — no log2
rounding hazards) and the power-of-two scale is *constructed* in the exponent
field, so the kernel is bit-exact against the pure-jnp reference.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _exp2_int(e: jax.Array) -> jax.Array:
    """Exact 2^e for integer e in [-126, 127], via exponent-field construction."""
    e = jnp.clip(e, -126, 127)
    bits = (e + 127).astype(jnp.int32) << 23
    return jax.lax.bitcast_convert_type(bits, jnp.float32)


def _floor_log2(x: jax.Array) -> jax.Array:
    """floor(log2 x) for x > 0 (normal f32), from the exponent bit field."""
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.int32)
    return ((bits >> 23) & 0xFF) - 127


def _quantize_block(x: jax.Array, b_m: int, g: int, rounding: str) -> jax.Array:
    """Fake-quantize a (rows, cols) block; cols must be a multiple of g."""
    rows, cols = x.shape
    xg = x.reshape(rows, cols // g, g)
    maxabs = jnp.max(jnp.abs(xg), axis=-1, keepdims=True)
    e = _floor_log2(jnp.maximum(maxabs, 1e-30))
    e = jnp.where(maxabs > 0, e, 0)
    scale = _exp2_int(e - (b_m - 1))
    qmax = float(2**b_m - 1)
    v = xg / scale
    q = jnp.trunc(v) if rounding == "truncate" else jnp.round(v)
    q = jnp.clip(q, -qmax, qmax)
    return (q * scale).reshape(rows, cols)


def _kernel(x_ref, o_ref, *, b_m: int, g: int, rounding: str):
    o_ref[...] = _quantize_block(x_ref[...].astype(jnp.float32), b_m, g, rounding)


@functools.partial(jax.jit, static_argnames=("b_m", "g", "rounding", "block_rows",
                                             "block_cols", "interpret"))
def bfp_fake_quant_pallas(
    x: jax.Array,
    b_m: int = 4,
    g: int = 16,
    rounding: str = "nearest",
    block_rows: int = 256,
    block_cols: int = 512,
    interpret: bool = True,
) -> jax.Array:
    """Fake-quantize ``x`` along its last axis in BFP(b_m, g).

    Works for any rank: leading dims are flattened into rows. The last axis is
    padded to a multiple of g (padding never leaks into group maxima because
    padded lanes are zero and |x| >= 0 dominates them only within their own
    padded group, which is discarded).
    """
    orig_shape = x.shape
    k = orig_shape[-1]
    xf = x.reshape(-1, k).astype(jnp.float32)
    rows = xf.shape[0]
    pad_k = (-k) % g
    if pad_k:
        xf = jnp.pad(xf, ((0, 0), (0, pad_k)))
    kp = k + pad_k

    br = min(block_rows, rows)
    bc = min(block_cols, kp)
    bc = max(g, (bc // g) * g)  # block must contain whole groups
    pad_r = (-rows) % br
    pad_c = (-kp) % bc
    if pad_r or pad_c:
        xf = jnp.pad(xf, ((0, pad_r), (0, pad_c)))

    grid = (xf.shape[0] // br, xf.shape[1] // bc)
    out = pl.pallas_call(
        functools.partial(_kernel, b_m=b_m, g=g, rounding=rounding),
        grid=grid,
        in_specs=[pl.BlockSpec((br, bc), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(xf.shape, jnp.float32),
        interpret=interpret,
    )(xf)
    return out[:rows, :k].reshape(orig_shape)
