"""Pallas TPU kernel: fused Mirage GEMM (BFP quantize + matmul + f32 accumulate).

This is the TPU-native realization of the paper's dataflow steps 2-9 in one
VMEM round trip: each (block_m x block_k) activation tile and (block_k x
block_n) weight tile are BFP-quantized *in VMEM* (groups of g along K), the
power-of-two group scales are folded back into the mantissas (exact), and the
MXU contracts the block with f32 accumulation across the K grid dimension.

Compared to the photonic MMVMU, the "16-wide modular dot + CRT per group"
becomes "whole-block MXU dot with folded scales" — value-identical under the
paper's own Eq. 10 no-overflow invariant (see DESIGN.md Section 8.1), because
every per-group partial product is exactly representable in the f32
accumulator. Block shapes are MXU-aligned (multiples of 128 where possible)
and contain whole BFP groups (block_k % g == 0).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bfp_quantize import _quantize_block


def _kernel(x_ref, w_ref, o_ref, *, b_m: int, g: int, rounding: str,
            compute_dtype):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    xq = _quantize_block(x_ref[...].astype(jnp.float32), b_m, g, rounding)
    # weights: contraction dim is axis 0 -> quantize along columns of w^T
    wq = _quantize_block(
        w_ref[...].astype(jnp.float32).T, b_m, g, rounding
    ).T
    o_ref[...] += jnp.dot(
        xq.astype(compute_dtype), wq.astype(compute_dtype),
        preferred_element_type=jnp.float32,
    )


@functools.partial(
    jax.jit,
    static_argnames=("b_m", "g", "rounding", "block_m", "block_n", "block_k",
                     "compute_dtype", "interpret"),
)
def mirage_gemm_pallas(
    x: jax.Array,
    w: jax.Array,
    b_m: int = 4,
    g: int = 16,
    rounding: str = "nearest",
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    compute_dtype: str = "float32",
    interpret: bool = True,
) -> jax.Array:
    """``x @ w`` with fused BFP(b_m, g) quantization. x: (..., K), w: (K, N)."""
    orig_shape = x.shape
    K = orig_shape[-1]
    N = w.shape[1]
    assert w.shape[0] == K, (x.shape, w.shape)
    xf = x.reshape(-1, K).astype(jnp.float32)
    M = xf.shape[0]

    bm_ = min(block_m, max(M, 8))
    bn = min(block_n, max(N, 8))
    bk = min(block_k, K + (-K) % g)
    bk = max(g, (bk // g) * g)

    pm, pn, pk = (-M) % bm_, (-N) % bn, (-K) % bk
    if pm or pk:
        xf = jnp.pad(xf, ((0, pm), (0, pk)))
    wf = jnp.pad(w.astype(jnp.float32), ((0, pk), (0, pn))) if (pk or pn) else w.astype(jnp.float32)

    grid = (xf.shape[0] // bm_, wf.shape[1] // bn, xf.shape[1] // bk)
    cdt = jnp.bfloat16 if compute_dtype == "bfloat16" else jnp.float32
    out = pl.pallas_call(
        functools.partial(_kernel, b_m=b_m, g=g, rounding=rounding,
                          compute_dtype=cdt),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((xf.shape[0], wf.shape[1]), jnp.float32),
        interpret=interpret,
    )(xf, wf)
    return out[:M, :N].reshape(orig_shape[:-1] + (N,))
