"""Pallas kernel: fused single-pass RRNS majority decode.

Kernel counterpart of :func:`repro.analog.rrns.rrns_decode` (same
consistency-count voting identity — see that module's docstring), laid out
on a **subset-major grid**: ``grid = (element_blocks, S)`` with the subset
axis innermost, so for each output block the kernel revisits the block S
times, accumulating the running (first-max) winner directly in the output
refs — the per-subset reconstruction, the congruence checks, the binomial
vote lookup and the winner select all fuse into one VMEM-resident pass per
(block, subset) step. No ``(S, ...)`` intermediate ever exists.

Per-subset constants stream in as ``(1, ...)``-blocked operand rows indexed
by the subset grid axis (the same trick ``rns_matmul`` uses for the modulus
value), so ONE compiled kernel serves any (moduli, n_required, psi) table.

The kernel runs entirely in f32 and therefore requires ``tables.f32_exact``
(every reconstruction sum inside the exact-integer window 2^24 — always
true at the paper point k=5 with two redundant moduli); larger moduli sets
must use the jnp fallback decode.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.obs import health as obs_health


def _decode_kernel(wrow_ref, sub_ref, minv_ref, res_ref, dec_ref, vot_ref,
                   *, n_total: int, n_required: int, psi: float,
                   binom: Tuple[int, ...]):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        dec_ref[...] = jnp.zeros_like(dec_ref)
        vot_ref[...] = jnp.full_like(vot_ref, -2.0)

    # reconstruction: weights are 0 for non-members, so the full-width
    # contraction over n_total positions equals the member sum exactly
    acc = None
    for i in range(n_total):
        term = res_ref[i] * wrow_ref[0, i]
        acc = term if acc is None else acc + term
    M_s = sub_ref[0, 0]
    inv_M = sub_ref[0, 1]
    psi_s = sub_ref[0, 2]
    lo = sub_ref[0, 3]
    # round-based signed fold into [psi_s + 1 - M_s, psi_s] (two selects
    # absorb the half-up boundary and the reciprocal off-by-one)
    q = jnp.floor(acc * inv_M + 0.5)
    X = acc - q * M_s
    X = jnp.where(X > psi_s, X - M_s, X)
    X = jnp.where(X < lo, X + M_s, X)
    # consistency count over ALL positions: members are congruent by CRT
    # construction, so cons ranges over [n_required, n_total] and the vote
    # count is binom[cons - n_required]
    cons = None
    for i in range(n_total):
        d = X - res_ref[i]
        k = jnp.round(d * minv_ref[1, i])
        ok = (d - k * minv_ref[0, i] == 0.0).astype(jnp.float32)
        cons = ok if cons is None else cons + ok
    votes = jnp.full(X.shape, float(binom[0]))
    for e in range(1, n_total - n_required + 1):
        votes = jnp.where(cons == float(n_required + e), float(binom[e]),
                          votes)
    votes = jnp.where(jnp.abs(X) <= psi, votes, -1.0)
    # strict > keeps the FIRST max across the subset-major grid sweep ==
    # the oracle's dict-insertion-order tie-break
    better = votes > vot_ref[...]
    dec_ref[...] = jnp.where(better, X, dec_ref[...])
    vot_ref[...] = jnp.where(better, votes, vot_ref[...])


def _decode_flat(res_flat: jax.Array, tables, block_e: int,
                 interpret: bool) -> Tuple[jax.Array, jax.Array]:
    n_total, E = res_flat.shape
    S = tables.n_subsets
    be = min(block_e, max(E, 1))
    pad = (-E) % be
    if pad:
        res_flat = jnp.pad(res_flat, ((0, 0), (0, pad)))
    wrow = jnp.asarray(tables.weights, jnp.float32)            # (S, n_total)
    sub = jnp.stack([
        tables.subset_M.astype(np.float32),
        (1.0 / tables.subset_M).astype(np.float32),
        tables.subset_psi.astype(np.float32),
        (tables.subset_psi + 1 - tables.subset_M).astype(np.float32),
    ], axis=1)                                                 # (S, 4)
    moduli = np.asarray(tables.moduli, np.float32)
    minv = jnp.asarray(np.stack([moduli, 1.0 / moduli]))       # (2, n_total)
    grid = (res_flat.shape[1] // be, S)
    dec, vot = pl.pallas_call(
        functools.partial(
            _decode_kernel, n_total=n_total,
            n_required=tables.n_required, psi=float(tables.psi),
            binom=tables.binom),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, n_total), lambda e, s: (s, 0)),
            pl.BlockSpec((1, 4), lambda e, s: (s, 0)),
            pl.BlockSpec((2, n_total), lambda e, s: (0, 0)),
            pl.BlockSpec((n_total, be), lambda e, s: (0, e)),
        ],
        out_specs=[
            pl.BlockSpec((be,), lambda e, s: (e,)),
            pl.BlockSpec((be,), lambda e, s: (e,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((res_flat.shape[1],), jnp.float32),
            jax.ShapeDtypeStruct((res_flat.shape[1],), jnp.float32),
        ],
        interpret=interpret,
    )(wrow, sub, minv, res_flat.astype(jnp.float32))
    dec, vot = dec[:E], vot[:E]
    any_legal = vot >= 0.0
    decoded = jnp.where(any_legal, dec, 0.0).astype(jnp.int32)
    corrected = jnp.where(any_legal, vot < float(S), True)
    if obs_health.active():
        # same correction-radius split as rrns.rrns_decode, recorded
        # here because the kernel epilogue is the only place the vote
        # counts still exist. One fused reduction (vot >= S implies
        # trusted, so trusted - full_agreement = repaired and E - trusted
        # = untrustworthy): these sums stay live in the decode hot path
        # and cost ~6% of decode throughput on the op-dispatch-bound
        # interpret-mode box — see the bench_serving obs_sweep notes.
        T = float(tables.vote_threshold)
        n = jnp.sum(jnp.stack([vot >= T, vot >= float(S)])
                    .astype(jnp.int32), axis=1)
        obs_health.record("rrns_corrected", n[0] - n[1])
        obs_health.record("rrns_uncorrected", jnp.int32(E) - n[0])
    return decoded, corrected


def rrns_decode_pallas(residues: jax.Array, tables, block_e: int = 4096,
                       interpret: bool = True) -> Tuple[jax.Array, jax.Array]:
    """RRNS majority decode through the subset-major Pallas kernel.

    residues: (n_total, ...) int32 over ``tables.moduli``; trailing dims are
    flattened into the kernel's element axis. Bit-identical outputs to
    :func:`repro.analog.rrns.rrns_decode` (and hence to the frozen
    ``rrns_decode_np`` oracle). Requires ``tables.f32_exact``.
    """
    if not tables.f32_exact:
        raise ValueError(
            "rrns_decode_pallas runs in f32 and needs every reconstruction "
            "bound inside the 2^24 exact-integer window; this moduli set "
            f"({tables.moduli}) exceeds it — use the jnp rrns_decode, whose "
            "int32 fallback handles large moduli")
    shape = residues.shape[1:]
    flat = residues.reshape(residues.shape[0], -1)
    decoded, corrected = _decode_flat(flat, tables, block_e, interpret)
    return decoded.reshape(shape), corrected.reshape(shape)
