"""Pallas TPU kernel: GQA flash attention (forward).

This is §Perf iteration FA: the dry-run showed the training memory term is
dominated by materialized attention score/probability traffic (the chunked
JAX reference writes (q_chunk x kv_chunk) score blocks and online-softmax
carries through HBM every kv step). This kernel keeps scores, probabilities,
and the running (m, l, acc) statistics in VMEM scratch across the kv-block
grid dimension — per-layer attention HBM traffic drops from
O(L*S*H) score bytes to O((L+S)*H*D) pure operand/result bytes.

Layout: grid = (B * H q-heads, q blocks, kv blocks); GQA is handled in the
BlockSpec index maps (q head h reads kv head h // rep — no KV repetition is
materialized). Causal and sliding-window masks are applied from absolute
block offsets. Block shapes default to MXU-aligned (128, 128).

Validated against ref.py / the pure-jnp chunked reference in interpret mode
(tests/test_flash_kernel.py); on real TPU hardware pass interpret=False.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            sm_scale: float, causal: bool, window: Optional[int],
            block_q: int, block_k: int, seq_k: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)            # (bq, D)
    k = k_ref[0].astype(jnp.float32)            # (bk, D)
    v = v_ref[0].astype(jnp.float32)            # (bk, D)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * sm_scale

    qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kpos < seq_k                          # padding
    if causal:
        mask &= qpos >= kpos
    if window is not None:
        mask &= qpos - kpos < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,        # (B, Lq, H, D), rope applied
    k: jax.Array,        # (B, S, Kv, D)
    v: jax.Array,        # (B, S, Kv, D)
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    B, Lq, H, D = q.shape
    S, Kv = k.shape[1], k.shape[2]
    assert H % Kv == 0
    rep = H // Kv
    sm_scale = 1.0 / math.sqrt(D)

    bq = min(block_q, Lq)
    bk = min(block_k, S)
    pad_q = (-Lq) % bq
    pad_k = (-S) % bk
    qh = jnp.moveaxis(q, 2, 1).reshape(B * H, Lq, D)
    kh = jnp.moveaxis(k, 2, 1).reshape(B * Kv, S, D)
    vh = jnp.moveaxis(v, 2, 1).reshape(B * Kv, S, D)
    if pad_q:
        qh = jnp.pad(qh, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        kh = jnp.pad(kh, ((0, 0), (0, pad_k), (0, 0)))
        vh = jnp.pad(vh, ((0, 0), (0, pad_k), (0, 0)))

    grid = (B * H, qh.shape[1] // bq, kh.shape[1] // bk)

    out = pl.pallas_call(
        functools.partial(_kernel, sm_scale=sm_scale, causal=causal,
                          window=window, block_q=bq, block_k=bk, seq_k=S),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda bh, iq, ik: (bh, iq, 0)),
            # GQA: q head bh reads kv head bh // rep (per batch)
            pl.BlockSpec((1, bk, D),
                         lambda bh, iq, ik, rep=rep, H=H, Kv=Kv:
                         ((bh // H) * Kv + (bh % H) // rep, ik, 0)),
            pl.BlockSpec((1, bk, D),
                         lambda bh, iq, ik, rep=rep, H=H, Kv=Kv:
                         ((bh // H) * Kv + (bh % H) // rep, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, qh.shape[1], D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),   # running max m
            pltpu.VMEM((bq, 1), jnp.float32),   # running denom l
            pltpu.VMEM((bq, D), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(qh, kh, vh)
    out = out[:, :Lq].reshape(B, H, Lq, D)
    return jnp.moveaxis(out, 1, 2)
