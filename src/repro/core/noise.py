"""Analog noise model + Redundant RNS (RRNS) error correction (paper §VII).

The paper argues RNS residues are noise-sensitive (small residue errors scale
up through CRT) and points to RRNS — adding ``r`` redundant moduli so that any
residue error can be detected/corrected by majority decoding over
``C(n+r, n)`` reconstruction subsets. The paper discusses but does not build
this; we implement it as a beyond-paper feature so the noise story is testable.
"""

from __future__ import annotations

import itertools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import rns


def inject_phase_noise(
    residues: jax.Array, moduli: Sequence[int], sigma: float, key: jax.Array
) -> jax.Array:
    """Additive Gaussian phase noise on residue readout, re-quantized to the
    nearest phase level and wrapped mod m (the detector reads phases on a ring).

    residues: (n, ...) int32, sigma in units of one phase level. The flat
    special case of :func:`repro.analog.channel.phase_noise` (same draws,
    bit-identical outputs).
    """
    from repro.analog import channel
    return channel.phase_noise(residues, moduli, (sigma,) * len(moduli), key)


def rrns_decode_np(
    residues: np.ndarray, moduli: Sequence[int], n_required: int, psi: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Majority-vote RRNS decoding on host (numpy, python-int CRT).

    residues: (n_total, ...) with n_total = n_required + n_redundant.
    Reconstructs X from every size-``n_required`` subset of moduli; the value
    agreed on by the most subsets (and consistent with |X| <= psi) wins.

    Returns (decoded, corrected_mask). With one redundant modulus single-residue
    errors are detectable; with two they are correctable (classic RRNS result).
    """
    n_total = len(moduli)
    flat = residues.reshape(n_total, -1)
    out = np.zeros(flat.shape[1], dtype=np.int64)
    corrected = np.zeros(flat.shape[1], dtype=bool)
    subsets = list(itertools.combinations(range(n_total), n_required))
    for j in range(flat.shape[1]):
        votes = {}
        for sub in subsets:
            sub_moduli = [moduli[i] for i in sub]
            sub_res = flat[list(sub), j][:, None]
            val = int(rns.from_rns_generic_np(sub_res, sub_moduli)[0])
            if abs(val) <= psi:
                votes[val] = votes.get(val, 0) + 1
        if not votes:
            out[j] = 0
            corrected[j] = True
            continue
        best = max(votes.items(), key=lambda kv: kv[1])
        out[j] = best[0]
        corrected[j] = best[1] < len(subsets)
    return out.reshape(residues.shape[1:]), corrected.reshape(residues.shape[1:])


def snr_requirement_db(m: int) -> float:
    """Paper §IV-B1: to distinguish m phase levels the core needs SNR > m.

    Canonical copy lives with the §IV-B device constants."""
    from repro.analog import device
    return device.snr_requirement_db(m)
