"""Block Floating Point (BFP) quantization (paper Section III-A, step 2).

Groups of ``g`` consecutive elements along the contraction dimension share one
exponent; each element keeps a signed mantissa of ``b_m`` magnitude bits.
Values are stored as ``q * 2^(E - (b_m - 1))`` where ``E = floor(log2 max|x|)``
over the group and ``q`` is an integer in ``[-(2^b_m - 1), 2^b_m - 1]``.

All functions are shape-polymorphic over leading batch dims and jit-friendly.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class BFPTensor(NamedTuple):
    """Quantized representation of a tensor grouped along its last axis.

    mantissa: integer-valued f32 array, shape (..., G, g).
    scale:    power-of-two f32 array, shape (..., G, 1) — equals 2^(E - b_m + 1).
    orig_k:   static original length of the contraction axis (pre-padding).
    """

    mantissa: jax.Array
    scale: jax.Array
    orig_k: int


def _group_reshape(x: jax.Array, g: int) -> Tuple[jax.Array, int]:
    """Pad the last axis to a multiple of g and reshape to (..., G, g)."""
    k = x.shape[-1]
    pad = (-k) % g
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    new_shape = x.shape[:-1] + ((k + pad) // g, g)
    return x.reshape(new_shape), k


def _exponent(maxabs: jax.Array) -> jax.Array:
    """floor(log2 |x|) computed exactly via frexp; zero groups get exponent 0."""
    # frexp: x = m * 2^e with m in [0.5, 1)  =>  floor(log2 x) = e - 1.
    _, e = jnp.frexp(jnp.maximum(maxabs, jnp.finfo(jnp.float32).tiny))
    e = e - 1
    return jnp.where(maxabs > 0, e, jnp.zeros_like(e))


def _exponent_bits(maxabs: jax.Array) -> jax.Array:
    """floor(log2 |x|) via f32 exponent-field extraction.

    Bit-identical to :func:`_exponent` for non-negative finite inputs
    (subnormals are clamped to the smallest normal first, matching the
    frexp path's tiny-clamp), but compiles to integer SIMD instead of a
    libm frexp call — measurably faster on CPU for large tensors.
    """
    m = jnp.maximum(maxabs, jnp.finfo(jnp.float32).tiny)
    bits = jax.lax.bitcast_convert_type(m.astype(jnp.float32), jnp.int32)
    e = ((bits >> 23) & 0xFF) - 127
    return jnp.where(maxabs > 0, e, jnp.zeros_like(e))


def _exp2_exact(e: jax.Array) -> jax.Array:
    """Exact 2^e for integer e, by constructing the f32 exponent field.

    (jnp.exp2 is NOT guaranteed exact for integer arguments on all XLA
    backends — observed 2-ulp error for exp2(96.0) on CPU.)
    """
    e = jnp.clip(e, -126, 127).astype(jnp.int32)
    return jax.lax.bitcast_convert_type((e + 127) << 23, jnp.float32)


def _round(v: jax.Array, rounding: str, key: Optional[jax.Array]) -> jax.Array:
    if rounding == "nearest":
        return jnp.round(v)  # round-half-to-even
    if rounding == "truncate":
        return jnp.trunc(v)  # toward zero: hardware LSB truncation on sign-magnitude
    if rounding == "stochastic":
        if key is None:
            raise ValueError("stochastic rounding requires a PRNG key")
        u = jax.random.uniform(key, v.shape, dtype=v.dtype)
        return jnp.floor(v + u)
    raise ValueError(f"unknown rounding mode {rounding!r}")


def bfp_quantize(
    x: jax.Array,
    b_m: int,
    g: int,
    rounding: str = "nearest",
    key: Optional[jax.Array] = None,
) -> BFPTensor:
    """Quantize ``x`` along its last axis into BFP(b_m, g).

    Returns mantissas as integer-valued float32 (exact for b_m <= 23) so the
    downstream integer dot products map straight onto the MXU.
    """
    x = x.astype(jnp.float32)
    xg, orig_k = _group_reshape(x, g)
    maxabs = jnp.max(jnp.abs(xg), axis=-1, keepdims=True)
    e = _exponent_bits(maxabs)  # == _exponent, minus the libm frexp call
    scale = _exp2_exact(e - (b_m - 1))
    qmax = float(2**b_m - 1)
    q = _round(xg / scale, rounding, key)
    q = jnp.clip(q, -qmax, qmax)
    return BFPTensor(mantissa=q, scale=scale, orig_k=orig_k)


def bfp_dequantize(t: BFPTensor) -> jax.Array:
    """Reconstruct the (quantized) values, shape (..., K) with padding removed."""
    xg = t.mantissa * t.scale
    flat = xg.reshape(xg.shape[:-2] + (xg.shape[-2] * xg.shape[-1],))
    return flat[..., : t.orig_k]


def bfp_quantize_contract(
    w: jax.Array,
    b_m: int,
    g: int,
    rounding: str = "nearest",
    key: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Quantize a weight operand ``w: (K, N)`` grouped along K (axis -2).

    Transpose-free equivalent of ``bfp_quantize(w.T, ...)`` followed by
    transposing mantissa/scale back to contraction-major layout: returns
    ``(mantissa (G, g, N), scale (G, 1, N))`` with bit-identical values but
    no (K, N) <-> (N, K) round-trip copies. This is the layout every
    group-batched GEMM backend consumes directly.
    """
    w = w.astype(jnp.float32)
    K, N = w.shape
    pad = (-K) % g
    if pad:
        w = jnp.pad(w, ((0, pad), (0, 0)))
    wg = w.reshape((K + pad) // g, g, N)
    maxabs = jnp.max(jnp.abs(wg), axis=-2, keepdims=True)     # (G, 1, N)
    scale = _exp2_exact(_exponent_bits(maxabs) - (b_m - 1))
    qmax = float(2**b_m - 1)
    q = jnp.clip(_round(wg / scale, rounding, key), -qmax, qmax)
    return q, scale


def bfp_decompose_contract(
    w: jax.Array,
    b_m: int,
    g: int,
) -> Tuple[jax.Array, jax.Array]:
    """Exact (mantissa, scale) decomposition of an ALREADY-on-grid weight.

    The weight-stationary contract (``policy.assume_quantized_weights``):
    ``w`` was produced by ``bfp_fake_quant`` with the SAME (b_m, g) grouping
    along its contraction dim, so every group max re-derives the original
    exponent (the quantizer keeps ``max|q| in [2^(b_m-1), 2^b_m - 1]``) and
    ``w / scale`` recovers the integer mantissas exactly — no round, no
    clip. Bit-identical to :func:`bfp_quantize_contract` for on-grid
    inputs (property-tested); garbage-in for off-grid inputs, exactly like
    the fast path's folded reuse of a pre-quantized operand.
    """
    w = w.astype(jnp.float32)
    K, N = w.shape
    pad = (-K) % g
    if pad:
        w = jnp.pad(w, ((0, pad), (0, 0)))
    wg = w.reshape((K + pad) // g, g, N)
    maxabs = jnp.max(jnp.abs(wg), axis=-2, keepdims=True)     # (G, 1, N)
    scale = _exp2_exact(_exponent_bits(maxabs) - (b_m - 1))
    return wg * (1.0 / scale), scale


def bfp_fake_quant(
    x: jax.Array,
    b_m: int,
    g: int,
    rounding: str = "nearest",
    key: Optional[jax.Array] = None,
) -> jax.Array:
    """Quantize-dequantize in one shot ("fake quantization")."""
    return bfp_dequantize(bfp_quantize(x, b_m, g, rounding, key))


def bfp_error_bound(b_m: int) -> float:
    """Per-element relative-to-group-max quantization error bound.

    |x - dq(q(x))| <= 0.5 * scale = 2^(E - b_m)  for round-to-nearest, and
    <= scale = 2^(E - b_m + 1) for truncation. Expressed as a fraction of the
    group max (|max| >= 2^E): nearest -> 2^-b_m, truncate -> 2^(1-b_m).
    """
    return 2.0 ** (-b_m)
