"""Stationary-residue weight caching (the program-once MMVMU dataflow).

The photonic core programs a weight tile into the MMVMU phase shifters ONCE
and then streams activations against it for many MVMs (paper §III-A); the
programming cost — BFP quantization, forward conversion to residues, DAC
re-gridding and phase-shifter drift — is paid per *programming event*, not
per GEMM. The RNS-family backends used to pay all of it per call, which at
serving decode shapes (M = slots) dominates the whole GEMM.

:class:`StationaryResidues` is that programmed tile as a pytree: the
residue-encoded, channel-programmed weight operand of one GEMM site, in the
exact ``(n_mod, G, g, N)`` group-major layout the group-batched backends
consume. Backends whose registry entry sets ``supports_stationary_residues``
accept it directly in the ``w`` slot of ``mirage_matmul`` /
``mirage_matmul_nograd`` and skip the whole weight-side pipeline; the
serving engine builds one per GEMM weight at admission
(:func:`encode_stationary_params`) and reuses it across every prefill batch
and decode tick. Being a pytree, a stacked ``(L, ...)`` encoding scans and
vmaps exactly like the raw stacked weights it replaces.

Clean-channel encodings are bit-identical to what the backends compute
per-call, so swapping them in changes no numerics (parity-tested). With
``phase_drift_sigma > 0`` the drift is drawn once at encode time — the
hardware-faithful semantics (drift is a programming error, frozen until the
tile is reprogrammed), where the per-call path re-draws it per GEMM.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import bfp, rns


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class StationaryResidues:
    """A residue-encoded, channel-programmed stationary GEMM weight.

    residues: int32 ``(*stack, n_mod, G, g, N)`` programmed residues over
      ``moduli`` (group-major, contraction dim split into G groups of g).
    scale: f32 ``(*stack, G, 1, N)`` BFP group scales (powers of two).
    moduli: static moduli tuple the residues are encoded over.
    b_m / g / orig_k: static BFP parameters + original contraction length.
    """

    residues: jax.Array
    scale: jax.Array
    moduli: Tuple[int, ...]
    b_m: int
    g: int
    orig_k: int

    def tree_flatten(self):
        return ((self.residues, self.scale),
                (self.moduli, self.b_m, self.g, self.orig_k))

    @classmethod
    def tree_unflatten(cls, aux, children):
        residues, scale = children
        moduli, b_m, g, orig_k = aux
        return cls(residues=residues, scale=scale, moduli=moduli, b_m=b_m,
                   g=g, orig_k=orig_k)

    def check_matches(self, policy, moduli: Tuple[int, ...],
                      k_dim: int) -> None:
        """Static consistency check against the executing policy."""
        if tuple(self.moduli) != tuple(moduli):
            raise ValueError(
                f"stationary residues were programmed over moduli "
                f"{self.moduli} but the policy executes over {moduli} — "
                f"re-encode with the policy that will run them")
        if (self.b_m, self.g) != (policy.b_m, policy.g):
            raise ValueError(
                f"stationary residues use BFP(b_m={self.b_m}, g={self.g}) "
                f"but the policy is BFP(b_m={policy.b_m}, g={policy.g})")
        if self.orig_k != k_dim:
            raise ValueError(
                f"stationary residues hold a K={self.orig_k} weight but the "
                f"activation contraction dim is K={k_dim}")


def stationary_moduli(policy) -> Tuple[int, ...]:
    """Moduli set a stationary weight must be programmed over for a policy:
    base + redundant for the error-corrected mode, base otherwise."""
    if policy.mode in ("mirage_rrns", "mirage_rrns_ref"):
        from repro.analog import rrns
        return rrns.rrns_moduli(policy)
    return tuple(policy.moduli)


def _leaf_key(policy, path: str) -> Optional[jax.Array]:
    """Deterministic per-leaf programming key: noise_seed folded with a
    crc32 of the parameter path (no CPython hash — reproducible anywhere)."""
    if policy.noise_seed is None:
        return None
    base = jax.random.PRNGKey(policy.noise_seed)
    return jax.random.fold_in(base, zlib.crc32(path.encode()) & 0x7FFFFFFF)


def encode_stationary(w: jax.Array, policy,
                      moduli: Optional[Sequence[int]] = None,
                      key: Optional[jax.Array] = None) -> StationaryResidues:
    """Program one weight ``(*stack, K, N)`` into stationary residues.

    BFP-quantize along K, forward-convert to ``moduli`` residues, then run
    the program-side analog chain (DAC re-grid + phase-shifter drift) for
    channel-carrying modes. Leading stack dims (scan layers, MoE experts)
    are vmapped through unchanged.
    """
    moduli = tuple(moduli) if moduli is not None else stationary_moduli(policy)
    if w.ndim > 2:
        if key is not None:
            # one programming-drift draw per stacked tile (layer / expert)
            keys = jax.random.split(key, w.shape[0])
            return jax.vmap(
                lambda wi, ki: encode_stationary(wi, policy, moduli, ki)
            )(w, keys)
        return jax.vmap(
            lambda wi: encode_stationary(wi, policy, moduli, None))(w)
    from repro.analog import channel
    cfg = channel.AnalogChannelConfig.from_policy(policy)
    qw, sw = bfp.bfp_quantize_contract(w, policy.b_m, policy.g,
                                       policy.rounding)       # (G, g, N)
    wr = rns.to_rns(qw, moduli)                    # (n_mod, G, g, N) int32
    carries_channel = policy.mode in ("mirage_rns_noisy", "mirage_rrns",
                                      "mirage_rrns_ref")
    if carries_channel:
        k_prog = key
        if cfg.phase_drift_sigma > 0 and k_prog is None:
            if policy.noise_seed is None:
                raise ValueError(
                    "phase_drift_sigma > 0 needs a programming key: pass "
                    "key= or set policy.noise_seed")
            k_prog = _leaf_key(policy, "stationary")
        wr = channel.apply_program_channel(wr, moduli, cfg, k_prog)
    return StationaryResidues(residues=wr, scale=sw, moduli=moduli,
                              b_m=policy.b_m, g=policy.g, orig_k=w.shape[-2])


# parameter leaves that are GEMM weights (matches the trainer's
# weight-stationary quantization convention); "emb" is excluded — embedding
# gathers and the tied unembed head stay FP32 on the digital side
_GEMM_LEAF = ("w", "gate", "up", "down")


def encode_stationary_params(params, policy):
    """Program every GEMM weight leaf of a param pytree into stationary
    residues, leaving everything else (norms, biases, embeddings, router
    logits — consumed outside ``mirage_matmul``) untouched.

    The serving engine calls this once at admission; the resulting pytree
    drops into every jitted prefill/decode signature in place of ``params``
    (containers flatten to array leaves, stacked layers still scan).
    """

    def enc(path, p):
        keys = [k.key if hasattr(k, "key") else str(k) for k in path]
        leaf = keys[-1]
        if leaf not in _GEMM_LEAF or getattr(p, "ndim", 0) < 2:
            return p
        if "router" in keys:
            return p                   # router matmul runs plain fp32
        pathstr = "/".join(keys)
        return encode_stationary(p, policy, key=_leaf_key(policy, pathstr))

    return jax.tree_util.tree_map_with_path(enc, params)
