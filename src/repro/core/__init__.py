"""Mirage core: BFP + RNS numerics for DNN training (the paper's contribution)."""

from repro.core.precision import (
    MiragePolicy,
    PAPER_POLICY,
    FP32_POLICY,
    BF16_POLICY,
    INT8_POLICY,
    FAITHFUL_POLICY,
    RNS_POLICY,
    get_policy,
    special_moduli,
    required_output_bits,
    check_overflow_bound,
)
from repro.core.bfp import (
    BFPTensor,
    bfp_quantize,
    bfp_dequantize,
    bfp_fake_quant,
    bfp_error_bound,
)
from repro.core.rns import (
    to_rns,
    to_rns_special,
    from_rns_special,
    from_rns_generic_np,
    rns_matmul,
    mod_matmul,
    rns_dot_reconstruct,
)
from repro.core.gemm import (
    mirage_matmul,
    mirage_matmul_nograd,
    quantize_operands,
)
from repro.core.bfp import bfp_quantize_contract
from repro.core import backends
from repro.core.backends import (
    GemmBackend,
    available_backends,
    get_backend,
    register_fn,
)
