"""Mirage GEMM: BFP + RNS matrix multiplication with a quantized backward pass.

This is the paper's contribution as a composable JAX op. ``mirage_matmul``
executes ``x @ w`` under a :class:`MiragePolicy`, dispatching on
``policy.mode`` through the backend registry (``repro.core.backends``):

  fp32 / bf16 / int8       baselines the paper compares against
  mirage_fast              BFP-quantize both operands along the contraction
                           dim, fold the power-of-two group scales back into
                           the mantissas, and run ONE MXU matmul. Value-exact
                           w.r.t. the faithful path whenever f32 accumulation
                           is exact (property-tested).
  mirage_faithful          group-batched integer dot products + FP32 partial
                           accumulation (paper dataflow steps 2-9, with the
                           RNS conversions elided exactly as the paper's own
                           accuracy model does, Section IV-A).
  mirage_rns               the full hardware path: forward conversion to the
                           special moduli set, per-modulus modular GEMM over
                           all groups at once, CRT reverse conversion, FP32
                           scale-accumulate. Optional Pallas kernel + analog
                           noise injection.
  mirage_rns_pallas        mirage_rns forced through the Pallas residue kernel.
  *_ref                    the seed fori_loop implementations, frozen as
                           parity oracles and benchmark baselines.

New modes register themselves (``backends.register_fn``) and are reachable
from every consumer without touching this module.

Training: ``mirage_matmul`` has a ``custom_vjp`` so BOTH backward GEMMs
(Eqs. 2-3) run the same quantized path, each BFP-grouped along its own
contraction dimension, while the caller keeps FP32 master weights (Eq. 4).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import backends, bfp
from repro.core.precision import MiragePolicy


# --------------------------------------------------------------------------
# Operand quantization helpers (public API, used by tests and tooling)
# --------------------------------------------------------------------------

def quantize_operands(
    x: jax.Array, w: jax.Array, policy: MiragePolicy
) -> Tuple[bfp.BFPTensor, bfp.BFPTensor]:
    """BFP-quantize activations (grouped along last dim of x) and weights
    (grouped along first dim of w — i.e. the shared contraction dim)."""
    qx = bfp.bfp_quantize(x, policy.b_m, policy.g, policy.rounding)
    # Weights: (K, N) -> transpose so the contraction dim is last, quantize,
    # then restore layout as (G, g, N).
    qwt = bfp.bfp_quantize(w.T, policy.b_m, policy.g, policy.rounding)
    return qx, qwt


# --------------------------------------------------------------------------
# Registry dispatch
# --------------------------------------------------------------------------

def _forward_impl(x: jax.Array, w: jax.Array, policy: MiragePolicy,
                  key: Optional[jax.Array] = None) -> jax.Array:
    return backends.resolve(policy).forward(x, w, policy, key=key)


# --------------------------------------------------------------------------
# Differentiable op: quantized forward AND backward GEMMs
# --------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def mirage_matmul(x: jax.Array, w: jax.Array, policy: MiragePolicy) -> jax.Array:
    """``x @ w`` under the Mirage numerics policy. x: (..., K), w: (K, N)."""
    return _forward_impl(x, w, policy)


def _mm_fwd(x, w, policy):
    return _forward_impl(x, w, policy), (x, w)


def _mm_bwd(policy, residuals, gout):
    x, w = residuals
    gout = gout.astype(jnp.float32)
    # dX = dO @ W^T (contraction over N). Under weight-stationary quant the
    # transposed read reuses the SAME stored grid values (hardware-faithful).
    dx = _forward_impl(gout, w.T, policy)
    # dW = X^T @ dO (contraction over tokens): neither operand is a
    # stationary weight -> always quantize both sides.
    dw_policy = (policy.replace(assume_quantized_weights=False)
                 if policy.assume_quantized_weights else policy)
    xf = x.reshape(-1, x.shape[-1])            # (M, K)
    gf = gout.reshape(-1, gout.shape[-1])      # (M, N)
    dw = _forward_impl(xf.T, gf, dw_policy)    # (K, N)
    return dx.astype(x.dtype), dw.astype(w.dtype)


mirage_matmul.defvjp(_mm_fwd, _mm_bwd)


def mirage_matmul_nograd(x, w, policy: MiragePolicy,
                         key: Optional[jax.Array] = None):
    """Forward-only variant (serving paths); avoids residual bookkeeping.

    ``key`` seeds stochastic backends (``policy.noise_sigma > 0`` analog
    noise); deterministic backends ignore it.
    """
    return _forward_impl(x, w, policy, key=key)
