"""Mirage GEMM: BFP + RNS matrix multiplication with a quantized backward pass.

This is the paper's contribution as a composable JAX op. ``mirage_matmul``
executes ``x @ w`` under a :class:`MiragePolicy`:

  fp32 / bf16 / int8       baselines the paper compares against
  mirage_fast              BFP-quantize both operands along the contraction
                           dim, fold the power-of-two group scales back into
                           the mantissas, and run ONE MXU matmul. Value-exact
                           w.r.t. the faithful path whenever f32 accumulation
                           is exact (property-tested).
  mirage_faithful          per-group integer dot products + FP32 partial
                           accumulation (paper dataflow steps 2-9, with the
                           RNS conversions elided exactly as the paper's own
                           accuracy model does, Section IV-A).
  mirage_rns               the full hardware path: forward conversion to the
                           special moduli set, per-modulus modular GEMM,
                           CRT reverse conversion, FP32 scale-accumulate.

Training: ``mirage_matmul`` has a ``custom_vjp`` so BOTH backward GEMMs
(Eqs. 2-3) run the same quantized path, each BFP-grouped along its own
contraction dimension, while the caller keeps FP32 master weights (Eq. 4).
"""

from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import bfp, rns
from repro.core.precision import MiragePolicy


# --------------------------------------------------------------------------
# Baselines
# --------------------------------------------------------------------------

def _matmul_fp32(x, w):
    return jnp.matmul(x.astype(jnp.float32), w.astype(jnp.float32),
                      preferred_element_type=jnp.float32)


def _matmul_bf16(x, w):
    return jnp.matmul(x.astype(jnp.bfloat16), w.astype(jnp.bfloat16),
                      preferred_element_type=jnp.float32)


def _matmul_int8(x, w):
    """Per-tensor symmetric int8 (the paper's INT8 systolic baseline)."""
    sx = jnp.maximum(jnp.max(jnp.abs(x)), 1e-30) / 127.0
    sw = jnp.maximum(jnp.max(jnp.abs(w)), 1e-30) / 127.0
    qx = jnp.clip(jnp.round(x / sx), -127, 127)
    qw = jnp.clip(jnp.round(w / sw), -127, 127)
    acc = jnp.matmul(qx, qw, preferred_element_type=jnp.float32)
    return acc * (sx * sw)


# --------------------------------------------------------------------------
# Mirage paths
# --------------------------------------------------------------------------

def quantize_operands(
    x: jax.Array, w: jax.Array, policy: MiragePolicy
) -> Tuple[bfp.BFPTensor, bfp.BFPTensor]:
    """BFP-quantize activations (grouped along last dim of x) and weights
    (grouped along first dim of w — i.e. the shared contraction dim)."""
    qx = bfp.bfp_quantize(x, policy.b_m, policy.g, policy.rounding)
    # Weights: (K, N) -> transpose so the contraction dim is last, quantize,
    # then restore layout as (G, g, N).
    qwt = bfp.bfp_quantize(w.T, policy.b_m, policy.g, policy.rounding)
    return qx, qwt


def _fold_scales(q: bfp.BFPTensor) -> jax.Array:
    """Dequantized values, padding INCLUDED (pad mantissas are zero)."""
    xg = q.mantissa * q.scale
    return xg.reshape(xg.shape[:-2] + (xg.shape[-2] * xg.shape[-1],))


def _matmul_mirage_fast(x, w, policy: MiragePolicy):
    if policy.use_pallas:
        from repro.kernels import ops as kops
        return kops.mirage_matmul_fused(x, w, policy)
    dt = jnp.bfloat16 if policy.compute_dtype == "bfloat16" else jnp.float32
    qx = bfp.bfp_quantize(x, policy.b_m, policy.g, policy.rounding)
    xq = _fold_scales(qx)                      # (..., Kpad)
    if policy.assume_quantized_weights:
        # weight operand already on the BFP grid (weight-stationary quant:
        # quantized once per step, reused across microbatches/remat/transpose)
        wq = w
        if xq.shape[-1] != w.shape[0]:         # padding from x grouping
            wq = jnp.pad(w, ((0, xq.shape[-1] - w.shape[0]), (0, 0)))
    else:
        qwt = bfp.bfp_quantize(w.T, policy.b_m, policy.g, policy.rounding)
        wq = _fold_scales(qwt).T               # (Kpad, N)
        if wq.shape[0] != xq.shape[-1]:
            wq = wq[: xq.shape[-1]]
    return jnp.matmul(xq.astype(dt), wq.astype(dt),
                      preferred_element_type=jnp.float32)


def _per_group_operands(x, w, policy: MiragePolicy):
    """Returns (qx, sx, qw, sw): mantissas/scales shaped for group-wise dots.

    qx: (..., G, g)   sx: (..., G, 1)
    qw: (G, g, N)     sw: (G, 1, N)
    """
    qxt, qwt = quantize_operands(x, w, policy)
    qw = qwt.mantissa.transpose(1, 2, 0)  # (N, G, g) -> (G, g, N)
    sw = qwt.scale.transpose(1, 2, 0)     # (N, G, 1) -> (G, 1, N)
    return qxt.mantissa, qxt.scale, qw, sw


def _matmul_mirage_faithful(x, w, policy: MiragePolicy):
    """Paper dataflow: per-group integer dot + FP32 partial accumulation."""
    qx, sx, qw, sw = _per_group_operands(x, w, policy)
    G = qx.shape[-2]
    N = qw.shape[-1]
    out_shape = x.shape[:-1] + (N,)

    def body(j, acc):
        qxj = jax.lax.dynamic_index_in_dim(qx, j, axis=qx.ndim - 2, keepdims=False)
        sxj = jax.lax.dynamic_index_in_dim(sx, j, axis=sx.ndim - 2, keepdims=False)
        qwj = jax.lax.dynamic_index_in_dim(qw, j, axis=0, keepdims=False)
        swj = jax.lax.dynamic_index_in_dim(sw, j, axis=0, keepdims=False)
        # Exact integer dot product of one g-group (|.| <= g * qmax^2 <= psi).
        p = jnp.matmul(qxj, qwj, preferred_element_type=jnp.float32)
        return acc + p * sxj * swj[0]

    acc0 = jnp.zeros(out_shape, jnp.float32)
    return jax.lax.fori_loop(0, G, body, acc0)


def _matmul_mirage_rns(x, w, policy: MiragePolicy):
    """Full RNS hardware path: forward conversion -> per-modulus modular GEMM
    per g-group -> CRT reverse conversion -> FP32 scale-accumulate."""
    qx, sx, qw, sw = _per_group_operands(x, w, policy)
    G = qx.shape[-2]
    N = qw.shape[-1]
    k = policy.k
    moduli = policy.moduli
    out_shape = x.shape[:-1] + (N,)

    def body(j, acc):
        qxj = jax.lax.dynamic_index_in_dim(qx, j, axis=qx.ndim - 2, keepdims=False)
        sxj = jax.lax.dynamic_index_in_dim(sx, j, axis=sx.ndim - 2, keepdims=False)
        qwj = jax.lax.dynamic_index_in_dim(qw, j, axis=0, keepdims=False)
        swj = jax.lax.dynamic_index_in_dim(sw, j, axis=0, keepdims=False)
        xr = rns.to_rns_special(qxj, k)            # (3, ..., g)
        wr = rns.to_rns_special(qwj, k)            # (3, g, N)
        res = jnp.stack(
            [rns.mod_matmul(xr[i], wr[i], m) for i, m in enumerate(moduli)],
            axis=0,
        ).astype(jnp.int32)
        p = rns.from_rns_special(res, k, signed=True).astype(jnp.float32)
        return acc + p * sxj * swj[0]

    acc0 = jnp.zeros(out_shape, jnp.float32)
    return jax.lax.fori_loop(0, G, body, acc0)


def _forward_impl(x: jax.Array, w: jax.Array, policy: MiragePolicy) -> jax.Array:
    if policy.mode == "fp32":
        return _matmul_fp32(x, w)
    if policy.mode == "bf16":
        return _matmul_bf16(x, w)
    if policy.mode == "int8":
        return _matmul_int8(x, w)
    if policy.mode == "mirage_fast":
        return _matmul_mirage_fast(x, w, policy)
    if policy.mode == "mirage_faithful":
        return _matmul_mirage_faithful(x, w, policy)
    if policy.mode == "mirage_rns":
        return _matmul_mirage_rns(x, w, policy)
    raise ValueError(f"unknown mode {policy.mode!r}")


# --------------------------------------------------------------------------
# Differentiable op: quantized forward AND backward GEMMs
# --------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def mirage_matmul(x: jax.Array, w: jax.Array, policy: MiragePolicy) -> jax.Array:
    """``x @ w`` under the Mirage numerics policy. x: (..., K), w: (K, N)."""
    return _forward_impl(x, w, policy)


def _mm_fwd(x, w, policy):
    return _forward_impl(x, w, policy), (x, w)


def _mm_bwd(policy, residuals, gout):
    x, w = residuals
    gout = gout.astype(jnp.float32)
    # dX = dO @ W^T (contraction over N). Under weight-stationary quant the
    # transposed read reuses the SAME stored grid values (hardware-faithful).
    dx = _forward_impl(gout, w.T, policy)
    # dW = X^T @ dO (contraction over tokens): neither operand is a
    # stationary weight -> always quantize both sides.
    dw_policy = (policy.replace(assume_quantized_weights=False)
                 if policy.assume_quantized_weights else policy)
    xf = x.reshape(-1, x.shape[-1])            # (M, K)
    gf = gout.reshape(-1, gout.shape[-1])      # (M, N)
    dw = _forward_impl(xf.T, gf, dw_policy)    # (K, N)
    return dx.astype(x.dtype), dw.astype(w.dtype)


mirage_matmul.defvjp(_mm_fwd, _mm_bwd)


def mirage_matmul_nograd(x, w, policy: MiragePolicy):
    """Forward-only variant (serving paths); avoids residual bookkeeping."""
    return _forward_impl(x, w, policy)
