"""Mirage GEMM: BFP + RNS matrix multiplication with a quantized backward pass.

This is the paper's contribution as a composable JAX op. ``mirage_matmul``
executes ``x @ w`` under a :class:`MiragePolicy`, dispatching on
``policy.mode`` through the backend registry (``repro.core.backends``):

  fp32 / bf16 / int8       baselines the paper compares against
  mirage_fast              BFP-quantize both operands along the contraction
                           dim, fold the power-of-two group scales back into
                           the mantissas, and run ONE MXU matmul. Value-exact
                           w.r.t. the faithful path whenever f32 accumulation
                           is exact (property-tested).
  mirage_faithful          group-batched integer dot products + FP32 partial
                           accumulation (paper dataflow steps 2-9, with the
                           RNS conversions elided exactly as the paper's own
                           accuracy model does, Section IV-A).
  mirage_rns               the full hardware path: forward conversion to the
                           special moduli set, per-modulus modular GEMM over
                           all groups at once, CRT reverse conversion, FP32
                           scale-accumulate. Optional Pallas kernel + analog
                           noise injection.
  mirage_rns_pallas        mirage_rns forced through the Pallas residue kernel.
  *_ref                    the seed fori_loop implementations, frozen as
                           parity oracles and benchmark baselines.

New modes register themselves (``backends.register_fn``) and are reachable
from every consumer without touching this module.

Training: ``mirage_matmul`` has a ``custom_vjp`` so BOTH backward GEMMs
(Eqs. 2-3) run the same quantized path, each BFP-grouped along its own
contraction dimension, while the caller keeps FP32 master weights (Eq. 4).
"""

from __future__ import annotations

import contextlib
import functools
import threading
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import backends, bfp, stationary
from repro.core.precision import MiragePolicy
from repro.obs import health as obs_health
from repro.obs import trace as obs_trace


# --------------------------------------------------------------------------
# Ambient noise keys (serving: fresh analog noise per decode tick)
# --------------------------------------------------------------------------
#
# ``policy.noise_seed`` alone gives a STATIC error pattern per GEMM site
# (the key is the seed folded with operand shapes) — right for programming/
# fabrication error, wrong for shot/thermal noise which redraws every shot.
# The policy is a hashable static argument of every jitted step, so varying
# the seed per tick would recompile per tick. Instead a caller *inside* a
# jitted function opens :func:`noise_key_scope` with a traced key (a plain
# input of that jit); every ``mirage_matmul`` / ``mirage_matmul_nograd``
# traced under the scope whose backend ``supports_noise`` and got no
# explicit ``key`` derives a per-call subkey (scope key folded with a call
# counter, so each GEMM site draws independently). Deterministic backends
# never consult the scope, and nothing changes when no scope is open —
# training and the keyless static-seed path are untouched.

_AMBIENT = threading.local()


@contextlib.contextmanager
def noise_key_scope(key: jax.Array):
    """Make ``key`` the ambient randomness source for stochastic GEMMs
    traced inside the ``with`` block. Re-entrant (inner scopes shadow).

    Forward-only by design (serving): backward GEMMs (``_mm_bwd``) run
    outside the caller's scope and keep the existing key-or-seed
    requirement — training under noise still goes through
    ``policy.noise_seed``."""
    stack = getattr(_AMBIENT, "stack", None)
    if stack is None:
        stack = _AMBIENT.stack = []
    stack.append([key, 0])
    try:
        yield
    finally:
        stack.pop()


@contextlib.contextmanager
def fold_noise_scope(tag):
    """Nested scope whose key is the enclosing scope's key folded with
    ``tag`` — no-op when no scope is open. ``tag`` may be TRACED (a scan
    layer index): the per-call counter alone is a trace-time constant, so
    without this every iteration of a ``lax.scan`` over layers would reuse
    the same subkey per GEMM site. The model's layer scans open one of
    these per iteration so each layer draws independent noise."""
    stack = getattr(_AMBIENT, "stack", None)
    if not stack:
        yield
        return
    with noise_key_scope(jax.random.fold_in(stack[-1][0], tag)):
        yield


def _ambient_subkey() -> Optional[jax.Array]:
    stack = getattr(_AMBIENT, "stack", None)
    if not stack:
        return None
    top = stack[-1]
    top[1] += 1
    return jax.random.fold_in(top[0], top[1])


# --------------------------------------------------------------------------
# Operand quantization helpers (public API, used by tests and tooling)
# --------------------------------------------------------------------------

def quantize_operands(
    x: jax.Array, w: jax.Array, policy: MiragePolicy
) -> Tuple[bfp.BFPTensor, bfp.BFPTensor]:
    """BFP-quantize activations (grouped along last dim of x) and weights
    (grouped along first dim of w — i.e. the shared contraction dim)."""
    qx = bfp.bfp_quantize(x, policy.b_m, policy.g, policy.rounding)
    # Weights: (K, N) -> transpose so the contraction dim is last, quantize,
    # then restore layout as (G, g, N).
    qwt = bfp.bfp_quantize(w.T, policy.b_m, policy.g, policy.rounding)
    return qx, qwt


# --------------------------------------------------------------------------
# Registry dispatch
# --------------------------------------------------------------------------

def _forward_impl(x: jax.Array, w: jax.Array, policy: MiragePolicy,
                  key: Optional[jax.Array] = None) -> jax.Array:
    backend = backends.resolve(policy)
    if (isinstance(w, stationary.StationaryResidues)
            and not backend.supports_stationary_residues):
        raise TypeError(
            f"backend {backend.name!r} cannot execute a pre-encoded "
            f"StationaryResidues weight (capability flag "
            f"supports_stationary_residues is unset) — pass the raw FP32 "
            f"weight, or run an RNS-family mode")
    if key is None and backend.supports_noise:
        key = _ambient_subkey()
    # span around the dispatch: inside jit this runs at TRACE time, so the
    # host duration is compile/dispatch cost — the value is the
    # jax.profiler.TraceAnnotation it opens when the tracer has
    # annotate=True, which names the backend's device ops in a profiler
    # capture (launch/serve.py --profile-window)
    with obs_trace.get_tracer().span(f"gemm.{policy.mode}"):
        return backend.forward(x, w, policy, key=key)


# --------------------------------------------------------------------------
# Differentiable op: quantized forward AND backward GEMMs
# --------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def mirage_matmul(x: jax.Array, w: jax.Array, policy: MiragePolicy) -> jax.Array:
    """``x @ w`` under the Mirage numerics policy. x: (..., K), w: (K, N)."""
    return _forward_impl(x, w, policy)


def _mm_fwd(x, w, policy):
    return _forward_impl(x, w, policy), (x, w)


def _mm_bwd(policy, residuals, gout):
    x, w = residuals
    gout = gout.astype(jnp.float32)
    # dX = dO @ W^T (contraction over N). Under weight-stationary quant the
    # transposed read reuses the SAME stored grid values (hardware-faithful).
    # Backends whose weight-stationary skip is only exact for aligned
    # groupings (group-dot/RNS: integer mantissas required) re-quantize the
    # transposed read instead — w.T is grouped along N, not the K grid.
    dx_policy = policy
    if (policy.assume_quantized_weights
            and backends.resolve(policy).weight_stationary_aligned_only):
        dx_policy = policy.replace(assume_quantized_weights=False)
    dx = _forward_impl(gout, w.T, dx_policy)
    # dW = X^T @ dO (contraction over tokens): neither operand is a
    # stationary weight -> always quantize both sides.
    dw_policy = (policy.replace(assume_quantized_weights=False)
                 if policy.assume_quantized_weights else policy)
    xf = x.reshape(-1, x.shape[-1])            # (M, K)
    gf = gout.reshape(-1, gout.shape[-1])      # (M, N)
    dw = _forward_impl(xf.T, gf, dw_policy)    # (K, N)
    return dx.astype(x.dtype), dw.astype(w.dtype)


mirage_matmul.defvjp(_mm_fwd, _mm_bwd)


def mirage_matmul_nograd(x, w, policy: MiragePolicy,
                         key: Optional[jax.Array] = None):
    """Forward-only variant (serving paths); avoids residual bookkeeping.

    ``key`` seeds stochastic backends (``policy.noise_sigma > 0`` analog
    noise); deterministic backends ignore it. When no key is passed and an
    enclosing :func:`noise_key_scope` is open (the serving engine opens one
    per decode tick), stochastic backends draw a per-call subkey from it.
    """
    return _forward_impl(x, w, policy, key=key)


def mirage_matmul_auto(x, w, policy: MiragePolicy) -> jax.Array:
    """:func:`mirage_matmul`, except under an open analog-health scope.

    ``custom_vjp`` traces its primal in a sub-trace whose intermediates
    cannot legally reach the enclosing scope, so health records made inside
    the differentiable op would leak. Health scopes are only opened by the
    serving engine's forward-only steps (``repro.obs.health``), where the
    custom backward is dead weight anyway — dispatch straight to the
    forward impl there. Model GEMM call sites shared between training and
    serving route through this."""
    if obs_health.active():
        return _forward_impl(x, w, policy)
    return mirage_matmul(x, w, policy)
