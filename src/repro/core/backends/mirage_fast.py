"""mirage_fast: BFP-quantize, fold scales into mantissas, one MXU matmul.

Value-exact w.r.t. the faithful path whenever f32 accumulation is exact
(property-tested). The weight side quantizes in place along K via
``bfp_quantize_contract`` — bit-identical values to the seed's
transpose/quantize/transpose-back dance, without the two (K, N) copies.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bfp
from repro.core.backends.base import register_fn


def _fold_x(x, policy):
    """Quantize-and-fold activations along the contraction dim -> (..., Kpad)."""
    t = bfp.bfp_quantize(x, policy.b_m, policy.g, policy.rounding)
    xg = t.mantissa * t.scale
    return xg.reshape(xg.shape[:-2] + (xg.shape[-2] * xg.shape[-1],))


@register_fn("mirage_fast",
             description="BFP quantize -> fold scales -> one MXU matmul",
             supports_weight_stationary=True)
def _matmul_mirage_fast(x, w, policy, *, key=None):
    if policy.use_pallas:
        from repro.kernels import ops as kops
        return kops.mirage_matmul_fused(x, w, policy)
    dt = jnp.bfloat16 if policy.compute_dtype == "bfloat16" else jnp.float32
    xq = _fold_x(x, policy)                    # (..., Kpad)
    if policy.assume_quantized_weights:
        # weight operand already on the BFP grid (weight-stationary quant:
        # quantized once per step, reused across microbatches/remat/transpose)
        wq = w
        if xq.shape[-1] != w.shape[0]:         # padding from x grouping
            wq = jnp.pad(w, ((0, xq.shape[-1] - w.shape[0]), (0, 0)))
    else:
        qw, sw = bfp.bfp_quantize_contract(w, policy.b_m, policy.g,
                                           policy.rounding)
        wq = (qw * sw).reshape(-1, w.shape[-1])  # (Kpad, N)
        if wq.shape[0] != xq.shape[-1]:
            wq = wq[: xq.shape[-1]]
    return jnp.matmul(xq.astype(dt), wq.astype(dt),
                      preferred_element_type=jnp.float32)
