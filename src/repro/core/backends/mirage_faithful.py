"""mirage_faithful: per-group integer dots + FP32 scale-accumulate,
executed as group-batched dots instead of the seed's sequential fori_loop.

Paper dataflow steps 2-9 with the RNS conversions elided exactly as the
paper's own accuracy model does (Section IV-A). The group axis is the batch
axis of the dot — the photonic core runs the groups in parallel across
MMVMU rows, so this IS the hardware execution model, and it is what lets
XLA emit one (or a few block-batched) large contractions instead of G tiny
ones. Bit-identical to the seed fori_loop backend (see
``backends.grouped`` for the exactness argument; parity-tested).
"""

from __future__ import annotations

from repro.core.backends import grouped
from repro.core.backends.base import register_fn


@register_fn("mirage_faithful",
             description="group-batched integer dots + FP32 scale-accumulate",
             supports_weight_stationary=True,
             weight_stationary_aligned_only=True)
def _matmul_mirage_faithful(x, w, policy, *, key=None):
    qx, sx, qw, sw, batch = grouped.prepare_operands(x, w, policy)
    # Scales are powers of two and constant per group: folding them into the
    # mantissas BEFORE the dot keeps every group dot exact (== integer dot
    # then scale, bitwise) and turns the reduction into a plain stacked sum.
    xv = qx * sx
    wv = qw * sw
    out = grouped.grouped_dot(xv, wv, policy.group_block)
    return out.reshape(batch + (out.shape[-1],))
