"""Baseline GEMM backends the paper compares against (Table III)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.backends.base import register_fn


@register_fn("fp32", description="plain f32 matmul (paper FP32 baseline)",
             quantized=False)
def _matmul_fp32(x, w, policy, *, key=None):
    return jnp.matmul(x.astype(jnp.float32), w.astype(jnp.float32),
                      preferred_element_type=jnp.float32)


@register_fn("bf16", description="bfloat16 matmul, f32 accumulation",
             quantized=False)
def _matmul_bf16(x, w, policy, *, key=None):
    return jnp.matmul(x.astype(jnp.bfloat16), w.astype(jnp.bfloat16),
                      preferred_element_type=jnp.float32)


@register_fn("int8", description="per-tensor symmetric int8 systolic baseline")
def _matmul_int8(x, w, policy, *, key=None):
    sx = jnp.maximum(jnp.max(jnp.abs(x)), 1e-30) / 127.0
    sw = jnp.maximum(jnp.max(jnp.abs(w)), 1e-30) / 127.0
    qx = jnp.clip(jnp.round(x / sx), -127, 127)
    qw = jnp.clip(jnp.round(w / sw), -127, 127)
    acc = jnp.matmul(qx, qw, preferred_element_type=jnp.float32)
    return acc * (sx * sw)
