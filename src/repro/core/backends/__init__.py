"""Pluggable GEMM backend registry.

Importing this package registers every built-in backend; external code adds
new modes with :func:`register` / :func:`register_fn` and they become
reachable from ``MiragePolicy(mode=...)`` everywhere (models, trainer,
launcher, benchmarks) without touching dispatch.

    from repro.core import backends

    @backends.register_fn("mirage_rns_noisy_rrns", supports_noise=True)
    def _my_backend(x, w, policy, *, key=None):
        ...
"""

from repro.core.backends.base import (
    GemmBackend,
    available_backends,
    get_backend,
    is_registered,
    register,
    register_fn,
    resolve,
)

# Importing the implementation modules registers the built-in backends.
from repro.core.backends import baselines   # noqa: F401  (fp32 / bf16 / int8)
from repro.core.backends import mirage_fast      # noqa: F401
from repro.core.backends import mirage_faithful  # noqa: F401
from repro.core.backends import mirage_rns       # noqa: F401
from repro.core.backends import mirage_rrns      # noqa: F401  (analog channel)
from repro.core.backends import reference        # noqa: F401

__all__ = [
    "GemmBackend",
    "available_backends",
    "get_backend",
    "is_registered",
    "register",
    "register_fn",
    "resolve",
]
