"""mirage_rns: the full hardware path, group-batched.

Forward conversion to the special moduli set -> per-modulus modular GEMM
over all groups at once -> (optional) analog phase noise on the residue
readout -> CRT reverse conversion -> FP32 scale-accumulate.

The seed looped groups sequentially, converting and CRT-reconstructing
(M, N) tiles G times; here conversion, the three residue contractions, and
the CRT each run ONCE over group-major tensors, and the modular reductions
use :func:`grouped.exact_mod` (mul/floor/select) instead of per-element
fmod — bit-identical integers, far fewer libm calls.

``policy.use_pallas`` routes the residue contraction through the
``rns_matmul_pallas`` kernel by flattening the (modulus, group) axes into
the kernel's modulus-major grid; residues are integers either way, so the
kernel path matches the pure-jnp path exactly.

``policy.noise_sigma > 0`` injects Gaussian phase noise on the residue
outputs (paper Section VII) and requires an explicit PRNG ``key``; at
sigma == 0 the path is a no-op (zero-noise fast path).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import noise, rns, stationary
from repro.core.backends import grouped
from repro.core.backends.base import register_fn


def _rns_blocked(xr, wr, sx, sw, policy, gb):
    """Scan over gb-group blocks, running the FULL per-block pipeline
    (residue dots -> CRT -> scale-accumulate) inside the scan body so the
    per-modulus intermediate is bounded at (gb, M, N) — this is what makes
    ``policy.group_block`` / the vectorize budget actually cap memory."""
    nm, G, M, g = xr.shape
    N = wr.shape[-1]
    k = policy.k
    moduli = policy.moduli
    pad = (-G) % gb
    if pad:
        # zero groups: zero residues -> zero CRT value -> zero contribution
        xr = jnp.pad(xr, ((0, 0), (0, pad), (0, 0), (0, 0)))
        wr = jnp.pad(wr, ((0, 0), (0, pad), (0, 0), (0, 0)))
        sx = jnp.pad(sx, ((0, pad), (0, 0), (0, 0)))
        sw = jnp.pad(sw, ((0, pad), (0, 0), (0, 0)))
    nb = (G + pad) // gb
    xs = (jnp.moveaxis(xr, 0, 1).reshape(nb, gb, nm, M, g),
          jnp.moveaxis(wr, 0, 1).reshape(nb, gb, nm, g, N),
          sx.reshape(nb, gb, M, 1), sw.reshape(nb, gb, 1, N))

    def body(acc, blk):
        xrb, wrb, sxb, swb = blk                   # group-blocked slices
        res = jnp.stack(
            [grouped.grouped_residue_dot(
                xrb[:, i].astype(jnp.float32), wrb[:, i].astype(jnp.float32), m)
             for i, m in enumerate(moduli)],
            axis=0,
        ).astype(jnp.int32)                        # (nm, gb, M, N)
        p = rns.from_rns_special(res, k, signed=True).astype(jnp.float32)
        return acc + jnp.sum(p * sxb * swb, axis=0), None

    acc, _ = jax.lax.scan(body, jnp.zeros((M, N), jnp.float32), xs)
    return acc


def _rns_forward(x, w, policy, key):
    k = policy.k
    moduli = policy.moduli
    if isinstance(w, stationary.StationaryResidues):
        # program-once dataflow: the weight side was quantized, converted
        # and programmed at admission — only the streamed operand converts
        w.check_matches(policy, moduli, x.shape[-1])
        qx, sx, batch = grouped.prepare_activations(x, policy)
        wr, sw = w.residues, w.scale
    else:
        qx, sx, qw, sw, batch = grouped.prepare_operands(x, w, policy)
        wr = rns.to_rns_special(qw, k)             # (n_mod, G, g, N) int32
    G, M, _ = qx.shape
    N = wr.shape[-1]
    xr = rns.to_rns_special(qx, k)                 # (n_mod, G, M, g) int32
    noisy = policy.noise_sigma > 0
    if noisy and key is None:
        raise ValueError(
            "policy.noise_sigma > 0 requires an explicit PRNG key: "
            "call mirage_matmul_nograd(x, w, policy, key=key) — the "
            "differentiable mirage_matmul path is deterministic only")
    gb = policy.group_block
    if gb == 0:
        # the vectorized path materializes the residue stack for EVERY
        # modulus, so the budgeted intermediate is n_mod * (G, M, N)
        single = (len(moduli) * G * M * N * 4
                  <= grouped.VECTORIZE_BUDGET_BYTES)
        gb = -1 if single else grouped.DEFAULT_GROUP_BLOCK
    # Pallas and noise injection operate on the full residue tensor; the
    # memory-bounded scan regime applies to the plain jnp path only.
    if 0 < gb < G and not policy.use_pallas and not noisy:
        out = _rns_blocked(xr, wr, sx, sw, policy, gb)
        return out.reshape(batch + (N,))
    if policy.use_pallas:
        from repro.kernels import ops as kops
        res = kops.rns_group_matmul(xr, wr, moduli,
                                    interpret=policy.interpret)
    else:
        res = jnp.stack(
            [grouped.grouped_residue_dot(
                xr[i].astype(jnp.float32), wr[i].astype(jnp.float32), m)
             for i, m in enumerate(moduli)],
            axis=0,
        ).astype(jnp.int32)                        # (n_mod, G, M, N)
    if noisy:
        res = noise.inject_phase_noise(res, moduli, policy.noise_sigma, key)
    p = rns.from_rns_special(res, k, signed=True).astype(jnp.float32)
    return grouped.scale_accumulate(p, sx, sw, batch)


@register_fn("mirage_rns",
             description="group-batched RNS path: residue GEMMs + CRT",
             supports_noise=True,
             supports_stationary_residues=True,
             supports_weight_stationary=True,
             weight_stationary_aligned_only=True)
def _matmul_mirage_rns(x, w, policy, *, key=None):
    return _rns_forward(x, w, policy, key)


@register_fn("mirage_rns_pallas",
             description="mirage_rns forced through the Pallas residue kernel",
             supports_noise=True,
             supports_stationary_residues=True,
             supports_weight_stationary=True,
             weight_stationary_aligned_only=True)
def _matmul_mirage_rns_pallas(x, w, policy, *, key=None):
    return _rns_forward(x, w, policy.replace(use_pallas=True), key)
