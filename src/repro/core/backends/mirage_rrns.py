"""mirage_rns_noisy / mirage_rrns: the RNS path through the analog channel.

Both backends run the group-batched residue pipeline of ``mirage_rns`` but
route every operand and readout through the composable analog channel model
(``repro.analog.channel``): DAC quantization and phase-shifter programming
drift on the stationary operand, DAC quantization on the streamed operand,
then inter-MMU crosstalk, SNR-parameterized shot/thermal detector noise and
ADC re-quantization on the residue readout.

  mirage_rns_noisy  base moduli only; corrupted residues go straight into
                    CRT, so single phase-level errors explode (§VII) — the
                    uncorrected baseline of the noise story.
  mirage_rrns       residues carried over base + redundant moduli; the
                    readout is majority-decoded with the jittable RRNS
                    tables (``repro.analog.rrns``), correcting any single
                    residue error with the default 2 redundant moduli.

Everything is pure jnp — no host callbacks — so both modes run fully
jitted from the trainer, the serve launcher, and the benchmarks via
``policy.mode`` alone. Stochastic stages need randomness: pass an explicit
``key`` (``mirage_matmul_nograd``), or set ``policy.noise_seed`` for keyless
call sites (jitted training) — the per-GEMM key is then the seed folded
with the operand shapes, i.e. a static error pattern per GEMM site.

Redundant residue contractions use the same ``grouped_residue_dot`` as the
base moduli (any modulus within the f32-exact window works), so the r extra
moduli cost exactly r more group-batched contractions — mirroring the r
extra modular MMVMU columns the hardware would add.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.analog import channel, rrns
from repro.core import rns
from repro.core.backends import grouped
from repro.core.backends.base import register_fn


def _effective_rrns_moduli(policy) -> Tuple[int, ...]:
    extra = tuple(policy.redundant_moduli)
    if not extra:
        extra = rrns.default_redundant_moduli(policy.k)
    return tuple(policy.moduli) + extra


def _channel_key(policy, key: Optional[jax.Array],
                 shapes) -> jax.Array:
    if key is not None:
        return key
    if policy.noise_seed is not None:
        base = jax.random.PRNGKey(policy.noise_seed)
        # fold in the operand shapes so forward / dX / dW GEMMs of one layer
        # draw distinct (but step-static) error patterns
        tag = hash(tuple(shapes)) & 0x7FFFFFFF
        return jax.random.fold_in(base, tag)
    raise ValueError(
        "the analog channel has stochastic stages (snr_db / noise_sigma / "
        "phase_drift_sigma) but no randomness source: pass an explicit PRNG "
        "key via mirage_matmul_nograd(x, w, policy, key=key), or set "
        "policy.noise_seed for keyless jitted call sites (trainer/serving)")


def _analog_forward(x, w, policy, key, correct: bool):
    if policy.use_pallas:
        raise NotImplementedError(
            "the analog-channel backends (mirage_rns_noisy / mirage_rrns) "
            "run pure jnp; use_pallas does not compose with channel stages "
            "yet (ROADMAP follow-up) — unset it rather than silently "
            "benchmarking the same path twice")
    qx, sx, qw, sw, batch = grouped.prepare_operands(x, w, policy)
    cfg = channel.AnalogChannelConfig.from_policy(policy)
    moduli = (_effective_rrns_moduli(policy) if correct
              else tuple(policy.moduli))
    if cfg.stochastic:
        k_prog, k_det = jax.random.split(
            _channel_key(policy, key, (x.shape, w.shape)))
    else:
        k_prog = k_det = None
    xr = rns.to_rns(qx, moduli)                    # (n_mod, G, M, g) int32
    wr = rns.to_rns(qw, moduli)                    # (n_mod, G, g, N) int32
    xr = channel.converter_quantize(xr, moduli, cfg.dac_bits)
    wr = channel.apply_program_channel(wr, moduli, cfg, k_prog)
    res = jnp.stack(
        [grouped.grouped_residue_dot(
            xr[i].astype(jnp.float32), wr[i].astype(jnp.float32), m)
         for i, m in enumerate(moduli)],
        axis=0,
    ).astype(jnp.int32)                            # (n_mod, G, M, N)
    res = channel.apply_readout_channel(res, moduli, cfg, k_det)
    if correct:
        tables = rrns.get_tables(moduli, n_required=len(policy.moduli),
                                 psi=policy.psi)
        decoded, _ = rrns.rrns_decode(res, tables)
        p = decoded.astype(jnp.float32)
    else:
        p = rns.from_rns_special(res, policy.k, signed=True).astype(jnp.float32)
    return grouped.scale_accumulate(p, sx, sw, batch)


@register_fn("mirage_rns_noisy",
             description="RNS path through the full analog channel model "
                         "(DAC/drift/crosstalk/detector-SNR/ADC), uncorrected",
             supports_noise=True)
def _matmul_mirage_rns_noisy(x, w, policy, *, key=None):
    return _analog_forward(x, w, policy, key, correct=False)


@register_fn("mirage_rrns",
             description="redundant-RNS path: analog channel + jittable "
                         "majority decode over CRT subset tables",
             supports_noise=True)
def _matmul_mirage_rrns(x, w, policy, *, key=None):
    return _analog_forward(x, w, policy, key, correct=True)
