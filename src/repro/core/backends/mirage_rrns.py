"""mirage_rns_noisy / mirage_rrns: the RNS path through the analog channel.

Both backends run the group-batched residue pipeline of ``mirage_rns`` but
route every operand and readout through the composable analog channel model
(``repro.analog.channel``): DAC quantization and phase-shifter programming
drift on the stationary operand, DAC quantization on the streamed operand,
then inter-MMU crosstalk, SNR-parameterized shot/thermal detector noise,
ADC re-quantization and (optionally) correlated burst errors on the
residue readout.

  mirage_rns_noisy  base moduli only; corrupted residues go straight into
                    CRT, so single phase-level errors explode (§VII) — the
                    uncorrected baseline of the noise story.
  mirage_rrns       residues carried over base + redundant moduli; the
                    readout is majority-decoded with the fused single-pass
                    RRNS decode (``repro.analog.rrns``), correcting any
                    single residue error with the default 2 redundant
                    moduli.
  mirage_rrns_ref   the pre-fusion pipeline (per-call weight encode +
                    subset-loop ``rrns_decode_reference``), frozen as the
                    walltime baseline and a parity oracle.

Fast-path machinery (this PR's tentpole):

* **Stationary residues** — when the ``w`` slot carries a
  :class:`repro.core.stationary.StationaryResidues` container (the serving
  engine programs one per GEMM weight at admission), the whole weight-side
  BFP-quantize → residue-encode → DAC/drift-program pipeline is skipped;
  only the streamed activations are converted per call, mirroring the
  paper's program-once MMVMU dataflow. Clean-channel outputs are
  bit-identical to the per-call path.
* **Pallas composition** — ``policy.use_pallas`` routes the residue
  contraction through the ``rns_matmul`` Pallas kernel WITH the readout
  channel fused into its epilogue at residue granularity (detector noise +
  ADC on the VMEM-resident block; noise pre-sampled outside from the same
  key the jnp path uses, so both paths are bit-identical at crosstalk=0).
  Nonzero crosstalk needs neighbor-group outputs, so that config runs the
  kernel clean and the readout chain in jnp — the channel always composes.
* **Fused decode** — the RRNS majority vote runs as the single-pass
  consistency-count decode (``rrns.rrns_decode``), or its subset-major
  Pallas kernel (``kernels.rrns_decode``) under ``use_pallas``.

Everything is pure jnp — no host callbacks — so both modes run fully
jitted from the trainer, the serve launcher, and the benchmarks via
``policy.mode`` alone. Stochastic stages need randomness: pass an explicit
``key`` (``mirage_matmul_nograd``), or set ``policy.noise_seed`` for keyless
call sites (jitted training) — the per-GEMM key is then the seed folded
with a deterministic mix of the operand dims (no CPython ``hash``), i.e. a
static, reproducible-everywhere error pattern per GEMM site.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.analog import channel, rrns
from repro.core import rns, stationary
from repro.core.backends import grouped
from repro.core.backends.base import register_fn
from repro.obs import health as obs_health


def _dims_tag(shapes) -> int:
    """Deterministic fold of operand dims into a 31-bit tag. Unlike
    ``hash(tuple(shapes))`` this is implementation-independent, so a given
    ``noise_seed`` reproduces the same static error pattern on every
    CPython/platform."""
    t = 0
    for shape in shapes:
        for d in shape:
            t = (t * 1000003 + int(d) + 0x9E3779B1) % 0x7FFFFFFF
    return t


def _channel_key(policy, key: Optional[jax.Array],
                 shapes) -> jax.Array:
    if key is not None:
        return key
    if policy.noise_seed is not None:
        base = jax.random.PRNGKey(policy.noise_seed)
        # fold in the operand dims so forward / dX / dW GEMMs of one layer
        # draw distinct (but step-static) error patterns
        return jax.random.fold_in(base, _dims_tag(shapes))
    raise ValueError(
        "the analog channel has stochastic stages (snr_db / noise_sigma / "
        "phase_drift_sigma / burst_rate) but no randomness source: pass an "
        "explicit PRNG key via mirage_matmul_nograd(x, w, policy, key=key), "
        "or set policy.noise_seed for keyless jitted call sites "
        "(trainer/serving)")


def _prepare(x, w, policy, moduli, cfg, k_prog, allow_stationary):
    """Residue-encode both operands; the stationary container skips the
    whole weight-side pipeline (already programmed at admission)."""
    if isinstance(w, stationary.StationaryResidues):
        if not allow_stationary:
            raise ValueError(
                "the reference backend freezes the pre-fusion per-call "
                "pipeline and does not accept stationary residues")
        w.check_matches(policy, moduli, x.shape[-1])
        qx, sx, batch = grouped.prepare_activations(x, policy)
        wr, sw = w.residues, w.scale
    else:
        qx, sx, qw, sw, batch = grouped.prepare_operands(x, w, policy)
        wr = rns.to_rns(qw, moduli)                # (n_mod, G, g, N) int32
        wr = channel.apply_program_channel(wr, moduli, cfg, k_prog)
    xr = rns.to_rns(qx, moduli)                    # (n_mod, G, M, g) int32
    xr = channel.converter_quantize(xr, moduli, cfg.dac_bits)
    return xr, wr, sx, sw, batch


def _residue_dots_jnp(xr, wr, moduli):
    return jnp.stack(
        [grouped.grouped_residue_dot(
            xr[i].astype(jnp.float32), wr[i].astype(jnp.float32), m)
         for i, m in enumerate(moduli)],
        axis=0,
    ).astype(jnp.int32)                            # (n_mod, G, M, N)


def _analog_forward(x, w, policy, key, correct: bool, reference: bool = False):
    cfg = channel.AnalogChannelConfig.from_policy(policy)
    moduli = (rrns.rrns_moduli(policy) if correct
              else tuple(policy.moduli))
    # runtime fault controls (chaos injection) make otherwise-static
    # stages data-dependent: the noise/burst paths must trace even when
    # the config alone would skip them, and they need key material
    ctl = channel.fault_controls()
    if cfg.stochastic or ctl is not None:
        k_shape = (w.orig_k, w.residues.shape[-1]) \
            if isinstance(w, stationary.StationaryResidues) else w.shape
        k_prog, k_det, k_burst = jax.random.split(
            _channel_key(policy, key, (x.shape, k_shape)), 3)
    else:
        k_prog = k_det = k_burst = None
    xr, wr, sx, sw, batch = _prepare(x, w, policy, moduli, cfg, k_prog,
                                     allow_stationary=not reference)
    use_pallas = policy.use_pallas and not reference
    if use_pallas:
        from repro.kernels import ops as kops
        sig = cfg.detector_sigmas(moduli)
        if ctl is not None or cfg.crosstalk or not any(s > 0 for s in sig):
            # crosstalk mixes NEIGHBOR group outputs — outside one kernel
            # block's reach — and a noiseless readout has nothing to fuse:
            # both run the plain kernel + the (cheap) jnp readout chain
            res = kops.rns_group_matmul(xr, wr, moduli,
                                        interpret=policy.interpret)
            res = channel.apply_readout_channel(res, moduli, cfg, k_det)
        else:
            G, M = xr.shape[1], xr.shape[2]
            N = wr.shape[-1]
            sig_col = jnp.asarray(sig, jnp.float32).reshape(-1, 1, 1, 1)
            noise = jax.random.normal(
                k_det, (len(moduli), G, M, N)) * sig_col
            if obs_health.active():
                # the detector noise is applied INSIDE the kernel epilogue,
                # so count flips from the pre-sampled draw: residues are
                # integers, hence round(res + n) != res (mod m) exactly
                # when round(n) % m != 0 — identical to the jnp path's
                # after-vs-before count
                mods = jnp.asarray(moduli, jnp.float32).reshape(-1, 1, 1, 1)
                obs_health.record("detector_flips", jnp.sum(
                    (jnp.mod(jnp.round(noise), mods) != 0).astype(jnp.int32),
                    axis=(1, 2, 3)))
            res = kops.rns_group_matmul_channel(
                xr, wr, moduli, noise, adc_bits=cfg.adc_bits,
                interpret=policy.interpret)
    else:
        res = _residue_dots_jnp(xr, wr, moduli)
        res = channel.apply_readout_channel(res, moduli, cfg, k_det)
    if ctl is not None:
        # traced burst controls: the schedule's storm adds onto any static
        # config rate; width takes the wider of the two
        res = channel.burst_errors(
            res, moduli, cfg.burst_rate + ctl["burst_rate"],
            jnp.maximum(jnp.int32(cfg.burst_width), ctl["burst_width"]),
            k_burst)
    elif cfg.burst_rate > 0:
        res = channel.burst_errors(res, moduli, cfg.burst_rate,
                                   cfg.burst_width, k_burst)
    if correct:
        tables = rrns.get_tables(moduli, n_required=len(policy.moduli),
                                 psi=policy.psi)
        if reference:
            decoded, _ = rrns.rrns_decode_reference(res, tables)
        elif use_pallas:
            from repro.kernels.rrns_decode import rrns_decode_pallas
            decoded, _ = rrns_decode_pallas(res, tables,
                                            interpret=policy.interpret)
        else:
            decoded, _ = rrns.rrns_decode(res, tables)
        p = decoded.astype(jnp.float32)
    else:
        p = rns.from_rns_special(res, policy.k, signed=True).astype(jnp.float32)
    return grouped.scale_accumulate(p, sx, sw, batch)


@register_fn("mirage_rns_noisy",
             description="RNS path through the full analog channel model "
                         "(DAC/drift/crosstalk/detector-SNR/ADC/burst), "
                         "uncorrected",
             supports_noise=True,
             supports_stationary_residues=True,
             supports_weight_stationary=True,
             weight_stationary_aligned_only=True)
def _matmul_mirage_rns_noisy(x, w, policy, *, key=None):
    return _analog_forward(x, w, policy, key, correct=False)


@register_fn("mirage_rrns",
             description="redundant-RNS path: analog channel + fused "
                         "single-pass majority decode over CRT subset tables",
             supports_noise=True,
             supports_stationary_residues=True,
             supports_weight_stationary=True,
             weight_stationary_aligned_only=True)
def _matmul_mirage_rrns(x, w, policy, *, key=None):
    return _analog_forward(x, w, policy, key, correct=True)


@register_fn("mirage_rrns_ref",
             description="pre-fusion RRNS pipeline (per-call weight encode, "
                         "subset-loop decode) — walltime baseline / oracle",
             supports_noise=True,
             reference=True)
def _matmul_mirage_rrns_ref(x, w, policy, *, key=None):
    return _analog_forward(x, w, policy, key, correct=True, reference=True)
