"""Seed fori_loop GEMM implementations, frozen as reference backends.

These are the pre-registry implementations of the faithful and RNS paths,
kept verbatim (sequential ``jax.lax.fori_loop`` over groups, transposed
weight quantization, fmod-based modular reduction) as bit-exactness oracles
for the vectorized backends and as the "seed" side of the
``benchmarks/bench_gemm.py`` before/after comparison. Not deployment paths.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import bfp, rns
from repro.core.backends.base import register_fn


def _per_group_operands(x, w, policy):
    """Seed operand prep: (qx (..., G, g), sx (..., G, 1), qw (G, g, N),
    sw (G, 1, N)) via quantizing w.T and transposing back."""
    qxt = bfp.bfp_quantize(x, policy.b_m, policy.g, policy.rounding)
    qwt = bfp.bfp_quantize(w.T, policy.b_m, policy.g, policy.rounding)
    qw = qwt.mantissa.transpose(1, 2, 0)  # (N, G, g) -> (G, g, N)
    sw = qwt.scale.transpose(1, 2, 0)     # (N, G, 1) -> (G, 1, N)
    return qxt.mantissa, qxt.scale, qw, sw


@register_fn("mirage_faithful_ref",
             description="seed fori_loop faithful path (parity oracle)",
             reference=True)
def _matmul_mirage_faithful_ref(x, w, policy, *, key=None):
    """Seed dataflow: sequential per-group integer dot + FP32 accumulation."""
    qx, sx, qw, sw = _per_group_operands(x, w, policy)
    G = qx.shape[-2]
    N = qw.shape[-1]
    out_shape = x.shape[:-1] + (N,)

    def body(j, acc):
        qxj = jax.lax.dynamic_index_in_dim(qx, j, axis=qx.ndim - 2, keepdims=False)
        sxj = jax.lax.dynamic_index_in_dim(sx, j, axis=sx.ndim - 2, keepdims=False)
        qwj = jax.lax.dynamic_index_in_dim(qw, j, axis=0, keepdims=False)
        swj = jax.lax.dynamic_index_in_dim(sw, j, axis=0, keepdims=False)
        # Exact integer dot product of one g-group (|.| <= g * qmax^2 <= psi).
        p = jnp.matmul(qxj, qwj, preferred_element_type=jnp.float32)
        return acc + p * sxj * swj[0]

    acc0 = jnp.zeros(out_shape, jnp.float32)
    return jax.lax.fori_loop(0, G, body, acc0)


@register_fn("mirage_rns_ref",
             description="seed fori_loop RNS path (parity oracle)",
             reference=True)
def _matmul_mirage_rns_ref(x, w, policy, *, key=None):
    """Seed RNS path: per-group forward conversion -> per-modulus modular
    GEMM -> CRT reverse conversion -> FP32 scale-accumulate."""
    qx, sx, qw, sw = _per_group_operands(x, w, policy)
    G = qx.shape[-2]
    N = qw.shape[-1]
    k = policy.k
    moduli = policy.moduli
    out_shape = x.shape[:-1] + (N,)

    def body(j, acc):
        qxj = jax.lax.dynamic_index_in_dim(qx, j, axis=qx.ndim - 2, keepdims=False)
        sxj = jax.lax.dynamic_index_in_dim(sx, j, axis=sx.ndim - 2, keepdims=False)
        qwj = jax.lax.dynamic_index_in_dim(qw, j, axis=0, keepdims=False)
        swj = jax.lax.dynamic_index_in_dim(sw, j, axis=0, keepdims=False)
        xr = rns.to_rns_special(qxj, k)            # (3, ..., g)
        wr = rns.to_rns_special(qwj, k)            # (3, g, N)
        res = jnp.stack(
            [rns.mod_matmul(xr[i], wr[i], m) for i, m in enumerate(moduli)],
            axis=0,
        ).astype(jnp.int32)
        p = rns.from_rns_special(res, k, signed=True).astype(jnp.float32)
        return acc + p * sxj * swj[0]

    acc0 = jnp.zeros(out_shape, jnp.float32)
    return jax.lax.fori_loop(0, G, body, acc0)
