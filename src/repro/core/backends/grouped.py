"""Shared machinery for group-batched GEMM backends (faithful + RNS).

The paper's dataflow decomposes a K-contraction into ``G = K/g`` independent
g-wide integer group-dots followed by an FP32 scale-accumulate (Section
III-A steps 2-9). The seed implementation executed the groups with a
sequential ``jax.lax.fori_loop``; here the group axis is the *batch* axis of
a single ``dot_general`` (photonic hardware runs the groups in parallel
across MMVMU rows — the batched dot is the faithful execution model).

Layouts (group-major, so the group axis is leading everywhere):

  xv / qx : (G, M, g)   activations, M = prod(batch dims)
  wv / qw : (G, g, N)   weights
  sx      : (G, M, 1)   activation group scales (powers of two)
  sw      : (G, 1, N)   weight group scales (powers of two)

Exactness notes (load-bearing — the parity tests assert bit-identity with
the seed fori_loop backends):

* Folding the power-of-two group scales into the mantissas BEFORE the group
  dot is exact: every product and every within-group partial sum is an
  integer bounded by ``g * qmax^2 <= 2^14`` times a common power of two,
  hence exactly representable in f32. The scaled group dot therefore equals
  ``(p_int * sx) * sw`` bitwise.
* The cross-group accumulation is the only place f32 rounding happens. The
  seed folds groups left-to-right; a stacked-axis reduction matches that
  bitwise whenever partial sums stay inside the f32 exact window (always
  true at the paper operating point for activation-scale data; a documented
  property test covers the adversarial dynamic-range corner with allclose).

On CPU, XLA lowers *batched* dot_general to a slow non-Eigen path, so a
single huge (G, M, N) intermediate loses to streaming once it falls out of
cache. :func:`grouped_dot` is therefore adaptive: one batched dot while the
intermediate fits :data:`VECTORIZE_BUDGET_BYTES`, otherwise a ``lax.scan``
over group *blocks* (bounded memory, still block-batched inside). On TPU
the single-dot regime is always preferable (MXU batches natively); the
budget only matters for the CPU container.
"""

from __future__ import annotations

import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import bfp


def _env_int(name: str, default: int) -> int:
    """Integer env override; malformed values fall back to the default."""
    raw = os.environ.get(name)
    if raw is None:
        return default
    try:
        return int(raw)
    except ValueError:
        return default


# (G, M, N) f32 intermediates up to this size run as ONE group-batched dot;
# beyond it the scan-over-blocks regime keeps the working set bounded. The
# defaults are tuned for the 2-core CPU container; on TPU (where the MXU
# batches natively and the single-dot regime should always win) raise the
# budget via MIRAGE_VECTORIZE_BUDGET_BYTES without touching code.
VECTORIZE_BUDGET_BYTES = _env_int("MIRAGE_VECTORIZE_BUDGET_BYTES",
                                  32 * 1024 * 1024)

# Group-block size for the scan regime (MIRAGE_SCAN_BLOCK overrides).
DEFAULT_GROUP_BLOCK = _env_int("MIRAGE_SCAN_BLOCK", 8)

# f32 holds integers exactly up to 2^24: cap on any integer partial dot.
F32_EXACT_WINDOW = 1 << 24


def prepare_activations(
    x: jax.Array, policy,
) -> Tuple[jax.Array, jax.Array, Tuple[int, ...]]:
    """BFP-quantize the activation operand into group-major layout.

    Returns ``(qx, sx, batch)``; the weight-side counterpart lives in
    :func:`prepare_operands`. Split out so backends running against a
    pre-encoded stationary weight (``repro.core.stationary``) can skip the
    weight side entirely.
    """
    batch = x.shape[:-1]
    t = bfp.bfp_quantize(x, policy.b_m, policy.g, policy.rounding)
    G, g = t.mantissa.shape[-2], t.mantissa.shape[-1]
    M = 1
    for d in batch:
        M *= d
    qx = jnp.moveaxis(t.mantissa.reshape((M, G, g)), 1, 0)        # (G, M, g)
    sx = jnp.moveaxis(t.scale.reshape((M, G, 1)), 1, 0)           # (G, M, 1)
    return qx, sx, batch


def prepare_operands(
    x: jax.Array, w: jax.Array, policy,
) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array, Tuple[int, ...]]:
    """BFP-quantize both operands into group-major layout.

    Returns ``(qx, sx, qw, sw, batch)`` with the layouts documented above;
    ``batch`` is the original leading shape of ``x``. Mantissas/scales are
    bit-identical to the seed's ``gemm.quantize_operands`` (property-tested),
    but the weight side is grouped in place along K — no (K, N) <-> (N, K)
    transpose round-trip.

    Under ``policy.assume_quantized_weights`` (weight-stationary contract:
    ``w`` is already on the BFP grid along this K-grouping) the weight side
    uses the round/clip-free exact decomposition — bit-identical results,
    less work per call.
    """
    qx, sx, batch = prepare_activations(x, policy)
    if policy.assume_quantized_weights:
        qw, sw = bfp.bfp_decompose_contract(w, policy.b_m, policy.g)
    else:
        qw, sw = bfp.bfp_quantize_contract(w, policy.b_m, policy.g,
                                           policy.rounding)       # (G, g, N)
    return qx, sx, qw, sw, batch


def _block_dot(xb: jax.Array, wb: jax.Array) -> jax.Array:
    """(gb, M, g) x (gb, g, N) -> (M, N): block-batched dots + stacked sum."""
    if xb.shape[0] == 1:
        return jnp.matmul(xb[0], wb[0], preferred_element_type=jnp.float32)
    t = jax.lax.dot_general(xb, wb, (((2,), (1,)), ((0,), (0,))),
                            preferred_element_type=jnp.float32)
    return jnp.sum(t, axis=0)


def grouped_dot(xv: jax.Array, wv: jax.Array,
                group_block: int = 0) -> jax.Array:
    """Scale-accumulated sum of per-group dots: (G, M, g) x (G, g, N) -> (M, N).

    group_block: 0 = adaptive (single batched dot inside the vectorize
    budget, scan over DEFAULT_GROUP_BLOCK-sized blocks beyond it); -1 =
    force the single batched dot; n > 0 = force n-group blocks.
    """
    G, M, g = xv.shape
    N = wv.shape[-1]
    if group_block == 0:
        single = G * M * N * 4 <= VECTORIZE_BUDGET_BYTES
        gb = -1 if single else DEFAULT_GROUP_BLOCK
    else:
        gb = group_block
    if gb < 0 or gb >= G:
        t = jax.lax.dot_general(xv, wv, (((2,), (1,)), ((0,), (0,))),
                                preferred_element_type=jnp.float32)
        return jnp.sum(t, axis=0)
    pad = (-G) % gb
    if pad:
        # zero groups contribute exactly 0.0 to the accumulation
        xv = jnp.pad(xv, ((0, pad), (0, 0), (0, 0)))
        wv = jnp.pad(wv, ((0, pad), (0, 0), (0, 0)))
    nb = (G + pad) // gb
    xs = xv.reshape(nb, gb, M, g)
    ws = wv.reshape(nb, gb, g, N)

    def body(acc, blk):
        xb, wb = blk
        return acc + _block_dot(xb, wb), None

    acc, _ = jax.lax.scan(body, jnp.zeros((M, N), jnp.float32), (xs, ws))
    return acc


# --------------------------------------------------------------------------
# Exact modular reduction without fmod
# --------------------------------------------------------------------------

def exact_mod(a: jax.Array, m: int) -> jax.Array:
    """``a mod m`` for integer-valued f32 ``a`` in [0, 2^24), exact.

    Computes ``a - floor(a * (1/m)) * m`` and corrects the quotient's
    possible off-by-one from the rounded reciprocal — a handful of SIMD
    mul/floor/select ops instead of a libm fmod per element (the fmod is
    what made the seed RNS path fmod-bound). Property-tested exhaustively
    against ``jnp.mod`` over the full window for the paper's moduli.
    """
    mf = float(m)
    q = jnp.floor(a * (1.0 / mf))
    r = a - q * mf
    r = jnp.where(r < 0, r + mf, r)
    r = jnp.where(r >= mf, r - mf, r)
    return r


def grouped_residue_dot(xr: jax.Array, wr: jax.Array, m: int) -> jax.Array:
    """Per-group modular dot for one modulus: (G, M, g) x (G, g, N) -> (G, M, N).

    Residues are in [0, m); the exact integer group dot is bounded by
    ``g * (m-1)^2`` which must stay inside the f32 exact window — when it
    does not, the g axis is split into sub-chunks that are mod-reduced
    before combining (the same blocking the Pallas kernel applies).
    """
    G, M, g = xr.shape
    cap = max(1, (F32_EXACT_WINDOW - 1) // max(1, (m - 1) ** 2))
    if g <= cap:
        t = jax.lax.dot_general(xr, wr, (((2,), (1,)), ((0,), (0,))),
                                preferred_element_type=jnp.float32)
        return exact_mod(t, m)
    acc = None
    for k0 in range(0, g, cap):
        t = jax.lax.dot_general(
            xr[:, :, k0:k0 + cap], wr[:, k0:k0 + cap, :],
            (((2,), (1,)), ((0,), (0,))), preferred_element_type=jnp.float32)
        part = exact_mod(t, m)
        acc = part if acc is None else acc + part
    # sum of < g/cap residues < m each stays far inside the exact window
    return exact_mod(acc, m)


def scale_accumulate(p: jax.Array, sx: jax.Array, sw: jax.Array,
                     batch: Tuple[int, ...]) -> jax.Array:
    """sum_G of p * sx * sw: (G, M, N) -> batch + (N,).

    Used by paths that materialize integer per-group results (the RNS path,
    where residues must stay unscaled through CRT). The multiplies are exact
    (power-of-two scales); only the cross-group sum rounds.
    """
    N = p.shape[-1]
    return jnp.sum(p * sx * sw, axis=0).reshape(batch + (N,))
