"""GEMM backend protocol + registry.

Every execution mode of ``mirage_matmul`` (baselines, BFP fast path, the
hardware-faithful group-dot path, the full RNS path, future noisy/RRNS or
Pallas-only variants) is a :class:`GemmBackend` registered here by name.
``core.gemm`` dispatches on ``policy.mode`` through :func:`get_backend`, so
new modes plug in by registration alone — no dispatch edits anywhere.

A backend's ``fn`` has signature ``fn(x, w, policy, *, key=None)``:

  x: (..., K) activations   w: (K, N) weights   policy: MiragePolicy
  key: optional PRNG key, required only by stochastic backends (analog
       noise injection, stochastic rounding). Deterministic backends
       ignore it.

Capability flags let consumers (trainer, launcher, benchmarks) reason
about a mode without hard-coding mode-name string comparisons.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax


@dataclasses.dataclass(frozen=True)
class GemmBackend:
    """A registered GEMM execution strategy.

    Attributes:
      name: registry key; ``MiragePolicy.mode`` strings resolve to this.
      fn: forward implementation ``(x, w, policy, *, key=None) -> (..., N)``.
      description: one-liner for ``--help`` style listings.
      quantized: operands are quantized (not an exact-f32 baseline).
      supports_weight_stationary: honours ``policy.assume_quantized_weights``
        (weight operand already on the BFP grid; skips its own W quantize).
      weight_stationary_aligned_only: the weight-stationary skip is exact
        ONLY when the operand was quantized along the SAME contraction
        grouping (true for the group-dot/RNS backends, whose mantissas must
        be integers). ``gemm._mm_bwd`` re-quantizes the transposed dX read
        for such backends instead of propagating the skip.
      supports_noise: honours ``policy.noise_sigma`` via the ``key`` argument.
      supports_stationary_residues: accepts a
        :class:`repro.core.stationary.StationaryResidues` container in the
        ``w`` slot (pre-encoded, channel-programmed residues; the
        program-once MMVMU dataflow) and skips the whole weight-side
        quantize/encode/program pipeline.
      reference: seed/oracle implementation kept for parity testing — not a
        deployment path.
    """

    name: str
    fn: Callable[..., jax.Array]
    description: str = ""
    quantized: bool = True
    supports_weight_stationary: bool = False
    weight_stationary_aligned_only: bool = False
    supports_noise: bool = False
    supports_stationary_residues: bool = False
    reference: bool = False

    def forward(self, x: jax.Array, w: jax.Array, policy,
                key: Optional[jax.Array] = None) -> jax.Array:
        return self.fn(x, w, policy, key=key)


_REGISTRY: Dict[str, GemmBackend] = {}


def register(backend: GemmBackend) -> GemmBackend:
    """Register (or replace) a backend under ``backend.name``."""
    _REGISTRY[backend.name] = backend
    return backend


def register_fn(name: str, **flags):
    """Decorator: register a plain forward function as a backend.

    >>> @register_fn("my_mode", description="...")
    ... def _my_mode(x, w, policy, *, key=None): ...
    """

    def deco(fn):
        register(GemmBackend(name=name, fn=fn, **flags))
        return fn

    return deco


def get_backend(name: str) -> GemmBackend:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"no GEMM backend registered under {name!r}; "
            f"available: {sorted(_REGISTRY)}"
        ) from None


def resolve(policy) -> GemmBackend:
    """Backend for a policy's mode string."""
    return get_backend(policy.mode)


def available_backends() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def is_registered(name: str) -> bool:
    return name in _REGISTRY
