"""Precision policies for Mirage numerics.

The paper's operating point is ``b_m = 4, g = 16`` with the special moduli set
``{2^k - 1, 2^k, 2^k + 1}`` for ``k = 5`` -> ``{31, 32, 33}`` (Section V-A).
A :class:`MiragePolicy` bundles everything a GEMM needs to know about the
numerics: mode, BFP parameters, moduli, rounding, and which execution path
(pure-jnp fast / pure-jnp faithful / RNS / Pallas kernel) to take.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

GEMM_MODES = (
    "fp32",            # plain f32 matmul (paper's FP32 baseline)
    "bf16",            # bfloat16 matmul, f32 accumulation (bfloat16 baseline)
    "int8",            # per-tensor symmetric int8 (paper's INT8 baseline)
    "mirage_fast",     # BFP quantize -> fold scales -> one MXU matmul
    "mirage_faithful", # BFP quantize -> group-batched integer dots + FP32 acc
    "mirage_rns",      # full RNS path: residue GEMMs per modulus + CRT
    "mirage_rns_pallas",   # mirage_rns forced through the Pallas residue kernel
    "mirage_rns_noisy",    # RNS path through the full analog channel model
    "mirage_rrns",         # redundant-RNS path: analog channel + majority decode
    "mirage_faithful_ref", # seed fori_loop faithful path (parity oracle)
    "mirage_rns_ref",      # seed fori_loop RNS path (parity oracle)
    "mirage_rrns_ref",     # pre-fusion RRNS path (per-call weight encode +
                           # subset-loop decode; walltime baseline + oracle)
)

ROUNDING_MODES = ("nearest", "truncate", "stochastic")


def special_moduli(k: int) -> Tuple[int, int, int]:
    """The paper's conversion-friendly three-moduli set {2^k-1, 2^k, 2^k+1}."""
    if k < 2:
        raise ValueError(f"k must be >= 2, got {k}")
    return (2**k - 1, 2**k, 2**k + 1)


def rns_range(moduli: Tuple[int, ...]) -> int:
    """Dynamic range M = prod(m_i). Values live in [-(M-1)//2, (M-1)//2]."""
    return math.prod(moduli)


def required_output_bits(b_m: int, g: int) -> int:
    """Eq. (10): b_out = 2*(b_m + 1) + log2(g) - 1."""
    return 2 * (b_m + 1) + int(math.ceil(math.log2(max(g, 1)))) - 1


def check_overflow_bound(b_m: int, g: int, moduli: Tuple[int, ...]) -> None:
    """Assert Eq. (10): log2(M) >= b_out so group dot products never overflow."""
    M = rns_range(moduli)
    b_out = required_output_bits(b_m, g)
    if math.log2(M) < b_out:
        raise ValueError(
            f"RNS range M={M} (log2={math.log2(M):.2f} bits) cannot hold "
            f"b_out={b_out} bits for b_m={b_m}, g={g} (Eq. 10). "
            f"Increase k or reduce b_m/g."
        )


@dataclasses.dataclass(frozen=True)
class MiragePolicy:
    """Numerics policy applied to every dense GEMM in the model zoo.

    Attributes:
      mode: one of GEMM_MODES.
      b_m: BFP mantissa bits (paper default 4).
      g: BFP group size along the contraction dim (paper default 16).
      k: special-moduli parameter; moduli = {2^k-1, 2^k, 2^k+1} (paper k=5).
      rounding: mantissa rounding. Paper truncates (hardware shift); we default
        to round-to-nearest which is free on TPU and slightly more accurate.
      compute_dtype: dtype of the folded-scale matmul on the fast path.
        BFP(b_m<=6) values are exactly representable in bfloat16, so "bfloat16"
        is value-identical to "float32" while halving bytes and doubling MXU
        throughput on TPU.
      use_pallas: route the fast path through the fused Pallas kernel.
      interpret: run Pallas kernels in interpret mode (CPU container).
      noise_sigma: analog phase-noise sigma (residue-level), Section VII.
        Honoured by backends with ``supports_noise``; requires an explicit
        PRNG key through ``mirage_matmul_nograd(..., key=...)`` or a
        ``noise_seed``. For the analog-channel backends this is the flat
        detector sigma added in quadrature with the SNR-derived one.
      snr_db: amplitude SNR at the detector (analog-channel backends):
        per-modulus noise sigma is ``m / 10^(snr_db/20)`` phase levels, so
        the paper's "SNR > m" requirement (§IV-B1) is ``snr_db >
        20*log10(m)``. ``None`` disables SNR-derived noise.
      phase_drift_sigma: Gaussian programming drift on the stationary
        operand's phase shifters, in phase-level units (once per GEMM).
      dac_bits / adc_bits: converter precision for the analog channel.
        ``None`` = exact ``ceil(log2 m)``-bit converters (paper design
        point); fewer bits re-grid residues onto ``2^bits`` levels.
      crosstalk: inter-MMU leakage coefficient; each group output channel
        deterministically absorbs ``crosstalk`` of each neighbor group.
      burst_rate / burst_width: correlated burst errors on the readout
        (``analog.channel.burst_errors``): with probability ``burst_rate``
        per output element, ``burst_width`` adjacent residue channels take
        simultaneous uniform errors. width=1 stays inside the RRNS
        single-error correction radius; width>=2 exceeds it.
      noise_seed: implicit PRNG seed for stochastic channel stages when no
        explicit key is passed. Keyless jitted call sites (training) fold
        the seed with the operand shapes: a STATIC error pattern per GEMM
        site, like fixed programming/fabrication error — redraws do not
        vary across steps. The serving engine instead opens a
        ``gemm.noise_key_scope`` per decode tick with the seed folded with
        the tick counter, so served noise is FRESH per step (shot/thermal
        behaviour) yet deterministic per seed.
      redundant_moduli: extra RRNS moduli for error correction (Section
        VII). ``()`` lets the ``mirage_rrns`` backend pick the default set
        (first two primes above 2^k + 1 — single-error correcting).
      group_block: group-batched execution blocking for the faithful/RNS
        backends. 0 = adaptive (one batched dot while the (G, M, N)
        intermediate fits the vectorize budget, scan over group blocks
        beyond); -1 = force the single batched dot; n > 0 = force n-group
        blocks. The RNS backend's Pallas and noise-injection paths operate
        on the full residue tensor and ignore blocking.
    """

    mode: str = "mirage_fast"
    b_m: int = 4
    g: int = 16
    k: int = 5
    rounding: str = "nearest"
    compute_dtype: str = "float32"
    use_pallas: bool = False
    interpret: bool = True
    noise_sigma: float = 0.0
    snr_db: Optional[float] = None
    phase_drift_sigma: float = 0.0
    dac_bits: Optional[int] = None
    adc_bits: Optional[int] = None
    crosstalk: float = 0.0
    burst_rate: float = 0.0
    burst_width: int = 1
    noise_seed: Optional[int] = None
    redundant_moduli: Tuple[int, ...] = ()
    group_block: int = 0
    # Weight-stationary quantization: the weight operand is ALREADY on the
    # BFP grid (quantized once per step, like the photonic core programs a
    # tile once and keeps it stationary) — the GEMM then skips its weight-
    # side quantization. See runtime/trainer.py and EXPERIMENTS.md §Perf.
    assume_quantized_weights: bool = False

    def __post_init__(self):
        if self.mode not in GEMM_MODES:
            # lazy import: custom modes registered with backends.register_fn
            # are valid too (the registry imports this module at load time)
            from repro.core import backends
            if not backends.is_registered(self.mode):
                raise ValueError(
                    f"mode {self.mode!r} not in {GEMM_MODES} and not a "
                    f"registered backend ({backends.available_backends()})")
        if self.rounding not in ROUNDING_MODES:
            raise ValueError(f"rounding {self.rounding!r} not in {ROUNDING_MODES}")
        if self.mode.startswith("mirage"):
            check_overflow_bound(self.b_m, self.g, self.moduli)

    @property
    def moduli(self) -> Tuple[int, int, int]:
        return special_moduli(self.k)

    @property
    def all_moduli(self) -> Tuple[int, ...]:
        return self.moduli + tuple(self.redundant_moduli)

    @property
    def rns_M(self) -> int:
        return rns_range(self.moduli)

    @property
    def psi(self) -> int:
        """Half-range: signed values representable in [-psi, psi]."""
        return (self.rns_M - 1) // 2

    @property
    def mantissa_max(self) -> int:
        """Symmetric (b_m+1)-bit signed mantissa magnitude bound (sign + b_m bits)."""
        return 2**self.b_m - 1

    @property
    def converter_bits(self) -> int:
        """DAC/ADC precision: ceil(log2 m) for the largest modulus (paper: 6b at k=5)."""
        return max(int(math.ceil(math.log2(m))) for m in self.all_moduli)

    def replace(self, **kw) -> "MiragePolicy":
        return dataclasses.replace(self, **kw)


# Canonical policies
PAPER_POLICY = MiragePolicy()  # b_m=4, g=16, k=5 — the paper's chosen point
FP32_POLICY = MiragePolicy(mode="fp32")
BF16_POLICY = MiragePolicy(mode="bf16")
INT8_POLICY = MiragePolicy(mode="int8")
FAITHFUL_POLICY = MiragePolicy(mode="mirage_faithful")
RNS_POLICY = MiragePolicy(mode="mirage_rns")


_POLICY_ALIASES = {"mirage": "mirage_fast"}


def get_policy(name: str, **overrides) -> MiragePolicy:
    """Policy for a mode name (any GEMM_MODES entry or registered backend)."""
    mode = _POLICY_ALIASES.get(name, name)
    base = {
        "fp32": FP32_POLICY,
        "bf16": BF16_POLICY,
        "int8": INT8_POLICY,
        "mirage_fast": PAPER_POLICY,
        "mirage_faithful": FAITHFUL_POLICY,
        "mirage_rns": RNS_POLICY,
    }.get(mode)
    if base is None:
        base = MiragePolicy(mode=mode)  # validates via GEMM_MODES / registry
    return base.replace(**overrides) if overrides else base
