"""Residue Number System arithmetic (paper Sections II-D, III-A, III-C).

Signed integers ``X`` in ``[-psi, psi]`` (``psi = (M-1)//2``, ``M = prod m_i``)
are represented by non-negative residues ``x_i = X mod m_i``. The RNS is closed
under + and *, so GEMMs run per-modulus at ``ceil(log2 m_i)`` bits.

The paper uses the conversion-friendly set ``{2^k - 1, 2^k, 2^k + 1}``
(Section III-C), for which forward conversion reduces to shifts/adds and
reverse conversion (CRT) has a well-known adder-based closed form
[Wang et al. 2002; Hiasat 2019]. Both are implemented here in int32-safe JAX
(valid for k <= 10), plus a python-int generic CRT used as a test oracle.
"""

from __future__ import annotations

import math
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# --------------------------------------------------------------------------
# Forward conversion: BNS -> RNS
# --------------------------------------------------------------------------

def to_rns(x: jax.Array, moduli: Sequence[int]) -> jax.Array:
    """Residues of (possibly negative) integers, stacked on a new leading axis.

    x: integer-valued array (int32 or exact f32). Returns int32 array of shape
    (n_moduli,) + x.shape with entries in [0, m_i).
    """
    xi = jnp.round(x).astype(jnp.int32)
    return jnp.stack([jnp.mod(xi, m) for m in moduli], axis=0)


def to_rns_special(x: jax.Array, k: int) -> jax.Array:
    """Forward conversion for {2^k-1, 2^k, 2^k+1} using shifts/adds only.

    Mirrors the paper's 'simple shift operation' hardware (Section III-A step 3):
      x mod 2^k     : low k bits
      x mod 2^k - 1 : sum of k-bit digits, folded
      x mod 2^k + 1 : alternating sum of k-bit digits, folded
    Input magnitude must satisfy |x| < M = 2^k (2^{2k} - 1).
    """
    m1, m2, m3 = 2**k - 1, 2**k, 2**k + 1
    M = m1 * m2 * m3
    xi = jnp.round(x).astype(jnp.int32)
    xi = jnp.mod(xi, M)  # lift to [0, M)
    mask = m2 - 1
    d0 = xi & mask
    d1 = (xi >> k) & mask
    d2 = (xi >> (2 * k)) & mask
    d3 = xi >> (3 * k)  # nonzero only while folding
    # mod 2^k - 1: digits sum (2^k == 1 mod m1); two folds suffice for 3 digits.
    s = d0 + d1 + d2 + d3
    s = (s & mask) + (s >> k)
    s = (s & mask) + (s >> k)
    r1 = jnp.where(s == m1, 0, s)
    # mod 2^k: low bits.
    r2 = d0
    # mod 2^k + 1: alternating digit sum (2^k == -1 mod m3).
    a = d0 - d1 + d2 - d3
    r3 = jnp.mod(a, m3)
    return jnp.stack([r1, r2, r3.astype(jnp.int32)], axis=0)


# --------------------------------------------------------------------------
# Reverse conversion: RNS -> BNS
# --------------------------------------------------------------------------

def from_rns_special(res: jax.Array, k: int, signed: bool = True) -> jax.Array:
    """Adder-based CRT for {2^k-1, 2^k, 2^k+1} (int32-safe for k <= 10).

    Derivation: write X = q * 2^k + r2. Then
      q ≡ r1 - r2 (mod 2^k - 1)   and   q ≡ r2 - r3 (mod 2^k + 1),
    and CRT over the co-prime pair (2^k-1, 2^k+1) with both inverses equal to
    2^(k-1) gives
      q = | (a (2^k+1) + b (2^k-1)) * 2^(k-1) |_{2^{2k} - 1}.
    """
    m1, m2, m3 = 2**k - 1, 2**k, 2**k + 1
    M = m1 * m2 * m3
    Mq = m1 * m3  # 2^{2k} - 1
    r1, r2, r3 = res[0], res[1], res[2]
    a = jnp.mod(r1 - r2, m1)
    b = jnp.mod(r2 - r3, m3)
    q = jnp.mod((a * m3 + b * m1) * (2 ** (k - 1)), Mq)
    X = q * m2 + r2
    if signed:
        psi = (M - 1) // 2
        X = jnp.where(X > psi, X - M, X)
    return X.astype(jnp.int32)


def crt_constants(moduli: Sequence[int]) -> Tuple[int, Tuple[int, ...]]:
    """Generic CRT constants: M and c_i = (M_i * T_i) mod M (python ints)."""
    M = math.prod(moduli)
    consts = []
    for m in moduli:
        Mi = M // m
        Ti = pow(Mi, -1, m)
        consts.append((Mi * Ti) % M)
    return M, tuple(consts)


def from_rns_generic_np(res: np.ndarray, moduli: Sequence[int], signed: bool = True) -> np.ndarray:
    """Generic CRT oracle on host with python-int precision (any moduli)."""
    M, consts = crt_constants(moduli)
    acc = np.zeros(res.shape[1:], dtype=object)
    for i, c in enumerate(consts):
        acc = (acc + res[i].astype(object) * c) % M
    if signed:
        psi = (M - 1) // 2
        acc = np.where(acc > psi, acc - M, acc)
    return acc.astype(np.int64)


# --------------------------------------------------------------------------
# Modular arithmetic primitives
# --------------------------------------------------------------------------

def mod_matmul(xr: jax.Array, wr: jax.Array, m: int) -> jax.Array:
    """(xr @ wr) mod m for non-negative residues.

    Accumulates exact integer partial dot products in f32 and reduces
    ``mod m`` per partial. This equals the per-MAC modular accumulation the
    optical phase performs (mod is a ring homomorphism). Inputs may be int32
    or exact f32, batched on leading dims.

    Exactness: a K-wide dot of residues is bounded by ``K * (m-1)^2``; f32
    holds integers exactly only below 2^24, so the contraction dim is
    chunked to keep every partial inside that window (mirroring the K-block
    accumulation the Pallas kernel performs). The seed implementation
    silently returned wrong residues once ``K * (m-1)^2 >= 2^24``.
    """
    xf = xr.astype(jnp.float32)
    wf = wr.astype(jnp.float32)
    K = xf.shape[-1]
    cap = max(1, ((1 << 24) - 1) // max(1, (m - 1) ** 2))
    if K <= cap:
        acc = jnp.matmul(xf, wf, preferred_element_type=jnp.float32)
        return jnp.mod(acc, float(m))
    acc = None
    for k0 in range(0, K, cap):
        part = jnp.mod(
            jnp.matmul(xf[..., k0:k0 + cap], wf[..., k0:k0 + cap, :],
                       preferred_element_type=jnp.float32),
            float(m))
        acc = part if acc is None else acc + part
    # ceil(K/cap) partials < m each: far below the f32 exact window
    return jnp.mod(acc, float(m))


def mod_mac(a: jax.Array, b: jax.Array, c: jax.Array, m: int) -> jax.Array:
    """(a * b + c) mod m elementwise on residues."""
    return jnp.mod(a * b + c, m)


def rns_matmul(
    x_res: jax.Array, w_res: jax.Array, moduli: Sequence[int]
) -> jax.Array:
    """Per-modulus residue matmuls: (n, M, K) x (n, K, N) -> (n, M, N)."""
    outs = [mod_matmul(x_res[i], w_res[i], m) for i, m in enumerate(moduli)]
    return jnp.stack(outs, axis=0)


def rns_dot_reconstruct(
    x: jax.Array, w: jax.Array, k: int
) -> jax.Array:
    """End-to-end integer matmul via RNS: quantized ints in, exact ints out.

    x: (..., K) integer-valued, w: (K, N) integer-valued. The result is exact
    as long as |x @ w| <= psi (Eq. 10 responsibility of the caller).
    """
    moduli = (2**k - 1, 2**k, 2**k + 1)
    xr = to_rns_special(x, k)
    wr = to_rns_special(w, k)
    out_res = jnp.stack(
        [mod_matmul(xr[i], wr[i], m) for i, m in enumerate(moduli)], axis=0
    ).astype(jnp.int32)
    return from_rns_special(out_res, k, signed=True)
