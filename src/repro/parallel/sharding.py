"""Sharding rules: FSDP x TP x EP x SP over the production mesh.

Logical roles:
  fsdp  -> 'data'   (parameters + optimizer state sharded at rest; GSPMD
                     inserts per-layer all-gathers — ZeRO-3 style)
  tp    -> 'model'  (Megatron column/row GEMM sharding)
  ep    -> 'model'  (expert dim of MoE weights/buffers when E % tp == 0)
  dp    -> ('pod', 'data')  (batch; the pod axis is an outer DP axis)
  sp    -> 'data'   (sequence axis of long-context decode caches)

Every rule degrades gracefully: an axis is applied to a tensor dim only when
the dim is divisible by the axis size, so odd head counts (qwen2's 14 heads,
qwen3's 40) fall back to replication on that dim instead of failing — GSPMD
then inserts the resharding collectives, which the roofline analysis makes
visible (and the perf loop attacks).
"""

from __future__ import annotations

import re
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------

def _axsize(mesh: Mesh, name) -> int:
    if name is None:
        return 1
    if isinstance(name, tuple):
        n = 1
        for a in name:
            n *= _axsize(mesh, a)
        return n
    return mesh.shape[name] if name in mesh.shape else 1


def _fit(mesh: Mesh, shape: Tuple[int, ...], want: Tuple[Any, ...]) -> P:
    """Keep each requested axis only if the dim divides evenly."""
    assert len(want) == len(shape), (shape, want)
    out = []
    for dim, ax in zip(shape, want):
        if ax is None:
            out.append(None)
        elif dim % _axsize(mesh, ax) == 0 and _axsize(mesh, ax) > 1:
            out.append(ax)
        else:
            out.append(None)
    return P(*out)


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def tp_size(mesh: Mesh) -> int:
    return _axsize(mesh, "model")


def kv_repeat_for(cfg: ModelConfig, mesh: Mesh) -> int:
    """Repeat KV heads so attention shards over the full TP degree (value-
    identical; see tests). Only when head counts divide cleanly."""
    tp = tp_size(mesh)
    H, kv = cfg.n_heads, cfg.n_kv_heads
    if cfg.family in ("ssm",):
        return 1
    if H % tp == 0 and kv < tp and tp % kv == 0:
        return tp // kv
    return 1


# --------------------------------------------------------------------------
# parameter specs (path-pattern -> dim roles)
# --------------------------------------------------------------------------

_PARAM_RULES = (
    # (path regex, roles per dim)  — roles resolved below
    (r"embed/emb$",            ("tp", "fsdp")),  # vocab-parallel
    (r"lm_head/w$",            ("fsdp", "tp")),
    (r"(attn|self_attn|cross_attn)/(q|k|v)/w$", ("fsdp", "tp")),
    (r"(attn|self_attn|cross_attn)/o/w$",       ("tp", "fsdp")),
    (r"(attn|self_attn|cross_attn)/(q|k|v)/b$", ("tp",)),
    (r"(attn|self_attn|cross_attn)/o/b$",       (None,)),
    (r"mlp/(gate|up)/w$",      ("fsdp", "tp")),
    (r"mlp/down/w$",           ("tp", "fsdp")),
    (r"mlp/(gate|up)/b$",      ("tp",)),
    (r"mlp/down/b$",           (None,)),
    (r"moe/router/w$",         ("fsdp", None)),
    (r"moe/(gate|up)$",        ("ep", "fsdp", "tp_if_no_ep")),
    (r"moe/down$",             ("ep", "tp_if_no_ep", "fsdp")),
    # Mamba2 projections: the in_proj output is SPLIT into uneven z|x|B|C|dt
    # segments and the conv output re-sliced at the same offsets — those
    # boundaries never align with TP shard boundaries, which trips the same
    # multi-axis-mesh SPMD miscompilation as within-head rope sharding (see
    # param_spec). FSDP over the data axis stays; TP stays off until the
    # block grows shard-aligned segment layouts.
    (r"mamba/in_proj/w$",      ("fsdp", None)),
    (r"mamba/out_proj/w$",     (None, "fsdp")),
    (r"mamba/conv_w$",         (None, None)),
    (r"frontend_proj/(fc1|fc2)?/?w$", ("fsdp", "tp")),
    (r"frontend_proj/w$",      ("fsdp", "tp")),
    (r"shared/proj/w$",        ("fsdp", "tp")),
)


def _path_str(path) -> str:
    parts = []
    for k in path:
        if isinstance(k, jax.tree_util.DictKey):
            parts.append(str(k.key))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_spec(mesh: Mesh, cfg: ModelConfig, pathstr: str,
               shape: Tuple[int, ...], stacked_layers: bool = True) -> P:
    """Spec for one parameter leaf. Layer-stacked leaves (leading n_layers or
    n_apps dim from vmapped init) get a leading None."""
    # strip the known stacked prefix
    lead_none = 0
    core = shape
    if pathstr.startswith(("layers/", "enc_layers/", "dec_layers/")) and len(shape) >= 1:
        lead_none = 1
        core = shape[1:]

    # Hybrid (zamba-style) stacks run their shared block inside a lax.cond
    # nested in the layer scan; ANY sharded array reaching that cond (even
    # contraction-only fsdp specs, or lm_head sharding propagated backward
    # through the scan) hits the same multi-axis-mesh SPMD miscompilation
    # as within-head rope sharding — silently wrong numerics, ~1e0 off.
    # Until the cond is restructured, hybrid params replicate wholesale.
    if cfg.family == "hybrid" or pathstr.startswith("shared/"):
        return P(*([None] * len(shape)))

    roles: Optional[Tuple[Any, ...]] = None
    for pat, r in _PARAM_RULES:
        if re.search(pat, pathstr):
            roles = r
            break
    if roles is None or len(roles) != len(core):
        # norms, scalars, A_log, biases we didn't match: replicate
        return P(*([None] * len(shape)))

    ep_ok = cfg.n_experts > 0 and cfg.n_experts % tp_size(mesh) == 0
    # q/k/v projections: shard the head-concat dim over TP only when every
    # shard holds WHOLE heads. A within-head split is legal GSPMD, but
    # rope's split/concat on the head_dim then crosses shard boundaries and
    # XLA's SPMD partitioner miscompiles it on 2-axis meshes (observed on
    # the CPU backend, jax 0.4.37: silently wrong numerics, ~1e0 off). GQA
    # archs hit this whenever n_kv_heads < tp; replicating the kv
    # projection there matches standard Megatron practice anyway.
    if re.search(r"(attn|self_attn|cross_attn)/(q|k|v)/(w|b)$", pathstr):
        hd = cfg.resolved_head_dim
        if hd and (core[-1] // hd) % max(tp_size(mesh), 1) != 0:
            roles = tuple(None if r == "tp" else r for r in roles)
    resolved = []
    for role in roles:
        if role == "fsdp":
            resolved.append("data")
        elif role == "tp":
            resolved.append("model")
        elif role == "ep":
            resolved.append("model" if ep_ok else None)
        elif role == "tp_if_no_ep":
            resolved.append(None if ep_ok else "model")
        else:
            resolved.append(None)
    spec = _fit(mesh, core, tuple(resolved))
    return P(*([None] * lead_none + list(spec)))


def param_shardings(mesh: Mesh, cfg: ModelConfig, params_shape_tree):
    """NamedSharding tree for a (possibly abstract) parameter pytree."""
    def one(path, leaf):
        return NamedSharding(mesh, param_spec(mesh, cfg, _path_str(path),
                                              tuple(leaf.shape)))
    return jax.tree_util.tree_map_with_path(one, params_shape_tree)


# --------------------------------------------------------------------------
# batch / cache specs
# --------------------------------------------------------------------------

def batch_shardings(mesh: Mesh, cfg: ModelConfig, batch_tree):
    """Token/label/frame/patch inputs: batch over (pod, data)."""
    dp = dp_axes(mesh)

    def one(path, leaf):
        shape = tuple(leaf.shape)
        name = _path_str(path)
        if name.endswith("idx") or not shape:
            return NamedSharding(mesh, P())
        if name.split("/")[-1] in ("tokens", "labels"):
            return NamedSharding(mesh, _fit(mesh, shape, (dp,) + (None,) * (len(shape) - 1)))
        if name.split("/")[-1] in ("frames", "patches"):
            return NamedSharding(mesh, _fit(mesh, shape, (dp,) + (None,) * (len(shape) - 1)))
        return NamedSharding(mesh, cache_spec(mesh, cfg, name, shape))
    return jax.tree_util.tree_map_with_path(one, batch_tree)


def cache_spec(mesh: Mesh, cfg: ModelConfig, name: str,
               shape: Tuple[int, ...]) -> P:
    """KV/SSM cache leaves. Layout:
      k/v/self_k/self_v/cross_k/cross_v/shared_k/shared_v:
          (nl, B, S, kv_eff, hd) -> (None, dp, sp_if_B_unshardable, tp, None)
      kp/vp/shared_kp/shared_vp (paged page pools):
          (nl, NB, bs, kv_eff, hd) -> (None, dp_if_NB_divisible, None, tp, None)
          — the BLOCK dim takes the data axis (blocks are the unit of both
          allocation and placement; per-slot gathers cross shards and GSPMD
          inserts the collectives, which the roofline makes visible).
          Prefix sharing aliases one block into MANY slots' tables
          (refcounted, copy-on-write), so a block's readers may live on any
          dp shard — block-dim placement, not slot-dim placement, is what
          keeps those aliased gathers addressable without replication
      bt (block tables): (slots, max_blocks) -> (dp, None)
      ssm:  (nl, B, H, P, N)     -> (None, dp, tp, None, None)
      conv: (nl, B, K-1, C)      -> (None, dp, None, tp)
    """
    dp = dp_axes(mesh)
    leaf = name.split("/")[-1]
    if leaf == "idx":
        # scalar (uniform batch) or a per-slot vector (continuous-batching
        # stacked layout) — the vector shards over dp like the slot dim
        return P() if not shape else _fit(mesh, shape, (dp,))
    if not shape:
        return P()
    if leaf == "bt":
        return _fit(mesh, shape, (dp, None))
    if leaf in ("kp", "vp", "shared_kp", "shared_vp"):
        nl, NB, bs, kv, hd = shape
        b_ax = dp if NB % _axsize(mesh, dp) == 0 else None
        # shared_* pools feed the hybrid family's cond-nested shared block:
        # no tp there (see param_spec hybrid note)
        kv_ax = None if leaf.startswith("shared_") else "model"
        return _fit(mesh, shape, (None, b_ax, None, kv_ax, None))
    if leaf in ("k", "v", "self_k", "self_v", "cross_k", "cross_v",
                "shared_k", "shared_v"):
        nl, B, S, kv, hd = shape
        b_ax = dp if B % _axsize(mesh, dp) == 0 else None
        # SP: if batch can't use the data axis, shard the sequence dim there
        s_ax = None if b_ax is not None else (
            "data" if S % _axsize(mesh, "data") == 0 else None)
        kv_ax = None if leaf.startswith("shared_") else "model"
        return _fit(mesh, shape, (None, b_ax, s_ax, kv_ax, None))
    if leaf == "ssm":
        # head dim stays unsharded: the mamba decode step re-slices its
        # conv channels at segment boundaries that never align with TP
        # shards (same SPMD miscompilation family as within-head rope;
        # see param_spec)
        nl, B, H, Pd, N = shape
        b_ax = dp if B % _axsize(mesh, dp) == 0 else None
        return _fit(mesh, shape, (None, b_ax, None, None, None))
    if leaf == "conv":
        nl, B, K, C = shape
        b_ax = dp if B % _axsize(mesh, dp) == 0 else None
        return _fit(mesh, shape, (None, b_ax, None, None))
    return P(*([None] * len(shape)))


def serve_block_shards(mesh: Mesh, n_blocks: int, n_slots: int) -> int:
    """How many contiguous chunks the paged pools' BLOCK dim and the slot
    dim actually split into over dp — the serving allocator's locality
    geometry (``BlockAllocator(n_shards=...)``). XLA splits a sharded dim
    into equal contiguous chunks, so block ``b`` lives on shard
    ``b // (n_blocks // d)`` and slot ``s`` on ``s // (n_slots // d)``.
    Returns 1 whenever either dim can't take the axis (``cache_spec`` /
    ``_fit`` then replicate it and locality has no meaning)."""
    d = _axsize(mesh, dp_axes(mesh))
    if d > 1 and n_blocks % d == 0 and n_slots % d == 0:
        return d
    return 1


def serve_state_shardings(mesh: Mesh, cfg: ModelConfig, abstract_state):
    """Shardings for the continuous-batching engine state
    (``runtime.server.LMServer.state``): cache leaves follow
    :func:`cache_spec` with the slot dim as the batch dim, and the per-slot
    control vectors (last_tok/active/emitted/eos/max_tok) shard over dp
    alongside it — one serving replica per dp shard of slots. Prefix-shared
    page-pool blocks are referenced by slots across dp shards; that aliasing
    is safe because pool leaves shard on the BLOCK dim (cache_spec), so a
    shared block has one home and every reader gathers from it."""
    dp = dp_axes(mesh)
    # hybrid decode runs its shared block under lax.cond inside the tick;
    # sharded state reaching it miscompiles on multi-axis meshes (see
    # param_spec) — the whole serving state replicates for that family
    if cfg.family == "hybrid":
        return jax.tree_util.tree_map(
            lambda _: NamedSharding(mesh, P()), abstract_state)

    def one(path, leaf):
        name = _path_str(path)
        shape = tuple(leaf.shape)
        if name.startswith("cache/"):
            return NamedSharding(mesh, cache_spec(mesh, cfg,
                                                  name[len("cache/"):], shape))
        if name.startswith("health/"):
            # engine-wide analog-fault accumulators (scalars / per-channel
            # vectors): tiny, replicated — never sharded over slots
            return NamedSharding(mesh, P())
        if not shape:
            return NamedSharding(mesh, P())
        return NamedSharding(
            mesh, _fit(mesh, shape, (dp,) + (None,) * (len(shape) - 1)))
    return jax.tree_util.tree_map_with_path(one, abstract_state)


def train_state_shardings(mesh: Mesh, cfg: ModelConfig, abstract_state):
    """Shardings for {'params', 'opt': {'m','v','mom','count'}, 'step', 'err'}.
    Optimizer moments and error-feedback buffers shard exactly like their
    parameters (ZeRO-1 falls out of the fsdp component of the param specs)."""
    def one(path, leaf):
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        keys = [k.key if hasattr(k, "key") else str(k) for k in path]
        if keys[0] == "params":
            sub = keys[1:]
        elif keys[0] == "opt" and keys[1] in ("m", "v", "mom"):
            sub = keys[2:]
        elif keys[0] == "err":
            sub = keys[1:]
        else:
            return NamedSharding(mesh, P(*([None] * leaf.ndim)))
        return NamedSharding(
            mesh, param_spec(mesh, cfg, "/".join(sub), tuple(leaf.shape)))
    return jax.tree_util.tree_map_with_path(one, abstract_state)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
