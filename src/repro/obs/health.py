"""Analog-health telemetry: device-side fault counters for the RRNS path.

Mirage's whole pitch is high-precision analog compute DESPITE noise; the
counters here make that tradeoff observable while serving. The problem they
solve: RRNS correction events happen deep inside jitted decode/verify steps
(the majority decode runs per GEMM per layer per tick), so host-side
instrumentation cannot see them, and returning them per tick would add a
device→host transfer to the hot loop.

Mechanism — a trace-time collection scope, the telemetry twin of
``repro.core.gemm.noise_key_scope``:

  * the serving engine's jitted step functions open :func:`collect` around
    the model call; the scope is a thread-local visible while JAX TRACES
    the step (tracing runs the Python body once);
  * instrumented library code (``analog/rrns.py`` decode,
    ``analog/channel.py`` stages, the ``mirage_rrns`` backend's Pallas
    paths) calls :func:`record` with small traced summaries (scalar fault
    counts, per-channel flip vectors). With no scope open ``record`` is a
    no-op and — crucially — the summary is never even computed, because
    every call site guards on :func:`active`;
  * the step folds the collected values into device-resident accumulators
    carried in the engine state (:func:`fold` — elementwise add), so the
    counters ride the existing state donation and NEVER travel to host on
    a tick. A snapshot (``LMServer.health_snapshot``) fetches the whole
    accumulator dict with ONE ``jax.device_get``.

:func:`spec` derives the accumulator structure from the policy alone (an
over-approximation is fine: keys that never get recorded just stay zero;
recorded keys missing from the spec are dropped — the spec is the contract
for what a given policy CAN report). Counters are int32: at the reduced
serving shapes a counter would take ~2^31 corrected faults to wrap, far
beyond any run this repo performs; a production deployment would widen to
int64 under ``jax_enable_x64``.

Token-parity invariant: recording only ADDS reductions next to the decode
— it never feeds back into the value path, so an instrumented engine is
token-identical to the uninstrumented one (tested in
``tests/test_obs.py``; the bench enforces it on a live RRNS run).

Inner transformations: values traced inside a ``lax.scan`` body (the models
stack layers with scan) or a ``jax.checkpoint`` belong to that inner trace
and may NOT escape to the enclosing jit through the thread-local — JAX
raises ``UnexpectedTracerError``. The scan chokepoints therefore wrap their
bodies with :func:`lifted` (records inside the body drain into a NESTED
scope and leave the body as extra stacked outputs) and run through
:func:`lifting_scan` (sums the stacked per-iteration values over the scan
axis and re-records them one trace level up). The pair composes — a lifted
scan inside a lifted scan re-records level by level. Branch traces
(``lax.cond``) have no output channel a wrapper can widen without tracing
both branches twice, so GEMMs under a cond guard open :func:`suppressed`
instead — those sites record nothing rather than crash (only the hybrid
family's shared attention block, documented there).
"""

from __future__ import annotations

import contextlib
import threading
from typing import Dict, Tuple

import jax.numpy as jnp

_SCOPE = threading.local()


class HealthCollector:
    """Accumulates traced contributions recorded while the scope is open."""

    def __init__(self):
        self.values: Dict[str, jnp.ndarray] = {}

    def add(self, name: str, value) -> None:
        v = jnp.asarray(value)
        cur = self.values.get(name)
        self.values[name] = v if cur is None else cur + v


def active() -> bool:
    """True when a :func:`collect` scope is open on this thread. Call
    sites guard their summary computation on this so a disabled engine
    (training, benchmarks, non-analog serving) traces ZERO extra ops."""
    stack = getattr(_SCOPE, "stack", None)
    return bool(stack) and stack[-1] is not None


def record(name: str, value) -> None:
    """Add ``value`` (scalar or per-channel vector, int32) into the
    innermost open collection scope; no-op without one."""
    stack = getattr(_SCOPE, "stack", None)
    if stack and stack[-1] is not None:
        stack[-1].add(name, value)


@contextlib.contextmanager
def collect():
    """Open a collection scope; yields the :class:`HealthCollector`."""
    stack = getattr(_SCOPE, "stack", None)
    if stack is None:
        stack = _SCOPE.stack = []
    c = HealthCollector()
    stack.append(c)
    try:
        yield c
    finally:
        stack.pop()


@contextlib.contextmanager
def suppressed():
    """Disable collection inside the block even when an outer scope is
    open. For call sites inside branch traces (``lax.cond``) whose
    intermediates cannot legally reach the enclosing scope."""
    stack = getattr(_SCOPE, "stack", None)
    if stack is None:
        stack = _SCOPE.stack = []
    stack.append(None)
    try:
        yield
    finally:
        stack.pop()


# --------------------------------------------------------------------------
# Crossing inner transformations (lax.scan / jax.checkpoint)
# --------------------------------------------------------------------------

def lifted(body):
    """Wrap a ``lax.scan`` body so health values recorded inside it leave
    the body as extra stacked outputs: ``(carry, ys)`` becomes ``(carry,
    (ys, {name: value}))`` when a scope is active (unchanged otherwise).
    Apply BEFORE ``jax.checkpoint`` so the lift rides the remat's real
    output channel. Must be paired with :func:`lifting_scan`."""
    def wrapped(carry, xs):
        if not active():
            return body(carry, xs)
        with collect() as hc:
            carry, ys = body(carry, xs)
        return carry, (ys, dict(hc.values))
    return wrapped


def lifting_scan(body, init, xs, **kwargs):
    """``jax.lax.scan`` for a :func:`lifted` body: unpacks the stacked
    health outputs, sums them over the scan axis and re-records the totals
    into the enclosing scope, then returns the plain ``(carry, ys)``."""
    import jax

    if not active():
        return jax.lax.scan(body, init, xs, **kwargs)
    carry, (ys, lifted_h) = jax.lax.scan(body, init, xs, **kwargs)
    for name, v in lifted_h.items():
        record(name, jnp.sum(v, axis=0))
    return carry, ys


# --------------------------------------------------------------------------
# Accumulator structure
# --------------------------------------------------------------------------

def spec(policy) -> Dict[str, Tuple[int, ...]]:
    """Accumulator shapes a policy's serving path can record.

    Keys:
      rrns_corrected     faults a decode subset-vote repaired exactly
                         (winner inside the correction radius — consistent
                         with >= n_total - floor(r/2) moduli — but >= 1
                         residue disagreed)
      rrns_uncorrected   decodes whose winner is BEYOND the correction
                         radius (or has no legal reconstruction at all):
                         the output value is untrustworthy
      detector_flips     per-channel count of residues moved >= 1 phase
                         level by detector noise (readout side)
      drift_flips        per-channel count from programming drift (program
                         side; zero under stationary weights, which program
                         once at admission outside the tick)
      burst_hits         correlated burst events injected by the channel

    Returns {} for policies whose backend is deterministic AND
    non-correcting — the engine then carries no health state at all.
    """
    from repro.analog import rrns as rrns_mod
    from repro.analog.channel import AnalogChannelConfig
    from repro.core import backends

    try:
        backend = backends.resolve(policy)
    except KeyError:
        return {}
    if not backend.supports_noise:
        return {}
    correct = policy.mode in ("mirage_rrns", "mirage_rrns_ref")
    moduli = (rrns_mod.rrns_moduli(policy) if correct
              else tuple(policy.moduli))
    cfg = AnalogChannelConfig.from_policy(policy)
    out: Dict[str, Tuple[int, ...]] = {}
    if correct:
        out["rrns_corrected"] = ()
        out["rrns_uncorrected"] = ()
    if any(s > 0 for s in cfg.detector_sigmas(moduli)):
        out["detector_flips"] = (len(moduli),)
    if cfg.phase_drift_sigma > 0:
        out["drift_flips"] = (len(moduli),)
    if cfg.burst_rate > 0:
        out["burst_hits"] = ()
    return out


def init(spec_: Dict[str, Tuple[int, ...]]) -> Dict[str, jnp.ndarray]:
    """Zeroed device accumulators for a spec."""
    return {k: jnp.zeros(shape, jnp.int32)
            for k, shape in sorted(spec_.items())}


def fold(health: Dict[str, jnp.ndarray],
         collected: Dict[str, jnp.ndarray]) -> Dict[str, jnp.ndarray]:
    """Add a step's collected contributions into the accumulators.

    Iterates the ACCUMULATOR keys: spec'd keys nothing recorded stay
    unchanged; recorded keys outside the spec are dropped (the spec is the
    policy's declared surface — see :func:`spec`)."""
    out = {}
    for k, v in health.items():
        c = collected.get(k)
        out[k] = v if c is None else v + c.astype(v.dtype)
    return out
