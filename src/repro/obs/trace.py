"""Span tracer: a thread-safe ring buffer of timed spans, ~zero cost off.

The serving tick pipeline (admit → prefill chunk → draft → verify/decode →
host sync), the bucketed prefill, trainer steps and the GEMM backend
dispatch are all instrumented with :meth:`SpanTracer.span`. Design:

  * **disabled is the default and costs one attribute check**: ``span()``
    on a disabled tracer returns a shared no-op context manager — no
    generator frame, no clock read, no allocation. The <2% instrumented-on
    overhead gate in ``benchmarks/bench_serving.py`` covers the ENABLED
    path; the disabled path is unmeasurable.
  * **bounded memory**: spans land in a preallocated ring buffer
    (``capacity`` spans, default 64k); wraparound keeps the most recent
    spans. A long soak never grows the tracer.
  * **Chrome-trace export**: :meth:`chrome_trace` renders the ring as a
    ``traceEvents`` JSON object (``ph: "X"`` complete events, microsecond
    timestamps) loadable in ``chrome://tracing`` / Perfetto;
    :meth:`export` writes it to a file (``launch/serve.py
    --trace-export``).
  * **jax.profiler composition**: with ``annotate=True`` every span also
    opens a ``jax.profiler.TraceAnnotation``, so host spans line up with
    XLA device activity inside a profiler capture window
    (``launch/serve.py --profile-window`` wraps N ticks in
    ``jax.profiler.trace``). Note that a span around code traced inside
    ``jax.jit`` measures TRACE time on first call and ~dispatch time after
    — device-side truth comes from the profiler capture, which is exactly
    why the two compose.
"""

from __future__ import annotations

import contextlib
import json
import os
import threading
import time
from typing import Dict, List, Optional

_NULL = contextlib.nullcontext()


class _SpanCM:
    """Reusable-per-call context manager recording one span on exit."""

    __slots__ = ("_tracer", "_name", "_args", "_t0", "_ann")

    def __init__(self, tracer: "SpanTracer", name: str,
                 args: Optional[Dict]):
        self._tracer = tracer
        self._name = name
        self._args = args

    def __enter__(self):
        if self._tracer.annotate and self._tracer._annotation is not None:
            self._ann = self._tracer._annotation(self._name)
            self._ann.__enter__()
        else:
            self._ann = None
        self._t0 = time.perf_counter_ns()
        return self

    def __exit__(self, *exc):
        dur = time.perf_counter_ns() - self._t0
        if self._ann is not None:
            self._ann.__exit__(*exc)
        self._tracer._record(self._name, self._t0, dur, self._args)
        return False


class SpanTracer:
    """Ring-buffer span recorder; see module docstring."""

    def __init__(self, capacity: int = 65536, enabled: bool = False,
                 annotate: bool = False):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self.enabled = bool(enabled)
        self.annotate = bool(annotate)
        self._lock = threading.Lock()
        self._ring: List[Optional[tuple]] = [None] * self.capacity
        self._head = 0          # next write index
        self._total = 0         # spans ever recorded (wraparound counter)
        self._t_origin = time.perf_counter_ns()
        self._annotation = None
        if annotate:
            self._load_annotation()

    def _load_annotation(self):
        try:
            from jax.profiler import TraceAnnotation
            self._annotation = TraceAnnotation
        except Exception:       # jax absent/old: spans still record
            self._annotation = None

    def configure(self, enabled: Optional[bool] = None,
                  annotate: Optional[bool] = None) -> "SpanTracer":
        if enabled is not None:
            self.enabled = bool(enabled)
        if annotate is not None:
            self.annotate = bool(annotate)
            if self.annotate and self._annotation is None:
                self._load_annotation()
        return self

    # -- recording -----------------------------------------------------

    def span(self, name: str, args: Optional[Dict] = None):
        """Context manager timing the enclosed block. No-op when disabled."""
        if not self.enabled:
            return _NULL
        return _SpanCM(self, name, args)

    def instant(self, name: str, args: Optional[Dict] = None) -> None:
        """Zero-duration marker event."""
        if not self.enabled:
            return
        self._record(name, time.perf_counter_ns(), 0, args, ph="i")

    def _record(self, name, t0_ns, dur_ns, args, ph="X"):
        tid = threading.get_ident()
        with self._lock:
            self._ring[self._head] = (name, t0_ns, dur_ns, tid, args, ph)
            self._head = (self._head + 1) % self.capacity
            self._total += 1

    # -- export --------------------------------------------------------

    def spans(self) -> List[Dict]:
        """Recorded spans, oldest first (at most ``capacity``)."""
        with self._lock:
            n = min(self._total, self.capacity)
            start = (self._head - n) % self.capacity
            raw = [self._ring[(start + i) % self.capacity] for i in range(n)]
        return [{"name": s[0], "t0_ns": s[1], "dur_ns": s[2], "tid": s[3],
                 "args": s[4] or {}, "ph": s[5]} for s in raw
                if s is not None]

    @property
    def n_recorded(self) -> int:
        """Spans ever recorded (including those evicted by wraparound)."""
        with self._lock:
            return self._total

    @property
    def n_dropped(self) -> int:
        with self._lock:
            return max(0, self._total - self.capacity)

    def clear(self) -> None:
        with self._lock:
            self._ring = [None] * self.capacity
            self._head = 0
            self._total = 0

    def chrome_trace(self) -> Dict:
        """Chrome-trace / Perfetto ``traceEvents`` JSON object."""
        pid = os.getpid()
        events = []
        for s in self.spans():
            ev = {
                "name": s["name"],
                "ph": s["ph"],
                "ts": (s["t0_ns"] - self._t_origin) / 1e3,   # µs
                "pid": pid,
                "tid": s["tid"],
                "args": s["args"],
            }
            if s["ph"] == "X":
                ev["dur"] = s["dur_ns"] / 1e3
            else:
                ev["s"] = "t"  # instant event scope: thread
            events.append(ev)
        return {"traceEvents": events, "displayTimeUnit": "ms",
                "otherData": {"dropped_spans": self.n_dropped}}

    def export(self, path: str) -> str:
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.chrome_trace(), f)
        return path


_default = SpanTracer()


def get_tracer() -> SpanTracer:
    """Process-wide default tracer. Disabled by default: library code calls
    ``get_tracer().span(...)`` freely; only an entry point (launcher,
    benchmark, test) flips it on via :func:`configure`."""
    return _default


def configure(enabled: Optional[bool] = None,
              annotate: Optional[bool] = None,
              capacity: Optional[int] = None) -> SpanTracer:
    """Configure the default tracer. Changing ``capacity`` clears it."""
    global _default
    if capacity is not None and capacity != _default.capacity:
        _default = SpanTracer(capacity=capacity, enabled=_default.enabled,
                              annotate=_default.annotate)
    return _default.configure(enabled=enabled, annotate=annotate)


@contextlib.contextmanager
def profile_window(logdir: str, tracer: Optional[SpanTracer] = None):
    """Capture a ``jax.profiler`` trace into ``logdir`` for the enclosed
    block, composing with the span tracer's annotations (spans appear as
    named ranges inside the device timeline). Degrades to a warning when
    the installed jax cannot start a profiler session."""
    import jax

    prev = None
    if tracer is not None:
        prev = (tracer.enabled, tracer.annotate)
        tracer.configure(enabled=True, annotate=True)
    started = False
    try:
        try:
            jax.profiler.start_trace(logdir)
            started = True
        except Exception as e:  # pragma: no cover - env dependent
            print(f"# profile window unavailable: {e}")
        yield
    finally:
        if started:
            try:
                jax.profiler.stop_trace()
            except Exception as e:  # pragma: no cover
                print(f"# profile stop failed: {e}")
        if tracer is not None and prev is not None:
            tracer.configure(enabled=prev[0], annotate=prev[1])
