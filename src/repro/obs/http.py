"""Tiny stdlib HTTP endpoint exposing the registry and tracer.

Started from ``launch/serve.py --metrics-port``; serves

  /metrics        Prometheus text exposition (scrape target)
  /metrics.json   registry snapshot as JSON (same data, tooling-friendly)
  /trace          Chrome-trace JSON of the span ring buffer

Runs a ``ThreadingHTTPServer`` on a daemon thread so it never blocks the
serving loop or prevents process exit. ``port=0`` binds an ephemeral port
(the bound port is on :attr:`MetricsServer.port`) — CI uses this to avoid
port races. The registry's collectors (e.g. the lazy analog-health fetch)
run inside the scrape handler, i.e. on the HTTP thread, which is exactly
the "one host transfer per snapshot, never per tick" contract.
"""

from __future__ import annotations

import json
import threading
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional

from . import metrics as metrics_mod
from . import trace as trace_mod


class MetricsServer:
    def __init__(self, port: int = 0, host: str = "127.0.0.1",
                 registry=None, tracer=None):
        self.registry = registry or metrics_mod.get_registry()
        self.tracer = tracer or trace_mod.get_tracer()
        outer = self

        class Handler(BaseHTTPRequestHandler):
            def log_message(self, fmt, *args):  # silence per-request stderr
                pass

            def _send(self, body: bytes, ctype: str, code: int = 200):
                self.send_response(code)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def do_GET(self):
                path = self.path.split("?", 1)[0]
                try:
                    if path in ("/metrics", "/"):
                        body = outer.registry.prometheus_text().encode()
                        self._send(body, "text/plain; version=0.0.4")
                    elif path == "/metrics.json":
                        body = json.dumps(outer.registry.snapshot()).encode()
                        self._send(body, "application/json")
                    elif path == "/trace":
                        body = json.dumps(outer.tracer.chrome_trace()).encode()
                        self._send(body, "application/json")
                    else:
                        self._send(b"not found\n", "text/plain", 404)
                except BrokenPipeError:
                    pass

        self._httpd = ThreadingHTTPServer((host, port), Handler)
        self._httpd.daemon_threads = True
        self.host = host
        self.port = self._httpd.server_address[1]
        self._thread: Optional[threading.Thread] = None

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> "MetricsServer":
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="obs-metrics-http",
            daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5.0)
            self._thread = None


def serve_metrics(port: int = 0, host: str = "127.0.0.1",
                  registry=None, tracer=None) -> MetricsServer:
    """Start a daemon-threaded metrics endpoint; returns the running server
    (check ``.port`` when started with ``port=0``)."""
    return MetricsServer(port=port, host=host, registry=registry,
                         tracer=tracer).start()
