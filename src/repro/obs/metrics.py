"""Low-overhead metrics registry: counters / gauges / histograms with labels.

The serving engine, trainer and benchmarks record into ONE
:class:`MetricsRegistry` per component (the engine's Scheduler owns one by
default; pass a shared registry to aggregate several components). Design
constraints, in order:

  * **hot-path cost**: a counter increment is one dict-free attribute add
    under a lock (label resolution is cached on first use, so steady-state
    ``labels()`` is a tuple-keyed dict hit). Nothing allocates per
    observation except the histogram's bucket index.
  * **snapshot while writing**: every read path (``snapshot()``,
    ``prometheus_text()``) takes the same per-instrument lock as the
    writers, so a scrape during a decode tick sees a consistent value —
    never a torn histogram (property-tested with writer threads).
  * **pull, not push**: values that are derived state (queue depth, block
    pool occupancy, device-side analog-health counters) register as
    callback gauges / collectors and are evaluated lazily at scrape time —
    the analog-health collector is what keeps the device→host transfer at
    one per SNAPSHOT instead of one per tick.

Exposition: :meth:`MetricsRegistry.snapshot` returns a plain JSON-able
dict; :meth:`MetricsRegistry.prometheus_text` renders the text exposition
format (``# HELP`` / ``# TYPE`` / ``name{label="v"} value``, histograms as
cumulative ``_bucket{le=...}`` + ``_sum`` + ``_count``) that
``repro.obs.http`` serves from ``launch/serve.py --metrics-port``.
"""

from __future__ import annotations

import bisect
import math
import threading
from typing import Callable, Dict, Iterable, List, Optional, Sequence, Tuple

# Label-cardinality guard: a mistyped high-cardinality label (request id,
# token value, ...) silently eats memory and makes scrapes quadratic; fail
# loudly instead. Generous enough for every legitimate use here (slots,
# moduli channels, buckets).
MAX_LABEL_SETS = 1024

# Default latency buckets (seconds): 1ms .. ~120s, x2 per step — wide
# enough for CPU-interpret serving ticks and TPU microseconds alike.
DEFAULT_BUCKETS = tuple(0.001 * 2 ** i for i in range(18))


def _fmt_value(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    f = float(v)
    return repr(int(f)) if f == int(f) else repr(f)


def _label_str(names: Sequence[str], values: Sequence[str]) -> str:
    if not names:
        return ""
    inner = ",".join(f'{n}="{v}"' for n, v in zip(names, values))
    return "{" + inner + "}"


class _Instrument:
    """Common parent/child machinery for labelled instruments.

    A metric created with ``label_names`` is a PARENT: observations go
    through ``labels(v1, v2, ...)`` which returns (and caches) the child
    bound to those label values. A metric without labels is its own child.
    """

    kind = "untyped"

    def __init__(self, name: str, help: str = "",
                 label_names: Sequence[str] = ()):
        self.name = name
        self.help = help
        self.label_names = tuple(label_names)
        self._lock = threading.Lock()
        self._children: Dict[Tuple[str, ...], "_Instrument"] = {}
        if not self.label_names:
            self._children[()] = self

    def labels(self, *values) -> "_Instrument":
        key = tuple(str(v) for v in values)
        if len(key) != len(self.label_names):
            raise ValueError(
                f"{self.name}: expected {len(self.label_names)} label "
                f"values {self.label_names}, got {values!r}")
        child = self._children.get(key)
        if child is None:
            with self._lock:
                child = self._children.get(key)
                if child is None:
                    if len(self._children) >= MAX_LABEL_SETS:
                        raise ValueError(
                            f"{self.name}: label cardinality exceeded "
                            f"{MAX_LABEL_SETS} distinct label sets — a "
                            f"high-cardinality label leaked into a metric")
                    child = self._make_child()
                    self._children[key] = child
        return child

    def _make_child(self) -> "_Instrument":
        child = type(self)(self.name, self.help)
        child._lock = self._lock  # one lock per metric family
        return child

    def _series(self) -> Iterable[Tuple[Tuple[str, ...], "_Instrument"]]:
        with self._lock:
            return list(self._children.items())


class Counter(_Instrument):
    """Monotonic counter. ``inc(n)`` only; ``set`` exists for the legacy
    Scheduler dict view (internal use — Prometheus semantics still hold as
    long as callers only ever move it forward)."""

    kind = "counter"

    def __init__(self, name, help="", label_names=()):
        super().__init__(name, help, label_names)
        self._value = 0.0

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    @property
    def value(self) -> float:
        with self._lock:
            return self._value


class Gauge(_Instrument):
    """Point-in-time value. Either set directly (``set``/``inc``/``dec``)
    or backed by a zero-argument callable evaluated at scrape time."""

    kind = "gauge"

    def __init__(self, name, help="", label_names=(),
                 fn: Optional[Callable[[], float]] = None):
        super().__init__(name, help, label_names)
        self._value = 0.0
        self._fn = fn

    def set(self, v: float) -> None:
        with self._lock:
            self._value = float(v)

    def set_fn(self, fn: Callable[[], float]) -> None:
        with self._lock:
            self._fn = fn

    def inc(self, n: float = 1.0) -> None:
        with self._lock:
            self._value += n

    def dec(self, n: float = 1.0) -> None:
        self.inc(-n)

    @property
    def value(self) -> float:
        fn = self._fn
        if fn is not None:
            try:
                return float(fn())
            except Exception:
                return float("nan")
        with self._lock:
            return self._value


class Histogram(_Instrument):
    """Fixed-bucket histogram (cumulative ``le`` semantics on exposition).

    ``buckets`` are the UPPER edges of the non-overflow buckets, strictly
    increasing; an implicit +Inf bucket catches the rest. ``observe`` costs
    one bisect + two adds. ``percentile(q)`` interpolates linearly inside
    the winning bucket (the +Inf bucket reports the largest finite edge) —
    an estimate for dashboards; exact tails come from raw samples where the
    caller keeps them (``Scheduler.latency_summary``).
    """

    kind = "histogram"

    def __init__(self, name, help="", label_names=(),
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        super().__init__(name, help, label_names)
        b = tuple(float(x) for x in buckets)
        if list(b) != sorted(set(b)):
            raise ValueError(f"{name}: bucket edges must be strictly "
                             f"increasing, got {buckets!r}")
        self.buckets = b
        self._counts = [0] * (len(b) + 1)
        self._sum = 0.0
        self._count = 0

    def _make_child(self):
        child = Histogram(self.name, self.help, buckets=self.buckets)
        child._lock = self._lock
        return child

    def observe(self, v: float) -> None:
        i = bisect.bisect_left(self.buckets, v)
        with self._lock:
            self._counts[i] += 1
            self._sum += v
            self._count += 1

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def sum(self) -> float:
        with self._lock:
            return self._sum

    def percentile(self, q: float) -> float:
        """Estimated q-quantile (q in [0, 1]) by linear interpolation
        within the winning bucket."""
        with self._lock:
            total = self._count
            counts = list(self._counts)
        if total == 0:
            return 0.0
        rank = q * total
        cum = 0
        for i, c in enumerate(counts):
            cum += c
            if cum >= rank and c > 0:
                hi = (self.buckets[i] if i < len(self.buckets)
                      else self.buckets[-1] if self.buckets else 0.0)
                lo = self.buckets[i - 1] if i > 0 else 0.0
                frac = (rank - (cum - c)) / c
                return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
        return self.buckets[-1] if self.buckets else 0.0


class MetricsRegistry:
    """Named instruments + scrape-time collectors.

    ``counter/gauge/histogram`` get-or-create by name (re-registration with
    a different kind raises — that is always a bug). ``add_collector``
    registers a pre-scrape hook, called ONCE per ``snapshot()`` /
    ``prometheus_text()``; the serving engine's analog-health collector
    uses it to fetch the device-side counters with a single host transfer
    per scrape.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: Dict[str, _Instrument] = {}
        self._collectors: List[Callable[["MetricsRegistry"], None]] = []

    # -- registration --------------------------------------------------

    def _get_or_make(self, cls, name, help, label_names, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is not None:
                if not isinstance(m, cls):
                    raise ValueError(
                        f"metric {name!r} already registered as {m.kind}")
                return m
            m = cls(name, help, label_names, **kw)
            self._metrics[name] = m
            return m

    def counter(self, name: str, help: str = "",
                label_names: Sequence[str] = ()) -> Counter:
        return self._get_or_make(Counter, name, help, label_names)

    def gauge(self, name: str, help: str = "",
              label_names: Sequence[str] = ()) -> Gauge:
        return self._get_or_make(Gauge, name, help, label_names)

    def gauge_fn(self, name: str, fn: Callable[[], float],
                 help: str = "") -> Gauge:
        g = self._get_or_make(Gauge, name, help, ())
        g.set_fn(fn)
        return g

    def histogram(self, name: str, help: str = "",
                  label_names: Sequence[str] = (),
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get_or_make(Histogram, name, help, label_names,
                                 buckets=buckets)

    def add_collector(self, fn: Callable[["MetricsRegistry"], None]) -> None:
        with self._lock:
            self._collectors.append(fn)

    def get(self, name: str) -> Optional[_Instrument]:
        with self._lock:
            return self._metrics.get(name)

    # -- exposition ----------------------------------------------------

    def _run_collectors(self) -> None:
        with self._lock:
            collectors = list(self._collectors)
        for fn in collectors:
            try:
                fn(self)
            except Exception:
                pass  # a broken collector must never kill a scrape

    def snapshot(self) -> Dict:
        """JSON-able dict of every series: counters/gauges as numbers,
        histograms as {buckets, counts, sum, count, p50/p95/p99}."""
        self._run_collectors()
        with self._lock:
            metrics = list(self._metrics.items())
        out: Dict = {}
        for name, m in metrics:
            series = {}
            for key, child in m._series():
                label = _label_str(m.label_names, key) or "_"
                if isinstance(child, Histogram):
                    with child._lock:
                        series[label] = {
                            "buckets": list(child.buckets),
                            "counts": list(child._counts),
                            "sum": child._sum,
                            "count": child._count,
                        }
                    series[label].update(
                        {f"p{int(q * 100)}": child.percentile(q)
                         for q in (0.5, 0.95, 0.99)})
                else:
                    series[label] = child.value
            out[name] = {"kind": m.kind, "help": m.help, "series": series}
        return out

    def prometheus_text(self) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        self._run_collectors()
        with self._lock:
            metrics = list(self._metrics.items())
        lines: List[str] = []
        for name, m in sorted(metrics):
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            for key, child in m._series():
                ls = _label_str(m.label_names, key)
                if isinstance(child, Histogram):
                    with child._lock:
                        counts = list(child._counts)
                        total, s = child._count, child._sum
                    cum = 0
                    for i, edge in enumerate(
                            list(child.buckets) + [math.inf]):
                        cum += counts[i]
                        le = _label_str(
                            m.label_names + ("le",),
                            key + (_fmt_value(edge),))
                        lines.append(f"{name}_bucket{le} {cum}")
                    lines.append(f"{name}_sum{ls} {_fmt_value(s)}")
                    lines.append(f"{name}_count{ls} {total}")
                else:
                    lines.append(f"{name}{ls} {_fmt_value(child.value)}")
        return "\n".join(lines) + "\n"


_default = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    """Process-wide default registry (launchers/benchmarks convenience;
    the serving engine defaults to a private registry per Scheduler)."""
    return _default
