"""Unified telemetry for the repro: metrics registry, span tracer, and
analog-health counters.

Three planes, one package:

  * :mod:`repro.obs.metrics` — counters/gauges/histograms with labels;
    JSON snapshot + Prometheus text exposition. The serving Scheduler's
    metrics are registry-backed.
  * :mod:`repro.obs.trace` — thread-safe ring-buffer span tracer
    (~zero cost disabled), Chrome-trace/Perfetto export, composes with
    ``jax.profiler`` via named annotations.
  * :mod:`repro.obs.health` — device-side accumulators for RRNS
    corrected/uncorrected residue faults and per-channel noise-stage
    activations, fetched with one host transfer per snapshot.

``repro.obs.http`` serves the first two over HTTP
(``launch/serve.py --metrics-port``).
"""

from . import health
from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      get_registry)
from .trace import SpanTracer, configure, get_tracer, profile_window

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "SpanTracer",
    "configure",
    "get_registry",
    "get_tracer",
    "health",
    "profile_window",
]
