"""Deterministic fault-injection harness for the serving runtime (chaos).

Mirage's premise is surviving analog imperfection; proving the runtime
*reacts* correctly needs reproducible imperfection. This module gives the
serving engine named **fault sites** driven by a seeded per-tick
:class:`FaultSchedule`, so a chaos run replays bit-identically:

  device-side (enter the compiled step as traced control operands through
  ``analog.channel.fault_scope`` — no recompilation per fault):
    ``snr_drop``       scale the detector noise sigma (an SNR collapse of
                       ``20*log10(scale)`` dB; needs a stochastic base
                       channel, i.e. ``policy.snr_db`` set)
    ``burst_storm``    add correlated burst errors at ``rate``/``width``
                       on top of the configured channel
    ``stuck_channel``  clamp residue channel ``channel`` to ``level``
                       after the detector stage (a dead/pegged detector)

  host-side (applied between ticks, never inside jit):
    ``pool_exhaustion``  quarantine ``blocks`` free KV blocks
                         (:meth:`BlockAllocator.quarantine`) so admission
                         and decode growth hit the real exhaustion paths
    ``worker_crash``     make the prefill pipeline worker raise on the
                         next job it picks up (once per scheduled tick)
    ``host_corruption``  flip sampled tokens in the device->host payload
                         to out-of-vocab garbage at ``rate`` (a corrupted
                         transfer the engine must detect and retry)

A schedule is a list of :class:`FaultEvent` windows ``[start, stop)`` in
engine decode-tick units, or the compact string form used by the CLI::

    snr_drop@4:12:scale=30;worker_crash@2;pool_exhaustion@3:9:blocks=16

Overlapping channel events compose: sigma scales multiply, burst rates
add (width takes the max), stuck masks union. Everything host-side draws
from ``numpy`` generators seeded by ``(seed, site, tick)`` — independent
of the engine's device RNG streams, which stay untouched.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

SITES = ("snr_drop", "burst_storm", "stuck_channel",
         "pool_exhaustion", "worker_crash", "host_corruption")

# per-site recognized params and their defaults
_PARAMS = {
    "snr_drop": {"scale": 10.0},
    "burst_storm": {"rate": 0.05, "width": 2},
    "stuck_channel": {"channel": 0, "level": 0},
    "pool_exhaustion": {"blocks": 8},
    "worker_crash": {},
    "host_corruption": {"rate": 0.25},
}


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One fault window: ``site`` active on ticks ``[start, stop)``."""

    site: str
    start: int
    stop: int
    params: Dict[str, float] = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        if self.site not in SITES:
            raise ValueError(f"unknown fault site {self.site!r} "
                             f"(expected one of {SITES})")
        if not 0 <= self.start < self.stop:
            raise ValueError(f"bad window [{self.start}, {self.stop}) "
                             f"for {self.site}")
        unknown = set(self.params) - set(_PARAMS[self.site])
        if unknown:
            raise ValueError(f"{self.site}: unknown params {sorted(unknown)} "
                             f"(expected {sorted(_PARAMS[self.site])})")

    def active(self, tick: int) -> bool:
        return self.start <= tick < self.stop

    def get(self, name: str):
        return self.params.get(name, _PARAMS[self.site][name])


class FaultSchedule:
    """An ordered set of fault windows, parseable from the compact CLI
    string (see module docstring). Empty schedules are valid (a chaos
    harness that injects nothing is the identity engine)."""

    def __init__(self, events: Sequence[FaultEvent] = ()):
        self.events: List[FaultEvent] = list(events)

    def __len__(self) -> int:
        return len(self.events)

    def __iter__(self):
        return iter(self.events)

    @property
    def horizon(self) -> int:
        """First tick with no scheduled fault at or after it."""
        return max((e.stop for e in self.events), default=0)

    def sites(self) -> set:
        return {e.site for e in self.events}

    def active(self, site: str, tick: int) -> List[FaultEvent]:
        return [e for e in self.events
                if e.site == site and e.active(tick)]

    @classmethod
    def parse(cls, spec: str) -> "FaultSchedule":
        """``site@start[:stop][:k=v[,k=v...]][;...]`` — stop defaults to
        ``start + 1`` (a one-tick event)."""
        events = []
        for part in filter(None, (p.strip() for p in spec.split(";"))):
            if "@" not in part:
                raise ValueError(f"bad fault spec {part!r}: expected "
                                 f"site@start[:stop][:k=v,...]")
            site, rest = part.split("@", 1)
            fields = rest.split(":")
            start = int(fields[0])
            stop, params = start + 1, {}
            for f in fields[1:]:
                if "=" in f:
                    for kv in filter(None, f.split(",")):
                        k, v = kv.split("=")
                        params[k.strip()] = float(v)
                else:
                    stop = int(f)
            events.append(FaultEvent(site=site.strip(), start=start,
                                     stop=stop, params=params))
        return cls(events)

    def describe(self) -> str:
        return "; ".join(
            f"{e.site}@[{e.start},{e.stop})"
            + (f" {e.params}" if e.params else "")
            for e in self.events) or "(empty)"


class FaultInjector:
    """Evaluates a :class:`FaultSchedule` against the engine's decode-tick
    clock and hands each fault site its per-tick controls.

    The engine (``LMServer(..., fault_injector=...)``) owns the clock and
    calls:

      * :meth:`controls` once per compiled step launch — returns the
        traced channel-control pytree (identity when no channel fault is
        active this tick);
      * :meth:`pool_squeeze` / :meth:`worker_crash` between ticks;
      * :meth:`corrupt_tokens` on every device->host token payload.

    ``log`` accumulates one line per state change so chaos runs are
    auditable; deterministic for a given (schedule, seed).
    """

    def __init__(self, schedule: FaultSchedule, seed: int = 0):
        self.schedule = schedule
        self.seed = int(seed)
        self.log: List[str] = []
        self._crashed_at: set = set()
        self._last_active: Dict[str, bool] = {s: False for s in SITES}

    # -- bookkeeping -----------------------------------------------------

    def _note_transitions(self, tick: int) -> None:
        for site in SITES:
            now = bool(self.schedule.active(site, tick))
            if now != self._last_active[site]:
                self.log.append(
                    f"tick {tick}: {site} "
                    f"{'enters' if now else 'leaves'} window")
                self._last_active[site] = now

    def _rng(self, site: str, tick: int) -> np.random.Generator:
        return np.random.default_rng(
            (self.seed * 0x9E3779B1 + SITES.index(site) * 7919 + tick)
            % (2 ** 63))

    # -- device-side (channel) sites -------------------------------------

    def channel_faults_scheduled(self) -> bool:
        return bool(self.schedule.sites()
                    & {"snr_drop", "burst_storm", "stuck_channel"})

    def controls(self, tick: int, n_moduli: int) -> Dict[str, np.ndarray]:
        """The channel fault-control pytree for ``tick`` — identity values
        when nothing is active, so the compiled step is reusable and
        bit-identical to the unfaulted engine."""
        self._note_transitions(tick)
        sigma_scale = 1.0
        for e in self.schedule.active("snr_drop", tick):
            sigma_scale *= float(e.get("scale"))
        burst_rate, burst_width = 0.0, 1
        for e in self.schedule.active("burst_storm", tick):
            burst_rate += float(e.get("rate"))
            burst_width = max(burst_width, int(e.get("width")))
        stuck_mask = np.zeros((n_moduli,), np.bool_)
        stuck_level = np.zeros((n_moduli,), np.int32)
        for e in self.schedule.active("stuck_channel", tick):
            ch = int(e.get("channel"))
            if 0 <= ch < n_moduli:
                stuck_mask[ch] = True
                stuck_level[ch] = int(e.get("level"))
        return {
            "sigma_scale": np.float32(sigma_scale),
            "burst_rate": np.float32(burst_rate),
            "burst_width": np.int32(burst_width),
            "stuck_mask": stuck_mask,
            "stuck_level": stuck_level,
        }

    # -- host-side sites -------------------------------------------------

    def pool_squeeze(self, tick: int) -> int:
        """Number of KV blocks that should be held in quarantine at
        ``tick`` (the engine applies the delta vs its current hold)."""
        return sum(int(e.get("blocks"))
                   for e in self.schedule.active("pool_exhaustion", tick))

    def worker_crash(self, tick: int) -> bool:
        """True exactly once per scheduled crash tick: the next prefill
        job the pipeline worker picks up must raise."""
        for e in self.schedule.active("worker_crash", tick):
            if e.start not in self._crashed_at:
                self._crashed_at.add(e.start)
                self.log.append(f"tick {tick}: worker_crash fired")
                return True
        return False

    def corrupt_tokens(self, tick: int, tokens: np.ndarray,
                       vocab_size: int) -> np.ndarray:
        """Maybe corrupt a device->host sampled-token payload: each entry
        flips to out-of-vocab garbage with the scheduled rate (seeded by
        (seed, tick) — replays identically). Returns ``tokens`` untouched
        when no window is active."""
        rate = sum(float(e.get("rate"))
                   for e in self.schedule.active("host_corruption", tick))
        if rate <= 0 or tokens.size == 0:
            return tokens
        rng = self._rng("host_corruption", tick)
        hit = rng.random(tokens.shape) < min(rate, 1.0)
        if not hit.any():
            return tokens
        out = tokens.copy()
        out[hit] = vocab_size + rng.integers(1, 2 ** 20, int(hit.sum()))
        self.log.append(
            f"tick {tick}: host_corruption flipped {int(hit.sum())} token(s)")
        return out
