"""SNR-adaptive degradation: a health-driven guardian over the serving engine.

Mirage's RRNS redundancy buys a fixed correction radius (``r`` redundant
moduli correct ``floor(r/2)`` residue errors per output). When the analog
channel degrades past that radius — an SNR collapse, a burst storm, a
stuck detector — the winning reconstruction is no longer certified by
enough consistent moduli, and the PR-7 health counters
(``rrns_uncorrected``: winners beyond the correction radius) say so in
real time. This module turns those counters into an automatic response:

  **verify-before-commit windows.** The guardian advances the engine in
  windows of ``window`` ticks. Before each window it takes a
  crash-consistent :meth:`LMServer.snapshot` and silences token streaming;
  after the window it reads the uncorrected-fault delta. A clean window
  (delta <= ``threshold``) COMMITS: the buffered tokens stream out and the
  engine keeps its state. A dirty window ROLLS BACK to the snapshot,
  escalates one rung on the degradation ladder and REPLAYS the same
  window under the stronger code — so no token produced by an
  uncorrectable computation is ever streamed. ``rrns_uncorrected == 0``
  over a window is a certificate that every decode in it was consistent
  with at least ``n_total - floor(r/2)`` moduli — inside the correction
  radius, hence exactly repaired — which is why committed streams under
  a mid-run SNR collapse are exactly the clean-backend streams.

  **the degradation ladder.** Escalation reprograms the engine via
  :meth:`LMServer.switch_backend` — stationary residues are re-encoded
  from the raw fp32 params under the new policy:

      mirage_rrns r=2  ->  mirage_rrns r=4  ->  fp32 (hard fallback)

  (``r`` = redundant moduli; ``default_redundant_moduli(k, r)`` picks the
  first ``r`` primes above ``2^k + 1``.) The fp32 rung has no analog
  channel, so its windows are always clean — the ladder terminates.

  **cooldown recovery.** After ``cooldown`` consecutive committed windows
  above the base rung, the guardian probes one rung DOWN. A premature
  probe is safe: the probe window verifies like any other, so a
  still-degraded channel just rolls the probe back and re-escalates —
  no unverified token escapes during recovery either.

Requirements: a ``mirage_rrns`` base policy, ``instrument=True`` (the
health counters drive everything) and no pipelined prefill (each window
boundary needs a quiescent, snapshottable engine).
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.runtime.server import LMServer, Request


def degradation_ladder(policy, max_r: int = 4) -> List:
    """Escalation rungs for ``policy`` (mode ``mirage_rrns``): the policy
    itself, stronger-RRNS variants stepping the redundant-moduli count by
    2 (each step buys one more correctable error per output) up to
    ``max_r``, then the fp32 hard fallback."""
    if policy.mode != "mirage_rrns":
        raise ValueError(
            f"the degradation ladder starts from mode='mirage_rrns' "
            f"(got {policy.mode!r}); other modes have no redundancy to "
            f"escalate")
    from repro.analog.rrns import default_redundant_moduli
    rungs = [policy]
    r = len(policy.redundant_moduli) or 2
    while r < max_r:
        r = min(max_r, r + 2)
        rungs.append(dataclasses.replace(
            policy,
            redundant_moduli=default_redundant_moduli(policy.k, r)))
    rungs.append(dataclasses.replace(policy, mode="fp32"))
    return rungs


def _rung_name(policy) -> str:
    if policy.mode == "fp32":
        return "fp32"
    return f"{policy.mode}[r={len(policy.redundant_moduli) or 2}]"


class SNRGuardian:
    """Drives an :class:`LMServer` through verify-before-commit windows
    (see module docstring). Use :meth:`run_until_drained` in place of the
    engine's own, or :meth:`run_window` from a custom serving loop.

    ``transitions`` logs every escalation / recovery probe (one line
    each) — the chaos-smoke CI asserts on it; ``level`` is the current
    ladder rung (0 = base policy).
    """

    def __init__(self, server: LMServer, window: int = 4,
                 threshold: int = 0, cooldown: int = 3, max_r: int = 4):
        if server._pipe is not None:
            raise ValueError(
                "the guardian snapshots at window boundaries; pipelined "
                "prefill keeps compute in flight across them — run with "
                "pipeline_depth=0")
        if not server.instrument:
            raise ValueError("the guardian is driven by the analog-health "
                             "counters; build the engine with "
                             "instrument=True")
        self.server = server
        self.ladder = degradation_ladder(server.model.policy, max_r=max_r)
        self.level = 0
        self.window = int(window)
        self.threshold = int(threshold)
        self.cooldown = int(cooldown)
        self.transitions: List[str] = []
        self._clean_windows = 0

    # -- health reading --------------------------------------------------

    def _uncorrected(self) -> int:
        v = self.server.health_snapshot().get("rrns_uncorrected", 0)
        return int(sum(v)) if isinstance(v, list) else int(v)

    def _live_requests(self) -> Dict[int, Request]:
        srv = self.server
        live: Dict[int, Request] = {}
        for r in list(srv.scheduler.waiting) + \
                [e["req"] for e in srv.prefilling] + \
                [x for x in srv.slot_req if x is not None]:
            live[r.rid] = r
        return live

    # -- the verify-before-commit window ---------------------------------

    def run_window(self) -> List[Request]:
        """One guarded window: snapshot, run ``window`` ticks with token
        streaming held back, then commit (stream + return retirements) or
        roll back + escalate (returns [] — the same work replays under
        the stronger rung on the next call)."""
        srv = self.server
        sched = srv.scheduler
        live = self._live_requests()
        snap = srv.snapshot()
        pre_lens = {rid: len(d["tokens_out"])
                    for rid, d in snap["requests"].items()}
        pre_unc = self._uncorrected()
        on_token = sched.on_token
        sched.on_token = None
        retired: List[Request] = []
        try:
            for _ in range(self.window):
                retired.extend(srv.tick())
                if not sched.waiting and \
                        all(r is None for r in srv.slot_req):
                    break
        finally:
            sched.on_token = on_token
        delta = self._uncorrected() - pre_unc
        if delta > self.threshold and self.level + 1 < len(self.ladder):
            srv.restore(snap, requests=live)
            self.level += 1
            srv.switch_backend(self.ladder[self.level])
            self.transitions.append(
                f"tick {snap['counters']['tick']}: {delta} uncorrected in "
                f"window -> escalate to {_rung_name(self.ladder[self.level])}")
            self._clean_windows = 0
            return []
        # commit: release the window's tokens in emission order
        if on_token is not None:
            for rid, n0 in pre_lens.items():
                r = live[rid]
                for tok in r.tokens_out[n0:]:
                    on_token(r, tok)
        if delta > self.threshold:
            # already at the last rung (fp32 never gets here: it has no
            # channel): nothing stronger exists, so the window stands
            self.transitions.append(
                f"tick {snap['counters']['tick']}: {delta} uncorrected at "
                f"final rung {_rung_name(self.ladder[self.level])} — "
                f"committing anyway")
            self._clean_windows = 0
            return retired
        self._clean_windows += 1
        if self.level > 0 and self._clean_windows >= self.cooldown:
            self.level -= 1
            srv.switch_backend(self.ladder[self.level])
            self.transitions.append(
                f"tick {srv._tick_count}: {self._clean_windows} clean "
                f"windows -> probe down to "
                f"{_rung_name(self.ladder[self.level])}")
            self._clean_windows = 0
        return retired

    def run_until_drained(self, max_windows: int = 2_500) -> List[Request]:
        """Drain the engine under guardianship. Progress is guaranteed:
        the ladder is finite and its last rung (fp32) always verifies, so
        every window eventually commits."""
        srv = self.server
        out: List[Request] = []
        for _ in range(max_windows):
            if not srv.scheduler.waiting and \
                    all(r is None for r in srv.slot_req):
                break
            out.extend(self.run_window())
        return out
