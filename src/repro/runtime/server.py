"""Serving runtime: a continuous-batching engine over a stacked slot cache.

The paper applies Mirage to forward-only inference (Section V-D); the
production question is how to serve it. The engine here is built around
three invariants:

  * **one jitted decode step per tick** over a stacked ``(slots, ...)``
    cache pytree with a per-slot position vector (``cache["idx"]``) and an
    active-slot mask — occupancy raises throughput instead of multiplying
    per-slot dispatches;
  * **device-side selection and retirement**: greedy/sampled next tokens,
    EOS and max-token masks are all computed on device; exactly ONE
    device→host transfer per tick (a packed ``(slots, 2)`` token/done
    array);
  * **bucketed batched prefill**: prompts are right-padded to a small set
    of length buckets (admission groups padded to power-of-two batch
    sizes), so the number of prefill compilations is bounded by
    ``len(buckets) * log2(slots)``; the resulting cache is inserted into
    the live stacked cache with a jitted scatter (``models.lm.cache_insert``),
    never through per-slot Python lists.

Noisy / RRNS serving is first-class: every tick (and every prefill batch)
opens a :func:`repro.core.gemm.noise_key_scope` with a key folded from
``policy.noise_seed`` and the tick counter, so analog-channel backends
(``mirage_rns_noisy`` / ``mirage_rrns``) draw FRESH shot/thermal noise per
decode step while staying fully jitted (the key is a traced input, not a
static policy field — no recompiles).

For RNS-family backends the engine additionally programs every GEMM weight
into **stationary residues** once at admission
(:func:`repro.core.stationary.encode_stationary_params`): BFP quantization,
residue conversion and DAC/drift programming are paid once per server
lifetime instead of once per GEMM per tick — the paper's program-once
MMVMU dataflow. At decode shapes the per-call weight pipeline dominates
the GEMM, so this is the difference between the error-corrected path being
a curiosity and a serving mode. Clean-channel numerics are bit-identical
to the per-call path (parity-tested).

**Paged KV memory** (``cache_layout="paged"``): instead of one dense
``cap``-length ring per slot, KV lives in a global pool of fixed-size
blocks addressed through per-slot block tables
(:mod:`repro.runtime.paging`). Memory scales with the workload's live
token count (rounded up to blocks) instead of ``slots x cap``; blocks are
allocated on demand at admission and during decode, returned to the pool
at retirement, and the device only ever sees jittable arrays (page pools +
an int32 table whose unmapped entries are an OOB sentinel). The dense
layout is retained as the parity oracle — the paged engine is
token-identical under greedy decode.

**Chunked (piggybacked) prefill** (``prefill_chunk=N``, paged only): long
prompts stream through the decode loop N tokens per tick instead of
running one monolithic prefill at admission, so a long arrival no longer
stalls every active decode stream (the TTFT/TPOT spike
``benchmarks/bench_serving.py`` measures). The final chunk emits the first
token; TTFT is stamped only when that token's bytes reach the host.

:class:`PerSlotLMServer` is the seed's slot-at-a-time loop, retained only
as the parity oracle (token-exact vs the batched engine under greedy
decode) and as the benchmark baseline.
"""

from __future__ import annotations

import collections
import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import gemm
from repro.models import lm as lm_helpers
from repro.runtime.paging import blocks_for


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (L,) int32
    max_tokens: int = 32
    eos_id: Optional[int] = None
    tokens_out: List[int] = dataclasses.field(default_factory=list)
    t_enqueue: float = 0.0
    t_admit: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0

    @property
    def queue_time(self) -> float:
        return self.t_admit - self.t_enqueue

    @property
    def ttft(self) -> float:
        return self.t_first_token - self.t_enqueue

    @property
    def tpot(self) -> float:
        """Mean time per output token after the first."""
        n = len(self.tokens_out)
        if n <= 1:
            return 0.0
        return (self.t_done - self.t_first_token) / (n - 1)


def default_buckets(cache_len: int, min_bucket: int = 8) -> Tuple[int, ...]:
    """Power-of-two prompt-length buckets up to the cache capacity."""
    out, b = [], min_bucket
    while b < cache_len:
        out.append(b)
        b *= 2
    out.append(cache_len)
    return tuple(out)


def pick_bucket(length: int, buckets: Sequence[int]) -> int:
    for b in buckets:
        if length <= b:
            return b
    raise ValueError(f"prompt length {length} exceeds largest bucket "
                     f"{buckets[-1]}")


class Scheduler:
    """FCFS admission + retirement bookkeeping + per-request latency metrics.

    The scheduler owns the waiting deque and the host-visible request
    lifecycle (enqueue → admit → stream tokens → retire); the engine owns
    the device state. ``on_token`` is the streaming hook: called once per
    materialized token, in emission order.
    """

    def __init__(self, on_token: Optional[Callable[[Request, int], None]] = None):
        self.waiting: collections.deque[Request] = collections.deque()
        self.finished: List[Request] = []
        self.on_token = on_token
        self.metrics: Dict[str, Any] = {
            "completed": 0, "tokens": 0, "ticks": 0,
            "admitted": 0, "prefill_batches": 0,
            # chunked prefill: total chunk steps run, and the gauge of
            # requests admitted but still streaming their prompt (these are
            # no longer "waiting" yet hold a slot — queue accounting must
            # count them or occupancy reads wrong)
            "prefill_chunks": 0, "prefilling": 0,
        }

    def submit(self, req: Request) -> None:
        req.t_enqueue = time.perf_counter()
        self.waiting.append(req)

    def take(self, n: int) -> List[Request]:
        """Pop up to ``n`` requests in FCFS order for admission."""
        out = []
        while self.waiting and len(out) < n:
            out.append(self.waiting.popleft())
        return out

    def record_admit(self, reqs: Sequence[Request]) -> None:
        t = time.perf_counter()
        for r in reqs:
            r.t_admit = t
        self.metrics["admitted"] += len(reqs)
        self.metrics["prefill_batches"] += 1

    def emit(self, req: Request, tok: int) -> None:
        req.tokens_out.append(tok)
        if self.on_token is not None:
            self.on_token(req, tok)

    def retire(self, req: Request) -> Request:
        req.t_done = time.perf_counter()
        self.metrics["completed"] += 1
        self.metrics["tokens"] += len(req.tokens_out)
        self.finished.append(req)
        return req

    def latency_summary(self) -> Dict[str, float]:
        done = self.finished
        if not done:
            return {"ttft_mean_s": 0.0, "tpot_mean_s": 0.0,
                    "queue_mean_s": 0.0}
        return {
            "ttft_mean_s": float(np.mean([r.ttft for r in done])),
            "tpot_mean_s": float(np.mean([r.tpot for r in done])),
            "queue_mean_s": float(np.mean([r.queue_time for r in done])),
        }


class LMServer:
    """Continuous-batching serving engine (the deployment path).

    Device state is one pytree::

        {"cache":   stacked cache, per-slot ``idx`` (see lm.cache_spec),
         "last_tok": (S,) int32   last emitted token per slot,
         "active":   (S,) bool    slot occupancy mask,
         "emitted":  (S,) int32   tokens emitted per slot,
         "eos":      (S,) int32   per-slot EOS id (-1 = none),
         "max_tok":  (S,) int32   per-slot token budget}

    ``tick()`` = admit (bucketed batched prefill + jitted scatter insert)
    then one jitted decode step for every slot at once.
    """

    def __init__(self, model, params, cap: int, batch_slots: int = 8,
                 greedy: bool = True,
                 buckets: Optional[Sequence[int]] = None,
                 on_token: Optional[Callable[[Request, int], None]] = None,
                 scheduler: Optional[Scheduler] = None,
                 sample_seed: int = 0,
                 stationary_weights: Optional[bool] = None,
                 cache_layout: str = "dense",
                 block_size: int = 16,
                 n_blocks: Optional[int] = None,
                 prefill_chunk: Optional[int] = None):
        self.model = model
        self.params = params
        self.cap = cap
        self.greedy = greedy
        self.n_slots = batch_slots
        cfg = model.cfg
        self.cache_len = min(cap, cfg.sliding_window or cap)
        if cache_layout not in ("dense", "paged"):
            raise ValueError(f"unknown cache_layout {cache_layout!r}")
        if prefill_chunk is not None and cache_layout != "paged":
            raise ValueError(
                "prefill_chunk requires cache_layout='paged' (chunk steps "
                "scatter through block tables with linear addressing; the "
                "dense ring keeps whole-prompt bucketed prefill)")
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, got {prefill_chunk}")
        self.cache_layout = cache_layout
        self.block_size = block_size
        self.prefill_chunk = prefill_chunk
        # pure-SSM models have no KV to page (recurrent state is O(1) per
        # slot and stays dense under both layouts) — no pool, no tables
        has_pages = not (model.kind == "mamba" and not cfg.attn_every)
        if cache_layout == "paged" and has_pages:
            from repro.runtime.paging import BlockAllocator
            mb = blocks_for(cap, block_size)
            # default pool = slots * ceil(cap/bs): no memory saving but never
            # exhausts; pass a smaller n_blocks (sized to the live-token
            # budget of the workload) to realize the paged win
            self.alloc: Optional["BlockAllocator"] = BlockAllocator(
                n_blocks if n_blocks is not None else batch_slots * mb,
                block_size, batch_slots, mb)
        else:
            self.alloc = None
        # chunked-prefill in-flight entries: {"req", "slot", "pos"}
        self.prefilling: List[Dict[str, Any]] = []
        self._slot_pos = [0] * batch_slots   # host mirror of each slot's idx
        # lifetime block reservation per occupied slot (see _free_budget)
        self._slot_budget = [0] * batch_slots
        # SSM/hybrid recurrences carry state through padded steps, so those
        # families bucket by EXACT prompt length (still batched across
        # same-length prompts); attention families right-pad to buckets.
        self.pad_prefill = model.kind != "mamba"
        self.buckets = tuple(sorted(buckets)) if buckets else \
            default_buckets(self.cache_len)
        if self.buckets[-1] > self.cache_len:
            raise ValueError(f"bucket {self.buckets[-1]} exceeds cache "
                             f"capacity {self.cache_len}")
        self.scheduler = scheduler or Scheduler(on_token=on_token)
        self.slot_req: List[Optional[Request]] = [None] * batch_slots

        seed = model.policy.noise_seed if model.policy.noise_seed is not None \
            else 0
        # distinct streams: fold(base, 0) -> decode ticks, fold(base, 1) ->
        # prefill batches, fold(base, 2) -> prefill chunks; each then folds
        # its own counter per event
        self._noise_base = jax.random.PRNGKey(seed)
        self._sample_base = jax.random.PRNGKey(sample_seed)
        self._tick_count = 0
        self._prefill_count = 0
        self._chunk_count = 0

        # program-once weight admission: RNS-family backends execute against
        # pre-encoded stationary residues. Auto-on for model families whose
        # GEMM weights all flow through `dense` (the merged parallel
        # projection concatenates raw weight arrays; MoE experts cross a
        # shard_map boundary with positional specs — both keep per-call
        # encoding). Force with stationary_weights=True/False.
        if stationary_weights is None:
            from repro.core import backends as _backends
            stationary_weights = (
                _backends.resolve(model.policy).supports_stationary_residues
                and getattr(model, "kind", None) in ("attn_mlp", "mamba")
                and not getattr(model.opt, "merge_parallel_proj", False))
        self.stationary_weights = bool(stationary_weights)
        if self.stationary_weights:
            from repro.core import stationary
            self._exec_params = stationary.encode_stationary_params(
                params, model.policy)
        else:
            self._exec_params = params

        self.state = self._init_state(batch_slots)
        self._decode_tick = jax.jit(self._make_tick_fn())
        self._prefill_insert = jax.jit(self._make_prefill_fn())
        if self.prefill_chunk is not None:
            mid, last = self._make_chunk_fns()
            self._chunk_mid = jax.jit(mid)
            self._chunk_last = jax.jit(last)

    # ------------------------------------------------------------------
    # device-side step functions
    # ------------------------------------------------------------------

    def _init_state(self, n_slots: int) -> Dict[str, Any]:
        if self.cache_layout == "paged" and self.alloc is not None:
            cache = self.model.init_cache(
                n_slots, self.cap, per_slot_idx=True, layout="paged",
                block_size=self.block_size, n_blocks=self.alloc.n_blocks)
        else:
            cache = self.model.init_cache(n_slots, self.cap,
                                          per_slot_idx=True)
        return {
            "cache": cache,
            "last_tok": jnp.zeros((n_slots,), jnp.int32),
            "active": jnp.zeros((n_slots,), bool),
            "emitted": jnp.zeros((n_slots,), jnp.int32),
            "eos": jnp.full((n_slots,), -1, jnp.int32),
            "max_tok": jnp.zeros((n_slots,), jnp.int32),
        }

    def _sync_tables(self) -> None:
        """Mirror the allocator's block tables to the device cache leaf
        (lazily — only after alloc/free/remap changed them)."""
        if self.alloc is not None and self.alloc.dirty:
            self.state["cache"]["bt"] = jnp.asarray(self.alloc.tables)
            self.alloc.dirty = False

    def _make_tick_fn(self):
        model, greedy = self.model, self.greedy

        def tick(params, state, noise_key, sample_key):
            cache0 = state["cache"]
            idx0 = cache0["idx"]
            with gemm.noise_key_scope(noise_key):
                logits, cache = model.decode_step(
                    params, cache0, state["last_tok"][:, None])
            logits = logits[:, -1, :]
            if greedy:
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            else:
                tok = jax.random.categorical(sample_key, logits
                                             ).astype(jnp.int32)
            active = state["active"]
            emitted = state["emitted"] + active.astype(jnp.int32)
            hit_eos = (state["eos"] >= 0) & (tok == state["eos"])
            done = active & (hit_eos | (emitted >= state["max_tok"]))
            # inactive slots don't advance their position (their k/v writes
            # land on a frozen slot / a dropped page and are overwritten on
            # reuse), and their SSM recurrent state stays frozen — a slot
            # mid-chunked-prefill carries real state between chunks that the
            # engine-wide step must not perturb
            cache = dict(cache, idx=jnp.where(active, cache["idx"], idx0))
            for leaf in ("ssm", "conv"):
                if leaf in cache:
                    m = active.reshape((1, -1) + (1,) * (cache[leaf].ndim - 2))
                    cache[leaf] = jnp.where(m, cache[leaf], cache0[leaf])
            new_state = dict(
                state,
                cache=cache,
                last_tok=jnp.where(active, tok, state["last_tok"]),
                active=active & ~done,
                emitted=emitted,
            )
            # the tick's single device->host payload: (S, 2) [token|-1, done]
            payload = jnp.stack(
                [jnp.where(active, tok, -1), done.astype(jnp.int32)], axis=-1)
            return new_state, payload

        return tick

    def _make_prefill_fn(self):
        model, cap, greedy = self.model, self.cap, self.greedy

        def prefill_insert(params, state, tokens, lens, slots, eos, max_tok,
                           noise_key, sample_key):
            with gemm.noise_key_scope(noise_key):
                logits, new_cache = model.prefill(params, tokens, cap,
                                                  lens=lens)
            logits = logits[:, -1, :]
            if greedy:
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            else:
                tok = jax.random.categorical(sample_key, logits
                                             ).astype(jnp.int32)
            # instant retirement: the prefill token already hit EOS or the
            # whole budget was one token — never occupy a slot
            done0 = ((eos >= 0) & (tok == eos)) | (max_tok <= 1)
            state = dict(
                state,
                cache=lm_helpers.cache_insert(state["cache"], new_cache,
                                              slots),
                last_tok=state["last_tok"].at[slots].set(tok, mode="drop"),
                active=state["active"].at[slots].set(~done0, mode="drop"),
                emitted=state["emitted"].at[slots].set(1, mode="drop"),
                eos=state["eos"].at[slots].set(eos, mode="drop"),
                max_tok=state["max_tok"].at[slots].set(max_tok, mode="drop"),
            )
            payload = jnp.stack([tok, done0.astype(jnp.int32)], axis=-1)
            return state, payload

        return prefill_insert

    def _make_chunk_fns(self):
        """Jitted chunk steps for piggybacked prefill. ``slot``/``pos0``/
        ``true_len`` (and eos/max_tok) are traced scalars, so ONE compile
        serves every chunk of every request (SSM/hybrid additionally compile
        once per distinct final-chunk length — exact-length chunking, same
        reason as the exact-length prefill buckets)."""
        model, greedy = self.model, self.greedy

        def chunk_mid(params, state, tokens, slot, pos0, true_len, noise_key):
            with gemm.noise_key_scope(noise_key):
                _, cache = model.prefill_chunk(
                    params, state["cache"], tokens, slot, pos0, true_len)
            return dict(state, cache=cache)

        def chunk_last(params, state, tokens, slot, pos0, true_len, eos,
                       max_tok, noise_key, sample_key):
            with gemm.noise_key_scope(noise_key):
                logits, cache = model.prefill_chunk(
                    params, state["cache"], tokens, slot, pos0, true_len)
            logits = logits[:, -1, :]
            if greedy:
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            else:
                tok = jax.random.categorical(sample_key, logits
                                             ).astype(jnp.int32)
            done0 = ((eos >= 0) & (tok[0] == eos)) | (max_tok <= 1)
            state = dict(
                state, cache=cache,
                last_tok=state["last_tok"].at[slot].set(tok[0]),
                active=state["active"].at[slot].set(~done0),
                emitted=state["emitted"].at[slot].set(1),
                eos=state["eos"].at[slot].set(eos),
                max_tok=state["max_tok"].at[slot].set(max_tok),
            )
            payload = jnp.stack(
                [tok, jnp.reshape(done0, (1,)).astype(jnp.int32)], axis=-1)
            return state, payload

        return chunk_mid, chunk_last

    def _next_keys(self, stream: int, count: int):
        noise = jax.random.fold_in(
            jax.random.fold_in(self._noise_base, stream), count)
        sample = jax.random.fold_in(
            jax.random.fold_in(self._sample_base, stream), count)
        return noise, sample

    # ------------------------------------------------------------------
    # host-side loop
    # ------------------------------------------------------------------

    def submit(self, req: Request) -> None:
        # chunked prefill streams arbitrarily long prompts through the paged
        # cache (up to its linear capacity); bucketed prefill is bounded by
        # the largest bucket
        limit = self.cap if self.prefill_chunk else self.buckets[-1]
        if len(req.prompt) > limit:
            raise ValueError(
                f"request {req.rid}: prompt length {len(req.prompt)} exceeds "
                + (f"cache capacity {limit}" if self.prefill_chunk else
                   f"largest bucket {limit}"))
        if self.alloc is not None:
            # paged addressing is linear — it cannot ring-wrap like the
            # dense layout, so a lifetime that outgrows the table capacity
            # would silently drop its own recent KV. Reject loudly.
            capacity = self.alloc.max_blocks_per_slot * self.block_size
            if len(req.prompt) + req.max_tokens > capacity:
                raise ValueError(
                    f"request {req.rid}: prompt {len(req.prompt)} + "
                    f"max_tokens {req.max_tokens} exceeds the paged cache's "
                    f"linear capacity {capacity}; raise cap or lower "
                    f"max_tokens")
            # and a lifetime block budget exceeding the whole pool could
            # never be admitted — reject instead of livelocking the FCFS
            # queue behind an unsatisfiable head-of-line wait
            if self._block_budget(req) > self.alloc.n_blocks:
                raise ValueError(
                    f"request {req.rid}: prompt {len(req.prompt)} + "
                    f"max_tokens {req.max_tokens} needs "
                    f"{self._block_budget(req)} blocks of {self.block_size} "
                    f"but the pool holds {self.alloc.n_blocks}; grow "
                    f"n_blocks")
        self.scheduler.submit(req)

    def _bucket(self, length: int) -> int:
        return pick_bucket(length, self.buckets) if self.pad_prefill \
            else length

    def _block_budget(self, req: Request) -> int:
        """Blocks a request needs over its whole lifetime: prompt plus
        decode growth up to ``max_tokens`` (``submit`` bounds this by the
        per-slot table capacity)."""
        return blocks_for(len(req.prompt) + req.max_tokens, self.block_size)

    def _free_budget(self) -> int:
        """Pool blocks neither allocated nor RESERVED for the future decode
        growth of already-admitted requests. Admission gates on this — not
        on the raw free count — so a tight pool serializes admissions
        instead of exhausting mid-decode (ensure() would raise out of
        ``tick()`` and kill every in-flight stream)."""
        reserved = sum(
            max(0, self._slot_budget[i] - int(self.alloc.n_owned[i]))
            for i, r in enumerate(self.slot_req) if r is not None)
        return self.alloc.free_count - reserved

    def _take_admissible(self, n: int) -> List[Request]:
        """Pop up to ``n`` waiting requests FCFS. Under the paged layout,
        stop at the first whose lifetime block budget cannot be reserved
        (head-of-line admission keeps FCFS order; blocked work waits for
        retirements to free blocks)."""
        if self.alloc is None:
            return self.scheduler.take(n)
        out, budget = [], self._free_budget()
        while self.scheduler.waiting and len(out) < n:
            need = self._block_budget(self.scheduler.waiting[0])
            if need > budget:
                break
            budget -= need
            out.append(self.scheduler.waiting.popleft())
        return out

    def _admit(self) -> List[Request]:
        """Admit waiting requests into free slots (bucketed batched
        prefill, or chunked prefill when ``prefill_chunk`` is set). Returns
        requests retired AT admission (prefill token was EOS / one-token
        budget) — their slots are immediately reusable, so the loop keeps
        admitting while slots free up and work waits."""
        if self.prefill_chunk is not None:
            return self._admit_chunked()
        retired: List[Request] = []
        while True:
            free = [i for i, r in enumerate(self.slot_req) if r is None]
            if not free or not self.scheduler.waiting:
                return retired
            reqs = self._take_admissible(len(free))
            if not reqs:
                return retired
            groups: Dict[int, List[Request]] = {}
            for r in reqs:
                groups.setdefault(self._bucket(len(r.prompt)), []).append(r)
            for Lb, group in sorted(groups.items()):
                B = len(group)
                Bp = 1 << (B - 1).bit_length()      # pad batch to a pow2
                tokens = np.zeros((Bp, Lb), np.int32)
                lens = np.ones((Bp,), np.int32)
                slots = np.full((Bp,), self.n_slots, np.int32)  # OOB = drop
                eos = np.full((Bp,), -1, np.int32)
                max_tok = np.ones((Bp,), np.int32)
                my_slots = []
                for j, r in enumerate(group):
                    tokens[j, :len(r.prompt)] = r.prompt
                    lens[j] = len(r.prompt)
                    slots[j] = free.pop(0)
                    my_slots.append(int(slots[j]))
                    eos[j] = -1 if r.eos_id is None else r.eos_id
                    max_tok[j] = r.max_tokens
                    if self.alloc is not None:
                        # reserved by _take_admissible: cannot fail
                        self.alloc.ensure(my_slots[j], len(r.prompt))
                        self._slot_budget[my_slots[j]] = self._block_budget(r)
                self.scheduler.record_admit(group)
                self._sync_tables()
                nk, sk = self._next_keys(1, self._prefill_count)
                self._prefill_count += 1
                self.state, payload = self._prefill_insert(
                    self._exec_params, self.state, jnp.asarray(tokens),
                    jnp.asarray(lens), jnp.asarray(slots), jnp.asarray(eos),
                    jnp.asarray(max_tok), nk, sk)
                # TTFT is stamped only once the token bytes are on host
                payload = np.asarray(jax.device_get(payload))
                t_host = time.perf_counter()
                for j, r in enumerate(group):
                    r.t_first_token = t_host
                    self.scheduler.emit(r, int(payload[j, 0]))
                    if payload[j, 1]:
                        if self.alloc is not None:
                            self.alloc.release(my_slots[j])
                        retired.append(self.scheduler.retire(r))
                    else:
                        self.slot_req[my_slots[j]] = r
                        self._slot_pos[my_slots[j]] = len(r.prompt)

    def _admit_chunked(self) -> List[Request]:
        """Chunked (piggybacked) prefill: waiting prompts claim a slot and
        their prompt's blocks up front, then stream through the decode loop
        ONE fixed-size chunk per tick — a long arrival adds one bounded
        chunk step to each tick instead of a whole-prompt prefill stall.
        The final chunk runs device-side token selection; TTFT is stamped
        only when that token materializes on host. Requests retired at the
        final chunk (EOS / one-token budget) free their slot immediately."""
        retired: List[Request] = []
        # claim slots + prompt blocks for as many waiting prompts as fit
        while self.scheduler.waiting:
            free = [i for i, r in enumerate(self.slot_req) if r is None]
            if not free:
                break
            head = self.scheduler.waiting[0]
            if self.alloc is not None and \
                    self._block_budget(head) > self._free_budget():
                break
            req = self.scheduler.waiting.popleft()
            slot = free[0]
            if self.alloc is not None:
                # reserve the lifetime budget but allocate lazily, one
                # chunk's worth at a time — queued prompts must not pin
                # pool blocks they won't write for many ticks
                self._slot_budget[slot] = self._block_budget(req)
            self.slot_req[slot] = req
            self.scheduler.record_admit([req])
            self.prefilling.append({"req": req, "slot": slot, "pos": 0})
        if not self.prefilling:
            return retired
        # one chunk per tick, FCFS entry first (bounded per-tick latency)
        e = self.prefilling[0]
        req, slot, pos = e["req"], e["slot"], e["pos"]
        C = self.prefill_chunk
        take = min(C, len(req.prompt) - pos)
        last = pos + take >= len(req.prompt)
        toks = np.asarray(req.prompt[pos:pos + take], np.int32)[None, :]
        if self.pad_prefill and take < C:
            # attention families right-pad (masked); SSM/hybrid recurrences
            # need exact-length chunks, costing one compile per distinct
            # final-chunk length
            toks = np.pad(toks, ((0, 0), (0, C - take)))
        if self.alloc is not None:
            self.alloc.ensure(slot, pos + take)   # reserved: cannot fail
        self._sync_tables()
        nk, sk = self._next_keys(2, self._chunk_count)
        self._chunk_count += 1
        args = (self._exec_params, self.state, jnp.asarray(toks),
                jnp.asarray(slot, jnp.int32), jnp.asarray(pos, jnp.int32),
                jnp.asarray(take, jnp.int32))
        if not last:
            self.state = self._chunk_mid(*args, nk)
            e["pos"] = pos + take
        else:
            eos = -1 if req.eos_id is None else req.eos_id
            self.state, payload = self._chunk_last(
                *args, jnp.asarray(eos, jnp.int32),
                jnp.asarray(req.max_tokens, jnp.int32), nk, sk)
            payload = np.asarray(jax.device_get(payload))
            req.t_first_token = time.perf_counter()
            self.prefilling.pop(0)
            self._slot_pos[slot] = len(req.prompt)
            self.scheduler.emit(req, int(payload[0, 0]))
            if payload[0, 1]:
                self.slot_req[slot] = None
                if self.alloc is not None:
                    self.alloc.release(slot)
                retired.append(self.scheduler.retire(req))
        self.scheduler.metrics["prefill_chunks"] += 1
        self.scheduler.metrics["prefilling"] = len(self.prefilling)
        return retired

    def tick(self) -> List[Request]:
        """Admit waiting requests (piggybacking one prefill chunk when
        chunked prefill is on), then decode one token for EVERY active slot
        in a single jitted call."""
        done: List[Request] = list(self._admit())
        mid_prefill = {e["slot"] for e in self.prefilling}
        decode_slots = [i for i, r in enumerate(self.slot_req)
                        if r is not None and i not in mid_prefill]
        if decode_slots:
            if self.alloc is not None:
                cap_pos = self.alloc.max_blocks_per_slot * self.block_size
                for i in decode_slots:
                    # this tick writes each slot's token at position
                    # _slot_pos[i]; grow its table on block boundaries
                    # (reserved at admission — cannot exhaust; writes past
                    # the linear capacity drop on device, hence the clamp)
                    self.alloc.ensure(i, min(self._slot_pos[i] + 1, cap_pos))
                self._sync_tables()
            nk, sk = self._next_keys(0, self._tick_count)
            self._tick_count += 1
            self.state, payload = self._decode_tick(
                self._exec_params, self.state, nk, sk)
            payload = np.asarray(jax.device_get(payload))  # the ONE transfer
            for i, (tok, is_done) in enumerate(payload):
                req = self.slot_req[i]
                if req is None or tok < 0:
                    continue
                self._slot_pos[i] += 1
                self.scheduler.emit(req, int(tok))
                if is_done:
                    self.slot_req[i] = None
                    if self.alloc is not None:
                        self.alloc.release(i)
                    done.append(self.scheduler.retire(req))
        self.scheduler.metrics["ticks"] += 1
        if self.prefill_chunk is not None:
            self.scheduler.metrics["prefilling"] = len(self.prefilling)
        return done

    def run_until_drained(self, max_ticks: int = 10_000) -> List[Request]:
        finished: List[Request] = []
        for _ in range(max_ticks):
            if not self.scheduler.waiting and \
                    all(r is None for r in self.slot_req):
                break
            finished.extend(self.tick())
        return finished

    def resize_slots(self, new_slots: int) -> None:
        """Elastic slot-count change mid-flight (scale with offered load).
        Active slots are compacted to the front of the new stacked cache;
        under the paged layout the page POOL is untouched (block ids are
        stable) — only the table rows and allocator bookkeeping move."""
        from repro.runtime.elastic import resize_serving_state
        if self.prefilling:
            raise RuntimeError(
                "cannot resize slots while chunked prefill is in flight")
        keep = [i for i, r in enumerate(self.slot_req) if r is not None]
        if len(keep) > new_slots:
            raise ValueError(
                f"cannot shrink to {new_slots} slots with {len(keep)} active")
        self.state = resize_serving_state(self.model, self.state, self.cap,
                                          new_slots, keep)
        if self.alloc is not None:
            self.alloc.remap_slots(keep, new_slots)
            self._sync_tables()
        self.slot_req = [self.slot_req[i] for i in keep] + \
            [None] * (new_slots - len(keep))
        self._slot_pos = [self._slot_pos[i] for i in keep] + \
            [0] * (new_slots - len(keep))
        self._slot_budget = [self._slot_budget[i] for i in keep] + \
            [0] * (new_slots - len(keep))
        self.n_slots = new_slots

    def resize_block_pool(self, new_n_blocks: int) -> None:
        """Elastic block-pool resize (grow under admission pressure, shrink
        after a long-context burst retires). Live blocks are compacted to
        the front of the new pool, page arrays move with them, and every
        block table is rewritten — live requests keep decoding their exact
        continuations."""
        if self.alloc is None:
            raise RuntimeError(
                "block pool resize requires cache_layout='paged'")
        from repro.runtime.elastic import resize_block_pool
        self.state = resize_block_pool(self.state, self.alloc, new_n_blocks)
        self._sync_tables()

    @property
    def metrics(self) -> Dict[str, Any]:
        return self.scheduler.metrics


class PerSlotLMServer:
    """The seed's slot-at-a-time decode loop — kept ONLY as the parity
    oracle for the batched engine (token-exact under greedy decode) and as
    the baseline of ``benchmarks/bench_serving.py``. Each tick runs one
    batch-1 jitted decode + one host sync per active slot."""

    def __init__(self, model, params, cap: int, batch_slots: int = 8,
                 greedy: bool = True):
        self.model = model
        self.params = params
        self.cap = cap
        self.greedy = greedy
        self.slots: List[Optional[Request]] = [None] * batch_slots
        self.waiting: collections.deque[Request] = collections.deque()
        self._prefill = jax.jit(
            lambda p, toks: model.prefill(p, toks, cap))
        self._decode = jax.jit(model.decode_step)
        self._caches: List[Any] = [None] * batch_slots
        self.metrics = {"completed": 0, "tokens": 0, "ticks": 0}

    def submit(self, req: Request):
        req.t_enqueue = time.perf_counter()
        self.waiting.append(req)

    def _admit(self):
        done = []
        for i in range(len(self.slots)):
            while self.slots[i] is None and self.waiting:
                req = self.waiting.popleft()
                req.t_admit = time.perf_counter()
                logits, cache = self._prefill(
                    self.params, jnp.asarray(req.prompt)[None, :])
                tok = int(jnp.argmax(logits[0, -1]))   # materializes on host
                req.t_first_token = time.perf_counter()
                req.tokens_out.append(tok)
                if (req.eos_id is not None and tok == req.eos_id) or \
                        req.max_tokens <= 1:
                    # retired at admission; the slot stays free
                    req.t_done = time.perf_counter()
                    self.metrics["completed"] += 1
                    self.metrics["tokens"] += len(req.tokens_out)
                    done.append(req)
                    continue
                self.slots[i] = req
                self._caches[i] = cache
        return done

    def _retire(self, i: int):
        req = self.slots[i]
        req.t_done = time.perf_counter()
        self.metrics["completed"] += 1
        self.metrics["tokens"] += len(req.tokens_out)
        self.slots[i] = None
        self._caches[i] = None
        return req

    def tick(self) -> List[Request]:
        """Admit waiting requests, decode one token for each active slot."""
        done = list(self._admit())
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            last = jnp.asarray([[req.tokens_out[-1]]], jnp.int32)
            logits, self._caches[i] = self._decode(
                self.params, self._caches[i], last)
            tok = int(jnp.argmax(logits[0, -1]))
            req.tokens_out.append(tok)
            if (req.eos_id is not None and tok == req.eos_id) or \
                    len(req.tokens_out) >= req.max_tokens:
                done.append(self._retire(i))
        self.metrics["ticks"] += 1
        return done

    def run_until_drained(self, max_ticks: int = 10_000) -> List[Request]:
        finished = []
        for _ in range(max_ticks):
            if not self.waiting and all(s is None for s in self.slots):
                break
            finished.extend(self.tick())
        return finished
