"""Serving runtime: continuous-batching decode loop over prefill/decode steps.

Serving flow (paper Section V-D applies Mirage to inference — forward-only):
  * requests enter a waiting queue;
  * ``prefill`` runs per request (or batched per bucket) and parks the KV/SSM
    cache in the batch slot;
  * ``decode_step`` advances every active slot one token per tick;
  * finished slots (EOS or max_tokens) retire and free capacity.

On real hardware the jitted step functions carry the same in/out shardings
the dry-run proves; the loop itself is host-side Python.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (L,) int32
    max_tokens: int = 32
    eos_id: Optional[int] = None
    tokens_out: List[int] = dataclasses.field(default_factory=list)
    t_enqueue: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0


class LMServer:
    """Single-sequence-slot batched decoder (batch = len(slots))."""

    def __init__(self, model, params, cap: int, batch_slots: int = 8,
                 greedy: bool = True):
        self.model = model
        self.params = params
        self.cap = cap
        self.greedy = greedy
        self.slots: List[Optional[Request]] = [None] * batch_slots
        self.waiting: List[Request] = []
        self._prefill = jax.jit(
            lambda p, toks: model.prefill(p, toks, cap))
        self._decode = jax.jit(model.decode_step)
        self._caches: List[Any] = [None] * batch_slots
        self.metrics = {"completed": 0, "tokens": 0, "ticks": 0}

    def submit(self, req: Request):
        req.t_enqueue = time.perf_counter()
        self.waiting.append(req)

    def _admit(self):
        for i, slot in enumerate(self.slots):
            if slot is None and self.waiting:
                req = self.waiting.pop(0)
                logits, cache = self._prefill(
                    self.params, jnp.asarray(req.prompt)[None, :])
                tok = int(jnp.argmax(logits[0, -1]))
                req.tokens_out.append(tok)
                req.t_first_token = time.perf_counter()
                self.slots[i] = req
                self._caches[i] = cache

    def _retire(self, i: int):
        req = self.slots[i]
        req.t_done = time.perf_counter()
        self.metrics["completed"] += 1
        self.metrics["tokens"] += len(req.tokens_out)
        self.slots[i] = None
        self._caches[i] = None
        return req

    def tick(self) -> List[Request]:
        """Admit waiting requests, decode one token for each active slot."""
        self._admit()
        done = []
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            last = jnp.asarray([[req.tokens_out[-1]]], jnp.int32)
            logits, self._caches[i] = self._decode(
                self.params, self._caches[i], last)
            tok = int(jnp.argmax(logits[0, -1]))
            req.tokens_out.append(tok)
            if (req.eos_id is not None and tok == req.eos_id) or \
                    len(req.tokens_out) >= req.max_tokens:
                done.append(self._retire(i))
        self.metrics["ticks"] += 1
        return done

    def run_until_drained(self, max_ticks: int = 10_000) -> List[Request]:
        finished = []
        for _ in range(max_ticks):
            if not self.waiting and all(s is None for s in self.slots):
                break
            finished.extend(self.tick())
        return finished
