"""Serving runtime: a continuous-batching engine over a stacked slot cache.

The paper applies Mirage to forward-only inference (Section V-D); the
production question is how to serve it. The engine here is built around
three invariants:

  * **one jitted decode step per tick** over a stacked ``(slots, ...)``
    cache pytree with a per-slot position vector (``cache["idx"]``) and an
    active-slot mask — occupancy raises throughput instead of multiplying
    per-slot dispatches;
  * **device-side selection and retirement**: greedy/sampled next tokens,
    EOS and max-token masks are all computed on device; exactly ONE
    device→host transfer per tick (a packed ``(slots, 2)`` token/done
    array);
  * **bucketed batched prefill**: prompts are right-padded to a small set
    of length buckets (admission groups padded to power-of-two batch
    sizes), so the number of prefill compilations is bounded by
    ``len(buckets) * log2(slots)``; the resulting cache is inserted into
    the live stacked cache with a jitted scatter (``models.lm.cache_insert``),
    never through per-slot Python lists.

Noisy / RRNS serving is first-class: every tick (and every prefill batch)
opens a :func:`repro.core.gemm.noise_key_scope` with a key folded from
``policy.noise_seed`` and the tick counter, so analog-channel backends
(``mirage_rns_noisy`` / ``mirage_rrns``) draw FRESH shot/thermal noise per
decode step while staying fully jitted (the key is a traced input, not a
static policy field — no recompiles).

For RNS-family backends the engine additionally programs every GEMM weight
into **stationary residues** once at admission
(:func:`repro.core.stationary.encode_stationary_params`): BFP quantization,
residue conversion and DAC/drift programming are paid once per server
lifetime instead of once per GEMM per tick — the paper's program-once
MMVMU dataflow. At decode shapes the per-call weight pipeline dominates
the GEMM, so this is the difference between the error-corrected path being
a curiosity and a serving mode. Clean-channel numerics are bit-identical
to the per-call path (parity-tested).

**Paged KV memory** (``cache_layout="paged"``): instead of one dense
``cap``-length ring per slot, KV lives in a global pool of fixed-size
blocks addressed through per-slot block tables
(:mod:`repro.runtime.paging`). Memory scales with the workload's live
token count (rounded up to blocks) instead of ``slots x cap``; blocks are
allocated on demand at admission and during decode, returned to the pool
at retirement, and the device only ever sees jittable arrays (page pools +
an int32 table whose unmapped entries are an OOB sentinel). The dense
layout is retained as the parity oracle — the paged engine is
token-identical under greedy decode.

**Chunked (piggybacked) prefill** (``prefill_chunk=N``, paged only): long
prompts stream through the decode loop N tokens per tick instead of
running one monolithic prefill at admission, so a long arrival no longer
stalls every active decode stream (the TTFT/TPOT spike
``benchmarks/bench_serving.py`` measures). The final chunk emits the first
token; TTFT is stamped only when that token's bytes reach the host.

**Prefix caching** (``prefix_cache=True``, paged only): admission matches
an incoming prompt's token prefix against live block tables at BLOCK
granularity through a hash-chain index (:class:`repro.runtime.paging.
PrefixIndex`). Matched full blocks are mapped read-only into the new
slot's table with their refcounts bumped — prefill for those positions is
skipped entirely; only the un-matched suffix runs (as one chunk step). A
prompt fully covered minus its last token attaches with NO prefill at all
and emits its first token from the next decode tick (TTFT stamps at that
token's host materialization). Writes into a shared block fork a private
copy first (copy-on-write guard), so sharers never see each other's
tokens. SSM/hybrid families carry recurrent state that cannot be skipped,
so the flag is inert there (documented, parity-tested).

**Speculative decoding** (``spec_k=k``, paged + greedy only): each tick a
host-side prompt-lookup draft proposes ``k`` tokens per slot; ONE jitted
verify step (:meth:`repro.models.lm.LM.verify_step`) scores all ``k+1``
positions through page-gather attention and accepts the longest prefix of
drafts matching the verified greedy tokens — plus one bonus token — so
output is token-identical to greedy tick-by-tick decode while the
per-tick channel/RRNS-decode overhead amortizes over >1 accepted token.
Rejected tails need no KV rollback: the next verify tick re-writes
exactly those positions before any gather reads them; SSM/conv recurrent
state rolls back by selecting the per-step stacked state at the accepted
position.

**Meshed serving** (``mesh=jax.sharding.Mesh(...)``): the engine runs
dp×tp-sharded end to end. Params place with
:func:`repro.parallel.sharding.param_shardings`, the whole state pytree
(stacked/paged cache, per-slot control vectors, health accumulators) with
:func:`repro.parallel.sharding.serve_state_shardings`, and every jitted
step is built with those shardings as ``in_shardings``/``out_shardings``
and the state argument **donated** — per-tick device state never round-
trips or copies; the packed payload is the one replicated output the host
reads. Under a meshed PAGED cache the pools shard their block dim over
``data`` and the allocator becomes shard-aware (per-shard free lists,
same-shard-first placement — see :mod:`repro.runtime.paging`), so decode
page-gathers stay local instead of becoming all-to-alls. Token streams are
identical to the single-device engine under greedy decode (the per-slot
computation and the tick/prefill noise-key schedule do not depend on the
mesh), including ``mirage_rrns`` on the same noise-seed.

**Pipelined prefill** (``pipeline_depth=N``): whole-prompt bucketed
prefill splits into a slot-independent *compute* half (forward pass +
token selection — params and prompt tokens only) and a cheap donated
*scatter* half (insert into the live state). A daemon worker thread runs
computes from a queue while the decode loop keeps ticking; the decode
thread applies finished scatters at the next tick. Admission stops
claiming slots once ``N`` prefills are in flight (bounded backpressure),
so a compile storm or a wave of long prompts can never buffer unboundedly
ahead of token emission. Token-identical to the synchronous path for
deterministic backends (per-slot decode depends only on the slot's own
history); noisy backends draw a differently-interleaved — still valid —
per-tick key stream because admission timing shifts.

**AOT warmup** (:meth:`LMServer.warmup`): compile every (bucket, batch)
prefill shape plus the tick/verify/chunk steps before traffic by running
the REAL jitted steps against the idle state with out-of-bounds slot ids
(scatters drop device-side; the few touched control leaves are snapshot/
restored), so a warmed drain triggers zero compiles
(:meth:`LMServer.compile_counts` is the assertion hook).

:class:`PerSlotLMServer` is the seed's slot-at-a-time loop, retained only
as the parity oracle (token-exact vs the batched engine under greedy
decode) and as the benchmark baseline.
"""

from __future__ import annotations

import collections
import collections.abc
import contextlib
import copy
import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analog import channel as analog_channel
from repro.core import gemm
from repro.models import lm as lm_helpers
from repro.obs import health as obs_health
from repro.obs import trace as obs_trace
from repro.obs.metrics import MetricsRegistry
from repro.runtime.paging import blocks_for


@dataclasses.dataclass(frozen=True)
class _PrefixMatch:
    """Admission-time prefix-index lookup result."""
    block_ids: Tuple[int, ...] = ()
    m: int = 0              # positions covered by shared blocks
    full_hit: bool = False  # whole prompt minus last token is shared
    fork_extra: int = 0     # 1 extra block reserved for the deferred fork


_NO_MATCH = _PrefixMatch()


def _lookup_draft(ctx: np.ndarray, k: int, n: int = 3) -> np.ndarray:
    """Prompt-lookup drafting (self-drafting speculative decoding): find
    the most recent earlier occurrence of the context's trailing n-gram
    and propose the tokens that followed it, falling back to shorter
    n-grams and finally to repeating the last token. Host-side and
    deterministic; the verify step makes ANY draft exact under greedy —
    a bad draft just yields the single bonus token (= plain decode)."""
    L = len(ctx)
    out = np.full((k,), ctx[-1] if L else 0, np.int32)
    for nn in range(min(n, L - 1), 0, -1):
        key = ctx[L - nn:]
        for s in range(L - nn - 1, -1, -1):
            if np.array_equal(ctx[s:s + nn], key):
                take = ctx[s + nn:s + nn + k]
                out[:len(take)] = take
                return out
    return out


class AdmissionRejected(RuntimeError):
    """Raised by ``submit`` when the engine refuses a request instead of
    queueing it unboundedly (queue-depth cap hit, or the engine is
    draining). Carries ``retry_after_s`` — the backoff hint a fronting
    load balancer would surface as HTTP 429 Retry-After."""

    def __init__(self, msg: str, retry_after_s: float = 0.1):
        super().__init__(msg)
        self.retry_after_s = float(retry_after_s)


#: terminal request statuses — every submitted request ends in exactly one
TERMINAL_STATUSES = ("completed", "timed_out", "rejected", "failed")


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray            # (L,) int32
    max_tokens: int = 32
    eos_id: Optional[int] = None
    tokens_out: List[int] = dataclasses.field(default_factory=list)
    t_enqueue: float = 0.0
    t_admit: float = 0.0
    t_first_token: float = 0.0
    t_done: float = 0.0
    # -- robustness lifecycle -------------------------------------------
    # queued -> active -> completed | timed_out | failed; rejected at
    # submit. A fault-aborted request transitions active -> queued again
    # (bounded by retries), restarting its stream from scratch.
    status: str = "queued"
    ttl_s: Optional[float] = None        # total deadline from enqueue
    queue_ttl_s: Optional[float] = None  # admission deadline from enqueue
    max_retries: int = 0                 # 0 = use the engine default
    retries: int = 0
    error: Optional[str] = None          # terminal failure reason

    @property
    def terminal(self) -> bool:
        return self.status in TERMINAL_STATUSES

    def deadline(self, now: float) -> bool:
        """Total-TTL expiry at wall time ``now``."""
        return self.ttl_s is not None and now - self.t_enqueue > self.ttl_s

    def queue_deadline(self, now: float) -> bool:
        """Queue-TTL (or total-TTL) expiry while still waiting."""
        if self.queue_ttl_s is not None and \
                now - self.t_enqueue > self.queue_ttl_s:
            return True
        return self.deadline(now)

    @property
    def queue_time(self) -> float:
        return self.t_admit - self.t_enqueue

    @property
    def ttft(self) -> float:
        return self.t_first_token - self.t_enqueue

    @property
    def tpot(self) -> float:
        """Mean time per output token after the first."""
        n = len(self.tokens_out)
        if n <= 1:
            return 0.0
        return (self.t_done - self.t_first_token) / (n - 1)


def default_buckets(cache_len: int, min_bucket: int = 8) -> Tuple[int, ...]:
    """Power-of-two prompt-length buckets up to the cache capacity."""
    out, b = [], min_bucket
    while b < cache_len:
        out.append(b)
        b *= 2
    out.append(cache_len)
    return tuple(out)


def pick_bucket(length: int, buckets: Sequence[int]) -> int:
    for b in buckets:
        if length <= b:
            return b
    raise ValueError(f"prompt length {length} exceeds largest bucket "
                     f"{buckets[-1]}")


class _SchedulerMetrics(collections.abc.MutableMapping):
    """Dict-shaped view over registry-backed counters.

    The Scheduler's metrics were a plain dict; every ``metrics["x"] += 1``
    site now lands in a :class:`repro.obs.metrics.Counter`
    (``serve_<x>_total``), so the host loop, the JSON snapshot and the
    Prometheus scrape share ONE source of truth while the call sites keep
    their dict shape.

    ``prefilling`` is not a counter: it is DERIVED from the engine's
    in-flight chunked-prefill list through a callback bound by
    :class:`LMServer`. The old code stored it and updated it on some code
    paths only (reset in ``_admit_chunked`` but also assigned in ``tick``),
    so the gauge could go stale; deriving it makes staleness impossible.
    Writes to it are ignored. Without an engine bound it reads 0.
    """

    _COUNTERS = (
        ("completed", "requests retired"),
        ("tokens", "tokens emitted by retired requests"),
        ("ticks", "engine ticks run"),
        ("admitted", "requests admitted into slots"),
        ("prefill_batches", "bucketed prefill batches launched"),
        # chunked prefill: total chunk steps run
        ("prefill_chunks", "chunked-prefill steps run"),
        # prefix caching: admissions that reused shared blocks, the
        # subset that skipped prefill entirely, and total blocks mapped
        # read-only instead of being prefilled
        ("prefix_hits", "admissions that mapped shared prefix blocks"),
        ("prefix_full_hits", "admissions that skipped prefill entirely"),
        ("prefix_shared_blocks", "blocks mapped read-only at admission"),
        # copy-on-write forks resolved by the guard before a shared-block
        # write (prefix sharing's write-path cost)
        ("cow_forks", "copy-on-write block forks"),
        # speculative decoding: verify ticks run, per-slot verify
        # steps, and tokens accepted (accepted/spec_slot_ticks is the
        # mean accepted-tokens-per-tick the benchmark gates on)
        ("spec_ticks", "speculative verify ticks run"),
        ("spec_slot_ticks", "per-slot speculative verify steps"),
        ("spec_accepted", "draft tokens accepted"),
        # request-level robustness: terminal statuses other than
        # completed, plus fault-abort retries returned to the queue
        ("timed_out", "requests retired by queue/decode deadline"),
        ("rejected", "requests refused at admission (queue cap/drain)"),
        ("failed", "requests terminally failed (retries exhausted)"),
        ("retried", "fault-aborted requests returned to the queue"),
    )

    def __init__(self, registry: MetricsRegistry):
        self._counters = {
            name: registry.counter(f"serve_{name}_total", help=help_)
            for name, help_ in self._COUNTERS}
        self._prefilling_fn: Optional[Callable[[], int]] = None

    def bind_prefilling(self, fn: Callable[[], int]) -> None:
        self._prefilling_fn = fn

    def __getitem__(self, key: str) -> int:
        if key == "prefilling":
            fn = self._prefilling_fn
            return int(fn()) if fn is not None else 0
        return int(self._counters[key].value)

    def __setitem__(self, key: str, value: int) -> None:
        if key == "prefilling":
            return  # derived from the engine's in-flight list; see class doc
        self._counters[key].set(value)

    def __delitem__(self, key: str) -> None:
        raise TypeError("scheduler metrics keys are fixed")

    def __iter__(self):
        yield from self._counters
        yield "prefilling"

    def __len__(self) -> int:
        return len(self._counters) + 1


class Scheduler:
    """FCFS admission + retirement bookkeeping + per-request latency metrics.

    The scheduler owns the waiting deque and the host-visible request
    lifecycle (enqueue → admit → stream tokens → retire); the engine owns
    the device state. ``on_token`` is the streaming hook: called once per
    materialized token, in emission order.

    Metrics live in ``registry`` (a private
    :class:`repro.obs.metrics.MetricsRegistry` unless one is passed — pass
    the process-wide ``repro.obs.get_registry()`` to expose them over
    ``launch/serve.py --metrics-port``); ``self.metrics`` is a dict-shaped
    view over the same instruments for the host loop and existing callers.
    """

    def __init__(self, on_token: Optional[Callable[[Request, int], None]] = None,
                 registry: Optional[MetricsRegistry] = None,
                 max_queue_depth: Optional[int] = None):
        self.waiting: collections.deque[Request] = collections.deque()
        self.finished: List[Request] = []
        self.on_token = on_token
        self.max_queue_depth = max_queue_depth
        self.registry = registry if registry is not None else MetricsRegistry()
        self.metrics: _SchedulerMetrics = _SchedulerMetrics(self.registry)
        self._h_ttft = self.registry.histogram(
            "serve_ttft_seconds", help="time to first token (enqueue→host)")
        self._h_tpot = self.registry.histogram(
            "serve_tpot_seconds", help="mean time per output token after "
                                       "the first, per retired request")
        self._h_queue = self.registry.histogram(
            "serve_queue_seconds", help="enqueue→admission wait")
        self.registry.gauge_fn(
            "serve_queue_depth", lambda: len(self.waiting),
            help="requests waiting for admission")

    def submit(self, req: Request) -> None:
        if self.max_queue_depth is not None and \
                len(self.waiting) >= self.max_queue_depth:
            req.status = "rejected"
            req.error = "queue full"
            self.metrics["rejected"] += 1
            # backoff hint: roughly one admission wave per queued request
            raise AdmissionRejected(
                f"request {req.rid}: queue at max depth "
                f"{self.max_queue_depth}",
                retry_after_s=0.05 * len(self.waiting))
        req.t_enqueue = time.perf_counter()
        req.status = "queued"
        self.waiting.append(req)

    def take(self, n: int) -> List[Request]:
        """Pop up to ``n`` requests in FCFS order for admission."""
        out = []
        while self.waiting and len(out) < n:
            out.append(self.waiting.popleft())
        return out

    def record_admit(self, reqs: Sequence[Request]) -> None:
        t = time.perf_counter()
        for r in reqs:
            r.t_admit = t
            r.status = "active"
        self.metrics["admitted"] += len(reqs)
        self.metrics["prefill_batches"] += 1

    def emit(self, req: Request, tok: int) -> None:
        req.tokens_out.append(tok)
        if self.on_token is not None:
            self.on_token(req, tok)

    def retire(self, req: Request, status: str = "completed") -> Request:
        """Move ``req`` to ``finished`` with a terminal ``status``. The
        latency histograms only observe phases the request actually
        reached: a request timed out in the queue has no TTFT/TPOT and a
        never-admitted one has no queue-exit time — observing zeros there
        would poison the percentiles."""
        if status not in TERMINAL_STATUSES:
            raise ValueError(f"non-terminal retirement status {status!r}")
        req.t_done = time.perf_counter()
        req.status = status
        self.metrics[status if status != "completed" else "completed"] += 1
        self.metrics["tokens"] += len(req.tokens_out)
        if req.t_first_token > 0:
            self._h_ttft.observe(req.ttft)
            self._h_tpot.observe(req.tpot)
        if req.t_admit > 0:
            self._h_queue.observe(req.queue_time)
        self.finished.append(req)
        return req

    def expire_queued(self, now: Optional[float] = None) -> List[Request]:
        """Retire waiting requests whose queue (or total) deadline passed;
        FCFS order of the survivors is preserved."""
        now = time.perf_counter() if now is None else now
        if not any(r.queue_ttl_s is not None or r.ttl_s is not None
                   for r in self.waiting):
            return []
        expired, kept = [], collections.deque()
        for r in self.waiting:
            if r.queue_deadline(now):
                r.error = "deadline exceeded in queue"
                expired.append(self.retire(r, status="timed_out"))
            else:
                kept.append(r)
        self.waiting = kept
        return expired

    def latency_summary(self) -> Dict[str, float]:
        """Means + exact p50/p95/p99 tails over every retired request (the
        registry histograms expose bucket-interpolated estimates of the
        same distributions for live scraping; these are the exact values
        the benchmark rows record).

        Robust to degenerate drains: an empty ``finished`` list returns
        all-zero rows, and requests that never reached a given phase
        (rejected / timed out before their first token) are excluded from
        that phase's statistics instead of contributing garbage."""
        keys = [f"{m}_{s}_s" for m in ("ttft", "tpot")
                for s in ("mean", "p50", "p95", "p99")] + ["queue_mean_s"]
        out = {k: 0.0 for k in keys}
        admitted = [r for r in self.finished if r.t_admit > 0]
        if admitted:
            out["queue_mean_s"] = float(
                np.mean([r.queue_time for r in admitted]))
        streamed = [r for r in self.finished if r.t_first_token > 0]
        if not streamed:
            return out
        for name, arr in (("ttft", np.asarray([r.ttft for r in streamed])),
                          ("tpot", np.asarray([r.tpot for r in streamed]))):
            out[f"{name}_mean_s"] = float(arr.mean())
            for q in (50, 95, 99):
                out[f"{name}_p{q}_s"] = float(np.percentile(arr, q))
        return out


class _PrefillPipeline:
    """Prefill/decode overlap for :class:`LMServer` (``pipeline_depth``).

    A daemon worker thread runs the slot-independent half of bucketed
    prefill (``_prefill_compute``: the whole forward pass + token
    selection, reading only the never-donated exec params) while the
    decode loop keeps ticking; the decode thread applies the cheap donated
    scatter when a compute lands. Backpressure is the ``depth`` bound on
    jobs in flight — admission stops claiming slots past it, so prefill
    compilation or a wave of long prompts can never buffer unboundedly
    ahead of token emission. Single producer, single worker: both queues
    are FIFO, so jobs complete and scatter in submission order (the FCFS
    key schedule stays deterministic). JAX dispatch is thread-safe.
    """

    _STALL_S = 300.0

    def __init__(self, server: "LMServer", depth: int):
        self.server = server
        self.depth = int(depth)
        self.inflight = 0      # submitted, not yet scattered (decode thread)
        # chaos hook (runtime.faults ``worker_crash``): fail the NEXT job
        # the worker picks up — the job errors exactly as a real compute
        # crash would, exercising the release/requeue recovery path
        self.crash_next = False
        self._in: queue.Queue = queue.Queue()
        self._out: queue.Queue = queue.Queue()
        self._thread = threading.Thread(
            target=self._worker, name="lmserver-prefill", daemon=True)
        self._thread.start()

    @property
    def full(self) -> bool:
        return self.inflight >= self.depth

    def submit(self, job: Dict[str, Any]) -> None:
        self.inflight += 1
        self._in.put(job)

    def _worker(self) -> None:
        srv = self.server
        while True:
            job = self._in.get()
            if job is None:
                return
            if self.crash_next:
                self.crash_next = False
                self._out.put((job, None, RuntimeError(
                    "injected prefill worker crash")))
                continue
            try:
                out = srv._prefill_compute(
                    srv._exec_params, jnp.asarray(job["tokens"]),
                    jnp.asarray(job["lens"]), job["nk"], job["sk"],
                    *job.get("ctl", ()))
                self._out.put((job, out, None))
            except BaseException as e:    # handled on the decode thread
                self._out.put((job, None, e))

    def collect(self, block: bool) -> List[Tuple[Dict[str, Any], Any, Any]]:
        """Finished jobs, oldest first: everything already done, plus —
        when ``block`` (nothing else can make progress) — wait for at
        least one."""
        items: List[Tuple[Dict[str, Any], Any, Any]] = []
        while True:
            try:
                if block and not items:
                    items.append(self._out.get(timeout=self._STALL_S))
                else:
                    items.append(self._out.get_nowait())
            except queue.Empty:
                if block and not items:
                    raise RuntimeError(
                        f"prefill pipeline made no progress for "
                        f"{self._STALL_S:.0f}s (worker dead?)")
                break
        self.inflight -= len(items)
        return items

    def close(self) -> None:
        self._in.put(None)
        self._thread.join(timeout=10.0)


class LMServer:
    """Continuous-batching serving engine (the deployment path).

    Device state is one pytree::

        {"cache":   stacked cache, per-slot ``idx`` (see lm.cache_spec),
         "last_tok": (S,) int32   last emitted token per slot,
         "active":   (S,) bool    slot occupancy mask,
         "emitted":  (S,) int32   tokens emitted per slot,
         "eos":      (S,) int32   per-slot EOS id (-1 = none),
         "max_tok":  (S,) int32   per-slot token budget}

    ``tick()`` = admit (bucketed batched prefill + jitted scatter insert)
    then one jitted decode step for every slot at once.
    """

    def __init__(self, model, params, cap: int, batch_slots: int = 8,
                 greedy: bool = True,
                 buckets: Optional[Sequence[int]] = None,
                 on_token: Optional[Callable[[Request, int], None]] = None,
                 scheduler: Optional[Scheduler] = None,
                 sample_seed: int = 0,
                 stationary_weights: Optional[bool] = None,
                 cache_layout: str = "dense",
                 block_size: int = 16,
                 n_blocks: Optional[int] = None,
                 prefill_chunk: Optional[int] = None,
                 prefix_cache: bool = False,
                 spec_k: int = 0,
                 instrument: bool = True,
                 mesh=None,
                 pipeline_depth: int = 0,
                 block_placement: str = "locality",
                 fault_injector=None,
                 max_queue_depth: Optional[int] = None,
                 default_ttl_s: Optional[float] = None,
                 default_queue_ttl_s: Optional[float] = None,
                 max_retries: int = 1):
        self.model = model
        self.params = params
        self.cap = cap
        self.greedy = greedy
        self.n_slots = batch_slots
        cfg = model.cfg
        self.mesh = mesh
        if pipeline_depth < 0:
            raise ValueError(f"pipeline_depth must be >= 0, got "
                             f"{pipeline_depth}")
        if pipeline_depth and (prefill_chunk is not None or prefix_cache):
            raise ValueError(
                "pipeline_depth overlaps whole-prompt bucketed prefill with "
                "decode; chunked prefill already interleaves by construction "
                "and prefix matching is ordered host state — combine with "
                "neither")
        self.pipeline_depth = int(pipeline_depth)
        self.cache_len = min(cap, cfg.sliding_window or cap)
        if cache_layout not in ("dense", "paged"):
            raise ValueError(f"unknown cache_layout {cache_layout!r}")
        if prefill_chunk is not None and cache_layout != "paged":
            raise ValueError(
                "prefill_chunk requires cache_layout='paged' (chunk steps "
                "scatter through block tables with linear addressing; the "
                "dense ring keeps whole-prompt bucketed prefill)")
        if prefill_chunk is not None and prefill_chunk < 1:
            raise ValueError(f"prefill_chunk must be >= 1, got {prefill_chunk}")
        if prefix_cache and cache_layout != "paged":
            raise ValueError(
                "prefix_cache requires cache_layout='paged' (blocks are the "
                "sharing unit; the dense rings have nothing to share)")
        if spec_k < 0:
            raise ValueError(f"spec_k must be >= 0, got {spec_k}")
        if spec_k:
            if cache_layout != "paged":
                raise ValueError(
                    "spec_k requires cache_layout='paged' (the verify step "
                    "writes k+1 positions through block tables; the dense "
                    "ring is single-token)")
            if not greedy:
                raise ValueError(
                    "spec_k requires greedy=True (verify-then-accept is "
                    "exact under greedy sampling only)")
        self.cache_layout = cache_layout
        self.block_size = block_size
        self.prefill_chunk = prefill_chunk
        self.spec_k = int(spec_k)
        # pure-SSM models have no KV to page (recurrent state is O(1) per
        # slot and stays dense under both layouts) — no pool, no tables
        has_pages = not (model.kind == "mamba" and not cfg.attn_every)
        if cache_layout == "paged" and has_pages:
            from repro.runtime.paging import BlockAllocator
            mb = blocks_for(cap, block_size)
            # default pool = slots * ceil(cap/bs): no memory saving but never
            # exhausts; pass a smaller n_blocks (sized to the live-token
            # budget of the workload) to realize the paged win
            nb = n_blocks if n_blocks is not None else batch_slots * mb
            # under a mesh the pool's block dim and the slot dim shard over
            # ``data`` (cache_spec): tell the allocator the shard geometry
            # so it can keep each slot's page-gathers on its own shard
            if mesh is not None:
                from repro.parallel import sharding as shard_rules
                n_shards = shard_rules.serve_block_shards(
                    mesh, nb, batch_slots)
            else:
                n_shards = 1
            self.alloc: Optional["BlockAllocator"] = BlockAllocator(
                nb, block_size, batch_slots, mb,
                n_shards=n_shards, placement=block_placement)
        else:
            self.alloc = None
        # prefix caching needs pages to share AND skippable prefill: SSM /
        # hybrid recurrent state at the match point cannot be reconstructed
        # from blocks, so the flag is inert for the mamba kind (documented;
        # the engine stays token-identical, it just never shares)
        self.prefix_cache = bool(prefix_cache) and self.alloc is not None \
            and model.kind != "mamba"
        if self.prefix_cache:
            from repro.runtime.paging import PrefixIndex
            self.prefix_index: Optional["PrefixIndex"] = \
                PrefixIndex(block_size)
        else:
            self.prefix_index = None
        # chunked-prefill in-flight entries: {"req", "slot", "pos"}
        self.prefilling: List[Dict[str, Any]] = []
        self._slot_pos = [0] * batch_slots   # host mirror of each slot's idx
        # lifetime block reservation per occupied slot (see _free_budget)
        self._slot_budget = [0] * batch_slots
        # linear position cap per occupied slot (prompt + max_tokens): the
        # speculative ensure() clamps here so draft positions past the
        # request's own budget never allocate past its reservation
        self._slot_poscap = [0] * batch_slots
        # full-prefix-hit slots owe one deferred copy-on-write fork when
        # their first decode write lands inside a shared block; the free
        # block for it is reserved until the guard resolves it
        self._fork_pending = [0] * batch_slots
        # SSM/hybrid recurrences carry state through padded steps, so those
        # families bucket by EXACT prompt length (still batched across
        # same-length prompts); attention families right-pad to buckets.
        self.pad_prefill = model.kind != "mamba"
        self.buckets = tuple(sorted(buckets)) if buckets else \
            default_buckets(self.cache_len)
        if self.buckets[-1] > self.cache_len:
            raise ValueError(f"bucket {self.buckets[-1]} exceeds cache "
                             f"capacity {self.cache_len}")
        self.scheduler = scheduler or Scheduler(on_token=on_token)
        if max_queue_depth is not None:
            self.scheduler.max_queue_depth = int(max_queue_depth)
        self.slot_req: List[Optional[Request]] = [None] * batch_slots

        # request-level robustness: per-request deadlines default to these
        # engine-wide TTLs at submit; fault-aborted requests retry up to
        # ``max_retries`` times (per-request override via
        # ``Request.max_retries``) before failing terminally
        self.default_ttl_s = default_ttl_s
        self.default_queue_ttl_s = default_queue_ttl_s
        self.max_retries = int(max_retries)
        self._draining = False
        self.last_prefill_error: Optional[BaseException] = None
        # chaos harness (runtime.faults): host-side sites apply between
        # ticks; channel sites enter the jitted steps as ONE trailing
        # traced control pytree (identity values when no window is
        # active), so a chaos engine shares the clean engine's compiles
        self._injector = fault_injector
        self._chaos_tick = 0
        self._ctl: Optional[Dict[str, np.ndarray]] = None

        # analog-health accumulators: shapes derive from the policy alone
        # (empty for deterministic backends → no "health" state key, no
        # collection scope, zero change to those paths).
        # ``instrument=False`` builds the UNINSTRUMENTED engine — the
        # overhead/parity comparator benchmarks measure against.
        self.instrument = bool(instrument)
        self._health_spec = obs_health.spec(model.policy) if instrument \
            else {}
        if self._health_spec:
            from repro.analog import rrns as rrns_mod
            self._health_moduli = (
                rrns_mod.rrns_moduli(model.policy)
                if model.policy.mode in ("mirage_rrns", "mirage_rrns_ref")
                else tuple(model.policy.moduli))
        else:
            self._health_moduli = ()
        self._bound_registry: Optional[MetricsRegistry] = None

        seed = model.policy.noise_seed if model.policy.noise_seed is not None \
            else 0
        # distinct streams: fold(base, 0) -> decode ticks, fold(base, 1) ->
        # prefill batches, fold(base, 2) -> prefill chunks; each then folds
        # its own counter per event
        self._noise_base = jax.random.PRNGKey(seed)
        self._sample_base = jax.random.PRNGKey(sample_seed)
        self._tick_count = 0
        self._prefill_count = 0
        self._chunk_count = 0

        # program-once weight admission: RNS-family backends execute against
        # pre-encoded stationary residues. Auto-on for model families whose
        # GEMM weights all flow through `dense` (the merged parallel
        # projection concatenates raw weight arrays; MoE experts cross a
        # shard_map boundary with positional specs — both keep per-call
        # encoding). Force with stationary_weights=True/False.
        if stationary_weights is None:
            from repro.core import backends as _backends
            stationary_weights = (
                _backends.resolve(model.policy).supports_stationary_residues
                and getattr(model, "kind", None) in ("attn_mlp", "mamba")
                and not getattr(model.opt, "merge_parallel_proj", False))
        self.stationary_weights = bool(stationary_weights)
        if self.stationary_weights:
            from repro.core import stationary
            self._exec_params = stationary.encode_stationary_params(
                params, model.policy)
        else:
            self._exec_params = params

        self._compute_fault_ctl()
        self.state = self._init_state(batch_slots)
        self._bind_observability()
        self._place_on_mesh()
        self._build_steps()
        self._pipe: Optional[_PrefillPipeline] = \
            _PrefillPipeline(self, self.pipeline_depth) \
            if self.pipeline_depth else None

    # ------------------------------------------------------------------
    # mesh placement + jitted-step construction
    # ------------------------------------------------------------------

    def _compute_fault_ctl(self) -> None:
        """Decide whether the jitted steps carry the trailing channel
        fault-control operand: only when an injector schedules a channel
        fault AND the current backend routes through the analog channel.
        Re-run by :meth:`switch_backend` (the fp32 fallback has no channel
        to fault — its steps keep the plain signatures)."""
        from repro.core import backends as _backends
        pol = self.model.policy
        if _backends.resolve(pol).supports_noise:
            from repro.analog import rrns as rrns_mod
            self._ctl_n_moduli = len(
                rrns_mod.rrns_moduli(pol)
                if pol.mode in ("mirage_rrns", "mirage_rrns_ref")
                else tuple(pol.moduli))
        else:
            self._ctl_n_moduli = 0
        self._use_fault_ctl = (
            self._injector is not None and self._ctl_n_moduli > 0
            and self._injector.channel_faults_scheduled())

    def _with_faults(self, fn):
        """Wrap a step body to take ONE trailing fault-control pytree and
        open :func:`repro.analog.channel.fault_scope` around the trace —
        identity controls are bit-identical to no scope, so one compile
        serves every fault intensity. No-op (``fn`` unchanged) when this
        engine runs without scheduled channel faults."""
        if not self._use_fault_ctl:
            return fn

        def wrapped(*args):
            *rest, ctl = args
            with analog_channel.fault_scope(ctl):
                return fn(*rest)

        return wrapped

    def _fault_ctl_args(self) -> tuple:
        """The trailing control operand for fault-wrapped step calls this
        tick (empty tuple when the steps are unwrapped)."""
        if not self._use_fault_ctl:
            return ()
        if self._ctl is None:
            self._ctl = self._injector.controls(self._chaos_tick,
                                                self._ctl_n_moduli)
        return (self._ctl,)

    def _place_on_mesh(self) -> None:
        """Compute the engine's NamedShardings from the existing rules
        (:mod:`repro.parallel.sharding`) and place params + state. Param
        leaves the path rules don't recognize (e.g. stationary-residue
        sub-trees) replicate; cache leaves follow ``cache_spec`` (paged
        pools shard the BLOCK dim over ``data``, tables/control vectors
        the slot dim). No-op without a mesh."""
        if self.mesh is None:
            self._param_sh = None
            self._state_sh = None
            return
        from repro.parallel import sharding as shard_rules
        cfg = self.model.cfg
        self._param_sh = shard_rules.param_shardings(
            self.mesh, cfg, self._exec_params)
        self._state_sh = shard_rules.serve_state_shardings(
            self.mesh, cfg, self.state)
        self._exec_params = jax.device_put(self._exec_params, self._param_sh)
        self.state = jax.device_put(self.state, self._state_sh)

    def _build_steps(self) -> None:
        """(Re)build every jitted step. Under a mesh each step pins
        ``in_shardings`` for params/state, emits state with its own
        shardings and the packed payload replicated, and DONATES the state
        argument — the tick-to-tick state never copies; every call site
        reassigns ``self.state`` from the step's output. Re-run after any
        elastic resize (the state tree and its shardings changed)."""
        mesh = self.mesh
        want_chunks = self.prefill_chunk is not None or self.prefix_cache
        # model-invoking steps optionally take the trailing fault-control
        # operand (wf); pure-scatter steps never do
        wf = self._with_faults
        x = 1 if self._use_fault_ctl else 0

        if mesh is None:
            self._decode_tick = jax.jit(wf(self._make_tick_fn()))
            self._prefill_insert = jax.jit(wf(self._make_prefill_fn()))
            self._prefill_compute = jax.jit(
                wf(self._make_prefill_compute_fn()))
            self._prefill_scatter = jax.jit(self._make_prefill_scatter_fn())
            # prefix-cache misses/partial hits prefill through the chunk
            # step (one call at pos0 = matched length), so both share fns
            if want_chunks:
                mid, last = self._make_chunk_fns()
                self._chunk_mid = jax.jit(wf(mid))
                self._chunk_last = jax.jit(wf(last))
            if self.prefix_cache:
                self._attach = jax.jit(self._make_attach_fn())
            if self.spec_k:
                self._verify_tick = jax.jit(wf(self._make_verify_fn()))
            return

        from jax.sharding import NamedSharding, PartitionSpec
        rep = NamedSharding(mesh, PartitionSpec())
        ps, ss = self._param_sh, self._state_sh

        def sharded(fn, n_rest, has_params=True, payload=True):
            """jit ``fn(params?, state, rest...)`` with placed + donated
            state; ``rest`` args (tokens, keys, scalars) stay unspecified —
            the compiler replicates the small host-built arrays."""
            in_sh = ([ps] if has_params else []) + [ss] + [None] * n_rest
            out_sh = (ss, rep) if payload else ss
            return jax.jit(fn, in_shardings=tuple(in_sh),
                           out_shardings=out_sh,
                           donate_argnums=(1 if has_params else 0,))

        self._decode_tick = sharded(wf(self._make_tick_fn()), 2 + x)
        self._prefill_insert = sharded(wf(self._make_prefill_fn()), 7 + x)
        # pipeline halves: compute reads only params (never donated, so the
        # worker thread can run it concurrently with decode); scatter is
        # the donated state update
        self._prefill_compute = jax.jit(
            wf(self._make_prefill_compute_fn()),
            in_shardings=(ps,) + (None,) * (4 + x))
        self._prefill_scatter = sharded(self._make_prefill_scatter_fn(), 6,
                                        has_params=False)
        if want_chunks:
            mid, last = self._make_chunk_fns()
            self._chunk_mid = sharded(wf(mid), 5 + x, payload=False)
            self._chunk_last = sharded(wf(last), 8 + x)
        if self.prefix_cache:
            self._attach = sharded(self._make_attach_fn(), 5,
                                   has_params=False, payload=False)
        if self.spec_k:
            self._verify_tick = sharded(wf(self._make_verify_fn()), 2 + x)

    def _refresh_placement(self) -> None:
        """After an elastic resize changed the state tree: recompute
        shardings, re-place, and rebuild the jitted steps (their
        in_shardings/donation bind to the old tree). No-op without a
        mesh — unsharded jits re-trace on the new shapes by themselves."""
        if self.mesh is not None:
            self._place_on_mesh()
            self._build_steps()

    # ------------------------------------------------------------------
    # device-side step functions
    # ------------------------------------------------------------------

    def _init_state(self, n_slots: int) -> Dict[str, Any]:
        if self.cache_layout == "paged" and self.alloc is not None:
            cache = self.model.init_cache(
                n_slots, self.cap, per_slot_idx=True, layout="paged",
                block_size=self.block_size, n_blocks=self.alloc.n_blocks)
        else:
            cache = self.model.init_cache(n_slots, self.cap,
                                          per_slot_idx=True)
        state = {
            "cache": cache,
            "last_tok": jnp.zeros((n_slots,), jnp.int32),
            "active": jnp.zeros((n_slots,), bool),
            "emitted": jnp.zeros((n_slots,), jnp.int32),
            "eos": jnp.full((n_slots,), -1, jnp.int32),
            "max_tok": jnp.zeros((n_slots,), jnp.int32),
        }
        if self._health_spec:
            # pool-wide (NOT per-slot) analog-fault accumulators; every
            # jitted step folds its traced contributions in, so the values
            # live on device until health_snapshot() fetches them
            state["health"] = obs_health.init(self._health_spec)
        return state

    def _sync_tables(self) -> None:
        """Mirror the allocator's block tables to the device cache leaf
        (lazily — only after alloc/free/remap changed them). Under a mesh
        the table is placed with its own sharding up front so the donated
        steps never reshard it."""
        if self.alloc is not None and self.alloc.dirty:
            bt = jnp.asarray(self.alloc.tables)
            if self.mesh is not None:
                bt = jax.device_put(bt, self._state_sh["cache"]["bt"])
            self.state["cache"]["bt"] = bt
            self.alloc.dirty = False

    def _health_scope(self):
        """Collection scope for a jitted step's model call — a real
        collector when this policy has health counters, else a shared
        null context (``active()`` stays False → record sites trace
        nothing)."""
        if self._health_spec:
            return obs_health.collect()
        return contextlib.nullcontext(None)

    def _fold_health(self, new_state, state, hc):
        if hc is not None:
            new_state["health"] = obs_health.fold(state["health"], hc.values)
        return new_state

    def _make_tick_fn(self):
        model, greedy = self.model, self.greedy

        def tick(params, state, noise_key, sample_key):
            cache0 = state["cache"]
            idx0 = cache0["idx"]
            with gemm.noise_key_scope(noise_key), self._health_scope() as hc:
                logits, cache = model.decode_step(
                    params, cache0, state["last_tok"][:, None])
            logits = logits[:, -1, :]
            if greedy:
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            else:
                tok = jax.random.categorical(sample_key, logits
                                             ).astype(jnp.int32)
            active = state["active"]
            emitted = state["emitted"] + active.astype(jnp.int32)
            hit_eos = (state["eos"] >= 0) & (tok == state["eos"])
            done = active & (hit_eos | (emitted >= state["max_tok"]))
            # inactive slots don't advance their position (their k/v writes
            # land on a frozen slot / a dropped page and are overwritten on
            # reuse), and their SSM recurrent state stays frozen — a slot
            # mid-chunked-prefill carries real state between chunks that the
            # engine-wide step must not perturb
            cache = dict(cache, idx=jnp.where(active, cache["idx"], idx0))
            for leaf in ("ssm", "conv"):
                if leaf in cache:
                    m = active.reshape((1, -1) + (1,) * (cache[leaf].ndim - 2))
                    cache[leaf] = jnp.where(m, cache[leaf], cache0[leaf])
            new_state = dict(
                state,
                cache=cache,
                last_tok=jnp.where(active, tok, state["last_tok"]),
                active=active & ~done,
                emitted=emitted,
            )
            self._fold_health(new_state, state, hc)
            # the tick's single device->host payload: (S, 2) [token|-1, done]
            payload = jnp.stack(
                [jnp.where(active, tok, -1), done.astype(jnp.int32)], axis=-1)
            return new_state, payload

        return tick

    def _make_prefill_compute_fn(self):
        """The slot-independent half of bucketed prefill: forward pass +
        token selection from params and prompt tokens alone — nothing it
        reads or writes belongs to the live engine state, which is what
        lets the pipeline worker run it on another thread mid-decode."""
        model, cap, greedy = self.model, self.cap, self.greedy

        def prefill_compute(params, tokens, lens, noise_key, sample_key):
            with gemm.noise_key_scope(noise_key), self._health_scope() as hc:
                logits, new_cache = model.prefill(params, tokens, cap,
                                                  lens=lens)
            logits = logits[:, -1, :]
            if greedy:
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            else:
                tok = jax.random.categorical(sample_key, logits
                                             ).astype(jnp.int32)
            hvals = hc.values if hc is not None else {}
            return tok, new_cache, hvals

        return prefill_compute

    def _make_prefill_scatter_fn(self):
        """The state half: insert a computed prefill into the live stacked
        state (jitted scatter) and derive the admission payload."""

        def prefill_scatter(state, tok, new_cache, hvals, slots, eos,
                            max_tok):
            # instant retirement: the prefill token already hit EOS or the
            # whole budget was one token — never occupy a slot
            done0 = ((eos >= 0) & (tok == eos)) | (max_tok <= 1)
            state = dict(
                state,
                cache=lm_helpers.cache_insert(state["cache"], new_cache,
                                              slots),
                last_tok=state["last_tok"].at[slots].set(tok, mode="drop"),
                active=state["active"].at[slots].set(~done0, mode="drop"),
                emitted=state["emitted"].at[slots].set(1, mode="drop"),
                eos=state["eos"].at[slots].set(eos, mode="drop"),
                max_tok=state["max_tok"].at[slots].set(max_tok, mode="drop"),
            )
            if self._health_spec:
                state["health"] = obs_health.fold(state["health"], hvals)
            payload = jnp.stack([tok, done0.astype(jnp.int32)], axis=-1)
            return state, payload

        return prefill_scatter

    def _make_prefill_fn(self):
        """Synchronous prefill = compute ∘ scatter traced into ONE jit —
        the op graph is identical to the pre-split monolith, so the
        single-jit path stays bit-exact while the pipeline reuses the
        same halves as two jits."""
        compute = self._make_prefill_compute_fn()
        scatter = self._make_prefill_scatter_fn()

        def prefill_insert(params, state, tokens, lens, slots, eos, max_tok,
                           noise_key, sample_key):
            tok, new_cache, hvals = compute(params, tokens, lens,
                                            noise_key, sample_key)
            return scatter(state, tok, new_cache, hvals, slots, eos,
                           max_tok)

        return prefill_insert

    def _make_chunk_fns(self):
        """Jitted chunk steps for piggybacked prefill. ``slot``/``pos0``/
        ``true_len`` (and eos/max_tok) are traced scalars, so ONE compile
        serves every chunk of every request (SSM/hybrid additionally compile
        once per distinct final-chunk length — exact-length chunking, same
        reason as the exact-length prefill buckets)."""
        model, greedy = self.model, self.greedy

        def chunk_mid(params, state, tokens, slot, pos0, true_len, noise_key):
            with gemm.noise_key_scope(noise_key), self._health_scope() as hc:
                _, cache = model.prefill_chunk(
                    params, state["cache"], tokens, slot, pos0, true_len)
            return self._fold_health(dict(state, cache=cache), state, hc)

        def chunk_last(params, state, tokens, slot, pos0, true_len, eos,
                       max_tok, noise_key, sample_key):
            with gemm.noise_key_scope(noise_key), self._health_scope() as hc:
                logits, cache = model.prefill_chunk(
                    params, state["cache"], tokens, slot, pos0, true_len)
            logits = logits[:, -1, :]
            if greedy:
                tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            else:
                tok = jax.random.categorical(sample_key, logits
                                             ).astype(jnp.int32)
            done0 = ((eos >= 0) & (tok[0] == eos)) | (max_tok <= 1)
            state = dict(
                state, cache=cache,
                last_tok=state["last_tok"].at[slot].set(tok[0]),
                active=state["active"].at[slot].set(~done0),
                emitted=state["emitted"].at[slot].set(1),
                eos=state["eos"].at[slot].set(eos),
                max_tok=state["max_tok"].at[slot].set(max_tok),
            )
            self._fold_health(state, state, hc)
            payload = jnp.stack(
                [tok, jnp.reshape(done0, (1,)).astype(jnp.int32)], axis=-1)
            return state, payload

        return chunk_mid, chunk_last

    def _make_attach_fn(self):
        """Jitted full-prefix-hit admission: the whole prompt minus its
        last token is already in shared blocks, so the slot attaches with
        NO prefill — ``idx = L-1``, ``last_tok = prompt[-1]``, ``emitted =
        0`` (the engine invariant ``idx = L + emitted - 1`` holds; the
        next decode tick produces the request's FIRST token)."""

        def attach(state, slot, last_tok, idx, eos, max_tok):
            cache = dict(state["cache"],
                         idx=state["cache"]["idx"].at[slot].set(idx))
            return dict(
                state, cache=cache,
                last_tok=state["last_tok"].at[slot].set(last_tok),
                active=state["active"].at[slot].set(True),
                emitted=state["emitted"].at[slot].set(0),
                eos=state["eos"].at[slot].set(eos),
                max_tok=state["max_tok"].at[slot].set(max_tok))

        return attach

    def _make_verify_fn(self):
        """Jitted speculative verify tick: score ``k`` drafts + 1 bonus
        position per slot in one step, accept device-side, roll recurrent
        state back to the accepted position. Exactly greedy: a token is
        accepted iff every draft before it equals the verified argmax."""
        model, k = self.model, self.spec_k

        def verify(params, state, drafts, noise_key):
            cache0 = state["cache"]
            idx0 = cache0["idx"]
            S = idx0.shape[0]
            tokens = jnp.concatenate(
                [state["last_tok"][:, None], drafts], axis=1)   # (S, k+1)
            with gemm.noise_key_scope(noise_key), self._health_scope() as hc:
                logits, cache, steps = model.verify_step(
                    params, cache0, tokens)
            g = jnp.argmax(logits, axis=-1).astype(jnp.int32)   # (S, k+1)
            active = state["active"]
            # leading-ones acceptance: position j is kept iff all drafts
            # before it matched greedy, it fits the remaining budget, and
            # no earlier kept token was EOS (the EOS itself is kept)
            lead = jnp.cumprod(
                (drafts == g[:, :-1]).astype(jnp.int32), axis=1)
            ok = jnp.concatenate(
                [jnp.ones((S, 1), jnp.int32), lead], axis=1)
            rem = state["max_tok"] - state["emitted"]
            j = jnp.arange(k + 1)[None, :]
            is_eos = (state["eos"][:, None] >= 0) & \
                (g == state["eos"][:, None])
            eos_before = jnp.concatenate(
                [jnp.zeros((S, 1), jnp.int32),
                 jnp.cumsum(is_eos.astype(jnp.int32), axis=1)[:, :-1]],
                axis=1)
            keep = jnp.cumprod(
                ok * (j < rem[:, None]).astype(jnp.int32) *
                (eos_before == 0).astype(jnp.int32), axis=1)
            a = jnp.maximum(jnp.sum(keep, axis=1), 1)           # (S,)
            last = jnp.take_along_axis(g, (a - 1)[:, None], axis=1)[:, 0]
            emitted = state["emitted"] + \
                jnp.where(active, a, 0).astype(jnp.int32)
            kept_eos = jnp.any((keep > 0) & is_eos, axis=1)
            done = active & (kept_eos | (emitted >= state["max_tok"]))
            # rejected-tail KV needs no rollback (the next tick re-writes
            # positions idx..idx+k before gathering); idx just advances by
            # the accepted count. Inactive slots stay frozen throughout.
            cache = dict(cache, idx=jnp.where(active, idx0 + a, idx0))
            if steps is not None:
                # recurrent rollback: state after token a-1, per slot
                rows = jnp.arange(S)
                for name in ("ssm", "conv"):
                    st = steps[name]                 # (nl, T, S, ...)
                    sel = st[:, a - 1, rows]         # (nl, S, ...)
                    m = active.reshape((1, -1) + (1,) * (sel.ndim - 2))
                    cache[name] = jnp.where(m, sel, cache0[name])
            new_state = dict(
                state, cache=cache,
                last_tok=jnp.where(active, last, state["last_tok"]),
                active=active & ~done,
                emitted=emitted)
            self._fold_health(new_state, state, hc)
            toks = jnp.where(active[:, None] & (keep > 0), g, -1)
            payload = jnp.concatenate(
                [toks, done.astype(jnp.int32)[:, None]], axis=1)  # (S,k+2)
            return new_state, payload

        return verify

    def _next_keys(self, stream: int, count: int):
        noise = jax.random.fold_in(
            jax.random.fold_in(self._noise_base, stream), count)
        sample = jax.random.fold_in(
            jax.random.fold_in(self._sample_base, stream), count)
        return noise, sample

    # ------------------------------------------------------------------
    # host-side loop
    # ------------------------------------------------------------------

    def submit(self, req: Request) -> None:
        if self._draining:
            req.status = "rejected"
            req.error = "server draining"
            self.scheduler.metrics["rejected"] += 1
            raise AdmissionRejected(
                f"request {req.rid}: server is draining")
        if req.ttl_s is None:
            req.ttl_s = self.default_ttl_s
        if req.queue_ttl_s is None:
            req.queue_ttl_s = self.default_queue_ttl_s
        # chunked prefill streams arbitrarily long prompts through the paged
        # cache (up to its linear capacity); bucketed prefill is bounded by
        # the largest bucket
        limit = self.cap if self.prefill_chunk else self.buckets[-1]
        if len(req.prompt) > limit:
            raise ValueError(
                f"request {req.rid}: prompt length {len(req.prompt)} exceeds "
                + (f"cache capacity {limit}" if self.prefill_chunk else
                   f"largest bucket {limit}"))
        if self.alloc is not None:
            # paged addressing is linear — it cannot ring-wrap like the
            # dense layout, so a lifetime that outgrows the table capacity
            # would silently drop its own recent KV. Reject loudly.
            capacity = self.alloc.max_blocks_per_slot * self.block_size
            if len(req.prompt) + req.max_tokens > capacity:
                raise ValueError(
                    f"request {req.rid}: prompt {len(req.prompt)} + "
                    f"max_tokens {req.max_tokens} exceeds the paged cache's "
                    f"linear capacity {capacity}; raise cap or lower "
                    f"max_tokens")
            # and a lifetime block budget exceeding the whole pool could
            # never be admitted — reject instead of livelocking the FCFS
            # queue behind an unsatisfiable head-of-line wait
            if self._block_budget(req) > self.alloc.n_blocks:
                raise ValueError(
                    f"request {req.rid}: prompt {len(req.prompt)} + "
                    f"max_tokens {req.max_tokens} needs "
                    f"{self._block_budget(req)} blocks of {self.block_size} "
                    f"but the pool holds {self.alloc.n_blocks}; grow "
                    f"n_blocks")
        self.scheduler.submit(req)

    def _bucket(self, length: int) -> int:
        return pick_bucket(length, self.buckets) if self.pad_prefill \
            else length

    def _block_budget(self, req: Request) -> int:
        """Blocks a request needs over its whole lifetime: prompt plus
        decode growth up to ``max_tokens`` (``submit`` bounds this by the
        per-slot table capacity)."""
        return blocks_for(len(req.prompt) + req.max_tokens, self.block_size)

    def _free_budget(self) -> int:
        """Pool blocks neither allocated nor RESERVED for the future decode
        growth of already-admitted requests. Admission gates on this — not
        on the raw free count — so a tight pool serializes admissions
        instead of exhausting mid-decode (ensure() would raise out of
        ``tick()`` and kill every in-flight stream)."""
        reserved = sum(
            max(0, self._slot_budget[i] - int(self.alloc.n_owned[i]))
            + self._fork_pending[i]
            for i, r in enumerate(self.slot_req) if r is not None)
        return self.alloc.free_count - reserved

    # -- prefix caching (copy-on-write shared blocks) -------------------

    def _match_prefix(self, prompt) -> _PrefixMatch:
        """Look the prompt up in the prefix index. A FULL hit means shared
        blocks cover positions ``0..L-2`` (``ceil((L-1)/bs)`` blocks):
        prefill is skipped entirely and the first decode tick emits the
        first token, writing position ``L-1`` itself (forking the last
        shared block first when ``L-1`` falls inside it). A partial hit
        covers ``m = K*bs`` positions; the suffix prefills as one chunk."""
        if not self.prefix_cache:
            return _NO_MATCH
        L = len(prompt)
        if L < 2:
            return _NO_MATCH
        ids = self.prefix_index.match(np.asarray(prompt, np.int32))
        if not ids:
            return _NO_MATCH
        bs = self.block_size
        need_full = blocks_for(L - 1, bs)
        if len(ids) >= need_full:
            return _PrefixMatch(tuple(ids[:need_full]), L - 1, True,
                                1 if (L - 1) % bs else 0)
        return _PrefixMatch(tuple(ids), len(ids) * bs, False, 0)

    def _register_prefix(self, slot: int, req: Request) -> None:
        """Index the slot's full prompt blocks so later admissions can map
        them read-only. Decode writes never land in them (generated tokens
        start at position L >= full-block end); a full-hit sharer's write
        at L-1 forks first (copy-on-write guard)."""
        if self.prefix_index is None:
            return
        n_full = len(req.prompt) // self.block_size
        if n_full == 0 or int(self.alloc.lo[slot]) > 0:
            return
        ids = [int(b) for b in self.alloc.tables[slot, :n_full]]
        if any(b == self.alloc.sentinel for b in ids):
            return
        self.prefix_index.insert_chain(np.asarray(req.prompt, np.int32),
                                       ids)

    def _release_slot(self, slot: int) -> None:
        freed = self.alloc.release(slot) if self.alloc is not None else []
        self._fork_pending[slot] = 0
        if freed and self.prefix_index is not None:
            self.prefix_index.evict_blocks(freed)

    def _copy_block(self, src: int, dst: int) -> None:
        """Device-side page copy for a copy-on-write fork (every pool
        leaf; layer dim leads, block dim is axis 1)."""
        cache = self.state["cache"]
        for leaf in lm_helpers.PAGE_POOL_LEAVES:
            if leaf in cache:
                cache[leaf] = cache[leaf].at[:, dst].set(cache[leaf][:, src])

    def _cow_guard(self, slot: int, pos_lo: int, pos_hi: int) -> None:
        """Before device writes at positions ``[pos_lo, pos_hi)`` of a
        slot: fork shared blocks (the sharer gets a private copy — other
        holders keep the original) and evict solely-owned but still-indexed
        blocks from the prefix index (their content is about to diverge
        from the indexed token chain)."""
        if self.prefix_index is None or self.alloc is None:
            return
        bs = self.block_size
        hi = max(pos_hi, pos_lo + 1)
        for j in range(pos_lo // bs, (hi - 1) // bs + 1):
            if j >= int(self.alloc.n_owned[slot]):
                break
            b = int(self.alloc.tables[slot, j])
            if b == self.alloc.sentinel:
                continue
            if self.alloc.is_shared(b):
                src, dst = self.alloc.fork_cow(slot, j)
                self._copy_block(src, dst)
                self.scheduler.metrics["cow_forks"] += 1
            elif self.prefix_index.contains_block(b):
                self.prefix_index.evict_blocks([b])
        self._fork_pending[slot] = 0

    def _maybe_trim(self, slot: int) -> None:
        """Sliding-window models: free blocks wholly behind the attention
        window mid-flight (the validity mask already hides them). Refcount-
        aware — a shared prefix block outlives one slot's trim."""
        w = self.model.cfg.sliding_window
        if self.alloc is None or not w:
            return
        freed = self.alloc.trim_below(slot, self._slot_pos[slot] - w + 1)
        if freed and self.prefix_index is not None:
            self.prefix_index.evict_blocks(freed)

    def _take_admissible(self, n: int) -> List[Request]:
        """Pop up to ``n`` waiting requests FCFS. Under the paged layout,
        stop at the first whose lifetime block budget cannot be reserved
        (head-of-line admission keeps FCFS order; blocked work waits for
        retirements to free blocks)."""
        if self.alloc is None:
            return self.scheduler.take(n)
        out, budget = [], self._free_budget()
        while self.scheduler.waiting and len(out) < n:
            need = self._block_budget(self.scheduler.waiting[0])
            if need > budget:
                break
            budget -= need
            out.append(self.scheduler.waiting.popleft())
        return out

    def _admit(self) -> List[Request]:
        """Admit waiting requests into free slots (bucketed batched
        prefill, or chunked prefill when ``prefill_chunk`` is set). Returns
        requests retired AT admission (prefill token was EOS / one-token
        budget) — their slots are immediately reusable, so the loop keeps
        admitting while slots free up and work waits."""
        if self._pipe is not None:
            return self._admit_pipelined()
        if self.prefill_chunk is not None:
            return self._admit_chunked()
        if self.prefix_cache:
            return self._admit_prefix()
        retired: List[Request] = []
        while True:
            free = [i for i, r in enumerate(self.slot_req) if r is None]
            if not free or not self.scheduler.waiting:
                return retired
            reqs = self._take_admissible(len(free))
            if not reqs:
                return retired
            groups: Dict[int, List[Request]] = {}
            for r in reqs:
                groups.setdefault(self._bucket(len(r.prompt)), []).append(r)
            for Lb, group in sorted(groups.items()):
                B = len(group)
                Bp = 1 << (B - 1).bit_length()      # pad batch to a pow2
                tokens = np.zeros((Bp, Lb), np.int32)
                lens = np.ones((Bp,), np.int32)
                slots = np.full((Bp,), self.n_slots, np.int32)  # OOB = drop
                eos = np.full((Bp,), -1, np.int32)
                max_tok = np.ones((Bp,), np.int32)
                my_slots = []
                for j, r in enumerate(group):
                    tokens[j, :len(r.prompt)] = r.prompt
                    lens[j] = len(r.prompt)
                    slots[j] = free.pop(0)
                    my_slots.append(int(slots[j]))
                    eos[j] = -1 if r.eos_id is None else r.eos_id
                    max_tok[j] = r.max_tokens
                    if self.alloc is not None:
                        # reserved by _take_admissible: cannot fail
                        self.alloc.ensure(my_slots[j], len(r.prompt))
                        self._slot_budget[my_slots[j]] = self._block_budget(r)
                    self._slot_poscap[my_slots[j]] = \
                        len(r.prompt) + r.max_tokens
                self.scheduler.record_admit(group)
                self._sync_tables()
                nk, sk = self._next_keys(1, self._prefill_count)
                self._prefill_count += 1
                with obs_trace.get_tracer().span(
                        "serve.prefill_batch", {"bucket": Lb, "batch": B}):
                    self.state, payload = self._prefill_insert(
                        self._exec_params, self.state, jnp.asarray(tokens),
                        jnp.asarray(lens), jnp.asarray(slots),
                        jnp.asarray(eos), jnp.asarray(max_tok), nk, sk,
                        *self._fault_ctl_args())
                    # TTFT is stamped only once the token bytes are on host
                    payload = np.asarray(jax.device_get(payload))
                t_host = time.perf_counter()
                for j, r in enumerate(group):
                    r.t_first_token = t_host
                    self.scheduler.emit(r, int(payload[j, 0]))
                    if payload[j, 1]:
                        self._release_slot(my_slots[j])
                        retired.append(self.scheduler.retire(r))
                    else:
                        self.slot_req[my_slots[j]] = r
                        self._slot_pos[my_slots[j]] = len(r.prompt)

    def _admit_pipelined(self) -> List[Request]:
        """Pipelined whole-prompt admission: claim slots/blocks and hand
        the bucketed prefill COMPUTE to the worker thread; apply finished
        scatters here. Slots claimed at enqueue sit in ``self.prefilling``
        (decode excludes them, the gauge counts them, drain waits on
        them). Backpressure: stop claiming once ``pipeline_depth`` jobs
        are in flight. Noise/sample keys are assigned at enqueue in FCFS
        order — the same stream-1 counter schedule the sync path uses."""
        retired: List[Request] = []
        pipe = self._pipe
        while not pipe.full:
            free = [i for i, r in enumerate(self.slot_req) if r is None]
            if not free or not self.scheduler.waiting:
                break
            reqs = self._take_admissible(len(free))
            if not reqs:
                break
            groups: Dict[int, List[Request]] = {}
            for r in reqs:
                groups.setdefault(self._bucket(len(r.prompt)), []).append(r)
            # one take may submit a few groups past the depth bound; the
            # outer loop re-checks before claiming any further requests
            for Lb, group in sorted(groups.items()):
                B = len(group)
                Bp = 1 << (B - 1).bit_length()
                tokens = np.zeros((Bp, Lb), np.int32)
                lens = np.ones((Bp,), np.int32)
                slots = np.full((Bp,), self.n_slots, np.int32)
                eos = np.full((Bp,), -1, np.int32)
                max_tok = np.ones((Bp,), np.int32)
                my_slots = []
                for j, r in enumerate(group):
                    tokens[j, :len(r.prompt)] = r.prompt
                    lens[j] = len(r.prompt)
                    slots[j] = free.pop(0)
                    my_slots.append(int(slots[j]))
                    eos[j] = -1 if r.eos_id is None else r.eos_id
                    max_tok[j] = r.max_tokens
                    if self.alloc is not None:
                        self.alloc.ensure(my_slots[j], len(r.prompt))
                        self._slot_budget[my_slots[j]] = \
                            self._block_budget(r)
                    self._slot_poscap[my_slots[j]] = \
                        len(r.prompt) + r.max_tokens
                    # claim the slot now; decode skips it via prefilling
                    self.slot_req[my_slots[j]] = r
                self.scheduler.record_admit(group)
                nk, sk = self._next_keys(1, self._prefill_count)
                self._prefill_count += 1
                job = {"group": group, "my_slots": my_slots,
                       "tokens": tokens, "lens": lens, "slots": slots,
                       "eos": eos, "max_tok": max_tok, "nk": nk, "sk": sk,
                       "ctl": self._fault_ctl_args()}
                for j, r in enumerate(group):
                    self.prefilling.append(
                        {"req": r, "slot": my_slots[j], "pos": 0,
                         "job": job})
                pipe.submit(job)
        # apply finished computes; block for one when nothing else can
        # make progress (no decodable slot) and work is in flight
        mid = {e["slot"] for e in self.prefilling}
        can_decode = any(r is not None and i not in mid
                         for i, r in enumerate(self.slot_req))
        block = not can_decode and pipe.inflight > 0
        for job, out, err in pipe.collect(block=block):
            if err is not None:
                # worker crash mid-compute: the live state is untouched
                # (compute reads only params + prompt tokens, the scatter
                # never ran). Release the claimed slots/blocks and return
                # each request to the queue head for a bounded retry — one
                # crashed batch must not kill every stream on the engine.
                self.last_prefill_error = err
                self.prefilling = [e for e in self.prefilling
                                   if e["job"] is not job]
                for j in reversed(range(len(job["group"]))):
                    r = job["group"][j]
                    s = job["my_slots"][j]
                    if self.slot_req[s] is r:
                        self.slot_req[s] = None
                        self._release_slot(s)
                        self._slot_pos[s] = 0
                        self._slot_budget[s] = 0
                        self._slot_poscap[s] = 0
                    t = self._retry_or_fail(
                        r, f"prefill worker crash: {err}")
                    if t is not None:
                        retired.append(t)
                continue
            tok, new_cache, hvals = out
            self._sync_tables()
            with obs_trace.get_tracer().span(
                    "serve.prefill_scatter",
                    {"batch": len(job["group"])}):
                self.state, payload = self._prefill_scatter(
                    self.state, tok, new_cache, hvals,
                    jnp.asarray(job["slots"]), jnp.asarray(job["eos"]),
                    jnp.asarray(job["max_tok"]))
                payload = np.asarray(jax.device_get(payload))
            t_host = time.perf_counter()
            self.prefilling = [e for e in self.prefilling
                               if e["job"] is not job]
            for j, r in enumerate(job["group"]):
                s = job["my_slots"][j]
                r.t_first_token = t_host
                self.scheduler.emit(r, int(payload[j, 0]))
                if payload[j, 1]:
                    self.slot_req[s] = None
                    self._release_slot(s)
                    retired.append(self.scheduler.retire(r))
                else:
                    self._slot_pos[s] = len(r.prompt)
        return retired

    def _admit_prefix(self) -> List[Request]:
        """Admission with prefix caching: requests are admitted ONE at a
        time (each admission registers its prompt blocks before the next
        is matched, so a wave of same-prefix arrivals shares within the
        wave). Misses and partial hits prefill their unmatched suffix as a
        single chunk step at ``pos0 = matched``; full hits attach with no
        prefill. The head-of-line budget gate reserves the request's
        lifetime budget MINUS its shared blocks (plus one block for a
        deferred copy-on-write fork)."""
        retired: List[Request] = []
        while True:
            free = [i for i, r in enumerate(self.slot_req) if r is None]
            if not free or not self.scheduler.waiting:
                return retired
            head = self.scheduler.waiting[0]
            m = self._match_prefix(head.prompt)
            need = self._block_budget(head) - len(m.block_ids) + m.fork_extra
            if need > self._free_budget():
                return retired
            req = self.scheduler.waiting.popleft()
            retired.extend(self._admit_one(req, free[0], m))

    def _admit_one(self, req: Request, slot: int,
                   m: _PrefixMatch) -> List[Request]:
        L = len(req.prompt)
        self._slot_budget[slot] = self._block_budget(req)
        self._slot_poscap[slot] = L + req.max_tokens
        self._fork_pending[slot] = 0
        if m.block_ids:
            self.alloc.share(slot, m.block_ids)
            self.scheduler.metrics["prefix_hits"] += 1
            self.scheduler.metrics["prefix_shared_blocks"] += \
                len(m.block_ids)
        self.scheduler.record_admit([req])
        eos = -1 if req.eos_id is None else req.eos_id
        if m.full_hit:
            # no prefill at all: idx = L-1, emitted = 0; the next decode
            # tick writes position L-1 (forking its shared block first)
            # and emits the FIRST token — TTFT stamps there, on host
            self.scheduler.metrics["prefix_full_hits"] += 1
            self._fork_pending[slot] = m.fork_extra
            self.slot_req[slot] = req
            self._slot_pos[slot] = L - 1
            self._sync_tables()
            self.state = self._attach(
                self.state, jnp.asarray(slot, jnp.int32),
                jnp.asarray(int(req.prompt[L - 1]), jnp.int32),
                jnp.asarray(L - 1, jnp.int32), jnp.asarray(eos, jnp.int32),
                jnp.asarray(req.max_tokens, jnp.int32))
            return []
        # miss (m.m == 0) or partial hit: one chunk step over the suffix,
        # starting at the matched block boundary; attention families
        # right-pad to a power of two to bound compile counts
        self.alloc.ensure(slot, L)
        self._sync_tables()
        suffix = np.asarray(req.prompt[m.m:], np.int32)[None, :]
        C = L - m.m
        if self.pad_prefill and C > 1:
            Cp = 1 << (C - 1).bit_length()
            if Cp > C:
                suffix = np.pad(suffix, ((0, 0), (0, Cp - C)))
        nk, sk = self._next_keys(2, self._chunk_count)
        self._chunk_count += 1
        self.state, payload = self._chunk_last(
            self._exec_params, self.state, jnp.asarray(suffix),
            jnp.asarray(slot, jnp.int32), jnp.asarray(m.m, jnp.int32),
            jnp.asarray(C, jnp.int32), jnp.asarray(eos, jnp.int32),
            jnp.asarray(req.max_tokens, jnp.int32), nk, sk,
            *self._fault_ctl_args())
        payload = np.asarray(jax.device_get(payload))
        req.t_first_token = time.perf_counter()
        self._slot_pos[slot] = L
        self.scheduler.emit(req, int(payload[0, 0]))
        if payload[0, 1]:
            self._release_slot(slot)
            return [self.scheduler.retire(req)]
        self.slot_req[slot] = req
        self._register_prefix(slot, req)
        return []

    def _admit_chunked(self) -> List[Request]:
        """Chunked (piggybacked) prefill: waiting prompts claim a slot and
        their prompt's blocks up front, then stream through the decode loop
        ONE fixed-size chunk per tick — a long arrival adds one bounded
        chunk step to each tick instead of a whole-prompt prefill stall.
        The final chunk runs device-side token selection; TTFT is stamped
        only when that token materializes on host. Requests retired at the
        final chunk (EOS / one-token budget) free their slot immediately."""
        retired: List[Request] = []
        # claim slots + prompt blocks for as many waiting prompts as fit
        while self.scheduler.waiting:
            free = [i for i, r in enumerate(self.slot_req) if r is None]
            if not free:
                break
            head = self.scheduler.waiting[0]
            m = self._match_prefix(head.prompt)
            if self.alloc is not None and \
                    self._block_budget(head) - len(m.block_ids) + \
                    m.fork_extra > self._free_budget():
                break
            req = self.scheduler.waiting.popleft()
            slot = free[0]
            if self.alloc is not None:
                # reserve the lifetime budget but allocate lazily, one
                # chunk's worth at a time — queued prompts must not pin
                # pool blocks they won't write for many ticks
                self._slot_budget[slot] = self._block_budget(req)
            self._slot_poscap[slot] = len(req.prompt) + req.max_tokens
            self._fork_pending[slot] = 0
            self.slot_req[slot] = req
            self.scheduler.record_admit([req])
            if m.block_ids:
                self.alloc.share(slot, m.block_ids)
                self.scheduler.metrics["prefix_hits"] += 1
                self.scheduler.metrics["prefix_shared_blocks"] += \
                    len(m.block_ids)
            if m.full_hit:
                # skip the prefilling queue entirely (see _admit_one)
                self.scheduler.metrics["prefix_full_hits"] += 1
                self._fork_pending[slot] = m.fork_extra
                L = len(req.prompt)
                self._slot_pos[slot] = L - 1
                self._sync_tables()
                eos = -1 if req.eos_id is None else req.eos_id
                self.state = self._attach(
                    self.state, jnp.asarray(slot, jnp.int32),
                    jnp.asarray(int(req.prompt[L - 1]), jnp.int32),
                    jnp.asarray(L - 1, jnp.int32),
                    jnp.asarray(eos, jnp.int32),
                    jnp.asarray(req.max_tokens, jnp.int32))
            else:
                # chunks resume AFTER the shared prefix (pos0 = m.m)
                self.prefilling.append(
                    {"req": req, "slot": slot, "pos": m.m})
        # late prefix re-match: a request claimed while its prefix donor
        # was still mid-chunk finds the donor's blocks registered by the
        # time its own FIRST chunk runs — match then, not just at claim
        while self.prefilling:
            e = self.prefilling[0]
            req, slot = e["req"], e["slot"]
            if not (self.prefix_cache and e["pos"] == 0
                    and int(self.alloc.n_owned[slot]) == 0):
                break
            m = self._match_prefix(req.prompt)
            if m.block_ids:
                self.alloc.share(slot, m.block_ids)
                self.scheduler.metrics["prefix_hits"] += 1
                self.scheduler.metrics["prefix_shared_blocks"] += \
                    len(m.block_ids)
            if not m.full_hit:
                e["pos"] = m.m
                break
            self.scheduler.metrics["prefix_full_hits"] += 1
            self._fork_pending[slot] = m.fork_extra
            L = len(req.prompt)
            self._slot_pos[slot] = L - 1
            self._sync_tables()
            eos = -1 if req.eos_id is None else req.eos_id
            self.state = self._attach(
                self.state, jnp.asarray(slot, jnp.int32),
                jnp.asarray(int(req.prompt[L - 1]), jnp.int32),
                jnp.asarray(L - 1, jnp.int32), jnp.asarray(eos, jnp.int32),
                jnp.asarray(req.max_tokens, jnp.int32))
            self.prefilling.pop(0)
        if not self.prefilling:
            return retired
        # one chunk per tick, FCFS entry first (bounded per-tick latency)
        e = self.prefilling[0]
        req, slot, pos = e["req"], e["slot"], e["pos"]
        C = self.prefill_chunk
        take = min(C, len(req.prompt) - pos)
        last = pos + take >= len(req.prompt)
        toks = np.asarray(req.prompt[pos:pos + take], np.int32)[None, :]
        if self.pad_prefill and take < C:
            # attention families right-pad (masked); SSM/hybrid recurrences
            # need exact-length chunks, costing one compile per distinct
            # final-chunk length
            toks = np.pad(toks, ((0, 0), (0, C - take)))
        if self.alloc is not None:
            self.alloc.ensure(slot, pos + take)   # reserved: cannot fail
        self._sync_tables()
        nk, sk = self._next_keys(2, self._chunk_count)
        self._chunk_count += 1
        args = (self._exec_params, self.state, jnp.asarray(toks),
                jnp.asarray(slot, jnp.int32), jnp.asarray(pos, jnp.int32),
                jnp.asarray(take, jnp.int32))
        tr = obs_trace.get_tracer()
        if not last:
            with tr.span("serve.chunk", {"take": take}):
                self.state = self._chunk_mid(*args, nk,
                                             *self._fault_ctl_args())
            e["pos"] = pos + take
        else:
            eos = -1 if req.eos_id is None else req.eos_id
            with tr.span("serve.chunk", {"take": take, "last": True}):
                self.state, payload = self._chunk_last(
                    *args, jnp.asarray(eos, jnp.int32),
                    jnp.asarray(req.max_tokens, jnp.int32), nk, sk,
                    *self._fault_ctl_args())
                payload = np.asarray(jax.device_get(payload))
            req.t_first_token = time.perf_counter()
            self.prefilling.pop(0)
            self._slot_pos[slot] = len(req.prompt)
            self.scheduler.emit(req, int(payload[0, 0]))
            if payload[0, 1]:
                self.slot_req[slot] = None
                self._release_slot(slot)
                retired.append(self.scheduler.retire(req))
            else:
                self._register_prefix(slot, req)
        self.scheduler.metrics["prefill_chunks"] += 1
        return retired

    def tick(self) -> List[Request]:
        """Admit waiting requests (piggybacking one prefill chunk when
        chunked prefill is on), then decode one token for EVERY active slot
        in a single jitted call — or, with ``spec_k``, verify ``k`` drafted
        tokens per slot in a single jitted call.

        Robustness hooks run first: scheduled chaos faults apply for this
        tick, then queue/decode deadlines retire expired requests — every
        path out of the engine leaves a terminal ``Request.status``."""
        if self.scheduler.registry is not self._bound_registry:
            self._bind_observability()
        tr = obs_trace.get_tracer()
        t_tick = time.perf_counter()
        if self._injector is not None:
            self._apply_host_faults()
        with tr.span("serve.tick"):
            done = self._expire_deadlines()
            done.extend(self._tick_body(tr))
        self.scheduler.metrics["ticks"] += 1
        self._chaos_tick += 1
        self._ctl = None
        self._h_tick.observe(time.perf_counter() - t_tick)
        return done

    def _apply_host_faults(self) -> None:
        """Evaluate the fault schedule at this engine tick: refresh the
        traced channel controls and apply the host-side sites (block-pool
        squeeze, prefill-worker crash). The pool squeeze only ever takes
        blocks from the FREE budget — blocks reserved for already-admitted
        requests stay allocatable, so the ``reserved: cannot fail``
        invariants of the decode/chunk paths survive any schedule."""
        inj, t = self._injector, self._chaos_tick
        if self._use_fault_ctl:
            self._ctl = inj.controls(t, self._ctl_n_moduli)
        if self.alloc is not None:
            want = inj.pool_squeeze(t)
            have = len(self.alloc.quarantined)
            if want > have:
                take = min(want - have, max(0, self._free_budget()))
                if take > 0:
                    self.alloc.quarantine(take)
            elif want < have:
                self.alloc.unquarantine(
                    sorted(self.alloc.quarantined)[:have - want])
        if self._pipe is not None and inj.worker_crash(t):
            self._pipe.crash_next = True

    def _expire_deadlines(self) -> List[Request]:
        """Retire queued requests past their queue/total TTL and abort
        active slots past their total TTL (terminal status ``timed_out`` —
        deadlines are final, never retried). Slots mid-PIPELINED-prefill
        are skipped until their scatter lands (the worker job still
        references them); they expire on the next tick."""
        now = time.perf_counter()
        done: List[Request] = list(self.scheduler.expire_queued(now))
        pipe_mid = {e["slot"] for e in self.prefilling} \
            if self._pipe is not None else set()
        for i, req in enumerate(self.slot_req):
            if req is None or i in pipe_mid:
                continue
            if req.deadline(now):
                req.error = "deadline exceeded mid-flight"
                self._abort_slot(i)
                done.append(self.scheduler.retire(req, status="timed_out"))
        return done

    def _abort_slot(self, slot: int) -> None:
        """Tear a live slot down outside the normal retirement path
        (deadline / fault abort): drop host bookkeeping, release its
        blocks, and clear the device-side active bit eagerly so the next
        decode tick freezes the slot instead of emitting for it."""
        self.slot_req[slot] = None
        self.prefilling = [e for e in self.prefilling if e["slot"] != slot]
        self._release_slot(slot)
        self._slot_pos[slot] = 0
        self._slot_budget[slot] = 0
        self._slot_poscap[slot] = 0
        active = self.state["active"].at[slot].set(False)
        if self.mesh is not None:
            active = jax.device_put(active, self._state_sh["active"])
        self.state["active"] = active

    def _retry_or_fail(self, req: Request,
                       reason: str) -> Optional[Request]:
        """Fault-abort disposition: within the retry budget the request
        returns to the QUEUE HEAD with its stream reset (it restarts from
        scratch — emitted tokens are withdrawn, so a streaming consumer
        sees the retry as a new stream); past it the request retires with
        terminal status ``failed`` and ``error`` set. Returns the retired
        request, or None when requeued."""
        limit = req.max_retries if req.max_retries > 0 else self.max_retries
        if req.retries < limit:
            req.retries += 1
            req.tokens_out = []
            req.t_first_token = 0.0
            req.t_admit = 0.0
            req.status = "queued"
            self.scheduler.metrics["retried"] += 1
            self.scheduler.waiting.appendleft(req)
            return None
        req.error = reason
        return self.scheduler.retire(req, status="failed")

    def _tick_body(self, tr) -> List[Request]:
        with tr.span("serve.admit"):
            done: List[Request] = list(self._admit())
        mid_prefill = {e["slot"] for e in self.prefilling}
        decode_slots = [i for i, r in enumerate(self.slot_req)
                        if r is not None and i not in mid_prefill]
        if decode_slots and self.spec_k:
            done.extend(self._spec_tick(decode_slots))
        elif decode_slots:
            if self.alloc is not None:
                cap_pos = self.alloc.max_blocks_per_slot * self.block_size
                for i in decode_slots:
                    # this tick writes each slot's token at position
                    # _slot_pos[i]: fork/unindex a shared block there, then
                    # grow the table on block boundaries (reserved at
                    # admission — cannot exhaust; writes past the linear
                    # capacity drop on device, hence the clamp)
                    self._cow_guard(i, self._slot_pos[i],
                                    self._slot_pos[i] + 1)
                    self.alloc.ensure(i, min(self._slot_pos[i] + 1, cap_pos))
                self._sync_tables()
            nk, sk = self._next_keys(0, self._tick_count)
            self._tick_count += 1
            with tr.span("serve.decode", {"slots": len(decode_slots)}):
                self.state, payload = self._decode_tick(
                    self._exec_params, self.state, nk, sk,
                    *self._fault_ctl_args())
            with tr.span("serve.host_sync"):
                # the ONE transfer
                payload = np.asarray(jax.device_get(payload))
            vocab = self.model.cfg.vocab_size
            if self._injector is not None:
                payload = payload.copy()  # device_get views are read-only
                payload[:, 0] = self._injector.corrupt_tokens(
                    self._chaos_tick, payload[:, 0], vocab)
            t_host = time.perf_counter()
            for i, (tok, is_done) in enumerate(payload):
                req = self.slot_req[i]
                if req is None or tok < 0:
                    continue
                if tok >= vocab:
                    # out-of-vocab token = corrupted device->host transfer:
                    # the stream can no longer be trusted — abort the slot
                    # and retry the request from scratch (bounded)
                    self._abort_slot(i)
                    t = self._retry_or_fail(req, "corrupted host transfer")
                    if t is not None:
                        done.append(t)
                    continue
                self._slot_pos[i] += 1
                if req.t_first_token == 0.0:
                    # full-prefix-hit admissions skip prefill entirely —
                    # their FIRST token is this tick's, so TTFT stamps at
                    # its host materialization, not at admission
                    req.t_first_token = t_host
                self.scheduler.emit(req, int(tok))
                if is_done:
                    self.slot_req[i] = None
                    self._release_slot(i)
                    done.append(self.scheduler.retire(req))
                else:
                    self._maybe_trim(i)
        return done

    def _spec_tick(self, decode_slots: List[int]) -> List[Request]:
        """One speculative tick: host-side prompt-lookup drafts for every
        decoding slot, ONE jitted verify over all ``k+1`` positions,
        leading-ones acceptance (token-identical to greedy decode). Still
        exactly one device→host transfer per tick — now ``(S, k+2)``."""
        k = self.spec_k
        drafts = np.zeros((self.n_slots, k), np.int32)
        for i in decode_slots:
            req = self.slot_req[i]
            ctx = np.concatenate([np.asarray(req.prompt, np.int32),
                                  np.asarray(req.tokens_out, np.int32)])
            drafts[i] = _lookup_draft(ctx, k)
        if self.alloc is not None:
            cap_pos = self.alloc.max_blocks_per_slot * self.block_size
            for i in decode_slots:
                p0 = self._slot_pos[i]
                # the verify writes positions [p0, p0+k]: fork/unindex
                # shared blocks in that range, then map blocks up to the
                # request's own position cap — accepted tokens always fit
                # under it (the budget mask caps acceptance first), so
                # drafted positions past it may drop on device, never KV
                # the request will read
                self._cow_guard(i, p0, p0 + k + 1)
                self.alloc.ensure(i, min(
                    p0 + 1 + k, max(self._slot_poscap[i], p0 + 1), cap_pos))
            self._sync_tables()
        nk, _ = self._next_keys(0, self._tick_count)
        self._tick_count += 1
        tr = obs_trace.get_tracer()
        with tr.span("serve.verify", {"slots": len(decode_slots), "k": k}):
            self.state, payload = self._verify_tick(
                self._exec_params, self.state, jnp.asarray(drafts), nk,
                *self._fault_ctl_args())
        with tr.span("serve.host_sync"):
            payload = np.asarray(jax.device_get(payload))
        vocab = self.model.cfg.vocab_size
        if self._injector is not None:
            payload = payload.copy()  # device_get views are read-only
            payload[:, :k + 1] = self._injector.corrupt_tokens(
                self._chaos_tick, payload[:, :k + 1], vocab)
        t_host = time.perf_counter()
        done: List[Request] = []
        self.scheduler.metrics["spec_ticks"] += 1
        for i in decode_slots:
            req = self.slot_req[i]
            if np.any(payload[i, :k + 1] >= vocab):
                # corrupted transfer (see _tick_body): abort + retry
                self._abort_slot(i)
                t = self._retry_or_fail(req, "corrupted host transfer")
                if t is not None:
                    done.append(t)
                continue
            is_done = payload[i, k + 1]
            n_acc = 0
            for t in payload[i, :k + 1]:
                if t < 0:
                    break
                n_acc += 1
                self._slot_pos[i] += 1
                if req.t_first_token == 0.0:
                    req.t_first_token = t_host
                self.scheduler.emit(req, int(t))
            self.scheduler.metrics["spec_slot_ticks"] += 1
            self.scheduler.metrics["spec_accepted"] += n_acc
            if is_done:
                self.slot_req[i] = None
                self._release_slot(i)
                done.append(self.scheduler.retire(req))
            else:
                self._maybe_trim(i)
        return done

    def run_until_drained(self, max_ticks: int = 10_000) -> List[Request]:
        finished: List[Request] = []
        for _ in range(max_ticks):
            if not self.scheduler.waiting and \
                    all(r is None for r in self.slot_req):
                break
            finished.extend(self.tick())
        return finished

    def drain(self, max_ticks: int = 10_000) -> List[Request]:
        """Graceful drain: stop admitting NEW work (``submit`` raises
        :class:`AdmissionRejected` while draining) but run every queued
        and in-flight request to a terminal status."""
        self._draining = True
        try:
            return self.run_until_drained(max_ticks)
        finally:
            self._draining = False

    def shutdown(self, max_ticks: int = 10_000) -> List[Request]:
        """Teardown: reject everything still WAITING (terminal status
        ``rejected`` — a restart would re-run their prefills anyway),
        drain the in-flight slots to completion, stop the pipeline
        worker. Returns every request retired here."""
        self._draining = True
        out: List[Request] = []
        while self.scheduler.waiting:
            r = self.scheduler.waiting.popleft()
            r.error = "server shutting down"
            out.append(self.scheduler.retire(r, status="rejected"))
        out.extend(self.run_until_drained(max_ticks))
        self.close()
        return out

    # ------------------------------------------------------------------
    # crash-consistent snapshots + backend switching
    # ------------------------------------------------------------------

    _SNAP_VERSION = 1

    @staticmethod
    def _req_to_dict(r: Request) -> Dict[str, Any]:
        return {"rid": r.rid, "prompt": np.asarray(r.prompt, np.int32),
                "max_tokens": int(r.max_tokens), "eos_id": r.eos_id,
                "tokens_out": list(r.tokens_out),
                "t_enqueue": r.t_enqueue, "t_admit": r.t_admit,
                "t_first_token": r.t_first_token, "t_done": r.t_done,
                "status": r.status, "ttl_s": r.ttl_s,
                "queue_ttl_s": r.queue_ttl_s,
                "max_retries": int(r.max_retries),
                "retries": int(r.retries), "error": r.error}

    def snapshot(self) -> Dict[str, Any]:
        """Crash-consistent engine snapshot: ONE picklable host pytree
        holding the device state, allocator tables, prefix index, request
        queues, RNG base keys and their counters. Restoring it — into this
        engine or an identically-configured one in a fresh process —
        resumes token-identical streams (per-tick analog noise included:
        the keys and counters replay the exact fold schedule). Requires a
        quiescent prefill pipeline (in-flight worker compute is thread
        state that cannot be captured consistently) — tick until
        ``_pipe.inflight == 0`` first."""
        if self._pipe is not None and self._pipe.inflight:
            raise RuntimeError(
                "snapshot requires a quiescent prefill pipeline (tick "
                "until no prefill is in flight)")
        live: Dict[int, Request] = {}
        for r in list(self.scheduler.waiting) + \
                [e["req"] for e in self.prefilling] + \
                [x for x in self.slot_req if x is not None]:
            live[r.rid] = r
        return {
            "version": self._SNAP_VERSION,
            "n_slots": self.n_slots,
            "state": jax.device_get(self.state),
            "alloc": copy.deepcopy(self.alloc.__dict__)
            if self.alloc is not None else None,
            "prefix": copy.deepcopy(self.prefix_index.__dict__)
            if self.prefix_index is not None else None,
            "requests": {rid: self._req_to_dict(r)
                         for rid, r in live.items()},
            "waiting": [r.rid for r in self.scheduler.waiting],
            "slot_req": [r.rid if r is not None else None
                         for r in self.slot_req],
            "prefilling": [{"rid": e["req"].rid, "slot": e["slot"],
                            "pos": e["pos"]} for e in self.prefilling],
            "slot_pos": list(self._slot_pos),
            "slot_budget": list(self._slot_budget),
            "slot_poscap": list(self._slot_poscap),
            "fork_pending": list(self._fork_pending),
            "counters": {"tick": self._tick_count,
                         "prefill": self._prefill_count,
                         "chunk": self._chunk_count,
                         "chaos": self._chaos_tick},
            "keys": {"noise": np.asarray(self._noise_base),
                     "sample": np.asarray(self._sample_base)},
            "metrics": {k: int(v)
                        for k, v in self.scheduler.metrics.items()
                        if k != "prefilling"},
            "finished_rids": [r.rid for r in self.scheduler.finished],
        }

    def restore(self, snap: Dict[str, Any],
                requests: Optional[Dict[int, Request]] = None) -> None:
        """Load a :meth:`snapshot` back into this engine (same model /
        policy / topology configuration). ``requests`` optionally maps
        rid -> live ``Request`` objects to mutate in place — the
        guardian's rollback path, which must keep object identity across
        the restore; without it, requests are rebuilt from the snapshot
        (the fresh-process crash-recovery path; requests already finished
        at snapshot time are not reconstructed — their streams were
        delivered before the crash)."""
        if snap.get("version") != self._SNAP_VERSION:
            raise ValueError(f"snapshot version {snap.get('version')!r} != "
                             f"engine version {self._SNAP_VERSION}")
        if snap["n_slots"] != self.n_slots:
            raise ValueError(f"snapshot has {snap['n_slots']} slots, "
                             f"engine has {self.n_slots}")
        if self._pipe is not None and self._pipe.inflight:
            raise RuntimeError("cannot restore over in-flight prefills")
        state = jax.tree_util.tree_map(jnp.asarray, snap["state"])
        if self.mesh is not None:
            state = jax.device_put(state, self._state_sh)
        self.state = state
        if self.alloc is not None and snap["alloc"] is not None:
            self.alloc.__dict__.clear()
            self.alloc.__dict__.update(copy.deepcopy(snap["alloc"]))
            self.alloc.dirty = True
            self._sync_tables()
        if self.prefix_index is not None and snap["prefix"] is not None:
            self.prefix_index.__dict__.clear()
            self.prefix_index.__dict__.update(copy.deepcopy(snap["prefix"]))
        pool: Dict[int, Request] = {
            r.rid: r for r in self.scheduler.finished}
        for r in list(self.scheduler.waiting) + \
                [x for x in self.slot_req if x is not None] + \
                [e["req"] for e in self.prefilling]:
            pool[r.rid] = r
        if requests:
            pool.update(requests)

        def revive(rid: int) -> Request:
            d = snap["requests"][rid]
            r = pool.get(rid)
            if r is None:
                r = Request(rid=d["rid"],
                            prompt=np.asarray(d["prompt"], np.int32),
                            max_tokens=d["max_tokens"],
                            eos_id=d["eos_id"])
                pool[rid] = r
            r.tokens_out = list(d["tokens_out"])
            r.t_enqueue = d["t_enqueue"]
            r.t_admit = d["t_admit"]
            r.t_first_token = d["t_first_token"]
            r.t_done = d["t_done"]
            r.status = d["status"]
            r.ttl_s = d["ttl_s"]
            r.queue_ttl_s = d["queue_ttl_s"]
            r.max_retries = d["max_retries"]
            r.retries = d["retries"]
            r.error = d["error"]
            return r

        self.scheduler.waiting = collections.deque(
            revive(rid) for rid in snap["waiting"])
        self.slot_req = [revive(rid) if rid is not None else None
                         for rid in snap["slot_req"]]
        self.prefilling = [{"req": revive(e["rid"]), "slot": e["slot"],
                            "pos": e["pos"]} for e in snap["prefilling"]]
        self._slot_pos = list(snap["slot_pos"])
        self._slot_budget = list(snap["slot_budget"])
        self._slot_poscap = list(snap["slot_poscap"])
        self._fork_pending = list(snap["fork_pending"])
        c = snap["counters"]
        self._tick_count = c["tick"]
        self._prefill_count = c["prefill"]
        self._chunk_count = c["chunk"]
        self._chaos_tick = c["chaos"]
        self._ctl = None
        self._noise_base = jnp.asarray(snap["keys"]["noise"])
        self._sample_base = jnp.asarray(snap["keys"]["sample"])
        for k, v in snap["metrics"].items():
            self.scheduler.metrics[k] = v
        self.scheduler.finished = [
            pool[rid] for rid in snap["finished_rids"] if rid in pool]

    def switch_backend(self, new_policy) -> None:
        """Reprogram the engine's numeric backend mid-flight — the
        SNR-adaptive degradation path (:mod:`repro.runtime.resilience`):
        rebuild the model on ``new_policy`` (e.g. escalated RRNS
        redundancy, or the fp32 hard-fallback), re-encode stationary
        residues from the RAW params (residue coding is policy-specific),
        swap the analog-health accumulators to the new policy's spec, and
        rebuild every jitted step. In-flight KV/recurrent state is plain
        numeric state, not policy-coded — live streams continue under the
        new backend from their current positions."""
        if self._pipe is not None and self._pipe.inflight:
            raise RuntimeError(
                "cannot switch backends with pipelined prefills in flight")
        from repro.models.registry import build_model
        self.model = build_model(self.model.cfg, new_policy, self.model.opt)
        if self.stationary_weights:
            from repro.core import backends as _backends
            from repro.core import stationary
            if _backends.resolve(new_policy).supports_stationary_residues:
                self._exec_params = stationary.encode_stationary_params(
                    self.params, new_policy)
            else:
                self._exec_params = self.params
        else:
            self._exec_params = self.params
        self._health_spec = obs_health.spec(new_policy) \
            if self.instrument else {}
        if self._health_spec:
            from repro.analog import rrns as rrns_mod
            self._health_moduli = (
                rrns_mod.rrns_moduli(new_policy)
                if new_policy.mode in ("mirage_rrns", "mirage_rrns_ref")
                else tuple(new_policy.moduli))
        else:
            self._health_moduli = ()
        state = dict(self.state)
        state.pop("health", None)
        if self._health_spec:
            state["health"] = obs_health.init(self._health_spec)
        self.state = state
        seed = new_policy.noise_seed \
            if new_policy.noise_seed is not None else 0
        self._noise_base = jax.random.PRNGKey(seed)
        self._compute_fault_ctl()
        self._ctl = None
        self._place_on_mesh()
        self._build_steps()

    # ------------------------------------------------------------------
    # AOT warmup
    # ------------------------------------------------------------------

    def compile_counts(self) -> Dict[str, int]:
        """Per-step jit-cache sizes — the no-recompile assertion hook:
        snapshot after :meth:`warmup`, drain traffic, snapshot again;
        equal dicts mean the drain hit only warmed shapes."""
        out: Dict[str, int] = {}
        for name in ("_decode_tick", "_prefill_insert", "_prefill_compute",
                     "_prefill_scatter", "_chunk_mid", "_chunk_last",
                     "_attach", "_verify_tick"):
            fn = getattr(self, name, None)
            if fn is not None and hasattr(fn, "_cache_size"):
                out[name.lstrip("_")] = int(fn._cache_size())
        return out

    def warmup(self) -> Dict[str, float]:
        """AOT-compile every (bucket, batch) prefill shape plus the
        tick/verify/chunk steps before traffic, by running the REAL jitted
        steps against the idle state (donation-compatible — no state
        copies):

          * prefill warms target out-of-bounds slot ids, so every scatter
            drops device-side;
          * tick/verify on the all-inactive state are state-preserving by
            design (the active mask freezes idx/recurrent state; garbage
            KV lands where admission overwrites it);
          * chunk/attach warms touch one slot's control leaves and
            recurrent state — those few small leaves are snapshot before
            and restored after, which is also why warmup requires an IDLE
            engine.

        Warmup keys come from their own stream (3): the real tick/prefill
        counters are untouched, so a warmed engine emits the exact token
        streams of a cold one, including under per-tick analog noise.

        Attention families pad prompts to the configured buckets and
        batches to powers of two, so coverage is complete; exact-length
        families (SSM kind) are warmed at the bucket lengths only — other
        prompt lengths still compile on first arrival. Records
        ``serve_warmup_seconds`` / ``serve_warmup_compiled`` gauges and
        returns ``{"seconds": ..., "compiled": ...}``."""
        if self.scheduler.waiting or self.prefilling or \
                any(r is not None for r in self.slot_req):
            raise RuntimeError(
                "warmup requires an idle engine — run it before traffic")
        t0 = time.perf_counter()
        before = sum(self.compile_counts().values())
        nk, sk = self._next_keys(3, 0)
        # fault-wrapped steps warm with identity controls (bit-identical
        # to the unwrapped trace; same compile serves live fault values)
        fc = (analog_channel.identity_fault_controls(self._ctl_n_moduli),) \
            if self._use_fault_ctl else ()
        cache = self.state["cache"]
        saved = jax.device_get({
            "state": {k: v for k, v in self.state.items() if k != "cache"},
            "cache": {k: cache[k] for k in ("idx", "ssm", "conv")
                      if k in cache}})

        # every (bucket, batch) prefill shape admission can produce:
        # batches pad to powers of two up to the first pow2 >= n_slots
        batches, b = [], 1
        while b < self.n_slots:
            batches.append(b)
            b <<= 1
        batches.append(b)
        oob = self.n_slots
        for Lb in self.buckets:
            for B in batches:
                tokens = jnp.zeros((B, Lb), jnp.int32)
                lens = jnp.ones((B,), jnp.int32)
                slots = jnp.full((B,), oob, jnp.int32)
                eos = jnp.full((B,), -1, jnp.int32)
                mt = jnp.ones((B,), jnp.int32)
                if self._pipe is not None:
                    tok, nc, hv = self._prefill_compute(
                        self._exec_params, tokens, lens, nk, sk, *fc)
                    self.state, _ = self._prefill_scatter(
                        self.state, tok, nc, hv, slots, eos, mt)
                else:
                    self.state, _ = self._prefill_insert(
                        self._exec_params, self.state, tokens, lens, slots,
                        eos, mt, nk, sk, *fc)
        self.state, _ = self._decode_tick(self._exec_params, self.state,
                                          nk, sk, *fc)
        if self.spec_k:
            drafts = jnp.zeros((self.n_slots, self.spec_k), jnp.int32)
            self.state, _ = self._verify_tick(self._exec_params, self.state,
                                              drafts, nk, *fc)
        z = jnp.asarray(0, jnp.int32)
        if self.prefill_chunk is not None or self.prefix_cache:
            sizes = set()
            if self.prefill_chunk is not None:
                sizes.add(self.prefill_chunk)
            if self.prefix_cache and self.pad_prefill:
                # _admit_one pads the unmatched suffix to a power of two
                c = 1
                while c < self.buckets[-1]:
                    sizes.add(c)
                    c <<= 1
                sizes.add(c)
            for C in sorted(sizes):
                toks = jnp.zeros((1, C), jnp.int32)
                if self.prefill_chunk is not None and C == self.prefill_chunk:
                    self.state = self._chunk_mid(
                        self._exec_params, self.state, toks, z, z,
                        jnp.asarray(C, jnp.int32), nk, *fc)
                self.state, _ = self._chunk_last(
                    self._exec_params, self.state, toks, z, z,
                    jnp.asarray(C, jnp.int32), jnp.asarray(-1, jnp.int32),
                    jnp.asarray(1, jnp.int32), nk, sk, *fc)
        if self.prefix_cache:
            self.state = self._attach(self.state, z, z, z,
                                      jnp.asarray(-1, jnp.int32),
                                      jnp.asarray(1, jnp.int32))
        # restore the touched control/recurrent leaves; the next sharded
        # call re-places the (uncommitted) restored arrays via in_shardings
        self.state = dict(self.state,
                          **{k: jnp.asarray(v)
                             for k, v in saved["state"].items()})
        self.state["cache"] = dict(self.state["cache"],
                                   **{k: jnp.asarray(v)
                                      for k, v in saved["cache"].items()})
        dt = time.perf_counter() - t0
        compiled = sum(self.compile_counts().values()) - before
        reg = self.scheduler.registry
        reg.gauge("serve_warmup_seconds",
                  help="AOT warmup walltime (compile every serving shape "
                       "before traffic)").set(dt)
        reg.gauge("serve_warmup_compiled",
                  help="jit entries compiled by warmup").set(compiled)
        return {"seconds": dt, "compiled": float(compiled)}

    def close(self) -> None:
        """Stop the prefill pipeline worker thread (idempotent; the engine
        itself needs no teardown)."""
        if getattr(self, "_pipe", None) is not None:
            self._pipe.close()
            self._pipe = None

    def resize_slots(self, new_slots: int) -> None:
        """Elastic slot-count change mid-flight (scale with offered load).
        Active slots are compacted to the front of the new stacked cache;
        under the paged layout the page POOL is untouched (block ids are
        stable) — only the table rows and allocator bookkeeping move."""
        from repro.runtime.elastic import resize_serving_state
        if self.prefilling:
            raise RuntimeError(
                "cannot resize slots while chunked prefill is in flight")
        keep = [i for i, r in enumerate(self.slot_req) if r is not None]
        if len(keep) > new_slots:
            raise ValueError(
                f"cannot shrink to {new_slots} slots with {len(keep)} active")
        self.state = resize_serving_state(self.model, self.state, self.cap,
                                          new_slots, keep)
        if self.alloc is not None:
            freed = self.alloc.remap_slots(keep, new_slots)
            if freed and self.prefix_index is not None:
                self.prefix_index.evict_blocks(freed)
            self._sync_tables()
        self.slot_req = [self.slot_req[i] for i in keep] + \
            [None] * (new_slots - len(keep))
        self._slot_pos = [self._slot_pos[i] for i in keep] + \
            [0] * (new_slots - len(keep))
        self._slot_budget = [self._slot_budget[i] for i in keep] + \
            [0] * (new_slots - len(keep))
        self._slot_poscap = [self._slot_poscap[i] for i in keep] + \
            [0] * (new_slots - len(keep))
        self._fork_pending = [self._fork_pending[i] for i in keep] + \
            [0] * (new_slots - len(keep))
        self.n_slots = new_slots
        self._refresh_placement()

    def resize_block_pool(self, new_n_blocks: int) -> None:
        """Elastic block-pool resize (grow under admission pressure, shrink
        after a long-context burst retires). Live blocks are compacted to
        the front of the new pool, page arrays move with them, and every
        block table is rewritten — live requests keep decoding their exact
        continuations."""
        if self.alloc is None:
            raise RuntimeError(
                "block pool resize requires cache_layout='paged'")
        from repro.runtime.elastic import resize_block_pool
        # the allocator's shard-preserving compaction returns the explicit
        # renumbering (NOT simple sorted order once n_shards > 1); the
        # prefix index follows the same map — shared/indexed blocks keep
        # their refcounts, only their ids move
        self.state, old_ids, new_ids = resize_block_pool(
            self.state, self.alloc, new_n_blocks)
        if self.prefix_index is not None:
            self.prefix_index.remap(
                {int(o): int(n) for o, n in zip(old_ids, new_ids)})
        self._sync_tables()
        self._refresh_placement()

    # ------------------------------------------------------------------
    # observability
    # ------------------------------------------------------------------

    def _bind_observability(self) -> None:
        """Attach engine-derived gauges and the analog-health collector to
        the CURRENT scheduler's registry. Idempotent per registry and
        re-run lazily whenever ``self.scheduler`` is swapped for a fresh
        one (the serving benchmark does this between load points), so the
        exposition always reflects the live scheduler."""
        reg = self.scheduler.registry
        self._bound_registry = reg
        m = self.scheduler.metrics
        # the satellite fix: "prefilling" is derived from the in-flight
        # list in exactly one place — here — instead of being assigned on
        # some code paths and reset on others
        m.bind_prefilling(lambda: len(self.prefilling))
        reg.gauge_fn("serve_prefilling", lambda: len(self.prefilling),
                     help="requests admitted but still streaming their "
                          "prompt (chunked prefill in flight)")
        reg.gauge_fn("serve_slots_active",
                     lambda: sum(r is not None for r in self.slot_req),
                     help="slots holding a live request")
        reg.gauge_fn("serve_prefix_hit_rate",
                     lambda: (m["prefix_hits"] / m["admitted"])
                     if m["admitted"] else 0.0,
                     help="fraction of admissions that mapped shared "
                          "prefix blocks")
        reg.gauge_fn("serve_spec_accept_per_slot_tick",
                     lambda: (m["spec_accepted"] / m["spec_slot_ticks"])
                     if m["spec_slot_ticks"] else 0.0,
                     help="mean draft tokens accepted per per-slot "
                          "verify step")
        self._h_tick = reg.histogram(
            "serve_tick_seconds", help="engine tick walltime (admit + "
                                       "decode/verify + host sync)")
        if self.alloc is not None:
            alloc = self.alloc
            reg.gauge_fn("serve_block_pool_in_use",
                         lambda: alloc.used_count,
                         help="page-pool blocks with refcount > 0")
            reg.gauge_fn("serve_block_pool_occupancy",
                         lambda: alloc.occupancy,
                         help="in-use fraction of the page pool")
            reg.gauge_fn("serve_block_pool_fragmentation",
                         lambda: alloc.fragmentation,
                         help="free holes inside the live block region as "
                              "a fraction of that region (0 = compact)")
            # block-locality telemetry: how well the per-shard free lists
            # kept page-gather decode local (single-shard pools read
            # local=everything, spilled=0, remote=0)
            reg.gauge_fn("serve_block_local_allocs",
                         lambda: alloc.local_allocs,
                         help="block allocations on the owning slot's "
                              "home data-shard")
            reg.gauge_fn("serve_block_spilled_allocs",
                         lambda: alloc.spilled_allocs,
                         help="block allocations that fell to a remote "
                              "shard (home free list was dry)")
            reg.gauge_fn("serve_block_remote_fraction",
                         lambda: alloc.remote_fraction(),
                         help="fraction of live table references whose "
                              "block lives off the slot's shard — each is "
                              "a cross-shard gather every decode tick")

            def _collect_shard_depth(r, _alloc=alloc):
                g = r.gauge("serve_block_free_per_shard",
                            help="free-list depth per data shard of the "
                                 "page pool", label_names=("shard",))
                for k, v in enumerate(_alloc.free_by_shard()):
                    g.labels(str(k)).set(v)

            reg.add_collector(_collect_shard_depth)
        if self._health_spec:
            reg.add_collector(self._collect_health)

    def health_snapshot(self) -> Dict[str, Any]:
        """Current analog-health counters as plain ints/lists. Costs ONE
        ``jax.device_get`` of the accumulator dict — never called from the
        tick path; the registry collector invokes it once per scrape.
        Empty for deterministic backends."""
        h = self.state.get("health")
        if h is None:
            return {}
        h = jax.device_get(h)
        return {k: (int(v) if np.ndim(v) == 0 else
                    [int(x) for x in np.asarray(v)])
                for k, v in h.items()}

    def _collect_health(self, reg) -> None:
        for name, val in self.health_snapshot().items():
            if isinstance(val, list):
                g = reg.gauge(f"serve_health_{name}",
                              help="per-channel analog fault counter",
                              label_names=("channel",))
                for mod, v in zip(self._health_moduli, val):
                    g.labels(str(mod)).set(v)
            else:
                reg.gauge(f"serve_health_{name}",
                          help="analog fault counter").set(val)

    @property
    def metrics(self) -> Dict[str, Any]:
        if self.scheduler.registry is not self._bound_registry:
            self._bind_observability()
        return self.scheduler.metrics


class PerSlotLMServer:
    """The seed's slot-at-a-time decode loop — kept ONLY as the parity
    oracle for the batched engine (token-exact under greedy decode) and as
    the baseline of ``benchmarks/bench_serving.py``. Each tick runs one
    batch-1 jitted decode + one host sync per active slot."""

    def __init__(self, model, params, cap: int, batch_slots: int = 8,
                 greedy: bool = True):
        self.model = model
        self.params = params
        self.cap = cap
        self.greedy = greedy
        self.slots: List[Optional[Request]] = [None] * batch_slots
        self.waiting: collections.deque[Request] = collections.deque()
        self._prefill = jax.jit(
            lambda p, toks: model.prefill(p, toks, cap))
        self._decode = jax.jit(model.decode_step)
        self._caches: List[Any] = [None] * batch_slots
        self.metrics = {"completed": 0, "tokens": 0, "ticks": 0}

    def submit(self, req: Request):
        req.t_enqueue = time.perf_counter()
        self.waiting.append(req)

    def _admit(self):
        done = []
        for i in range(len(self.slots)):
            while self.slots[i] is None and self.waiting:
                req = self.waiting.popleft()
                req.t_admit = time.perf_counter()
                logits, cache = self._prefill(
                    self.params, jnp.asarray(req.prompt)[None, :])
                tok = int(jnp.argmax(logits[0, -1]))   # materializes on host
                req.t_first_token = time.perf_counter()
                req.tokens_out.append(tok)
                if (req.eos_id is not None and tok == req.eos_id) or \
                        req.max_tokens <= 1:
                    # retired at admission; the slot stays free
                    req.t_done = time.perf_counter()
                    self.metrics["completed"] += 1
                    self.metrics["tokens"] += len(req.tokens_out)
                    done.append(req)
                    continue
                self.slots[i] = req
                self._caches[i] = cache
        return done

    def _retire(self, i: int):
        req = self.slots[i]
        req.t_done = time.perf_counter()
        self.metrics["completed"] += 1
        self.metrics["tokens"] += len(req.tokens_out)
        self.slots[i] = None
        self._caches[i] = None
        return req

    def tick(self) -> List[Request]:
        """Admit waiting requests, decode one token for each active slot."""
        done = list(self._admit())
        for i, req in enumerate(self.slots):
            if req is None:
                continue
            last = jnp.asarray([[req.tokens_out[-1]]], jnp.int32)
            logits, self._caches[i] = self._decode(
                self.params, self._caches[i], last)
            tok = int(jnp.argmax(logits[0, -1]))
            req.tokens_out.append(tok)
            if (req.eos_id is not None and tok == req.eos_id) or \
                    len(req.tokens_out) >= req.max_tokens:
                done.append(self._retire(i))
        self.metrics["ticks"] += 1
        return done

    def run_until_drained(self, max_ticks: int = 10_000) -> List[Request]:
        finished = []
        for _ in range(max_ticks):
            if not self.waiting and all(s is None for s in self.slots):
                break
            finished.extend(self.tick())
        return finished
