"""Training runtime: train state, step function, microbatch accumulation,
fault-tolerance hooks. Pure functions — distribution comes entirely from the
sharding specs the launcher attaches via jit in/out_shardings.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.optim import grad_compress
from repro.optim.optimizers import clip_by_global_norm, make_optimizer
from repro.optim.schedules import constant


def init_train_state(model, train_cfg: TrainConfig, key) -> Dict[str, Any]:
    params = model.init(key)
    opt_init, _ = make_optimizer(train_cfg)
    state = {"params": params, "opt": opt_init(params),
             "step": jnp.zeros((), jnp.int32)}
    if train_cfg.grad_compression == "bfp":
        state["err"] = grad_compress.init_error_buffer(params)
    return state


def abstract_train_state(model, train_cfg: TrainConfig):
    """ShapeDtypeStruct tree of the train state — no allocation (dry-run)."""
    return jax.eval_shape(
        lambda k: init_train_state(model, train_cfg, k), jax.random.PRNGKey(0))


_QUANT_LEAF = ("w", "emb", "gate", "up", "down")


def _prequantize_params(params, policy, dtype):
    """Weight-stationary quantization: put every GEMM weight on the BFP grid
    ONCE (grouped along its contraction dim = axis -2), exactly as the
    photonic core programs a tile once and streams inputs against it.
    BFP(b_m<=6) grid values are bf16-exact, so bf16 storage is lossless."""
    from repro.core import bfp

    def q(path, p):
        keys = [k.key if hasattr(k, "key") else str(k) for k in path]
        leaf = keys[-1]
        if p.ndim < 2 or leaf not in _QUANT_LEAF:
            return p
        if leaf == "emb":
            return p  # embedding gathers stay FP32 (digital side in paper)
        moved = jnp.moveaxis(p, -2, -1)
        qv = bfp.bfp_fake_quant(moved, policy.b_m, policy.g, policy.rounding)
        return jnp.moveaxis(qv, -1, -2).astype(dtype)

    return jax.tree_util.tree_map_with_path(q, params)


def make_train_step(model, train_cfg: TrainConfig,
                    lr_schedule: Optional[Callable] = None):
    """Returns train_step(state, batch) -> (state, metrics).

    Microbatching: batch is split along axis 0 into `microbatches` slices and
    gradients are accumulated with lax.scan (constant memory in the number of
    microbatches; remat inside the model bounds activation memory).
    """
    from repro.core import backends

    _, opt_update = make_optimizer(train_cfg)
    lr_schedule = lr_schedule or constant(train_cfg.lr)
    nmb = train_cfg.microbatches
    # weight-stationary quantization applies when the GEMM backend declares
    # it honours pre-quantized weight operands (capability flag, not a
    # mode-name comparison — new registered backends opt in themselves)
    wsq = (train_cfg.weight_stationary_quant
           and backends.resolve(train_cfg.policy).supports_weight_stationary)
    qdtype = (jnp.bfloat16 if train_cfg.quant_param_dtype == "bfloat16"
              else jnp.float32)

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def train_step(state, batch):
        params = state["params"]
        if wsq:
            # quantize once per step; grads flow straight-through to the FP32
            # master below (paper Eq. 4 semantics).
            params = _prequantize_params(params, train_cfg.policy, qdtype)

        if nmb > 1:
            def split(x):
                return x.reshape((nmb, x.shape[0] // nmb) + x.shape[1:])
            mbatch = jax.tree_util.tree_map(split, batch)

            def acc_body(acc, mb):
                (l, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb)
                acc_g, acc_l = acc
                acc_g = jax.tree_util.tree_map(jnp.add, acc_g, g)
                return (acc_g, acc_l + l), metrics

            zero_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), ms = jax.lax.scan(
                acc_body, (zero_g, jnp.zeros(())), mbatch)
            grads = jax.tree_util.tree_map(lambda g: g / nmb, grads)
            loss = loss_sum / nmb
            metrics = jax.tree_util.tree_map(lambda m: m[-1], ms)
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)

        if train_cfg.grad_compression == "bfp":
            grads, new_err = grad_compress.compress_with_error_feedback(
                grads, state["err"], train_cfg.policy.b_m, train_cfg.policy.g)

        if train_cfg.grad_clip > 0:
            grads, gnorm = clip_by_global_norm(grads, train_cfg.grad_clip)
        else:
            gnorm = jnp.zeros(())

        lr = lr_schedule(state["step"])
        # the optimizer always updates the FP32 MASTER weights (Eq. 4)
        new_params, new_opt = opt_update(grads, state["opt"],
                                         state["params"], lr)
        new_state = dict(state, params=new_params, opt=new_opt,
                         step=state["step"] + 1)
        if train_cfg.grad_compression == "bfp":
            new_state["err"] = new_err
        metrics = dict(metrics, loss=loss, grad_norm=gnorm, lr=lr)
        return new_state, metrics

    return train_step


@dataclasses.dataclass
class StepTimer:
    """Straggler monitor: per-step EMA + slow-step flags (runtime/elastic.py
    consumes these to trigger mitigation at scale)."""
    ema: float = 0.0
    beta: float = 0.9
    slow_factor: float = 2.0
    slow_steps: int = 0

    def record(self, dt: float) -> bool:
        slow = self.ema > 0 and dt > self.slow_factor * self.ema
        self.ema = dt if self.ema == 0 else (self.beta * self.ema
                                             + (1 - self.beta) * dt)
        if slow:
            self.slow_steps += 1
        return slow


def train_loop(model, train_cfg: TrainConfig, state, data_iter, n_steps: int,
               checkpointer=None, ckpt_every: int = 0, log_every: int = 10,
               log_fn=print, registry=None):
    """Single-host training loop with checkpoint/restart + straggler hooks.

    Observability: each phase of the loop opens a tracer span
    (``train.data_next`` / ``train.step`` / ``train.host_sync`` — free when
    the tracer is disabled) and step latency/count land in ``registry``
    (default: the process registry) as ``train_step_seconds`` /
    ``train_steps_total``."""
    reg = registry if registry is not None else obs_metrics.get_registry()
    h_step = reg.histogram("train_step_seconds",
                           "walltime per optimizer step (dispatch + sync)")
    c_steps = reg.counter("train_steps_total", "optimizer steps completed")
    g_slow = reg.gauge("train_slow_steps", "straggler-flagged steps so far")
    tr = obs_trace.get_tracer()
    step_fn = jax.jit(make_train_step(model, train_cfg))
    timer = StepTimer()
    metrics = {}
    for i in range(n_steps):
        with tr.span("train.data_next"):
            batch = next(data_iter)
        t0 = time.perf_counter()
        with tr.span("train.step", {"i": i}):
            state, metrics = step_fn(state, batch)
        with tr.span("train.host_sync"):
            jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        slow = timer.record(dt)
        h_step.observe(dt)
        c_steps.inc()
        g_slow.set(timer.slow_steps)
        step = int(state["step"])
        if log_every and (i % log_every == 0 or i == n_steps - 1):
            log_fn(f"step {step}: loss={float(metrics['loss']):.4f} "
                   f"ppl={float(metrics.get('ppl', 0)):.2f} "
                   f"gnorm={float(metrics['grad_norm']):.3f}"
                   + (" [SLOW STEP]" if slow else ""))
        if checkpointer is not None and ckpt_every and step % ckpt_every == 0:
            checkpointer.save(state, step)
    return state, metrics
