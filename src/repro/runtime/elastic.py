"""Elastic scaling, preemption handling, straggler mitigation.

At 1000+-node scale the failure model is: nodes die (restore on a smaller
mesh), nodes come back (restore on a bigger mesh), the scheduler preempts
(SIGTERM -> checkpoint -> exit), and individual hosts straggle (flag + skip).
This module provides the host-side machinery; the numerical state lives in
checkpoint/checkpointer.py whose restore is already mesh-elastic.
"""

from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable, Optional

import jax

from repro.checkpoint.checkpointer import Checkpointer


class PreemptionGuard:
    """SIGTERM/SIGINT -> set a flag; the train loop checkpoints and exits
    cleanly at the next step boundary instead of dying mid-write."""

    def __init__(self, install: bool = True):
        self.preempted = False
        self._orig = {}
        if install:
            for sig in (signal.SIGTERM, signal.SIGINT):
                try:
                    self._orig[sig] = signal.signal(sig, self._handler)
                except ValueError:
                    pass  # non-main thread (tests)

    def _handler(self, signum, frame):
        self.preempted = True

    def uninstall(self):
        for sig, h in self._orig.items():
            signal.signal(sig, h)


@dataclasses.dataclass
class ElasticConfig:
    min_devices: int = 1
    reshard_on_restore: bool = True


def current_world() -> int:
    return jax.device_count()


def resize_serving_state(model, state, cap: int, new_slots: int,
                         keep: Optional[list] = None):
    """Rebuild a continuous-batching serving state with a different slot
    count (elastic up/down scale with offered load).

    ``state`` is the :class:`repro.runtime.server.LMServer` device pytree
    ({"cache": stacked cache, per-slot vectors...}). Slots listed in
    ``keep`` are compacted to the front of the new state; everything else
    starts empty (inactive). The caller remaps its host-side slot
    bookkeeping (and, for the paged layout, the block allocator via
    ``BlockAllocator.remap_slots``) to ``range(len(keep))``.

    Dense caches move through the ``models.lm`` gather/scatter helpers;
    paged caches keep their page POOLS untouched (block ids are stable
    under slot compaction) and only gather the per-slot leaves — ``idx``,
    the ``bt`` table rows and any dense recurrent state. Blocks shared
    between kept slots (copy-on-write prefix caching) stay shared: ids do
    not move, and ``remap_slots`` carries their refcounts; blocks whose
    only holders were dropped slots are freed (the server evicts them
    from its prefix index).
    """
    import jax.numpy as jnp

    from repro.models import lm as lm_helpers

    keep = list(keep or [])
    if len(keep) > new_slots:
        raise ValueError(f"{len(keep)} live slots do not fit in {new_slots}")
    cache = state["cache"]
    paged = "bt" in cache
    if paged:
        pool = next(k for k in lm_helpers.PAGE_POOL_LEAVES if k in cache)
        new_cache = model.init_cache(
            new_slots, cap, per_slot_idx=True, layout="paged",
            block_size=cache[pool].shape[2], n_blocks=cache[pool].shape[1])
        for k in lm_helpers.PAGE_POOL_LEAVES:
            if k in cache:
                new_cache[k] = cache[k]
    else:
        new_cache = model.init_cache(new_slots, cap, per_slot_idx=True)
    new_state = {"cache": new_cache}
    # "health" is the engine's pool-wide analog-fault accumulator dict —
    # not per-slot state; it survives a resize unchanged
    if "health" in state:
        new_state["health"] = state["health"]
    for k, v in state.items():
        if k in ("cache", "health"):
            continue
        new_state[k] = jnp.zeros((new_slots,) + v.shape[1:], v.dtype)
    if keep:
        dst = jnp.arange(len(keep), dtype=jnp.int32)
        src = jnp.asarray(keep, jnp.int32)
        if paged:
            for k, v in new_cache.items():
                if k in lm_helpers.PAGE_POOL_LEAVES:
                    continue
                old = cache[k]
                if lm_helpers.cache_slot_axis(k) == 0:
                    new_cache[k] = v.at[dst].set(old[src])
                else:
                    new_cache[k] = v.at[:, dst].set(old[:, src])
            new_state["cache"] = new_cache
        else:
            new_state["cache"] = lm_helpers.cache_insert(
                new_cache, lm_helpers.cache_extract(cache, src), dst)
        for k, v in state.items():
            if k in ("cache", "health"):
                continue
            new_state[k] = new_state[k].at[dst].set(v[src])
    return new_state


def resize_block_pool(state, allocator, new_n_blocks: int):
    """Elastic paged-pool resize: compact live blocks to the front of a
    pool of ``new_n_blocks`` (grow under admission pressure, shrink after a
    long-context burst retires). ``allocator`` is the server's
    :class:`repro.runtime.paging.BlockAllocator` — its ``resize_pool``
    renumbers the live blocks and rewrites every table; this moves the page
    ARRAYS to match. Refcounts move with the renumbering, so blocks shared
    across slots stay shared at their new ids. Under a sharded allocator
    the compaction is shard-preserving, so the renumbering is NOT simple
    sorted order — the explicit ``(old_ids, new_ids)`` map is returned
    alongside the new state so the caller can remap its prefix index by
    the same permutation. Raises if the live blocks don't fit the new
    pool."""
    import jax.numpy as jnp

    from repro.models import lm as lm_helpers

    old_ids, new_ids = allocator.resize_pool(new_n_blocks)
    cache = dict(state["cache"])
    for k in lm_helpers.PAGE_POOL_LEAVES:
        if k not in cache:
            continue
        v = cache[k]
        nv = jnp.zeros(v.shape[:1] + (int(new_n_blocks),) + v.shape[2:],
                       v.dtype)
        if len(old_ids):
            nv = nv.at[:, jnp.asarray(new_ids)].set(
                v[:, jnp.asarray(old_ids)])
        cache[k] = nv
    cache["bt"] = jnp.asarray(allocator.tables)
    allocator.dirty = False
    return dict(state, cache=cache), old_ids, new_ids


def elastic_restore(ckpt: Checkpointer, abstract_state, shardings,
                    step: Optional[int] = None):
    """Restore the latest checkpoint onto the CURRENT mesh. Because leaves are
    stored unsharded (host numpy) and re-device_put with today's shardings,
    this works across device-count changes (elastic up/down scale)."""
    return ckpt.restore(abstract_state, step=step, shardings=shardings)


class StragglerMitigator:
    """Tracks per-step wall time; when a step exceeds ``factor`` x EMA more
    than ``patience`` consecutive times, fires ``on_straggle`` (at cluster
    scale: re-shard around the slow host / raise for the controller).

    On a single host this demotes to monitoring + logging, but the hook is
    what a production controller subscribes to."""

    def __init__(self, factor: float = 2.0, patience: int = 3,
                 on_straggle: Optional[Callable[[int, float], None]] = None):
        self.factor = factor
        self.patience = patience
        self.on_straggle = on_straggle or (lambda step, dt: None)
        self.ema = 0.0
        self.beta = 0.9
        self.consecutive = 0
        self.events = 0

    def record(self, step: int, dt: float) -> bool:
        slow = self.ema > 0 and dt > self.factor * self.ema
        if slow:
            self.consecutive += 1
            if self.consecutive >= self.patience:
                self.events += 1
                self.on_straggle(step, dt)
                self.consecutive = 0
        else:
            self.consecutive = 0
        self.ema = dt if self.ema == 0 else self.beta * self.ema + (1 - self.beta) * dt
        return slow


def fault_tolerant_train_loop(model, train_cfg, state, data, n_steps: int,
                              ckpt: Checkpointer, ckpt_every: int = 50,
                              log_fn=print, guard: Optional[PreemptionGuard] = None,
                              straggler: Optional[StragglerMitigator] = None):
    """Training loop with preemption-safe checkpointing + data-state capture.

    The data pipeline state is stored in checkpoint metadata, so a restart
    resumes on exactly the batch the failed run would have consumed next."""
    import jax as _jax
    from repro.runtime.trainer import make_train_step

    step_fn = _jax.jit(make_train_step(model, train_cfg))
    guard = guard or PreemptionGuard(install=False)
    straggler = straggler or StragglerMitigator()
    metrics = {}
    from repro.obs import trace as obs_trace
    tr = obs_trace.get_tracer()
    for _ in range(n_steps):
        with tr.span("train.data_next"):
            batch = next(data)
        t0 = time.perf_counter()
        with tr.span("train.step"):
            state, metrics = step_fn(state, batch)
        with tr.span("train.host_sync"):
            _jax.block_until_ready(metrics["loss"])
        step = int(state["step"])
        straggler.record(step, time.perf_counter() - t0)
        if ckpt_every and step % ckpt_every == 0:
            ckpt.save_async(state, step, metadata={"data": data.state()}
                            if hasattr(data, "state") else None)
        if guard.preempted:
            log_fn(f"preempted at step {step}: checkpointing and exiting")
            ckpt.wait()
            ckpt.save(state, step, metadata={"data": data.state()}
                      if hasattr(data, "state") else None)
            break
    ckpt.wait()
    return state, metrics
