"""Paged KV serving memory: block allocator + prefix index behind the
block-table cache.

A dense stacked cache gives every serving slot its own ``cap``-length ring,
so KV memory scales with ``slots x max_context`` even when most requests are
short. The paged layout replaces the per-slot rings with ONE global pool of
fixed-size blocks (``(n_layers, n_blocks, block_size, kv_heads, head_dim)``
page arrays) plus a small per-slot **block table** mapping logical block
``i`` of a slot to a physical block id. Memory then scales with the *live
token count* of the workload, rounded up to blocks — the same trick
production LLM engines use (vLLM-style paged attention).

Blocks are the unit of SHARING, not just placement: each physical block
carries a **refcount**, so the same block can appear in several slots'
tables at once (copy-on-write prefix caching — requests with a common
prompt prefix map the same prompt blocks read-only and skip prefill for
those positions). The :class:`PrefixIndex` maps hash-chained full-block
token prefixes to live block ids so admission can find reusable blocks in
O(prompt blocks).

Blocks are ALSO the unit of device **placement**: under a
``jax.sharding.Mesh`` the page pools shard their block dim over the
``data`` axis (``parallel.sharding.cache_spec``), i.e. the ``data``-shard
of physical block ``b`` is ``b // (n_blocks // n_shards)`` (XLA splits a
sharded dim into equal contiguous chunks). Slots shard over the same axis,
so a slot's page-gather decode is LOCAL exactly when its table references
blocks homed on its own shard. The allocator therefore keeps **per-shard
free lists** and prefers same-shard blocks for each slot (``placement=
"locality"``), falling back to a remote shard only when the home shard
runs dry (counted in :attr:`BlockAllocator.spilled_allocs`);
``placement="round_robin"`` is the locality-blind baseline the serving
benchmark gates against. With ``n_shards=1`` (the single-device default)
all of this degrades to the original one-heap behavior bit-for-bit.

Split of responsibilities:

  * the **allocator** (this module) is host-side bookkeeping: lowest-id
    free heaps (one per shard), per-slot tables, refcounts,
    alloc/share/fork/free/defrag. It owns the authoritative ``tables``
    array and mirrors it to the device cache leaf ``bt`` (the server syncs
    lazily via :attr:`BlockAllocator.dirty`);
  * the **device** side only ever sees jittable arrays: the page pools and
    the ``(slots, max_blocks)`` int32 table whose unmapped entries hold the
    OOB sentinel ``n_blocks`` — scatter-writes through a sentinel drop on
    device, gathers clamp and are hidden by the position validity mask.

Freed blocks re-enter their home shard's min-heap, so reuse prefers LOW
physical ids within each shard: after a burst retires, the live region
compacts toward the front of every shard's range (defrag-on-retirement),
which is what makes :meth:`resize_pool` (elastic pool shrink/grow,
``runtime.elastic.resize_block_pool``) cheap.
"""

from __future__ import annotations

import hashlib
import heapq
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


def blocks_for(n_positions: int, block_size: int) -> int:
    """Blocks needed to hold ``n_positions`` tokens."""
    return -(-max(int(n_positions), 0) // block_size)


class BlockAllocator:
    """Free-heap block allocator with per-slot block tables, per-block
    refcounts (shared read-only blocks for copy-on-write prefix caching)
    and per-shard free lists (block-locality placement under data-sharded
    page pools).

    A slot's mapped logical blocks form the contiguous range ``[lo, hi)``
    of its table row (``lo > 0`` after :meth:`trim_below` dropped
    behind-window blocks for SWA decoding; ``hi`` == :attr:`n_owned`).

    Invariants (asserted by :meth:`check_invariants`, property-tested in
    ``tests/test_paging.py``):
      * ``refcount[b]`` equals the number of table entries referencing
        ``b`` across all slots (shared blocks count once per slot);
      * a block is on a free heap iff its refcount is zero, and it sits on
        its OWN shard's heap (``shard_of_block``);
      * within one slot the mapped entries are distinct block ids; entries
        outside ``[lo, hi)`` hold the sentinel ``n_blocks``;
      * with ``placement="locality"`` a block allocated while its home
        shard had free blocks is local (spills only happen on exhaustion).
    """

    PLACEMENTS = ("locality", "round_robin")

    def __init__(self, n_blocks: int, block_size: int, n_slots: int,
                 max_blocks_per_slot: Optional[int] = None,
                 n_shards: int = 1, placement: str = "locality"):
        if n_blocks < 1 or block_size < 1:
            raise ValueError(f"bad pool geometry: n_blocks={n_blocks} "
                             f"block_size={block_size}")
        if n_shards < 1 or n_blocks % n_shards:
            raise ValueError(
                f"n_shards={n_shards} must divide n_blocks={n_blocks} "
                f"(XLA splits the sharded block dim into equal contiguous "
                f"chunks)")
        if placement not in self.PLACEMENTS:
            raise ValueError(f"unknown placement {placement!r} "
                             f"(expected one of {self.PLACEMENTS})")
        self.n_blocks = int(n_blocks)
        self.block_size = int(block_size)
        self.n_slots = int(n_slots)
        self.max_blocks_per_slot = int(max_blocks_per_slot or n_blocks)
        self.n_shards = int(n_shards)
        self.placement = placement
        self.sentinel = self.n_blocks
        self._per_shard = self.n_blocks // self.n_shards
        # one lowest-id min-heap per shard; shard k owns the contiguous id
        # range [k * per_shard, (k+1) * per_shard)
        self._free: List[List[int]] = [
            list(range(k * self._per_shard, (k + 1) * self._per_shard))
            for k in range(self.n_shards)]
        for h in self._free:
            heapq.heapify(h)
        self.tables = np.full((self.n_slots, self.max_blocks_per_slot),
                              self.sentinel, np.int32)
        self.refcount = np.zeros((self.n_blocks,), np.int64)
        self.n_owned = np.zeros((self.n_slots,), np.int64)   # hi watermark
        self.lo = np.zeros((self.n_slots,), np.int64)        # first mapped
        self.peak_in_use = 0
        # placement telemetry: how many allocations landed on the owning
        # slot's home shard vs spilled to a remote one (round_robin counts
        # the same way, so the benchmark compares policies directly)
        self.local_allocs = 0
        self.spilled_allocs = 0
        self._rr = 0                 # round_robin rotation cursor
        # blocks withheld from allocation by fault injection (chaos pool
        # squeeze): off every free heap but still refcount-zero
        self.quarantined: set = set()
        # host->device table sync flag: the server pushes ``tables`` to the
        # cache's ``bt`` leaf only when this is set (and clears it)
        self.dirty = True

    # -- shard geometry --------------------------------------------------

    def shard_of_block(self, block: int) -> int:
        """The ``data``-shard holding physical block ``block`` (the pool's
        block dim is split into equal contiguous chunks)."""
        return int(block) // self._per_shard

    def shard_of_slot(self, slot: int) -> int:
        """The ``data``-shard holding ``slot``'s row of the stacked state
        (same contiguous-chunk rule on the slot dim). Robust to slot counts
        that don't divide evenly (locality then degrades gracefully)."""
        return min(int(slot) * self.n_shards // max(self.n_slots, 1),
                   self.n_shards - 1)

    # -- queries ---------------------------------------------------------

    @property
    def free_count(self) -> int:
        return sum(len(h) for h in self._free)

    def free_by_shard(self) -> List[int]:
        """Free-list depth per shard (the per-shard observability gauge)."""
        return [len(h) for h in self._free]

    @property
    def used_count(self) -> int:
        return self.n_blocks - self.free_count

    @property
    def occupancy(self) -> float:
        """In-use fraction of the whole pool."""
        return self.used_count / self.n_blocks

    @property
    def fragmentation(self) -> float:
        """Free holes inside the live region — the span ``[0, hwm)`` up to
        the highest live block id — as a fraction of that span. 0 means
        the live blocks sit compacted at the front (the state the lowest-id
        free heap converges to after retirements); high values mean an
        elastic pool shrink (``resize_pool``) would have to move blocks."""
        live = np.flatnonzero(self.refcount > 0)
        if live.size == 0:
            return 0.0
        hwm = int(live[-1]) + 1
        return (hwm - live.size) / hwm

    def remote_fraction(self) -> float:
        """Fraction of live (slot, block) table references whose block is
        homed on a DIFFERENT shard than the slot — each such reference is a
        cross-shard page gather every decode tick (the collective GSPMD
        inserts). 0.0 means fully local decode."""
        total = remote = 0
        for s in range(self.n_slots):
            home = self.shard_of_slot(s)
            for b in self.tables[s, self.lo[s]:self.n_owned[s]]:
                total += 1
                if self.shard_of_block(int(b)) != home:
                    remote += 1
        return remote / total if total else 0.0

    def can_fit(self, n_positions: int) -> bool:
        return blocks_for(n_positions, self.block_size) <= self.free_count

    def slot_blocks(self, slot: int) -> List[int]:
        """The slot's currently mapped physical block ids (logical order)."""
        return [int(b)
                for b in self.tables[slot, self.lo[slot]:self.n_owned[slot]]]

    def is_shared(self, block: int) -> bool:
        return self.refcount[block] > 1

    # -- mutation --------------------------------------------------------

    def _pop_free(self, home: int) -> int:
        """Pop one free block for a slot homed on shard ``home``.

        ``locality``: the home shard's lowest free id, spilling to the
        remote shard with the deepest free list (ties -> lowest shard)
        only when home is dry. ``round_robin``: rotate across shards
        regardless of home (the placement-blind baseline). Both count
        local vs spilled against ``home`` so the policies are comparable.
        """
        if not any(self._free):
            raise RuntimeError(
                f"block pool exhausted ({self.n_blocks} blocks of "
                f"{self.block_size}); grow n_blocks or admit less")
        if self.placement == "round_robin" and self.n_shards > 1:
            for d in range(self.n_shards):
                k = (self._rr + d) % self.n_shards
                if self._free[k]:
                    self._rr = (k + 1) % self.n_shards
                    break
        elif self._free[home]:
            k = home
        else:
            k = max(range(self.n_shards), key=lambda j: len(self._free[j]))
        if k == home:
            self.local_allocs += 1
        else:
            self.spilled_allocs += 1
        return heapq.heappop(self._free[k])

    def _push_free(self, block: int) -> None:
        heapq.heappush(self._free[self.shard_of_block(block)], block)

    def ensure(self, slot: int, n_positions: int) -> None:
        """Grow ``slot``'s table until it covers ``n_positions`` tokens,
        preferring blocks homed on the slot's own shard.

        Raises :class:`RuntimeError` on pool exhaustion and
        :class:`ValueError` when the slot's table itself is full (the
        request outgrew ``max_blocks_per_slot * block_size`` capacity).
        """
        need = blocks_for(n_positions, self.block_size)
        if need > self.max_blocks_per_slot:
            raise ValueError(
                f"slot {slot} needs {need} blocks for {n_positions} "
                f"positions but tables hold {self.max_blocks_per_slot} "
                f"(capacity {self.max_blocks_per_slot * self.block_size})")
        if need - self.n_owned[slot] > self.free_count:
            # atomic: a failed grow leaves the slot untouched
            raise RuntimeError(
                f"block pool exhausted ({self.n_blocks} blocks of "
                f"{self.block_size}); grow n_blocks or admit less")
        home = self.shard_of_slot(slot)
        while self.n_owned[slot] < need:
            b = self._pop_free(home)
            self.tables[slot, self.n_owned[slot]] = b
            self.refcount[b] = 1
            self.n_owned[slot] += 1
            self.dirty = True
        self.peak_in_use = max(self.peak_in_use, self.used_count)

    def share(self, slot: int, blocks: Sequence[int]) -> None:
        """Map already-live ``blocks`` (a matched prompt prefix) into
        ``slot``'s table read-only, bumping their refcounts. The slot's
        table must have room; blocks must be live (refcount >= 1)."""
        n = int(self.n_owned[slot])
        if n + len(blocks) > self.max_blocks_per_slot:
            raise ValueError(
                f"slot {slot}: sharing {len(blocks)} blocks past "
                f"{self.max_blocks_per_slot}-entry table")
        for b in blocks:
            b = int(b)
            if not (0 <= b < self.n_blocks) or self.refcount[b] < 1:
                raise ValueError(f"cannot share dead block {b}")
        for b in blocks:
            self.tables[slot, n] = int(b)
            self.refcount[int(b)] += 1
            n += 1
        self.n_owned[slot] = n
        if blocks:
            self.dirty = True

    def fork_cow(self, slot: int, logical: int) -> Optional[Tuple[int, int]]:
        """Copy-on-write fork: give ``slot`` a private copy of its logical
        block ``logical`` if that block is shared. The copy prefers the
        slot's home shard (a fork is the one chance to bring a remote
        shared block local). Returns ``(src, dst)`` physical ids so the
        caller can copy the page rows on device, or ``None`` when no fork
        is needed (unmapped / already private). Raises
        :class:`RuntimeError` if the pool has no free block."""
        b = int(self.tables[slot, logical])
        if b == self.sentinel or self.refcount[b] <= 1:
            return None
        nb = self._pop_free(self.shard_of_slot(slot))
        self.refcount[b] -= 1
        self.refcount[nb] = 1
        self.tables[slot, logical] = nb
        self.peak_in_use = max(self.peak_in_use, self.used_count)
        self.dirty = True
        return b, nb

    def _drop_entry(self, slot: int, logical: int,
                    freed: List[int]) -> None:
        b = int(self.tables[slot, logical])
        if b == self.sentinel:
            return
        self.refcount[b] -= 1
        assert self.refcount[b] >= 0, f"double free of block {b}"
        if self.refcount[b] == 0:
            self._push_free(b)
            freed.append(b)
        self.tables[slot, logical] = self.sentinel
        self.dirty = True

    def release(self, slot: int) -> List[int]:
        """Drop all of ``slot``'s references; blocks whose refcount hits
        zero return to their home shard's heap (defrag-on-retirement: each
        min-heap hands low ids back first). Returns the list of block ids
        actually FREED (shared blocks survive in their other holders'
        tables) so the caller can evict them from the prefix index."""
        freed: List[int] = []
        for j in range(int(self.lo[slot]), int(self.n_owned[slot])):
            self._drop_entry(slot, j, freed)
        self.n_owned[slot] = 0
        self.lo[slot] = 0
        return freed

    def trim_below(self, slot: int, pos: int) -> List[int]:
        """Free ``slot``'s blocks that lie wholly below position ``pos``
        (sliding-window decode: KV behind the window is dead weight — the
        validity mask already hides it). Refcount-aware: a shared prefix
        block outlives one slot's trim. Returns the freed block ids."""
        new_lo = min(max(int(pos), 0) // self.block_size,
                     int(self.n_owned[slot]))
        freed: List[int] = []
        for j in range(int(self.lo[slot]), new_lo):
            self._drop_entry(slot, j, freed)
        if new_lo > self.lo[slot]:
            self.lo[slot] = new_lo
        return freed

    def remap_slots(self, keep: Sequence[int], new_slots: int) -> List[int]:
        """Elastic slot-count change: compact the kept slots' table rows to
        the front (row ``i`` <- old row ``keep[i]``), release everything
        else. Mirrors ``elastic.resize_serving_state`` slot compaction.
        Returns the block ids freed by the dropped slots. Kept slots may
        change home shard (their row index moved): their existing blocks
        keep their ids — locality degrades to a remote gather, never to an
        error — and future growth prefers the NEW home."""
        keep = list(keep)
        if len(keep) > new_slots:
            raise ValueError(f"{len(keep)} kept slots do not fit {new_slots}")
        freed: List[int] = []
        for s in range(self.n_slots):
            if s not in keep:
                freed.extend(self.release(s))
        new_tables = np.full((new_slots, self.max_blocks_per_slot),
                             self.sentinel, np.int32)
        new_owned = np.zeros((new_slots,), np.int64)
        new_lo = np.zeros((new_slots,), np.int64)
        for i, s in enumerate(keep):
            new_tables[i] = self.tables[s]
            new_owned[i] = self.n_owned[s]
            new_lo[i] = self.lo[s]
        self.tables, self.n_owned, self.lo, self.n_slots = \
            new_tables, new_owned, new_lo, new_slots
        self.dirty = True
        return freed

    def resize_pool(self, new_n_blocks: int) -> Tuple[np.ndarray, np.ndarray]:
        """Elastic pool resize with shard-preserving compaction: live
        blocks (refcount > 0) keep their SHARD and compact toward the
        front of that shard's new id range, in increasing old-id order —
        a block that decoded locally before the resize still decodes
        locally after it. A shard whose live blocks outgrow its new range
        overflows into other shards' free space (lowest shard first);
        single-shard pools reduce to the original global renumbering.
        Returns ``(old_ids, new_ids)`` (aligned arrays, arbitrary order)
        so the caller can move the page-array rows
        (``new_pages[:, new_ids] = old_pages[:, old_ids]``) and remap a
        prefix index; tables are rewritten in place (sentinel value
        changes with the pool size) and refcounts move with the
        renumbering, so shared blocks stay shared."""
        new_n_blocks = int(new_n_blocks)
        if self.quarantined:
            raise RuntimeError(
                f"{len(self.quarantined)} blocks are quarantined; "
                f"unquarantine before resizing the pool")
        if new_n_blocks < 1 or new_n_blocks % self.n_shards:
            raise ValueError(
                f"new_n_blocks={new_n_blocks} must be a positive multiple "
                f"of n_shards={self.n_shards}")
        used = np.sort(np.where(self.refcount > 0)[0])
        if len(used) > new_n_blocks:
            raise ValueError(f"{len(used)} blocks in use do not fit a pool "
                             f"of {new_n_blocks}")
        new_per = new_n_blocks // self.n_shards
        fill = [0] * self.n_shards          # next free offset per new shard
        old_ids = [int(b) for b in used]
        new_ids: List[Optional[int]] = [None] * len(old_ids)
        overflow: List[int] = []            # indexes into old_ids
        for i, b in enumerate(old_ids):
            k = self.shard_of_block(b)
            if fill[k] < new_per:
                new_ids[i] = k * new_per + fill[k]
                fill[k] += 1
            else:
                overflow.append(i)
        for i in overflow:                  # spill into remaining capacity
            k = next(j for j in range(self.n_shards) if fill[j] < new_per)
            new_ids[i] = k * new_per + fill[k]
            fill[k] += 1
        old_to_new = np.full((self.n_blocks,), new_n_blocks, np.int64)
        old_to_new[np.asarray(old_ids, np.int64)] = \
            np.asarray(new_ids, np.int64)
        new_refcount = np.zeros((new_n_blocks,), np.int64)
        new_refcount[np.asarray(new_ids, np.int64)] = self.refcount[used]
        mapped = self.tables < self.sentinel
        new_tables = np.full_like(self.tables, new_n_blocks)
        new_tables[mapped] = old_to_new[self.tables[mapped]]
        self.n_blocks = new_n_blocks
        self.sentinel = self.n_blocks
        self._per_shard = new_per
        self.tables = new_tables.astype(np.int32)
        self.refcount = new_refcount
        self._free = [[b for b in range(k * new_per, (k + 1) * new_per)
                       if new_refcount[b] == 0]
                      for k in range(self.n_shards)]
        for h in self._free:
            heapq.heapify(h)
        self.peak_in_use = min(self.peak_in_use, self.n_blocks)
        self.dirty = True
        return (np.asarray(old_ids, np.int64),
                np.asarray(new_ids, np.int64))

    # -- fault injection -------------------------------------------------

    def quarantine(self, n: int) -> List[int]:
        """Withhold up to ``n`` FREE blocks from allocation (chaos fault
        site ``pool_exhaustion``: simulated pressure without touching any
        live data). Quarantined blocks leave their shard's free heap —
        ``free_count`` drops, so admission and :meth:`ensure` hit the real
        exhaustion paths — but keep refcount zero and rejoin the pool via
        :meth:`unquarantine`. Pops HIGHEST ids first so the squeeze does
        not fight defrag-on-retirement's preference for low ids. Returns
        the block ids actually withheld (may be < ``n`` on a dry pool)."""
        taken: List[int] = []
        for h in self._free:
            h.sort(reverse=True)         # temporary: pop high ids
        while len(taken) < int(n) and any(self._free):
            k = max(range(self.n_shards), key=lambda j: len(self._free[j]))
            taken.append(self._free[k].pop(0))
        for h in self._free:
            heapq.heapify(h)
        self.quarantined.update(taken)
        return taken

    def unquarantine(self, blocks: Optional[Sequence[int]] = None) -> None:
        """Return ``blocks`` (default: all) from quarantine to their home
        shards' free heaps."""
        ids = list(self.quarantined) if blocks is None else \
            [int(b) for b in blocks]
        for b in ids:
            if b not in self.quarantined:
                raise ValueError(f"block {b} is not quarantined")
            self.quarantined.discard(b)
            self._push_free(b)

    # -- integrity -------------------------------------------------------

    def check_invariants(self) -> None:
        free_all: List[int] = []
        for k, h in enumerate(self._free):
            assert all(self.shard_of_block(b) == k for b in h), \
                f"shard {k} heap holds a foreign block"
            free_all.extend(h)
        free = set(free_all)
        assert len(free) == len(free_all), "duplicate ids on the free heaps"
        refs = np.zeros((self.n_blocks,), np.int64)
        for s in range(self.n_slots):
            lo, hi = int(self.lo[s]), int(self.n_owned[s])
            row = self.tables[s]
            assert 0 <= lo <= hi <= self.max_blocks_per_slot, \
                f"slot {s}: bad lo/hi {lo}/{hi}"
            assert np.all(row[hi:] == self.sentinel), \
                f"slot {s}: mapped entries beyond n_owned"
            assert np.all(row[:lo] == self.sentinel), \
                f"slot {s}: mapped entries below lo"
            blocks = [int(b) for b in row[lo:hi]]
            assert all(0 <= b < self.n_blocks for b in blocks), \
                f"slot {s}: block id out of range"
            assert len(blocks) == len(set(blocks)), \
                f"slot {s}: duplicate block in one table row"
            for b in blocks:
                refs[b] += 1
        assert np.array_equal(refs, self.refcount), \
            "refcount != live table references"
        q = {int(b) for b in self.quarantined}
        assert not (free & q), "quarantined block on a free heap"
        assert all(self.refcount[b] == 0 for b in q), \
            "quarantined block has live references"
        zero = {b for b in range(self.n_blocks) if self.refcount[b] == 0}
        assert free | q == zero, \
            "free heaps + quarantine != zero-refcount blocks"


class PrefixIndex:
    """Hash-chain prefix index over FULL prompt blocks.

    Key for logical block ``i`` of a prompt: ``sha1(key_{i-1} || tokens of
    block i)`` — chained, so a key identifies the whole token prefix
    through block ``i``, not just that block's tokens (``hash()`` is
    process-salted and unusable for a stable content key). ``match`` walks
    the chain until the first miss; ``insert_chain`` registers a prompt's
    full blocks after their KV is written. First insert wins: duplicate
    content keeps the original (already shareable) block.

    The index only ever references LIVE blocks: the server evicts ids the
    allocator reports freed (release/trim/remap) and ids it is about to
    overwrite (copy-on-write guard), and remaps ids on pool resize.
    """

    def __init__(self, block_size: int):
        self.block_size = int(block_size)
        self.by_key: Dict[bytes, int] = {}
        self.by_block: Dict[int, bytes] = {}

    def __len__(self) -> int:
        return len(self.by_key)

    def _chain_keys(self, prompt: np.ndarray, n_blocks: int) -> List[bytes]:
        toks = np.asarray(prompt, np.int32)
        keys, h = [], b"\x00"
        for i in range(n_blocks):
            h = hashlib.sha1(
                h + toks[i * self.block_size:(i + 1) * self.block_size]
                .tobytes()).digest()
            keys.append(h)
        return keys

    def match(self, prompt: np.ndarray) -> List[int]:
        """Longest indexed full-block prefix of ``prompt``: the physical
        block ids for blocks ``0..K-1`` (consecutive from the start)."""
        n_full = len(prompt) // self.block_size
        ids: List[int] = []
        for key in self._chain_keys(prompt, n_full):
            b = self.by_key.get(key)
            if b is None:
                break
            ids.append(b)
        return ids

    def insert_chain(self, prompt: np.ndarray, block_ids: Sequence[int]) -> None:
        """Register a prompt's full blocks (``block_ids[i]`` holds the KV of
        prompt block ``i``). Keys already present keep their original block."""
        keys = self._chain_keys(prompt, min(len(prompt) // self.block_size,
                                            len(block_ids)))
        for key, b in zip(keys, block_ids):
            if key in self.by_key:
                continue
            b = int(b)
            if b in self.by_block:       # block re-registered under a new
                del self.by_key[self.by_block[b]]   # chain: drop stale key
            self.by_key[key] = b
            self.by_block[b] = key

    def contains_block(self, block: int) -> bool:
        return int(block) in self.by_block

    def evict_blocks(self, blocks: Sequence[int]) -> None:
        """Drop freed / about-to-be-overwritten blocks from the index."""
        for b in blocks:
            key = self.by_block.pop(int(b), None)
            if key is not None:
                del self.by_key[key]

    def remap(self, old_to_new: Dict[int, int]) -> None:
        """Renumber block ids after an elastic pool resize (ids not in the
        mapping were freed by the resize and are evicted)."""
        by_key, by_block = {}, {}
        for key, b in self.by_key.items():
            nb = old_to_new.get(b)
            if nb is None:
                continue
            by_key[key] = int(nb)
            by_block[int(nb)] = key
        self.by_key, self.by_block = by_key, by_block
