"""Paged KV serving memory: the block allocator behind the block-table cache.

A dense stacked cache gives every serving slot its own ``cap``-length ring,
so KV memory scales with ``slots x max_context`` even when most requests are
short. The paged layout replaces the per-slot rings with ONE global pool of
fixed-size blocks (``(n_layers, n_blocks, block_size, kv_heads, head_dim)``
page arrays) plus a small per-slot **block table** mapping logical block
``i`` of a slot to a physical block id. Memory then scales with the *live
token count* of the workload, rounded up to blocks — the same trick
production LLM engines use (vLLM-style paged attention).

Split of responsibilities:

  * the **allocator** (this module) is host-side bookkeeping: a lowest-id
    free heap, per-slot tables, alloc/free/defrag on retirement. It owns the
    authoritative ``tables`` array and mirrors it to the device cache leaf
    ``bt`` (the server syncs lazily via :attr:`BlockAllocator.dirty`);
  * the **device** side only ever sees jittable arrays: the page pools and
    the ``(slots, max_blocks)`` int32 table whose unmapped entries hold the
    OOB sentinel ``n_blocks`` — scatter-writes through a sentinel drop on
    device, gathers clamp and are hidden by the position validity mask.

Freed blocks re-enter a min-heap, so reuse prefers LOW physical ids: after a
burst retires, the live region compacts toward the front of the pool
(defrag-on-retirement), which is what makes :meth:`resize_pool` (elastic
pool shrink/grow, ``runtime.elastic.resize_block_pool``) cheap.
"""

from __future__ import annotations

import heapq
from typing import List, Optional, Sequence, Tuple

import numpy as np


def blocks_for(n_positions: int, block_size: int) -> int:
    """Blocks needed to hold ``n_positions`` tokens."""
    return -(-max(int(n_positions), 0) // block_size)


class BlockAllocator:
    """Free-heap block allocator with per-slot block tables.

    Invariants (asserted by :meth:`check_invariants`, property-tested in
    ``tests/test_paging.py``):
      * every block is either on the free heap or owned by exactly one slot;
      * a slot's table maps logical blocks ``0..n_owned-1`` to distinct
        physical ids and holds the sentinel ``n_blocks`` everywhere else;
      * ``free_count + sum(owned) == n_blocks`` at all times.
    """

    def __init__(self, n_blocks: int, block_size: int, n_slots: int,
                 max_blocks_per_slot: Optional[int] = None):
        if n_blocks < 1 or block_size < 1:
            raise ValueError(f"bad pool geometry: n_blocks={n_blocks} "
                             f"block_size={block_size}")
        self.n_blocks = int(n_blocks)
        self.block_size = int(block_size)
        self.n_slots = int(n_slots)
        self.max_blocks_per_slot = int(max_blocks_per_slot or n_blocks)
        self.sentinel = self.n_blocks
        self._free: List[int] = list(range(self.n_blocks))
        heapq.heapify(self._free)
        self.tables = np.full((self.n_slots, self.max_blocks_per_slot),
                              self.sentinel, np.int32)
        self.owner = np.full((self.n_blocks,), -1, np.int64)
        self.n_owned = np.zeros((self.n_slots,), np.int64)
        self.peak_in_use = 0
        # host->device table sync flag: the server pushes ``tables`` to the
        # cache's ``bt`` leaf only when this is set (and clears it)
        self.dirty = True

    # -- queries ---------------------------------------------------------

    @property
    def free_count(self) -> int:
        return len(self._free)

    @property
    def used_count(self) -> int:
        return self.n_blocks - len(self._free)

    def can_fit(self, n_positions: int) -> bool:
        return blocks_for(n_positions, self.block_size) <= self.free_count

    def slot_blocks(self, slot: int) -> List[int]:
        return [int(b) for b in self.tables[slot, :self.n_owned[slot]]]

    # -- mutation --------------------------------------------------------

    def ensure(self, slot: int, n_positions: int) -> None:
        """Grow ``slot``'s table until it covers ``n_positions`` tokens.

        Raises :class:`RuntimeError` on pool exhaustion and
        :class:`ValueError` when the slot's table itself is full (the
        request outgrew ``max_blocks_per_slot * block_size`` capacity).
        """
        need = blocks_for(n_positions, self.block_size)
        if need > self.max_blocks_per_slot:
            raise ValueError(
                f"slot {slot} needs {need} blocks for {n_positions} "
                f"positions but tables hold {self.max_blocks_per_slot} "
                f"(capacity {self.max_blocks_per_slot * self.block_size})")
        if need - self.n_owned[slot] > len(self._free):
            # atomic: a failed grow leaves the slot untouched
            raise RuntimeError(
                f"block pool exhausted ({self.n_blocks} blocks of "
                f"{self.block_size}); grow n_blocks or admit less")
        while self.n_owned[slot] < need:
            b = heapq.heappop(self._free)
            self.tables[slot, self.n_owned[slot]] = b
            self.owner[b] = slot
            self.n_owned[slot] += 1
            self.dirty = True
        self.peak_in_use = max(self.peak_in_use, self.used_count)

    def release(self, slot: int) -> int:
        """Return all of ``slot``'s blocks to the pool (defrag-on-retirement:
        the min-heap hands low ids back first). Returns the count freed."""
        n = int(self.n_owned[slot])
        for j in range(n):
            b = int(self.tables[slot, j])
            heapq.heappush(self._free, b)
            self.owner[b] = -1
        if n:
            self.tables[slot, :n] = self.sentinel
            self.n_owned[slot] = 0
            self.dirty = True
        return n

    def remap_slots(self, keep: Sequence[int], new_slots: int) -> None:
        """Elastic slot-count change: compact the kept slots' table rows to
        the front (row ``i`` <- old row ``keep[i]``), release everything
        else. Mirrors ``elastic.resize_serving_state`` slot compaction."""
        keep = list(keep)
        if len(keep) > new_slots:
            raise ValueError(f"{len(keep)} kept slots do not fit {new_slots}")
        for s in range(self.n_slots):
            if s not in keep:
                self.release(s)
        new_tables = np.full((new_slots, self.max_blocks_per_slot),
                             self.sentinel, np.int32)
        new_owned = np.zeros((new_slots,), np.int64)
        for i, s in enumerate(keep):
            new_tables[i] = self.tables[s]
            new_owned[i] = self.n_owned[s]
            for b in self.slot_blocks(s):
                self.owner[b] = i
        self.tables, self.n_owned, self.n_slots = new_tables, new_owned, \
            new_slots
        self.dirty = True

    def resize_pool(self, new_n_blocks: int) -> Tuple[np.ndarray, np.ndarray]:
        """Elastic pool resize with compaction: used blocks are renumbered
        ``0..used-1`` in increasing old-id order. Returns ``(old_ids,
        new_ids)`` so the caller can move the page-array rows
        (``new_pages[:, new_ids] = old_pages[:, old_ids]``); tables are
        rewritten in place (sentinel value changes with the pool size)."""
        used = np.sort(np.where(self.owner >= 0)[0])
        if len(used) > new_n_blocks:
            raise ValueError(f"{len(used)} blocks in use do not fit a pool "
                             f"of {new_n_blocks}")
        old_to_new = np.full((self.n_blocks,), new_n_blocks, np.int64)
        old_to_new[used] = np.arange(len(used))
        new_owner = np.full((new_n_blocks,), -1, np.int64)
        new_owner[:len(used)] = self.owner[used]
        mapped = self.tables < self.sentinel
        new_tables = np.full_like(self.tables, new_n_blocks)
        new_tables[mapped] = old_to_new[self.tables[mapped]]
        old_ids, new_ids = used, np.arange(len(used))
        self.n_blocks = int(new_n_blocks)
        self.sentinel = self.n_blocks
        self.tables = new_tables.astype(np.int32)
        self.owner = new_owner
        self._free = [b for b in range(self.n_blocks) if new_owner[b] < 0]
        heapq.heapify(self._free)
        self.peak_in_use = min(self.peak_in_use, self.n_blocks)
        self.dirty = True
        return old_ids, new_ids

    # -- integrity -------------------------------------------------------

    def check_invariants(self) -> None:
        free = set(self._free)
        assert len(free) == len(self._free), "duplicate ids on the free heap"
        owned = []
        for s in range(self.n_slots):
            n = int(self.n_owned[s])
            row = self.tables[s]
            assert np.all(row[n:] == self.sentinel), \
                f"slot {s}: mapped entries beyond n_owned"
            blocks = [int(b) for b in row[:n]]
            assert all(0 <= b < self.n_blocks for b in blocks), \
                f"slot {s}: block id out of range"
            assert all(self.owner[b] == s for b in blocks), \
                f"slot {s}: owner mismatch"
            owned.extend(blocks)
        assert len(owned) == len(set(owned)), "block owned by two slots"
        assert not (free & set(owned)), "block both free and owned"
        assert len(free) + len(owned) == self.n_blocks, "blocks leaked"
