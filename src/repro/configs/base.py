"""Config dataclasses: model architecture, input shapes, mesh, training.

Every assigned architecture is a :class:`ModelConfig`; the four assigned input
shapes are :class:`ShapeConfig`. ``reduced()`` derives the CPU smoke-test
variant of any architecture (same family/topology, tiny dims).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.precision import MiragePolicy, PAPER_POLICY


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                      # dense | ssm | hybrid | moe | encdec | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // n_heads
    qkv_bias: bool = False
    qk_norm: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    norm_type: str = "rmsnorm"       # rmsnorm | layernorm
    sliding_window: Optional[int] = None   # SWA (mixtral)
    # --- MoE ---
    n_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0                # per-expert FFN width
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.01
    # --- SSM (mamba2 / zamba2) ---
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_conv: int = 4
    ssm_groups: int = 1
    attn_every: int = 0              # zamba2: shared attn block period (0 = none)
    # --- enc-dec ---
    encoder_layers: int = 0          # >0 -> encoder-decoder (n_layers = decoder)
    # --- modality frontend stubs ---
    frontend: Optional[str] = None   # vit_stub | audio_stub
    frontend_dim: int = 0            # stub embedding width
    frontend_len: int = 0            # patches / frames per example

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer block kind for the unified decoder stack."""
        if self.family == "ssm":
            return ("mamba",) * self.n_layers
        if self.family == "hybrid":
            # mamba stack with a SHARED attention block applied every
            # `attn_every` layers (zamba2-style).
            return ("mamba",) * self.n_layers
        if self.family == "moe":
            return ("attn_moe",) * self.n_layers
        return ("attn_mlp",) * self.n_layers

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests (one fwd/train step)."""
        return dataclasses.replace(
            self,
            n_layers=min(self.n_layers, 4 if self.attn_every == 0 else 4),
            d_model=64,
            n_heads=4,
            n_kv_heads=max(1, min(self.n_kv_heads, 2)),
            d_ff=128,
            vocab_size=256,
            head_dim=16,
            sliding_window=(32 if self.sliding_window else None),
            n_experts=min(self.n_experts, 8),
            experts_per_token=min(self.experts_per_token, 2),
            moe_d_ff=32 if self.moe_d_ff else 0,
            # dropless at smoke scale so decode == forward exactly (capacity
            # dropping is not causal; see tests/test_models_smoke.py)
            capacity_factor=8.0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_headdim=8,
            ssm_chunk=16,
            attn_every=2 if self.attn_every else 0,
            encoder_layers=min(self.encoder_layers, 2),
            frontend_dim=32 if self.frontend_dim else 0,
            frontend_len=8 if self.frontend_len else 0,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # train | prefill | decode

    def reduced(self) -> "ShapeConfig":
        return dataclasses.replace(
            self, seq_len=min(self.seq_len, 64), global_batch=min(self.global_batch, 2))


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    policy: MiragePolicy = PAPER_POLICY
    optimizer: str = "adamw"          # sgdm | adam | adamw
    lr: float = 3e-4
    weight_decay: float = 0.0
    beta1: float = 0.9
    beta2: float = 0.999
    momentum: float = 0.9
    grad_clip: float = 1.0
    microbatches: int = 1             # gradient accumulation steps
    remat: bool = True                # activation checkpointing over layers
    zero1: bool = True                # shard optimizer state over data axis
    grad_compression: str = "none"    # none | bfp (error-feedback BFP all-reduce)
    # Weight-stationary quantization (paper dataflow: program the tile once,
    # reuse): quantize GEMM weights ONCE per step outside the microbatch
    # loop; GEMMs skip their weight-side quantization; gradients flow
    # straight-through to the FP32 master (Eq. 4). §Perf iteration 1.
    weight_stationary_quant: bool = False
    quant_param_dtype: str = "float32"  # storage for pre-quantized weights
    seed: int = 0
