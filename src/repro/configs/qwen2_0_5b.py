"""Qwen2 0.5B [arXiv:2407.10671; hf:Qwen/Qwen2-0.5B].

24L, d_model 896, 14 heads (2 KV), d_ff 4864, vocab 151936. QKV bias,
tied embeddings."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen2-0.5b",
    family="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    n_kv_heads=2,
    d_ff=4864,
    vocab_size=151936,
    head_dim=64,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
)
