"""Zamba2 2.7B [arXiv:2411.15242; hf:Zyphra/Zamba2-2.7B].

Hybrid: 54 Mamba2 layers with a SHARED attention(+MLP) block applied every 6
layers (weights reused at each application; the block input concatenates the
original embeddings with the running hidden state, Zamba-style).
d_model 2560, 32 MHA heads (kv=32), shared-block d_ff 10240, vocab 32000,
ssm_state 64."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="zamba2-2.7b",
    family="hybrid",
    n_layers=54,
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,
    vocab_size=32000,
    head_dim=80,
    ssm_state=64,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_chunk=256,
    attn_every=6,
)
