"""InternVL2 2B [arXiv:2404.16821; hf:OpenGVLab/InternVL2-2B].

VLM: InternLM2-1.8B language backbone (24L, d_model 2048, 16 heads / 8 KV,
d_ff 8192, vocab 92553) + InternViT vision frontend. Per the assignment the
vision tower is a STUB: input_specs() provides precomputed patch embeddings
(B, patches, frontend_dim) which an MLP projector maps into the LM stream."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    head_dim=128,
    frontend="vit_stub",
    frontend_dim=1024,
    frontend_len=256,
)
