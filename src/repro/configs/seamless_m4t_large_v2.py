"""SeamlessM4T large v2 [arXiv:2308.11596; hf:facebook/seamless-m4t-v2-large].

Encoder-decoder transformer BACKBONE per the assignment: 24 encoder + 24
decoder layers, d_model 1024, 16 heads (kv=16), d_ff 8192, vocab 256206,
LayerNorm. The speech/audio modality frontend is a STUB: input_specs()
provides precomputed frame embeddings (B, frames, d_model)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,             # decoder layers
    encoder_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    head_dim=64,
    norm_type="layernorm",
    qkv_bias=True,
    frontend="audio_stub",
    frontend_dim=1024,
    frontend_len=1024,       # encoder frames per example (default; shapes override)
)
