"""Qwen3-MoE 30B-A3B [hf:Qwen/Qwen3-30B-A3B].

48L, d_model 2048, 32 heads (4 KV), vocab 151936; MoE: 128 experts, top-8,
per-expert d_ff 768 (gated). QK-norm per qwen3 family."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=768,               # kept equal to moe_d_ff for reporting
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
    n_experts=128,
    experts_per_token=8,
    moe_d_ff=768,
)
