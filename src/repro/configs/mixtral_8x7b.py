"""Mixtral 8x7B [arXiv:2401.04088; hf:mistralai/Mixtral-8x7B-v0.1].

32L, d_model 4096, 32 heads (8 KV), vocab 32000; MoE: 8 experts, top-2,
per-expert d_ff 14336 (gated); sliding-window attention (4096)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    head_dim=128,
    sliding_window=4096,
    rope_theta=1_000_000.0,
    n_experts=8,
    experts_per_token=2,
    moe_d_ff=14336,
)
