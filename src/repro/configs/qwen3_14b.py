"""Qwen3 14B [hf:Qwen/Qwen3-14B; config per assignment].

40L, d_model 5120, 40 heads (8 KV), d_ff 17408, vocab 151936. QK-norm
(per-head RMSNorm on q and k), no QKV bias (qwen3 dropped it)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen3-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_ff=17408,
    vocab_size=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
)
