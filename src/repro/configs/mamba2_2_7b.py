"""Mamba2 2.7B [arXiv:2405.21060; unverified].

Pure SSM (SSD / state-space duality): 64 layers, d_model 2560 (attention-free),
vocab 50280, ssm_state 128, headdim 64 (=> 80 SSD heads at expand=2)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="mamba2-2.7b",
    family="ssm",
    n_layers=64,
    d_model=2560,
    n_heads=1,          # unused (attention-free)
    n_kv_heads=1,
    d_ff=0,             # no MLP blocks in mamba2
    vocab_size=50280,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_chunk=256,
)
