"""Command R+ 104B [hf:CohereForAI/c4ai-command-r-plus; unverified].

Dense GQA decoder-only LM: 64L, d_model 12288, 96 heads (8 KV), d_ff 33792,
vocab 256000. No biases anywhere (Cohere style)."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="command-r-plus-104b",
    family="dense",
    n_layers=64,
    d_model=12288,
    n_heads=96,
    n_kv_heads=8,
    d_ff=33792,
    vocab_size=256000,
    head_dim=128,
    qkv_bias=False,
    rope_theta=75_000_000.0,
)
