"""Architecture registry: the 10 assigned architectures + paper-scale configs."""

from repro.configs.base import (
    ModelConfig,
    ShapeConfig,
    TrainConfig,
    TRAIN_4K,
    PREFILL_32K,
    DECODE_32K,
    LONG_500K,
    ALL_SHAPES,
)
from repro.configs.command_r_plus_104b import CONFIG as command_r_plus_104b
from repro.configs.qwen2_1_5b import CONFIG as qwen2_1_5b
from repro.configs.qwen2_0_5b import CONFIG as qwen2_0_5b
from repro.configs.qwen3_14b import CONFIG as qwen3_14b
from repro.configs.zamba2_2_7b import CONFIG as zamba2_2_7b
from repro.configs.mamba2_2_7b import CONFIG as mamba2_2_7b
from repro.configs.seamless_m4t_large_v2 import CONFIG as seamless_m4t_large_v2
from repro.configs.qwen3_moe_30b_a3b import CONFIG as qwen3_moe_30b_a3b
from repro.configs.mixtral_8x7b import CONFIG as mixtral_8x7b
from repro.configs.internvl2_2b import CONFIG as internvl2_2b

ARCHS = {
    c.arch_id: c
    for c in (
        command_r_plus_104b,
        qwen2_1_5b,
        qwen2_0_5b,
        qwen3_14b,
        zamba2_2_7b,
        mamba2_2_7b,
        seamless_m4t_large_v2,
        qwen3_moe_30b_a3b,
        mixtral_8x7b,
        internvl2_2b,
    )
}

SHAPES = {s.name: s for s in ALL_SHAPES}

# Cells skipped per the assignment rules (pure full-attention archs have no
# sub-quadratic long-context path; see DESIGN.md Section 4).
SKIPPED_CELLS = {
    ("command-r-plus-104b", "long_500k"): "pure full attention (no sub-quadratic path)",
    ("qwen2-1.5b", "long_500k"): "pure full attention",
    ("qwen2-0.5b", "long_500k"): "pure full attention",
    ("qwen3-14b", "long_500k"): "pure full attention",
    ("qwen3-moe-30b-a3b", "long_500k"): "pure full attention",
    ("internvl2-2b", "long_500k"): "pure full attention",
    ("seamless-m4t-large-v2", "long_500k"): "enc-dec with full attention",
}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; have {sorted(ARCHS)}")
    return ARCHS[arch_id]


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def cell_is_skipped(arch_id: str, shape_name: str):
    return SKIPPED_CELLS.get((arch_id, shape_name))
