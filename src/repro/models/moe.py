"""Mixture-of-Experts layer: top-k routing, capacity-bounded dispatch,
expert-parallel execution.

Dispatch is the classic fixed-capacity scheme (t5x/flaxformer style): each
expert owns a ``(C, d)`` buffer; tokens are scattered into their expert's
buffer in routing-priority order and tokens beyond capacity are dropped
(capacity_factor controls slack). The buffers are sharded over the ``model``
mesh axis on the expert dim when E >= TP (qwen3-moe) or TP-sharded on d_ff
when E < TP (mixtral) — see parallel/sharding.py; the scatter/gather pair is
what shows up as all-to-all traffic in the collective roofline.

Expert FFN GEMMs run under the Mirage policy (vmapped over experts). The
router stays FP32 (small and precision-critical — same spirit as the paper
keeping nonlinearities digital).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.precision import MiragePolicy
from repro.models import common
from repro.obs import health as obs_health


def moe_init(key, d_model: int, n_experts: int, d_ff: int):
    ks = jax.random.split(key, 4)
    std_in = 1.0 / jnp.sqrt(d_model)
    std_out = 1.0 / jnp.sqrt(d_ff)
    return {
        "router": common.dense_init(ks[0], d_model, n_experts),
        "gate": jax.random.normal(ks[1], (n_experts, d_model, d_ff)) * std_in,
        "up": jax.random.normal(ks[2], (n_experts, d_model, d_ff)) * std_in,
        "down": jax.random.normal(ks[3], (n_experts, d_ff, d_model)) * std_out,
    }


def _expert_ffn(gate_w, up_w, down_w, buf, policy: MiragePolicy):
    """buf: (C, d) for one expert."""
    from repro.core.gemm import mirage_matmul_auto
    h = (jax.nn.silu(mirage_matmul_auto(buf, gate_w, policy))
         * mirage_matmul_auto(buf, up_w, policy))
    return mirage_matmul_auto(h, down_w, policy)


def _expert_ffn_vmapped(gate_w, up_w, down_w, buffers, policy):
    """Expert FFNs vmapped over E. Health records inside the vmap body are
    batch tracers that cannot reach the enclosing scope, so when one is
    open they leave the body as per-expert outputs and their sums are
    re-recorded one level up (same lift as ``obs_health.lifting_scan``)."""
    if not obs_health.active():
        return jax.vmap(_expert_ffn, in_axes=(0, 0, 0, 0, None))(
            gate_w, up_w, down_w, buffers, policy)

    def one(gw, uw, dw, buf):
        with obs_health.collect() as hc:
            out = _expert_ffn(gw, uw, dw, buf, policy)
        return out, dict(hc.values)

    out, h = jax.vmap(one, in_axes=(0, 0, 0, 0))(gate_w, up_w, down_w,
                                                 buffers)
    for name, v in h.items():
        obs_health.record(name, jnp.sum(v, axis=0))
    return out


def moe_apply(p, x, policy: MiragePolicy, *, n_experts: int,
              experts_per_token: int, capacity_factor: float = 1.25,
              min_capacity: int = 4, opt=None) -> Tuple[jax.Array, jax.Array]:
    """x: (B, L, d) -> (out (B, L, d), aux_loss scalar)."""
    Bt, L, d = x.shape
    T = Bt * L
    E, K = n_experts, experts_per_token
    xf = x.reshape(T, d)
    xf = common.constrain(xf, opt, ("dp", None))
    # expert-parallel buffers when E divides TP, else capacity over dp
    tp = opt.axis_size(opt.act_tp) if (opt and opt.act_tp) else 1
    ep_ok = tp > 1 and E % tp == 0
    buf_roles = ("tp", None, None) if ep_ok else (None, "dp", None)

    logits = jnp.matmul(xf.astype(jnp.float32), p["router"]["w"])  # (T, E) fp32
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)                # (T, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    C = max(min_capacity, int(capacity_factor * T * K / E))

    # --- position of each (token, slot) inside its expert's buffer ---
    # processed slot-major so slot 0 (highest gate) gets priority.
    positions = []
    fill = jnp.zeros((E,), jnp.int32)
    for j in range(K):
        oh = jax.nn.one_hot(expert_ids[:, j], E, dtype=jnp.int32)  # (T, E)
        pos_within = jnp.cumsum(oh, axis=0) - 1                    # rank among slot-j picks
        pos = jnp.take_along_axis(pos_within, expert_ids[:, j:j+1], axis=1)[:, 0]
        pos = pos + fill[expert_ids[:, j]]
        fill = fill + jnp.sum(oh, axis=0)
        positions.append(pos)
    positions = jnp.stack(positions, axis=1)                       # (T, K)
    keep = positions < C                                           # overflow -> drop

    # --- dispatch: scatter tokens into (E, C, d) buffers ---
    e_flat = expert_ids.reshape(-1)
    pos_flat = jnp.where(keep, positions, C).reshape(-1)           # C = trash slot
    src = jnp.repeat(xf[:, None, :], K, axis=1).reshape(-1, d)
    buffers = jnp.zeros((E, C + 1, d), xf.dtype)
    buffers = buffers.at[e_flat, pos_flat].set(src)
    buffers = buffers[:, :C, :]
    buffers = common.constrain(buffers, opt, buf_roles)   # EP all-to-all here

    # --- expert FFNs (vmapped over E; Mirage GEMMs inside) ---
    out_buffers = _expert_ffn_vmapped(
        p["gate"], p["up"], p["down"], buffers, policy)            # (E, C, d)
    out_buffers = common.constrain(out_buffers, opt, buf_roles)

    # --- combine: gather each token's K results, weight by gates ---
    out_buffers = jnp.concatenate(
        [out_buffers, jnp.zeros((E, 1, d), out_buffers.dtype)], axis=1)
    gathered = out_buffers[e_flat, pos_flat].reshape(T, K, d)
    w = (gate_vals * keep).astype(gathered.dtype)
    out = jnp.einsum("tkd,tk->td", gathered, w)

    # --- load-balancing aux loss (Switch-style) ---
    me = jnp.mean(probs, axis=0)                                   # (E,)
    oh_top1 = jax.nn.one_hot(expert_ids[:, 0], E, dtype=jnp.float32)
    ce = jnp.mean(oh_top1, axis=0)
    aux = E * jnp.sum(me * ce)

    return out.reshape(Bt, L, d), aux


# ---------------------------------------------------------------------------
# Expert-parallel shard_map path (§Perf MoE structural fix)
# ---------------------------------------------------------------------------
#
# The GSPMD scatter-dispatch above lowers to scatter + all-reduce + gather +
# all-reduce chains against the model-sharded capacity buffers (measured:
# ~55% of the MoE train collective term). This path exploits that activations
# are REPLICATED across the model axis under our sharding plan: inside
# shard_map each model-rank routes its data-shard's tokens to ITS OWN E/tp
# experts entirely locally, and a single psum over 'model' combines the
# partial outputs — per layer the MoE communication collapses to one
# all-reduce of (tokens_local, d).

def _moe_local(xf, router_w, gate_w, up_w, down_w, *, E, K, C, model_axis,
               dp_axes, policy):
    """Per-device body. xf: (T_loc, d) local tokens (replicated over model);
    expert weights are the local (E_loc, ...) shard."""
    E_loc = gate_w.shape[0]
    m_idx = jax.lax.axis_index(model_axis)
    first = m_idx * E_loc

    logits = jnp.matmul(xf.astype(jnp.float32), router_w)          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    local_ids = expert_ids - first                                  # (T, K)
    is_mine = (local_ids >= 0) & (local_ids < E_loc)
    safe_ids = jnp.where(is_mine, local_ids, 0)

    # slot-major positions within each LOCAL expert buffer
    positions = []
    fill = jnp.zeros((E_loc,), jnp.int32)
    for j in range(K):
        oh = jax.nn.one_hot(safe_ids[:, j], E_loc, dtype=jnp.int32)
        oh = oh * is_mine[:, j:j + 1].astype(jnp.int32)
        pos_within = jnp.cumsum(oh, axis=0) - 1
        pos = jnp.take_along_axis(pos_within, safe_ids[:, j:j + 1], axis=1)[:, 0]
        pos = pos + fill[safe_ids[:, j]]
        fill = fill + jnp.sum(oh, axis=0)
        positions.append(pos)
    positions = jnp.stack(positions, axis=1)
    keep = is_mine & (positions < C)

    d = xf.shape[-1]
    e_flat = safe_ids.reshape(-1)
    pos_flat = jnp.where(keep, positions, C).reshape(-1)
    src = jnp.repeat(xf[:, None, :], K, axis=1).reshape(-1, d)
    buffers = jnp.zeros((E_loc, C + 1, d), xf.dtype)
    buffers = buffers.at[e_flat, pos_flat].set(src)[:, :C, :]

    out_buffers = _expert_ffn_vmapped(gate_w, up_w, down_w, buffers, policy)
    out_buffers = jnp.concatenate(
        [out_buffers, jnp.zeros((E_loc, 1, d), out_buffers.dtype)], axis=1)
    gathered = out_buffers[e_flat, pos_flat].reshape(-1, K, d)
    w = (gate_vals * keep).astype(gathered.dtype)
    partial = jnp.einsum("tkd,tk->td", gathered, w)
    out = jax.lax.psum(partial, model_axis)                         # combine

    # global-batch statistics: pmean the per-shard means BEFORE the product
    # (aux is nonlinear in the means — per-shard aux averaged would differ)
    me = jax.lax.pmean(jnp.mean(probs, axis=0), dp_axes)
    oh_top1 = jax.nn.one_hot(expert_ids[:, 0], E, dtype=jnp.float32)
    ce = jax.lax.pmean(jnp.mean(oh_top1, axis=0), dp_axes)
    aux = E * jnp.sum(me * ce)
    return out, aux


def moe_apply_ep(p, x, policy: MiragePolicy, *, n_experts: int,
                 experts_per_token: int, capacity_factor: float = 1.25,
                 min_capacity: int = 4, opt=None):
    """shard_map expert-parallel MoE. Requires E % tp == 0 and an activation
    sharding plan (opt.act_dp/act_tp); falls back to moe_apply otherwise."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    tp = opt.axis_size(opt.act_tp) if (opt and opt.act_tp) else 1
    if tp <= 1 or n_experts % tp != 0:
        return moe_apply(p, x, policy, n_experts=n_experts,
                         experts_per_token=experts_per_token,
                         capacity_factor=capacity_factor,
                         min_capacity=min_capacity, opt=opt)

    Bt, L, d = x.shape
    dp_total = opt.axis_size(opt.act_dp)
    T_loc = max((Bt // max(dp_total, 1)) * L, L)
    E, K = n_experts, experts_per_token
    C = max(min_capacity, int(capacity_factor * T_loc * K / E))

    from jax._src.mesh import thread_resources
    mesh = thread_resources.env.physical_mesh   # the `with mesh:` context
    if mesh.empty:
        return moe_apply(p, x, policy, n_experts=n_experts,
                         experts_per_token=experts_per_token,
                         capacity_factor=capacity_factor,
                         min_capacity=min_capacity, opt=opt)
    dp, tp_ax = opt.act_dp, opt.act_tp
    xf = x.reshape(Bt * L, d)

    fn = functools.partial(_moe_local, E=E, K=K, C=C, model_axis=tp_ax,
                           dp_axes=dp, policy=policy)

    def fn_no_health(*args):
        # shard_map body tracers cannot reach the enclosing health scope
        # (same wall as lax.cond branches) — suppress rather than leak
        with obs_health.suppressed():
            return fn(*args)

    out, aux = shard_map(
        fn_no_health, mesh=mesh,
        in_specs=(P(dp, None), P(None, None), P(tp_ax, None, None),
                  P(tp_ax, None, None), P(tp_ax, None, None)),
        out_specs=(P(dp, None), P()),
        check_rep=False,
    )(xf, p["router"]["w"], p["gate"], p["up"], p["down"])
    return out.reshape(Bt, L, d), aux
