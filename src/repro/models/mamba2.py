"""Mamba2 (SSD — state-space duality) block: chunked scan + O(1) decode.

Follows Dao & Gu (arXiv:2405.21060) with n_groups=1 (the 2.7B config): the
sequence is processed in chunks of Q tokens; within a chunk the quadratic
"attention-like" form runs on the MXU, between chunks a (H, P, N) state is
carried by ``lax.scan`` — so memory stays O(B*Q^2*H) regardless of L and the
same recurrence yields the single-token decode step.

Projections (in/out) go through the Mirage GEMM; the SSD recurrence itself is
elementwise/small-einsum state math and stays FP32, mirroring the paper's
"nonlinear ops stay digital FP32" split (DESIGN.md Section 4).
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.precision import MiragePolicy
from repro.models import common


def mamba_init(key, cfg):
    """Parameters for one Mamba2 block (n_groups = 1)."""
    d = cfg.d_model
    d_inner = cfg.d_inner
    H = cfg.ssm_heads
    N = cfg.ssm_state
    conv_dim = d_inner + 2 * N  # x, B, C share the causal conv
    ks = jax.random.split(key, 6)
    return {
        # order: [z (d_inner), x (d_inner), B (N), C (N), dt (H)]
        "in_proj": common.dense_init(ks[0], d, 2 * d_inner + 2 * N + H),
        "conv_w": jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim), jnp.float32) * 0.2,
        "conv_b": jnp.zeros((conv_dim,), jnp.float32),
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm": common.norm_init(d_inner),
        "out_proj": common.dense_init(ks[2], d_inner, d),
    }


def _split_proj(z_x_b_c_dt, d_inner: int, N: int, H: int):
    z = z_x_b_c_dt[..., :d_inner]
    x = z_x_b_c_dt[..., d_inner:2 * d_inner]
    B = z_x_b_c_dt[..., 2 * d_inner:2 * d_inner + N]
    C = z_x_b_c_dt[..., 2 * d_inner + N:2 * d_inner + 2 * N]
    dt = z_x_b_c_dt[..., 2 * d_inner + 2 * N:]
    return z, x, B, C, dt


def _causal_conv(u: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d. u: (B, L, C); w: (K, C)."""
    K = w.shape[0]
    up = jnp.pad(u, ((0, 0), (K - 1, 0), (0, 0)))
    # stack K shifted views — cheap and fusion-friendly for small K
    out = sum(up[:, i:i + u.shape[1], :] * w[i] for i in range(K))
    return out + b


def _segsum_decay(dA: jax.Array) -> jax.Array:
    """L[i, j] = exp(sum_{j<m<=i} dA_m) for i >= j else 0. dA: (B, Q, H).
    Returns (B, H, Q, Q)."""
    Bt, Q, H = dA.shape
    cs = jnp.cumsum(dA, axis=1)                       # (B, Q, H)
    diff = cs[:, :, None, :] - cs[:, None, :, :]      # (B, Qi, Qj, H)
    ii = jnp.arange(Q)
    mask = (ii[:, None] >= ii[None, :])[None, :, :, None]
    # mask BEFORE exp: masked lanes would overflow exp and poison gradients
    Lmat = jnp.exp(jnp.where(mask, diff, -1e30))
    return jnp.moveaxis(Lmat, 3, 1)                   # (B, H, Q, Q)


def ssd_scan(
    xh: jax.Array,      # (B, L, H, P)
    dt: jax.Array,      # (B, L, H)  — post-softplus
    A: jax.Array,       # (H,) negative
    Bm: jax.Array,      # (B, L, N)
    Cm: jax.Array,      # (B, L, N)
    chunk: int,
    init_state: Optional[jax.Array] = None,  # (B, H, P, N)
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD. Returns (y (B, L, H, P), final_state (B, H, P, N))."""
    Bt, L, H, P = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, L)
    pad = (-L) % Q
    if pad:
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    nc = xh.shape[1] // Q
    xc = xh.reshape(Bt, nc, Q, H, P)
    dtc = dt.reshape(Bt, nc, Q, H)
    Bc = Bm.reshape(Bt, nc, Q, N)
    Cc = Cm.reshape(Bt, nc, Q, N)

    state0 = (init_state if init_state is not None
              else jnp.zeros((Bt, H, P, N), jnp.float32))

    def step(state, inp):
        xq, dtq, Bq, Cq = inp          # (B, Q, H, P), (B, Q, H), (B, Q, N) x2
        dA = dtq * A                   # (B, Q, H)
        cs = jnp.cumsum(dA, axis=1)
        total = cs[:, -1, :]           # (B, H)
        # --- intra-chunk (diagonal block): y = (CB^T . L) (dt x) ---
        CB = jnp.einsum("bqn,bkn->bqk", Cq, Bq,
                        preferred_element_type=jnp.float32)
        Lmat = _segsum_decay(dA)       # (B, H, Q, Q)
        y_diag = jnp.einsum("bqk,bhqk,bkh,bkhp->bqhp", CB, Lmat, dtq, xq,
                            preferred_element_type=jnp.float32)
        # --- inter-chunk: contribution of the carried state ---
        y_off = jnp.einsum("bqn,bhpn->bqhp", Cq, state,
                           preferred_element_type=jnp.float32)
        y_off = y_off * jnp.exp(cs).transpose(0, 1, 2)[..., None]
        # --- state update: decay old state, absorb this chunk ---
        decay_to_end = jnp.exp(total[:, None, :] - cs)    # (B, Q, H)
        new_state = (state * jnp.exp(total)[:, :, None, None]
                     + jnp.einsum("bkn,bkh,bkhp->bhpn",
                                  Bq, dtq * decay_to_end, xq,
                                  preferred_element_type=jnp.float32))
        return new_state, y_diag + y_off

    final_state, ys = jax.lax.scan(
        step, state0,
        (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(dtc, 1, 0),
         jnp.moveaxis(Bc, 1, 0), jnp.moveaxis(Cc, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1).reshape(Bt, nc * Q, H, P)[:, :L]
    return y, final_state


def ssd_reference(xh, dt, A, Bm, Cm):
    """O(L) sequential oracle for tests: plain recurrence over tokens."""
    Bt, L, H, P = xh.shape
    N = Bm.shape[-1]

    def step(state, inp):
        x_t, dt_t, B_t, C_t = inp
        decay = jnp.exp(dt_t * A)                       # (B, H)
        state = (state * decay[:, :, None, None]
                 + jnp.einsum("bn,bh,bhp->bhpn", B_t, dt_t, x_t))
        y = jnp.einsum("bn,bhpn->bhp", C_t, state)
        return state, y

    state0 = jnp.zeros((Bt, H, P, N), jnp.float32)
    _, ys = jax.lax.scan(
        step, state0,
        (jnp.moveaxis(xh, 1, 0), jnp.moveaxis(dt, 1, 0),
         jnp.moveaxis(Bm, 1, 0), jnp.moveaxis(Cm, 1, 0)))
    return jnp.moveaxis(ys, 0, 1)


def mamba_apply(p, x, cfg, policy: MiragePolicy,
                init_state=None, conv_state=None, return_cache=False,
                opt=None):
    """Full Mamba2 block over a sequence. x: (B, L, d_model)."""
    Bt, L, d = x.shape
    d_inner, H, N, P = cfg.d_inner, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_headdim
    proj = common.dense(p["in_proj"], x, policy)
    z, xi, Bm, Cm, dt = _split_proj(proj, d_inner, N, H)
    # head-parallel layout: z/x/dt sharded over TP (head dim), B/C replicated
    z = common.constrain(z, opt, ("dp", None, "tp"))
    xi = common.constrain(xi, opt, ("dp", None, "tp"))
    Bm = common.constrain(Bm, opt, ("dp", None, None))
    Cm = common.constrain(Cm, opt, ("dp", None, None))
    dt = common.constrain(dt, opt, ("dp", None, "tp"))
    conv_in = jnp.concatenate([xi, Bm, Cm], axis=-1)
    if conv_state is not None:
        conv_src = jnp.concatenate([conv_state, conv_in], axis=1)
        conv = _causal_conv(conv_src, p["conv_w"], p["conv_b"])[:, conv_state.shape[1]:]
    else:
        conv_src = conv_in
        conv = _causal_conv(conv_in, p["conv_w"], p["conv_b"])
    conv = jax.nn.silu(conv)
    xi = conv[..., :d_inner]
    Bm = conv[..., d_inner:d_inner + N]
    Cm = conv[..., d_inner + N:]
    dt = jax.nn.softplus(dt + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = xi.reshape(Bt, L, H, P)
    xh = common.constrain(xh, opt, ("dp", None, "tp", None))
    y, state = ssd_scan(xh, dt, A, Bm, Cm, cfg.ssm_chunk, init_state)
    y = y + p["D"][None, None, :, None] * xh
    y = common.constrain(y, opt, ("dp", None, "tp", None))
    y = y.reshape(Bt, L, d_inner)
    y = common.norm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    out = common.dense(p["out_proj"], y, policy)
    if return_cache:
        K = cfg.ssm_conv
        T = conv_src.shape[1]
        new_conv_state = (conv_src[:, -(K - 1):, :] if T >= K - 1 else
                          jnp.pad(conv_src, ((0, 0), (K - 1 - T, 0), (0, 0))))
        return out, (state, new_conv_state)
    return out


def mamba_decode_step(p, x, cfg, policy: MiragePolicy, ssm_state, conv_state):
    """One-token decode. x: (B, 1, d). ssm_state: (B, H, P, N);
    conv_state: (B, K-1, conv_dim) of RAW (pre-conv) inputs."""
    Bt = x.shape[0]
    d_inner, H, N, P = cfg.d_inner, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_headdim
    proj = common.dense(p["in_proj"], x, policy)
    z, xi, Bm, Cm, dt = _split_proj(proj, d_inner, N, H)
    conv_in = jnp.concatenate([xi, Bm, Cm], axis=-1)       # (B, 1, C)
    window = jnp.concatenate([conv_state, conv_in], axis=1)  # (B, K, C)
    conv = jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"]
    conv = jax.nn.silu(conv)[:, None, :]
    new_conv_state = window[:, 1:, :]
    xi = conv[..., :d_inner]
    Bm = conv[..., d_inner:d_inner + N][:, 0]
    Cm = conv[..., d_inner + N:][:, 0]
    dt = jax.nn.softplus(dt + p["dt_bias"])[:, 0]          # (B, H)
    A = -jnp.exp(p["A_log"])
    xh = xi.reshape(Bt, H, P)
    decay = jnp.exp(dt * A)                                # (B, H)
    ssm_state = (ssm_state * decay[:, :, None, None]
                 + jnp.einsum("bn,bh,bhp->bhpn", Bm, dt, xh))
    y = jnp.einsum("bn,bhpn->bhp", Cm, ssm_state) + p["D"][None, :, None] * xh
    y = y.reshape(Bt, 1, d_inner)
    y = common.norm(p["norm"], y * jax.nn.silu(z), cfg.norm_eps)
    return common.dense(p["out_proj"], y, policy), ssm_state, new_conv_state
