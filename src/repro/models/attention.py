"""GQA attention: chunked (flash-style) training/prefill path + cached decode.

Memory discipline: scores are never materialized at (L x S). The prefill path
runs an online-softmax scan over KV chunks inside a map over Q chunks, so the
peak buffer is (B, q_chunk, H, kv_chunk). Supports causal masking, sliding
windows (mixtral), QK-norm (qwen3), cross-attention (seamless), and KV-head
repetition so kv heads can be sharded over large TP meshes.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.precision import MiragePolicy
from repro.models import common

NEG_INF = -1e30


class AttnParams(NamedTuple):
    pass  # attention params are plain dicts; see attn_init


def attn_init(key, d_model: int, n_heads: int, n_kv_heads: int, head_dim: int,
              qkv_bias: bool, qk_norm: bool, d_in: Optional[int] = None):
    ks = jax.random.split(key, 5)
    d_in = d_in or d_model
    p = {
        "q": common.dense_init(ks[0], d_in, n_heads * head_dim, qkv_bias),
        "k": common.dense_init(ks[1], d_in, n_kv_heads * head_dim, qkv_bias),
        "v": common.dense_init(ks[2], d_in, n_kv_heads * head_dim, qkv_bias),
        "o": common.dense_init(ks[3], n_heads * head_dim, d_model, False),
    }
    if qk_norm:
        p["q_norm"] = jnp.ones((head_dim,), jnp.float32)
        p["k_norm"] = jnp.ones((head_dim,), jnp.float32)
    return p


def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    """(B, S, Kv, D) -> (B, S, Kv*n_rep, D). Exact duplication, used to make
    kv heads divisible by the TP degree (value-identical; tested)."""
    if n_rep == 1:
        return k
    b, s, kv, d = k.shape
    return jnp.repeat(k, n_rep, axis=2)


def _chunk_mask(q_pos, k_pos, causal: bool, window: Optional[int]):
    """(Lq, Sk) boolean validity mask from absolute positions. Padded key
    slots carry position 2^30 and must be masked in the non-causal path too."""
    m = k_pos[None, :] < 2**29
    m = jnp.broadcast_to(m, (q_pos.shape[0], k_pos.shape[0]))
    if causal:
        m = m & (q_pos[:, None] >= k_pos[None, :])
    if window is not None:
        m = m & (q_pos[:, None] - k_pos[None, :] < window)
    return m


def chunked_attention(
    q: jax.Array,            # (B, Lq, H, D) — rope already applied
    k: jax.Array,            # (B, Sk, Kv, D)
    v: jax.Array,            # (B, Sk, Kv, D)
    q_positions: jax.Array,  # (Lq,) absolute positions
    k_positions: jax.Array,  # (Sk,)
    causal: bool = True,
    window: Optional[int] = None,
    q_chunk: int = 1024,
    kv_chunk: int = 1024,
    score_dtype=jnp.float32,
) -> jax.Array:
    """Online-softmax attention; returns (B, Lq, H, D).

    score_dtype=bfloat16 halves the HBM traffic of the materialized score/
    probability tensors (the dominant memory term of training cells — see
    EXPERIMENTS.md §Perf); running max/denominator/accumulator stay f32."""
    B, Lq, H, D = q.shape
    Sk, Kv = k.shape[1], k.shape[2]
    rep = H // Kv
    assert H % Kv == 0, (H, Kv)
    sm_scale = 1.0 / math.sqrt(D)

    qc = min(q_chunk, Lq)
    kc = min(kv_chunk, Sk)
    pad_q = (-Lq) % qc
    pad_k = (-Sk) % kc
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, (0, pad_q), constant_values=-1)
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        k_positions = jnp.pad(k_positions, (0, pad_k), constant_values=2**30)
    nq, nk = q.shape[1] // qc, k.shape[1] // kc

    # (B, nq, qc, Kv, rep, D) view of q; k/v chunked on axis 1.
    q5 = q.reshape(B, nq, qc, Kv, rep, D)
    k4 = k.reshape(B, nk, kc, Kv, D)
    v4 = v.reshape(B, nk, kc, Kv, D)
    qpos = q_positions.reshape(nq, qc)
    kpos = k_positions.reshape(nk, kc)

    def one_q_chunk(args):
        qi, qp = args  # (B, qc, Kv, rep, D), (qc,)

        def kv_step(carry, inp):
            acc, m_run, l_run = carry
            ki, vi, kp = inp  # (B, kc, Kv, D), (B, kc, Kv, D), (kc,)
            s = jnp.einsum("bqkrd,bskd->bqkrs",
                           qi.astype(score_dtype), ki.astype(score_dtype),
                           preferred_element_type=score_dtype) * sm_scale
            mask = _chunk_mask(qp, kp, causal, window)  # (qc, kc)
            neg = jnp.asarray(-3e4 if score_dtype == jnp.bfloat16 else NEG_INF,
                              score_dtype)
            s = jnp.where(mask[None, :, None, None, :], s, neg)
            m_new = jnp.maximum(m_run, jnp.max(s, axis=-1).astype(jnp.float32))
            p = jnp.exp(s - m_new[..., None].astype(score_dtype))
            alpha = jnp.exp(m_run - m_new)
            l_new = l_run * alpha + jnp.sum(p, axis=-1, dtype=jnp.float32)
            pv = jnp.einsum("bqkrs,bskd->bqkrd", p, vi.astype(score_dtype),
                            preferred_element_type=jnp.float32)
            acc_new = acc * alpha[..., None] + pv
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((B, qc, Kv, rep, D), jnp.float32)
        m0 = jnp.full((B, qc, Kv, rep), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, qc, Kv, rep), jnp.float32)
        (acc, m_run, l_run), _ = jax.lax.scan(
            kv_step, (acc0, m0, l0),
            (jnp.moveaxis(k4, 1, 0), jnp.moveaxis(v4, 1, 0), kpos))
        out = acc / jnp.maximum(l_run[..., None], 1e-30)
        return out  # (B, qc, Kv, rep, D)

    outs = jax.lax.map(one_q_chunk, (jnp.moveaxis(q5, 1, 0), qpos))
    out = jnp.moveaxis(outs, 0, 1).reshape(B, nq * qc, H, D)
    return out[:, :Lq]


def attn_apply(
    p, x, policy: MiragePolicy, *,
    n_heads: int, n_kv_heads: int, head_dim: int,
    positions: jax.Array, rope_theta: float,
    causal: bool = True, window: Optional[int] = None,
    qk_norm: bool = False, kv_repeat: int = 1,
    x_kv: Optional[jax.Array] = None, use_rope: bool = True,
    q_chunk: int = 1024, kv_chunk: int = 1024,
    kv_positions: Optional[jax.Array] = None, opt=None,
    skip_o_proj: bool = False,
):
    """Full attention block over a sequence (training / prefill path).

    x_kv: source for k/v (cross-attention); defaults to x (self-attention).
    skip_o_proj: return the pre-projection context (B, L, H*D) so the caller
    can merge the o-projection with another row-sharded GEMM (one TP
    all-reduce instead of two — §Perf iteration 3 for parallel blocks).
    Returns (out, (k_cache, v_cache)) so prefill can keep the projected KV.
    """
    B, L, _ = x.shape
    src = x if x_kv is None else x_kv
    S = src.shape[1]
    q = common.dense(p["q"], x, policy).reshape(B, L, n_heads, head_dim)
    k = common.dense(p["k"], src, policy).reshape(B, S, n_kv_heads, head_dim)
    v = common.dense(p["v"], src, policy).reshape(B, S, n_kv_heads, head_dim)
    if qk_norm:
        q = common.head_rmsnorm(p["q_norm"], q)
        k = common.head_rmsnorm(p["k_norm"], k)
    kv_pos = kv_positions if kv_positions is not None else (
        positions if x_kv is None else jnp.arange(S))
    if use_rope:
        q = common.apply_rope(q, positions, rope_theta)
        k = common.apply_rope(k, kv_pos, rope_theta)
    k = _repeat_kv(k, kv_repeat)
    v = _repeat_kv(v, kv_repeat)
    # Pin head-parallel layout: batch over dp, heads over tp (replicated when
    # the head count doesn't divide TP — never resharded mid-attention).
    q = common.constrain(q, opt, ("dp", None, "tp", None))
    k = common.constrain(k, opt, ("dp", None, "tp", None))
    v = common.constrain(v, opt, ("dp", None, "tp", None))
    score_dtype = (jnp.bfloat16 if opt is not None and
                   getattr(opt, "attn_dtype", "float32") == "bfloat16"
                   else jnp.float32)
    # Pallas flash kernel (TPU deployment path): valid for full-sequence
    # self-attention (contiguous positions starting at 0) — train/prefill.
    use_flash = (opt is not None and getattr(opt, "use_flash_kernel", False)
                 and x_kv is None and kv_positions is None)
    if use_flash:
        from repro.kernels.flash_attention import flash_attention
        out = flash_attention(
            q, k, v, causal=causal, window=window,
            interpret=getattr(policy, "interpret", True))
    else:
        out = chunked_attention(
            q, k, v, positions, kv_pos,
            causal=causal, window=window, q_chunk=q_chunk, kv_chunk=kv_chunk,
            score_dtype=score_dtype)
    out = out.reshape(B, L, n_heads * head_dim)
    out = common.constrain(out, opt, ("dp", None, "tp"))
    if skip_o_proj:
        return out, (k, v)
    return common.dense(p["o"], out, policy), (k, v)


def attn_decode_step(
    p, x, cache_k, cache_v, idx, policy: MiragePolicy, *,
    n_heads: int, n_kv_heads: int, head_dim: int, rope_theta: float,
    window: Optional[int] = None, qk_norm: bool = False, kv_repeat: int = 1,
    use_rope: bool = True, cross: bool = False,
    block_tables: Optional[jax.Array] = None,
):
    """One decode step. x: (B, 1, d). ``idx``: current length — a scalar
    (whole batch at one position: the per-slot oracle loop) or a ``(B,)``
    vector (continuous-batching engine: every slot decodes at its own
    position; write slots and validity masks are computed per row).

    Two cache layouts:

      * **dense** (``block_tables=None``): cache_k/v are ``(B, S_cap,
        Kv_eff, D)`` per-slot rings holding keys ALREADY rope'd at their
        absolute positions. Sliding windows use modular slot addressing
        (position p lives at slot ``p % S_cap``), so the cache capacity for
        SWA archs is min(seq, window). This is the parity oracle path.
      * **paged** (``block_tables`` = ``(B, max_blocks)`` int32): cache_k/v
        are GLOBAL page pools ``(n_blocks, block_size, Kv_eff, D)``; logical
        position p of row b lives at physical block ``block_tables[b,
        p // bs]`` offset ``p % bs`` (linear addressing, no ring wrap — the
        window is applied purely through the validity mask). Unmapped table
        entries carry the OOB sentinel ``n_blocks``: the scatter-write drops
        on device, and the gather clamps to a real block whose garbage is
        hidden by the ``kpos <= idx`` mask (the allocator guarantees blocks
        exist for every position <= idx). Requires vector ``idx``.

    Cross-attention reads a fixed precomputed dense cache and writes nothing.
    """
    B = x.shape[0]
    paged = block_tables is not None
    per_slot = jnp.ndim(idx) == 1
    assert not (paged and (cross or not per_slot)), \
        "paged decode needs a per-slot idx vector and a self-attention cache"
    q = common.dense(p["q"], x, policy).reshape(B, 1, n_heads, head_dim)
    if qk_norm:
        q = common.head_rmsnorm(p["q_norm"], q)
    rope_pos = jnp.reshape(idx, (B, 1)) if per_slot else jnp.reshape(idx, (1,))
    if use_rope:
        q = common.apply_rope(q, rope_pos, rope_theta)

    if not cross:
        knew = common.dense(p["k"], x, policy).reshape(B, 1, n_kv_heads, head_dim)
        vnew = common.dense(p["v"], x, policy).reshape(B, 1, n_kv_heads, head_dim)
        if qk_norm:
            knew = common.head_rmsnorm(p["k_norm"], knew)
        if use_rope:
            knew = common.apply_rope(knew, rope_pos, rope_theta)
        knew = _repeat_kv(knew, kv_repeat)
        vnew = _repeat_kv(vnew, kv_repeat)
        if paged:
            NB, bs = cache_k.shape[0], cache_k.shape[1]
            mb = block_tables.shape[1]
            LP = mb * bs
            blk = jnp.minimum(idx // bs, mb - 1)
            wb = jnp.where(idx < LP,
                           block_tables[jnp.arange(B), blk], NB)
            wo = jnp.mod(idx, bs)
            cache_k = cache_k.at[wb, wo].set(knew[:, 0], mode="drop")
            cache_v = cache_v.at[wb, wo].set(vnew[:, 0], mode="drop")
            keys = cache_k[jnp.minimum(block_tables, NB - 1)].reshape(
                B, LP, cache_k.shape[2], head_dim)
            vals = cache_v[jnp.minimum(block_tables, NB - 1)].reshape(
                B, LP, cache_v.shape[2], head_dim)
            kpos = jnp.arange(LP)
            idx_b = idx[:, None]
            valid = (kpos[None, :] <= idx_b) & \
                (kpos[None, :] >= (idx_b - (window - 1) if window else 0))
        else:
            S_cap = cache_k.shape[1]
            slot = jnp.mod(idx, S_cap)
            if per_slot:
                cache_k = cache_k.at[jnp.arange(B), slot].set(knew[:, 0])
                cache_v = cache_v.at[jnp.arange(B), slot].set(vnew[:, 0])
            else:
                cache_k = jax.lax.dynamic_update_slice(cache_k, knew,
                                                       (0, slot, 0, 0))
                cache_v = jax.lax.dynamic_update_slice(cache_v, vnew,
                                                       (0, slot, 0, 0))
            # absolute position held by each slot (after this write); per-row
            # when idx is a vector -> kpos/valid broadcast to (B, S_cap)
            slots = jnp.arange(S_cap)
            idx_b = idx[:, None] if per_slot else idx
            kpos = idx_b - jnp.mod(idx_b - slots, S_cap)
            valid = (kpos >= 0) & \
                (kpos >= (idx_b - (window - 1) if window else 0))
            keys, vals = cache_k, cache_v
    else:
        S_cap = cache_k.shape[1]
        valid = jnp.ones((S_cap,), bool)
        keys, vals = cache_k, cache_v

    Kv_eff = keys.shape[2]
    rep = n_heads // Kv_eff
    sm = 1.0 / math.sqrt(head_dim)
    q5 = q.reshape(B, 1, Kv_eff, rep, head_dim)
    s = jnp.einsum("bqkrd,bskd->bqkrs", q5, keys,
                   preferred_element_type=jnp.float32) * sm
    vmask = (valid[:, None, None, None, :] if valid.ndim == 2
             else valid[None, None, None, None, :])
    s = jnp.where(vmask, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqkrs,bskd->bqkrd", w, vals,
                     preferred_element_type=jnp.float32)
    out = out.reshape(B, 1, n_heads * head_dim)
    return common.dense(p["o"], out, policy), cache_k, cache_v


def attn_verify_step(
    p, x, cache_k, cache_v, idx, policy: MiragePolicy, *,
    n_heads: int, n_kv_heads: int, head_dim: int, rope_theta: float,
    window: Optional[int] = None, qk_norm: bool = False, kv_repeat: int = 1,
    block_tables: jax.Array = None,
):
    """Multi-token verify step for speculative decoding (paged cache only).

    x: ``(B, T, d)`` — per slot, the current token plus ``T-1`` draft
    tokens, occupying absolute positions ``idx[b] + j`` (``idx`` is the
    engine's per-slot position vector). All ``T`` keys/values are
    scatter-written through the block tables FIRST (the server reserves
    the blocks up front; OOB sentinel entries drop), then row ``j``
    attends over the gathered pages masked at ``kpos <= idx + j`` — the
    same write-then-gather contract as :func:`attn_chunk_step`, which is
    what makes rejected draft tails safe: their garbage KV sits at
    positions ``> idx + accepted`` and the NEXT verify tick re-writes
    exactly those positions before any gather reads them.
    """
    assert block_tables is not None, "verify step requires the paged layout"
    B, T = x.shape[0], x.shape[1]
    q = common.dense(p["q"], x, policy).reshape(B, T, n_heads, head_dim)
    knew = common.dense(p["k"], x, policy).reshape(B, T, n_kv_heads, head_dim)
    vnew = common.dense(p["v"], x, policy).reshape(B, T, n_kv_heads, head_dim)
    if qk_norm:
        q = common.head_rmsnorm(p["q_norm"], q)
        knew = common.head_rmsnorm(p["k_norm"], knew)
    pos = idx[:, None] + jnp.arange(T)[None, :]          # (B, T)
    q = common.apply_rope(q, pos, rope_theta)
    knew = common.apply_rope(knew, pos, rope_theta)
    knew = _repeat_kv(knew, kv_repeat)
    vnew = _repeat_kv(vnew, kv_repeat)

    NB, bs = cache_k.shape[0], cache_k.shape[1]
    mb = block_tables.shape[1]
    LP = mb * bs
    blk = jnp.minimum(pos // bs, mb - 1)
    wb = jnp.where(pos < LP,
                   jnp.take_along_axis(block_tables, blk, axis=1), NB)
    wo = jnp.mod(pos, bs)
    # positions within a slot are distinct, and slots never share a
    # writable block (the server's copy-on-write guard forks shared blocks
    # before any write), so the scatter indices are collision-free
    cache_k = cache_k.at[wb, wo].set(knew, mode="drop")
    cache_v = cache_v.at[wb, wo].set(vnew, mode="drop")
    keys = cache_k[jnp.minimum(block_tables, NB - 1)].reshape(
        B, LP, cache_k.shape[2], head_dim)
    vals = cache_v[jnp.minimum(block_tables, NB - 1)].reshape(
        B, LP, cache_v.shape[2], head_dim)
    kpos = jnp.arange(LP)
    valid = kpos[None, None, :] <= pos[:, :, None]       # (B, T, LP)
    if window:
        valid = valid & (kpos[None, None, :] > pos[:, :, None] - window)

    Kv_eff = keys.shape[2]
    rep = n_heads // Kv_eff
    sm = 1.0 / math.sqrt(head_dim)
    q5 = q.reshape(B, T, Kv_eff, rep, head_dim)
    s = jnp.einsum("btkrd,bskd->btkrs", q5, keys,
                   preferred_element_type=jnp.float32) * sm
    s = jnp.where(valid[:, :, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("btkrs,bskd->btkrd", w, vals,
                     preferred_element_type=jnp.float32)
    out = out.reshape(B, T, n_heads * head_dim)
    return common.dense(p["o"], out, policy), cache_k, cache_v


def attn_chunk_step(
    p, x, k_pages, v_pages, table_row, pos0, true_len,
    policy: MiragePolicy, *,
    n_heads: int, n_kv_heads: int, head_dim: int, rope_theta: float,
    window: Optional[int] = None, qk_norm: bool = False, kv_repeat: int = 1,
    q_chunk: int = 1024, kv_chunk: int = 1024,
):
    """Chunked-prefill attention for ONE serving slot over the paged cache.

    x: ``(1, C, d)`` — the next ``C`` prompt tokens of the slot, starting at
    absolute position ``pos0`` (traced). ``true_len <= C`` is the number of
    REAL tokens (attention families right-pad the final chunk; pads are
    dropped at the page write and masked in attention, so their garbage
    never enters the cache). k/v_pages are the global ``(n_blocks,
    block_size, Kv_eff, D)`` pools and ``table_row`` the slot's
    ``(max_blocks,)`` block table.

    The chunk's keys are scatter-written into the pages FIRST, then q
    attends over the gathered prefix+chunk with absolute positions — the
    same online-softmax ``chunked_attention`` as full prefill, so cross-
    chunk causality (and SWA windows) come from the position mask alone.
    """
    B, C = x.shape[0], x.shape[1]
    NB, bs = k_pages.shape[0], k_pages.shape[1]
    mb = table_row.shape[0]
    LP = mb * bs
    positions = pos0 + jnp.arange(C)
    q = common.dense(p["q"], x, policy).reshape(B, C, n_heads, head_dim)
    k = common.dense(p["k"], x, policy).reshape(B, C, n_kv_heads, head_dim)
    v = common.dense(p["v"], x, policy).reshape(B, C, n_kv_heads, head_dim)
    if qk_norm:
        q = common.head_rmsnorm(p["q_norm"], q)
        k = common.head_rmsnorm(p["k_norm"], k)
    q = common.apply_rope(q, positions, rope_theta)
    k = common.apply_rope(k, positions, rope_theta)
    k = _repeat_kv(k, kv_repeat)
    v = _repeat_kv(v, kv_repeat)
    # scatter the chunk into the pages; pads and positions beyond the table
    # capacity route to the OOB sentinel and drop on device
    j = jnp.arange(C)
    blk = jnp.minimum(positions // bs, mb - 1)
    dest = jnp.where((j < true_len) & (positions < LP), table_row[blk], NB)
    off = jnp.mod(positions, bs)
    k_pages = k_pages.at[dest, off].set(k[0], mode="drop")
    v_pages = v_pages.at[dest, off].set(v[0], mode="drop")
    # gather prefix + chunk; unwritten positions get kpos 2^30 (masked)
    kb = k_pages[jnp.minimum(table_row, NB - 1)].reshape(
        LP, k_pages.shape[2], head_dim)[None]
    vb = v_pages[jnp.minimum(table_row, NB - 1)].reshape(
        LP, v_pages.shape[2], head_dim)[None]
    kpos = jnp.arange(LP)
    kpos = jnp.where(kpos < pos0 + true_len, kpos, 2**30)
    out = chunked_attention(q, kb, vb, positions, kpos, causal=True,
                            window=window, q_chunk=q_chunk,
                            kv_chunk=kv_chunk)
    out = out.reshape(B, C, n_heads * head_dim)
    return common.dense(p["o"], out, policy), k_pages, v_pages
