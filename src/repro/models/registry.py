"""Model factory + abstract input specs for every (arch x shape) cell."""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core.precision import MiragePolicy, PAPER_POLICY
from repro.models.encdec import EncDec
from repro.models.lm import LM, LMCallOptions


def build_model(cfg: ModelConfig, policy: MiragePolicy = PAPER_POLICY,
                options: LMCallOptions = LMCallOptions()):
    if cfg.is_encdec:
        return EncDec(cfg, policy, options)
    return LM(cfg, policy, options)


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                options: LMCallOptions = LMCallOptions()) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    train/prefill: token batch (+ modality stubs). decode: one new token plus
    the KV/SSM cache of ``seq_len`` (the cache is an *input* of serve_step).
    """
    B, L = shape.global_batch, shape.seq_len
    sd = jax.ShapeDtypeStruct
    model = build_model(cfg, options=options)

    if cfg.is_encdec:
        # encoder consumes `L` frames; decoder trains on L//8 target tokens
        tgt = max(L // 8, 16)
        if shape.kind == "train":
            return {"frames": sd((B, L, cfg.frontend_dim), jnp.float32),
                    "tokens": sd((B, tgt), jnp.int32),
                    "labels": sd((B, tgt), jnp.int32)}
        if shape.kind == "prefill":
            return {"frames": sd((B, L, cfg.frontend_dim), jnp.float32),
                    "tokens": sd((B, tgt), jnp.int32)}
        cache = {k: sd(s, d) for k, (s, d)
                 in model.cache_spec(B, tgt, L).items()}
        return {"cache": cache, "tokens": sd((B, 1), jnp.int32)}

    extra = {}
    if cfg.frontend == "vit_stub":
        extra["patches"] = sd((B, cfg.frontend_len, cfg.frontend_dim),
                              jnp.float32)

    if shape.kind == "train":
        return {"tokens": sd((B, L), jnp.int32),
                "labels": sd((B, L), jnp.int32), **extra}
    if shape.kind == "prefill":
        return {"tokens": sd((B, L), jnp.int32), **extra}
    # decode: cache of seq_len capacity + one token
    cache = {k: sd(s, d) for k, (s, d) in model.cache_spec(B, L).items()}
    return {"cache": cache, "tokens": sd((B, 1), jnp.int32)}
