"""Model zoo: unified LM (dense/moe/ssm/hybrid/vlm) + enc-dec backbone."""

from repro.models.lm import LM, LMCallOptions
from repro.models.encdec import EncDec
from repro.models.registry import build_model, input_specs
